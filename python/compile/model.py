"""Layer-2: Qwen2-style decoder-only transformer in JAX (build time only).

The graph mirrors what MNN-LLM executes after its conversion pipeline
(paper §3): RMSNorm is fused (one kernel), attention is fused (one kernel),
Linear layers run on the combined-quantization scheme of §4.2:

  * attention projections + lm_head : W8A8  (lm_head prioritised to int8)
  * MLP projections                 : W4A8  (int4 weights, int8 activations)
  * embedding                       : bf16, **not in the graph** — the Rust
    engine streams embedding rows from the Flash tier (§4.1) and feeds the
    embedded hidden states in as the graph input.
  * KV cache                        : int8 asymmetric keys, fp8-e4m3 values.

Two entry points are lowered per model: ``prefill_fn`` (one per sequence
bucket) and ``decode_fn`` (single token against the cache). All weights are
graph *arguments* so the Rust runtime keeps them resident as PJRT buffers
(loaded once from artifacts/weights.bin).

fp8 values cross the PJRT boundary bit-cast as u8 — the xla crate has no f8
host type; the graph bitcasts back before use.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import quantize as qz
from .kernels import decode_attention, prefill_attention, rmsnorm, w4a8_matmul, w8a8_matmul


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Decoder-only transformer dimensions (Qwen2 family shapes)."""

    name: str
    vocab: int
    hidden: int
    inter: int
    layers: int
    heads: int
    kv_heads: int
    max_len: int  # static KV-cache capacity T
    rope_theta: float = 10000.0
    rms_eps: float = 1e-6

    @property
    def head_dim(self) -> int:
        return self.hidden // self.heads

    @property
    def kv_dim(self) -> int:
        return self.kv_heads * self.head_dim

    def param_count(self) -> int:
        """Total parameters (embedding + layers + lm_head), float-equivalent."""
        emb = self.vocab * self.hidden
        per_layer = (
            self.hidden * self.hidden  # wq
            + 2 * self.hidden * self.kv_dim  # wk, wv
            + self.hidden * self.hidden  # wo
            + self.hidden + 2 * self.kv_dim  # qkv biases
            + 3 * self.hidden * self.inter  # gate, up, down
            + 2 * self.hidden  # norms
        )
        return emb + self.layers * per_layer + self.hidden + self.vocab * self.hidden


TINY = ModelConfig("tiny-qwen2", vocab=2048, hidden=256, inter=704, layers=4,
                   heads=4, kv_heads=2, max_len=512)
SMALL = ModelConfig("small-qwen2", vocab=8192, hidden=384, inter=1056, layers=6,
                    heads=6, kv_heads=2, max_len=512)

CONFIGS = {c.name: c for c in (TINY, SMALL)}


# --------------------------------------------------------------------------
# Parameter construction (random init — see DESIGN.md §Substitutions: no
# pretrained weights offline; the paper measures speed, not accuracy).
# --------------------------------------------------------------------------

def _w8(rng, n, k, std):
    w = rng.normal(0.0, std, size=(n, k)).astype(np.float32)
    wq, ws, wb = qz.quantize_w8(jnp.asarray(w))
    return {"q": np.asarray(wq), "s": np.asarray(ws), "b": np.asarray(wb)}


def _w4(rng, n, k, std):
    w = rng.normal(0.0, std, size=(n, k)).astype(np.float32)
    wp, ws, wb = qz.quantize_w4(jnp.asarray(w))
    return {"q": np.asarray(wp), "s": np.asarray(ws), "b": np.asarray(wb)}


def build_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, np.ndarray]:
    """Deterministic quantized parameter set, keyed by flat names.

    Naming: ``L{i}.{wq|wk|wv|wo|gate|up|down}.{q|s|b}``, ``L{i}.{bq|bk|bv}``,
    ``L{i}.{ln1|ln2}``, ``fnorm``, ``lm_head.{q|s|b}``, plus ``embedding``
    (bf16, stored separately — never a graph argument).
    """
    rng = np.random.default_rng(seed)
    std = 0.4 / math.sqrt(cfg.hidden)
    p: Dict[str, np.ndarray] = {}
    p["embedding"] = rng.normal(0.0, 1.0, size=(cfg.vocab, cfg.hidden)).astype(np.float32)
    for i in range(cfg.layers):
        pre = f"L{i}."
        for nm, w in (
            ("wq", _w8(rng, cfg.hidden, cfg.hidden, std)),
            ("wk", _w8(rng, cfg.kv_dim, cfg.hidden, std)),
            ("wv", _w8(rng, cfg.kv_dim, cfg.hidden, std)),
            ("wo", _w8(rng, cfg.hidden, cfg.hidden, std)),
            ("gate", _w4(rng, cfg.inter, cfg.hidden, std)),
            ("up", _w4(rng, cfg.inter, cfg.hidden, std)),
            ("down", _w4(rng, cfg.hidden, cfg.inter, std)),
        ):
            for part, arr in w.items():
                p[pre + nm + "." + part] = arr
        p[pre + "bq"] = rng.normal(0.0, 0.02, size=(cfg.hidden,)).astype(np.float32)
        p[pre + "bk"] = rng.normal(0.0, 0.02, size=(cfg.kv_dim,)).astype(np.float32)
        p[pre + "bv"] = rng.normal(0.0, 0.02, size=(cfg.kv_dim,)).astype(np.float32)
        p[pre + "ln1"] = np.ones((cfg.hidden,), dtype=np.float32)
        p[pre + "ln2"] = np.ones((cfg.hidden,), dtype=np.float32)
    p["fnorm"] = np.ones((cfg.hidden,), dtype=np.float32)
    for part, arr in _w8(rng, cfg.vocab, cfg.hidden, std).items():
        p["lm_head." + part] = arr
    return p


def graph_weight_names(cfg: ModelConfig) -> List[str]:
    """Ordered weight-argument names for both lowered graphs (embedding is
    excluded — it lives in the Flash tier on the Rust side)."""
    names: List[str] = []
    for i in range(cfg.layers):
        pre = f"L{i}."
        for nm in ("wq", "wk", "wv", "wo", "gate", "up", "down"):
            names += [pre + nm + ".q", pre + nm + ".s", pre + nm + ".b"]
        names += [pre + "bq", pre + "bk", pre + "bv", pre + "ln1", pre + "ln2"]
    names += ["fnorm", "lm_head.q", "lm_head.s", "lm_head.b"]
    return names


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def _rope_angles(cfg: ModelConfig, positions):
    """positions: [S] i32 → (cos, sin) each [S, head_dim/2] f32."""
    half = cfg.head_dim // 2
    inv_freq = 1.0 / (cfg.rope_theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[:, None] * inv_freq[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def _apply_rope(x, cos, sin):
    """x: [heads, S, d]; rotate-half convention (HF Qwen2)."""
    d = x.shape[-1]
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    return jnp.concatenate(
        [x1 * cos[None] - x2 * sin[None], x2 * cos[None] + x1 * sin[None]], axis=-1
    )


# --------------------------------------------------------------------------
# Forward graphs
# --------------------------------------------------------------------------

def _linear8(x, w, pre):
    return w8a8_matmul(x, w[pre + ".q"], w[pre + ".s"], w[pre + ".b"])


def _linear4(x, w, pre):
    return w4a8_matmul(x, w[pre + ".q"], w[pre + ".s"], w[pre + ".b"])


def _mlp(x, w, pre):
    """SwiGLU MLP on the W4A8 path."""
    g = _linear4(x, w, pre + "gate")
    u = _linear4(x, w, pre + "up")
    return _linear4(jax.nn.silu(g) * u, w, pre + "down")


def prefill_fn(cfg: ModelConfig, hidden_in, *weights):
    """hidden_in: [S, hidden] f32 (embedded by the Rust engine).

    Returns (logits [S, vocab] f32,
             k_q [L,Hkv,T,d] i8, k_s [L,Hkv,T,1] f32, k_b [L,Hkv,T,1] f32,
             v_u8 [L,Hkv,T,d] u8  — fp8 bitcast).
    """
    names = graph_weight_names(cfg)
    w = dict(zip(names, weights))
    S = hidden_in.shape[0]
    T, L, Hkv, H, d = cfg.max_len, cfg.layers, cfg.kv_heads, cfg.heads, cfg.head_dim
    cos, sin = _rope_angles(cfg, jnp.arange(S, dtype=jnp.int32))
    x = hidden_in
    kq_all, ks_all, kb_all, v_all = [], [], [], []
    scale = 1.0 / math.sqrt(d)
    for i in range(L):
        pre = f"L{i}."
        h = rmsnorm(x, w[pre + "ln1"], eps=cfg.rms_eps)
        q = (_linear8(h, w, pre + "wq") + w[pre + "bq"]).reshape(S, H, d).transpose(1, 0, 2)
        k = (_linear8(h, w, pre + "wk") + w[pre + "bk"]).reshape(S, Hkv, d).transpose(1, 0, 2)
        v = (_linear8(h, w, pre + "wv") + w[pre + "bv"]).reshape(S, Hkv, d).transpose(1, 0, 2)
        q = _apply_rope(q, cos, sin) * scale  # pre-scaled query (§5.3)
        k = _apply_rope(k, cos, sin)
        attn = prefill_attention(q, k, v)  # [H, S, d]
        x = x + _linear8(attn.transpose(1, 0, 2).reshape(S, H * d), w, pre + "wo")
        x = x + _mlp(rmsnorm(x, w[pre + "ln2"], eps=cfg.rms_eps), w, pre)
        # Quantize fresh K/V into the static-capacity cache (§4.2).
        k_q, k_s, k_b = qz.quantize_key(k)  # [Hkv,S,d], [Hkv,S,1]
        v_f8 = qz.quantize_value_fp8(v)
        pad = [(0, 0), (0, T - S), (0, 0)]
        kq_all.append(jnp.pad(k_q, pad))
        ks_all.append(jnp.pad(k_s, pad))
        kb_all.append(jnp.pad(k_b, pad))
        v_all.append(jnp.pad(v_f8, pad))
    x = rmsnorm(x, w["fnorm"], eps=cfg.rms_eps)
    logits = _linear8(x, w, "lm_head")
    v_u8 = jax.lax.bitcast_convert_type(jnp.stack(v_all), jnp.uint8)
    return (
        logits,
        jnp.stack(kq_all),
        jnp.stack(ks_all),
        jnp.stack(kb_all),
        v_u8,
    )


def decode_fn(cfg: ModelConfig, hidden_in, pos, k_q, k_s, k_b, v_u8, *weights):
    """One decode step.

    hidden_in: [1, hidden] f32; pos: [1] i32 (index of this token);
    caches as produced by prefill_fn. Returns (logits [1, vocab], updated
    caches) — cache updates happen in-graph via dynamic_update_slice, so the
    Rust side just threads PJRT buffers between steps.
    """
    names = graph_weight_names(cfg)
    w = dict(zip(names, weights))
    L, Hkv, H, d, T = cfg.layers, cfg.kv_heads, cfg.heads, cfg.head_dim, cfg.max_len
    v_f8 = jax.lax.bitcast_convert_type(v_u8, jnp.float8_e4m3fn)
    cos, sin = _rope_angles(cfg, pos)  # [1, d/2]
    x = hidden_in
    scale = 1.0 / math.sqrt(d)
    for i in range(L):
        pre = f"L{i}."
        h = rmsnorm(x, w[pre + "ln1"], eps=cfg.rms_eps)
        q = (_linear8(h, w, pre + "wq") + w[pre + "bq"]).reshape(1, H, d).transpose(1, 0, 2)
        k = (_linear8(h, w, pre + "wk") + w[pre + "bk"]).reshape(1, Hkv, d).transpose(1, 0, 2)
        v = (_linear8(h, w, pre + "wv") + w[pre + "bv"]).reshape(1, Hkv, d).transpose(1, 0, 2)
        q = _apply_rope(q, cos, sin) * scale
        k = _apply_rope(k, cos, sin)
        new_kq, new_ks, new_kb = qz.quantize_key(k)  # [Hkv,1,d],[Hkv,1,1]
        new_v = qz.quantize_value_fp8(v)
        p = pos[0]
        k_q = jax.lax.dynamic_update_slice(k_q, new_kq[None], (i, 0, p, 0))
        k_s = jax.lax.dynamic_update_slice(k_s, new_ks[None], (i, 0, p, 0))
        k_b = jax.lax.dynamic_update_slice(k_b, new_kb[None], (i, 0, p, 0))
        v_f8 = jax.lax.dynamic_update_slice(v_f8, new_v[None], (i, 0, p, 0))
        attn = decode_attention(q, k_q[i], k_s[i], k_b[i], v_f8[i], pos)  # [H,1,d]
        x = x + _linear8(attn.transpose(1, 0, 2).reshape(1, H * d), w, pre + "wo")
        x = x + _mlp(rmsnorm(x, w[pre + "ln2"], eps=cfg.rms_eps), w, pre)
    x = rmsnorm(x, w["fnorm"], eps=cfg.rms_eps)
    logits = _linear8(x, w, "lm_head")
    return logits, k_q, k_s, k_b, jax.lax.bitcast_convert_type(v_f8, jnp.uint8)


# --------------------------------------------------------------------------
# Pure-python reference generation (used by tests and to cross-check Rust)
# --------------------------------------------------------------------------

def reference_generate(cfg: ModelConfig, params: Dict[str, np.ndarray],
                       prompt_ids: List[int], gen: int, bucket: int) -> Tuple[List[int], np.ndarray]:
    """End-to-end greedy generation in pure JAX, using the same graphs that
    get lowered. Returns (token ids, prefill last-row logits)."""
    names = graph_weight_names(cfg)
    weights = [jnp.asarray(params[n]) for n in names]
    emb = params["embedding"]
    S = bucket
    ids = list(prompt_ids)
    hidden = np.zeros((S, cfg.hidden), dtype=np.float32)
    hidden[: len(ids)] = emb[np.asarray(ids)]
    logits, k_q, k_s, k_b, v_u8 = prefill_fn(cfg, jnp.asarray(hidden), *weights)
    last = np.asarray(logits)[len(ids) - 1]
    nxt = int(np.argmax(last))
    out = [nxt]
    for step in range(gen - 1):
        pos = len(ids) + step
        h = jnp.asarray(emb[nxt][None].astype(np.float32))
        logits, k_q, k_s, k_b, v_u8 = decode_fn(
            cfg, h, jnp.asarray([pos], dtype=jnp.int32), k_q, k_s, k_b, v_u8, *weights
        )
        nxt = int(np.argmax(np.asarray(logits)[0]))
        out.append(nxt)
    return out, last
