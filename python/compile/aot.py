"""AOT exporter: lower the L2 graphs once, write self-contained artifacts.

Outputs (under ``artifacts/``):

  manifest.json       model config, weight table, graph arg/result orders
  weights.bin         quantized graph weights (custom container, see below)
  embedding.bin       bf16 embedding rows — streamed from the Flash tier by
                      the Rust engine, never a graph argument (§4.1)
  prefill_{S}.hlo.txt one per sequence bucket
  decode.hlo.txt      single-token step

Interchange is HLO *text*: jax ≥ 0.5 serializes HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version behind the Rust
``xla`` crate) rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

weights.bin layout (little-endian):
  magic "MNNW" | u32 version=1 | u32 tensor_count
  per tensor: u16 name_len | name (utf8) | u8 dtype | u8 ndim |
              u32 dims[ndim] | u64 nbytes | raw bytes
  dtype codes: 0=f32, 1=i8, 2=u8, 3=bf16, 4=i32
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import struct
import sys
from typing import Dict, List

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np
from jax._src.lib import xla_client as xc

from .model import CONFIGS, ModelConfig, build_params, decode_fn, graph_weight_names, prefill_fn

PREFILL_BUCKETS = (16, 64, 256)

_DTYPE_CODES = {
    np.dtype(np.float32): 0,
    np.dtype(np.int8): 1,
    np.dtype(np.uint8): 2,
    np.dtype(ml_dtypes.bfloat16): 3,
    np.dtype(np.int32): 4,
}


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-compatible path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write_weights_bin(path: str, tensors: Dict[str, np.ndarray]) -> List[dict]:
    """Write the container; return the manifest weight table."""
    table = []
    with open(path, "wb") as f:
        f.write(b"MNNW")
        f.write(struct.pack("<II", 1, len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            code = _DTYPE_CODES[arr.dtype]
            nb = arr.nbytes
            f.write(struct.pack("<H", len(name)))
            f.write(name.encode())
            f.write(struct.pack("<BB", code, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(struct.pack("<Q", nb))
            f.write(arr.tobytes())
            table.append({"name": name, "dtype": code, "shape": list(arr.shape), "nbytes": nb})
    return table


def export(cfg: ModelConfig, out_dir: str, seed: int = 0) -> None:
    os.makedirs(out_dir, exist_ok=True)
    params = build_params(cfg, seed=seed)

    # Embedding → bf16 flash file. build_params already bf16-rounds the f32
    # copy used by the reference path so Rust (bf16→f32) matches exactly.
    emb_bf16 = params["embedding"].astype(ml_dtypes.bfloat16)
    params["embedding"] = emb_bf16.astype(np.float32)
    with open(os.path.join(out_dir, "embedding.bin"), "wb") as f:
        f.write(emb_bf16.tobytes())

    names = graph_weight_names(cfg)
    graph_weights = {n: params[n] for n in names}
    weight_table = write_weights_bin(os.path.join(out_dir, "weights.bin"), graph_weights)

    w_structs = [jax.ShapeDtypeStruct(params[n].shape, params[n].dtype) for n in names]
    T, L, Hkv, d = cfg.max_len, cfg.layers, cfg.kv_heads, cfg.head_dim

    graphs = {}
    for S in PREFILL_BUCKETS:
        if S > cfg.max_len:
            continue
        fn = functools.partial(prefill_fn, cfg)
        lowered = jax.jit(fn).lower(
            jax.ShapeDtypeStruct((S, cfg.hidden), jnp.float32), *w_structs
        )
        fname = f"prefill_{S}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(to_hlo_text(lowered))
        graphs[f"prefill_{S}"] = {
            "file": fname,
            "args": ["hidden"] + names,
            "results": ["logits", "k_q", "k_s", "k_b", "v_u8"],
            "bucket": S,
        }

    fn = functools.partial(decode_fn, cfg)
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((1, cfg.hidden), jnp.float32),
        jax.ShapeDtypeStruct((1,), jnp.int32),
        jax.ShapeDtypeStruct((L, Hkv, T, d), jnp.int8),
        jax.ShapeDtypeStruct((L, Hkv, T, 1), jnp.float32),
        jax.ShapeDtypeStruct((L, Hkv, T, 1), jnp.float32),
        jax.ShapeDtypeStruct((L, Hkv, T, d), jnp.uint8),
        *w_structs,
    )
    with open(os.path.join(out_dir, "decode.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    graphs["decode"] = {
        "file": "decode.hlo.txt",
        "args": ["hidden", "pos", "k_q", "k_s", "k_b", "v_u8"] + names,
        "results": ["logits", "k_q", "k_s", "k_b", "v_u8"],
    }

    manifest = {
        "model": {
            "name": cfg.name,
            "vocab": cfg.vocab,
            "hidden": cfg.hidden,
            "inter": cfg.inter,
            "layers": cfg.layers,
            "heads": cfg.heads,
            "kv_heads": cfg.kv_heads,
            "head_dim": cfg.head_dim,
            "max_len": cfg.max_len,
            "rope_theta": cfg.rope_theta,
            "rms_eps": cfg.rms_eps,
            "param_count": cfg.param_count(),
        },
        "seed": seed,
        "prefill_buckets": [s for s in PREFILL_BUCKETS if s <= cfg.max_len],
        "weights": weight_table,
        "embedding": {
            "file": "embedding.bin",
            "dtype": "bf16",
            "shape": [cfg.vocab, cfg.hidden],
        },
        "graphs": graphs,
        "tokenizer": {"kind": "byte", "vocab": cfg.vocab},
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"exported {cfg.name} → {out_dir} "
          f"({len(weight_table)} weight tensors, {len(graphs)} graphs)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default="tiny-qwen2", choices=sorted(CONFIGS))
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    export(CONFIGS[args.model], args.out_dir, seed=args.seed)


if __name__ == "__main__":
    sys.exit(main())
