"""Pallas W8A8 / W4A8 quantized matmul kernels (paper §4.2 + §5.1).

Hardware adaptation (DESIGN.md §5): the paper tiles int8 GEMM for ARM
register files (e_p × h_p accumulator blocks, l_p = instruction width).
On TPU the analogous resources are VMEM blocks feeding the MXU, so the
kernel expresses the same schedule as a Pallas grid over (m, n) output
blocks with the full reduction dimension resident per block:

  grid = (m/bm, n/bn);  x block [bm, k];  w block [bn, k];  out block [bm, bn]

Activation quantization is *dynamic per row* (the paper quantizes
activations to int8 at runtime), fused into the kernel so the fp32
activation never round-trips to HBM in quantized form.

Kernels run under interpret=True — CPU PJRT cannot execute Mosaic
custom-calls; real-TPU perf is estimated in DESIGN/EXPERIMENTS from the
VMEM footprint and MXU utilization of these block shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INT8_MIN, INT8_MAX = -128, 127


def _quant_rows(x):
    """Per-row dynamic asymmetric int8 quantization of a [bm, k] block."""
    x_min = jnp.min(x, axis=-1, keepdims=True)
    x_max = jnp.max(x, axis=-1, keepdims=True)
    rng = jnp.maximum(x_max - x_min, 1e-8)
    scale = rng / float(INT8_MAX - INT8_MIN)
    bias = x_min - INT8_MIN * scale
    q = jnp.clip(jnp.round((x - bias) / scale), INT8_MIN, INT8_MAX).astype(jnp.int8)
    return q, scale, bias


def _affine_block(x, w_q_i32, w_scale, w_bias):
    """Integer GEMM + affine corrections for one (bm, bn) output block.

    x: [bm, k] f32; w_q_i32: [bn, k] i32; w_scale/w_bias: [bn, 1] f32.
    """
    k = x.shape[-1]
    x_q, sx, bx = _quant_rows(x)
    acc = jax.lax.dot_general(
        x_q.astype(jnp.int32),
        w_q_i32,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    ).astype(jnp.float32)
    xq_row = jnp.sum(x_q.astype(jnp.int32), axis=-1, keepdims=True).astype(jnp.float32)
    wq_row = jnp.sum(w_q_i32, axis=-1, keepdims=True).astype(jnp.float32)
    return (
        sx * w_scale.T * acc
        + sx * w_bias.T * xq_row
        + bx * w_scale.T * wq_row.T
        + k * bx * w_bias.T
    )


def _w8a8_kernel(x_ref, wq_ref, ws_ref, wb_ref, o_ref):
    o_ref[...] = _affine_block(
        x_ref[...], wq_ref[...].astype(jnp.int32), ws_ref[...], wb_ref[...]
    )


def _w4a8_kernel(x_ref, wp_ref, ws_ref, wb_ref, o_ref):
    packed = wp_ref[...]  # [bn, k//2] u8
    lo = (packed & 0xF).astype(jnp.int32)
    hi = (packed >> 4).astype(jnp.int32)
    # Interleave nibbles back to [bn, k]: even k-index = low nibble.
    bn, half = packed.shape
    w_q = jnp.stack([lo, hi], axis=-1).reshape(bn, half * 2)
    o_ref[...] = _affine_block(x_ref[...], w_q, ws_ref[...], wb_ref[...])


def _pick_block(dim: int, pref: int) -> int:
    """Largest divisor of `dim` that is <= pref (block shapes must tile)."""
    b = min(pref, dim)
    while dim % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def w8a8_matmul(x, w_q, w_scale, w_bias, *, block_m: int = 16, block_n: int = 128):
    """x:[m,k] f32 × asymmetric-int8 w_q:[n,k] → [m,n] f32 (W8A8 path)."""
    m, k = x.shape
    n = w_q.shape[0]
    bm, bn = _pick_block(m, block_m), _pick_block(n, block_n)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _w8a8_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w_q, w_scale, w_bias)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def w4a8_matmul(x, w_packed, w_scale, w_bias, *, block_m: int = 16, block_n: int = 128):
    """x:[m,k] f32 × packed-4-bit w:[n,k/2] u8 → [m,n] f32 (W4A8 path)."""
    m, k = x.shape
    n = w_packed.shape[0]
    bm, bn = _pick_block(m, block_m), _pick_block(n, block_n)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _w4a8_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k // 2), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w_packed, w_scale, w_bias)
