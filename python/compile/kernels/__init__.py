"""Layer-1 Pallas kernels (build-time only; lowered with interpret=True)."""

from .qmatmul import w4a8_matmul, w8a8_matmul
from .rmsnorm import rmsnorm
from .attention import decode_attention, prefill_attention

__all__ = [
    "w8a8_matmul",
    "w4a8_matmul",
    "rmsnorm",
    "decode_attention",
    "prefill_attention",
]
