"""Pallas attention kernels with combined KV-cache quantization (§4.2, §5.3).

Two kernels:

* ``decode_attention`` — one query token against the full quantized cache:
  int8 asymmetric keys (per-token scale/bias; the reduced dim head_dim is
  fixed so per-token params are stable) and fp8-e4m3 values (stat-free, so
  appends never re-quantize history). Softmax runs in fp32 and the query is
  pre-scaled by 1/sqrt(d) *before* QK^T so fp16-ish magnitudes cannot
  overflow the accumulation (paper §5.3).

* ``prefill_attention`` — causal self-attention over fresh fp32 K/V, fp32
  softmax, grid over query heads (GQA mapping done via BlockSpec index_map,
  the TPU analogue of the paper's per-head work-item split).

Both use interpret=True (CPU PJRT cannot run Mosaic custom-calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")


def _decode_kernel(q_ref, kq_ref, ks_ref, kb_ref, v_ref, pos_ref, o_ref):
    # Blocks: q [1, 1, d]; k_q [1, T, d] i8; ks/kb [1, T, 1]; v [1, T, d] f8.
    q = q_ref[0].astype(jnp.float32)  # [1, d] (pre-scaled by 1/sqrt(d))
    k = kq_ref[0].astype(jnp.float32) * ks_ref[0] + kb_ref[0]  # [T, d]
    v = v_ref[0].astype(jnp.float32)  # [T, d]
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [1, T] fp32
    t = scores.shape[-1]
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, t), 1)
    scores = jnp.where(idx <= pos_ref[0], scores, NEG_INF)
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    o_ref[0] = jax.lax.dot_general(
        probs, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


@jax.jit
def decode_attention(q, k_q, k_scale, k_bias, v_f8, pos):
    """q:[H,1,d] f32 (pre-scaled), k_q:[Hkv,T,d] i8, k_scale/k_bias:[Hkv,T,1],
    v_f8:[Hkv,T,d] f8e4m3, pos: [1] i32 → [H,1,d] f32."""
    H, _, d = q.shape
    Hkv, T, _ = k_q.shape
    group = H // Hkv
    kv_map = lambda h: (h // group, 0, 0)  # noqa: E731 — GQA head→kv-head map
    return pl.pallas_call(
        _decode_kernel,
        grid=(H,),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, T, d), kv_map),
            pl.BlockSpec((1, T, 1), kv_map),
            pl.BlockSpec((1, T, 1), kv_map),
            pl.BlockSpec((1, T, d), kv_map),
            pl.BlockSpec((1,), lambda h: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda h: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((H, 1, d), jnp.float32),
        interpret=True,
    )(q, k_q, k_scale, k_bias, v_f8, pos)


def _prefill_kernel(q_ref, k_ref, v_ref, o_ref):
    q = q_ref[0].astype(jnp.float32)  # [S, d]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    s = q.shape[0]
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [S, S]
    qi = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    scores = jnp.where(ki <= qi, scores, NEG_INF)
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    o_ref[0] = jax.lax.dot_general(
        probs, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


@jax.jit
def prefill_attention(q, k, v):
    """Causal GQA attention. q:[H,S,d] f32 (pre-scaled), k/v:[Hkv,S,d] → [H,S,d]."""
    H, S, d = q.shape
    Hkv = k.shape[0]
    group = H // Hkv
    kv_map = lambda h: (h // group, 0, 0)  # noqa: E731
    return pl.pallas_call(
        _prefill_kernel,
        grid=(H,),
        in_specs=[
            pl.BlockSpec((1, S, d), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, S, d), kv_map),
            pl.BlockSpec((1, S, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, S, d), lambda h: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((H, S, d), jnp.float32),
        interpret=True,
    )(q, k, v)
