"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``ref_*`` function implements the same math as the corresponding
kernel in this package, with no Pallas involved, so pytest can compare the
two under hypothesis-driven shape/dtype sweeps.
"""

from __future__ import annotations

import jax.numpy as jnp

from ..quantize import (
    INT8_MAX,
    INT8_MIN,
    asym_quant_params,
    asym_quantize,
    dequantize_value_fp8,
    unpack_w4,
)


def quantize_activation_rows(x):
    """Dynamic per-row asymmetric int8 activation quantization.
    x: [m, k] f32 → (x_q i8, scale [m,1], bias [m,1])."""
    scale, bias = asym_quant_params(x, INT8_MIN, INT8_MAX, axis=-1)
    x_q = asym_quantize(x, scale, bias, INT8_MIN, INT8_MAX, jnp.int8)
    return x_q, scale, bias


def _affine_gemm(x, w_q_i32, w_scale, w_bias):
    """Shared integer-GEMM-with-corrections math.

    With x = x_q*sx + bx (per row) and w = w_q*sw + bw (per out-channel):
      x·wᵀ = sx·sw (x_q·w_qᵀ) + sx·bw Σ_k x_q + bx·sw Σ_k w_q + k·bx·bw
    """
    m, k = x.shape
    x_q, sx, bx = quantize_activation_rows(x)
    acc = jnp.matmul(
        x_q.astype(jnp.int32), w_q_i32.T, preferred_element_type=jnp.int32
    ).astype(jnp.float32)
    xq_row = jnp.sum(x_q.astype(jnp.int32), axis=-1, keepdims=True).astype(jnp.float32)
    wq_row = jnp.sum(w_q_i32, axis=-1, keepdims=True).astype(jnp.float32)
    return (
        sx * w_scale.T * acc
        + sx * w_bias.T * xq_row
        + bx * w_scale.T * wq_row.T
        + k * bx * w_bias.T
    )


def ref_w8a8_matmul(x, w_q, w_scale, w_bias):
    """x:[m,k] f32, w_q:[n,k] i8, w_scale/w_bias:[n,1] f32 → [m,n] f32."""
    return _affine_gemm(x, w_q.astype(jnp.int32), w_scale, w_bias)


def ref_w4a8_matmul(x, w_packed, w_scale, w_bias):
    """Same math with 4-bit packed weights (nibbles 0..15)."""
    return _affine_gemm(x, unpack_w4(w_packed), w_scale, w_bias)


def ref_rmsnorm(x, w, eps: float = 1e-6):
    """RMSNorm computed in fp32 (paper fuses this at conversion time)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return x32 * (1.0 / jnp.sqrt(var + eps)) * w.astype(jnp.float32)


def ref_decode_attention(q, k_q, k_scale, k_bias, v_f8, pos):
    """Single-token GQA attention over a quantized KV cache.

    q:       [H, 1, d] f32 — already pre-scaled by 1/sqrt(d) (§5.3)
    k_q:     [Hkv, T, d] i8, k_scale/k_bias: [Hkv, T, 1]
    v_f8:    [Hkv, T, d] fp8e4m3
    pos:     scalar i32; positions [0, pos] are valid cache entries
    returns  [H, 1, d] f32
    """
    H = q.shape[0]
    Hkv, T, d = k_q.shape
    group = H // Hkv
    k = k_q.astype(jnp.float32) * k_scale + k_bias  # [Hkv, T, d]
    v = dequantize_value_fp8(v_f8)
    kh = jnp.repeat(k, group, axis=0)  # [H, T, d]
    vh = jnp.repeat(v, group, axis=0)
    scores = jnp.einsum("hqd,htd->hqt", q.astype(jnp.float32), kh)  # fp32 softmax path
    idx = jnp.arange(T)[None, None, :]
    scores = jnp.where(idx <= pos, scores, -jnp.inf)
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return jnp.einsum("hqt,htd->hqd", probs, vh)


def ref_prefill_attention(q, k, v):
    """Causal GQA attention, fp32 softmax. q:[H,S,d] (pre-scaled), k/v:[Hkv,S,d]."""
    H, S, d = q.shape
    Hkv = k.shape[0]
    group = H // Hkv
    kh = jnp.repeat(k, group, axis=0)
    vh = jnp.repeat(v, group, axis=0)
    scores = jnp.einsum("hqd,htd->hqt", q.astype(jnp.float32), kh.astype(jnp.float32))
    qi = jnp.arange(S)[None, :, None]
    ki = jnp.arange(S)[None, None, :]
    scores = jnp.where(ki <= qi, scores, -jnp.inf)
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return jnp.einsum("hqt,htd->hqd", probs, vh.astype(jnp.float32))
