"""Pallas RMSNorm kernel (fp32 accumulation — paper §5.3 mixed precision).

The paper fuses RMSNorm at model-conversion time and keeps the reduction in
fp32 even when the surrounding compute is fp16. Here the whole kernel is
fp32-accumulating regardless of input dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # [bs, hidden]
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = x * (1.0 / jnp.sqrt(var + eps)) * w_ref[...].astype(jnp.float32)


def _pick_block(dim: int, pref: int) -> int:
    b = min(pref, dim)
    while dim % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("eps", "block_s"))
def rmsnorm(x, w, *, eps: float = 1e-6, block_s: int = 64):
    """x: [s, hidden], w: [hidden] → [s, hidden] f32."""
    s, hidden = x.shape
    bs = _pick_block(s, block_s)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(s // bs,),
        in_specs=[
            pl.BlockSpec((bs, hidden), lambda i: (i, 0)),
            pl.BlockSpec((hidden,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bs, hidden), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, hidden), jnp.float32),
        interpret=True,
    )(x, w)
