"""Asymmetric quantization helpers (paper §4.2, Eq. 1).

The paper quantizes weights with an asymmetric affine scheme:

    w_q = round((w - w_min) / step) + clip_min,   step = (w_max - w_min) / (clip_max - clip_min)

which dequantizes as ``w ≈ w_q * scale + bias`` with

    scale = step,  bias = w_min - clip_min * step.

We carry the (scale, bias) form everywhere — it makes the integer-GEMM
correction terms linear (see kernels/qmatmul.py).

All functions are pure jnp so they can run both at model-build time
(weight quantization) and inside the lowered graphs (KV-cache / activation
quantization).
"""

from __future__ import annotations

import jax.numpy as jnp


def asym_quant_params(x, clip_min: int, clip_max: int, axis=-1, eps: float = 1e-8):
    """Per-`axis`-slice asymmetric (scale, bias) for quantizing x into
    [clip_min, clip_max]. Returns (scale, bias) with the reduced axis kept."""
    x_min = jnp.min(x, axis=axis, keepdims=True)
    x_max = jnp.max(x, axis=axis, keepdims=True)
    rng = jnp.maximum(x_max - x_min, eps)
    scale = rng / float(clip_max - clip_min)
    bias = x_min - clip_min * scale
    return scale, bias


def asym_quantize(x, scale, bias, clip_min: int, clip_max: int, dtype):
    """Quantize with precomputed (scale, bias); clamps to the clip range."""
    q = jnp.round((x - bias) / scale)
    q = jnp.clip(q, clip_min, clip_max)
    return q.astype(dtype)


def asym_dequantize(q, scale, bias):
    return q.astype(jnp.float32) * scale + bias


# --- int8 weights / activations (W8A8 CPU path) -----------------------------

INT8_MIN, INT8_MAX = -128, 127


def quantize_w8(w):
    """Per-output-channel asymmetric int8. w: [n, k] → (w_q i8, scale [n,1], bias [n,1])."""
    scale, bias = asym_quant_params(w, INT8_MIN, INT8_MAX, axis=-1)
    w_q = asym_quantize(w, scale, bias, INT8_MIN, INT8_MAX, jnp.int8)
    return w_q, scale, bias


# --- int4 weights (W4A8), packed two nibbles per byte ------------------------

INT4_MIN, INT4_MAX = 0, 15  # unsigned nibble with affine bias


def quantize_w4(w):
    """Per-output-channel asymmetric 4-bit. w: [n, k] (k even)
    → (packed u8 [n, k//2], scale [n,1], bias [n,1]).
    Nibble layout: even k-index in the low nibble, odd in the high nibble."""
    scale, bias = asym_quant_params(w, INT4_MIN, INT4_MAX, axis=-1)
    q = asym_quantize(w, scale, bias, INT4_MIN, INT4_MAX, jnp.uint8)
    lo = q[:, 0::2]
    hi = q[:, 1::2]
    packed = (lo | (hi << 4)).astype(jnp.uint8)
    return packed, scale, bias


def unpack_w4(packed):
    """Inverse of the packing in quantize_w4 (values in 0..15, interleaved)."""
    lo = (packed & 0xF).astype(jnp.int32)
    hi = (packed >> 4).astype(jnp.int32)
    n, half = packed.shape
    out = jnp.zeros((n, half * 2), dtype=jnp.int32)
    out = out.at[:, 0::2].set(lo)
    out = out.at[:, 1::2].set(hi)
    return out


# --- KV cache quantization (§4.2) -------------------------------------------
# Keys: reduced dim in QK^T is head_dim (fixed) → per-token asymmetric int8.
# Values: reduced dim is seqlen (grows) → fp8 e4m3, no per-tensor stats, so
# appending new tokens never re-quantizes old ones.


def quantize_key(k):
    """k: [..., d] → (k_q i8, scale [...,1], bias [...,1]) per-token."""
    scale, bias = asym_quant_params(k, INT8_MIN, INT8_MAX, axis=-1)
    k_q = asym_quantize(k, scale, bias, INT8_MIN, INT8_MAX, jnp.int8)
    return k_q, scale, bias


def quantize_value_fp8(v):
    """v: [...] f32 → fp8 e4m3 (stat-free, append-friendly)."""
    return v.astype(jnp.float8_e4m3fn)


def dequantize_value_fp8(v_f8):
    return v_f8.astype(jnp.float32)
