"""AOT artifact container tests: weights.bin parses, manifest is coherent."""

import dataclasses
import json
import os
import struct
import tempfile

import numpy as np
import pytest

from compile.aot import export, write_weights_bin
from compile.model import TINY, graph_weight_names

CFG = dataclasses.replace(TINY, layers=1, max_len=32)


def _read_weights_bin(path):
    tensors = {}
    with open(path, "rb") as f:
        assert f.read(4) == b"MNNW"
        version, count = struct.unpack("<II", f.read(8))
        assert version == 1
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode()
            code, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            (nbytes,) = struct.unpack("<Q", f.read(8))
            tensors[name] = (code, dims, f.read(nbytes))
    return tensors


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    export(CFG, out, seed=0)
    return out


def test_container_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "w.bin")
        a = np.arange(12, dtype=np.float32).reshape(3, 4)
        b = (np.arange(8) - 4).astype(np.int8)
        table = write_weights_bin(path, {"a": a, "b": b})
        tensors = _read_weights_bin(path)
        assert tensors["a"][0] == 0 and tensors["a"][1] == (3, 4)
        assert np.frombuffer(tensors["a"][2], dtype=np.float32).reshape(3, 4).tolist() == a.tolist()
        assert tensors["b"][0] == 1
        assert [t["name"] for t in table] == ["a", "b"]


def test_manifest_and_files_exist(exported):
    m = json.load(open(os.path.join(exported, "manifest.json")))
    assert m["model"]["name"] == CFG.name
    for g in m["graphs"].values():
        assert os.path.exists(os.path.join(exported, g["file"]))
    assert os.path.exists(os.path.join(exported, "weights.bin"))
    assert os.path.exists(os.path.join(exported, "embedding.bin"))
    # Embedding file is bf16 [vocab, hidden] = 2 bytes/elt.
    sz = os.path.getsize(os.path.join(exported, "embedding.bin"))
    assert sz == CFG.vocab * CFG.hidden * 2


def test_manifest_weight_order_matches_graph_args(exported):
    m = json.load(open(os.path.join(exported, "manifest.json")))
    names = graph_weight_names(CFG)
    assert [w["name"] for w in m["weights"]] == names
    for key, g in m["graphs"].items():
        assert g["args"][-len(names):] == names, key


def test_weights_bin_parses_fully(exported):
    m = json.load(open(os.path.join(exported, "manifest.json")))
    tensors = _read_weights_bin(os.path.join(exported, "weights.bin"))
    for w in m["weights"]:
        code, dims, raw = tensors[w["name"]]
        assert code == w["dtype"]
        assert list(dims) == w["shape"]
        assert len(raw) == w["nbytes"]


def test_hlo_text_is_parseable_shape(exported):
    """HLO text must start with an HloModule header (what the Rust parser
    expects) and mention an ENTRY computation."""
    m = json.load(open(os.path.join(exported, "manifest.json")))
    for g in m["graphs"].values():
        text = open(os.path.join(exported, g["file"])).read()
        assert text.startswith("HloModule"), g["file"]
        assert "ENTRY" in text
