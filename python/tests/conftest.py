import os
import sys

# Tests run as `pytest python/tests` from the repo root or `pytest tests`
# from python/ — make `compile` importable either way.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
