"""Pallas quantized matmul vs the pure-jnp oracle (hypothesis shape sweeps)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import w4a8_matmul, w8a8_matmul
from compile.kernels.ref import ref_w4a8_matmul, ref_w8a8_matmul, quantize_activation_rows
from compile.quantize import quantize_w4, quantize_w8, unpack_w4

RTOL, ATOL = 2e-4, 2e-4


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 48),
    k=st.sampled_from([8, 16, 64, 96, 256]),
    n=st.sampled_from([8, 24, 64, 128, 192]),
    seed=st.integers(0, 2**16),
)
def test_w8a8_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, m, k), _rand(rng, n, k)
    wq, ws, wb = quantize_w8(w)
    out = w8a8_matmul(x, wq, ws, wb)
    ref = ref_w8a8_matmul(x, wq, ws, wb)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=RTOL, atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 48),
    k=st.sampled_from([8, 16, 64, 96, 256]),
    n=st.sampled_from([8, 24, 64, 128, 192]),
    seed=st.integers(0, 2**16),
)
def test_w4a8_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, m, k), _rand(rng, n, k)
    wp, ws, wb = quantize_w4(w)
    out = w4a8_matmul(x, wp, ws, wb)
    ref = ref_w4a8_matmul(x, wp, ws, wb)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("block_m,block_n", [(1, 8), (4, 16), (16, 128), (64, 256)])
def test_w8a8_block_shape_invariance(block_m, block_n):
    """Tiling must never change the numbers — only the schedule."""
    rng = np.random.default_rng(7)
    x, w = _rand(rng, 24, 64), _rand(rng, 96, 64)
    wq, ws, wb = quantize_w8(w)
    base = np.asarray(w8a8_matmul(x, wq, ws, wb, block_m=24, block_n=96))
    tiled = np.asarray(w8a8_matmul(x, wq, ws, wb, block_m=block_m, block_n=block_n))
    np.testing.assert_allclose(tiled, base, rtol=1e-5, atol=1e-5)


def test_w8a8_close_to_float_matmul():
    """Quantized GEMM tracks the fp32 product closely in direction and
    magnitude (cosine > 0.999, relative Frobenius error < 2%)."""
    rng = np.random.default_rng(3)
    x, w = _rand(rng, 16, 256), _rand(rng, 128, 256)
    wq, ws, wb = quantize_w8(w)
    out = np.asarray(w8a8_matmul(x, wq, ws, wb)).ravel()
    ref = np.asarray(x @ w.T).ravel()
    cos = out @ ref / (np.linalg.norm(out) * np.linalg.norm(ref))
    assert cos > 0.999
    assert np.linalg.norm(out - ref) / np.linalg.norm(ref) < 0.02


def test_w4_pack_roundtrip():
    rng = np.random.default_rng(11)
    w = _rand(rng, 32, 64)
    wp, ws, wb = quantize_w4(w)
    unpacked = np.asarray(unpack_w4(wp))
    assert unpacked.shape == (32, 64)
    assert unpacked.min() >= 0 and unpacked.max() <= 15
    # Dequantized weights approximate the originals within one step.
    deq = unpacked * np.asarray(ws) + np.asarray(wb)
    step = np.asarray(ws)
    assert np.all(np.abs(deq - np.asarray(w)) <= step * 0.5 + 1e-6)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 32), k=st.sampled_from([4, 32, 128]), seed=st.integers(0, 2**16))
def test_activation_quant_roundtrip(m, k, seed):
    """Dynamic activation quantization reconstructs within one step."""
    rng = np.random.default_rng(seed)
    x = _rand(rng, m, k)
    xq, sx, bx = quantize_activation_rows(x)
    deq = np.asarray(xq).astype(np.float32) * np.asarray(sx) + np.asarray(bx)
    assert np.all(np.abs(deq - np.asarray(x)) <= np.asarray(sx) * 0.51 + 1e-7)


def test_constant_rows_do_not_nan():
    """Zero-range activation rows (the eps guard) must stay finite."""
    x = jnp.ones((4, 16), dtype=jnp.float32) * 3.0
    w = jnp.asarray(np.random.default_rng(0).normal(size=(8, 16)).astype(np.float32))
    wq, ws, wb = quantize_w8(w)
    out = np.asarray(w8a8_matmul(x, wq, ws, wb))
    assert np.all(np.isfinite(out))
