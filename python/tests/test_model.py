"""L2 model graph tests: shapes, cache semantics, prefill/decode agreement."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    CONFIGS,
    TINY,
    build_params,
    decode_fn,
    graph_weight_names,
    prefill_fn,
    reference_generate,
)

CFG = dataclasses.replace(TINY, layers=2, max_len=64)


@pytest.fixture(scope="module")
def setup():
    params = build_params(CFG, seed=0)
    names = graph_weight_names(CFG)
    weights = [jnp.asarray(params[n]) for n in names]
    return params, weights


def _embed(params, ids, bucket, hidden):
    h = np.zeros((bucket, hidden), dtype=np.float32)
    h[: len(ids)] = params["embedding"][np.asarray(ids)]
    return jnp.asarray(h)


def test_prefill_shapes(setup):
    params, weights = setup
    S, T = 16, CFG.max_len
    hidden = _embed(params, [1, 2, 3], S, CFG.hidden)
    logits, kq, ks, kb, vu8 = prefill_fn(CFG, hidden, *weights)
    assert logits.shape == (S, CFG.vocab)
    assert kq.shape == (CFG.layers, CFG.kv_heads, T, CFG.head_dim)
    assert kq.dtype == jnp.int8
    assert ks.shape == (CFG.layers, CFG.kv_heads, T, 1)
    assert vu8.shape == (CFG.layers, CFG.kv_heads, T, CFG.head_dim)
    assert vu8.dtype == jnp.uint8


def test_decode_updates_only_pos(setup):
    """A decode step must write cache slots only at its position."""
    params, weights = setup
    ids = [5, 6, 7, 8]
    hidden = _embed(params, ids, 16, CFG.hidden)
    _, kq, ks, kb, vu8 = prefill_fn(CFG, hidden, *weights)
    pos = len(ids)
    h = jnp.asarray(params["embedding"][3][None].astype(np.float32))
    _, kq2, ks2, kb2, vu82 = decode_fn(
        CFG, h, jnp.asarray([pos], dtype=jnp.int32), kq, ks, kb, vu8, *weights
    )
    kq_np, kq2_np = np.asarray(kq), np.asarray(kq2)
    # Everything except column `pos` is unchanged.
    mask = np.ones(CFG.max_len, dtype=bool)
    mask[pos] = False
    assert np.array_equal(kq_np[:, :, mask], kq2_np[:, :, mask])
    # Position `pos` actually got new keys (scales became nonzero).
    assert np.any(np.asarray(ks2)[:, :, pos] != np.asarray(ks)[:, :, pos])


def test_prefill_prefix_consistency(setup):
    """Logits for a prompt prefix don't depend on (zero-embedded) suffix
    rows *before* them — i.e. row i only sees rows ≤ i (causality through
    the whole stack, not just attention)."""
    params, weights = setup
    ids = [9, 10, 11, 12, 13]
    h1 = _embed(params, ids, 16, CFG.hidden)
    h2 = _embed(params, ids + [99, 100], 16, CFG.hidden)
    l1, *_ = prefill_fn(CFG, h1, *weights)
    l2, *_ = prefill_fn(CFG, h2, *weights)
    np.testing.assert_allclose(
        np.asarray(l1)[: len(ids)], np.asarray(l2)[: len(ids)], rtol=2e-3, atol=2e-3
    )


def test_decode_matches_prefill_rows(setup):
    """Feeding tokens one-by-one through decode must reproduce the prefill
    logits for the same sequence (cache correctness end-to-end)."""
    params, weights = setup
    ids = [3, 1, 4, 1, 5, 9]
    S = 16
    # Full prefill over the whole sequence.
    l_full, *_ = prefill_fn(CFG, _embed(params, ids, S, CFG.hidden), *weights)
    # Prefill on the first token only, then decode the rest.
    l, kq, ks, kb, vu8 = prefill_fn(CFG, _embed(params, ids[:1], S, CFG.hidden), *weights)
    logits_rows = [np.asarray(l)[0]]
    for t, tok in enumerate(ids[1:], start=1):
        h = jnp.asarray(params["embedding"][tok][None].astype(np.float32))
        l, kq, ks, kb, vu8 = decode_fn(
            CFG, h, jnp.asarray([t], dtype=jnp.int32), kq, ks, kb, vu8, *weights
        )
        logits_rows.append(np.asarray(l)[0])
    # Row t of full prefill == decode-step logits at position t.
    full = np.asarray(l_full)
    for t in range(len(ids)):
        np.testing.assert_allclose(logits_rows[t], full[t], rtol=2e-2, atol=2e-2)


def test_reference_generate_deterministic(setup):
    params, _ = setup
    ids1, _ = reference_generate(CFG, params, [1, 2, 3], gen=4, bucket=16)
    ids2, _ = reference_generate(CFG, params, [1, 2, 3], gen=4, bucket=16)
    assert ids1 == ids2


def test_param_count_matches_table1_shape():
    """The analytic parameter split reproduces Table 1 for Qwen2-7B dims."""
    from compile.model import ModelConfig

    qwen7b = ModelConfig("qwen2-7b", vocab=151646, hidden=3584, inter=18944,
                         layers=28, heads=28, kv_heads=4, max_len=32768)
    emb = qwen7b.vocab * qwen7b.hidden
    total = qwen7b.param_count()
    # vocab × hidden = 0.5435 B; the paper's printed "Embedding 1.09 B" is
    # 2× that (embedding + lm_head storage, see EXPERIMENTS.md §Table 1).
    assert abs(emb / 1e9 - 0.5435) < 0.005
    assert abs(2 * emb / 1e9 - 1.09) < 0.01
    # §4.1 claim: bf16 embedding+head in flash saves ≈ 2.18 GB of DRAM.
    assert abs(2 * emb * 2 / 1e9 - 2.18) < 0.02
    # emb+lm_head ≈ 15% of total parameters (the paper's "15%" claim).
    assert 0.13 < 2 * emb / total < 0.17
    assert 7.0 < total / 1e9 < 7.7


def test_all_configs_buildable():
    for cfg in CONFIGS.values():
        small = dataclasses.replace(cfg, layers=1, max_len=32)
        p = build_params(small, seed=1)
        assert set(graph_weight_names(small)) <= set(p)
