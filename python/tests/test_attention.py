"""Pallas attention kernels vs oracles + KV-quantization properties."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import decode_attention, prefill_attention
from compile.kernels.ref import ref_decode_attention, ref_prefill_attention
from compile.quantize import dequantize_value_fp8, quantize_key, quantize_value_fp8


def _rand(rng, *shape, scale=1.0):
    return jnp.asarray((rng.normal(size=shape) * scale).astype(np.float32))


@settings(max_examples=20, deadline=None)
@given(
    heads=st.sampled_from([(2, 1), (4, 2), (4, 4), (8, 2)]),
    t=st.sampled_from([8, 32, 64, 128]),
    d=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**16),
)
def test_decode_attention_matches_ref(heads, t, d, seed):
    H, Hkv = heads
    rng = np.random.default_rng(seed)
    pos = int(rng.integers(0, t))
    q = _rand(rng, H, 1, d, scale=1.0 / np.sqrt(d))
    k = _rand(rng, Hkv, t, d)
    v = _rand(rng, Hkv, t, d)
    kq, ks, kb = quantize_key(k)
    vf8 = quantize_value_fp8(v)
    out = decode_attention(q, kq, ks, kb, vf8, jnp.asarray([pos], dtype=jnp.int32))
    ref = ref_decode_attention(q, kq, ks, kb, vf8, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    heads=st.sampled_from([(2, 1), (4, 2), (8, 4)]),
    s=st.sampled_from([4, 16, 64]),
    d=st.sampled_from([16, 64]),
    seed=st.integers(0, 2**16),
)
def test_prefill_attention_matches_ref(heads, s, d, seed):
    H, Hkv = heads
    rng = np.random.default_rng(seed)
    q = _rand(rng, H, s, d, scale=1.0 / np.sqrt(d))
    k = _rand(rng, Hkv, s, d)
    v = _rand(rng, Hkv, s, d)
    out = prefill_attention(q, k, v)
    ref = ref_prefill_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_decode_ignores_positions_beyond_pos():
    """Cache garbage past `pos` must not leak into the output (§4.1 spill
    correctness depends on this masking)."""
    rng = np.random.default_rng(0)
    H, Hkv, T, d = 4, 2, 32, 16
    q = _rand(rng, H, 1, d, scale=0.25)
    k = _rand(rng, Hkv, T, d)
    v = _rand(rng, Hkv, T, d)
    kq, ks, kb = quantize_key(k)
    vf8 = quantize_value_fp8(v)
    pos = 10
    out1 = np.asarray(decode_attention(q, kq, ks, kb, vf8, jnp.asarray([pos], dtype=jnp.int32)))
    # Trash everything beyond pos.
    k2 = np.asarray(kq).copy(); k2[:, pos + 1:] = 127
    v2 = np.asarray(vf8).copy(); v2[:, pos + 1:] = 100.0
    out2 = np.asarray(
        decode_attention(q, jnp.asarray(k2), ks, kb, jnp.asarray(v2), jnp.asarray([pos], dtype=jnp.int32))
    )
    np.testing.assert_allclose(out1, out2, rtol=1e-6, atol=1e-6)


def test_prefill_is_causal():
    """Changing future tokens must not change earlier rows."""
    rng = np.random.default_rng(1)
    H, Hkv, S, d = 4, 2, 16, 16
    q = _rand(rng, H, S, d, scale=0.25)
    k = _rand(rng, Hkv, S, d)
    v = _rand(rng, Hkv, S, d)
    base = np.asarray(prefill_attention(q, k, v))
    k2 = np.asarray(k).copy(); k2[:, S - 1] += 5.0
    v2 = np.asarray(v).copy(); v2[:, S - 1] -= 3.0
    pert = np.asarray(prefill_attention(q, jnp.asarray(k2), jnp.asarray(v2)))
    np.testing.assert_allclose(base[:, : S - 1], pert[:, : S - 1], rtol=1e-6, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), t=st.sampled_from([4, 16, 64]))
def test_key_quant_roundtrip(seed, t):
    rng = np.random.default_rng(seed)
    k = _rand(rng, 2, t, 32)
    kq, ks, kb = quantize_key(k)
    deq = np.asarray(kq).astype(np.float32) * np.asarray(ks) + np.asarray(kb)
    assert np.all(np.abs(deq - np.asarray(k)) <= np.asarray(ks) * 0.51 + 1e-7)


def test_fp8_value_append_stability():
    """fp8 values are stat-free: quantizing a longer cache must leave the
    prefix encoding bit-identical (the paper's reason for fp8 values)."""
    rng = np.random.default_rng(2)
    v_old = _rand(rng, 2, 8, 16)
    v_new = _rand(rng, 2, 4, 16)
    enc_old = np.asarray(quantize_value_fp8(v_old))
    both = jnp.concatenate([v_old, v_new], axis=1)
    enc_both = np.asarray(quantize_value_fp8(both))
    assert np.array_equal(
        enc_old.view(np.uint8), enc_both[:, :8].view(np.uint8)
    )


def test_fp8_roundtrip_error_bounded():
    rng = np.random.default_rng(3)
    v = _rand(rng, 2, 16, 16)
    deq = np.asarray(dequantize_value_fp8(quantize_value_fp8(v)))
    # e4m3: 3 mantissa bits → relative error ≤ 2^-4 in the normal range
    # (denormals below ~2^-6 have coarser absolute spacing — exclude them).
    vv = np.asarray(v)
    mask = np.abs(vv) >= 0.1
    rel = np.abs(deq[mask] - vv[mask]) / np.abs(vv[mask])
    assert rel.max() <= 2 ** -4 + 1e-3
    # And absolute error is bounded everywhere by the largest step at |v|<=max.
    assert np.abs(deq - vv).max() <= np.abs(vv).max() * 2 ** -4 + 1e-3
