"""Pallas RMSNorm vs oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import rmsnorm
from compile.kernels.ref import ref_rmsnorm


@settings(max_examples=25, deadline=None)
@given(
    s=st.integers(1, 64),
    hidden=st.sampled_from([16, 64, 256, 384]),
    seed=st.integers(0, 2**16),
)
def test_rmsnorm_matches_ref(s, hidden, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(s, hidden)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(hidden,)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(rmsnorm(x, w)), np.asarray(ref_rmsnorm(x, w)), rtol=1e-5, atol=1e-5
    )


def test_rmsnorm_scale_invariance():
    """RMSNorm(c*x) == RMSNorm(x) up to eps effects — the defining property."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))
    w = jnp.ones((64,), dtype=jnp.float32)
    a = np.asarray(rmsnorm(x, w))
    b = np.asarray(rmsnorm(x * 1000.0, w))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_rmsnorm_unit_rows():
    """Rows of the output have RMS 1 when w == 1."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(4, 256)).astype(np.float32))
    w = jnp.ones((256,), dtype=jnp.float32)
    out = np.asarray(rmsnorm(x, w))
    rms = np.sqrt(np.mean(out * out, axis=-1))
    np.testing.assert_allclose(rms, np.ones(4), rtol=1e-3)


def test_rmsnorm_fp32_large_values():
    """Mixed-precision guard: values near the fp16 limit must not overflow
    because the kernel accumulates in fp32 (§5.3)."""
    x = jnp.full((2, 64), 60000.0, dtype=jnp.float32)
    w = jnp.ones((64,), dtype=jnp.float32)
    out = np.asarray(rmsnorm(x, w))
    assert np.all(np.isfinite(out))
    np.testing.assert_allclose(out, np.ones_like(out), rtol=1e-3)
