//! Table 3 reproduction: LoRA computation order — (A·B)·x vs A·(B·x) —
//! analytic costs at paper scale plus measured wall time of both orders on
//! real adapters (the associativity rewrite of §5.5).
//!
//! Run: `cargo bench --bench table3_lora`

use mnn_llm::bench as bh;
use mnn_llm::lora::LoraAdapter;
use mnn_llm::util::rng::Rng;

fn main() {
    bh::section("Table 3 — analytic cost, h=3584, r=8 (Qwen2-7B scale)");
    let row = LoraAdapter::table3_costs(3584, 8);
    bh::table(
        &["order", "compute (MACs)", "memory accesses"],
        &[
            vec!["(LoRA_A·LoRA_B)·x".into(), row.naive_compute.to_string(), row.naive_memory.to_string()],
            vec!["LoRA_A·(LoRA_B·x)".into(), row.opt_compute.to_string(), row.opt_memory.to_string()],
        ],
    );
    println!(
        "optimized/naive memory = {:.3}% (paper: ≈0.5%)",
        100.0 * row.opt_memory as f64 / row.naive_memory as f64
    );

    bh::section("Measured: both orders on real adapters (batch 4)");
    let mut rng = Rng::new(7);
    let mut rows = Vec::new();
    for (h, r) in [(512usize, 8usize), (1024, 8), (2048, 8), (1024, 32)] {
        let ad = LoraAdapter::random(&mut rng, h, h, r);
        let x = rng.normal_vec(4 * h);
        let mut out = vec![0f32; 4 * h];
        let opt = bh::bench(&format!("A·(B·x)      h={h} r={r}"), || {
            out.fill(0.0);
            ad.apply(&x, 4, &mut out);
            std::hint::black_box(&out);
        });
        let naive = bh::bench(&format!("(A·B)·x      h={h} r={r}"), || {
            out.fill(0.0);
            ad.apply_materialized(&x, 4, &mut out);
            std::hint::black_box(&out);
        });
        rows.push(vec![
            format!("{h}"),
            format!("{r}"),
            format!("{:.3}", opt.mean_s * 1e3),
            format!("{:.3}", naive.mean_s * 1e3),
            format!("{:.0}×", naive.mean_s / opt.mean_s),
        ]);
    }
    bh::table(&["h", "r", "A·(B·x) ms", "(A·B)·x ms", "speedup"], &rows);
    println!("\n(The measured speedup tracks the analytic memory ratio: the rewrite is");
    println!(" the paper's multi-LoRA enabling optimization.)");
}
