//! Table 1 reproduction: Qwen2-7B parameter split, plus the §4.1 in-text
//! arithmetic (flash-embedding overhead, DRAM savings).
//!
//! Run: `cargo bench --bench table1_params`

use mnn_llm::bench as bh;
use mnn_llm::device::SocProfile;
use mnn_llm::model::config::ModelConfig;

fn main() {
    bh::section("Table 1 — Qwen2 7B model params (paper vs computed)");
    let c = ModelConfig::qwen2_7b();
    let emb = c.embedding_params() as f64 / 1e9;
    let layers = (c.layers as u64 * c.layer_params()) as f64 / 1e9;
    let total = c.total_params() as f64 / 1e9;
    bh::table(
        &["Params", "Paper (B)", "Computed (B)", "Note"],
        &[
            vec!["Embedding".into(), "1.09".into(), format!("{:.3}", emb),
                 "paper's 1.09 = emb+head storage (2×vocab×hidden)".into()],
            vec!["Layers".into(), "4.89".into(), format!("{:.3}", layers),
                 "paper derives from official 7.07B total".into()],
            vec!["Lm head".into(), "1.09".into(), format!("{:.3}", emb), "untied".into()],
            vec!["Total".into(), "7.07".into(), format!("{:.3}", total),
                 "official size excludes some per-layer biases".into()],
        ],
    );
    println!("\nStructure checks (the claims §4.1 builds on):");
    println!(
        "  emb+head / total = {:.1}%  (paper: 'about 15%')",
        100.0 * 2.0 * emb / total
    );
    println!(
        "  bf16 emb+head storage = {:.2} GB (paper: saves ≈2.18 GB of DRAM)",
        2.0 * emb * 2.0
    );

    bh::section("Config table (all models in the evaluation)");
    let rows: Vec<Vec<String>> = [ModelConfig::qwen2_1_5b(), ModelConfig::qwen2_7b(), ModelConfig::llama3_8b(), ModelConfig::tiny_qwen2()]
        .iter()
        .map(|m| {
            vec![
                m.name.clone(),
                m.layers.to_string(),
                m.hidden.to_string(),
                m.inter.to_string(),
                format!("{}/{}", m.heads, m.kv_heads),
                m.vocab.to_string(),
                format!("{:.3}", m.total_params() as f64 / 1e9),
            ]
        })
        .collect();
    bh::table(&["model", "layers", "hidden", "inter", "heads", "vocab", "params (B)"], &rows);

    bh::section("§4.1 flash-embedding arithmetic (device model)");
    let soc = SocProfile::snapdragon_8gen3();
    let row_bytes = c.hidden * 2;
    let delta = soc.flash_read_time(row_bytes) - soc.dram_read_time(row_bytes);
    let non_emb = (c.total_params() - 2 * c.embedding_params()) as usize;
    let step = soc.dram_read_time(non_emb);
    println!("  one bf16 row = {} KB; flash −DRAM = {:.0} µs (paper: ≈15 µs)", row_bytes / 1024, delta * 1e6);
    println!("  non-embedding stream = {:.0} ms (paper: ≈103 ms)", step * 1e3);
    println!("  decode overhead = {:.2}‰ (paper: ≈1.4‰)", 1e3 * delta / step);
}
