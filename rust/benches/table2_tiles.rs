//! Table 2 reproduction: tile sizes (e_p, h_p, l_p) solved from Eq. 2–4
//! per CPU instruction set, plus the memory-traffic reduction each tile
//! achieves and a *measured* packed-GEMM locality check on this host.
//!
//! Run: `cargo bench --bench table2_tiles`

use mnn_llm::bench as bh;
use mnn_llm::cpu::backend::{select, BackendChoice, ComputeBackend, ScalarBackend};
use mnn_llm::cpu::gemm_q::QLinear;
use mnn_llm::quant::asym::{QuantizedMatrix, WeightBits};
use mnn_llm::reorder::isa;
use mnn_llm::reorder::solver::{self, TileConfig};
use mnn_llm::util::json::Json;
use mnn_llm::util::rng::Rng;

/// Scalar vs SIMD backend on the int8-GEMM decode shape (one activation
/// row against a [h, l] W8A8 matrix — the lm_head/attention-projection
/// decode hot loop). Returns the JSON rows + the measured speedup.
fn backend_decode_comparison() -> (Vec<Json>, f64) {
    bh::section("Compute backends — int8-GEMM decode row, scalar vs SIMD (bit-identical)");
    let mut rng = Rng::new(7);
    let (l, h) = (1024usize, 1024usize);
    let wf = rng.normal_vec(h * l);
    let x = rng.normal_vec(l);
    let qm = QuantizedMatrix::from_f32(&wf, h, l, WeightBits::Int8);
    let tile = solver::solve_tiles(&isa::detect_host());
    let lin = QLinear::new(&qm, tile, None);
    let scalar: &dyn ComputeBackend = &ScalarBackend;
    let simd = select(BackendChoice::Simd);
    let mut out = vec![0f32; h];
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    let mut times = Vec::new();
    for (name, be) in [("scalar", scalar), (simd.name(), simd.as_ref())] {
        let m = bh::bench(&format!("{name:<10} decode GEMM {h}x{l} W8A8"), || {
            lin.forward_with(be, &x, 1, &mut out);
            std::hint::black_box(&out);
        });
        let rows_per_s = 1.0 / m.mean_s;
        times.push(m.mean_s);
        json_rows.push(Json::obj(vec![
            ("backend", Json::Str(name.into())),
            ("mean_s", Json::Num(m.mean_s)),
            ("rows_per_s", Json::Num(rows_per_s)),
        ]));
        rows.push(vec![name.to_string(), format!("{rows_per_s:.0}")]);
    }
    // Bit-identity spot check right here in the bench: same bits or bust.
    let mut a = vec![0f32; h];
    let mut b = vec![0f32; h];
    lin.forward_with(scalar, &x, 1, &mut a);
    lin.forward_with(simd.as_ref(), &x, 1, &mut b);
    assert_eq!(
        a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        b.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "backends diverged — the seam's contract is broken"
    );
    let speedup = times[0] / times[1];
    bh::table(&["backend", "decode rows/s"], &rows);
    println!("  SIMD speedup over scalar: {speedup:.2}× (outputs verified bit-identical)");
    (json_rows, speedup)
}

fn main() {
    bh::section("Table 2 — tile sizes per CPU architecture (Eq. 2–4 solver)");
    let paper = [(12, 8, 4), (10, 8, 8), (4, 8, 4), (4, 64, 4)];
    let mut solver_json = Vec::new();
    let rows: Vec<Vec<String>> = isa::table2_isas()
        .iter()
        .zip(paper)
        .map(|(i, p)| {
            let t = solver::solve_tiles(i);
            let traffic = solver::memory_accesses(1024.0, 1024.0, 1024.0, t.e_p as f64, t.h_p as f64);
            let naive = solver::naive_accesses(1024.0, 1024.0, 1024.0);
            solver_json.push(Json::obj(vec![
                ("isa", Json::Str(i.name.into())),
                ("e_p", Json::Num(t.e_p as f64)),
                ("h_p", Json::Num(t.h_p as f64)),
                ("l_p", Json::Num(t.l_p as f64)),
                ("matches_paper", Json::Bool((t.e_p, t.h_p, t.l_p) == p)),
                ("traffic_reduction", Json::Num(naive / traffic)),
            ]));
            vec![
                i.name.to_string(),
                format!("({}, {}, {})", p.0, p.1, p.2),
                format!("({}, {}, {})", t.e_p, t.h_p, t.l_p),
                if (t.e_p, t.h_p, t.l_p) == p { "✓".into() } else { "✗".into() },
                format!("{:.1}×", naive / traffic),
            ]
        })
        .collect();
    bh::table(&["ISA", "paper (e,h,l)", "solved (e,h,l)", "match", "traffic ↓"], &rows);

    bh::section("Measured on this host: packed layout vs naive-order GEMM (W8A8)");
    let mut rng = Rng::new(1);
    let (e, l, h) = (64, 1024, 1024);
    let wf = rng.normal_vec(h * l);
    let x = rng.normal_vec(e * l);
    let qm = QuantizedMatrix::from_f32(&wf, h, l, WeightBits::Int8);
    let host = solver::solve_tiles(&isa::detect_host());
    let mut out = vec![0f32; e * h];
    for (name, tile) in [
        (format!("solved tile {host:?}"), host),
        ("tiny tile (2,4,4) — under-tiled".into(), TileConfig { e_p: 2, h_p: 4, l_p: 4 }),
        ("paper sdot tile (12,8,4)".into(), TileConfig { e_p: 12, h_p: 8, l_p: 4 }),
        ("paper i8mm tile (10,8,8)".into(), TileConfig { e_p: 10, h_p: 8, l_p: 8 }),
    ] {
        let lin = QLinear::new(&qm, tile, None);
        bh::bench(&name, || {
            lin.forward(&x, e, &mut out);
            std::hint::black_box(&out);
        });
    }
    println!("\n(Absolute times are x86 scalar/autovec; the paper's win comes from the");
    println!(" same locality effect on ARM registers — see DESIGN.md §Substitutions.)");

    let (backend_rows, speedup) = backend_decode_comparison();
    let artifact = Json::obj(vec![
        ("bench", Json::Str("table2_tiles".into())),
        ("host_isa", Json::Str(isa::detect_host().name.into())),
        ("live_backend", Json::Str(select(BackendChoice::Auto).name().into())),
        ("solver", Json::Arr(solver_json)),
        ("decode_gemm", Json::Arr(backend_rows)),
        ("simd_speedup", Json::Num(speedup)),
    ]);
    bh::write_json("BENCH_table2.json", &artifact);
}
