//! Table 2 reproduction: tile sizes (e_p, h_p, l_p) solved from Eq. 2–4
//! per CPU instruction set, plus the memory-traffic reduction each tile
//! achieves and a *measured* packed-GEMM locality check on this host.
//!
//! Run: `cargo bench --bench table2_tiles`

use mnn_llm::bench as bh;
use mnn_llm::cpu::gemm_q::QLinear;
use mnn_llm::quant::asym::{QuantizedMatrix, WeightBits};
use mnn_llm::reorder::solver::{self, TileConfig};
use mnn_llm::reorder::isa;
use mnn_llm::util::rng::Rng;

fn main() {
    bh::section("Table 2 — tile sizes per CPU architecture (Eq. 2–4 solver)");
    let paper = [(12, 8, 4), (10, 8, 8), (4, 8, 4), (4, 64, 4)];
    let rows: Vec<Vec<String>> = isa::table2_isas()
        .iter()
        .zip(paper)
        .map(|(i, p)| {
            let t = solver::solve_tiles(i);
            let traffic = solver::memory_accesses(1024.0, 1024.0, 1024.0, t.e_p as f64, t.h_p as f64);
            let naive = solver::naive_accesses(1024.0, 1024.0, 1024.0);
            vec![
                i.name.to_string(),
                format!("({}, {}, {})", p.0, p.1, p.2),
                format!("({}, {}, {})", t.e_p, t.h_p, t.l_p),
                if (t.e_p, t.h_p, t.l_p) == p { "✓".into() } else { "✗".into() },
                format!("{:.1}×", naive / traffic),
            ]
        })
        .collect();
    bh::table(&["ISA", "paper (e,h,l)", "solved (e,h,l)", "match", "traffic ↓"], &rows);

    bh::section("Measured on this host: packed layout vs naive-order GEMM (W8A8)");
    let mut rng = Rng::new(1);
    let (e, l, h) = (64, 1024, 1024);
    let wf = rng.normal_vec(h * l);
    let x = rng.normal_vec(e * l);
    let qm = QuantizedMatrix::from_f32(&wf, h, l, WeightBits::Int8);
    let host = solver::solve_tiles(&isa::detect_host());
    let mut out = vec![0f32; e * h];
    for (name, tile) in [
        (format!("solved tile {host:?}"), host),
        ("tiny tile (2,4,4) — under-tiled".into(), TileConfig { e_p: 2, h_p: 4, l_p: 4 }),
        ("paper sdot tile (12,8,4)".into(), TileConfig { e_p: 12, h_p: 8, l_p: 4 }),
        ("paper i8mm tile (10,8,8)".into(), TileConfig { e_p: 10, h_p: 8, l_p: 8 }),
    ] {
        let lin = QLinear::new(&qm, tile, None);
        bh::bench(&name, || {
            lin.forward(&x, e, &mut out);
            std::hint::black_box(&out);
        });
    }
    println!("\n(Absolute times are x86 scalar/autovec; the paper's win comes from the");
    println!(" same locality effect on ARM registers — see DESIGN.md §Substitutions.)");
}
