//! Figure 4 reproduction: multithread speedup with balanced vs uniform
//! workload split on a 1-prime + 3-performance-core SoC (§5.2).
//!
//! The speedup series comes from the virtual-time core model (this box has
//! one core — DESIGN.md §Substitutions); a real-thread section verifies the
//! split machinery end-to-end on the actual GEMM.
//!
//! Run: `cargo bench --bench fig4_multicore`

use mnn_llm::bench as bh;
use mnn_llm::cpu::gemm_q::QLinear;
use mnn_llm::device::SocProfile;
use mnn_llm::parallel::balancer::{balanced_split, makespan, speedup_curve, uniform_split};
use mnn_llm::parallel::pool::WorkerConfig;
use mnn_llm::quant::asym::{QuantizedMatrix, WeightBits};
use mnn_llm::reorder::{isa, solver};
use mnn_llm::util::rng::Rng;

fn main() {
    let soc = SocProfile::snapdragon_8gen3();
    let rates: Vec<f64> = soc.high_perf_cores(4).iter().map(|c| c.rel_perf).collect();

    bh::section("Fig. 4 — speedup vs threads (1 prime + 3 performance cores)");
    println!("core rates: {rates:?} (prime = 1.0)");
    let items = 4096; // GEMM h-tiles in one big Linear
    let (bal, uni) = speedup_curve(items, &rates, 4);
    let rows: Vec<Vec<String>> = (0..4)
        .map(|t| {
            vec![
                (t + 1).to_string(),
                format!("{:.2}×", bal[t]),
                format!("{:.2}×", uni[t]),
                format!("{:.1}%", 100.0 * (bal[t] / uni[t] - 1.0)),
            ]
        })
        .collect();
    bh::table(&["threads", "balanced", "uniform", "balanced gain"], &rows);

    println!("\nShape checks (paper Fig. 4):");
    println!("  1 thread: both = 1.0×                    → {:.2}/{:.2}", bal[0], uni[0]);
    println!("  4 threads balanced ≈ 1+3·0.72 = 3.16×    → {:.2}×", bal[3]);
    println!("  4 threads uniform capped by slowest core → {:.2}× (< balanced)", uni[3]);

    bh::section("Split integrity on the real GEMM (1 OS core, correctness)");
    let mut rng = Rng::new(5);
    let (e, l, h) = (32, 512, 2048);
    let wf = rng.normal_vec(h * l);
    let x = rng.normal_vec(e * l);
    let qm = QuantizedMatrix::from_f32(&wf, h, l, WeightBits::Int8);
    let tile = solver::solve_tiles(&isa::detect_host());
    let lin = QLinear::new(&qm, tile, None);
    let mut out1 = vec![0f32; e * h];
    lin.forward(&x, e, &mut out1);
    // Same GEMM under a 4-way balanced split must give identical results.
    let cfg = WorkerConfig { rates: rates.clone() };
    let pa = mnn_llm::reorder::pack::pack_activations(&x, e, l, tile);
    let tiles = lin.h_tiles();
    let split = balanced_split(tiles, &cfg.rates);
    let mut out2 = vec![0f32; e * h];
    let mut lo = 0;
    for n in &split {
        lin.forward_packed(&pa, &mut out2, lo, lo + n);
        lo += n;
    }
    assert_eq!(out1, out2, "balanced split changed numbers");
    println!("  balanced 4-way split output == single-thread output ✓ (split {split:?})");

    bh::section("Virtual-time makespan per split policy (tiles of this GEMM)");
    let rows: Vec<Vec<String>> = [1usize, 2, 3, 4]
        .iter()
        .map(|&t| {
            let r = &rates[..t];
            let mb = makespan(&balanced_split(tiles, r), r);
            let mu = makespan(&uniform_split(tiles, r), r);
            vec![
                t.to_string(),
                format!("{:?}", balanced_split(tiles, r)),
                format!("{mb:.1}"),
                format!("{mu:.1}"),
            ]
        })
        .collect();
    bh::table(&["threads", "balanced split", "balanced makespan", "uniform makespan"], &rows);
}
