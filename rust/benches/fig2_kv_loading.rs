//! Figure 2 reproduction: KV loading time under four storage regimes —
//! (a) all-DRAM, (b) DRAM-Flash without prefetch, (c) DRAM-Flash with
//! prefetch under the hidden-capacity threshold, (d) beyond the threshold.
//!
//! Two parts: the device-model series at Qwen2-7B scale (the paper's
//! setting — reproduces the 3072K crossover and ~1 ms/1K overshoot), and a
//! real-I/O measurement of the spill/stage path on the tiny model.
//!
//! Run: `cargo bench --bench fig2_kv_loading`

use std::sync::Arc;

use mnn_llm::bench as bh;
use mnn_llm::coordinator::scheduler::{Backend, Coordinator};
use mnn_llm::coordinator::SchedulePolicy;
use mnn_llm::device::SocProfile;
use mnn_llm::kv::{EvictionPolicy, KvPool, PAGE_TOKENS};
use mnn_llm::memory::flash::FlashSim;
use mnn_llm::memory::hybrid::HybridKvLayer;
use mnn_llm::memory::prefetch::PrefetchPlanner;
use mnn_llm::model::fixtures;
use mnn_llm::model::native::{EngineOptions, NativeModel};
use mnn_llm::util::rng::Rng;

/// Qwen2-7B single-layer qkv+MLP weight bytes (≈178.83 MB, paper §4.1).
const LAYER_BYTES: usize = 178_830_000;
/// Qwen2-7B KV bytes per token (≈1 KB, paper §4.1).
const KV_TOKEN_BYTES: usize = 1024;

fn main() {
    let soc = SocProfile::snapdragon_8gen3();
    let planner = PrefetchPlanner::from_soc(&soc, LAYER_BYTES);
    let layers = 28;
    let compute = planner.window_s;

    bh::section("Fig. 2 — decode-step makespan vs flash-resident KV (Qwen2-7B model)");
    println!(
        "window {:.2} ms/layer | hidden capacity {:.2} MB ≈ {:.0}K tokens (paper: ~3 MB / 3072K)",
        planner.window_s * 1e3,
        planner.hidden_capacity_bytes() / 1e6,
        planner.hidden_capacity_bytes() / KV_TOKEN_BYTES as f64 / 1024.0 * 1024.0 / 1000.0
    );
    let mut rows = Vec::new();
    for k_tokens in [0usize, 512, 1024, 2048, 3072, 4096, 6144, 8192] {
        let bytes = k_tokens * 1024 * KV_TOKEN_BYTES / 1024; // k_tokens in "K"
        let dram_only = layers as f64 * compute;
        let serial = planner.step_makespan(layers, bytes, compute, false);
        let prefetch = planner.step_makespan(layers, bytes, compute, true);
        rows.push(vec![
            format!("{k_tokens}K"),
            format!("{:.1}", dram_only * 1e3),
            format!("{:.1}", serial * 1e3),
            format!("{:.1}", prefetch * 1e3),
            format!("{:.2}", serial / dram_only),
            format!("{:.2}", prefetch / dram_only),
        ]);
    }
    bh::table(
        &["flash KV", "(a) DRAM ms", "(b) no prefetch ms", "(c/d) prefetch ms", "b/a", "c/a"],
        &rows,
    );
    println!("\nShape checks:");
    let cap = planner.hidden_capacity_bytes() as usize;
    let under = planner.step_makespan(layers, cap / 2, compute, true);
    let base = layers as f64 * compute;
    println!(
        "  under threshold: prefetch overhead = {:.1}% (paper: hidden entirely)",
        100.0 * (under - base) / base
    );
    let over = planner.exposed_time(cap + 1_048_576) - planner.exposed_time(cap);
    println!("  beyond threshold: +{:.2} ms per extra 1K tokens (paper: ≈1 ms)", over * 1e3);

    bh::section("Real I/O on this host: spill + stage the tiny model's KV");
    let mut rng = Rng::new(3);
    let mut rows = Vec::new();
    for (name, budget, toks) in [
        ("all DRAM (no spill)", usize::MAX / 2, 128usize),
        ("spill beyond 64 tok", 64, 128),
        ("spill beyond 16 tok", 16, 128),
        ("spill beyond 16 tok, longer ctx", 16, 256),
    ] {
        let flash = Arc::new(FlashSim::temp(soc.flash).unwrap());
        let mut layer = HybridKvLayer::new(2, 64, flash, budget);
        let t_append = std::time::Instant::now();
        for _ in 0..toks {
            let k = rng.normal_vec(2 * 64);
            let v = rng.normal_vec(2 * 64);
            layer.append(&k, &v).unwrap();
        }
        let append_s = t_append.elapsed().as_secs_f64();
        let spilled = layer.spilled_tokens();
        let modeled = layer.stage_cost();
        let t_stage = std::time::Instant::now();
        layer.stage().unwrap();
        let stage_wall = t_stage.elapsed().as_secs_f64();
        rows.push(vec![
            name.to_string(),
            toks.to_string(),
            spilled.to_string(),
            format!("{:.2}", append_s * 1e3),
            format!("{:.3}", stage_wall * 1e3),
            format!("{:.3}", modeled * 1e3),
        ]);
    }
    bh::table(
        &["config", "tokens", "spilled", "append wall ms", "stage wall ms", "stage modeled (UFS) ms"],
        &rows,
    );
    println!("\n(Real spill I/O goes through an actual file; timing *figures* use the");
    println!(" UFS bandwidth model — this box's NVMe is far faster than mobile flash.)");

    // Part 3: the paged pool under concurrent-session pressure — the byte
    // budget is held by shedding the overflow to flash, and pages recycle
    // through the free lists instead of reallocating.
    bh::section("Paged KV pool — byte budget under concurrent sessions");
    let (kv_heads, head_dim, layers_per_sess, sessions, toks) = (2usize, 64usize, 2usize, 4usize, 96usize);
    let page = KvPool::page_bytes(kv_heads, head_dim);
    let mut rows = Vec::new();
    for (name, budget_pages) in [("unbounded", usize::MAX / page), ("8 pages", 8), ("3 pages", 3)] {
        let budget = budget_pages.saturating_mul(page);
        let pool = Arc::new(KvPool::new(budget));
        let flash = Arc::new(FlashSim::temp(soc.flash).unwrap());
        let mut layers: Vec<HybridKvLayer> = (0..sessions * layers_per_sess)
            .map(|_| {
                HybridKvLayer::with_pool(kv_heads, head_dim, flash.clone(), usize::MAX / 2,
                                         pool.clone())
            })
            .collect();
        let t0 = std::time::Instant::now();
        for _ in 0..toks {
            for l in &mut layers {
                let k = rng.normal_vec(kv_heads * head_dim);
                let v = rng.normal_vec(kv_heads * head_dim);
                l.append(&k, &v).unwrap();
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let spilled: u64 = layers.iter().map(|l| l.spill_count()).sum();
        let stats = pool.stats();
        assert!(pool.resident_bytes() <= pool.budget_bytes());
        rows.push(vec![
            name.to_string(),
            format!("{:.1}", pool.resident_bytes() as f64 / page as f64),
            spilled.to_string(),
            stats.allocated.to_string(),
            stats.reused.to_string(),
            format!("{:.2}", wall * 1e3),
        ]);
    }
    bh::table(
        &["pool budget", "resident pages", "spilled rec", "pages alloc", "pages reused", "wall ms"],
        &rows,
    );
    println!("\n({} sessions × {} layers, {} tokens each; page = {} B = {} records.)",
             sessions, layers_per_sess, toks, page, mnn_llm::kv::PAGE_TOKENS);

    // Part 4: the *weight* half of hybrid storage — sweep the packed-layer
    // DRAM budget on the fixture model. Tokens are asserted bit-identical
    // at every budget; tight budgets show LRU evictions, one-layer-ahead
    // prefetch traffic, and the modeled UFS read time they pay.
    bh::section("Weight residency — packed-layer DRAM budget sweep (4-layer fixture)");
    let fx = fixtures::write_fixture_with_layers(31, 4).unwrap();
    let probe = NativeModel::load(fx.dir(), EngineOptions::default()).unwrap();
    let total = probe.weight_metrics().packed_bytes;
    drop(probe);
    let prompt: Vec<usize> = (0..24).map(|i| 40 + i).collect();
    let mut reference: Option<Vec<usize>> = None;
    let mut rows = Vec::new();
    for (name, budget) in [
        ("unlimited", usize::MAX),
        ("= packed", total),
        ("1/2 packed", total / 2),
        ("1/4 packed", total / 4),
    ] {
        let m = NativeModel::load(
            fx.dir(),
            EngineOptions { weight_dram_bytes: budget, ..EngineOptions::default() },
        )
        .unwrap();
        let t0 = std::time::Instant::now();
        let out = m.generate_once(&prompt, 16);
        let wall = t0.elapsed().as_secs_f64();
        match &reference {
            None => reference = Some(out),
            Some(want) => assert_eq!(&out, want, "budget `{name}` changed tokens"),
        }
        let wm = m.weight_metrics();
        rows.push(vec![
            name.to_string(),
            format!("{:.1}", wm.resident_bytes as f64 / 1024.0),
            wm.demand_fetches.to_string(),
            wm.evictions.to_string(),
            format!("{}/{}", wm.prefetch_hits, wm.prefetch_stalls),
            format!("{:.3}", wm.flash_read_s * 1e3),
            format!("{:.2}", wall * 1e3),
        ]);
    }
    bh::table(
        &["weight budget", "resident KB", "fetches", "evict", "pf hit/stall", "flash (UFS) ms", "wall ms"],
        &rows,
    );
    println!("\n(Packed layers total {:.1} KB; tokens bit-identical at every budget —",
             total as f64 / 1024.0);
    println!(" the budget trades DRAM for modeled flash-read time, same as KV spill.)");

    // Part 5: cross-session eviction policy — who pays for pool pressure.
    // ShedSelf: whichever session appends over budget spills itself.
    // LargestHolder: the engine spills the biggest context between ticks.
    // Tokens are bit-identical either way; the flash-traffic attribution
    // moves from "whoever appends" to "whoever holds the most".
    bh::section("Eviction policy under a shared KV budget — ShedSelf vs LargestHolder");
    let fxe = fixtures::write_fixture(35).unwrap();
    let cfge = fixtures::fixture_config();
    let pagee = KvPool::page_bytes(cfge.kv_heads, cfge.head_dim());
    let long_prompt: Vec<usize> = (0..2 * PAGE_TOKENS - 1).map(|i| 40 + i % 200).collect();
    let short_prompt: Vec<usize> = (0..PAGE_TOKENS - 1).map(|i| 30 + i % 200).collect();
    let mut rows = Vec::new();
    let mut reference: Option<Vec<Vec<usize>>> = None;
    for (name, policy) in [
        ("shed-self (PR 1)", EvictionPolicy::ShedSelf),
        ("largest-holder", EvictionPolicy::LargestHolder),
    ] {
        let m = NativeModel::load(
            fxe.dir(),
            EngineOptions {
                kv_pool_bytes: 6 * pagee,
                eviction: policy,
                ..EngineOptions::default()
            },
        )
        .unwrap();
        let mut c = Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Interleaved);
        let long_id = c.submit(long_prompt.clone(), 12);
        let short_id = c.submit(short_prompt.clone(), 12);
        let rs = c.run_all().unwrap();
        let tokens: Vec<Vec<usize>> = rs.iter().map(|r| r.tokens.clone()).collect();
        match &reference {
            None => reference = Some(tokens),
            Some(want) => assert_eq!(&tokens, want, "eviction policy changed tokens"),
        }
        let spill_of = |id: u64| {
            rs.iter().find(|r| r.id == id).map(|r| r.metrics.spilled_records).unwrap_or(0)
        };
        rows.push(vec![
            name.to_string(),
            spill_of(long_id).to_string(),
            spill_of(short_id).to_string(),
            c.metrics.kv.holder_sheds.to_string(),
            c.metrics.kv.preemptions.to_string(),
        ]);
    }
    bh::table(
        &["policy", "long-req spills", "short-req spills", "holder sheds", "preemptions"],
        &rows,
    );
    println!("\n(Two sessions over a 6-page budget; tokens asserted identical across policies.)");

    // Part 6: the shared-prefix COW cache — prefill work vs shared-prefix
    // fraction × session count. Cold engines pay the full prompt for every
    // session; warm engines prefill the shared region once (request 1
    // publishes it) and later sessions fork off the cached pages, paying
    // only their suffixes — fewer prompt tokens, fewer prefill-phase
    // weight fetches, lower TTFT for the follow-up requests.
    bh::section("Prefix cache — shared-prefix fraction × sessions → prefill work + TTFT");
    let fxp = fixtures::write_fixture_with_layers(37, 4).unwrap();
    let probep = NativeModel::load(fxp.dir(), EngineOptions::default()).unwrap();
    let per_layer_p = probep.weight_metrics().packed_bytes / 4;
    drop(probep);
    let total_len = 32usize;
    let mut rows = Vec::new();
    for sessions in [2usize, 4, 8] {
        for shared in [8usize, 16, 24] {
            let prefix: Vec<usize> = (0..shared).map(|i| 50 + (3 * i) % 300).collect();
            let prompts: Vec<Vec<usize>> = (0..sessions)
                .map(|s| {
                    let mut p = prefix.clone();
                    p.extend((shared..total_len).map(|i| 100 + (s * 37 + i) % 300));
                    p
                })
                .collect();
            let run = |cache: usize| {
                let m = NativeModel::load(
                    fxp.dir(),
                    EngineOptions {
                        weight_dram_bytes: per_layer_p * 2,
                        prefill_chunk_tokens: 8,
                        prefix_cache_bytes: cache,
                        ..EngineOptions::default()
                    },
                )
                .unwrap();
                let mut c =
                    Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Interleaved);
                c.submit(prompts[0].clone(), 4);
                let mut rs = c.run_all().unwrap();
                for p in &prompts[1..] {
                    c.submit(p.clone(), 4);
                }
                rs.extend(c.run_all().unwrap());
                rs.sort_by_key(|r| r.id);
                let toks: Vec<Vec<usize>> = rs.iter().map(|r| r.tokens.clone()).collect();
                let follow_ttft: Vec<f64> = rs[1..].iter().map(|r| r.metrics.ttft_s).collect();
                let w = c.backend().as_native().unwrap().weight_metrics();
                (toks, w.prefill_fetches, w.prompt_tokens_prefilled, c.metrics.prefix, follow_ttft)
            };
            let (cold_t, cold_f, cold_p, _, cold_ttft) = run(0);
            let (warm_t, warm_f, warm_p, px, warm_ttft) = run(1 << 22);
            assert_eq!(warm_t, cold_t, "prefix cache changed tokens");
            let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
            rows.push(vec![
                sessions.to_string(),
                format!("{shared}/{total_len}"),
                format!("{cold_p} → {warm_p}"),
                format!("{cold_f} → {warm_f}"),
                px.prefill_tokens_saved.to_string(),
                px.cow_copies.to_string(),
                format!("{:.2} → {:.2}", mean(&cold_ttft) * 1e3, mean(&warm_ttft) * 1e3),
            ]);
        }
    }
    bh::table(
        &[
            "sessions",
            "shared",
            "prompt tok (cold → warm)",
            "prefill fetches (cold → warm)",
            "tok saved",
            "cow",
            "follow-up TTFT ms (cold → warm)",
        ],
        &rows,
    );
    println!("\n(Each config: request 1 publishes the prefix, the rest fork off it; tokens");
    println!(" asserted bit-identical with the cache disabled. TTFT over follow-up requests.)");
}
