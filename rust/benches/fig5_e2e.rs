//! Figure 5 reproduction: prefill and decode speeds of MNN-LLM vs
//! llama.cpp, MLC-LLM and fastllm on CPU (4 threads) and GPU, prompts
//! {64, 256, 1024}, decode capped at 16 tokens, models Qwen2-1.5B /
//! Qwen2-7B / Llama3-8B.
//!
//! Part 1 — the figure itself, from the calibrated engine models on the
//! Snapdragon-8Gen3 device profile (DESIGN.md §Substitutions: the
//! competitor binaries cannot run here).
//!
//! Part 2 — measured mechanism ablations on the *real* native engine with
//! the tiny model: each paper optimization toggled off, so the factor
//! decomposition in part 1 is grounded in running code.
//!
//! Run: `cargo bench --bench fig5_e2e`

use mnn_llm::baselines::{self, Device};
use mnn_llm::bench as bh;
use mnn_llm::coordinator::scheduler::{Backend, Coordinator};
use mnn_llm::coordinator::SchedulePolicy;
use mnn_llm::device::SocProfile;
use mnn_llm::model::config::ModelConfig;
use mnn_llm::model::native::{EngineOptions, NativeModel};
use mnn_llm::reorder::solver::TileConfig;
use mnn_llm::util::json::Json;
use mnn_llm::util::rng::Rng;

const PROMPTS: [usize; 3] = [64, 256, 1024];
const DECODE_CTX: usize = 256;

fn figure(soc: &SocProfile, device: Device, label: &str) {
    for model in [ModelConfig::qwen2_1_5b(), ModelConfig::qwen2_7b(), ModelConfig::llama3_8b()] {
        bh::section(&format!("Fig. 5 [{label}] — {}", model.name));
        let mut rows = Vec::new();
        for eng in baselines::engines() {
            let f = match device {
                Device::Cpu4Threads => eng.cpu,
                Device::Gpu => eng.gpu,
            };
            let Some(f) = f else {
                rows.push(vec![
                    eng.name.into(),
                    "—".into(), "—".into(), "—".into(), "—".into(),
                ]);
                continue;
            };
            let mut cells = vec![eng.name.to_string()];
            for p in PROMPTS {
                cells.push(format!("{:.0}", baselines::prefill_tok_s(soc, &model, &f, device, p)));
            }
            cells.push(format!("{:.1}", baselines::decode_tok_s(soc, &model, &f, device, DECODE_CTX)));
            rows.push(cells);
        }
        bh::table(
            &["engine", "prefill@64", "prefill@256", "prefill@1024", "decode tok/s"],
            &rows,
        );
    }
}

fn ratio_summary(soc: &SocProfile) {
    bh::section("Headline ratios (paper: 8.6×/20.5× prefill, 2.3×/8.9× decode on CPU; 25.3×/7.1× & 2.8×/1.7× on GPU)");
    let engines = baselines::engines();
    let get = |n: &str| engines.iter().find(|e| e.name == n).unwrap();
    let m15 = ModelConfig::qwen2_1_5b();
    let m7 = ModelConfig::qwen2_7b();
    let mnn_c = get("MNN-LLM").cpu.unwrap();
    let mnn_g = get("MNN-LLM").gpu.unwrap();
    let mut rows = Vec::new();
    let mut push = |name: &str, ours: f64, paper: &str| {
        rows.push(vec![name.into(), format!("{ours:.1}×"), paper.into()]);
    };
    push("CPU prefill vs llama.cpp (1.5B@256)",
         baselines::prefill_tok_s(soc, &m15, &mnn_c, Device::Cpu4Threads, 256)
             / baselines::prefill_tok_s(soc, &m15, &get("llama.cpp").cpu.unwrap(), Device::Cpu4Threads, 256),
         "8.6× (max)");
    push("CPU prefill vs fastllm (1.5B@256)",
         baselines::prefill_tok_s(soc, &m15, &mnn_c, Device::Cpu4Threads, 256)
             / baselines::prefill_tok_s(soc, &m15, &get("fastllm").cpu.unwrap(), Device::Cpu4Threads, 256),
         "20.5× (max)");
    push("CPU decode vs llama.cpp (1.5B)",
         baselines::decode_tok_s(soc, &m15, &mnn_c, Device::Cpu4Threads, DECODE_CTX)
             / baselines::decode_tok_s(soc, &m15, &get("llama.cpp").cpu.unwrap(), Device::Cpu4Threads, DECODE_CTX),
         "2.3×");
    push("CPU decode vs fastllm (1.5B)",
         baselines::decode_tok_s(soc, &m15, &mnn_c, Device::Cpu4Threads, DECODE_CTX)
             / baselines::decode_tok_s(soc, &m15, &get("fastllm").cpu.unwrap(), Device::Cpu4Threads, DECODE_CTX),
         "8.9×");
    push("GPU prefill vs llama.cpp (1.5B@1024)",
         baselines::prefill_tok_s(soc, &m15, &mnn_g, Device::Gpu, 1024)
             / baselines::prefill_tok_s(soc, &m15, &get("llama.cpp").gpu.unwrap(), Device::Gpu, 1024),
         "25.3× (max)");
    push("GPU decode vs llama.cpp (1.5B)",
         baselines::decode_tok_s(soc, &m15, &mnn_g, Device::Gpu, DECODE_CTX)
             / baselines::decode_tok_s(soc, &m15, &get("llama.cpp").gpu.unwrap(), Device::Gpu, DECODE_CTX),
         "7.1×");
    push("GPU prefill vs MLC-LLM (1.5B@1024)",
         baselines::prefill_tok_s(soc, &m15, &mnn_g, Device::Gpu, 1024)
             / baselines::prefill_tok_s(soc, &m15, &get("MLC-LLM").gpu.unwrap(), Device::Gpu, 1024),
         "2.8×");
    push("GPU decode vs MLC-LLM (1.5B)",
         baselines::decode_tok_s(soc, &m15, &mnn_g, Device::Gpu, DECODE_CTX)
             / baselines::decode_tok_s(soc, &m15, &get("MLC-LLM").gpu.unwrap(), Device::Gpu, DECODE_CTX),
         "1.7×");
    push("GPU prefill vs MLC-LLM (7B@64) — MLC wins",
         baselines::prefill_tok_s(soc, &m7, &mnn_g, Device::Gpu, 64)
             / baselines::prefill_tok_s(soc, &m7, &get("MLC-LLM").gpu.unwrap(), Device::Gpu, 64),
         "<1× (paper caveat)");
    bh::table(&["ratio", "ours", "paper"], &rows);
}

/// Part 2: real ablations on the native engine. Prefers real AOT
/// artifacts; falls back to the self-contained fixture model so the
/// measurement always runs.
fn ablations() -> Json {
    let aot = std::path::PathBuf::from("artifacts");
    let (_fx, dir, model_name) = if aot.join("manifest.json").exists() {
        (None, aot, "tiny-qwen2 (AOT artifacts)")
    } else {
        let fx = mnn_llm::model::fixtures::write_fixture(11).expect("fixture");
        let dir = fx.dir().to_path_buf();
        (Some(fx), dir, "fixture-2l (generated)")
    };
    bh::section(&format!("Measured ablations — native engine, {model_name}, this host"));
    let vocab = mnn_llm::model::Manifest::load(&dir).expect("manifest").model.vocab;
    let mut rng = Rng::new(11);
    let prompt: Vec<usize> = (0..64).map(|_| rng.below(vocab)).collect();
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    let mut live_backend = String::new();
    let mut baseline_prefill = 0.0;
    let mut baseline_decode = 0.0;
    for (name, opts) in [
        (
            "MNN-LLM full (solved tile, W4A8/W8A8)",
            EngineOptions::default(),
        ),
        (
            "− hardware tile (2,4,4 under-tiled)",
            EngineOptions {
                tile: TileConfig { e_p: 2, h_p: 4, l_p: 4 },
                ..EngineOptions::default()
            },
        ),
        (
            "− flash embedding (DRAM table)",
            EngineOptions { embedding_in_flash: false, ..EngineOptions::default() },
        ),
        (
            "+ KV spill (budget 48 tok)",
            EngineOptions { kv_budget_tokens: 48, ..EngineOptions::default() },
        ),
    ] {
        let m = NativeModel::load(&dir, opts).unwrap();
        let mut sess = m.new_session();
        // Prefill timing.
        let t0 = std::time::Instant::now();
        let logits = m.prefill(&mut sess, &prompt);
        let prefill_s = t0.elapsed().as_secs_f64();
        // Decode timing (16 steps, paper cap).
        let mut tok = mnn_llm::model::sampler::argmax(&logits);
        let t1 = std::time::Instant::now();
        for _ in 0..16 {
            let l = m.decode(&mut sess, tok);
            tok = mnn_llm::model::sampler::argmax(&l);
        }
        let decode_s = t1.elapsed().as_secs_f64() / 16.0;
        if rows.is_empty() {
            baseline_prefill = prefill_s;
            baseline_decode = decode_s;
            live_backend = m.backend_name().to_string();
        }
        rows.push(vec![
            name.to_string(),
            format!("{:.1}", prompt.len() as f64 / prefill_s),
            format!("{:.1}", 1.0 / decode_s),
            format!("{:.2}×", prefill_s / baseline_prefill),
            format!("{:.2}×", decode_s / baseline_decode),
        ]);
        json_rows.push(Json::obj(vec![
            ("config", Json::Str(name.into())),
            ("prefill_tok_s", Json::Num(prompt.len() as f64 / prefill_s)),
            ("decode_tok_s", Json::Num(1.0 / decode_s)),
        ]));
    }
    bh::table(
        &["config", "prefill tok/s", "decode tok/s", "prefill cost", "decode cost"],
        &rows,
    );
    Json::obj(vec![
        ("model", Json::Str(model_name.into())),
        ("live_backend", Json::Str(live_backend)),
        ("rows", Json::Arr(json_rows)),
    ])
}

/// §5.4's "≈3%" claim: long-tail rearrangement ops with and without region
/// fusion, on a realistic trace (per-layer transpose/concat/gather chain).
fn geometry_ablation() {
    use mnn_llm::geometry::{apply_regions, fuse_region_list, ops};
    bh::section("Geometry compute — region fusion on the long-tail op trace (§5.4)");
    // One decoder layer's rearrangements at qwen2-1.5b scale: head
    // transpose [S,H,d]→[H,S,d], KV gather of 3 consecutive row groups,
    // output concat of 12 head chunks.
    let (s, h, d) = (256usize, 12usize, 128usize);
    let mut regions = Vec::new();
    regions.extend(ops::permute3([s, h, d], [1, 0, 2]));
    // Token gather: one region per token (the shape Gather lowers to).
    let idx: Vec<usize> = (64..64 + s).collect();
    regions.extend(ops::gather_rows(&idx, d));
    regions.extend(ops::concat_rows(&vec![s; h], d));
    let fused = fuse_region_list(&regions);
    let n = s * h * d;
    let src: Vec<f32> = (0..n).map(|i| i as f32).collect();
    let mut dst = vec![0f32; n.max(3 * s * d)];
    let raw = bh::bench(&format!("unfused trace ({} regions)", regions.len()), || {
        apply_regions(&regions, &src, &mut dst);
        std::hint::black_box(&dst);
    });
    let opt = bh::bench(&format!("fused trace   ({} regions)", fused.len()), || {
        apply_regions(&fused, &src, &mut dst);
        std::hint::black_box(&dst);
    });
    println!(
        "  region count {} → {}; long-tail op time −{:.1}% (paper: ≈3% of *total* inference)",
        regions.len(),
        fused.len(),
        100.0 * (1.0 - opt.mean_s / raw.mean_s)
    );
}

/// Streaming TTFT under load: the quantity the step()-based engine makes
/// visible (and the batch coordinator could not). Three requests arrive
/// together; under Fifo the third's first token waits for two whole
/// completions, under Interleaved it waits only for three prefills.
fn streaming_ttft() {
    bh::section("Streaming TTFT under load — Fifo vs Interleaved (fixture model, step() engine)");
    let fx = mnn_llm::model::fixtures::write_fixture(12).expect("fixture");
    let mut rng = Rng::new(12);
    let vocab = mnn_llm::model::fixtures::fixture_config().vocab;
    let prompts: Vec<Vec<usize>> =
        (0..3).map(|_| (0..48).map(|_| rng.below(vocab)).collect()).collect();
    let mut rows = Vec::new();
    for policy in [SchedulePolicy::Fifo, SchedulePolicy::Interleaved] {
        let m = NativeModel::load(fx.dir(), EngineOptions::default()).unwrap();
        let mut c = Coordinator::new(Backend::Native(Box::new(m)), policy);
        for p in &prompts {
            c.submit(p.clone(), 16);
        }
        let rs = c.run_all().unwrap();
        for r in &rs {
            rows.push(vec![
                format!("{policy:?}"),
                r.id.to_string(),
                format!("{:.1}", r.metrics.ttft_s * 1e3),
                format!("{:.1}", r.metrics.e2e_s * 1e3),
                format!("{:?}", r.finish_reason),
            ]);
        }
    }
    bh::table(&["policy", "req", "TTFT ms", "e2e ms", "finish"], &rows);
    println!("\n(TTFT = arrival → first Token event, queue wait included; under Fifo the");
    println!(" later requests' TTFT grows by whole earlier completions, under Interleaved");
    println!(" only by the earlier prefills — same greedy tokens either way.)");
}

/// Fused batched decode under a tight weight budget: the amortization
/// curve. One engine tick runs all B sessions through a single layer walk,
/// so flash weight fetches per generated token fall ≈ 1/B while the
/// sequential baseline stays ≈ layers/token — the §4.1 decode-bandwidth
/// lever continuous batching buys on the native backend.
fn batched_decode_amortization() -> Json {
    bh::section(
        "Fused batched decode — weight-fetch amortization vs batch size \
         (fixture-6l, DRAM budget = 2 of 6 layers)",
    );
    const LAYERS: usize = 6;
    const STEPS: usize = 16;
    let fx = mnn_llm::model::fixtures::write_fixture_with_layers(13, LAYERS).expect("fixture");
    let per_layer = {
        let probe = NativeModel::load(fx.dir(), EngineOptions::default()).unwrap();
        probe.weight_metrics().packed_bytes / LAYERS
    };
    let opts = EngineOptions { weight_dram_bytes: per_layer * 2, ..EngineOptions::default() };
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    let mut seq_fpt_at_1 = 0.0;
    for b in [1usize, 2, 4, 8] {
        let m = NativeModel::load(fx.dir(), opts.clone()).unwrap();
        let mut rng = Rng::new(13 + b as u64);
        let mut sessions = Vec::new();
        let mut toks = Vec::new();
        for _ in 0..b {
            let prompt: Vec<usize> = (0..8).map(|_| rng.below(m.config.vocab)).collect();
            let mut s = m.new_session();
            let l = m.prefill(&mut s, &prompt);
            toks.push(mnn_llm::model::sampler::argmax(&l));
            sessions.push(s);
        }
        let w0 = m.weight_metrics();
        let t0 = std::time::Instant::now();
        for _ in 0..STEPS {
            let rows_l = {
                let mut refs: Vec<&mut mnn_llm::model::native::NativeSession> =
                    sessions.iter_mut().collect();
                m.decode_batch(&mut refs, &toks)
            };
            for (r, l) in rows_l.iter().enumerate() {
                toks[r] = mnn_llm::model::sampler::argmax(l);
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let w1 = m.weight_metrics();
        let tokens = (w1.tokens_generated - w0.tokens_generated) as f64;
        let fetches = (w1.total_fetches() - w0.total_fetches()) as f64;
        let fpt = fetches / tokens;
        if b == 1 {
            seq_fpt_at_1 = fpt;
        }
        rows.push(vec![
            format!("B={b}"),
            format!("{fetches:.0}"),
            format!("{tokens:.0}"),
            format!("{fpt:.2}"),
            format!("{:.2}×", if fpt > 0.0 { seq_fpt_at_1 / fpt } else { f64::INFINITY }),
            format!("{:.1}", tokens / wall),
        ]);
        json_rows.push(Json::obj(vec![
            ("batch", Json::Num(b as f64)),
            ("weight_fetches", Json::Num(fetches)),
            ("tokens", Json::Num(tokens)),
            ("fetches_per_token", Json::Num(fpt)),
            ("decode_tok_s", Json::Num(tokens / wall)),
        ]));
    }
    bh::table(
        &["batch", "weight fetches", "tokens", "fetch/tok", "amortization", "decode tok/s"],
        &rows,
    );
    println!("\n(One fused layer walk per tick shared by all B sessions: fetch/tok ≈ layers/B");
    println!(" under a streaming budget, vs ≈ layers for sequential decode — the guarded 1/3");
    println!(" bound at B=4 lives in tests/batched_decode.rs.)");
    Json::Arr(json_rows)
}

/// Chunked + fused batched prefill under a tight weight budget: the TTFT
/// and prefill-bandwidth sweep. A mixed arrival burst (short prompts next
/// to long ones) is served across a chunk-size × rows-per-tick grid; the
/// table reports TTFT p50/p95 and pure-prefill weight fetches per prompt
/// (fused admission shares one layer walk across every prompt admitted in
/// a tick; chunking keeps a long prompt from monopolizing the tick).
fn chunked_prefill_sweep() -> Json {
    bh::section(
        "Chunked+fused prefill — chunk size × max_rows_per_tick \
         (fixture-6l, DRAM budget = 2 of 6 layers, 4 short + 2 long prompts)",
    );
    const LAYERS: usize = 6;
    let fx = mnn_llm::model::fixtures::write_fixture_with_layers(14, LAYERS).expect("fixture");
    let per_layer = {
        let probe = NativeModel::load(fx.dir(), EngineOptions::default()).unwrap();
        probe.weight_metrics().packed_bytes / LAYERS
    };
    let mut rng = Rng::new(14);
    let vocab = mnn_llm::model::fixtures::fixture_config().vocab;
    let mut prompts: Vec<Vec<usize>> =
        (0..4).map(|_| (0..6).map(|_| rng.below(vocab)).collect()).collect();
    prompts.extend((0..2).map(|_| (0..48).map(|_| rng.below(vocab)).collect::<Vec<_>>()));
    let fmt_lim = |v: usize| if v == usize::MAX { "∞".to_string() } else { v.to_string() };
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    for (chunk, cap) in [
        (usize::MAX, usize::MAX), // PR 4 behavior: monolithic, uncapped
        (16, usize::MAX),
        (8, usize::MAX),
        (8, 4),
        (4, usize::MAX),
        (4, 2),
    ] {
        let m = NativeModel::load(
            fx.dir(),
            EngineOptions {
                weight_dram_bytes: per_layer * 2,
                prefill_chunk_tokens: chunk,
                max_rows_per_tick: cap,
                ..EngineOptions::default()
            },
        )
        .unwrap();
        let mut c = Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Interleaved);
        for p in &prompts {
            c.submit(p.clone(), 8);
        }
        c.run_all().unwrap();
        let mut ttfts: Vec<f64> = c.metrics.completed.iter().map(|m| m.ttft_s).collect();
        ttfts.sort_by(f64::total_cmp);
        let w = c.backend().as_native().unwrap().weight_metrics();
        rows.push(vec![
            fmt_lim(chunk),
            fmt_lim(cap),
            format!("{:.1}", mnn_llm::util::stats::median(&ttfts) * 1e3),
            format!("{:.1}", mnn_llm::util::stats::percentile(&ttfts, 95.0) * 1e3),
            format!("{:.2}", w.prefill_fetches as f64 / prompts.len() as f64),
            format!("{:.2}", w.fetches_per_prompt_token()),
        ]);
        json_rows.push(Json::obj(vec![
            ("chunk", Json::Str(fmt_lim(chunk))),
            ("rows_per_tick", Json::Str(fmt_lim(cap))),
            ("ttft_p50_s", Json::Num(mnn_llm::util::stats::median(&ttfts))),
            ("ttft_p95_s", Json::Num(mnn_llm::util::stats::percentile(&ttfts, 95.0))),
            ("prefill_fetches_per_prompt", Json::Num(w.prefill_fetches as f64 / prompts.len() as f64)),
            ("fetches_per_prompt_token", Json::Num(w.fetches_per_prompt_token())),
        ]));
    }
    bh::table(
        &[
            "chunk",
            "rows/tick",
            "TTFT p50 ms",
            "TTFT p95 ms",
            "prefill fetch/prompt",
            "fetch/ptok",
        ],
        &rows,
    );
    println!("\n(Fused admission prefills every same-tick arrival through ONE layer walk and");
    println!(" chunking bounds a long prompt's share of each tick, so short prompts' TTFT");
    println!(" stops scaling with the long prompts ahead of them; the guarded ≤1/2");
    println!(" fetches-per-prompt bound lives in tests/chunked_prefill.rs.)");
    Json::Arr(json_rows)
}

/// Speculative decoding on the fused tick: spec_depth × batch size under
/// the same tight weight budget as the batched-decode sweep. The paired
/// fixture (6-layer target whose upper layers are residual passthroughs +
/// the matching 1-layer draft) makes greedy acceptance deterministic, so
/// the sweep isolates the mechanism: a depth-k verify walk commits up to
/// k+1 tokens against ONE layer-fetch sweep, multiplying the batch
/// amortization — flash fetches per committed token fall ≈ layers/(B·(k+1))
/// while plain decode pays ≈ layers/B.
fn speculation_sweep() -> Json {
    bh::section(
        "Speculative decoding — spec_depth × batch \
         (paired fixture-6l target + 1l draft, DRAM budget = 2 of 6 layers)",
    );
    const LAYERS: usize = 6;
    const NEW_TOKENS: usize = 16;
    let (tfx, dfx) =
        mnn_llm::model::fixtures::write_paired_fixture(13, LAYERS).expect("paired fixture");
    let per_layer = {
        let probe = NativeModel::load(tfx.dir(), EngineOptions::default()).unwrap();
        probe.weight_metrics().packed_bytes / LAYERS
    };
    let opts = EngineOptions { weight_dram_bytes: per_layer * 2, ..EngineOptions::default() };
    let vocab = mnn_llm::model::fixtures::fixture_config().vocab;
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    for b in [1usize, 2, 4] {
        let mut plain_fpt = 0.0;
        for depth in [0usize, 2, 4] {
            let m = NativeModel::load(tfx.dir(), opts.clone()).unwrap();
            let mut c =
                Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Interleaved);
            if depth > 0 {
                let d = NativeModel::load(dfx.dir(), EngineOptions::default()).unwrap();
                c.attach_draft(d, depth);
            }
            let mut rng = Rng::new(13 + b as u64);
            for _ in 0..b {
                let prompt: Vec<usize> = (0..8).map(|_| rng.below(vocab)).collect();
                c.submit(prompt, NEW_TOKENS);
            }
            let t0 = std::time::Instant::now();
            let rs = c.run_all().unwrap();
            let wall = t0.elapsed().as_secs_f64();
            let tokens: usize = rs.iter().map(|r| r.tokens.len()).sum();
            let w = c.backend().as_native().unwrap().weight_metrics();
            let fpt = w.decode_fetches as f64 / tokens.max(1) as f64;
            if depth == 0 {
                plain_fpt = fpt;
            }
            let sm = c.metrics.spec;
            rows.push(vec![
                format!("B={b}"),
                depth.to_string(),
                sm.walks.to_string(),
                format!("{:.2}", sm.committed_per_walk()),
                format!("{:.0}%", sm.acceptance_rate() * 100.0),
                format!("{fpt:.2}"),
                format!("{:.2}×", if fpt > 0.0 { plain_fpt / fpt } else { f64::INFINITY }),
                format!("{:.1}", tokens as f64 / wall),
            ]);
            json_rows.push(Json::obj(vec![
                ("batch", Json::Num(b as f64)),
                ("spec_depth", Json::Num(depth as f64)),
                ("walks", Json::Num(sm.walks as f64)),
                ("committed_per_walk", Json::Num(sm.committed_per_walk())),
                ("acceptance_rate", Json::Num(sm.acceptance_rate())),
                ("decode_fetches_per_token", Json::Num(fpt)),
                (
                    "amortization_vs_plain",
                    Json::Num(if fpt > 0.0 { plain_fpt / fpt } else { 0.0 }),
                ),
                ("decode_tok_s", Json::Num(tokens as f64 / wall)),
            ]));
        }
    }
    bh::table(
        &[
            "batch",
            "depth",
            "walks",
            "tok/walk",
            "accept",
            "decode fetch/tok",
            "vs depth 0",
            "decode tok/s",
        ],
        &rows,
    );
    println!("\n(Each verify row advances k+1 positions through the tick's single fused layer");
    println!(" walk, so committed tokens per fetch sweep scale with B·(accepted+1); rejected");
    println!(" proposals truncate right back out of the KV. The guarded fetch-drop bound");
    println!(" lives in tests/speculative.rs.)");
    Json::Arr(json_rows)
}

/// Replica-scaling sweep for the cluster subsystem: 1/2/4 data-parallel
/// engine replicas behind the KV-locality-aware router, serving the same
/// request burst on the I/O-dominated configuration (2 of 6 layers
/// resident, flash reads sleeping their modeled time, one row per tick).
/// A single engine spends most of each tick stalled on flash, so
/// replicas' reads overlap and aggregate goodput scales even on one
/// core — the regime the `cluster` module targets. Reports aggregate
/// decode goodput and TTFT p50/p95; writes `BENCH_cluster.json`.
fn cluster_scaling_sweep() -> Json {
    use mnn_llm::cluster::{Cluster, RouterPolicy};
    use mnn_llm::coordinator::Engine;
    use mnn_llm::device::MemTier;

    bh::section(
        "Cluster replica scaling — aggregate goodput & TTFT vs replicas \
         (fixture-6l, DRAM budget = 2 of 6 layers, stalled flash reads, 8 requests)",
    );
    const LAYERS: usize = 6;
    const NEW_TOKENS: usize = 6;
    const REQUESTS: u64 = 8;
    let fx = mnn_llm::model::fixtures::write_fixture_with_layers(15, LAYERS).expect("fixture");
    let per_layer = {
        let probe = NativeModel::load(fx.dir(), EngineOptions::default()).unwrap();
        probe.weight_metrics().packed_bytes / LAYERS
    };
    let opts = move || EngineOptions {
        weight_dram_bytes: per_layer * 2,
        weight_flash_stall: Some(MemTier { name: "bench-stall", read_bw: 1e9, latency_s: 1.5e-3 }),
        max_rows_per_tick: 1,
        ..EngineOptions::default()
    };
    let vocab = mnn_llm::model::fixtures::fixture_config().vocab;
    let dir = fx.dir().to_path_buf();
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    let mut tok_s_at_1 = 0.0;
    for replicas in [1usize, 2, 4] {
        let dir = dir.clone();
        let mut cluster = Cluster::new(replicas, RouterPolicy::KvAffinity, move |_r| {
            let m = NativeModel::load(&dir, opts())?;
            Ok(Engine::new(m, SchedulePolicy::Interleaved))
        })
        .expect("cluster startup");
        let mut rng = Rng::new(15);
        for _ in 0..REQUESTS {
            let prompt: Vec<usize> = (0..8).map(|_| rng.below(vocab)).collect();
            cluster.submit(prompt, NEW_TOKENS).expect("submit");
        }
        let t0 = std::time::Instant::now();
        let rs = cluster.run_all().expect("drain");
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(rs.len() as u64, REQUESTS);
        let tokens: usize = rs.iter().map(|r| r.metrics.new_tokens).sum();
        let tok_s = tokens as f64 / wall;
        if replicas == 1 {
            tok_s_at_1 = tok_s;
        }
        let mut ttfts: Vec<f64> = rs.iter().map(|r| r.metrics.ttft_s).collect();
        ttfts.sort_by(f64::total_cmp);
        let p50 = mnn_llm::util::stats::median(&ttfts);
        let p95 = mnn_llm::util::stats::percentile(&ttfts, 95.0);
        rows.push(vec![
            replicas.to_string(),
            format!("{tok_s:.1}"),
            format!("{:.2}×", if tok_s_at_1 > 0.0 { tok_s / tok_s_at_1 } else { 1.0 }),
            format!("{:.1}", p50 * 1e3),
            format!("{:.1}", p95 * 1e3),
            format!("{wall:.3}"),
        ]);
        json_rows.push(Json::obj(vec![
            ("replicas", Json::Num(replicas as f64)),
            ("aggregate_tok_s", Json::Num(tok_s)),
            ("speedup_vs_1", Json::Num(if tok_s_at_1 > 0.0 { tok_s / tok_s_at_1 } else { 1.0 })),
            ("ttft_p50_s", Json::Num(p50)),
            ("ttft_p95_s", Json::Num(p95)),
            ("wall_s", Json::Num(wall)),
        ]));
    }
    bh::table(
        &["replicas", "agg tok/s", "vs 1", "TTFT p50 ms", "TTFT p95 ms", "wall s"],
        &rows,
    );
    println!("\n(Each replica owns a full engine — weight arena, KV pool, prefix cache — on");
    println!(" its own thread; the router places by session/prefix affinity then least");
    println!(" outstanding work. The guarded ≥1.7× two-replica bound lives in");
    println!(" tests/cluster.rs.)");
    Json::Arr(json_rows)
}

fn main() {
    let soc = SocProfile::snapdragon_8gen3();
    figure(&soc, Device::Cpu4Threads, "CPU, 4 threads");
    figure(&soc, Device::Gpu, "GPU (OpenCL model)");
    ratio_summary(&soc);
    let ablation_json = ablations();
    geometry_ablation();
    streaming_ttft();
    let batched_json = batched_decode_amortization();
    let chunked_json = chunked_prefill_sweep();
    let spec_json = speculation_sweep();
    let artifact = Json::obj(vec![
        ("bench", Json::Str("fig5_e2e".into())),
        ("ablations", ablation_json),
        ("batched_decode", batched_json),
        ("chunked_prefill", chunked_json),
        ("speculation", spec_json),
    ]);
    bh::write_json("BENCH_fig5.json", &artifact);
    let cluster_json = cluster_scaling_sweep();
    let cluster_artifact = Json::obj(vec![
        ("bench", Json::Str("cluster_scaling".into())),
        ("replica_sweep", cluster_json),
    ]);
    bh::write_json("BENCH_cluster.json", &cluster_artifact);
}
