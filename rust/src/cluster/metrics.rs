//! Per-replica + aggregated metrics for a [`super::Cluster`].

use crate::coordinator::EngineMetrics;

/// One [`EngineMetrics`] snapshot per replica, plus an aggregate view.
/// Snapshots are taken at replica quiescent points (idle, shutdown, or an
/// explicit metrics round-trip), so after `Cluster::run_all` they are
/// exact.
#[derive(Clone, Debug, Default)]
pub struct ClusterMetrics {
    pub per_replica: Vec<EngineMetrics>,
}

impl ClusterMetrics {
    pub fn replicas(&self) -> usize {
        self.per_replica.len()
    }

    /// Fold every replica's counters into one engine-shaped view:
    /// completed requests concatenate, counters sum, byte gauges sum
    /// (each replica owns a disjoint arena), and the compute-backend name
    /// is taken from the first replica that ran anything (replicas are
    /// homogeneous by construction).
    pub fn aggregate(&self) -> EngineMetrics {
        let mut acc = EngineMetrics::default();
        for m in &self.per_replica {
            merge_into(&mut acc, m);
        }
        acc
    }

    /// Aggregate summary line plus one indented line per replica.
    pub fn summary(&self, wall_s: f64) -> String {
        let mut s = format!("cluster x{}: {}", self.replicas(), self.aggregate().summary(wall_s));
        for (i, m) in self.per_replica.iter().enumerate() {
            s.push_str(&format!("\n  r{i}: {}", m.summary(wall_s)));
        }
        s
    }
}

/// Merge one replica's metrics into an accumulator.
fn merge_into(acc: &mut EngineMetrics, m: &EngineMetrics) {
    acc.completed.extend(m.completed.iter().copied());
    acc.cancelled += m.cancelled;
    acc.rejected += m.rejected;
    acc.failed += m.failed;

    acc.kv.spilled_records += m.kv.spilled_records;
    acc.kv.restored_records += m.kv.restored_records;
    acc.kv.preemptions += m.kv.preemptions;
    acc.kv.holder_sheds += m.kv.holder_sheds;

    acc.weights.resident_bytes += m.weights.resident_bytes;
    acc.weights.packed_bytes += m.weights.packed_bytes;
    acc.weights.demand_fetches += m.weights.demand_fetches;
    acc.weights.evictions += m.weights.evictions;
    acc.weights.prefetch_issued += m.weights.prefetch_issued;
    acc.weights.prefetch_hits += m.weights.prefetch_hits;
    acc.weights.prefetch_stalls += m.weights.prefetch_stalls;
    acc.weights.prefetch_depth = acc.weights.prefetch_depth.max(m.weights.prefetch_depth);
    acc.weights.flash_read_s += m.weights.flash_read_s;
    acc.weights.tokens_generated += m.weights.tokens_generated;
    acc.weights.decode_fetches += m.weights.decode_fetches;
    acc.weights.prompt_tokens_prefilled += m.weights.prompt_tokens_prefilled;
    acc.weights.prefill_fetches += m.weights.prefill_fetches;

    acc.prefix.lookups += m.prefix.lookups;
    acc.prefix.hits += m.prefix.hits;
    acc.prefix.prefill_tokens_saved += m.prefix.prefill_tokens_saved;
    acc.prefix.bytes_saved += m.prefix.bytes_saved;
    acc.prefix.inserts += m.prefix.inserts;
    acc.prefix.evictions += m.prefix.evictions;
    acc.prefix.entries += m.prefix.entries;
    acc.prefix.shared_page_bytes += m.prefix.shared_page_bytes;
    acc.prefix.stash_bytes += m.prefix.stash_bytes;
    acc.prefix.cow_copies += m.prefix.cow_copies;

    if acc.compute.backend.is_empty() {
        acc.compute.backend = m.compute.backend;
    }
    acc.compute.gemm_calls += m.compute.gemm_calls;
    acc.compute.gemm_tiles += m.compute.gemm_tiles;
    acc.compute.attention_rows += m.compute.attention_rows;
    acc.compute.norm_rows += m.compute.norm_rows;
    acc.compute.activation_rows += m.compute.activation_rows;
    acc.compute.rope_heads += m.compute.rope_heads;

    acc.spec.walks += m.spec.walks;
    acc.spec.proposed += m.spec.proposed;
    acc.spec.accepted += m.spec.accepted;
    acc.spec.committed += m.spec.committed;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::RequestMetrics;

    #[test]
    fn aggregate_sums_counters_and_concatenates_requests() {
        let mut a = EngineMetrics::default();
        a.push(RequestMetrics { new_tokens: 4, ..Default::default() });
        a.cancelled = 1;
        a.kv.spilled_records = 10;
        a.spec.walks = 3;
        a.compute.backend = "scalar";
        a.compute.gemm_calls = 5;
        let mut b = EngineMetrics::default();
        b.push(RequestMetrics { new_tokens: 6, ..Default::default() });
        b.push(RequestMetrics { new_tokens: 2, ..Default::default() });
        b.failed = 2;
        b.kv.spilled_records = 5;
        b.compute.backend = "scalar";
        b.compute.gemm_calls = 7;
        let cm = ClusterMetrics { per_replica: vec![a, b] };
        let agg = cm.aggregate();
        assert_eq!(agg.count(), 3);
        assert_eq!(agg.cancelled, 1);
        assert_eq!(agg.failed, 2);
        assert_eq!(agg.kv.spilled_records, 15);
        assert_eq!(agg.spec.walks, 3);
        assert_eq!(agg.compute.backend, "scalar");
        assert_eq!(agg.compute.gemm_calls, 12);
        let total: usize = agg.completed.iter().map(|m| m.new_tokens).sum();
        assert_eq!(total, 12);
        // Aggregate throughput uses the cluster-wide wall clock.
        assert!((agg.throughput_tok_s(2.0) - 6.0).abs() < 1e-9);
        let s = cm.summary(2.0);
        assert!(s.contains("cluster x2"), "{s}");
        assert!(s.contains("r0:"), "{s}");
        assert!(s.contains("r1:"), "{s}");
    }
}
