//! Placement policy: which replica serves which request.
//!
//! The router sees only cheap summaries — an outstanding-work gauge per
//! replica, a session → replica map, and per-replica
//! [`PrefixFingerprintIndex`] snapshots — never token data or engine
//! internals, so placement is O(replicas) per request and the hot tick
//! loops are untouched.
//!
//! Placement order (under [`RouterPolicy::KvAffinity`], the default):
//!
//! 1. **Session affinity** — a request carrying a `Request::session_id`
//!    the router has seen before goes back to the replica that served it:
//!    that replica holds the conversation's KV spill files and
//!    prefix-cache entries, and bouncing a session re-pays the prefill.
//! 2. **Shared-prefix affinity** — otherwise the prompt is fingerprinted
//!    at page boundaries and placed on the replica whose `PrefixCache`
//!    holds its longest prefix (ties → less outstanding work), so shared
//!    system prompts stay hot on one replica instead of being re-stored N
//!    times.
//! 3. **Least outstanding work** — otherwise the replica with the fewest
//!    estimated outstanding tokens (prompt + budget of every un-finished
//!    placement), ties → lowest replica id. This is the whole policy
//!    under [`RouterPolicy::LeastOutstanding`].

use std::collections::HashMap;

use crate::coordinator::{Request, RequestId};
use crate::kv::paged::PrefixFingerprintIndex;

/// Replica index within a [`super::Cluster`].
pub type ReplicaId = usize;

/// Which placement policy the router runs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RouterPolicy {
    /// Load only: least outstanding estimated work, ties → lowest id.
    /// The locality-blind baseline (tests compare against it).
    LeastOutstanding,
    /// Session affinity, then shared-prefix affinity, then least
    /// outstanding work.
    #[default]
    KvAffinity,
}

/// KV-locality-aware request router. Pure bookkeeping — no channels, no
/// threads — so policies are unit-testable without spinning up engines.
pub struct Router {
    policy: RouterPolicy,
    /// Estimated outstanding tokens per replica (prompt + new-token
    /// budget of every placement not yet observed terminal).
    outstanding: Vec<u64>,
    /// Live placements: request → (replica, charged work). Entries are
    /// removed — and the charge refunded — when the cluster observes the
    /// request's terminal event.
    placements: HashMap<RequestId, (ReplicaId, u64)>,
    /// Session → last replica that served it. Persists across requests
    /// (that is the point); bounded by the number of distinct sessions.
    sessions: HashMap<u64, ReplicaId>,
}

impl Router {
    pub fn new(replicas: usize, policy: RouterPolicy) -> Router {
        Router {
            policy,
            outstanding: vec![0; replicas.max(1)],
            placements: HashMap::new(),
            sessions: HashMap::new(),
        }
    }

    pub fn policy(&self) -> RouterPolicy {
        self.policy
    }

    /// Estimated work units a request pins on its replica until terminal.
    fn work_estimate(req: &Request) -> u64 {
        (req.prompt.len() + req.max_new_tokens) as u64
    }

    /// Place `req`, charging its work estimate to the chosen replica.
    /// `prefix` is one fingerprint-index snapshot per replica (`None` for
    /// replicas without a prefix cache).
    pub fn place(
        &mut self,
        req: &Request,
        prefix: &[Option<PrefixFingerprintIndex>],
    ) -> ReplicaId {
        let choice = match self.policy {
            RouterPolicy::LeastOutstanding => None,
            RouterPolicy::KvAffinity => self.affinity_choice(req, prefix),
        };
        let replica =
            choice.unwrap_or_else(|| self.least_outstanding()).min(self.outstanding.len() - 1);
        let work = Self::work_estimate(req);
        if let Some(o) = self.outstanding.get_mut(replica) {
            *o = o.saturating_add(work);
        }
        if let Some(s) = req.session_id {
            self.sessions.insert(s, replica);
        }
        self.placements.insert(req.id, (replica, work));
        replica
    }

    /// Affinity tiers 1–2; `None` falls through to least-outstanding.
    fn affinity_choice(
        &self,
        req: &Request,
        prefix: &[Option<PrefixFingerprintIndex>],
    ) -> Option<ReplicaId> {
        if let Some(sid) = req.session_id {
            if let Some(&r) = self.sessions.get(&sid) {
                return Some(r);
            }
        }
        // Longest cached prefix wins; ties → less outstanding work, then
        // lowest id (the iteration order below encodes both tiebreaks).
        let mut best: Option<(usize, u64, ReplicaId)> = None;
        for (r, ix) in prefix.iter().enumerate() {
            let Some(ix) = ix else { continue };
            let m = ix.match_len(&req.prompt);
            if m == 0 {
                continue;
            }
            let load = self.outstanding.get(r).copied().unwrap_or(0);
            let better = match best {
                None => true,
                Some((bm, bl, _)) => m > bm || (m == bm && load < bl),
            };
            if better {
                best = Some((m, load, r));
            }
        }
        best.map(|(_, _, r)| r)
    }

    /// The replica with the fewest outstanding estimated tokens (ties →
    /// lowest id).
    fn least_outstanding(&self) -> ReplicaId {
        self.outstanding
            .iter()
            .enumerate()
            .min_by_key(|&(_, &o)| o)
            .map(|(r, _)| r)
            .unwrap_or(0)
    }

    /// Where a still-outstanding request was placed (`None` once its
    /// terminal event has been observed, or if it was never placed).
    pub fn replica_of(&self, id: RequestId) -> Option<ReplicaId> {
        self.placements.get(&id).map(|&(r, _)| r)
    }

    /// Where a session was last served.
    pub fn session_replica(&self, session: u64) -> Option<ReplicaId> {
        self.sessions.get(&session).copied()
    }

    /// The request reached a terminal event: refund its work charge and
    /// forget the placement (session affinity persists).
    pub fn on_terminal(&mut self, id: RequestId) {
        if let Some((r, work)) = self.placements.remove(&id) {
            if let Some(o) = self.outstanding.get_mut(r) {
                *o = o.saturating_sub(work);
            }
        }
    }

    /// Current outstanding-work estimate for a replica.
    pub fn outstanding(&self, replica: ReplicaId) -> u64 {
        self.outstanding.get(replica).copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv::paged::PrefixCache;

    fn req(id: RequestId, prompt: usize, gen: usize) -> Request {
        Request::new(id, vec![7; prompt], gen)
    }

    fn no_prefix(n: usize) -> Vec<Option<PrefixFingerprintIndex>> {
        vec![None; n]
    }

    #[test]
    fn least_outstanding_balances_and_refunds() {
        let mut r = Router::new(2, RouterPolicy::LeastOutstanding);
        assert_eq!(r.place(&req(1, 10, 10), &no_prefix(2)), 0, "tie → lowest id");
        assert_eq!(r.place(&req(2, 10, 10), &no_prefix(2)), 1);
        assert_eq!(r.place(&req(3, 1, 1), &no_prefix(2)), 0, "tie again → 0");
        assert_eq!(r.place(&req(4, 10, 10), &no_prefix(2)), 1, "0 is more loaded");
        assert_eq!(r.replica_of(3), Some(0));
        r.on_terminal(1);
        r.on_terminal(3);
        assert_eq!(r.outstanding(0), 0);
        assert_eq!(r.replica_of(1), None, "terminal forgets the placement");
        assert_eq!(r.place(&req(5, 1, 1), &no_prefix(2)), 0);
        // Terminal for an unknown id is a no-op.
        r.on_terminal(999);
        assert_eq!(r.outstanding(0), 2);
    }

    #[test]
    fn session_affinity_sticks_even_under_load_imbalance() {
        let mut r = Router::new(2, RouterPolicy::KvAffinity);
        let first = req(1, 4, 4).with_session(70);
        assert_eq!(r.place(&first, &no_prefix(2)), 0);
        // Pile unrelated work onto replica 0 so pure load would pick 1…
        for id in 2..6 {
            r.place(&req(id, 100, 100), &no_prefix(2));
        }
        assert!(r.outstanding(0) > r.outstanding(1));
        // …but the resubmitted session stays on 0.
        let again = req(9, 4, 4).with_session(70);
        assert_eq!(r.place(&again, &no_prefix(2)), 0);
        assert_eq!(r.session_replica(70), Some(0));
        // LeastOutstanding ignores the session tag entirely.
        let mut blind = Router::new(2, RouterPolicy::LeastOutstanding);
        blind.place(&req(1, 4, 4).with_session(70), &no_prefix(2));
        for id in 2..6 {
            blind.place(&req(id, 100, 100), &no_prefix(2));
        }
        assert_ne!(blind.place(&req(9, 4, 4).with_session(70), &no_prefix(2)), 0);
    }

    /// A real cache warmed with `ids` (via the public insert path), so
    /// its fingerprint index is exactly what a replica would export.
    fn warm_index(ids: Vec<usize>) -> PrefixFingerprintIndex {
        use crate::kv::paged::{CachedStash, KvPool};
        use std::sync::Arc;
        let pool = Arc::new(KvPool::unbounded());
        let cache = PrefixCache::new(usize::MAX);
        let toks = ids.len();
        let pages = (0..2)
            .map(|_| {
                (0..toks.div_ceil(crate::kv::PAGE_TOKENS))
                    .map(|_| pool.take_handle(2, 8))
                    .collect()
            })
            .collect();
        let stash = CachedStash::charge(
            vec![vec![0f32; toks * 16]; 2],
            vec![vec![0f32; toks * 16]; 2],
            toks,
            pool.clone(),
        );
        assert!(cache.insert(ids, pages, stash));
        cache.fingerprint_index()
    }

    #[test]
    fn prefix_affinity_prefers_longest_cached_prefix() {
        // Replica 1 has the prompt's whole first two pages cached;
        // replica 0 only shares one page. KvAffinity must pick 1 even
        // though 0 carries less load.
        let prompt: Vec<usize> = (0..40).collect();
        let mut partial: Vec<usize> = (0..40).collect();
        if let Some(t) = partial.get_mut(20) {
            *t = 777; // diverges inside page 2
        }
        let ix0 = warm_index(partial);
        let ix1 = warm_index(prompt.clone());
        let mut r = Router::new(2, RouterPolicy::KvAffinity);
        r.place(&req(1, 2, 2), &[None, None]); // skew load onto 0? no: 0 gets it
        assert!(r.outstanding(0) > r.outstanding(1));
        let p = Request::new(2, prompt.clone(), 4);
        assert_eq!(
            r.place(&p, &[Some(ix0.clone()), Some(ix1.clone())]),
            1,
            "longest prefix outranks load"
        );
        // The load-only baseline scatters the same prompt to the
        // least-loaded replica instead.
        let mut blind = Router::new(2, RouterPolicy::LeastOutstanding);
        blind.place(&req(1, 100, 100), &[None, None]);
        assert_eq!(blind.place(&p, &[Some(ix0), Some(ix1)]), 1);
        // …and with load reversed, it abandons the cached replica.
        let mut blind2 = Router::new(2, RouterPolicy::LeastOutstanding);
        blind2.place(&req(1, 2, 2), &[None, None]);
        let mut r2 = Router::new(2, RouterPolicy::KvAffinity);
        r2.place(&req(1, 2, 2), &[None, None]);
        let ix_warm0 = warm_index(prompt.clone());
        // Cache lives on replica 0, which also has more load.
        assert_eq!(
            r2.place(&Request::new(3, prompt.clone(), 4), &[Some(ix_warm0.clone()), None]),
            0,
            "affinity goes to the cache"
        );
        assert_eq!(
            blind2.place(&Request::new(3, prompt, 4), &[Some(ix_warm0), None]),
            1,
            "load-only ignores the cache"
        );
    }
}
