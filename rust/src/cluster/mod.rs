//! Data-parallel engine replicas behind a KV-locality-aware router.
//!
//! A [`Cluster`] owns N engine replicas. Each replica is a full
//! [`Engine`] — its own weight arena, KV pool, prefix cache, and (when
//! attached by the factory) draft model — running its tick loop on a
//! dedicated worker thread. The serving regime this targets is
//! **I/O-dominated**: when weights stream from (modeled) flash because
//! the arena holds only a slice of the model, a single engine spends most
//! of a tick blocked on flash reads, and a second replica's reads overlap
//! with the first's stalls — aggregate goodput scales even on one core.
//!
//! The cluster front end talks to replicas only over channels — one
//! command channel per replica, one shared note channel back — so
//! `Engine`'s single-owner `&mut` API never crosses a thread boundary.
//! Requests, cancellation, token streams and metrics are all routable by
//! id:
//!
//! * [`Cluster::submit_request`] assigns the **global** request id (the
//!   same numbering a single engine would assign), asks the [`Router`]
//!   for a placement, and sends the request to that replica, which queues
//!   it via `Engine::submit_assigned` (ids are preserved, so per-request
//!   RNG streams — derived from the id — are placement-invariant).
//! * Replicas push [`EngineEvent`]s and completed `Response`s back as
//!   notes; [`Cluster::pump`] applies them, updating router accounting on
//!   terminals and reusing the engine's own `deliver` routing so
//!   [`Cluster::submit_streaming`] hands out ordinary [`TokenStream`]s.
//! * [`Cluster::cancel`] routes by the request's recorded placement and
//!   is a clean no-op for unknown or already-terminal ids.
//! * [`ClusterMetrics`] keeps one `EngineMetrics` snapshot per replica
//!   (refreshed at idle points and by an explicit round-trip) plus an
//!   aggregated view.
//!
//! **Bit-identity.** Cluster outputs are bit-identical per request id to
//! a single engine serving the same submissions in the same order:
//! ids are assigned identically, each request's RNG stream derives only
//! from its id, sessions are isolated, and greedy/fused rows are
//! value-neutral by the backend contract — so *which* replica (or tick)
//! serves a request cannot change its tokens.
//!
//! Replica sizing reuses [`crate::parallel::balancer`]:
//! [`replica_worker_configs`] splits the machine's per-core rate vector
//! into one disjoint compute budget per replica, so co-resident replicas
//! do not oversubscribe the cores a single engine was tuned for.

pub mod metrics;
pub mod router;

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::coordinator::events::{EngineEvent, StreamInner, TokenStream};
use crate::coordinator::scheduler::deliver;
use crate::coordinator::{Engine, EngineMetrics, InferenceBackend, Request, RequestId, Response};
use crate::kv::paged::{PrefixCache, PrefixFingerprintIndex};
use crate::parallel::balancer::{balanced_split, split_ranges};
use crate::parallel::pool::WorkerConfig;

pub use metrics::ClusterMetrics;
pub use router::{ReplicaId, Router, RouterPolicy};

/// Cluster → replica. Boxed payloads keep the enum word-sized on the
/// channel.
enum Command {
    /// Queue a request that already carries its cluster-assigned id.
    Submit(Box<Request>),
    /// Cancel by id (the cluster routes to the placed replica; a stale id
    /// is a no-op on the engine too).
    Cancel(RequestId),
    /// Reply with a `Note::Metrics` snapshot (the blocking round-trip
    /// behind [`Cluster::refresh_metrics`]).
    Metrics,
    /// Stop: reply `Note::Stopped` with final metrics and exit the thread.
    Shutdown,
}

/// Replica → cluster.
enum Note {
    /// Sent once, before the loop: the replica loaded (or failed to). On
    /// success it exports its prefix-cache handle so the router can take
    /// fresh fingerprint snapshots at placement time.
    Ready {
        replica: ReplicaId,
        prefix: Option<Arc<PrefixCache>>,
        error: Option<String>,
    },
    /// One engine event, forwarded in emission order.
    Event { replica: ReplicaId, event: EngineEvent },
    /// One completed response.
    Finished { replica: ReplicaId, response: Box<Response> },
    /// Metrics snapshot at a quiescent point (replica went idle).
    Idle { replica: ReplicaId, metrics: Box<EngineMetrics> },
    /// Reply to `Command::Metrics`.
    Metrics { replica: ReplicaId, metrics: Box<EngineMetrics> },
    /// Final snapshot on shutdown; the thread exits right after.
    Stopped { replica: ReplicaId, metrics: Box<EngineMetrics> },
    /// The replica's step loop failed structurally; the thread exits and
    /// its in-flight requests will never reach terminals.
    Fault { replica: ReplicaId, error: String },
}

/// Apply one command on the worker thread. Returns true on `Shutdown`.
fn apply_cmd<B: InferenceBackend>(
    replica: ReplicaId,
    engine: &mut Engine<B>,
    tx: &Sender<Note>,
    cmd: Command,
) -> bool {
    match cmd {
        Command::Submit(req) => {
            engine.submit_assigned(*req);
            false
        }
        Command::Cancel(id) => {
            // The Cancelled event (if the id was still live here) is
            // forwarded at the top of the next loop iteration.
            engine.cancel(id);
            false
        }
        Command::Metrics => {
            let _ = tx.send(Note::Metrics { replica, metrics: Box::new(engine.metrics.clone()) });
            false
        }
        Command::Shutdown => true,
    }
}

/// The replica worker: build the engine **on this thread** (loads run in
/// parallel across replicas), announce readiness, then loop — forward
/// events/responses, drain commands (non-blocking while there is work,
/// blocking when idle), and advance one `step()` at a time.
fn replica_main<B: InferenceBackend>(
    replica: ReplicaId,
    factory: Arc<dyn Fn(ReplicaId) -> Result<Engine<B>> + Send + Sync>,
    rx: Receiver<Command>,
    tx: Sender<Note>,
) {
    let mut engine = match factory(replica) {
        Ok(e) => e,
        Err(e) => {
            let _ = tx.send(Note::Ready { replica, prefix: None, error: Some(format!("{e:#}")) });
            return;
        }
    };
    let prefix = engine.backend().prefix_cache_handle();
    if tx.send(Note::Ready { replica, prefix, error: None }).is_err() {
        return;
    }
    loop {
        // Forward whatever the last step (or a cancel) produced *before*
        // blocking: terminal events must reach the router promptly, and a
        // cancel that emptied the engine would otherwise strand its
        // Cancelled event until the next command.
        for event in engine.drain_events() {
            if tx.send(Note::Event { replica, event }).is_err() {
                return;
            }
        }
        for resp in engine.take_finished() {
            if tx.send(Note::Finished { replica, response: Box::new(resp) }).is_err() {
                return;
            }
        }
        if engine.has_work() {
            // Absorb any commands that arrived during the last tick, then
            // advance one tick.
            loop {
                match rx.try_recv() {
                    Ok(cmd) => {
                        if apply_cmd(replica, &mut engine, &tx, cmd) {
                            let _ = tx.send(Note::Stopped {
                                replica,
                                metrics: Box::new(engine.metrics.clone()),
                            });
                            return;
                        }
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => return,
                }
            }
            if !engine.has_work() {
                continue; // a cancel drained the engine; re-check idle
            }
            if let Err(e) = engine.step() {
                // A structural step failure (not a per-row backend error —
                // the engine absorbs those): this replica is done.
                let _ = tx.send(Note::Fault { replica, error: format!("{e:#}") });
                return;
            }
        } else {
            // Quiescent: publish an exact metrics snapshot, then block.
            let snap = Box::new(engine.metrics.clone());
            if tx.send(Note::Idle { replica, metrics: snap }).is_err() {
                return;
            }
            match rx.recv() {
                Ok(cmd) => {
                    if apply_cmd(replica, &mut engine, &tx, cmd) {
                        let _ = tx.send(Note::Stopped {
                            replica,
                            metrics: Box::new(engine.metrics.clone()),
                        });
                        return;
                    }
                }
                Err(_) => return,
            }
        }
    }
}

struct Worker {
    tx: Sender<Command>,
    join: Option<JoinHandle<()>>,
}

/// N engine replicas behind a router. See the module docs for the
/// architecture; the public surface mirrors `Engine`'s
/// (`submit`/`submit_request`/`submit_streaming`/`cancel`/`run_all`/
/// events) so callers move between one engine and a cluster freely.
pub struct Cluster {
    workers: Vec<Worker>,
    notes: Receiver<Note>,
    router: Router,
    /// Per-replica prefix-cache handles (from `Ready`), for fresh
    /// fingerprint snapshots at placement time.
    prefix: Vec<Option<Arc<PrefixCache>>>,
    next_id: u64,
    /// Ids submitted but not yet observed terminal.
    outstanding: HashSet<RequestId>,
    events: VecDeque<EngineEvent>,
    streams: HashMap<RequestId, Arc<Mutex<StreamInner>>>,
    finished: Vec<Response>,
    metrics: ClusterMetrics,
    /// Terminal `Failed` events observed (the cluster-level mirror of
    /// `EngineMetrics::failed`, counted as events arrive).
    failed: u64,
    /// Structural replica faults (each ends its replica thread).
    faults: Vec<String>,
}

impl Cluster {
    /// Spawn `replicas` worker threads, each building its own engine via
    /// `factory(replica_id)` (called **on** the worker thread, so replica
    /// loads run in parallel), and block until every replica is ready.
    /// The factory configures everything per replica: backend, engine
    /// options (use [`replica_worker_configs`] for disjoint core
    /// budgets), policy, draft model.
    pub fn new<B, F>(replicas: usize, policy: RouterPolicy, factory: F) -> Result<Cluster>
    where
        B: InferenceBackend + 'static,
        F: Fn(ReplicaId) -> Result<Engine<B>> + Send + Sync + 'static,
    {
        let n = replicas.max(1);
        let factory: Arc<dyn Fn(ReplicaId) -> Result<Engine<B>> + Send + Sync> =
            Arc::new(factory);
        let (note_tx, note_rx) = mpsc::channel();
        let mut workers = Vec::with_capacity(n);
        for r in 0..n {
            let (cmd_tx, cmd_rx) = mpsc::channel();
            let f = factory.clone();
            let tx = note_tx.clone();
            let join = thread::Builder::new()
                .name(format!("replica-{r}"))
                .spawn(move || replica_main(r, f, cmd_rx, tx))
                .map_err(|e| anyhow!("failed to spawn replica {r}: {e}"))?;
            workers.push(Worker { tx: cmd_tx, join: Some(join) });
        }
        drop(note_tx);
        let mut cluster = Cluster {
            workers,
            notes: note_rx,
            router: Router::new(n, policy),
            prefix: vec![None; n],
            next_id: 1,
            outstanding: HashSet::new(),
            events: VecDeque::new(),
            streams: HashMap::new(),
            finished: Vec::new(),
            metrics: ClusterMetrics { per_replica: vec![EngineMetrics::default(); n] },
            failed: 0,
            faults: Vec::new(),
        };
        cluster.await_ready(n)?;
        Ok(cluster)
    }

    /// Block until all `n` replicas sent `Ready`. An error Ready aborts
    /// construction (the `Err` return drops the cluster, which shuts the
    /// surviving replicas down).
    fn await_ready(&mut self, n: usize) -> Result<()> {
        let mut ready = 0usize;
        while ready < n {
            match self.notes.recv() {
                Ok(Note::Ready { replica, error: Some(e), .. }) => {
                    return Err(anyhow!("replica {replica} failed to load: {e}"));
                }
                Ok(Note::Ready { replica, prefix, error: None }) => {
                    if let Some(slot) = self.prefix.get_mut(replica) {
                        *slot = prefix;
                    }
                    ready += 1;
                }
                Ok(note) => self.apply_note(note),
                Err(_) => return Err(anyhow!("replica thread(s) exited during startup")),
            }
        }
        Ok(())
    }

    pub fn replicas(&self) -> usize {
        self.workers.len()
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Submit a plain prompt (mirrors `Engine::submit`).
    pub fn submit(&mut self, prompt: Vec<usize>, max_new_tokens: usize) -> Result<RequestId> {
        self.submit_request(Request::new(0, prompt, max_new_tokens))
    }

    /// Assign the global id, place the request, and send it to its
    /// replica. Id assignment matches a single engine's
    /// (`submit_request` numbering from 1 in submission order), which is
    /// what keeps cluster outputs bit-identical per id to one engine
    /// serving the same stream of submissions.
    pub fn submit_request(&mut self, mut req: Request) -> Result<RequestId> {
        self.pump();
        if req.id == 0 {
            req.id = self.next_id;
        }
        self.next_id = self.next_id.max(req.id + 1);
        req.arrival = Some(Instant::now());
        let id = req.id;
        // Fresh fingerprint snapshots: cheap (page-boundary hashes only),
        // and reading through the Arc observes inserts from completed
        // requests immediately, not at the next idle round-trip.
        let snaps: Vec<Option<PrefixFingerprintIndex>> = self
            .prefix
            .iter()
            .map(|p| p.as_ref().map(|c| c.fingerprint_index()))
            .collect();
        let replica = self.router.place(&req, &snaps);
        let sent = match self.workers.get(replica) {
            Some(w) => w.tx.send(Command::Submit(Box::new(req))).is_ok(),
            None => false,
        };
        if !sent {
            // Roll the placement back: the request never reached a
            // replica, so no terminal event will ever refund it.
            self.router.on_terminal(id);
            return Err(anyhow!("replica {replica} is down; request {id} not submitted"));
        }
        self.outstanding.insert(id);
        Ok(id)
    }

    /// Submit and get a [`TokenStream`] fed across the thread boundary:
    /// the replica's events arrive as notes and [`Cluster::pump`] routes
    /// them into the stream exactly as `Engine::submit_streaming` would.
    /// Drain the handle between `pump()`/`run_all()` calls.
    pub fn submit_streaming(&mut self, req: Request) -> Result<TokenStream> {
        // Register the stream before submitting so no event can race past
        // the exclusive routing. (Events only surface via pump(), so this
        // ordering is belt-and-braces, not load-bearing.)
        let id = if req.id == 0 { self.next_id } else { req.id };
        let inner = Arc::new(Mutex::new(StreamInner::default()));
        self.streams.insert(id, inner.clone());
        match self.submit_request(req) {
            Ok(got) => {
                debug_assert_eq!(got, id);
                Ok(TokenStream::new(got, inner))
            }
            Err(e) => {
                self.streams.remove(&id);
                Err(e)
            }
        }
    }

    /// Cancel by id. Routes to the replica the request was placed on;
    /// returns false — a clean no-op — for ids the cluster is not
    /// tracking (never submitted, already terminal, or foreign). True
    /// means the cancel was dispatched; the id's single terminal event
    /// (`Cancelled`, or `Finished` if completion won the race) still
    /// arrives via the normal event flow.
    pub fn cancel(&mut self, id: RequestId) -> bool {
        self.pump();
        if !self.outstanding.contains(&id) {
            return false;
        }
        let Some(replica) = self.router.replica_of(id) else {
            return false;
        };
        match self.workers.get(replica) {
            Some(w) => w.tx.send(Command::Cancel(id)).is_ok(),
            None => false,
        }
    }

    /// Apply all notes that have already arrived (non-blocking): forward
    /// events into streams or the cluster-wide queue, collect responses,
    /// update router accounting on terminals, absorb metrics snapshots.
    pub fn pump(&mut self) {
        while let Ok(note) = self.notes.try_recv() {
            self.apply_note(note);
        }
    }

    fn apply_note(&mut self, note: Note) {
        match note {
            Note::Ready { .. } => {} // only meaningful during startup
            Note::Event { event, .. } => {
                if event.is_terminal() {
                    let id = event.id();
                    self.router.on_terminal(id);
                    self.outstanding.remove(&id);
                    if matches!(event, EngineEvent::Failed { .. }) {
                        self.failed += 1;
                    }
                }
                deliver(&mut self.events, &mut self.streams, event);
            }
            Note::Finished { response, .. } => self.finished.push(*response),
            Note::Idle { replica, metrics }
            | Note::Metrics { replica, metrics }
            | Note::Stopped { replica, metrics } => {
                if let Some(slot) = self.metrics.per_replica.get_mut(replica) {
                    *slot = *metrics;
                }
            }
            Note::Fault { replica, error } => {
                self.faults.push(format!("replica {replica}: {error}"));
            }
        }
    }

    /// Pop the oldest undelivered cluster-wide event (streaming requests'
    /// events go to their handles instead, as with `Engine`).
    pub fn next_event(&mut self) -> Option<EngineEvent> {
        self.pump();
        self.events.pop_front()
    }

    /// Drain all undelivered cluster-wide events.
    pub fn drain_events(&mut self) -> Vec<EngineEvent> {
        self.pump();
        self.events.drain(..).collect()
    }

    /// Take the responses completed since the last call.
    pub fn take_finished(&mut self) -> Vec<Response> {
        self.pump();
        std::mem::take(&mut self.finished)
    }

    /// Ids submitted but not yet terminal.
    pub fn outstanding(&self) -> usize {
        self.outstanding.len()
    }

    /// Drive until every outstanding request reached its terminal, then
    /// return all completed responses in id (submission) order — the
    /// cluster mirror of `Engine::run_all`, with the same error contract:
    /// backend-failed requests surface as `Err` (completed responses stay
    /// available via [`take_finished`](Self::take_finished)). Blocks on
    /// the note channel; replica threads do the actual stepping.
    pub fn run_all(&mut self) -> Result<Vec<Response>> {
        let failed_before = self.failed;
        while !self.outstanding.is_empty() && self.faults.is_empty() {
            match self.notes.recv() {
                Ok(note) => self.apply_note(note),
                Err(_) => {
                    return Err(anyhow!(
                        "all replicas disconnected with {} request(s) outstanding",
                        self.outstanding.len()
                    ));
                }
            }
        }
        self.pump();
        if !self.faults.is_empty() {
            return Err(anyhow!("replica fault(s): {}", self.faults.join("; ")));
        }
        // Exact end-of-drain snapshots for every replica, so metric reads
        // after run_all are deterministic rather than racing idle notes.
        self.refresh_metrics()?;
        self.events.clear();
        let failed = self.failed - failed_before;
        if failed > 0 {
            return Err(anyhow!(
                "{failed} request(s) terminated by backend failures during the drain \
                 (completed responses remain available via take_finished())"
            ));
        }
        let mut out = std::mem::take(&mut self.finished);
        out.sort_by_key(|r| r.id);
        Ok(out)
    }

    /// Blocking metrics round-trip to every live replica; afterwards
    /// [`metrics`](Self::metrics) holds an up-to-date snapshot per
    /// replica. Other notes arriving meanwhile are applied normally.
    pub fn refresh_metrics(&mut self) -> Result<()> {
        let mut pending = vec![false; self.workers.len()];
        let mut waiting = 0usize;
        for (r, w) in self.workers.iter().enumerate() {
            if w.tx.send(Command::Metrics).is_ok() {
                if let Some(p) = pending.get_mut(r) {
                    *p = true;
                    waiting += 1;
                }
            }
        }
        while waiting > 0 {
            match self.notes.recv() {
                Ok(Note::Metrics { replica, metrics }) => {
                    if let Some(p) = pending.get_mut(replica) {
                        if *p {
                            *p = false;
                            waiting -= 1;
                        }
                    }
                    if let Some(slot) = self.metrics.per_replica.get_mut(replica) {
                        *slot = *metrics;
                    }
                }
                Ok(note) => self.apply_note(note),
                Err(_) => return Err(anyhow!("replica channel closed during metrics round-trip")),
            }
        }
        Ok(())
    }

    /// Per-replica + aggregated metrics (as of the last snapshot; call
    /// [`refresh_metrics`](Self::refresh_metrics) for exact numbers).
    pub fn metrics(&self) -> &ClusterMetrics {
        &self.metrics
    }

    /// Stop every replica (final metrics snapshots land in
    /// [`metrics`](Self::metrics)) and join the threads. Idempotent;
    /// `Drop` calls it.
    pub fn shutdown(&mut self) {
        for w in &self.workers {
            let _ = w.tx.send(Command::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(join) = w.join.take() {
                let _ = join.join();
            }
        }
        self.pump();
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Split one machine's per-core rate vector into `replicas` disjoint
/// [`WorkerConfig`]s — contiguous core ranges, evenly many cores per
/// replica via [`balanced_split`] — so co-resident replicas size their
/// compute pools against distinct cores instead of all oversubscribing
/// the full machine. A replica left with zero cores (more replicas than
/// cores, the testbed case) falls back to a single uniform worker.
pub fn replica_worker_configs(machine: &WorkerConfig, replicas: usize) -> Vec<WorkerConfig> {
    let n = replicas.max(1);
    let split = balanced_split(machine.rates.len(), &vec![1.0; n]);
    split_ranges(&split)
        .into_iter()
        .map(|(lo, hi)| match machine.rates.get(lo..hi) {
            Some(rates) if !rates.is_empty() => WorkerConfig { rates: rates.to_vec() },
            _ => WorkerConfig::uniform(1),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_configs_partition_the_machine() {
        let machine = WorkerConfig { rates: vec![2.0, 2.0, 1.0, 1.0] };
        let cfgs = replica_worker_configs(&machine, 2);
        assert_eq!(cfgs.len(), 2);
        let total: usize = cfgs.iter().map(|c| c.threads()).sum();
        assert_eq!(total, 4, "cores are partitioned, not duplicated");
        let mut all: Vec<f64> = cfgs.iter().flat_map(|c| c.rates.clone()).collect();
        all.sort_by(f64::total_cmp);
        let mut want = machine.rates.clone();
        want.sort_by(f64::total_cmp);
        assert_eq!(all, want);
    }

    #[test]
    fn worker_configs_fall_back_on_oversubscription() {
        // 1 core, 4 replicas: every replica still gets a usable pool.
        let machine = WorkerConfig::uniform(1);
        let cfgs = replica_worker_configs(&machine, 4);
        assert_eq!(cfgs.len(), 4);
        for c in &cfgs {
            assert!(c.threads() >= 1);
        }
    }
}
