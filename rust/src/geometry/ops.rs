//! Long-tail operators expressed as Regions (paper §5.4): Transpose,
//! Gather, Concat, Slice. Each returns the Region list describing the op;
//! the engine fuses lists across consecutive ops before executing.

use super::region::{Region, View};

/// Transpose a [rows, cols] matrix.
pub fn transpose(rows: usize, cols: usize) -> Vec<Region> {
    vec![Region::new(
        [1, cols, rows],
        View::new(0, [0, 1, cols]),
        View::new(0, [0, rows, 1]),
    )]
}

/// Permute a 3-D tensor [d0, d1, d2] by `perm` (e.g. [1, 0, 2]).
pub fn permute3(dims: [usize; 3], perm: [usize; 3]) -> Vec<Region> {
    let src_stride_dense = [dims[1] * dims[2], dims[2], 1];
    // Iterate in output order; src stride = dense stride of permuted dim.
    let out_dims = [dims[perm[0]], dims[perm[1]], dims[perm[2]]];
    let src_stride = [
        src_stride_dense[perm[0]],
        src_stride_dense[perm[1]],
        src_stride_dense[perm[2]],
    ];
    vec![Region::new(
        out_dims,
        View::new(0, src_stride),
        View::contiguous(out_dims),
    )]
}

/// Gather rows `idx` from an [n, row_len] matrix (one Region per row;
/// consecutive indices fuse away in fuse_region_list).
pub fn gather_rows(idx: &[usize], row_len: usize) -> Vec<Region> {
    idx.iter()
        .enumerate()
        .map(|(i, &r)| Region::memcpy(row_len, r * row_len, i * row_len))
        .collect()
}

/// Concat along axis 0: inputs are [rows_i, row_len] matrices stored
/// back-to-back in one source buffer; one Region per input.
pub fn concat_rows(rows: &[usize], row_len: usize) -> Vec<Region> {
    let mut out = Vec::with_capacity(rows.len());
    let mut src_off = 0;
    let mut dst_off = 0;
    for &r in rows {
        out.push(Region::memcpy(r * row_len, src_off, dst_off));
        src_off += r * row_len;
        dst_off += r * row_len;
    }
    out
}

/// Slice rows [lo, hi) of an [n, row_len] matrix.
pub fn slice_rows(lo: usize, hi: usize, row_len: usize) -> Vec<Region> {
    vec![Region::memcpy((hi - lo) * row_len, lo * row_len, 0)]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::fuse::fuse_region_list;
    use crate::geometry::region::apply_regions;

    #[test]
    fn transpose_op() {
        let src = vec![1, 2, 3, 4, 5, 6];
        let mut dst = vec![0; 6];
        apply_regions(&transpose(2, 3), &src, &mut dst);
        assert_eq!(dst, vec![1, 4, 2, 5, 3, 6]);
    }

    #[test]
    fn permute3_matches_manual() {
        // [2,3,4] -> perm [2,0,1]: out[k][i][j] = in[i][j][k].
        let dims = [2, 3, 4];
        let src: Vec<u32> = (0..24).collect();
        let mut dst = vec![0u32; 24];
        apply_regions(&permute3(dims, [2, 0, 1]), &src, &mut dst);
        for i in 0..2 {
            for j in 0..3 {
                for k in 0..4 {
                    let got = dst[(k * 2 + i) * 3 + j];
                    let want = src[(i * 3 + j) * 4 + k];
                    assert_eq!(got, want);
                }
            }
        }
    }

    #[test]
    fn gather_fuses_when_consecutive() {
        let g = gather_rows(&[3, 4, 5, 6], 8);
        assert_eq!(g.len(), 4);
        let fused = fuse_region_list(&g);
        assert_eq!(fused.len(), 1, "consecutive gather collapses to one copy");
        let src: Vec<u32> = (0..64).collect();
        let mut dst = vec![0u32; 32];
        apply_regions(&fused, &src, &mut dst);
        assert_eq!(dst[..8], src[24..32]);
    }

    #[test]
    fn concat_fuses_to_single_copy() {
        let c = concat_rows(&[2, 3, 1], 4);
        let fused = fuse_region_list(&c);
        assert_eq!(fused.len(), 1);
        assert_eq!(fused[0].elements(), 24);
    }

    #[test]
    fn slice_is_one_region() {
        let s = slice_rows(2, 5, 10);
        assert_eq!(s.len(), 1);
        let src: Vec<u32> = (0..100).collect();
        let mut dst = vec![0u32; 30];
        apply_regions(&s, &src, &mut dst);
        assert_eq!(dst[..], src[20..50]);
    }
}
