//! Region fusion (paper §5.4): rule-based rewriting that reduces the number
//! of read/write passes for chains and lists of rearrangement Regions.
//!
//! Rules implemented (the paper's named rule families):
//! * loop unrolling / tiling — `normalize` drops unit dims and merges
//!   adjacent dims whose strides compose contiguously (fewer, deeper loops);
//! * loop interchange — `normalize` orders dims so the unit-stride dim is
//!   innermost (enables the memcpy fast path in the executor);
//! * loop fusion — `fuse_pair` merges two Regions that are contiguous
//!   extensions of each other (e.g. adjacent Concat chunks, consecutive
//!   Gather rows) into one Region;
//! * operator fusion — `compose` collapses A;B (write tmp, read tmp) into a
//!   single Region when A's destination view is contiguous and B's source
//!   addressing decomposes carry-free over A's iteration box, eliminating
//!   the intermediate buffer entirely (e.g. Transpose∘Transpose,
//!   Slice∘Transpose).

use super::region::{Region, View, DIMS};

/// Normalize: drop unit dims, merge mergeable adjacent dims, and order so
/// the smallest dst stride is innermost. Never changes the mapping.
pub fn normalize(r: &Region) -> Region {
    // Collect non-unit dims as (size, src_stride, dst_stride).
    let mut dims: Vec<(usize, usize, usize)> = (0..DIMS)
        .filter(|&i| r.size[i] > 1)
        .map(|i| (r.size[i], r.src.stride[i], r.dst.stride[i]))
        .collect();
    if dims.is_empty() {
        // Scalar copy (or empty box).
        let n = if r.elements() == 0 { 0 } else { 1 };
        return Region {
            size: [1, 1, n],
            src: View::new(r.src.offset, [0, 0, 1]),
            dst: View::new(r.dst.offset, [0, 0, 1]),
        };
    }
    // Interchange: sort by dst stride descending (unit stride innermost).
    dims.sort_by(|a, b| b.2.cmp(&a.2));
    // Merge: adjacent (outer, inner) merge when outer strides equal
    // inner_stride * inner_size on BOTH views.
    let mut merged: Vec<(usize, usize, usize)> = Vec::with_capacity(dims.len());
    for d in dims {
        if let Some(last) = merged.last_mut() {
            let (osz, osrc, odst) = *last;
            let (isz, isrc, idst) = d;
            if osrc == isrc * isz && odst == idst * isz {
                *last = (osz * isz, isrc, idst);
                continue;
            }
        }
        merged.push(d);
    }
    while merged.len() < DIMS {
        merged.insert(0, (1, 0, 0));
    }
    if merged.len() > DIMS {
        // Couldn't express in 3 dims (can't happen when input had ≤3).
        return *r;
    }
    Region {
        size: [merged[0].0, merged[1].0, merged[2].0],
        src: View::new(r.src.offset, [merged[0].1, merged[1].1, merged[2].1]),
        dst: View::new(r.dst.offset, [merged[0].2, merged[1].2, merged[2].2]),
    }
}

/// True when `r` is a flat 1-D unit-stride copy on both views.
fn is_flat_copy(r: &Region) -> bool {
    r.size[0] == 1
        && r.size[1] == 1
        && r.src.stride[2] == 1
        && r.dst.stride[2] == 1
}

/// Loop fusion: try to merge `a` and `b` into one Region when `b` continues
/// `a` along some axis on both views (adjacent concat chunks / gathered
/// consecutive rows). Inputs should be normalized.
pub fn fuse_pair(a: &Region, b: &Region) -> Option<Region> {
    // Concatenation of flat copies (concat chunks, gathered consecutive
    // rows): lengths may differ.
    if is_flat_copy(a)
        && is_flat_copy(b)
        && b.src.offset == a.src.offset + a.size[2]
        && b.dst.offset == a.dst.offset + a.size[2]
    {
        let mut size = a.size;
        size[2] += b.size[2];
        return Some(Region { size, src: a.src, dst: a.dst });
    }
    if a.size != b.size {
        return None;
    }
    if a.src.stride != b.src.stride || a.dst.stride != b.dst.stride {
        return None;
    }
    // b must start exactly one "outer step" after a on both views. Try
    // extending along each existing dim, or prepending a new outer dim.
    for i in 0..DIMS {
        let step_src = a.src.stride[i] * a.size[i];
        let step_dst = a.dst.stride[i] * a.size[i];
        let can_extend = (0..DIMS).all(|j| j == i || a.size[j] == 1 || true);
        if !can_extend {
            continue;
        }
        // Extending dim i is valid only if i is the outermost non-unit dim
        // (otherwise the iteration order would interleave wrongly) OR all
        // outer dims are unit.
        let outer_ok = (0..i).all(|j| a.size[j] == 1);
        if !outer_ok {
            continue;
        }
        if b.src.offset == a.src.offset + step_src && b.dst.offset == a.dst.offset + step_dst {
            let mut size = a.size;
            size[i] *= 2;
            return Some(Region { size, src: a.src, dst: a.dst });
        }
    }
    // Prepend a new outer dim if dim0 is unit.
    if a.size[0] == 1 {
        let dsrc = b.src.offset.checked_sub(a.src.offset)?;
        let ddst = b.dst.offset.checked_sub(a.dst.offset)?;
        if dsrc > 0 || ddst > 0 {
            let mut src = a.src;
            let mut dst = a.dst;
            src.stride[0] = dsrc;
            dst.stride[0] = ddst;
            let mut size = a.size;
            size[0] = 2;
            return Some(Region { size, src, dst });
        }
    }
    None
}

/// Greedy left-to-right fusion over a region list (normalizing first).
/// Returns the (usually shorter) fused list.
pub fn fuse_region_list(regions: &[Region]) -> Vec<Region> {
    let mut out: Vec<Region> = Vec::with_capacity(regions.len());
    for r in regions {
        let r = normalize(r);
        if r.elements() == 0 {
            continue;
        }
        if let Some(last) = out.last() {
            if let Some(merged) = fuse_pair(last, &r) {
                *out.last_mut().unwrap() = normalize(&merged);
                continue;
            }
        }
        out.push(r);
    }
    out
}

/// Mixed-radix digits of `v` over box `radix` (outer→inner). None if v
/// exceeds the box capacity.
fn digits(v: usize, radix: [usize; DIMS]) -> Option<[usize; DIMS]> {
    let cap = radix[0] * radix[1] * radix[2];
    if v >= cap {
        return None;
    }
    let d2 = v % radix[2];
    let rest = v / radix[2];
    let d1 = rest % radix[1];
    let d0 = rest / radix[1];
    if d0 >= radix[0] {
        return None;
    }
    Some([d0, d1, d2])
}

/// Operator fusion: compose A;B (A writes tmp, B reads tmp) into one Region
/// A→C, when
/// * A's dst view is contiguous row-major over A.size with offset 0, and
/// * B's src addressing decomposes carry-free into A's iteration box.
///
/// Returns None when the precondition fails (caller keeps the two Regions).
pub fn compose(a: &Region, b: &Region) -> Option<Region> {
    let a = normalize(a);
    let b = normalize(b);
    // a.dst must be contiguous row-major at offset 0 (size-1 dims have
    // arbitrary stride — ignore them).
    if a.dst.offset != 0 {
        return None;
    }
    let mut expect = 1;
    for i in (0..DIMS).rev() {
        if a.size[i] > 1 && a.dst.stride[i] != expect {
            return None;
        }
        expect *= a.size[i];
    }
    // Delinearize B's src offset and per-dim strides over A's box.
    let off_d = digits(b.src.offset, a.size)?;
    let mut stride_d = [[0usize; DIMS]; DIMS];
    for j in 0..DIMS {
        stride_d[j] = digits(b.src.stride[j], a.size)?;
    }
    // Carry-free check: along each A-digit i, the maximum total index
    // reached must stay below the radix.
    for i in 0..DIMS {
        let mut max_i = off_d[i];
        for j in 0..DIMS {
            max_i += (b.size[j] - 1) * stride_d[j][i];
        }
        if max_i >= a.size[i] {
            return None;
        }
    }
    // Compose: new src offset/strides in A's *source* address space.
    let src_off = a.src.addr(off_d);
    let mut src_stride = [0usize; DIMS];
    for j in 0..DIMS {
        src_stride[j] = (0..DIMS).map(|i| stride_d[j][i] * a.src.stride[i]).sum();
    }
    Some(normalize(&Region {
        size: b.size,
        src: View::new(src_off, src_stride),
        dst: b.dst,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::region::{apply_region, apply_regions};
    use crate::util::prop::prop_check;
    use crate::util::rng::Rng;

    fn transpose2d(rows: usize, cols: usize) -> Region {
        Region::new(
            [1, cols, rows],
            View::new(0, [0, 1, cols]),
            View::new(0, [0, rows, 1]),
        )
    }

    #[test]
    fn normalize_preserves_mapping() {
        prop_check(200, |rng: &mut Rng| {
            let size = [rng.range(1, 4), rng.range(1, 5), rng.range(1, 6)];
            // Random-but-valid strides: permutation-of-contiguous times gaps.
            let src = View::new(rng.range(0, 3), [
                rng.range(1, 40),
                rng.range(1, 12),
                rng.range(1, 4),
            ]);
            let dst = View::contiguous(size);
            let r = Region::new(size, src, dst);
            let n = normalize(&r);
            let src_len = r.src_extent().max(n.src_extent());
            let buf: Vec<u32> = (0..src_len as u32).collect();
            let mut d1 = vec![u32::MAX; r.dst_extent()];
            let mut d2 = vec![u32::MAX; r.dst_extent()];
            apply_region(&r, &buf, &mut d1);
            apply_region(&n, &buf, &mut d2);
            if d1 != d2 {
                return Err(format!("normalize changed mapping: {r:?} -> {n:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn normalize_merges_contiguous_dims() {
        // A [2, 3, 4] row-major copy is a single 24-element memcpy.
        let size = [2, 3, 4];
        let r = Region::new(size, View::contiguous(size), View::contiguous(size));
        let n = normalize(&r);
        assert_eq!(n.size, [1, 1, 24]);
        assert!(n.inner_contiguous());
    }

    #[test]
    fn normalize_makes_unit_stride_innermost() {
        // Pathological order: unit-stride dim outermost.
        let r = Region::new(
            [4, 1, 3],
            View::new(0, [1, 0, 4]),
            View::new(0, [1, 0, 4]),
        );
        let n = normalize(&r);
        assert_eq!(n.src.stride[2], 1);
        assert_eq!(n.dst.stride[2], 1);
    }

    #[test]
    fn fuse_adjacent_concat_chunks() {
        // Two concat chunks writing [0..12) and [12..24) from two sources
        // placed consecutively — fuse into one region.
        let a = Region::memcpy(12, 0, 0);
        let b = Region::memcpy(12, 12, 12);
        let fused = fuse_region_list(&[a, b]);
        assert_eq!(fused.len(), 1);
        assert_eq!(fused[0].elements(), 24);
        let src: Vec<u32> = (0..24).collect();
        let mut dst = vec![0u32; 24];
        apply_regions(&fused, &src, &mut dst);
        assert_eq!(dst, src);
    }

    #[test]
    fn fuse_gather_of_consecutive_rows() {
        // Gather rows [5, 6, 7] of an [8, 16] matrix = 3 regions → 1.
        let regions: Vec<Region> = [5usize, 6, 7]
            .iter()
            .enumerate()
            .map(|(i, &r)| Region::memcpy(16, r * 16, i * 16))
            .collect();
        let fused = fuse_region_list(&regions);
        assert_eq!(fused.len(), 1);
        assert_eq!(fused[0].elements(), 48);
    }

    #[test]
    fn nonadjacent_rows_do_not_fuse_incorrectly() {
        let regions: Vec<Region> = [1usize, 5, 2]
            .iter()
            .enumerate()
            .map(|(i, &r)| Region::memcpy(16, r * 16, i * 16))
            .collect();
        let fused = fuse_region_list(&regions);
        // Whatever the count, the mapping must be preserved.
        let src: Vec<u32> = (0..8 * 16).collect();
        let mut want = vec![0u32; 48];
        let mut got = vec![0u32; 48];
        apply_regions(&regions, &src, &mut want);
        apply_regions(&fused, &src, &mut got);
        assert_eq!(want, got);
    }

    #[test]
    fn compose_transpose_transpose_is_copy() {
        let (r, c) = (6, 10);
        let t1 = transpose2d(r, c);
        let t2 = transpose2d(c, r);
        let composed = compose(&t1, &t2).expect("should compose");
        // Net effect = identity copy of 60 elements.
        let n = normalize(&composed);
        assert_eq!(n.size[2], r * c);
        assert_eq!(n.src.stride[2], 1);
        assert_eq!(n.dst.stride[2], 1);
        // And it really is the identity.
        let src: Vec<u32> = (0..(r * c) as u32).collect();
        let mut dst = vec![0u32; r * c];
        apply_region(&composed, &src, &mut dst);
        assert_eq!(dst, src);
    }

    #[test]
    fn compose_matches_two_pass_execution() {
        prop_check(200, |rng: &mut Rng| {
            // A: random contiguous-dst region; B: reads A's output.
            let size_a = [rng.range(1, 4), rng.range(1, 4), rng.range(1, 6)];
            let a = Region::new(
                size_a,
                View::new(rng.range(0, 4), [
                    rng.range(1, 30),
                    rng.range(1, 10),
                    rng.range(1, 3),
                ]),
                View::contiguous(size_a),
            );
            // B transposes the flattened output as [p, q] with p*q = n.
            let n = a.elements();
            let p = (1..=n).rev().find(|p| n % p == 0 && *p <= 8).unwrap_or(1);
            let q = n / p;
            let b = Region::new(
                [1, q, p],
                View::new(0, [0, 1, q]),
                View::new(0, [0, p, 1]),
            );
            let Some(c) = compose(&a, &b) else { return Ok(()) };
            let src: Vec<u32> = (0..a.src_extent() as u32).collect();
            // Two-pass.
            let mut tmp = vec![0u32; n];
            apply_region(&a, &src, &mut tmp);
            let mut want = vec![0u32; b.dst_extent()];
            apply_region(&b, &tmp, &mut want);
            // Fused.
            let mut got = vec![u32::MAX; b.dst_extent()];
            apply_region(&c, &src, &mut got);
            if want != got {
                return Err(format!("compose mismatch\nA={a:?}\nB={b:?}\nC={c:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn compose_refuses_noncontiguous_intermediate() {
        let a = Region::new(
            [1, 1, 4],
            View::new(0, [0, 0, 1]),
            View::new(0, [0, 0, 2]), // strided dst
        );
        let b = Region::memcpy(4, 0, 0);
        assert!(compose(&a, &b).is_none());
    }
}
