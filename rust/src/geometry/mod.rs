//! Geometry compute (paper §5.4): long-tail data-rearrangement operators
//! (Transpose / Gather / Concat / Slice) abstracted as linear address
//! mappings f(x) = offset + stride·x over a 3-D iteration box, executed by
//! one generic copy loop, and *fused* by rule-based rewriting (the paper's
//! loop unrolling / interchange / tiling / fusion rules) so chains of
//! rearrangements touch memory once instead of once per operator.

pub mod fuse;
pub mod ops;
pub mod region;

pub use fuse::{compose, fuse_region_list, normalize};
pub use region::{apply_region, apply_regions, Region, View};
