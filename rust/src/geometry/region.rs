//! The Region primitive (paper Eq. 5): a 3-D iteration box `size` and two
//! affine views, source and destination:
//!
//!   addr(x) = offset + Σ_i stride_i · x_i ,  x_i ∈ [0, size_i)
//!
//! Any rearrangement op is one or more Regions; the executor below is the
//! *only* data-movement loop in the engine's long-tail path.

pub const DIMS: usize = 3;

/// One affine address view.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct View {
    pub offset: usize,
    pub stride: [usize; DIMS],
}

impl View {
    pub fn new(offset: usize, stride: [usize; DIMS]) -> Self {
        View { offset, stride }
    }

    /// Contiguous row-major view over a `size` box.
    pub fn contiguous(size: [usize; DIMS]) -> Self {
        View { offset: 0, stride: [size[1] * size[2], size[2], 1] }
    }

    #[inline]
    pub fn addr(&self, x: [usize; DIMS]) -> usize {
        self.offset + self.stride[0] * x[0] + self.stride[1] * x[1] + self.stride[2] * x[2]
    }
}

/// A fundamental mapping: copy src view → dst view over the `size` box.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Region {
    pub size: [usize; DIMS],
    pub src: View,
    pub dst: View,
}

impl Region {
    pub fn new(size: [usize; DIMS], src: View, dst: View) -> Self {
        Region { size, src, dst }
    }

    /// A 1-D memcpy of `n` elements.
    pub fn memcpy(n: usize, src_off: usize, dst_off: usize) -> Self {
        Region {
            size: [1, 1, n],
            src: View::new(src_off, [0, 0, 1]),
            dst: View::new(dst_off, [0, 0, 1]),
        }
    }

    pub fn elements(&self) -> usize {
        self.size[0] * self.size[1] * self.size[2]
    }

    /// Highest source address touched + 1 (bounds checking).
    pub fn src_extent(&self) -> usize {
        self.src.addr([
            self.size[0].saturating_sub(1),
            self.size[1].saturating_sub(1),
            self.size[2].saturating_sub(1),
        ]) + 1
    }

    pub fn dst_extent(&self) -> usize {
        self.dst.addr([
            self.size[0].saturating_sub(1),
            self.size[1].saturating_sub(1),
            self.size[2].saturating_sub(1),
        ]) + 1
    }

    /// True if the innermost dimension is a unit-stride copy on both sides
    /// (the executor then uses slice copies instead of scalar stores).
    pub fn inner_contiguous(&self) -> bool {
        self.src.stride[2] == 1 && self.dst.stride[2] == 1
    }
}

/// Execute one region: dst[f_dst(x)] = src[f_src(x)] for all x.
pub fn apply_region<T: Copy>(r: &Region, src: &[T], dst: &mut [T]) {
    debug_assert!(r.elements() == 0 || r.src_extent() <= src.len());
    debug_assert!(r.elements() == 0 || r.dst_extent() <= dst.len());
    let [s0, s1, s2] = r.size;
    if r.inner_contiguous() {
        for i in 0..s0 {
            for j in 0..s1 {
                let sb = r.src.addr([i, j, 0]);
                let db = r.dst.addr([i, j, 0]);
                dst[db..db + s2].copy_from_slice(&src[sb..sb + s2]);
            }
        }
    } else {
        for i in 0..s0 {
            for j in 0..s1 {
                let sb = r.src.addr([i, j, 0]);
                let db = r.dst.addr([i, j, 0]);
                for k in 0..s2 {
                    dst[db + r.dst.stride[2] * k] = src[sb + r.src.stride[2] * k];
                }
            }
        }
    }
}

/// Execute a region list in order.
pub fn apply_regions<T: Copy>(rs: &[Region], src: &[T], dst: &mut [T]) {
    for r in rs {
        apply_region(r, src, dst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memcpy_region() {
        let src: Vec<i32> = (0..10).collect();
        let mut dst = vec![0i32; 10];
        apply_region(&Region::memcpy(6, 2, 1), &src, &mut dst);
        assert_eq!(dst, vec![0, 2, 3, 4, 5, 6, 7, 0, 0, 0]);
    }

    #[test]
    fn transpose_via_region() {
        // 2x3 -> 3x2 transpose as a single region.
        let src = vec![1, 2, 3, 4, 5, 6]; // [[1,2,3],[4,5,6]]
        let mut dst = vec![0; 6];
        let r = Region::new(
            [1, 3, 2], // iterate (col, row) of the output
            View::new(0, [0, 1, 3]),
            View::new(0, [0, 2, 1]),
        );
        apply_region(&r, &src, &mut dst);
        assert_eq!(dst, vec![1, 4, 2, 5, 3, 6]);
    }

    #[test]
    fn extents() {
        let r = Region::new([2, 2, 4], View::new(1, [8, 4, 1]), View::contiguous([2, 2, 4]));
        assert_eq!(r.src_extent(), 1 + 8 + 4 + 3 + 1);
        assert_eq!(r.dst_extent(), 16);
        assert_eq!(r.elements(), 16);
        assert!(r.inner_contiguous());
    }

    #[test]
    fn strided_inner_loop() {
        // Interleave: dst[2k] = src[k].
        let src = vec![1, 2, 3];
        let mut dst = vec![0; 6];
        let r = Region::new([1, 1, 3], View::new(0, [0, 0, 1]), View::new(0, [0, 0, 2]));
        apply_region(&r, &src, &mut dst);
        assert_eq!(dst, vec![1, 0, 2, 0, 3, 0]);
    }
}
