//! Flash-device simulator: file-backed byte store with UFS-class read
//! throttling. Writes model the paper's spill path (sequential appends);
//! reads charge `latency + bytes/bandwidth` of *virtual* time and optionally
//! sleep to emulate the stall wall-clock-visibly (benches use virtual time;
//! the engine uses non-sleeping mode so tests stay fast).

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use crate::device::MemTier;

/// Accumulated device-time accounting for a flash device.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FlashStats {
    pub reads: u64,
    pub read_bytes: u64,
    pub writes: u64,
    pub write_bytes: u64,
    /// Total virtual busy time of the device, seconds.
    pub busy_s: f64,
}

struct Inner {
    file: File,
    len: u64,
    stats: FlashStats,
}

/// A simulated flash device backed by a real file (real I/O exercises the
/// spill code path; timing comes from the MemTier model).
pub struct FlashSim {
    tier: MemTier,
    inner: Mutex<Inner>,
    /// If true, reads sleep for the modeled duration (wall-clock realism
    /// for the e2e example; off in unit tests).
    emulate_stall: bool,
    /// Failure injection: while set, appends fail with `ErrorKind::Other`
    /// (a full/faulted device). Lets tests prove the engine turns a KV
    /// spill failure into one request's terminal `Failed` event instead
    /// of a process-killing panic.
    poison_appends: AtomicBool,
}

impl FlashSim {
    /// Create/truncate the backing file.
    pub fn create(path: &Path, tier: MemTier, emulate_stall: bool) -> std::io::Result<Self> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)?;
        Ok(FlashSim {
            tier,
            inner: Mutex::new(Inner { file, len: 0, stats: FlashStats::default() }),
            emulate_stall,
            poison_appends: AtomicBool::new(false),
        })
    }

    /// Failure injection: make every subsequent `append`/`append_reader`
    /// fail (and `false` to heal). Reads are unaffected — already-spilled
    /// records stay loadable, like a device that went read-only.
    pub fn poison_appends(&self, poisoned: bool) {
        self.poison_appends.store(poisoned, Ordering::SeqCst);
    }

    fn check_poison(&self) -> std::io::Result<()> {
        if self.poison_appends.load(Ordering::SeqCst) {
            Err(std::io::Error::new(
                std::io::ErrorKind::Other,
                "injected flash append failure",
            ))
        } else {
            Ok(())
        }
    }

    /// A tmpfile-backed device (tests, benches). The path is unique even
    /// across concurrent callers — two FlashSims sharing a backing file
    /// would corrupt each other's records.
    pub fn temp(tier: MemTier) -> std::io::Result<Self> {
        let path = crate::util::unique_temp_path("mnn_flash", ".bin");
        Self::create(&path, tier, false)
    }

    /// Modeled duration of reading `bytes`.
    pub fn read_time(&self, bytes: usize) -> f64 {
        self.tier.latency_s + bytes as f64 / self.tier.read_bw
    }

    /// Append a record; returns its offset.
    pub fn append(&self, data: &[u8]) -> std::io::Result<u64> {
        self.check_poison()?;
        let mut g = self.inner.lock().unwrap();
        let off = g.len;
        g.file.seek(SeekFrom::Start(off))?;
        g.file.write_all(data)?;
        g.len += data.len() as u64;
        g.stats.writes += 1;
        g.stats.write_bytes += data.len() as u64;
        // Writes are buffered by the device; we charge them at read bw too
        // (conservative) but the paper's path only ever reads on the hot path.
        g.stats.busy_s += data.len() as f64 / self.tier.read_bw;
        Ok(off)
    }

    /// Append one record of exactly `len` bytes streamed from `r` in
    /// bounded chunks — DRAM never holds more than one chunk of the
    /// payload, which is what lets weight/embedding loading copy
    /// file → flash without a whole-table transient. The device lock is
    /// held across the stream so concurrent appends cannot interleave into
    /// the record; the device length only advances once all bytes landed,
    /// so a short read leaves the store consistent. Returns the offset.
    pub fn append_reader(&self, r: &mut dyn Read, len: usize) -> std::io::Result<u64> {
        self.check_poison()?;
        const CHUNK: usize = 256 << 10;
        let mut g = self.inner.lock().unwrap();
        let off = g.len;
        g.file.seek(SeekFrom::Start(off))?;
        let mut buf = vec![0u8; len.clamp(1, CHUNK)];
        let mut remaining = len;
        while remaining > 0 {
            let n = remaining.min(buf.len());
            r.read_exact(&mut buf[..n])?;
            g.file.write_all(&buf[..n])?;
            remaining -= n;
        }
        g.len += len as u64;
        g.stats.writes += 1;
        g.stats.write_bytes += len as u64;
        g.stats.busy_s += len as f64 / self.tier.read_bw;
        Ok(off)
    }

    /// Read `buf.len()` bytes at `off`, charging modeled time.
    pub fn read_at(&self, off: u64, buf: &mut [u8]) -> std::io::Result<f64> {
        let t = self.read_time(buf.len());
        {
            let mut g = self.inner.lock().unwrap();
            g.file.seek(SeekFrom::Start(off))?;
            g.file.read_exact(buf)?;
            g.stats.reads += 1;
            g.stats.read_bytes += buf.len() as u64;
            g.stats.busy_s += t;
        }
        if self.emulate_stall {
            std::thread::sleep(std::time::Duration::from_secs_f64(t));
        }
        Ok(t)
    }

    /// Truncate the backing file, discarding every stored record. Only
    /// safe when no previously returned offset will be read again (e.g.
    /// the engine reclaiming its KV spill store once all sessions ended).
    /// Cumulative stats are preserved.
    pub fn reset(&self) -> std::io::Result<()> {
        let mut g = self.inner.lock().unwrap();
        g.file.set_len(0)?;
        g.len = 0;
        Ok(())
    }

    pub fn len(&self) -> u64 {
        self.inner.lock().unwrap().len
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> FlashStats {
        self.inner.lock().unwrap().stats
    }

    pub fn tier(&self) -> MemTier {
        self.tier
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::SocProfile;

    fn ufs() -> MemTier {
        SocProfile::snapdragon_8gen3().flash
    }

    #[test]
    fn append_then_read_roundtrip() {
        let f = FlashSim::temp(ufs()).unwrap();
        let a = f.append(b"hello flash").unwrap();
        let b = f.append(b"more data").unwrap();
        assert_eq!(a, 0);
        assert_eq!(b, 11);
        let mut buf = vec![0u8; 9];
        f.read_at(b, &mut buf).unwrap();
        assert_eq!(&buf, b"more data");
        let mut buf2 = vec![0u8; 5];
        f.read_at(0, &mut buf2).unwrap();
        assert_eq!(&buf2, b"hello");
    }

    #[test]
    fn read_time_model() {
        let f = FlashSim::temp(ufs()).unwrap();
        // 1 MB at 1 GB/s ≈ 1 ms + 15 µs latency.
        let t = f.read_time(1 << 20);
        assert!((t - (15e-6 + (1 << 20) as f64 / 1e9)).abs() < 1e-9);
    }

    #[test]
    fn append_reader_streams_whole_record() {
        let f = FlashSim::temp(ufs()).unwrap();
        // Payload larger than one copy chunk exercises the chunk loop.
        let data: Vec<u8> = (0..(300 << 10)).map(|i| (i % 251) as u8).collect();
        let off = f.append_reader(&mut &data[..], data.len()).unwrap();
        assert_eq!(off, 0);
        assert_eq!(f.len(), data.len() as u64);
        let mut back = vec![0u8; data.len()];
        f.read_at(off, &mut back).unwrap();
        assert_eq!(back, data);
        // A short reader is an error and must not advance the store.
        let short = [0u8; 10];
        assert!(f.append_reader(&mut &short[..], 11).is_err());
        assert_eq!(f.len(), data.len() as u64, "failed append leaves length unchanged");
        let off2 = f.append(b"after").unwrap();
        assert_eq!(off2, data.len() as u64, "next append lands at the same offset");
    }

    #[test]
    fn poisoned_appends_fail_but_reads_survive() {
        let f = FlashSim::temp(ufs()).unwrap();
        let off = f.append(b"before").unwrap();
        f.poison_appends(true);
        assert!(f.append(b"nope").is_err());
        assert!(f.append_reader(&mut &b"nope"[..], 4).is_err());
        assert_eq!(f.len(), 6, "failed appends leave the store unchanged");
        let mut buf = vec![0u8; 6];
        f.read_at(off, &mut buf).unwrap();
        assert_eq!(&buf, b"before", "reads keep working");
        f.poison_appends(false);
        assert!(f.append(b"healed").is_ok());
    }

    #[test]
    fn stats_accumulate() {
        let f = FlashSim::temp(ufs()).unwrap();
        f.append(&[0u8; 100]).unwrap();
        let mut buf = vec![0u8; 50];
        f.read_at(0, &mut buf).unwrap();
        f.read_at(50, &mut buf).unwrap();
        let s = f.stats();
        assert_eq!(s.reads, 2);
        assert_eq!(s.read_bytes, 100);
        assert_eq!(s.write_bytes, 100);
        assert!(s.busy_s > 0.0);
    }
}
