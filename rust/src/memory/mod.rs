//! DRAM-Flash hybrid storage (paper §4.1).
//!
//! * [`flash`] — the flash-device simulator: a file-backed store whose
//!   reads are throttled to UFS-class bandwidth + latency (this testbed has
//!   no UFS; DESIGN.md §Substitutions).
//! * [`embedding`] — bf16 embedding table served from flash: the decode
//!   phase reads one `hidden×2`-byte row per token, so flash residency
//!   costs ≈1.4‰ latency while saving the full table's DRAM (≈15% of
//!   parameters for Qwen2-7B-class vocab).
//! * [`hybrid`] — KV-cache spill: tokens beyond a DRAM threshold migrate to
//!   flash; reads come back through a staging buffer.
//! * [`prefetch`] — overlap engine: issue flash reads for the *next*
//!   layer's spilled KV while the current layer computes (MLP + qkv
//!   window), hiding flash latency until the spilled span exceeds the
//!   bandwidth-delay product (Fig. 2's 3072K crossover).
//! * [`weight_store`] — the weight half of hybrid storage: `weights.bin`
//!   streamed onto flash at load, layers packed into relocatable blobs,
//!   held in a byte-budgeted LRU DRAM arena with async one-layer-ahead
//!   prefetch — models whose packed weights exceed DRAM still run,
//!   bit-identically, paying only modeled flash-read time.

pub mod embedding;
pub mod flash;
pub mod hybrid;
pub mod prefetch;
pub mod weight_store;

pub use embedding::FlashEmbedding;
pub use flash::FlashSim;
pub use hybrid::HybridKvLayer;
pub use prefetch::{PrefetchPlanner, PrefetchStats};
pub use weight_store::{
    FlashTensorStore, LayerWeights, WeightResidencyMetrics, WeightStore, WeightStoreBuilder,
};
