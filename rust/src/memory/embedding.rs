//! Flash-resident bf16 embedding table (paper §4.1).
//!
//! Decode reads exactly one row (`hidden` bf16 values ≈ 7 KB for Qwen2-7B)
//! per step — 1/vocab of the table — so the table never needs DRAM: rows
//! are read from flash on demand. Prefill reads one row per prompt token
//! (still tiny next to layer weights). The paper: storing the embedding in
//! flash saves ~15% of parameter DRAM at ~1.4‰ latency cost.

use std::path::Path;

use crate::memory::flash::FlashSim;
use crate::util::bf16;

/// The embedding table, resident on a FlashSim device.
pub struct FlashEmbedding {
    flash: FlashSim,
    base: u64,
    pub vocab: usize,
    pub hidden: usize,
}

impl FlashEmbedding {
    /// Load `embedding.bin` (bf16 [vocab, hidden] rows) onto `flash`,
    /// streaming file → flash in bounded chunks: the full table is never
    /// resident in DRAM, not even transiently during load.
    pub fn from_file(
        path: &Path,
        vocab: usize,
        hidden: usize,
        flash: FlashSim,
    ) -> std::io::Result<Self> {
        let file = std::fs::File::open(path)?;
        let want = vocab * hidden * 2;
        let have = file.metadata()?.len();
        if have != want as u64 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("embedding.bin: {have} bytes, expected {want}"),
            ));
        }
        let mut r = std::io::BufReader::new(file);
        let base = flash.append_reader(&mut r, want)?;
        Ok(FlashEmbedding { flash, base, vocab, hidden })
    }

    /// Build from an in-memory f32 table (tests/benches).
    pub fn from_f32(table: &[f32], vocab: usize, hidden: usize, flash: FlashSim) -> Self {
        assert_eq!(table.len(), vocab * hidden);
        let mut bytes = Vec::with_capacity(table.len() * 2);
        for &v in table {
            bytes.extend_from_slice(&bf16::f32_to_bf16(v).to_le_bytes());
        }
        let base = flash.append(&bytes).expect("flash append");
        FlashEmbedding { flash, base, vocab, hidden }
    }

    /// Bytes of one row on flash.
    pub fn row_bytes(&self) -> usize {
        self.hidden * 2
    }

    /// Look up token `id` into `out` ([hidden] f32). Returns the modeled
    /// flash read time for this row.
    pub fn lookup(&self, id: usize, out: &mut [f32]) -> std::io::Result<f64> {
        assert!(id < self.vocab, "token id {id} out of vocab {}", self.vocab);
        assert_eq!(out.len(), self.hidden);
        let mut buf = vec![0u8; self.row_bytes()];
        let t = self
            .flash
            .read_at(self.base + (id * self.row_bytes()) as u64, &mut buf)?;
        bf16::bytes_to_f32(&buf, out);
        Ok(t)
    }

    /// Batch lookup for a prompt; returns total modeled flash time.
    pub fn lookup_batch(&self, ids: &[usize], out: &mut [f32]) -> std::io::Result<f64> {
        assert_eq!(out.len(), ids.len() * self.hidden);
        let mut total = 0.0;
        for (i, &id) in ids.iter().enumerate() {
            total += self.lookup(id, &mut out[i * self.hidden..(i + 1) * self.hidden])?;
        }
        Ok(total)
    }

    /// DRAM saved by flash residency (the full table size).
    pub fn dram_saved_bytes(&self) -> usize {
        self.vocab * self.row_bytes()
    }

    pub fn flash(&self) -> &FlashSim {
        &self.flash
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::SocProfile;
    use crate::util::rng::Rng;

    fn make(vocab: usize, hidden: usize) -> (FlashEmbedding, Vec<f32>) {
        let mut rng = Rng::new(7);
        let table = rng.normal_vec(vocab * hidden);
        let flash = FlashSim::temp(SocProfile::snapdragon_8gen3().flash).unwrap();
        let emb = FlashEmbedding::from_f32(&table, vocab, hidden, flash);
        (emb, table)
    }

    #[test]
    fn lookup_matches_bf16_rounded_table() {
        let (emb, table) = make(32, 16);
        let mut out = vec![0f32; 16];
        for id in [0usize, 7, 31] {
            emb.lookup(id, &mut out).unwrap();
            for (i, &o) in out.iter().enumerate() {
                let want = crate::util::bf16::bf16_to_f32(crate::util::bf16::f32_to_bf16(
                    table[id * 16 + i],
                ));
                assert_eq!(o, want);
            }
        }
    }

    #[test]
    fn batch_lookup_concatenates_rows() {
        let (emb, _) = make(16, 8);
        let ids = [3usize, 3, 5];
        let mut out = vec![0f32; 3 * 8];
        emb.lookup_batch(&ids, &mut out).unwrap();
        assert_eq!(out[..8], out[8..16], "same id → same row");
        assert_ne!(out[..8], out[16..24]);
    }

    #[test]
    #[should_panic(expected = "out of vocab")]
    fn out_of_vocab_panics() {
        let (emb, _) = make(8, 4);
        let mut out = vec![0f32; 4];
        let _ = emb.lookup(9, &mut out);
    }

    #[test]
    fn from_file_streams_and_matches_from_f32() {
        let mut rng = Rng::new(11);
        let (vocab, hidden) = (16usize, 8usize);
        let table = rng.normal_vec(vocab * hidden);
        let mut bytes = Vec::with_capacity(table.len() * 2);
        for &v in &table {
            bytes.extend_from_slice(&crate::util::bf16::f32_to_bf16(v).to_le_bytes());
        }
        let path = crate::util::unique_temp_path("mnn_emb_stream", ".bin");
        std::fs::write(&path, &bytes).unwrap();
        let from_file = FlashEmbedding::from_file(
            &path,
            vocab,
            hidden,
            FlashSim::temp(SocProfile::snapdragon_8gen3().flash).unwrap(),
        )
        .unwrap();
        let from_mem = FlashEmbedding::from_f32(
            &table,
            vocab,
            hidden,
            FlashSim::temp(SocProfile::snapdragon_8gen3().flash).unwrap(),
        );
        let mut a = vec![0f32; hidden];
        let mut b = vec![0f32; hidden];
        for id in [0usize, 7, 15] {
            from_file.lookup(id, &mut a).unwrap();
            from_mem.lookup(id, &mut b).unwrap();
            assert_eq!(a, b);
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn decode_read_is_one_row() {
        let (emb, _) = make(64, 32);
        let before = emb.flash().stats();
        let mut out = vec![0f32; 32];
        emb.lookup(5, &mut out).unwrap();
        let after = emb.flash().stats();
        assert_eq!(after.reads - before.reads, 1);
        assert_eq!(after.read_bytes - before.read_bytes, 64);
    }
}
