//! KV-cache DRAM-Flash spill (paper §4.1, Fig. 2).
//!
//! Tokens beyond a DRAM budget migrate (oldest first) to the flash device
//! as the same serialized records the cache uses in DRAM. Before a decode
//! step's attention, spilled records must be staged back; the prefetcher
//! (memory::prefetch) overlaps that load with the previous layer's compute
//! window so it is free until the spilled span exceeds the
//! bandwidth-delay product.
//!
//! Two eviction triggers:
//! * the layer's own `dram_budget_tokens` (the paper's single-sequence
//!   spill threshold), and
//! * pressure on the shared [`KvPool`] the resident pages come from —
//!   when concurrent sessions collectively exceed the pool's byte budget,
//!   appends shed this layer's oldest records to flash until the pool is
//!   back under budget (or the layer is empty). This is what lets the
//!   coordinator keep admitting requests instead of OOMing.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::cpu::activation::softmax_inplace;
use crate::kv::{EvictionPolicy, KvLayer, KvPool};
use crate::memory::flash::FlashSim;

/// One layer's KV with a flash tier below it.
pub struct HybridKvLayer {
    /// DRAM-resident suffix of the sequence (pages from the shared pool).
    pub resident: KvLayer,
    /// Staged copy of the spilled prefix (refreshed by prefetch). Staging
    /// is transient scratch and deliberately lives on its own unbounded
    /// pool — the shared budget governs *resident* KV; long-context decode
    /// under pressure uses the streaming path, which never stages.
    staging: KvLayer,
    /// True when `staging` holds all spilled tokens.
    staged_valid: bool,
    flash: Arc<FlashSim>,
    /// Flash offsets of spilled token records, in token order.
    spilled: Vec<u64>,
    /// Spill threshold: max resident tokens before migration.
    pub dram_budget_tokens: usize,
    /// Who sheds under *pool* (cross-session) pressure: this layer itself
    /// on every append (`ShedSelf`), or the engine's largest-holder pass
    /// between scheduler ticks (`LargestHolder`).
    eviction: EvictionPolicy,
    /// Shared pool the resident pages are drawn from.
    pool: Arc<KvPool>,
    /// Cumulative records written to flash (spills).
    spilled_records: u64,
    /// Cumulative records read back from flash (stage + streaming).
    restored_records: AtomicU64,
}

impl HybridKvLayer {
    pub fn new(
        kv_heads: usize,
        head_dim: usize,
        flash: Arc<FlashSim>,
        dram_budget_tokens: usize,
    ) -> Self {
        Self::with_pool(kv_heads, head_dim, flash, dram_budget_tokens,
                        Arc::new(KvPool::unbounded()))
    }

    /// Resident pages come from `pool`; pool pressure triggers eviction
    /// under the default `ShedSelf` policy.
    pub fn with_pool(
        kv_heads: usize,
        head_dim: usize,
        flash: Arc<FlashSim>,
        dram_budget_tokens: usize,
        pool: Arc<KvPool>,
    ) -> Self {
        Self::with_pool_policy(
            kv_heads,
            head_dim,
            flash,
            dram_budget_tokens,
            pool,
            EvictionPolicy::ShedSelf,
        )
    }

    /// [`with_pool`](Self::with_pool) with an explicit cross-session
    /// eviction policy. Under `LargestHolder`, `append` honors only the
    /// layer's own token budget; restoring the *pool* budget is the
    /// engine's job (`NativeModel::enforce_kv_budget`).
    pub fn with_pool_policy(
        kv_heads: usize,
        head_dim: usize,
        flash: Arc<FlashSim>,
        dram_budget_tokens: usize,
        pool: Arc<KvPool>,
        eviction: EvictionPolicy,
    ) -> Self {
        HybridKvLayer {
            resident: KvLayer::with_pool(kv_heads, head_dim, pool.clone()),
            staging: KvLayer::new(kv_heads, head_dim),
            staged_valid: true, // nothing spilled yet
            flash,
            spilled: Vec::new(),
            dram_budget_tokens: dram_budget_tokens.max(1),
            eviction,
            pool,
            spilled_records: 0,
            restored_records: AtomicU64::new(0),
        }
    }

    /// Total sequence length (spilled + resident).
    pub fn len(&self) -> usize {
        self.spilled.len() + self.resident.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn spilled_tokens(&self) -> usize {
        self.spilled.len()
    }

    pub fn bytes_per_token(&self) -> usize {
        self.resident.bytes_per_token()
    }

    /// Records ever spilled to flash (monotone counter for EngineMetrics).
    pub fn spill_count(&self) -> u64 {
        self.spilled_records
    }

    /// Records ever read back from flash (monotone counter).
    pub fn restore_count(&self) -> u64 {
        self.restored_records.load(Ordering::Relaxed)
    }

    /// Move the oldest resident record to flash.
    fn spill_one(&mut self) -> std::io::Result<()> {
        let rec = self.resident.serialize_token(0);
        let off = self.flash.append(&rec)?;
        self.spilled.push(off);
        self.resident.drop_prefix(1);
        self.spilled_records += 1;
        self.staged_valid = false;
        Ok(())
    }

    /// Append one token; evict the oldest resident tokens while over the
    /// layer's token budget or — under `ShedSelf` — while the shared pool
    /// is over its byte budget. The spill is one sequential flash append
    /// per token (the paper: each step produces ~1 KB of new KV).
    pub fn append(&mut self, k: &[f32], v: &[f32]) -> std::io::Result<()> {
        self.resident.append(k, v);
        let shed_self = self.eviction == EvictionPolicy::ShedSelf;
        while !self.resident.is_empty()
            && (self.resident.len() > self.dram_budget_tokens
                || (shed_self && self.pool.over_budget()))
        {
            self.spill_one()?;
        }
        if self.resident.is_empty() {
            // Everything went to flash: release the (empty) tail page too.
            self.resident.clear();
        }
        Ok(())
    }

    /// Spill up to `n` of the oldest resident records to flash (the
    /// largest-holder eviction unit). Returns records actually spilled —
    /// 0 when nothing is resident. Value-neutral like all spilling.
    pub fn shed_oldest(&mut self, n: usize) -> std::io::Result<usize> {
        let n = n.min(self.resident.len());
        for _ in 0..n {
            self.spill_one()?;
        }
        if self.resident.is_empty() {
            self.resident.clear();
        }
        Ok(n)
    }

    /// Terminal release: drop ALL KV state — resident pages back to the
    /// pool, staging freed, spilled flash offsets forgotten. For sessions
    /// that have produced their last token: their KV will never be
    /// attended again, so holding it only pressures live sessions. The
    /// cumulative spill/restore counters survive for metrics.
    pub fn release(&mut self) {
        self.resident.clear();
        self.staging.clear();
        self.spilled.clear();
        self.staged_valid = true;
    }

    /// Preemption hook: spill every resident record to flash and release
    /// all of this layer's pages. Returns records spilled. Value-neutral:
    /// decode continues via the streaming path (or `stage()`).
    pub fn spill_all(&mut self) -> std::io::Result<usize> {
        let n = self.resident.len();
        for _ in 0..n {
            self.spill_one()?;
        }
        self.resident.clear();
        self.drop_staging();
        Ok(n)
    }

    /// Drop the **newest** tokens so `new_len` remain (no-op when
    /// `new_len >= len()`): the speculative-decoding rollback. Resident
    /// (newest) records go first via [`KvLayer::truncate`]; only when the
    /// rollback reaches past the resident suffix — draft tokens that were
    /// themselves spilled under mid-tick pressure — are the newest spilled
    /// flash offsets forgotten too (their records stay on the append-only
    /// flash device until the engine's idle reclamation truncates it).
    /// Forgetting spilled offsets invalidates any staged copy.
    pub fn truncate(&mut self, new_len: usize) {
        if new_len >= self.len() {
            return;
        }
        if new_len >= self.spilled.len() {
            self.resident.truncate(new_len - self.spilled.len());
        } else {
            self.resident.clear();
            self.spilled.truncate(new_len);
            self.drop_staging();
        }
    }

    /// Load all spilled records into staging. Returns modeled flash seconds
    /// spent (0.0 when already staged). The prefetcher calls this during
    /// the previous layer's compute window.
    pub fn stage(&mut self) -> std::io::Result<f64> {
        if self.staged_valid {
            return Ok(0.0);
        }
        self.staging.clear();
        let mut total = 0.0;
        let rec_len = self.resident.bytes_per_token();
        let mut buf = vec![0u8; rec_len];
        // Spills are sequential appends per layer, so batches of
        // consecutive offsets coalesce into large reads (the paper's "larger
        // continuous memory blocks" 1 GB/s assumption). We model per-record
        // reads but merge adjacent offsets to skip repeated fixed latency.
        let mut prev_end: Option<u64> = None;
        for &off in &self.spilled {
            let t = self.flash.read_at(off, &mut buf)?;
            total += match prev_end {
                Some(end) if end == off => t - self.flash.tier().latency_s,
                _ => t,
            };
            prev_end = Some(off + rec_len as u64);
            self.staging.push_serialized(&buf);
        }
        self.restored_records
            .fetch_add(self.spilled.len() as u64, Ordering::Relaxed);
        self.staged_valid = true;
        Ok(total)
    }

    /// Modeled time `stage()` would take right now (prefetch planning).
    pub fn stage_cost(&self) -> f64 {
        if self.staged_valid {
            return 0.0;
        }
        let bytes = self.spilled.len() * self.resident.bytes_per_token();
        // One latency charge: spilled records are contiguous on flash.
        self.flash.read_time(bytes)
    }

    /// GQA decode attention over the full (staged + resident) sequence.
    /// Panics if spilled tokens are not staged — call `stage()` (or let the
    /// prefetcher do it) first.
    pub fn decode_attention(&self, q: &[f32], heads: usize, out: &mut [f32]) {
        assert!(self.staged_valid, "spilled KV not staged; prefetch missing");
        let d = self.resident.head_dim;
        let kvh_n = self.resident.kv_heads;
        assert!(heads % kvh_n == 0);
        let group = heads / kvh_n;
        let n_sp = self.staging.len();
        let n_res = self.resident.len();
        let t = n_sp + n_res;
        assert!(t > 0);
        let scale = 1.0 / (d as f32).sqrt();
        let mut scores = vec![0f32; t];
        let mut qs = vec![0f32; d];
        for h in 0..heads {
            let kvh = h / group;
            for (qv, &xv) in qs.iter_mut().zip(&q[h * d..(h + 1) * d]) {
                *qv = xv * scale;
            }
            let (sp_scores, res_scores) = scores.split_at_mut(n_sp);
            for (tok, sc) in sp_scores.iter_mut().enumerate() {
                *sc = self.staging.key_dot(kvh, tok, &qs);
            }
            for (tok, sc) in res_scores.iter_mut().enumerate() {
                *sc = self.resident.key_dot(kvh, tok, &qs);
            }
            softmax_inplace(&mut scores);
            let o = &mut out[h * d..(h + 1) * d];
            o.fill(0.0);
            for (tok, &sc) in scores[..n_sp].iter().enumerate() {
                self.staging.accum_value(kvh, tok, sc, o);
            }
            for (tok, &sc) in scores[n_sp..].iter().enumerate() {
                self.resident.accum_value(kvh, tok, sc, o);
            }
        }
    }

    /// DRAM occupancy (resident + staging).
    pub fn dram_bytes(&self) -> usize {
        self.resident.resident_bytes() + self.staging.resident_bytes()
    }

    /// Pool-accounted bytes of the resident suffix only. Shared
    /// (prefix-cache) pages count fully — this is the layer's referenced
    /// footprint, not what releasing it would free.
    pub fn resident_kv_bytes(&self) -> usize {
        self.resident.resident_bytes()
    }

    /// Bytes of resident pages this layer holds exclusively (refcount 1):
    /// what shedding/releasing this layer could actually return to the
    /// pool right now.
    pub fn exclusive_kv_bytes(&self) -> usize {
        self.resident.exclusive_resident_bytes()
    }

    /// Resident pages shared with the prefix cache or another session.
    pub fn shared_page_count(&self) -> usize {
        self.resident.shared_page_count()
    }

    /// Report resident-page bytes against a holder-registry id (the
    /// owning session), for exact `LargestHolder` victim selection.
    pub fn set_holder(&mut self, id: crate::kv::HolderId) {
        self.resident.set_holder(id);
    }

    /// Prefix-cache attach: start this (empty) layer at `tokens` tokens
    /// backed by shared read-only pages. See [`KvLayer::attach_shared`].
    pub fn attach_shared(&mut self, pages: Vec<crate::kv::PageHandle>, tokens: usize) {
        assert!(self.is_empty(), "attach requires a fresh layer");
        self.resident.attach_shared(pages, tokens);
    }

    /// Prefix-cache publish: clone handles covering the first `tokens`
    /// resident tokens. Requires nothing spilled (the prefix must be
    /// whole in DRAM).
    pub fn share_prefix_pages(&self, tokens: usize) -> Vec<crate::kv::PageHandle> {
        assert!(self.spilled.is_empty(), "cannot publish a spilled prefix");
        self.resident.share_prefix_pages(tokens)
    }

    /// Release the staging copy (tokens remain on flash).
    pub fn drop_staging(&mut self) {
        self.staging.clear();
        self.staged_valid = self.spilled.is_empty();
    }

    /// GQA decode attention that *streams* spilled records from flash in
    /// chunks of `chunk_tokens`, using online (rescaled) softmax so no
    /// full-length staging buffer is ever materialized — DRAM stays
    /// O(resident + chunk) regardless of context length, which is the
    /// point of §4.1's hybrid storage. Returns modeled flash seconds.
    pub fn decode_attention_streaming(
        &self,
        q: &[f32],
        heads: usize,
        out: &mut [f32],
        chunk_tokens: usize,
    ) -> std::io::Result<f64> {
        let d = self.resident.head_dim;
        let kvh_n = self.resident.kv_heads;
        assert!(heads % kvh_n == 0);
        let group = heads / kvh_n;
        let t = self.len();
        assert!(t > 0);
        let chunk_tokens = chunk_tokens.max(1);
        let scale = 1.0 / (d as f32).sqrt();
        // Online-softmax state per head: running max, running sum, output.
        let mut run_m = vec![f32::NEG_INFINITY; heads];
        let mut run_s = vec![0f32; heads];
        out.fill(0.0);
        let mut qs = vec![0f32; heads * d];
        for (qv, &xv) in qs.iter_mut().zip(q) {
            *qv = xv * scale;
        }
        let absorb = |cache: &KvLayer,
                          tok: usize,
                          run_m: &mut [f32],
                          run_s: &mut [f32],
                          out: &mut [f32]| {
            for (h, (m, s)) in run_m.iter_mut().zip(run_s.iter_mut()).enumerate() {
                let kvh = h / group;
                let score = cache.key_dot(kvh, tok, &qs[h * d..(h + 1) * d]);
                let o = &mut out[h * d..(h + 1) * d];
                if score > *m {
                    let r = (*m - score).exp(); // rescale history
                    if *s > 0.0 {
                        for v in o.iter_mut() {
                            *v *= r;
                        }
                    }
                    *s *= r;
                    *m = score;
                }
                let w = (score - *m).exp();
                *s += w;
                cache.accum_value(kvh, tok, w, o);
            }
        };
        // Stream the spilled prefix chunk by chunk. The chunk scratch (and
        // its private pool) is only built when something is actually
        // spilled — decode's common no-spill case allocates nothing here.
        let mut flash_s = 0.0;
        if !self.spilled.is_empty() {
            let rec_len = self.resident.bytes_per_token();
            let mut chunk = KvLayer::new(kvh_n, d);
            let mut buf = vec![0u8; rec_len];
            for ids in self.spilled.chunks(chunk_tokens) {
                chunk.clear();
                let mut prev_end: Option<u64> = None;
                for &off in ids {
                    let t = self.flash.read_at(off, &mut buf)?;
                    flash_s += match prev_end {
                        Some(end) if end == off => t - self.flash.tier().latency_s,
                        _ => t,
                    };
                    prev_end = Some(off + rec_len as u64);
                    chunk.push_serialized(&buf);
                }
                self.restored_records
                    .fetch_add(ids.len() as u64, Ordering::Relaxed);
                for tok in 0..chunk.len() {
                    absorb(&chunk, tok, &mut run_m, &mut run_s, out);
                }
            }
        }
        // Then the DRAM-resident suffix.
        for tok in 0..self.resident.len() {
            absorb(&self.resident, tok, &mut run_m, &mut run_s, out);
        }
        // Normalize.
        for (h, &s) in run_s.iter().enumerate() {
            let inv = 1.0 / s;
            for v in out[h * d..(h + 1) * d].iter_mut() {
                *v *= inv;
            }
        }
        Ok(flash_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::attention::decode_attention as plain_attention;
    use crate::device::SocProfile;
    use crate::util::rng::Rng;

    fn flash() -> Arc<FlashSim> {
        Arc::new(FlashSim::temp(SocProfile::snapdragon_8gen3().flash).unwrap())
    }

    #[test]
    fn no_spill_below_budget() {
        let mut h = HybridKvLayer::new(2, 8, flash(), 10);
        let mut rng = Rng::new(1);
        for _ in 0..10 {
            let k = rng.normal_vec(16);
            let v = rng.normal_vec(16);
            h.append(&k, &v).unwrap();
        }
        assert_eq!(h.spilled_tokens(), 0);
        assert_eq!(h.len(), 10);
        assert_eq!(h.spill_count(), 0);
    }

    #[test]
    fn spills_oldest_beyond_budget() {
        let mut h = HybridKvLayer::new(2, 8, flash(), 4);
        let mut rng = Rng::new(2);
        for _ in 0..10 {
            let k = rng.normal_vec(16);
            let v = rng.normal_vec(16);
            h.append(&k, &v).unwrap();
        }
        assert_eq!(h.spilled_tokens(), 6);
        assert_eq!(h.resident.len(), 4);
        assert_eq!(h.len(), 10);
        assert_eq!(h.spill_count(), 6);
    }

    #[test]
    fn pool_pressure_evicts_instead_of_panicking() {
        // Budget of ONE page shared by two layers: appends keep succeeding;
        // the overflow is shed to flash and the pool ends under budget.
        let pool = Arc::new(KvPool::new(KvPool::page_bytes(2, 8)));
        let fl = flash();
        let mut a = HybridKvLayer::with_pool(2, 8, fl.clone(), usize::MAX / 2, pool.clone());
        let mut b = HybridKvLayer::with_pool(2, 8, fl, usize::MAX / 2, pool.clone());
        let mut rng = Rng::new(9);
        for _ in 0..40 {
            let k = rng.normal_vec(16);
            let v = rng.normal_vec(16);
            a.append(&k, &v).unwrap();
            let k = rng.normal_vec(16);
            let v = rng.normal_vec(16);
            b.append(&k, &v).unwrap();
            // The budget is re-established after every append.
            assert!(
                pool.resident_bytes() <= pool.budget_bytes(),
                "pool {} > budget {}",
                pool.resident_bytes(),
                pool.budget_bytes()
            );
        }
        assert_eq!(a.len(), 40);
        assert_eq!(b.len(), 40);
        assert!(a.spill_count() > 0 && b.spill_count() > 0);
    }

    #[test]
    fn spill_all_releases_pages_and_streaming_still_matches() {
        let pool = Arc::new(KvPool::unbounded());
        let fl = flash();
        let mut rng = Rng::new(12);
        let (heads, kv_heads, d, t) = (4, 2, 16, 20);
        let mut plain = KvLayer::new(kv_heads, d);
        let mut hybrid =
            HybridKvLayer::with_pool(kv_heads, d, fl, usize::MAX / 2, pool.clone());
        for _ in 0..t {
            let k = rng.normal_vec(kv_heads * d);
            let v = rng.normal_vec(kv_heads * d);
            plain.append(&k, &v);
            hybrid.append(&k, &v).unwrap();
        }
        assert!(pool.resident_bytes() > 0);
        let spilled = hybrid.spill_all().unwrap();
        assert_eq!(spilled, t);
        assert_eq!(pool.resident_bytes(), 0, "preemption releases all pages");
        assert_eq!(hybrid.len(), t, "tokens survive on flash");
        let q = rng.normal_vec(heads * d);
        let mut want = vec![0f32; heads * d];
        plain_attention(&q, heads, &plain, &mut want);
        let mut got = vec![0f32; heads * d];
        hybrid.decode_attention_streaming(&q, heads, &mut got, 8).unwrap();
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        assert_eq!(hybrid.restore_count(), t as u64);
    }

    #[test]
    fn release_forgets_state_but_keeps_counters() {
        let pool = Arc::new(KvPool::unbounded());
        let mut h = HybridKvLayer::with_pool(2, 8, flash(), 2, pool.clone());
        let mut rng = Rng::new(13);
        for _ in 0..8 {
            let k = rng.normal_vec(16);
            let v = rng.normal_vec(16);
            h.append(&k, &v).unwrap();
        }
        assert!(h.spill_count() > 0 && pool.resident_bytes() > 0);
        let spills_before = h.spill_count();
        h.release();
        assert_eq!(h.len(), 0, "all KV gone");
        assert_eq!(h.spilled_tokens(), 0);
        assert_eq!(pool.resident_bytes(), 0, "pages back in the pool");
        assert_eq!(h.spill_count(), spills_before, "counters survive");
    }

    #[test]
    fn hybrid_attention_matches_unspilled() {
        // The core §4.1 correctness claim: spilling must not change output.
        let mut rng = Rng::new(3);
        let (heads, kv_heads, d, t) = (4, 2, 16, 24);
        let mut plain = KvLayer::new(kv_heads, d);
        let mut hybrid = HybridKvLayer::new(kv_heads, d, flash(), 5);
        for _ in 0..t {
            let k = rng.normal_vec(kv_heads * d);
            let v = rng.normal_vec(kv_heads * d);
            plain.append(&k, &v);
            hybrid.append(&k, &v).unwrap();
        }
        assert!(hybrid.spilled_tokens() > 0);
        hybrid.stage().unwrap();
        let q = rng.normal_vec(heads * d);
        let mut want = vec![0f32; heads * d];
        plain_attention(&q, heads, &plain, &mut want);
        let mut got = vec![0f32; heads * d];
        hybrid.decode_attention(&q, heads, &mut got);
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn stage_is_idempotent_and_costed() {
        let mut rng = Rng::new(4);
        let mut h = HybridKvLayer::new(2, 8, flash(), 2);
        for _ in 0..8 {
            let k = rng.normal_vec(16);
            let v = rng.normal_vec(16);
            h.append(&k, &v).unwrap();
        }
        let est = h.stage_cost();
        assert!(est > 0.0);
        let t1 = h.stage().unwrap();
        assert!(t1 > 0.0);
        let t2 = h.stage().unwrap();
        assert_eq!(t2, 0.0, "second stage is free");
        assert_eq!(h.stage_cost(), 0.0);
        assert_eq!(h.restore_count(), 6, "stage restored the spilled prefix once");
    }

    #[test]
    #[should_panic(expected = "not staged")]
    fn attention_without_staging_panics() {
        let mut rng = Rng::new(5);
        let mut h = HybridKvLayer::new(1, 4, flash(), 1);
        for _ in 0..3 {
            let k = rng.normal_vec(4);
            let v = rng.normal_vec(4);
            h.append(&k, &v).unwrap();
        }
        let q = rng.normal_vec(4);
        let mut out = vec![0f32; 4];
        h.decode_attention(&q, 1, &mut out);
    }

    #[test]
    fn streaming_matches_staged_attention() {
        // Online softmax over flash chunks == full staged attention.
        let mut rng = Rng::new(7);
        let (heads, kv_heads, d, t) = (4, 2, 16, 40);
        let mut hybrid = HybridKvLayer::new(kv_heads, d, flash(), 6);
        for _ in 0..t {
            let k = rng.normal_vec(kv_heads * d);
            let v = rng.normal_vec(kv_heads * d);
            hybrid.append(&k, &v).unwrap();
        }
        let q = rng.normal_vec(heads * d);
        hybrid.stage().unwrap();
        let mut want = vec![0f32; heads * d];
        hybrid.decode_attention(&q, heads, &mut want);
        hybrid.drop_staging();
        for chunk in [1usize, 3, 8, 64] {
            let mut got = vec![0f32; heads * d];
            let flash_s = hybrid
                .decode_attention_streaming(&q, heads, &mut got, chunk)
                .unwrap();
            assert!(flash_s > 0.0);
            for (a, b) in want.iter().zip(&got) {
                assert!((a - b).abs() < 1e-4, "chunk {chunk}: {a} vs {b}");
            }
            // No staging buffer left behind.
            assert_eq!(hybrid.staging.len(), 0);
        }
    }

    #[test]
    fn streaming_without_spill_matches_plain() {
        let mut rng = Rng::new(8);
        let (heads, kv_heads, d, t) = (2, 1, 8, 10);
        let mut plain = KvLayer::new(kv_heads, d);
        let mut hybrid = HybridKvLayer::new(kv_heads, d, flash(), 100);
        for _ in 0..t {
            let k = rng.normal_vec(kv_heads * d);
            let v = rng.normal_vec(kv_heads * d);
            plain.append(&k, &v);
            hybrid.append(&k, &v).unwrap();
        }
        let q = rng.normal_vec(heads * d);
        let mut want = vec![0f32; heads * d];
        plain_attention(&q, heads, &plain, &mut want);
        let mut got = vec![0f32; heads * d];
        hybrid.decode_attention_streaming(&q, heads, &mut got, 4).unwrap();
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn dram_usage_bounded_by_budget() {
        let mut rng = Rng::new(6);
        let budget = 4;
        let mut h = HybridKvLayer::new(2, 8, flash(), budget);
        for _ in 0..50 {
            let k = rng.normal_vec(16);
            let v = rng.normal_vec(16);
            h.append(&k, &v).unwrap();
        }
        assert!(h.resident.len() <= budget);
    }

    #[test]
    fn largest_holder_policy_leaves_pool_pressure_to_the_engine() {
        // Under LargestHolder, append honors only the layer's own token
        // budget: pool pressure no longer makes the appender shed itself.
        let pool = Arc::new(KvPool::new(KvPool::page_bytes(2, 8)));
        let mut a = HybridKvLayer::with_pool_policy(
            2,
            8,
            flash(),
            usize::MAX / 2,
            pool.clone(),
            EvictionPolicy::LargestHolder,
        );
        let mut rng = Rng::new(14);
        for _ in 0..3 * crate::kv::PAGE_TOKENS {
            let k = rng.normal_vec(16);
            let v = rng.normal_vec(16);
            a.append(&k, &v).unwrap();
        }
        assert_eq!(a.spill_count(), 0, "no self-shedding under LargestHolder");
        assert!(pool.over_budget(), "pressure is left for the engine pass");
        // The engine-side eviction unit restores the budget explicitly.
        let shed = a.shed_oldest(2 * crate::kv::PAGE_TOKENS).unwrap();
        assert_eq!(shed, 2 * crate::kv::PAGE_TOKENS);
        assert!(!pool.over_budget());
        assert_eq!(a.len(), 3 * crate::kv::PAGE_TOKENS, "tokens survive on flash");
    }

    #[test]
    fn truncate_rolls_back_resident_tail_and_stays_value_neutral() {
        // Speculative rollback: append draft tokens, reject them, truncate —
        // attention must equal a layer that never saw the drafts.
        let pool = Arc::new(KvPool::unbounded());
        let mut rng = Rng::new(16);
        let (heads, kv_heads, d, t) = (4usize, 2usize, 16usize, 6usize);
        let mut plain = KvLayer::new(kv_heads, d);
        let mut hybrid =
            HybridKvLayer::with_pool(kv_heads, d, flash(), usize::MAX / 2, pool.clone());
        for _ in 0..t {
            let k = rng.normal_vec(kv_heads * d);
            let v = rng.normal_vec(kv_heads * d);
            plain.append(&k, &v);
            hybrid.append(&k, &v).unwrap();
        }
        let q = rng.normal_vec(heads * d);
        let mut want = vec![0f32; heads * d];
        hybrid.decode_attention_streaming(&q, heads, &mut want, 4).unwrap();
        for _ in 0..3 {
            let k = rng.normal_vec(kv_heads * d);
            let v = rng.normal_vec(kv_heads * d);
            hybrid.append(&k, &v).unwrap(); // rejected draft tokens
        }
        hybrid.truncate(t);
        assert_eq!(hybrid.len(), t);
        hybrid.truncate(t + 100); // no-op beyond current length
        assert_eq!(hybrid.len(), t);
        let mut got = vec![0f32; heads * d];
        hybrid.decode_attention_streaming(&q, heads, &mut got, 4).unwrap();
        assert_eq!(want, got, "rollback must be exact, not approximate");
        let mut full = vec![0f32; heads * d];
        plain_attention(&q, heads, &plain, &mut full);
        for (a, b) in full.iter().zip(&got) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
        hybrid.truncate(0);
        assert_eq!(hybrid.len(), 0);
        assert_eq!(pool.resident_bytes(), 0, "truncate(0) releases all pages");
    }

    #[test]
    fn truncate_into_spilled_tier_drops_offsets_and_staging() {
        // Rollback reaching past the resident suffix (drafts spilled under
        // mid-tick pressure): spilled offsets are forgotten and any staged
        // copy is invalidated, while the surviving prefix stays readable.
        let pool = Arc::new(KvPool::unbounded());
        let mut rng = Rng::new(17);
        let (heads, kv_heads, d) = (4usize, 2usize, 16usize);
        let keep = 3usize;
        let mut plain = KvLayer::new(kv_heads, d);
        let mut hybrid = HybridKvLayer::with_pool(kv_heads, d, flash(), 4, pool.clone());
        for i in 0..10 {
            let k = rng.normal_vec(kv_heads * d);
            let v = rng.normal_vec(kv_heads * d);
            if i < keep {
                plain.append(&k, &v);
            }
            hybrid.append(&k, &v).unwrap();
        }
        assert_eq!(hybrid.spilled_tokens(), 6);
        hybrid.stage().unwrap();
        hybrid.truncate(keep);
        assert_eq!(hybrid.len(), keep);
        assert_eq!(hybrid.spilled_tokens(), keep, "tail offsets forgotten");
        assert_eq!(pool.resident_bytes(), 0, "resident suffix fully released");
        assert!(hybrid.stage_cost() > 0.0, "stale staging was invalidated");
        let q = rng.normal_vec(heads * d);
        let mut want = vec![0f32; heads * d];
        plain_attention(&q, heads, &plain, &mut want);
        let mut got = vec![0f32; heads * d];
        hybrid.decode_attention_streaming(&q, heads, &mut got, 4).unwrap();
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
        // The layer is still append-able after a deep rollback.
        let k = rng.normal_vec(kv_heads * d);
        let v = rng.normal_vec(kv_heads * d);
        hybrid.append(&k, &v).unwrap();
        assert_eq!(hybrid.len(), keep + 1);
    }

    #[test]
    fn shed_oldest_caps_at_resident_and_stays_value_neutral() {
        let mut rng = Rng::new(15);
        let (heads, kv_heads, d, t) = (4usize, 2usize, 16usize, 10usize);
        let mut plain = KvLayer::new(kv_heads, d);
        let mut hybrid = HybridKvLayer::new(kv_heads, d, flash(), usize::MAX / 2);
        for _ in 0..t {
            let k = rng.normal_vec(kv_heads * d);
            let v = rng.normal_vec(kv_heads * d);
            plain.append(&k, &v);
            hybrid.append(&k, &v).unwrap();
        }
        assert_eq!(hybrid.shed_oldest(4).unwrap(), 4);
        assert_eq!(hybrid.shed_oldest(100).unwrap(), t - 4, "capped at resident");
        assert_eq!(hybrid.shed_oldest(1).unwrap(), 0, "nothing left to shed");
        let q = rng.normal_vec(heads * d);
        let mut want = vec![0f32; heads * d];
        plain_attention(&q, heads, &plain, &mut want);
        let mut got = vec![0f32; heads * d];
        hybrid.decode_attention_streaming(&q, heads, &mut got, 4).unwrap();
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }
}
