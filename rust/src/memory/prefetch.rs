//! KV prefetch scheduling (paper §4.1, Fig. 2c/2d).
//!
//! During layer *l*'s MLP and layer *l+1*'s qkv projection, the engine
//! prefetches layer *l+1*'s spilled KV from flash. If the load fits inside
//! that compute window, flash costs nothing; beyond the bandwidth-delay
//! product (paper: ~3 MB per window ⇒ 3072K tokens for Qwen2-7B), each
//! extra token adds ~1 ms/1K of exposed latency.
//!
//! The planner is pure arithmetic over the device model (used by Fig. 2 and
//! by the engine's virtual-time accounting); `run_prefetched_pass` applies
//! it to real `HybridKvLayer`s.

use crate::device::timeline::Timeline;
use crate::device::SocProfile;
use crate::memory::hybrid::HybridKvLayer;

/// Accumulated prefetch accounting for one forward pass.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PrefetchStats {
    /// Flash seconds fully hidden under compute.
    pub hidden_s: f64,
    /// Flash seconds exposed on the critical path.
    pub exposed_s: f64,
    /// Total compute seconds in the pass.
    pub compute_s: f64,
}

/// Compute/prefetch planner for a decode step.
#[derive(Clone, Copy, Debug)]
pub struct PrefetchPlanner {
    /// Compute window per layer available for overlap (MLP + next qkv), s.
    pub window_s: f64,
    /// Flash read bandwidth, bytes/s.
    pub flash_bw: f64,
    /// Flash fixed latency, s.
    pub flash_latency_s: f64,
}

impl PrefetchPlanner {
    /// Window from the device model: decode is memory-bound, so the window
    /// is the DRAM streaming time of one layer's qkv+MLP weights.
    pub fn from_soc(soc: &SocProfile, layer_qkv_mlp_bytes: usize) -> Self {
        PrefetchPlanner {
            window_s: soc.dram_read_time(layer_qkv_mlp_bytes),
            flash_bw: soc.flash.read_bw,
            flash_latency_s: soc.flash.latency_s,
        }
    }

    /// Bytes of spilled KV per layer that the window can hide (the Fig. 2
    /// crossover: ≈ window × flash_bw).
    pub fn hidden_capacity_bytes(&self) -> f64 {
        ((self.window_s - self.flash_latency_s) * self.flash_bw).max(0.0)
    }

    /// Exposed (critical-path) seconds for loading `bytes` of spilled KV in
    /// one layer's window.
    pub fn exposed_time(&self, bytes: usize) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        let load = self.flash_latency_s + bytes as f64 / self.flash_bw;
        (load - self.window_s).max(0.0)
    }

    /// Decode-step makespan over `layers` identical layers with
    /// `spilled_bytes` of flash KV each and `compute_s` compute per layer.
    /// `prefetch=false` models Fig. 2b (serial flash reads).
    pub fn step_makespan(
        &self,
        layers: usize,
        spilled_bytes: usize,
        compute_s: f64,
        prefetch: bool,
    ) -> f64 {
        let mut tl = Timeline::new();
        let load = if spilled_bytes == 0 {
            0.0
        } else {
            self.flash_latency_s + spilled_bytes as f64 / self.flash_bw
        };
        for _ in 0..layers {
            if prefetch {
                // Load for layer l+1 overlaps layer l's compute.
                let done = tl.io(load);
                tl.compute(compute_s);
                tl.join(done);
            } else {
                // Serial: the load is issued only when this layer's
                // attention needs it — after the previous compute finishes.
                tl.advance_to(tl.compute_free_at());
                let done = tl.io(load);
                tl.join(done);
                tl.compute(compute_s);
            }
        }
        tl.finish()
    }
}

/// Run one decode step's attention across hybrid layers with prefetch
/// pipelining: stage layer l+1 while "computing" layer l via `compute`.
/// Returns stats with hidden vs exposed flash time (virtual accounting;
/// the staging I/O itself is real).
pub fn run_prefetched_pass(
    layers: &mut [HybridKvLayer],
    window_s: f64,
    mut compute: impl FnMut(usize, &HybridKvLayer),
) -> std::io::Result<PrefetchStats> {
    let mut stats = PrefetchStats::default();
    // Stage layer 0 up front (nothing to hide behind).
    if !layers.is_empty() {
        let t = layers[0].stage()?;
        stats.exposed_s += t;
    }
    for l in 0..layers.len() {
        // Prefetch the next layer's spilled KV "during" this layer's window.
        if l + 1 < layers.len() {
            let t = layers[l + 1].stage()?;
            stats.hidden_s += t.min(window_s);
            stats.exposed_s += (t - window_s).max(0.0);
        }
        compute(l, &layers[l]);
        stats.compute_s += window_s;
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::SocProfile;

    /// Qwen2-7B single-layer qkv+MLP int8 bytes (paper: 178.83 MB in fp16;
    /// the §4.1 example charges ~3 ms of LPDDR5X time for it).
    const QWEN7B_LAYER_BYTES: usize = 178_830_000;

    fn planner() -> PrefetchPlanner {
        PrefetchPlanner::from_soc(&SocProfile::snapdragon_8gen3(), QWEN7B_LAYER_BYTES)
    }

    #[test]
    fn window_matches_paper_3ms() {
        let p = planner();
        assert!((p.window_s - 3.08e-3).abs() < 0.2e-3, "window {}", p.window_s);
    }

    #[test]
    fn hidden_capacity_matches_paper_3mb() {
        // Paper: "approximately 3 MB of KV values … within the computation
        // time" at 1 GB/s flash.
        let p = planner();
        let cap = p.hidden_capacity_bytes();
        assert!((cap - 3.0e6).abs() < 0.3e6, "cap {cap}");
    }

    #[test]
    fn exposed_time_kinks_at_capacity() {
        let p = planner();
        let cap = p.hidden_capacity_bytes() as usize;
        assert_eq!(p.exposed_time(0), 0.0);
        assert_eq!(p.exposed_time(cap / 2), 0.0);
        assert!(p.exposed_time(cap + 1_000_000) > 0.0);
        // Paper: each additional 1K tokens ≈ 1 ms. 1K tokens of Qwen2-7B KV
        // ≈ 1 KB/token (int8+fp8) → 1 MB → 1 ms at 1 GB/s.
        let extra = p.exposed_time(cap + 1_048_576) - p.exposed_time(cap);
        assert!((extra - 1.05e-3).abs() < 0.1e-3, "extra {extra}");
    }

    #[test]
    fn prefetch_beats_serial_makespan() {
        let p = planner();
        let compute = p.window_s;
        let bytes = 2_000_000; // under capacity
        let with = p.step_makespan(28, bytes, compute, true);
        let without = p.step_makespan(28, bytes, compute, false);
        assert!(with < without * 0.7, "with {with} without {without}");
        // Under capacity, prefetch fully hides flash: makespan ≈ compute
        // (+ the one un-hidden first load).
        let pure = 28.0 * compute;
        assert!((with - pure) / pure < 0.15, "with {with} pure {pure}");
    }

    #[test]
    fn real_layers_prefetch_pass() {
        use crate::memory::flash::FlashSim;
        use std::sync::Arc;
        let flash = Arc::new(FlashSim::temp(SocProfile::snapdragon_8gen3().flash).unwrap());
        let mut rng = crate::util::rng::Rng::new(8);
        let mut layers: Vec<HybridKvLayer> = (0..3)
            .map(|_| HybridKvLayer::new(2, 8, flash.clone(), 4))
            .collect();
        for l in &mut layers {
            for _ in 0..12 {
                let k = rng.normal_vec(16);
                let v = rng.normal_vec(16);
                l.append(&k, &v).unwrap();
            }
        }
        let mut visited = Vec::new();
        let stats = run_prefetched_pass(&mut layers, 1e-3, |l, layer| {
            assert!(layer.spilled_tokens() > 0);
            visited.push(l);
        })
        .unwrap();
        assert_eq!(visited, vec![0, 1, 2]);
        assert!(stats.hidden_s > 0.0 || stats.exposed_s > 0.0);
        // All layers staged → attention is legal on each.
        let q = rng.normal_vec(2 * 8);
        let mut out = vec![0f32; 2 * 8];
        for l in &layers {
            l.decode_attention(&q, 2, &mut out);
        }
    }
}
