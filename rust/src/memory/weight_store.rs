//! Layer-granular weight residency (paper §4.1 — the *weight* half of the
//! DRAM–Flash hybrid storage; the KV half lives in [`super::hybrid`]).
//!
//! The pipeline:
//! 1. [`FlashTensorStore::stream_from_file`] parses `weights.bin`
//!    *streamingly* and copies every payload straight onto a [`FlashSim`]
//!    in bounded chunks — at no point does DRAM hold the file, let alone
//!    two copies of it (the old load path read the whole file and then
//!    packed a second copy).
//! 2. Each transformer layer's seven [`QLinear`]s (+ rmsnorm gains) are
//!    packed once and serialized into one relocatable per-layer **blob**
//!    appended to the same flash device ([`LayerWeights::to_blob`]). The
//!    blob preserves every byte and f32 bit of the packed form, so a layer
//!    fetched back from flash is *bit-identical* to one that never left
//!    DRAM.
//! 3. [`WeightStore`] holds packed layers in a byte-budgeted DRAM arena
//!    ([`crate::model::native::EngineOptions::weight_dram_bytes`]) with LRU
//!    eviction. The lm_head, final norm and embedding are pinned outside
//!    the arena by the model. During forward, the engine issues an **async
//!    one-layer-ahead prefetch** on a [`BackgroundWorker`] so the flash
//!    read of layer *l+1* overlaps layer *l*'s compute (same overlap
//!    contract as the KV prefetcher); a prefetch that has not landed when
//!    the layer is needed turns into a blocking wait (`prefetch_stalls`),
//!    never a second read.
//!
//! The budget is a residency target, not a hard wall: the layer being
//! served (and, transiently, its prefetched successor) stays resident even
//! if it alone exceeds the budget — a model whose packed weights exceed
//! DRAM still runs, paying only modeled flash-read time.

use std::collections::{HashMap, HashSet, VecDeque};
use std::io::ErrorKind;
use std::path::Path;
use std::sync::{Arc, Condvar, Mutex};

use crate::cpu::gemm_q::QLinear;
use crate::memory::flash::FlashSim;
use crate::model::weights::{stream_entries, Tensor};
use crate::parallel::pool::BackgroundWorker;
use crate::quant::asym::{AsymParams, WeightBits};
use crate::reorder::gpu_layout::GpuWeightImage;
use crate::reorder::pack::PackedWeights;
use crate::reorder::solver::TileConfig;

fn corrupt(msg: &str) -> std::io::Error {
    std::io::Error::new(ErrorKind::InvalidData, format!("weight blob: {msg}"))
}

// ---------------------------------------------------------------------------
// Flash-resident raw tensors (load-time staging).

struct FlashTensor {
    dtype: u8,
    shape: Vec<usize>,
    off: u64,
    nbytes: usize,
}

/// `weights.bin` streamed onto a flash device: name → (dtype, shape,
/// offset). Raw tensors are read back one at a time while packing layers,
/// so load-path DRAM is bounded by one layer's tensors, not the file.
pub struct FlashTensorStore {
    flash: Arc<FlashSim>,
    entries: HashMap<String, FlashTensor>,
    order: Vec<String>,
}

impl FlashTensorStore {
    /// Stream the container at `path` straight onto `flash`. Header
    /// validation (and its overflow hardening) comes from
    /// [`stream_entries`]; payload bytes are copied in bounded chunks.
    pub fn stream_from_file(path: &Path, flash: Arc<FlashSim>) -> std::io::Result<Self> {
        let file = std::fs::File::open(path)?;
        let mut entries = HashMap::new();
        let mut order = Vec::new();
        stream_entries(std::io::BufReader::new(file), |meta, payload| {
            let off = flash.append_reader(payload, meta.nbytes)?;
            order.push(meta.name.clone());
            entries.insert(
                meta.name.clone(),
                FlashTensor {
                    dtype: meta.dtype,
                    shape: meta.shape.clone(),
                    off,
                    nbytes: meta.nbytes,
                },
            );
            Ok(())
        })?;
        Ok(FlashTensorStore { flash, entries, order })
    }

    /// Read one tensor back into DRAM (packing scratch). Missing names are
    /// `InvalidData`, mirroring `WeightFile::require`.
    pub fn read(&self, name: &str) -> std::io::Result<Tensor> {
        let e = self.entries.get(name).ok_or_else(|| {
            std::io::Error::new(
                ErrorKind::InvalidData,
                format!("weights.bin: missing tensor {name}"),
            )
        })?;
        let mut data = vec![0u8; e.nbytes];
        self.flash.read_at(e.off, &mut data)?;
        Ok(Tensor {
            name: name.to_string(),
            dtype: e.dtype,
            shape: e.shape.clone(),
            data,
        })
    }

    /// Tensor names in container order.
    pub fn order(&self) -> &[String] {
        &self.order
    }

    /// The backing device (shared with the residency arena's blobs).
    pub fn flash(&self) -> &Arc<FlashSim> {
        &self.flash
    }
}

// ---------------------------------------------------------------------------
// Per-layer packed weights + their relocatable blob form.

/// One decoder layer's packed weights — what the forward pass consumes.
/// This is the unit of residency: resident layers hold exactly this
/// struct; evicted layers exist only as blobs on flash.
pub struct LayerWeights {
    pub wq: QLinear,
    pub wk: QLinear,
    pub wv: QLinear,
    pub wo: QLinear,
    pub gate: QLinear,
    pub up: QLinear,
    pub down: QLinear,
    pub ln1: Vec<f32>,
    pub ln2: Vec<f32>,
}

const BITS_INT8: u8 = 0;
const BITS_INT4: u8 = 1;

/// Blob layout keys: every serialized weight record leads with the layout
/// it was packed for, so a blob is self-describing about which compute
/// backend can consume it — CPU-tiled records feed the `cpu::backend`
/// GEMM kernels, GPU-image records feed the (modeled) OpenCL image path.
/// A reader that dequantizes for the wrong backend fails loudly instead
/// of misinterpreting tile order.
const LAYOUT_CPU_TILE: u8 = 0;
const LAYOUT_GPU_IMAGE: u8 = 1;

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32_slice(out: &mut Vec<u8>, xs: &[f32]) {
    put_u64(out, xs.len() as u64);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_qlinear(out: &mut Vec<u8>, q: &QLinear) {
    let p = &q.packed;
    out.push(LAYOUT_CPU_TILE);
    // Dimensions ride as u64: usize→u64 is lossless on every target, so
    // the writer cannot truncate (`as u32` silently would); the reader's
    // u64→usize conversion is the single checked narrowing.
    put_u64(out, p.h as u64);
    put_u64(out, p.l as u64);
    put_u64(out, p.h_pad as u64);
    put_u64(out, p.l_pad as u64);
    put_u64(out, p.tile.e_p as u64);
    put_u64(out, p.tile.h_p as u64);
    put_u64(out, p.tile.l_p as u64);
    out.push(match p.bits {
        WeightBits::Int8 => BITS_INT8,
        WeightBits::Int4 => BITS_INT4,
    });
    out.push(u8::from(q.bias.is_some()));
    put_u64(out, p.data.len() as u64);
    out.extend_from_slice(&p.data);
    // (scale, bias) pairs and row sums: f32/i32 bits preserved exactly, so
    // deserialization is bit-identical, not merely numerically close.
    put_u64(out, p.params.len() as u64);
    for pr in &p.params {
        out.extend_from_slice(&pr.scale.to_le_bytes());
        out.extend_from_slice(&pr.bias.to_le_bytes());
    }
    put_u64(out, p.row_sums.len() as u64);
    for &s in &p.row_sums {
        out.extend_from_slice(&s.to_le_bytes());
    }
    if let Some(b) = &q.bias {
        put_f32_slice(out, b);
    }
}

/// Bounded little-endian reader over a blob.
struct Cursor<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> std::io::Result<&'a [u8]> {
        let end = self
            .off
            .checked_add(n)
            .ok_or_else(|| corrupt("offset overflow"))?;
        if end > self.buf.len() {
            return Err(corrupt("blob truncated"));
        }
        let s = &self.buf[self.off..end];
        self.off = end;
        Ok(s)
    }

    fn u8(&mut self) -> std::io::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u64(&mut self) -> std::io::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn len_prefix(&mut self) -> std::io::Result<usize> {
        usize::try_from(self.u64()?).map_err(|_| corrupt("length prefix too large"))
    }

    /// A u64 dimension field, checked into usize (fails cleanly on 32-bit
    /// hosts instead of wrapping).
    fn dim(&mut self) -> std::io::Result<usize> {
        usize::try_from(self.u64()?).map_err(|_| corrupt("dimension too large"))
    }

    fn f32_slice(&mut self) -> std::io::Result<Vec<f32>> {
        let n = self.len_prefix()?;
        let nbytes = n.checked_mul(4).ok_or_else(|| corrupt("f32 slice overflow"))?;
        let raw = self.take(nbytes)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

fn get_qlinear(c: &mut Cursor) -> std::io::Result<QLinear> {
    match c.u8()? {
        LAYOUT_CPU_TILE => {}
        LAYOUT_GPU_IMAGE => {
            return Err(corrupt("GPU-image record where a CPU-tiled record was expected"))
        }
        other => return Err(corrupt(&format!("unknown layout key {other}"))),
    }
    let h = c.dim()?;
    let l = c.dim()?;
    let h_pad = c.dim()?;
    let l_pad = c.dim()?;
    let tile = TileConfig { e_p: c.dim()?, h_p: c.dim()?, l_p: c.dim()? };
    let bits = match c.u8()? {
        BITS_INT8 => WeightBits::Int8,
        BITS_INT4 => WeightBits::Int4,
        other => return Err(corrupt(&format!("unknown bits code {other}"))),
    };
    let has_bias = c.u8()? != 0;
    let dlen = c.len_prefix()?;
    let data = c.take(dlen)?.to_vec();
    let np = c.len_prefix()?;
    let praw = c.take(np.checked_mul(8).ok_or_else(|| corrupt("params overflow"))?)?;
    let params: Vec<AsymParams> = praw
        .chunks_exact(8)
        .map(|ch| AsymParams {
            scale: f32::from_le_bytes(ch[0..4].try_into().unwrap()),
            bias: f32::from_le_bytes(ch[4..8].try_into().unwrap()),
        })
        .collect();
    let nr = c.len_prefix()?;
    let rraw = c.take(nr.checked_mul(4).ok_or_else(|| corrupt("row sums overflow"))?)?;
    let row_sums: Vec<i32> = rraw
        .chunks_exact(4)
        .map(|ch| i32::from_le_bytes(ch.try_into().unwrap()))
        .collect();
    let bias = if has_bias { Some(c.f32_slice()?) } else { None };
    Ok(QLinear {
        packed: PackedWeights {
            h,
            l,
            h_pad,
            l_pad,
            tile,
            bits,
            data,
            params,
            row_sums,
        },
        bias,
    })
}

/// Serialize a GPU-layout weight image ([l/32, h, 32] packed nibbles —
/// see `reorder::gpu_layout`) to a relocatable, layout-keyed blob. Same
/// container discipline as the CPU records, so GPU tensors can ride the
/// same flash device and residency arena.
pub fn gpu_image_to_blob(img: &GpuWeightImage) -> Vec<u8> {
    let mut out = Vec::with_capacity(1 + 24 + 8 + img.data.len());
    out.push(LAYOUT_GPU_IMAGE);
    // u64 dims: lossless on the writer, checked on the reader (see
    // `put_qlinear`).
    put_u64(&mut out, img.h as u64);
    put_u64(&mut out, img.l as u64);
    put_u64(&mut out, img.l_pad as u64);
    put_u64(&mut out, img.data.len() as u64);
    out.extend_from_slice(&img.data);
    out
}

/// Inverse of [`gpu_image_to_blob`]; bit-exact, and rejects CPU-tiled
/// records (the layout key is the backend contract).
pub fn gpu_image_from_blob(buf: &[u8]) -> std::io::Result<GpuWeightImage> {
    let mut c = Cursor { buf, off: 0 };
    match c.u8()? {
        LAYOUT_GPU_IMAGE => {}
        LAYOUT_CPU_TILE => {
            return Err(corrupt("CPU-tiled record where a GPU-image record was expected"))
        }
        other => return Err(corrupt(&format!("unknown layout key {other}"))),
    }
    let h = c.dim()?;
    let l = c.dim()?;
    let l_pad = c.dim()?;
    let dlen = c.len_prefix()?;
    let data = c.take(dlen)?.to_vec();
    if c.off != buf.len() {
        return Err(corrupt("trailing bytes"));
    }
    let expect = l_pad
        .checked_div(crate::reorder::gpu_layout::GPU_LP)
        .unwrap_or(0)
        .saturating_mul(h)
        .saturating_mul(crate::reorder::gpu_layout::GPU_LP)
        / 2;
    if l_pad % crate::reorder::gpu_layout::GPU_LP != 0 || data.len() != expect {
        return Err(corrupt("GPU image dimensions inconsistent with payload"));
    }
    Ok(GpuWeightImage { h, l, l_pad, data })
}

impl LayerWeights {
    /// Serialize to a relocatable blob (offsets are all internal): the
    /// exact packed bytes, quant params, row sums, biases and norms.
    pub fn to_blob(&self) -> Vec<u8> {
        let mut out = Vec::new();
        for q in [
            &self.wq, &self.wk, &self.wv, &self.wo, &self.gate, &self.up, &self.down,
        ] {
            put_qlinear(&mut out, q);
        }
        put_f32_slice(&mut out, &self.ln1);
        put_f32_slice(&mut out, &self.ln2);
        out
    }

    /// Inverse of [`to_blob`](Self::to_blob); bit-exact.
    pub fn from_blob(buf: &[u8]) -> std::io::Result<LayerWeights> {
        let mut c = Cursor { buf, off: 0 };
        let wq = get_qlinear(&mut c)?;
        let wk = get_qlinear(&mut c)?;
        let wv = get_qlinear(&mut c)?;
        let wo = get_qlinear(&mut c)?;
        let gate = get_qlinear(&mut c)?;
        let up = get_qlinear(&mut c)?;
        let down = get_qlinear(&mut c)?;
        let ln1 = c.f32_slice()?;
        let ln2 = c.f32_slice()?;
        if c.off != buf.len() {
            return Err(corrupt("trailing bytes"));
        }
        Ok(LayerWeights {
            wq,
            wk,
            wv,
            wo,
            gate,
            up,
            down,
            ln1,
            ln2,
        })
    }
}

// ---------------------------------------------------------------------------
// The residency arena.

/// Residency counters + snapshot gauges, surfaced through `EngineMetrics`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WeightResidencyMetrics {
    /// Arena-accounted DRAM bytes of resident layer blobs (snapshot).
    pub resident_bytes: usize,
    /// Total packed bytes across all layers (what `usize::MAX` budget holds).
    pub packed_bytes: usize,
    /// Synchronous (demand) blob fetches — misses the prefetcher didn't cover.
    pub demand_fetches: u64,
    /// Layers dropped from the arena to get back under budget.
    pub evictions: u64,
    /// Async prefetches issued.
    pub prefetch_issued: u64,
    /// `layer()` calls satisfied by a landed prefetch.
    pub prefetch_hits: u64,
    /// `layer()` calls that had to wait for an in-flight prefetch.
    pub prefetch_stalls: u64,
    /// Deepest lookahead a single `prefetch_ahead` call issued: how many
    /// upcoming layers the budget let the engine keep in flight at once
    /// (0 = never constrained enough to prefetch, 1 = classic one-ahead).
    pub prefetch_depth: usize,
    /// Modeled flash seconds spent reading layer blobs (demand + prefetch).
    pub flash_read_s: f64,
    /// Decode tokens generated against this store (the model notes one per
    /// decode row). Denominator of
    /// [`fetches_per_token`](Self::fetches_per_token) — the batched-decode
    /// amortization gauge.
    pub tokens_generated: u64,
    /// Flash blob fetches attributed to the decode phase (the model
    /// snapshots the fetch counters around each walk), so the gauge is
    /// not polluted by load warm-up or prefill traffic. A mixed tick
    /// (prefill chunks fused with decode rows) splits its shared walk's
    /// delta between here and `prefill_fetches` proportionally to the
    /// tick's decode/prefill row counts — each row drove the same layer
    /// walk once.
    pub decode_fetches: u64,
    /// Prompt tokens prefilled against this store (chunked or monolithic).
    /// Denominator of
    /// [`fetches_per_prompt_token`](Self::fetches_per_prompt_token).
    pub prompt_tokens_prefilled: u64,
    /// Flash blob fetches attributed to the prefill phase — the traffic
    /// fused batched prefill amortizes across concurrently admitted
    /// prompts. Pure-prefill walks land here in full; mixed ticks
    /// contribute their row-proportional share (the remainder of the
    /// split charged to `decode_fetches`).
    pub prefill_fetches: u64,
}

impl WeightResidencyMetrics {
    /// True when the budget actually constrained residency after load —
    /// any post-load flash traffic or eviction.
    pub fn under_pressure(&self) -> bool {
        self.demand_fetches > 0 || self.evictions > 0 || self.prefetch_issued > 0
    }

    /// All blob reads that hit flash: demand misses plus issued prefetches
    /// (a layer is read exactly once per fetch, whichever path pays).
    pub fn total_fetches(&self) -> u64 {
        self.demand_fetches + self.prefetch_issued
    }

    /// Decode-phase flash blob fetches per generated decode token — the
    /// quantity fused batched decode drives down: a sequential round over
    /// B sessions pays ≈ layers fetches per token under a tight budget,
    /// one fused round pays ≈ layers / B. Load warm-up and prefill fetches
    /// are excluded (see `decode_fetches`). 0.0 until any decode token was
    /// generated.
    pub fn fetches_per_token(&self) -> f64 {
        if self.tokens_generated == 0 {
            0.0
        } else {
            self.decode_fetches as f64 / self.tokens_generated as f64
        }
    }

    /// Pure-prefill flash blob fetches per prompt token — the quantity
    /// fused batched prefill drives down: admitting N short prompts one
    /// walk at a time pays ≈ layers fetches per prompt under a tight
    /// budget; one shared walk pays ≈ layers for all N. 0.0 until any
    /// prompt token was prefilled.
    pub fn fetches_per_prompt_token(&self) -> f64 {
        if self.prompt_tokens_prefilled == 0 {
            0.0
        } else {
            self.prefill_fetches as f64 / self.prompt_tokens_prefilled as f64
        }
    }
}

#[derive(Clone, Copy)]
struct Slot {
    off: u64,
    len: usize,
}

struct Resident {
    layer: Arc<LayerWeights>,
    /// LRU stamp (monotone; larger = more recently used).
    tick: u64,
    /// Inserted by prefetch and not yet claimed by a `layer()` call.
    unclaimed_prefetch: bool,
}

#[derive(Default)]
struct State {
    resident: HashMap<usize, Resident>,
    in_flight: HashSet<usize>,
    /// Blob bytes of the layers in `in_flight` (budget-aware prefetch
    /// depth accounts these against the budget before issuing more).
    in_flight_bytes: usize,
    tick: u64,
    resident_bytes: usize,
    demand_fetches: u64,
    evictions: u64,
    prefetch_issued: u64,
    prefetch_hits: u64,
    prefetch_stalls: u64,
    prefetch_depth: usize,
    flash_read_s: f64,
    tokens_generated: u64,
    decode_fetches: u64,
    prompt_tokens_prefilled: u64,
    prefill_fetches: u64,
}

struct Shared {
    state: Mutex<State>,
    cv: Condvar,
}

fn fetch_blob(flash: &FlashSim, slot: Slot) -> std::io::Result<(Arc<LayerWeights>, f64)> {
    let mut buf = vec![0u8; slot.len];
    let t = flash.read_at(slot.off, &mut buf)?;
    Ok((Arc::new(LayerWeights::from_blob(&buf)?), t))
}

/// Insert a fetched layer and LRU-evict others until back under budget.
/// The just-inserted layer is never the victim, so the active layer stays
/// resident even when it alone exceeds the budget.
fn insert_resident(
    st: &mut State,
    slots: &[Slot],
    budget: usize,
    li: usize,
    lw: Arc<LayerWeights>,
    from_prefetch: bool,
) {
    st.tick += 1;
    let tick = st.tick;
    if st
        .resident
        .insert(li, Resident { layer: lw, tick, unclaimed_prefetch: from_prefetch })
        .is_none()
    {
        st.resident_bytes += slots[li].len;
    }
    while st.resident_bytes > budget && st.resident.len() > 1 {
        let victim = st
            .resident
            .iter()
            .filter(|(&k, _)| k != li)
            .min_by_key(|(_, r)| r.tick)
            .map(|(&k, _)| k);
        let Some(v) = victim else { break };
        st.resident.remove(&v);
        st.resident_bytes -= slots[v].len;
        st.evictions += 1;
    }
}

/// The byte-budgeted DRAM arena over flash-resident layer blobs. Cheap to
/// clone (all state is shared); `layer()` takes `&self`, so the stateless
/// forward passes need no mutable access.
#[derive(Clone)]
pub struct WeightStore {
    flash: Arc<FlashSim>,
    slots: Arc<Vec<Slot>>,
    /// GPU-layout tensors (name → blob slot), stored on the same flash
    /// device with the GPU layout key. Served on demand, uncached: a real
    /// GPU backend uploads each image once at kernel-graph build, so the
    /// DRAM arena (sized for the per-tick CPU layer walk) never holds
    /// them.
    gpu: Arc<Vec<(String, Slot)>>,
    budget: usize,
    shared: Arc<Shared>,
}

impl WeightStore {
    /// Fetch layer `li` for use, waiting on an in-flight prefetch or
    /// reading the blob synchronously on a miss. The returned `Arc` stays
    /// valid even if the layer is evicted mid-use.
    pub fn layer(&self, li: usize) -> std::io::Result<Arc<LayerWeights>> {
        if li >= self.slots.len() {
            return Err(corrupt(&format!("layer {li} out of range {}", self.slots.len())));
        }
        let shared = &*self.shared;
        let mut st = shared.state.lock().unwrap();
        let mut counted_stall = false;
        loop {
            if st.resident.contains_key(&li) {
                st.tick += 1;
                let tick = st.tick;
                let mut hit = false;
                let arc = {
                    let r = st.resident.get_mut(&li).unwrap();
                    if r.unclaimed_prefetch {
                        r.unclaimed_prefetch = false;
                        // A claim that had to wait already counted as a
                        // stall; hit and stall are disjoint outcomes.
                        hit = !counted_stall;
                    }
                    r.tick = tick;
                    r.layer.clone()
                };
                if hit {
                    st.prefetch_hits += 1;
                }
                return Ok(arc);
            }
            if st.in_flight.contains(&li) {
                if !counted_stall {
                    st.prefetch_stalls += 1;
                    counted_stall = true;
                }
                st = shared.cv.wait(st).unwrap();
                continue;
            }
            break;
        }
        st.in_flight.insert(li);
        st.in_flight_bytes += self.slots[li].len;
        st.demand_fetches += 1;
        drop(st);
        let res = fetch_blob(&self.flash, self.slots[li]);
        let mut st = shared.state.lock().unwrap();
        st.in_flight.remove(&li);
        st.in_flight_bytes = st.in_flight_bytes.saturating_sub(self.slots[li].len);
        let out = match res {
            Ok((lw, t)) => {
                st.flash_read_s += t;
                insert_resident(&mut st, &self.slots, self.budget, li, lw.clone(), false);
                Ok(lw)
            }
            Err(e) => Err(e),
        };
        drop(st);
        shared.cv.notify_all();
        out
    }

    /// Begin loading layer `li` on `worker` unless it is already resident
    /// or in flight. Returns immediately; a later `layer(li)` either hits
    /// the landed copy or waits on the one read — never issues a second.
    /// Prefetch errors are swallowed (the demand path retries and surfaces
    /// them on the calling thread).
    ///
    /// When the budget cannot hold this blob *and* the largest other blob
    /// at once, prefetching is counter-productive: the demand insert of
    /// the current layer would evict the never-claimed prefetched one (or
    /// vice versa), doubling flash reads instead of hiding them — so those
    /// budgets skip prefetch and run pure demand paging.
    ///
    /// Returns true when the layer is *covered* (already resident, already
    /// in flight, or a fetch was just issued); false when out of range or
    /// skipped by the anti-thrash guard.
    pub fn prefetch(&self, worker: &BackgroundWorker, li: usize) -> bool {
        if li >= self.slots.len() {
            return false;
        }
        let largest_other = self
            .slots
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != li)
            .map(|(_, s)| s.len)
            .max()
            .unwrap_or(0);
        if self.budget < self.slots[li].len + largest_other {
            return false;
        }
        {
            let mut st = self.shared.state.lock().unwrap();
            if st.resident.contains_key(&li) || st.in_flight.contains(&li) {
                return true;
            }
            st.in_flight.insert(li);
            st.in_flight_bytes += self.slots[li].len;
            st.prefetch_issued += 1;
        }
        let flash = self.flash.clone();
        let slots = self.slots.clone();
        let shared = self.shared.clone();
        let budget = self.budget;
        let enqueued = worker.submit(move || {
            let res = fetch_blob(&flash, slots[li]);
            let mut st = shared.state.lock().unwrap();
            st.in_flight.remove(&li);
            st.in_flight_bytes = st.in_flight_bytes.saturating_sub(slots[li].len);
            if let Ok((lw, t)) = res {
                st.flash_read_s += t;
                insert_resident(&mut st, &slots, budget, li, lw, true);
            }
            drop(st);
            shared.cv.notify_all();
        });
        if !enqueued {
            // The worker thread is gone; roll back the in-flight mark so
            // `layer()` demand-fetches instead of waiting forever.
            let mut st = self.shared.state.lock().unwrap();
            st.in_flight.remove(&li);
            st.in_flight_bytes = st.in_flight_bytes.saturating_sub(self.slots[li].len);
            st.prefetch_issued -= 1;
            drop(st);
            self.shared.cv.notify_all();
            return false;
        }
        true
    }

    /// Budget-aware multi-layer prefetch: cover layers `start, start+1, …`
    /// while the **upcoming working set** — the current layer's blob
    /// (`start-1`), blobs already in flight, and the blobs covered by this
    /// call — fits the budget. (The raw `resident_bytes` gauge cannot gate
    /// depth: a steady-state LRU arena is always full; what matters is
    /// that the layers being prefetched plus the one being served fit,
    /// with LRU eviction freeing the just-used layers as fetches land.)
    ///
    /// The first layer ahead follows [`prefetch`](Self::prefetch)'s rules
    /// exactly (including its anti-thrash guard), so at any budget this is
    /// at least as deep as PR 2's classic one-ahead; a generous budget
    /// buys deeper lookahead, hiding more flash time on deep models. A
    /// budget that holds every layer issues nothing (all layers stay
    /// resident). Returns the depth covered this call; the deepest depth
    /// is surfaced as [`WeightResidencyMetrics::prefetch_depth`].
    pub fn prefetch_ahead(&self, worker: &BackgroundWorker, start: usize) -> usize {
        if self.budget >= self.total_packed_bytes() {
            return 0; // everything resident forever: nothing to hide
        }
        let current_len = match start.checked_sub(1) {
            Some(cur) if cur < self.slots.len() => self.slots[cur].len,
            _ => 0,
        };
        // Snapshot in-flight state once: those bytes are already committed,
        // and an upcoming layer that is in this set must not be counted a
        // second time when the loop walks over it.
        let (in_flight_bytes, in_flight_ids) = {
            let st = self.shared.state.lock().unwrap();
            (st.in_flight_bytes, st.in_flight.clone())
        };
        let mut working = current_len.saturating_add(in_flight_bytes);
        let mut depth = 0usize;
        for li in start..self.slots.len() {
            let add = if in_flight_ids.contains(&li) { 0 } else { self.slots[li].len };
            if depth > 0 && working.saturating_add(add) > self.budget {
                break;
            }
            if !self.prefetch(worker, li) {
                break;
            }
            working = working.saturating_add(add);
            depth += 1;
        }
        let mut st = self.shared.state.lock().unwrap();
        st.prefetch_depth = st.prefetch_depth.max(depth);
        depth
    }

    pub fn metrics(&self) -> WeightResidencyMetrics {
        let st = self.shared.state.lock().unwrap();
        WeightResidencyMetrics {
            resident_bytes: st.resident_bytes,
            packed_bytes: self.total_packed_bytes(),
            demand_fetches: st.demand_fetches,
            evictions: st.evictions,
            prefetch_issued: st.prefetch_issued,
            prefetch_hits: st.prefetch_hits,
            prefetch_stalls: st.prefetch_stalls,
            prefetch_depth: st.prefetch_depth,
            flash_read_s: st.flash_read_s,
            tokens_generated: st.tokens_generated,
            decode_fetches: st.decode_fetches,
            prompt_tokens_prefilled: st.prompt_tokens_prefilled,
            prefill_fetches: st.prefill_fetches,
        }
    }

    /// Record decode work: `tokens` generated rows and the decode share of
    /// the walk's fetch-counter delta (the model snapshots
    /// [`total_fetches`](WeightResidencyMetrics::total_fetches) around the
    /// walk; a mixed tick passes its row-proportional share). Feeds the
    /// decode-only fetches-per-token gauge that makes batched-decode
    /// weight amortization observable.
    pub fn note_decode_pass(&self, tokens: u64, fetches: u64) {
        let mut st = self.shared.state.lock().unwrap();
        st.tokens_generated += tokens;
        st.decode_fetches += fetches;
    }

    /// Record prefill work: `prompt_tokens` prefilled this walk and the
    /// prefill share of the walk's fetch-counter delta (the full delta for
    /// pure-prefill walks; the row-proportional remainder for mixed
    /// ticks). Feeds the fetches-per-prompt-token gauge that makes fused
    /// batched prefill's weight amortization observable.
    pub fn note_prefill_pass(&self, prompt_tokens: u64, fetches: u64) {
        let mut st = self.shared.state.lock().unwrap();
        st.prompt_tokens_prefilled += prompt_tokens;
        st.prefill_fetches += fetches;
    }

    /// Arena-accounted resident bytes (snapshot).
    pub fn resident_bytes(&self) -> usize {
        self.shared.state.lock().unwrap().resident_bytes
    }

    /// Resident layer count (snapshot).
    pub fn resident_layers(&self) -> usize {
        self.shared.state.lock().unwrap().resident.len()
    }

    /// Sum of all layer blob sizes.
    pub fn total_packed_bytes(&self) -> usize {
        self.slots.iter().map(|s| s.len).sum()
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget
    }

    pub fn num_layers(&self) -> usize {
        self.slots.len()
    }

    /// Fetch a GPU-layout tensor by name from flash (bit-exact; modeled
    /// read time lands in `flash_read_s` like any other blob fetch).
    pub fn gpu_image(&self, name: &str) -> std::io::Result<GpuWeightImage> {
        let slot = self
            .gpu
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| *s)
            .ok_or_else(|| {
                std::io::Error::new(
                    ErrorKind::NotFound,
                    format!("no GPU-layout tensor named {name:?}"),
                )
            })?;
        let mut buf = vec![0u8; slot.len];
        let t = self.flash.read_at(slot.off, &mut buf)?;
        self.shared.state.lock().unwrap().flash_read_s += t;
        gpu_image_from_blob(&buf)
    }

    /// Names of the GPU-layout tensors this store can serve.
    pub fn gpu_image_names(&self) -> Vec<String> {
        self.gpu.iter().map(|(n, _)| n.clone()).collect()
    }
}

/// Builds a [`WeightStore`] one layer at a time, spilling the oldest seeded
/// layers as the budget fills so load-time DRAM stays ≈ budget + one layer.
pub struct WeightStoreBuilder {
    flash: Arc<FlashSim>,
    budget: usize,
    slots: Vec<Slot>,
    gpu: Vec<(String, Slot)>,
    seed: VecDeque<(usize, Arc<LayerWeights>)>,
    seed_bytes: usize,
}

impl WeightStoreBuilder {
    pub fn new(flash: Arc<FlashSim>, budget_bytes: usize) -> Self {
        WeightStoreBuilder {
            flash,
            budget: budget_bytes,
            slots: Vec::new(),
            gpu: Vec::new(),
            seed: VecDeque::new(),
            seed_bytes: 0,
        }
    }

    /// Serialize `layer` to flash and (budget permitting) keep it warm.
    /// Returns the layer index.
    pub fn push_layer(&mut self, layer: LayerWeights) -> std::io::Result<usize> {
        let blob = layer.to_blob();
        let off = self.flash.append(&blob)?;
        let li = self.slots.len();
        self.slots.push(Slot { off, len: blob.len() });
        self.seed.push_back((li, Arc::new(layer)));
        self.seed_bytes += blob.len();
        while self.seed_bytes > self.budget && self.seed.len() > 1 {
            let (i, _) = self.seed.pop_front().unwrap();
            self.seed_bytes -= self.slots[i].len;
        }
        Ok(li)
    }

    /// Serialize a GPU-layout tensor to flash under `name` (layout-keyed
    /// blob; see [`gpu_image_to_blob`]). GPU tensors never occupy the
    /// DRAM seed budget — they are served straight from flash on demand.
    pub fn push_gpu_image(
        &mut self,
        name: &str,
        img: &GpuWeightImage,
    ) -> std::io::Result<()> {
        let blob = gpu_image_to_blob(img);
        let off = self.flash.append(&blob)?;
        self.gpu.push((name.to_string(), Slot { off, len: blob.len() }));
        Ok(())
    }

    pub fn finish(self) -> WeightStore {
        let mut state = State::default();
        for (i, lw) in self.seed {
            state.tick += 1;
            let tick = state.tick;
            state
                .resident
                .insert(i, Resident { layer: lw, tick, unclaimed_prefetch: false });
            state.resident_bytes += self.slots[i].len;
        }
        WeightStore {
            flash: self.flash,
            slots: Arc::new(self.slots),
            gpu: Arc::new(self.gpu),
            budget: self.budget,
            shared: Arc::new(Shared { state: Mutex::new(state), cv: Condvar::new() }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::SocProfile;
    use crate::quant::QuantizedMatrix;
    use crate::util::rng::Rng;

    const TILE: TileConfig = TileConfig { e_p: 4, h_p: 8, l_p: 4 };

    fn flash() -> Arc<FlashSim> {
        Arc::new(FlashSim::temp(SocProfile::snapdragon_8gen3().flash).unwrap())
    }

    fn qlin(rng: &mut Rng, n: usize, k: usize, bits: WeightBits, bias: bool) -> QLinear {
        let w = rng.normal_vec(n * k);
        let qm = QuantizedMatrix::from_f32(&w, n, k, bits);
        let b = bias.then(|| rng.normal_vec(n));
        QLinear::new(&qm, TILE, b)
    }

    /// A small but structurally complete layer. Deterministic in `seed`.
    fn layer(seed: u64) -> LayerWeights {
        let mut rng = Rng::new(seed);
        let (h, kvd, inter) = (16usize, 8usize, 24usize);
        LayerWeights {
            wq: qlin(&mut rng, h, h, WeightBits::Int8, true),
            wk: qlin(&mut rng, kvd, h, WeightBits::Int8, true),
            wv: qlin(&mut rng, kvd, h, WeightBits::Int8, true),
            wo: qlin(&mut rng, h, h, WeightBits::Int8, false),
            gate: qlin(&mut rng, inter, h, WeightBits::Int4, false),
            up: qlin(&mut rng, inter, h, WeightBits::Int4, false),
            down: qlin(&mut rng, h, inter, WeightBits::Int4, false),
            ln1: rng.normal_vec(h),
            ln2: rng.normal_vec(h),
        }
    }

    fn qlinear_eq(a: &QLinear, b: &QLinear) {
        assert_eq!(a.packed.h, b.packed.h);
        assert_eq!(a.packed.l, b.packed.l);
        assert_eq!(a.packed.h_pad, b.packed.h_pad);
        assert_eq!(a.packed.l_pad, b.packed.l_pad);
        assert_eq!(a.packed.tile, b.packed.tile);
        assert_eq!(a.packed.bits, b.packed.bits);
        assert_eq!(a.packed.data, b.packed.data);
        assert_eq!(a.packed.row_sums, b.packed.row_sums);
        assert_eq!(a.packed.params.len(), b.packed.params.len());
        for (x, y) in a.packed.params.iter().zip(&b.packed.params) {
            assert_eq!(x.scale.to_bits(), y.scale.to_bits());
            assert_eq!(x.bias.to_bits(), y.bias.to_bits());
        }
        match (&a.bias, &b.bias) {
            (None, None) => {}
            (Some(x), Some(y)) => {
                assert_eq!(x.len(), y.len());
                for (p, q) in x.iter().zip(y) {
                    assert_eq!(p.to_bits(), q.to_bits());
                }
            }
            _ => panic!("bias presence mismatch"),
        }
    }

    #[test]
    fn blob_roundtrip_is_bit_exact() {
        let a = layer(3);
        let blob = a.to_blob();
        let b = LayerWeights::from_blob(&blob).unwrap();
        for (x, y) in [
            (&a.wq, &b.wq),
            (&a.wk, &b.wk),
            (&a.wv, &b.wv),
            (&a.wo, &b.wo),
            (&a.gate, &b.gate),
            (&a.up, &b.up),
            (&a.down, &b.down),
        ] {
            qlinear_eq(x, y);
        }
        assert_eq!(a.ln1, b.ln1);
        assert_eq!(a.ln2, b.ln2);
        // And the forward outputs are bitwise identical.
        let mut rng = Rng::new(9);
        let x = rng.normal_vec(2 * a.wq.in_features());
        let mut out_a = vec![0f32; 2 * a.wq.out_features()];
        let mut out_b = out_a.clone();
        a.wq.forward(&x, 2, &mut out_a);
        b.wq.forward(&x, 2, &mut out_b);
        assert_eq!(out_a, out_b);
    }

    #[test]
    fn corrupt_blob_is_clean_error() {
        let blob = layer(4).to_blob();
        assert!(LayerWeights::from_blob(&blob[..blob.len() / 2]).is_err());
        let mut trailing = blob.clone();
        trailing.push(0);
        assert!(LayerWeights::from_blob(&trailing).is_err());
    }

    fn gpu_image(seed: u64, h: usize, l: usize) -> crate::reorder::gpu_layout::GpuWeightImage {
        let mut rng = Rng::new(seed);
        let w4: Vec<u8> = (0..h * l).map(|_| rng.below(16) as u8).collect();
        crate::reorder::gpu_layout::pack_gpu_image(&w4, h, l)
    }

    #[test]
    fn gpu_image_blob_roundtrip_is_bit_exact() {
        for (h, l) in [(8usize, 32usize), (17, 40), (4, 96)] {
            let img = gpu_image(h as u64 * 31 + l as u64, h, l);
            let blob = gpu_image_to_blob(&img);
            let back = gpu_image_from_blob(&blob).unwrap();
            assert_eq!(back.h, img.h);
            assert_eq!(back.l, img.l);
            assert_eq!(back.l_pad, img.l_pad);
            assert_eq!(back.data, img.data, "{h}x{l}");
        }
    }

    #[test]
    fn blob_dims_are_u64_and_forged_dims_fail_cleanly() {
        // Regression: dimensions used to be written with `as u32`, which
        // silently truncates. They now ride as lossless u64 fields...
        let img = gpu_image(3, 8, 32);
        let blob = gpu_image_to_blob(&img);
        assert_eq!(blob.len(), 1 + 3 * 8 + 8 + img.data.len());
        // ...and a forged header with an absurd dimension is a clean
        // decode error (consistency check), never a wrapped size.
        let mut bad = blob.clone();
        bad[1..9].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(gpu_image_from_blob(&bad).is_err());
    }

    #[test]
    fn layout_keys_keep_backends_from_misreading_blobs() {
        // A GPU-image record fed to the CPU-tile reader (and vice versa)
        // is a loud InvalidData error, never a silently misinterpreted
        // tile order.
        let gpu_blob = gpu_image_to_blob(&gpu_image(5, 8, 32));
        assert!(LayerWeights::from_blob(&gpu_blob).is_err());
        let cpu_blob = layer(6).to_blob();
        assert!(gpu_image_from_blob(&cpu_blob).is_err());
        // Unknown future layout keys are rejected too.
        let mut bad = gpu_blob.clone();
        bad[0] = 7;
        assert!(gpu_image_from_blob(&bad).is_err());
    }

    #[test]
    fn arena_serves_gpu_images_alongside_cpu_layers() {
        let mut b = WeightStoreBuilder::new(flash(), usize::MAX);
        b.push_layer(layer(200)).unwrap();
        let img = gpu_image(9, 16, 64);
        b.push_gpu_image("L0.gate.gpu", &img).unwrap();
        b.push_layer(layer(201)).unwrap();
        let store = b.finish();
        // CPU layers are untouched by the GPU side table.
        assert_eq!(store.num_layers(), 2);
        store.layer(1).unwrap();
        // The GPU tensor comes back bit-exact, with its layout properties
        // intact (what the modeled OpenCL path needs).
        let got = store.gpu_image("L0.gate.gpu").unwrap();
        assert_eq!(got.data, img.data);
        assert!(got.loads_are_128bit_aligned());
        assert!(got.work_items_coalesce());
        assert_eq!(store.gpu_image_names(), vec!["L0.gate.gpu".to_string()]);
        assert!(store.gpu_image("nope").is_err());
        // GPU fetches pay modeled flash time like any other blob read.
        assert!(store.metrics().flash_read_s > 0.0);
    }

    fn store_with(layers: u64, budget: usize) -> WeightStore {
        let mut b = WeightStoreBuilder::new(flash(), budget);
        for s in 0..layers {
            b.push_layer(layer(100 + s)).unwrap();
        }
        b.finish()
    }

    #[test]
    fn unlimited_budget_keeps_everything_resident() {
        let store = store_with(4, usize::MAX);
        assert_eq!(store.resident_layers(), 4);
        assert_eq!(store.resident_bytes(), store.total_packed_bytes());
        for li in 0..4 {
            store.layer(li).unwrap();
        }
        let m = store.metrics();
        assert_eq!(m.demand_fetches, 0);
        assert_eq!(m.evictions, 0);
        assert!(!m.under_pressure());
    }

    #[test]
    fn tight_budget_evicts_lru_and_refetches_bit_exact() {
        let unlimited = store_with(4, usize::MAX);
        let per_layer = unlimited.total_packed_bytes() / 4;
        let store = store_with(4, per_layer * 2);
        assert!(store.resident_layers() <= 2, "seed respects the budget");
        // Touch all layers round-robin twice: every miss refetches from
        // flash; contents must match the never-evicted copies bit-for-bit.
        for round in 0..2 {
            for li in 0..4 {
                let a = store.layer(li).unwrap();
                let b = unlimited.layer(li).unwrap();
                assert_eq!(a.to_blob(), b.to_blob(), "round {round} layer {li}");
                assert!(store.resident_bytes() <= per_layer * 2);
            }
        }
        let m = store.metrics();
        assert!(m.demand_fetches > 0);
        assert!(m.evictions > 0, "{m:?}");
        assert!(m.flash_read_s > 0.0);
        assert!(m.under_pressure());
    }

    #[test]
    fn lru_keeps_the_recently_used_layer() {
        let unlimited = store_with(3, usize::MAX);
        let per_layer = unlimited.total_packed_bytes() / 3;
        let store = store_with(3, per_layer * 2);
        store.layer(0).unwrap();
        store.layer(1).unwrap();
        let before = store.metrics().evictions;
        // 0 and 1 are the two resident layers; touching 2 must evict the
        // least recently used (0), so re-touching 1 stays a hit.
        store.layer(2).unwrap();
        assert_eq!(store.metrics().evictions, before + 1);
        let fetches = store.metrics().demand_fetches;
        store.layer(1).unwrap();
        assert_eq!(store.metrics().demand_fetches, fetches, "layer 1 was still resident");
    }

    #[test]
    fn prefetch_lands_and_is_claimed_without_demand_fetch() {
        let unlimited = store_with(3, usize::MAX);
        let per_layer = unlimited.total_packed_bytes() / 3;
        // Two layers fit: room for a prefetched blob next to the active one.
        let store = store_with(3, per_layer * 2);
        let worker = BackgroundWorker::new("test-prefetch");
        store.prefetch(&worker, 0);
        // layer(0) either finds the landed copy (hit) or waits for the
        // in-flight read (stall) — never a second read.
        let got = store.layer(0).unwrap();
        assert_eq!(got.to_blob(), unlimited.layer(0).unwrap().to_blob());
        let m = store.metrics();
        assert_eq!(m.prefetch_issued, 1);
        assert_eq!(m.demand_fetches, 0, "{m:?}");
        assert_eq!(m.prefetch_hits + m.prefetch_stalls, 1, "{m:?}");
        // Prefetching a resident layer is a no-op.
        store.prefetch(&worker, 0);
        assert_eq!(store.metrics().prefetch_issued, 1);
    }

    #[test]
    fn prefetch_skipped_when_budget_cannot_hold_two_blobs() {
        // Below two blobs, prefetch would thrash (demand insert of the
        // current layer evicts the never-claimed next one): pure demand
        // paging instead, still correct.
        let unlimited = store_with(3, usize::MAX);
        let per_layer = unlimited.total_packed_bytes() / 3;
        let store = store_with(3, per_layer);
        let worker = BackgroundWorker::new("test-prefetch-skip");
        store.prefetch(&worker, 0);
        assert_eq!(store.metrics().prefetch_issued, 0, "skipped, not issued");
        let got = store.layer(0).unwrap();
        assert_eq!(got.to_blob(), unlimited.layer(0).unwrap().to_blob());
        let m = store.metrics();
        assert_eq!(m.demand_fetches, 1, "{m:?}");
        assert_eq!(m.prefetch_hits + m.prefetch_stalls, 0, "{m:?}");
    }

    #[test]
    fn prefetch_ahead_depth_scales_with_budget() {
        let unlimited = store_with(6, usize::MAX);
        let per_layer = unlimited.total_packed_bytes() / 6;
        let worker = BackgroundWorker::new("test-prefetch-ahead");

        // Budget for every layer: nothing to prefetch, depth 0.
        let all = store_with(6, usize::MAX);
        assert_eq!(all.prefetch_ahead(&worker, 1), 0);
        assert_eq!(all.metrics().prefetch_depth, 0);

        // Two-blob budget: current + one ahead is all that fits — the
        // classic PR 2 depth.
        let two = store_with(6, per_layer * 2);
        two.layer(0).unwrap();
        let d = two.prefetch_ahead(&worker, 1);
        assert_eq!(d, 1, "{:?}", two.metrics());
        assert_eq!(two.metrics().prefetch_depth, 1);

        // Four-blob budget: current + three ahead fit the working set.
        let four = store_with(6, per_layer * 4);
        let d = four.prefetch_ahead(&worker, 1);
        assert_eq!(d, 3, "{:?}", four.metrics());
        assert_eq!(four.metrics().prefetch_depth, 3);
        // Every covered layer reads back bit-exact.
        for li in 0..6 {
            assert_eq!(
                four.layer(li).unwrap().to_blob(),
                unlimited.layer(li).unwrap().to_blob()
            );
        }

        // Below-two-blob budget: the anti-thrash guard keeps depth at 0.
        let tiny = store_with(6, per_layer);
        assert_eq!(tiny.prefetch_ahead(&worker, 1), 0);
        assert_eq!(tiny.metrics().prefetch_issued, 0);
    }

    #[test]
    fn fetches_per_token_tracks_decode_reads_over_generated_tokens() {
        let unlimited = store_with(4, usize::MAX);
        let per_layer = unlimited.total_packed_bytes() / 4;
        let store = store_with(4, per_layer); // pure demand paging
        assert_eq!(store.metrics().fetches_per_token(), 0.0, "no tokens yet");
        // A "prefill" walk before any decode: its fetches must NOT land in
        // the decode gauge (the model only notes decode passes).
        store.layer(0).unwrap();
        assert_eq!(store.metrics().decode_fetches, 0);
        // One "decode token" walking all 4 layers: 4 demand fetches
        // (nothing resident survives the rotation at a one-layer budget).
        let before = store.metrics().total_fetches();
        for li in 0..4 {
            store.layer(li).unwrap();
        }
        store.note_decode_pass(1, store.metrics().total_fetches() - before);
        let m1 = store.metrics();
        assert_eq!(m1.tokens_generated, 1);
        assert!(m1.decode_fetches >= 3, "{m1:?}");
        assert!(m1.total_fetches() > m1.decode_fetches, "prefill excluded");
        assert_eq!(m1.fetches_per_token(), m1.decode_fetches as f64);
        // A fused 4-row walk: same reads, 4 tokens — per-token cost ÷ 4.
        let mid = store.metrics().total_fetches();
        for li in 0..4 {
            store.layer(li).unwrap();
        }
        store.note_decode_pass(4, store.metrics().total_fetches() - mid);
        let m2 = store.metrics();
        let round2 = m2.decode_fetches - m1.decode_fetches;
        assert!(
            (m2.fetches_per_token() - m2.decode_fetches as f64 / 5.0).abs() < 1e-12,
            "{m2:?}"
        );
        assert!(round2 as f64 / 4.0 < m1.decode_fetches as f64, "amortized");
    }

    #[test]
    fn fetches_per_prompt_token_tracks_prefill_reads() {
        let unlimited = store_with(4, usize::MAX);
        let per_layer = unlimited.total_packed_bytes() / 4;
        let store = store_with(4, per_layer); // pure demand paging
        assert_eq!(store.metrics().fetches_per_prompt_token(), 0.0, "no prompts yet");
        // One 6-token prompt walking all 4 layers (pure prefill walk).
        let before = store.metrics().total_fetches();
        for li in 0..4 {
            store.layer(li).unwrap();
        }
        store.note_prefill_pass(6, store.metrics().total_fetches() - before);
        let m1 = store.metrics();
        assert_eq!(m1.prompt_tokens_prefilled, 6);
        assert!(m1.prefill_fetches >= 3, "{m1:?}");
        assert_eq!(m1.decode_fetches, 0, "prefill traffic stays off the decode gauge");
        assert_eq!(m1.fetches_per_prompt_token(), m1.prefill_fetches as f64 / 6.0);
        // A fused walk shared by 4 such prompts: same reads, 4× the
        // prompt tokens — per-prompt-token cost ÷ 4.
        let mid = store.metrics().total_fetches();
        for li in 0..4 {
            store.layer(li).unwrap();
        }
        store.note_prefill_pass(24, store.metrics().total_fetches() - mid);
        let m2 = store.metrics();
        let round2 = m2.prefill_fetches - m1.prefill_fetches;
        assert!(
            (round2 as f64 / 24.0) < m1.fetches_per_prompt_token(),
            "fused prefill amortizes: {m2:?}"
        );
    }

    #[test]
    fn single_layer_over_budget_still_served() {
        // A budget smaller than one blob: the active layer stays resident
        // anyway (the budget is a target, not a wall) and rotation works.
        let store = store_with(2, 1);
        for li in [0usize, 1, 0, 1] {
            store.layer(li).unwrap();
            assert_eq!(store.resident_layers(), 1);
        }
        assert!(store.metrics().evictions > 0);
    }
}
