//! Mobile-SoC device model (DESIGN.md §Substitutions).
//!
//! The paper evaluates on a Xiaomi 14 (Snapdragon 8 Gen 3): big.LITTLE CPU,
//! LPDDR5X DRAM, UFS 4.0 flash, Adreno GPU. None of that hardware exists in
//! this testbed, so every latency/throughput *figure* is derived from this
//! explicit, calibrated model, while the *code paths* (packing, spilling,
//! prefetching, scheduling) run for real. The model is deliberately simple —
//! bandwidth/compute rooflines — because that is exactly the regime the
//! paper reasons in (decode is memory-bound, prefill is compute-bound).

pub mod timeline;

/// One CPU core class in a big.LITTLE SoC.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CoreClass {
    pub name: &'static str,
    /// Relative sustained throughput (prime == 1.0).
    pub rel_perf: f64,
    /// Peak int8 ops/s for GEMM rooflines (single core).
    pub int8_ops_per_s: f64,
    /// Peak fp32 FLOP/s single core.
    pub f32_flops_per_s: f64,
}

/// Memory tier bandwidth/latency description.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MemTier {
    pub name: &'static str,
    /// Sustained sequential read bandwidth, bytes/s.
    pub read_bw: f64,
    /// Fixed per-request latency, seconds.
    pub latency_s: f64,
}

/// A system-on-chip profile: cores + memory tiers + GPU roofline.
#[derive(Clone, Debug)]
pub struct SocProfile {
    pub name: &'static str,
    /// Core list, one entry per physical core.
    pub cores: Vec<CoreClass>,
    pub dram: MemTier,
    pub flash: MemTier,
    /// GPU fp16 FLOP/s and memory bandwidth (image path).
    pub gpu_flops_per_s: f64,
    pub gpu_read_bw: f64,
}

pub const PRIME: CoreClass = CoreClass {
    name: "prime",
    rel_perf: 1.0,
    int8_ops_per_s: 250e9, // ~ X4 @3.3GHz with i8mm: 2×smmla/cycle ≈ 256 int8 MAC ops
    f32_flops_per_s: 50e9,
};

pub const PERF: CoreClass = CoreClass {
    name: "performance",
    rel_perf: 0.72,
    int8_ops_per_s: 180e9,
    f32_flops_per_s: 36e9,
};

pub const EFFICIENCY: CoreClass = CoreClass {
    name: "efficiency",
    rel_perf: 0.35,
    int8_ops_per_s: 70e9,
    f32_flops_per_s: 14e9,
};

impl SocProfile {
    /// Snapdragon 8 Gen 3-like profile (Xiaomi 14): 1 prime (Cortex-X4) +
    /// 3+2 performance (A720) + 2 efficiency (A520); LPDDR5X ≈ 58 GB/s
    /// (paper §4.1), UFS 4.0 ≈ 1 GB/s sustained for large sequential reads
    /// (the paper's assumed prefetch speed).
    pub fn snapdragon_8gen3() -> Self {
        SocProfile {
            name: "snapdragon-8gen3",
            cores: vec![PRIME, PERF, PERF, PERF, PERF, PERF, EFFICIENCY, EFFICIENCY],
            dram: MemTier { name: "LPDDR5X", read_bw: 58e9, latency_s: 100e-9 },
            flash: MemTier { name: "UFS4.0", read_bw: 1e9, latency_s: 15e-6 },
            gpu_flops_per_s: 4e12, // Adreno 750 fp16
            gpu_read_bw: 58e9,     // shared LPDDR
        }
    }

    /// The 4 high-performance cores the paper benches with (1 prime + 3 perf).
    pub fn high_perf_cores(&self, n: usize) -> Vec<CoreClass> {
        let mut cores: Vec<CoreClass> = self.cores.clone();
        // Descending by rel_perf; a NaN rel_perf (miscalibrated profile)
        // ranks last instead of panicking (total_cmp alone would rank +NaN
        // *first* here, which is worse than the panic it replaces).
        let key = |c: &CoreClass| if c.rel_perf.is_nan() { f64::NEG_INFINITY } else { c.rel_perf };
        cores.sort_by(|a, b| key(b).total_cmp(&key(a)));
        cores.truncate(n);
        cores
    }

    /// DRAM→registers time to stream `bytes` (memory-bound decode model).
    pub fn dram_read_time(&self, bytes: usize) -> f64 {
        self.dram.latency_s + bytes as f64 / self.dram.read_bw
    }

    /// Flash→DRAM time to stream `bytes`.
    pub fn flash_read_time(&self, bytes: usize) -> f64 {
        self.flash.latency_s + bytes as f64 / self.flash.read_bw
    }

    /// Aggregate int8 throughput of `threads` fastest cores.
    pub fn int8_ops_per_s(&self, threads: usize) -> f64 {
        self.high_perf_cores(threads).iter().map(|c| c.int8_ops_per_s).sum()
    }

    /// Aggregate fp32 throughput of `threads` fastest cores.
    pub fn f32_flops_per_s(&self, threads: usize) -> f64 {
        self.high_perf_cores(threads).iter().map(|c| c.f32_flops_per_s).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_matches_paper_constants() {
        let soc = SocProfile::snapdragon_8gen3();
        // Paper §4.1: "LPDDR5X achieves approximately 58 GB/s".
        assert_eq!(soc.dram.read_bw, 58e9);
        // Paper §4.1: DRAM is 19–130× faster than flash (0.45–3 GB/s).
        let ratio = soc.dram.read_bw / soc.flash.read_bw;
        assert!(ratio >= 19.0 && ratio <= 130.0, "ratio {ratio}");
    }

    #[test]
    fn high_perf_core_selection() {
        let soc = SocProfile::snapdragon_8gen3();
        let four = soc.high_perf_cores(4);
        assert_eq!(four.len(), 4);
        assert_eq!(four[0].name, "prime");
        assert!(four[1..].iter().all(|c| c.name == "performance"));
    }

    #[test]
    fn nan_rel_perf_does_not_panic_core_selection() {
        // Regression: high_perf_cores() used `partial_cmp().unwrap()`, so a
        // NaN rel_perf (miscalibrated profile) panicked instead of sorting.
        let mut soc = SocProfile::snapdragon_8gen3();
        soc.cores.push(CoreClass {
            name: "bogus",
            rel_perf: f64::NAN,
            int8_ops_per_s: 0.0,
            f32_flops_per_s: 0.0,
        });
        let four = soc.high_perf_cores(4);
        assert_eq!(four.len(), 4);
        assert!(four.iter().all(|c| c.name != "bogus"), "NaN sorts last in descending order");
    }

    #[test]
    fn paper_embedding_flash_overhead_example() {
        // Paper §4.1: reading one token's bf16 embedding row (7 KB for
        // Qwen2-7B) from UFS is "approximately 15 µs slower than LPDDR5X"
        // while loading the non-embedding parameters takes ~103 ms.
        let soc = SocProfile::snapdragon_8gen3();
        let row = 3584 * 2; // 7 KB
        let delta = soc.flash_read_time(row) - soc.dram_read_time(row);
        assert!(delta > 10e-6 && delta < 30e-6, "delta {delta}");
        let non_emb_bytes = 5.98e9; // layers + lm_head in int8 ≈ 6 GB
        let t = soc.dram_read_time(non_emb_bytes as usize);
        assert!(t > 0.08 && t < 0.13, "t {t}");
        // Overhead ratio ≈ 1.4‰ claimed; our constants land the same order.
        let ratio = delta / t;
        assert!(ratio < 0.5e-3, "ratio {ratio}");
    }

    #[test]
    fn aggregate_throughput_monotone_in_threads() {
        let soc = SocProfile::snapdragon_8gen3();
        let mut last = 0.0;
        for t in 1..=8 {
            let v = soc.int8_ops_per_s(t);
            assert!(v > last);
            last = v;
        }
    }
}
