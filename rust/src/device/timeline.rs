//! Discrete virtual-time timeline for overlap modelling (compute ∥ prefetch).
//!
//! Figure 2's point is *scheduling*: flash reads hide behind compute when
//! the prefetch window is long enough. We model that with two resources
//! (compute, flash-io) whose busy intervals advance independently; an
//! operation can be issued on one resource dependent on a prior completion.

/// A simple two-resource virtual timeline.
#[derive(Clone, Debug, Default)]
pub struct Timeline {
    compute_free_at: f64,
    io_free_at: f64,
    now: f64,
}

impl Timeline {
    pub fn new() -> Self {
        Self::default()
    }

    /// Advance the issue clock (e.g. tokens arriving).
    pub fn advance_to(&mut self, t: f64) {
        self.now = self.now.max(t);
    }

    /// Schedule a compute burst of `dur` seconds; returns completion time.
    pub fn compute(&mut self, dur: f64) -> f64 {
        let start = self.now.max(self.compute_free_at);
        self.compute_free_at = start + dur;
        self.compute_free_at
    }

    /// Schedule an IO burst of `dur` seconds (overlaps compute); returns
    /// completion time.
    pub fn io(&mut self, dur: f64) -> f64 {
        let start = self.now.max(self.io_free_at);
        self.io_free_at = start + dur;
        self.io_free_at
    }

    /// Block the *next compute* until the given IO completion (a dependency:
    /// e.g. attention needs prefetched KV).
    pub fn join(&mut self, at: f64) {
        self.compute_free_at = self.compute_free_at.max(at);
    }

    pub fn compute_free_at(&self) -> f64 {
        self.compute_free_at
    }

    pub fn io_free_at(&self) -> f64 {
        self.io_free_at
    }

    /// Makespan so far.
    pub fn finish(&self) -> f64 {
        self.compute_free_at.max(self.io_free_at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn io_overlaps_compute() {
        let mut tl = Timeline::new();
        tl.compute(10.0);
        tl.io(8.0); // fully hidden
        assert_eq!(tl.finish(), 10.0);
    }

    #[test]
    fn join_serializes_dependency() {
        let mut tl = Timeline::new();
        let io_done = tl.io(5.0);
        tl.join(io_done);
        tl.compute(2.0);
        assert_eq!(tl.finish(), 7.0);
    }

    #[test]
    fn unhidden_io_extends_makespan() {
        let mut tl = Timeline::new();
        tl.compute(3.0);
        let io_done = tl.io(9.0);
        tl.join(io_done);
        tl.compute(1.0);
        assert_eq!(tl.finish(), 10.0);
    }

    #[test]
    fn sequential_compute_accumulates() {
        let mut tl = Timeline::new();
        tl.compute(1.0);
        tl.compute(2.0);
        assert_eq!(tl.compute_free_at(), 3.0);
    }
}
