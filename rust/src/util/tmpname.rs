//! Unique temp-path generation shared by the flash simulator, the test
//! fixture writer, and tests that clone artifacts for mutation.
//!
//! Uniqueness must hold across *concurrent* callers in one process (cargo
//! runs tests in parallel threads) and across processes: the wall clock
//! alone can collide on coarse-resolution hosts, so the name combines the
//! pid, a process-wide sequence number, and nanoseconds.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

/// `$TMPDIR/{prefix}_{pid}_{seq}_{nanos}{suffix}` — unique per call.
/// `suffix` should include its dot (e.g. ".bin") or be empty for a dir.
pub fn unique_temp_path(prefix: &str, suffix: &str) -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .as_nanos();
    std::env::temp_dir().join(format!(
        "{prefix}_{}_{}_{nanos:x}{suffix}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paths_are_unique_and_shaped() {
        let a = unique_temp_path("mnn_t", ".bin");
        let b = unique_temp_path("mnn_t", ".bin");
        assert_ne!(a, b, "sequence number guarantees uniqueness");
        let name = a.file_name().unwrap().to_str().unwrap();
        assert!(name.starts_with("mnn_t_") && name.ends_with(".bin"));
    }
}
