//! Minimal JSON parser for artifacts/manifest.json (serde is not vendored
//! in this offline environment). Supports the full JSON grammar we emit:
//! objects, arrays, strings (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub at: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors (panic-free, Option-based) --

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.path("a", "b")` == obj["a"]["b"].
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    /// Build an object from `(key, value)` pairs (keys end up sorted —
    /// `Obj` is a BTreeMap — which keeps rendered artifacts diffable).
    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // -- rendering (the writer half: benches emit BENCH_*.json with it) --

    /// Serialize to compact JSON text this parser accepts back. Non-finite
    /// numbers become `null` (JSON has no NaN/Inf).
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    out.push_str(&format!("{n}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), at: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte '{}'", c as char))),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        return Err(self.err("truncated utf8"));
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_structure() {
        let src = r#"{"model": {"name": "tiny-qwen2", "vocab": 2048},
                      "buckets": [16, 64, 256],
                      "flag": true, "nothing": null, "pi": -3.5e-1}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.path(&["model", "name"]).unwrap().as_str(), Some("tiny-qwen2"));
        assert_eq!(j.path(&["model", "vocab"]).unwrap().as_usize(), Some(2048));
        assert_eq!(j.get("buckets").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("flag"), Some(&Json::Bool(true)));
        assert_eq!(j.get("nothing"), Some(&Json::Null));
        assert!((j.get("pi").unwrap().as_f64().unwrap() + 0.35).abs() < 1e-12);
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let j = Json::parse(r#"{"s": "a\n\"b\"A\\", "u": "héllo"}"#).unwrap();
        assert_eq!(j.get("s").unwrap().as_str(), Some("a\n\"b\"A\\"));
        assert_eq!(j.get("u").unwrap().as_str(), Some("héllo"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1, 2,]").is_err());
        assert!(Json::parse("{\"a\": 1} trailing").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn render_round_trips_through_parse() {
        let v = Json::obj(vec![
            ("name", Json::Str("table2".into())),
            ("ok", Json::Bool(true)),
            ("speedup", Json::Num(2.5)),
            ("rows", Json::Arr(vec![Json::Num(1.0), Json::Num(-3.0), Json::Null])),
            ("weird", Json::Str("a\"b\\c\nd\u{1}".into())),
        ]);
        let back = Json::parse(&v.render()).expect("own output parses");
        assert_eq!(back, v);
        // Non-finite numbers degrade to null rather than invalid JSON.
        let nan = Json::Arr(vec![Json::Num(f64::NAN), Json::Num(f64::INFINITY)]);
        assert_eq!(nan.render(), "[null,null]");
    }

    #[test]
    fn real_manifest_parses() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(src) = std::fs::read_to_string(path) {
            let j = Json::parse(&src).expect("manifest should parse");
            assert!(j.path(&["model", "vocab"]).is_some());
        }
    }
}
