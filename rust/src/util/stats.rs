//! Tiny statistics helpers shared by the bench harness and the balancer.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile via linear interpolation on a sorted copy. p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    // total_cmp: NaN samples (e.g. a 0/0 rate from an empty bench window)
    // sort to the end instead of panicking mid-report.
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Median (p50).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
    }

    #[test]
    fn nan_samples_sort_last_instead_of_panicking() {
        // Regression: percentile() used `partial_cmp().unwrap()`, which
        // panicked on any NaN sample (e.g. a 0/0 rate from an empty window).
        let xs = [1.0, f64::NAN, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 2.0, "NaN sorts after finite values");
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
