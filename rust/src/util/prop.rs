//! Miniature property-testing harness (proptest is not vendored offline).
//!
//! Usage:
//! ```ignore
//! prop_check(1000, |rng| {
//!     let n = rng.range(1, 64);
//!     let xs = rng.normal_vec(n);
//!     // ... assert invariant, or return Err(description)
//!     Ok(())
//! });
//! ```
//! On failure it reports the case index and the deterministic seed so the
//! exact case can be replayed with `prop_replay`.

use super::rng::Rng;

pub type PropResult = Result<(), String>;

const BASE_SEED: u64 = 0x5EED_CAFE_F00D_0001;

/// Run `cases` random cases of `f`; panic with seed info on first failure.
pub fn prop_check(cases: u64, mut f: impl FnMut(&mut Rng) -> PropResult) {
    let f = &mut f;
    for case in 0..cases {
        let seed = BASE_SEED ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property failed at case {case} (replay seed {seed:#x}): {msg}");
        }
    }
}

/// Replay one failing case by seed.
pub fn prop_replay(seed: u64, f: impl FnOnce(&mut Rng) -> PropResult) {
    let mut rng = Rng::new(seed);
    if let Err(msg) = f(&mut rng) {
        panic!("replayed property failure (seed {seed:#x}): {msg}");
    }
}

/// Convenience assert for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        prop_check(50, |rng| {
            n += 1;
            let a = rng.below(100);
            if a < 100 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        prop_check(100, |rng| {
            if rng.below(10) < 9 {
                Ok(())
            } else {
                Err("hit the 10% branch".into())
            }
        });
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first: Vec<usize> = Vec::new();
        prop_check(10, |rng| {
            first.push(rng.below(1000));
            Ok(())
        });
        let mut second: Vec<usize> = Vec::new();
        prop_check(10, |rng| {
            second.push(rng.below(1000));
            Ok(())
        });
        assert_eq!(first, second);
    }
}
