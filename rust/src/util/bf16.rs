//! bfloat16 <-> f32 conversion (embedding rows are stored bf16 in Flash,
//! paper §4.1/§4.2: "Embedding data read in bfloat16 format").

/// f32 → bf16 bits with round-to-nearest-even (matches numpy/ml_dtypes).
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // Quiet NaN, preserving the sign.
        return ((bits >> 16) as u16) | 0x0040;
    }
    let lsb = (bits >> 16) & 1;
    (bits.wrapping_add(0x0000_7FFF + lsb) >> 16) as u16
}

/// bf16 bits → f32 (exact).
#[inline]
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Convert a little-endian bf16 byte slice into f32s.
pub fn bytes_to_f32(bytes: &[u8], out: &mut [f32]) {
    assert_eq!(bytes.len(), out.len() * 2, "bf16 byte length mismatch");
    for (i, o) in out.iter_mut().enumerate() {
        let b = u16::from_le_bytes([bytes[2 * i], bytes[2 * i + 1]]);
        *o = bf16_to_f32(b);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_exact_for_bf16_values() {
        for bits in [0u16, 0x3F80, 0xBF80, 0x4000, 0x7F00, 0x0080] {
            let f = bf16_to_f32(bits);
            assert_eq!(f32_to_bf16(f), bits, "bits {bits:#06x} f {f}");
        }
    }

    #[test]
    fn conversion_error_bounded() {
        let mut rng = crate::util::rng::Rng::new(5);
        for _ in 0..1000 {
            let x = rng.normal() * 10.0;
            let back = bf16_to_f32(f32_to_bf16(x));
            // bf16 has 8 high mantissa bits: rel err ≤ 2^-8.
            assert!((back - x).abs() <= x.abs() * (1.0 / 256.0) + 1e-30, "{x} -> {back}");
        }
    }

    #[test]
    fn round_to_nearest_even_matches_numpy_samples() {
        // Spot values checked against numpy: np.float32(v).astype(bfloat16).
        assert_eq!(f32_to_bf16(1.0), 0x3F80);
        assert_eq!(f32_to_bf16(-2.5), 0xC020);
        assert_eq!(f32_to_bf16(3.14159265), 0x4049);
        assert_eq!(f32_to_bf16(65504.0), 0x4780);
    }

    #[test]
    fn bytes_decode() {
        let vals = [1.0f32, -0.5, 2.25];
        let bytes: Vec<u8> = vals
            .iter()
            .flat_map(|v| f32_to_bf16(*v).to_le_bytes())
            .collect();
        let mut out = [0f32; 3];
        bytes_to_f32(&bytes, &mut out);
        assert_eq!(out, vals);
    }
}
