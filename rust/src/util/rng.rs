//! Deterministic PRNG (xoshiro256**) — `rand` is not vendored offline.

/// xoshiro256** by Blackman & Vigna; fast, solid statistical quality,
/// deterministic across platforms — exactly what tests and benches need.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so any u64 (including 0) gives a good state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi].
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-12);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Vec of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Derive an independent sub-stream from this generator **without
    /// consuming from it**: the current state words and `salt` are folded
    /// through splitmix64, so forks with distinct salts are decorrelated
    /// from each other and from the parent. Non-mutating by construction
    /// (`&self`), which is what lets an optional feature (e.g. speculative
    /// accept/reject draws) take randomness from a fork while the parent
    /// stream's future output stays byte-for-byte unchanged.
    pub fn fork(&self, salt: u64) -> Rng {
        let mut sm = self.s[0]
            ^ self.s[1].rotate_left(13)
            ^ self.s[2].rotate_left(29)
            ^ self.s[3].rotate_left(47)
            ^ salt.wrapping_mul(0x9E3779B97F4A7C15);
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = Rng::new(9);
        let xs = r.normal_vec(20_000);
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn fork_never_consumes_from_the_parent() {
        // Regression guard for the speculative-decoding sub-stream: the
        // parent's output must be byte-for-byte identical whether or not
        // forks were taken — all existing seeded outputs stay unchanged.
        let mut plain = Rng::new(99);
        let plain_seq: Vec<u64> = (0..64).map(|_| plain.next_u64()).collect();
        let mut forked = Rng::new(99);
        let mut forks = Vec::new();
        let mut forked_seq = Vec::new();
        for i in 0..64u64 {
            forks.push(forked.fork(i)); // interleave forks with draws
            forked_seq.push(forked.next_u64());
        }
        assert_eq!(plain_seq, forked_seq, "fork consumed from the parent");
    }

    #[test]
    fn forks_are_deterministic_and_salt_distinct() {
        let r = Rng::new(5);
        assert_eq!(r.fork(1).next_u64(), r.fork(1).next_u64());
        assert_ne!(r.fork(1).next_u64(), r.fork(2).next_u64());
        // A fork differs from the parent's own stream.
        let mut p = Rng::new(5);
        assert_ne!(r.fork(0).next_u64(), p.next_u64());
    }

    #[test]
    fn below_covers_range() {
        let mut r = Rng::new(3);
        let mut seen = [false; 8];
        for _ in 0..200 {
            seen[r.below(8)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }
}
