//! Small self-contained substrates (no crates.io access in this environment,
//! so JSON parsing, PRNG, bf16 conversion and property testing are in-tree).

pub mod bf16;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod timer;
pub mod tmpname;

pub use tmpname::unique_temp_path;
