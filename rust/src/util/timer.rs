//! Wall-clock measurement helpers for the bench harness.

use std::time::Instant;

/// Run `f` once and return (result, seconds).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Measure `f` repeatedly: a warmup pass, then `iters` timed passes.
/// Returns per-iteration seconds.
pub fn time_iters(warmup: usize, iters: usize, mut f: impl FnMut()) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    (0..iters)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

/// Adaptive measurement: repeat `f` until `min_time_s` of samples or
/// `max_iters`, whichever first. Good default for micro-benches.
pub fn time_adaptive(min_time_s: f64, max_iters: usize, mut f: impl FnMut()) -> Vec<f64> {
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < max_iters
        && (samples.len() < 3 || start.elapsed().as_secs_f64() < min_time_s)
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_once_returns_result() {
        let (v, s) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn time_iters_counts() {
        let mut n = 0;
        let samples = time_iters(2, 5, || n += 1);
        assert_eq!(samples.len(), 5);
        assert_eq!(n, 7);
    }

    #[test]
    fn adaptive_respects_max() {
        let samples = time_adaptive(10.0, 4, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(samples.len(), 4);
    }
}
