//! Poison-tolerant mutex locking.
//!
//! `Mutex::lock().unwrap()` turns one panicked thread into a process-wide
//! cascade: every later lock on the same mutex panics too. The engine's
//! no-panic hot paths (scheduler tick, KV pool accounting, event streams)
//! guard plain counters and queues whose invariants hold at every await
//! point, so the right recovery is to take the data as-is and keep serving.

use std::sync::{Mutex, MutexGuard, PoisonError};

/// Lock `m`, recovering the guard if a previous holder panicked. Use on
/// mutexes whose protected state stays consistent between method calls
/// (counters, maps, queues) — i.e. all of this crate's.
pub fn lock_tolerant<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn poisoned_mutex_still_locks() {
        let m = Arc::new(Mutex::new(41usize));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.is_poisoned(), "setup: the mutex must actually be poisoned");
        let mut g = lock_tolerant(&m);
        *g += 1;
        assert_eq!(*g, 42);
    }
}
