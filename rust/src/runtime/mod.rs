//! PJRT runtime: loads the AOT artifacts (HLO text lowered once by
//! python/compile/aot.py) and executes them on the CPU PJRT client with
//! weights resident as device buffers. This is the three-layer path: Pallas
//! kernels (L1) inside the JAX graphs (L2), driven from Rust (L3) — Python
//! never runs at serving time.
//!
//! `WeightFile::load` is the streaming parser (one buffered copy per
//! tensor, never the whole file), so this backend's load-path peak DRAM is
//! the tensor set it uploads, not 2× it. The native backend goes further
//! and keeps layers flash-resident (`memory::weight_store`); PJRT keeps
//! everything as device buffers because the compiled graphs close over
//! every weight argument per call.
//!
//! The executable half depends on the `xla` crate, which is not part of
//! the offline toolchain, so it is compiled only under the `pjrt` feature
//! (see Cargo.toml). Without the feature a stub with the identical API is
//! compiled instead; `PjrtRuntime::load` then returns a descriptive error,
//! and everything that is backend-generic (notably [`KvState`], which the
//! scheduler threads through interleaved PJRT sessions) stays available.
//!
//! xla-crate 0.1.6 gotchas found while wiring this up (kept as a warning to
//! future readers):
//! * `buffer_from_host_raw_bytes` passes `ElementType` discriminants where
//!   the C side expects `PrimitiveType` (F32→F16) — never use it;
//! * `Literal::create_from_shape_and_untyped_data` + `buffer_from_host_
//!   literal` corrupts the heap after a few dozen uploads — the typed
//!   `buffer_from_host_buffer::<T>` path is the reliable one.

/// KV-cache state threaded between decode calls, host side. The CPU PJRT
/// "device" shares memory with the host, so re-upload per step is a memcpy.
pub struct KvState {
    pub k_q: Vec<i8>,
    pub k_s: Vec<f32>,
    pub k_b: Vec<f32>,
    pub v_u8: Vec<u8>,
    /// Tokens filled so far.
    pub pos: usize,
}

impl KvState {
    /// An empty (pre-prefill) state: what `InferenceBackend::new_session`
    /// hands out before the first prefill fills it, and what cancellation
    /// resets to so the host buffers are freed immediately.
    pub fn empty() -> Self {
        KvState { k_q: Vec::new(), k_s: Vec::new(), k_b: Vec::new(), v_u8: Vec::new(), pos: 0 }
    }

    /// DRAM bytes held by this state (the paper's KV-memory accounting).
    pub fn nbytes(&self) -> usize {
        self.k_q.len() + 4 * self.k_s.len() + 4 * self.k_b.len() + self.v_u8.len()
    }
}

#[cfg(feature = "pjrt")]
mod xla_backend {
    use std::path::Path;

    use anyhow::{anyhow, Context, Result};
    use xla::{PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

    use super::KvState;
    use crate::memory::embedding::FlashEmbedding;
    use crate::memory::flash::FlashSim;
    use crate::model::manifest::Manifest;
    use crate::model::weights::{WeightFile, DT_F32, DT_I8, DT_U8};

    /// One loaded model: compiled graphs + resident weight buffers.
    pub struct PjrtRuntime {
        pub client: PjRtClient,
        pub manifest: Manifest,
        prefill: Vec<(usize, PjRtLoadedExecutable)>,
        decode: PjRtLoadedExecutable,
        weight_bufs: Vec<PjRtBuffer>,
        pub embedding: FlashEmbedding,
    }

    fn upload(client: &PjRtClient, dtype: u8, data: &[u8], shape: &[usize]) -> Result<PjRtBuffer> {
        Ok(match dtype {
            DT_F32 => {
                let v: Vec<f32> = data
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                client.buffer_from_host_buffer(&v, shape, None)?
            }
            DT_I8 => {
                let v: Vec<i8> = data.iter().map(|&b| b as i8).collect();
                client.buffer_from_host_buffer(&v, shape, None)?
            }
            DT_U8 => client.buffer_from_host_buffer(data, shape, None)?,
            other => return Err(anyhow!("unsupported graph dtype {other}")),
        })
    }

    impl PjrtRuntime {
        /// Load everything from an artifacts directory.
        pub fn load(dir: &Path) -> Result<PjrtRuntime> {
            let manifest = Manifest::load(dir).context("manifest")?;
            let weights = WeightFile::load(&dir.join("weights.bin")).context("weights.bin")?;
            let client = PjRtClient::cpu()?;

            let compile = |file: &str| -> Result<PjRtLoadedExecutable> {
                let proto = xla::HloModuleProto::from_text_file(dir.join(file).to_str().unwrap())
                    .with_context(|| format!("parse {file}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                Ok(client.compile(&comp)?)
            };

            let mut prefill = Vec::new();
            for &b in &manifest.prefill_buckets {
                let g = manifest
                    .graph(&format!("prefill_{b}"))
                    .ok_or_else(|| anyhow!("missing prefill_{b} graph"))?;
                prefill.push((b, compile(&g.file)?));
            }
            let decode_entry = manifest.graph("decode").ok_or_else(|| anyhow!("missing decode"))?;
            let decode = compile(&decode_entry.file)?;

            // Weights become resident device buffers once, in manifest order.
            let mut weight_bufs = Vec::with_capacity(manifest.weights.len());
            for w in &manifest.weights {
                let t = weights.require(&w.name)?;
                weight_bufs.push(upload(&client, t.dtype, &t.data, &t.shape)?);
            }

            let soc = crate::device::SocProfile::snapdragon_8gen3();
            let embedding = FlashEmbedding::from_file(
                &dir.join(&manifest.embedding_file),
                manifest.model.vocab,
                manifest.model.hidden,
                FlashSim::temp(soc.flash)?,
            )?;

            Ok(PjrtRuntime { client, manifest, prefill, decode, weight_bufs, embedding })
        }

        /// The prefill bucket executable for a prompt of `len` tokens.
        fn prefill_exe(&self, len: usize) -> Result<(usize, &PjRtLoadedExecutable)> {
            let bucket = self.manifest.bucket_for(len);
            self.prefill
                .iter()
                .find(|(b, _)| *b == bucket)
                .map(|(b, e)| (*b, e))
                .ok_or_else(|| anyhow!("no bucket for len {len}"))
        }

        /// Run prefill; returns (last-token logits, KV state).
        pub fn prefill(&self, ids: &[usize]) -> Result<(Vec<f32>, KvState)> {
            let (bucket, exe) = self.prefill_exe(ids.len())?;
            if ids.len() > bucket {
                return Err(anyhow!("prompt {} exceeds largest bucket {bucket}", ids.len()));
            }
            let hidden = self.manifest.model.hidden;
            let mut host = vec![0f32; bucket * hidden];
            self.embedding
                .lookup_batch(ids, &mut host[..ids.len() * hidden])
                .context("flash embedding")?;
            let hidden_buf = self.client.buffer_from_host_buffer(&host, &[bucket, hidden], None)?;
            let mut args: Vec<&PjRtBuffer> = vec![&hidden_buf];
            args.extend(self.weight_bufs.iter());
            let result = exe.execute_b(&args)?[0][0].to_literal_sync()?;
            let parts = result.to_tuple()?;
            if parts.len() != 5 {
                return Err(anyhow!("prefill returned {} results, want 5", parts.len()));
            }
            let vocab = self.manifest.model.vocab;
            let all = parts[0].to_vec::<f32>()?;
            let last = all[(ids.len() - 1) * vocab..ids.len() * vocab].to_vec();
            Ok((
                last,
                KvState {
                    k_q: parts[1].to_vec::<i8>()?,
                    k_s: parts[2].to_vec::<f32>()?,
                    k_b: parts[3].to_vec::<f32>()?,
                    v_u8: parts[4].to_vec::<u8>()?,
                    pos: ids.len(),
                },
            ))
        }

        /// One decode step: token id at kv.pos; returns logits and advances kv.
        pub fn decode(&self, id: usize, kv: &mut KvState) -> Result<Vec<f32>> {
            let m = &self.manifest.model;
            if kv.pos >= m.max_len {
                return Err(anyhow!("KV capacity {} exhausted", m.max_len));
            }
            let (l, h_kv, t, d) = (m.layers, m.kv_heads, m.max_len, m.head_dim());
            let mut host = vec![0f32; m.hidden];
            self.embedding.lookup(id, &mut host).context("flash embedding")?;
            let hidden_buf = self.client.buffer_from_host_buffer(&host, &[1, m.hidden], None)?;
            let pos_buf = self.client.buffer_from_host_buffer(&[kv.pos as i32], &[1], None)?;
            let kq_buf = self.client.buffer_from_host_buffer(&kv.k_q, &[l, h_kv, t, d], None)?;
            let ks_buf = self.client.buffer_from_host_buffer(&kv.k_s, &[l, h_kv, t, 1], None)?;
            let kb_buf = self.client.buffer_from_host_buffer(&kv.k_b, &[l, h_kv, t, 1], None)?;
            let vu_buf = self.client.buffer_from_host_buffer(&kv.v_u8, &[l, h_kv, t, d], None)?;
            let mut args: Vec<&PjRtBuffer> =
                vec![&hidden_buf, &pos_buf, &kq_buf, &ks_buf, &kb_buf, &vu_buf];
            args.extend(self.weight_bufs.iter());
            let result = self.decode.execute_b(&args)?[0][0].to_literal_sync()?;
            let parts = result.to_tuple()?;
            if parts.len() != 5 {
                return Err(anyhow!("decode returned {} results, want 5", parts.len()));
            }
            kv.k_q = parts[1].to_vec::<i8>()?;
            kv.k_s = parts[2].to_vec::<f32>()?;
            kv.k_b = parts[3].to_vec::<f32>()?;
            kv.v_u8 = parts[4].to_vec::<u8>()?;
            kv.pos += 1;
            parts[0].to_vec::<f32>().map_err(Into::into)
        }

        /// Greedy generation: prefill + n-1 decode steps.
        pub fn generate(&self, prompt: &[usize], n: usize) -> Result<Vec<usize>> {
            let (logits, mut kv) = self.prefill(prompt)?;
            let mut tok = crate::model::sampler::argmax(&logits);
            let mut out = vec![tok];
            for _ in 1..n {
                let logits = self.decode(tok, &mut kv)?;
                tok = crate::model::sampler::argmax(&logits);
                out.push(tok);
            }
            Ok(out)
        }
    }
}

#[cfg(feature = "pjrt")]
pub use xla_backend::PjrtRuntime;

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::path::Path;

    use anyhow::{anyhow, Result};

    use super::KvState;
    use crate::model::manifest::Manifest;

    const NO_PJRT: &str =
        "mnn_llm was built without the `pjrt` feature; the PJRT backend is \
         unavailable (add the `xla` dependency and build with --features pjrt)";

    /// API-compatible stand-in for the xla-backed runtime. `load` always
    /// fails, so no instance can exist — the methods only satisfy callers'
    /// types (scheduler, CLI, artifact-gated tests).
    pub struct PjrtRuntime {
        pub manifest: Manifest,
    }

    impl PjrtRuntime {
        pub fn load(_dir: &Path) -> Result<PjrtRuntime> {
            Err(anyhow!(NO_PJRT))
        }

        pub fn prefill(&self, _ids: &[usize]) -> Result<(Vec<f32>, KvState)> {
            Err(anyhow!(NO_PJRT))
        }

        pub fn decode(&self, _id: usize, _kv: &mut KvState) -> Result<Vec<f32>> {
            Err(anyhow!(NO_PJRT))
        }

        pub fn generate(&self, _prompt: &[usize], _n: usize) -> Result<Vec<usize>> {
            Err(anyhow!(NO_PJRT))
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::PjrtRuntime;

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts() -> Option<PathBuf> {
        let d = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    #[ignore = "needs real AOT artifacts (python/compile/aot.py) under rust/artifacts"]
    fn loads_compiles_and_generates() {
        let dir = artifacts().expect("run the AOT pipeline first");
        let rt = PjrtRuntime::load(&dir).unwrap();
        let toks = rt.generate(&[104, 101, 108, 108, 111], 4).unwrap();
        assert_eq!(toks.len(), 4);
        assert!(toks.iter().all(|&t| t < rt.manifest.model.vocab));
        // Determinism.
        let again = rt.generate(&[104, 101, 108, 108, 111], 4).unwrap();
        assert_eq!(toks, again);
    }

    #[test]
    #[ignore = "needs real AOT artifacts (python/compile/aot.py) under rust/artifacts"]
    fn decode_continues_prefill() {
        let dir = artifacts().expect("run the AOT pipeline first");
        let rt = PjrtRuntime::load(&dir).unwrap();
        // prefill(p) == prefill(p[..1]) + decode chain: compare top-1.
        let p = [3usize, 1, 4, 1, 5];
        let (full, _) = rt.prefill(&p).unwrap();
        let (mut logits, mut kv) = rt.prefill(&p[..1]).unwrap();
        for &t in &p[1..] {
            logits = rt.decode(t, &mut kv).unwrap();
        }
        assert_eq!(
            crate::model::sampler::argmax(&full),
            crate::model::sampler::argmax(&logits)
        );
    }

    #[test]
    #[ignore = "needs real AOT artifacts (python/compile/aot.py) under rust/artifacts"]
    fn bucket_overflow_is_error() {
        let dir = artifacts().expect("run the AOT pipeline first");
        let rt = PjrtRuntime::load(&dir).unwrap();
        let long = vec![1usize; 300];
        assert!(rt.prefill(&long).is_err());
    }

    #[test]
    fn kv_state_accounting() {
        let kv = KvState { k_q: vec![0; 8], k_s: vec![0.0; 2], k_b: vec![0.0; 2],
                           v_u8: vec![0; 8], pos: 0 };
        assert_eq!(kv.nbytes(), 8 + 8 + 8 + 8);
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_load_is_a_clean_error() {
        let err = PjrtRuntime::load(std::path::Path::new("/nonexistent")).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    #[test]
    fn kv_state_accounting() {
        let kv = KvState { k_q: vec![0; 8], k_s: vec![0.0; 2], k_b: vec![0.0; 2],
                           v_u8: vec![0; 8], pos: 0 };
        assert_eq!(kv.nbytes(), 8 + 8 + 8 + 8);
    }
}
