//! `pallas-lint` — the repo's own static-analysis gate.
//!
//! ```text
//! cargo run --bin pallas-lint -- --check            # CI mode (default)
//! cargo run --bin pallas-lint -- --write-baseline   # record current ratchet counts
//! cargo run --bin pallas-lint -- --root src --baseline lint-baseline.txt
//! ```
//!
//! Exit codes: `0` clean, `1` violations (deny findings or ratchet
//! regressions), `2` usage or I/O error. Diagnostics are `file:line: rule:
//! message`, sorted and diff-stable.
//!
//! Run from `rust/` (CI does); `--root` defaults to `src`.

use std::path::PathBuf;
use std::process::ExitCode;

use mnn_llm::analysis::{self, baseline::Baseline, report, LintConfig};

struct Opts {
    root: PathBuf,
    baseline: PathBuf,
    write_baseline: bool,
}

fn parse_args() -> Result<Opts, String> {
    let mut opts = Opts {
        root: PathBuf::from("src"),
        baseline: PathBuf::from("lint-baseline.txt"),
        write_baseline: false,
    };
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--check" => opts.write_baseline = false,
            "--write-baseline" => opts.write_baseline = true,
            "--root" => {
                opts.root = PathBuf::from(args.next().ok_or("--root needs a directory")?);
            }
            "--baseline" => {
                opts.baseline = PathBuf::from(args.next().ok_or("--baseline needs a file")?);
            }
            "--help" | "-h" => {
                return Err(String::new()); // usage, exit 2 without an error line
            }
            other => return Err(format!("unknown argument `{other}`")),
        }
    }
    Ok(opts)
}

const USAGE: &str = "usage: pallas-lint [--check | --write-baseline] [--root DIR] [--baseline FILE]";

fn main() -> ExitCode {
    let opts = match parse_args() {
        Ok(o) => o,
        Err(msg) => {
            if !msg.is_empty() {
                eprintln!("pallas-lint: {msg}");
            }
            eprintln!("{USAGE}");
            return ExitCode::from(2);
        }
    };

    let cfg = LintConfig::default();
    let findings = match analysis::run(&opts.root, &cfg) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("pallas-lint: failed to lint {}: {e}", opts.root.display());
            return ExitCode::from(2);
        }
    };
    let (deny, ratchet) = analysis::partition(findings);
    let current = Baseline::from_findings(&ratchet);

    if opts.write_baseline {
        // Deny findings are never baselined — fail loudly even here.
        if !deny.is_empty() {
            print!("{}", report::format_findings(&deny));
            eprintln!("pallas-lint: {} deny finding(s); fix or waive before baselining", deny.len());
            return ExitCode::from(1);
        }
        if let Err(e) = std::fs::write(&opts.baseline, current.serialize()) {
            eprintln!("pallas-lint: cannot write {}: {e}", opts.baseline.display());
            return ExitCode::from(2);
        }
        println!(
            "pallas-lint: wrote {} ({} ratchet entries, {} sites)",
            opts.baseline.display(),
            current.counts.len(),
            current.counts.values().sum::<usize>()
        );
        return ExitCode::SUCCESS;
    }

    let committed = match Baseline::load(&opts.baseline) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("pallas-lint: bad baseline {}: {e}", opts.baseline.display());
            return ExitCode::from(2);
        }
    };
    let regressions = committed.regressions(&current);

    let mut failed = false;
    if !deny.is_empty() {
        print!("{}", report::format_findings(&deny));
        failed = true;
    }
    if !regressions.is_empty() {
        // Point at the concrete new sites, not just the counts: list the
        // ratchet findings for every regressed (rule, file) pair.
        let detail: Vec<_> = ratchet
            .iter()
            .filter(|f| regressions.iter().any(|r| r.rule == f.rule && r.path == f.path))
            .cloned()
            .collect();
        print!("{}", report::format_findings(&detail));
        print!("{}", report::format_regressions(&regressions));
        failed = true;
    }

    if failed {
        eprintln!(
            "pallas-lint: FAILED — {} deny finding(s), {} ratchet regression(s)",
            deny.len(),
            regressions.len()
        );
        return ExitCode::from(1);
    }

    let improvements = committed.improvements(&current);
    if !improvements.is_empty() {
        println!(
            "pallas-lint: {} ratchet entr(ies) improved — consider `--write-baseline` to lock in:",
            improvements.len()
        );
        for (rule, path, was, now) in improvements {
            println!("  {path}: {rule}: {was} -> {now}");
        }
    }
    println!(
        "pallas-lint: OK — 0 deny findings, {} ratchet sites at/below baseline",
        current.counts.values().sum::<usize>()
    );
    ExitCode::SUCCESS
}
