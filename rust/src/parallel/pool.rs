//! A small scoped thread pool applying the balanced split (paper §5.2),
//! plus a persistent [`BackgroundWorker`] for asynchronous one-shot jobs
//! (the weight residency manager's layer-ahead prefetch).
//!
//! The engine sets per-core load rates at startup (big.LITTLE aware); each
//! parallel GEMM then distributes its h-tiles with `balanced_split` and
//! runs one range per worker via `std::thread::scope`. On this 1-core
//! testbed the *policy* is what matters (virtual-time speedups come from
//! the device model); the pool still runs real threads so correctness under
//! concurrency is exercised.

use std::sync::mpsc;

use super::balancer::{balanced_split, split_ranges};

/// Runtime worker configuration: one entry per thread, relative rate.
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    pub rates: Vec<f64>,
}

impl WorkerConfig {
    /// `threads` workers over the SoC's fastest cores.
    pub fn from_soc(soc: &crate::device::SocProfile, threads: usize) -> Self {
        WorkerConfig {
            rates: soc.high_perf_cores(threads).iter().map(|c| c.rel_perf).collect(),
        }
    }

    pub fn uniform(threads: usize) -> Self {
        WorkerConfig { rates: vec![1.0; threads.max(1)] }
    }

    pub fn threads(&self) -> usize {
        self.rates.len()
    }
}

/// Distribute `items` work units over the workers with the balanced policy
/// and run `f(worker_idx, lo, hi)` concurrently on each range.
///
/// `f` only receives disjoint ranges, so it may mutate shared output
/// through interior pointers; we keep the safe API by letting the caller
/// split its buffers beforehand (see `run_balanced_collect`).
pub fn run_balanced<F>(cfg: &WorkerConfig, items: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let split = balanced_split(items, &cfg.rates);
    let ranges = split_ranges(&split);
    if cfg.threads() == 1 {
        let (lo, hi) = ranges[0];
        f(0, lo, hi);
        return;
    }
    std::thread::scope(|s| {
        for (i, (lo, hi)) in ranges.into_iter().enumerate() {
            if lo == hi {
                continue;
            }
            let f = &f;
            s.spawn(move || f(i, lo, hi));
        }
    });
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// One persistent background thread running submitted jobs in order.
///
/// `run_balanced` is synchronous by design (scoped threads joined per
/// call); prefetch wants the opposite — fire a flash read now, overlap it
/// with the current layer's compute, pick the result up later. Dropping
/// the worker closes the queue, runs what was already submitted, and joins
/// the thread, so jobs never outlive the state they capture by `Arc`.
pub struct BackgroundWorker {
    tx: Option<mpsc::Sender<Job>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl BackgroundWorker {
    pub fn new(name: &str) -> Self {
        let (tx, rx) = mpsc::channel::<Job>();
        let handle = std::thread::Builder::new()
            .name(name.to_string())
            .spawn(move || {
                while let Ok(job) = rx.recv() {
                    job();
                }
            })
            .expect("spawn background worker");
        BackgroundWorker { tx: Some(tx), handle: Some(handle) }
    }

    /// Enqueue a job; it runs asynchronously, after all previously
    /// submitted jobs. Returns false when the job could not be enqueued
    /// (the worker thread died — a previous job panicked); callers that
    /// track in-flight work must roll that state back on false, or waiters
    /// would block on a job that will never run.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) -> bool {
        match &self.tx {
            Some(tx) => tx.send(Box::new(job)).is_ok(),
            None => false,
        }
    }
}

impl Drop for BackgroundWorker {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Like `run_balanced` but each worker produces a Vec; results are returned
/// in worker order (for reductions).
pub fn run_balanced_collect<T, F>(cfg: &WorkerConfig, items: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, usize, usize) -> T + Sync,
{
    let split = balanced_split(items, &cfg.rates);
    let ranges = split_ranges(&split);
    std::thread::scope(|s| {
        let handles: Vec<_> = ranges
            .into_iter()
            .enumerate()
            .map(|(i, (lo, hi))| {
                let f = &f;
                s.spawn(move || f(i, lo, hi))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn all_items_processed_exactly_once() {
        let cfg = WorkerConfig { rates: vec![1.0, 0.72, 0.72, 0.72] };
        let n = 1000;
        let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        run_balanced(&cfg, n, |_, lo, hi| {
            for i in lo..hi {
                counts[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(counts.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn collect_returns_per_worker_results() {
        let cfg = WorkerConfig::uniform(4);
        let out = run_balanced_collect(&cfg, 100, |_, lo, hi| hi - lo);
        assert_eq!(out.iter().sum::<usize>(), 100);
        assert_eq!(out.len(), 4);
    }

    #[test]
    fn single_thread_runs_inline() {
        // Regression for a vacuous predecessor: it set its flag *after* the
        // call, so it asserted nothing. This one records, from inside the
        // closure, that the work ran on the calling thread itself.
        use std::sync::atomic::AtomicBool;
        let cfg = WorkerConfig::uniform(1);
        let caller = std::thread::current().id();
        let ran_inline = AtomicBool::new(false);
        run_balanced(&cfg, 10, |w, lo, hi| {
            assert_eq!((w, lo, hi), (0, 0, 10));
            ran_inline.store(std::thread::current().id() == caller, Ordering::SeqCst);
        });
        assert!(
            ran_inline.load(Ordering::SeqCst),
            "1-thread config must execute on the calling thread, not a spawned one"
        );
    }

    #[test]
    fn background_worker_runs_jobs_in_order_and_joins_on_drop() {
        use std::sync::{Arc, Mutex};
        let w = BackgroundWorker::new("test-bg");
        let log: Arc<Mutex<Vec<usize>>> = Arc::new(Mutex::new(Vec::new()));
        for i in 0..8 {
            let log = log.clone();
            w.submit(move || log.lock().unwrap().push(i));
        }
        drop(w); // closes the queue, drains, joins
        assert_eq!(*log.lock().unwrap(), (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn soc_config_prefers_fast_cores() {
        let soc = crate::device::SocProfile::snapdragon_8gen3();
        let cfg = WorkerConfig::from_soc(&soc, 4);
        assert_eq!(cfg.rates, vec![1.0, 0.72, 0.72, 0.72]);
    }

    #[test]
    fn empty_work_is_fine() {
        let cfg = WorkerConfig::uniform(3);
        run_balanced(&cfg, 0, |_, lo, hi| assert_eq!(lo, hi));
    }
}
