//! Multicore workload balancing (paper §5.2).
//!
//! Mobile SoCs are big.LITTLE: a prime core plus performance/efficiency
//! cores with different sustained throughput. Splitting a parallel loop
//! *uniformly* leaves the fast cores idle waiting for the slow ones; the
//! paper instead splits work proportionally to measured per-core load
//! rates, set at engine startup.
//!
//! * [`balancer`] — the split policy + makespan model (Fig. 4)
//! * [`pool`] — a real thread pool that applies the split (correctness on
//!   this 1-core testbed; speedups are evaluated on the device model)

pub mod balancer;
pub mod pool;

pub use balancer::{balanced_split, uniform_split, makespan, speedup_curve};
