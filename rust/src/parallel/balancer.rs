//! Workload split policies + the virtual-time makespan model (Fig. 4).
//!
//! Work is `items` indivisible units (e.g. h/h_p GEMM tiles, or seqlen
//! rows — the two parallel dimensions §5.2 names). Each core `i` has a
//! relative rate r_i (prime = 1.0). A split assigns a contiguous range per
//! core; the makespan in virtual time is max_i(n_i / r_i); the speedup vs
//! one prime core is items / makespan.

/// Split `items` uniformly across `rates.len()` cores (the baseline the
/// paper compares against).
pub fn uniform_split(items: usize, rates: &[f64]) -> Vec<usize> {
    let n = rates.len();
    let base = items / n;
    let rem = items % n;
    (0..n).map(|i| base + usize::from(i < rem)).collect()
}

/// Split `items` proportionally to core rates (largest-remainder rounding),
/// the paper's balanced policy.
pub fn balanced_split(items: usize, rates: &[f64]) -> Vec<usize> {
    let total: f64 = rates.iter().sum();
    assert!(total > 0.0, "need at least one active core");
    let ideal: Vec<f64> = rates.iter().map(|r| items as f64 * r / total).collect();
    let mut out: Vec<usize> = ideal.iter().map(|x| x.floor() as usize).collect();
    let assigned: usize = out.iter().sum();
    // Hand the remaining units to the largest fractional parts.
    let mut frac: Vec<(usize, f64)> = ideal
        .iter()
        .enumerate()
        .map(|(i, x)| (i, x - x.floor()))
        .collect();
    frac.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    for k in 0..(items - assigned) {
        out[frac[k % frac.len()].0] += 1;
    }
    out
}

/// Virtual-time makespan of a split: max_i(n_i / r_i).
pub fn makespan(split: &[usize], rates: &[f64]) -> f64 {
    split
        .iter()
        .zip(rates)
        .map(|(&n, &r)| if n == 0 { 0.0 } else { n as f64 / r })
        .fold(0.0, f64::max)
}

/// Speedup vs running everything on core 0 (the prime core), for both
/// policies at 1..=max_threads threads. Returns (balanced, uniform) curves —
/// exactly Fig. 4's two series.
pub fn speedup_curve(items: usize, rates: &[f64], max_threads: usize) -> (Vec<f64>, Vec<f64>) {
    let serial = items as f64 / rates[0];
    let mut bal = Vec::new();
    let mut uni = Vec::new();
    for t in 1..=max_threads.min(rates.len()) {
        let r = &rates[..t];
        bal.push(serial / makespan(&balanced_split(items, r), r));
        uni.push(serial / makespan(&uniform_split(items, r), r));
    }
    (bal, uni)
}

/// Convert a split into contiguous index ranges (for the thread pool).
pub fn split_ranges(split: &[usize]) -> Vec<(usize, usize)> {
    let mut out = Vec::with_capacity(split.len());
    let mut start = 0;
    for &n in split {
        out.push((start, start + n));
        start += n;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    /// Snapdragon-like 1 prime + 3 performance rates (Fig. 4's setup).
    fn fig4_rates() -> Vec<f64> {
        vec![1.0, 0.72, 0.72, 0.72]
    }

    #[test]
    fn splits_conserve_items() {
        prop_check(300, |rng| {
            let items = rng.range(1, 10_000);
            let n = rng.range(1, 8);
            let rates: Vec<f64> = (0..n).map(|_| rng.range_f32(0.1, 1.0) as f64).collect();
            for split in [balanced_split(items, &rates), uniform_split(items, &rates)] {
                if split.iter().sum::<usize>() != items {
                    return Err(format!("split {split:?} loses items"));
                }
                if split.len() != n {
                    return Err("wrong core count".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn balanced_never_worse_than_uniform() {
        // The §5.2 claim, as an invariant (up to rounding: allow 1 item).
        prop_check(300, |rng| {
            let items = rng.range(8, 5_000);
            let n = rng.range(2, 8);
            let rates: Vec<f64> = (0..n).map(|_| rng.range_f32(0.2, 1.0) as f64).collect();
            let mb = makespan(&balanced_split(items, &rates), &rates);
            let mu = makespan(&uniform_split(items, &rates), &rates);
            // Rounding can cost at most one item on the slowest core.
            let slack = 1.0 / rates.iter().cloned().fold(f64::INFINITY, f64::min);
            if mb > mu + slack {
                return Err(format!("balanced {mb} worse than uniform {mu}"));
            }
            Ok(())
        });
    }

    #[test]
    fn homogeneous_cores_make_policies_equal() {
        let rates = vec![1.0; 4];
        assert_eq!(balanced_split(1000, &rates), uniform_split(1000, &rates));
    }

    #[test]
    fn fig4_shape_balanced_beats_uniform_beyond_one_thread() {
        let (bal, uni) = speedup_curve(10_000, &fig4_rates(), 4);
        assert!((bal[0] - 1.0).abs() < 1e-9, "1 thread == serial");
        for t in 1..4 {
            assert!(bal[t] > uni[t] + 0.05, "t={} bal {} uni {}", t + 1, bal[t], uni[t]);
            assert!(bal[t] > bal[t - 1], "balanced speedup grows with threads");
        }
        // 4 threads balanced ≈ 1 + 3·0.72 = 3.16× vs prime-only.
        assert!((bal[3] - 3.16).abs() < 0.05, "bal4 {}", bal[3]);
        // Uniform is capped by the slowest core: 4×0.72 = 2.88×.
        assert!((uni[3] - 2.88).abs() < 0.05, "uni4 {}", uni[3]);
    }

    #[test]
    fn ranges_cover_exactly() {
        let split = balanced_split(100, &fig4_rates());
        let ranges = split_ranges(&split);
        assert_eq!(ranges.first().unwrap().0, 0);
        assert_eq!(ranges.last().unwrap().1, 100);
        for w in ranges.windows(2) {
            assert_eq!(w[0].1, w[1].0);
        }
    }

    #[test]
    fn zero_items_ok() {
        let rates = fig4_rates();
        assert_eq!(balanced_split(0, &rates).iter().sum::<usize>(), 0);
        assert_eq!(makespan(&balanced_split(0, &rates), &rates), 0.0);
    }
}
