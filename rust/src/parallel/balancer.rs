//! Workload split policies + the virtual-time makespan model (Fig. 4).
//!
//! Work is `items` indivisible units (e.g. h/h_p GEMM tiles, or seqlen
//! rows — the two parallel dimensions §5.2 names). Each core `i` has a
//! relative rate r_i (prime = 1.0). A split assigns a contiguous range per
//! core; the makespan in virtual time is max_i(n_i / r_i); the speedup vs
//! one prime core is items / makespan.

/// Split `items` uniformly across the *active* (rate > 0) cores — the
/// baseline the paper compares against. A core whose rate is 0 (parked /
/// thermally offlined) gets nothing: one item on a zero-rate core would
/// drive the makespan to infinity.
pub fn uniform_split(items: usize, rates: &[f64]) -> Vec<usize> {
    let active: Vec<usize> = (0..rates.len()).filter(|&i| rates[i] > 0.0).collect();
    assert!(!active.is_empty(), "need at least one active core");
    let base = items / active.len();
    let rem = items % active.len();
    let mut out = vec![0usize; rates.len()];
    for (j, &i) in active.iter().enumerate() {
        out[i] = base + usize::from(j < rem);
    }
    out
}

/// Split `items` proportionally to core rates (largest-remainder rounding),
/// the paper's balanced policy. Zero-rate cores get exactly zero items —
/// including during remainder distribution, whose wraparound used to be
/// able to land units on an inactive core.
pub fn balanced_split(items: usize, rates: &[f64]) -> Vec<usize> {
    let total: f64 = rates.iter().filter(|r| **r > 0.0).sum();
    assert!(total > 0.0, "need at least one active core");
    let ideal: Vec<f64> = rates
        .iter()
        .map(|&r| if r > 0.0 { items as f64 * r / total } else { 0.0 })
        .collect();
    let mut out: Vec<usize> = ideal.iter().map(|x| x.floor() as usize).collect();
    let assigned: usize = out.iter().sum();
    // Hand the remaining units to the largest fractional parts, cycling
    // over active cores only.
    let mut frac: Vec<(usize, f64)> = ideal
        .iter()
        .enumerate()
        .filter(|(i, _)| rates[*i] > 0.0)
        .map(|(i, x)| (i, x - x.floor()))
        .collect();
    frac.sort_by(|a, b| b.1.total_cmp(&a.1));
    for k in 0..(items - assigned) {
        out[frac[k % frac.len()].0] += 1;
    }
    out
}

/// Virtual-time makespan of a split: max_i(n_i / r_i).
pub fn makespan(split: &[usize], rates: &[f64]) -> f64 {
    split
        .iter()
        .zip(rates)
        .map(|(&n, &r)| if n == 0 { 0.0 } else { n as f64 / r })
        .fold(0.0, f64::max)
}

/// Speedup vs running everything on core 0 (the prime core), for both
/// policies at 1..=max_threads threads. Returns (balanced, uniform) curves —
/// exactly Fig. 4's two series.
pub fn speedup_curve(items: usize, rates: &[f64], max_threads: usize) -> (Vec<f64>, Vec<f64>) {
    let serial = items as f64 / rates[0];
    let mut bal = Vec::new();
    let mut uni = Vec::new();
    for t in 1..=max_threads.min(rates.len()) {
        let r = &rates[..t];
        bal.push(serial / makespan(&balanced_split(items, r), r));
        uni.push(serial / makespan(&uniform_split(items, r), r));
    }
    (bal, uni)
}

/// Convert a split into contiguous index ranges (for the thread pool).
pub fn split_ranges(split: &[usize]) -> Vec<(usize, usize)> {
    let mut out = Vec::with_capacity(split.len());
    let mut start = 0;
    for &n in split {
        out.push((start, start + n));
        start += n;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    /// Snapdragon-like 1 prime + 3 performance rates (Fig. 4's setup).
    fn fig4_rates() -> Vec<f64> {
        vec![1.0, 0.72, 0.72, 0.72]
    }

    #[test]
    fn splits_conserve_items() {
        // Rates include exact 0.0 (parked cores) — the former floor of 0.1
        // is why handing items to inactive cores went unnoticed.
        prop_check(300, |rng| {
            let items = rng.range(1, 10_000);
            let n = rng.range(1, 8);
            let mut rates: Vec<f64> = (0..n)
                .map(|_| {
                    if rng.below(4) == 0 {
                        0.0
                    } else {
                        rng.range_f32(0.1, 1.0) as f64
                    }
                })
                .collect();
            if rates.iter().all(|&r| r == 0.0) {
                rates[rng.below(n)] = 1.0; // precondition: ≥ 1 active core
            }
            for split in [balanced_split(items, &rates), uniform_split(items, &rates)] {
                if split.iter().sum::<usize>() != items {
                    return Err(format!("split {split:?} loses items"));
                }
                if split.len() != n {
                    return Err("wrong core count".into());
                }
                for (i, (&cnt, &r)) in split.iter().zip(&rates).enumerate() {
                    if r == 0.0 && cnt > 0 {
                        return Err(format!("core {i} is inactive but got {cnt} items"));
                    }
                }
                let m = makespan(&split, &rates);
                if !m.is_finite() {
                    return Err(format!("rates {rates:?} split {split:?}: makespan {m}"));
                }
            }
            Ok(())
        });
    }

    /// Regression: `uniform_split` used to hand items to zero-rate cores
    /// (and `balanced_split`'s largest-remainder wraparound could too),
    /// driving the makespan to infinity.
    #[test]
    fn zero_rate_cores_get_no_items() {
        let rates = vec![1.0, 0.0, 0.72, 0.0];
        for split in [uniform_split(100, &rates), balanced_split(100, &rates)] {
            assert_eq!(split.iter().sum::<usize>(), 100);
            assert_eq!(split[1], 0, "{split:?}");
            assert_eq!(split[3], 0, "{split:?}");
            assert!(makespan(&split, &rates).is_finite());
        }
        // All remainder pressure on a single active core still conserves.
        let one = vec![0.0, 0.3, 0.0];
        let split = balanced_split(7, &one);
        assert_eq!(split, vec![0, 7, 0]);
        assert_eq!(uniform_split(7, &one), vec![0, 7, 0]);
    }

    #[test]
    fn balanced_never_worse_than_uniform() {
        // The §5.2 claim, as an invariant (up to rounding: allow 1 item).
        prop_check(300, |rng| {
            let items = rng.range(8, 5_000);
            let n = rng.range(2, 8);
            let rates: Vec<f64> = (0..n).map(|_| rng.range_f32(0.2, 1.0) as f64).collect();
            let mb = makespan(&balanced_split(items, &rates), &rates);
            let mu = makespan(&uniform_split(items, &rates), &rates);
            // Rounding can cost at most one item on the slowest core.
            let slack = 1.0 / rates.iter().cloned().fold(f64::INFINITY, f64::min);
            if mb > mu + slack {
                return Err(format!("balanced {mb} worse than uniform {mu}"));
            }
            Ok(())
        });
    }

    #[test]
    fn homogeneous_cores_make_policies_equal() {
        let rates = vec![1.0; 4];
        assert_eq!(balanced_split(1000, &rates), uniform_split(1000, &rates));
    }

    #[test]
    fn fig4_shape_balanced_beats_uniform_beyond_one_thread() {
        let (bal, uni) = speedup_curve(10_000, &fig4_rates(), 4);
        assert!((bal[0] - 1.0).abs() < 1e-9, "1 thread == serial");
        for t in 1..4 {
            assert!(bal[t] > uni[t] + 0.05, "t={} bal {} uni {}", t + 1, bal[t], uni[t]);
            assert!(bal[t] > bal[t - 1], "balanced speedup grows with threads");
        }
        // 4 threads balanced ≈ 1 + 3·0.72 = 3.16× vs prime-only.
        assert!((bal[3] - 3.16).abs() < 0.05, "bal4 {}", bal[3]);
        // Uniform is capped by the slowest core: 4×0.72 = 2.88×.
        assert!((uni[3] - 2.88).abs() < 0.05, "uni4 {}", uni[3]);
    }

    #[test]
    fn ranges_cover_exactly() {
        let split = balanced_split(100, &fig4_rates());
        let ranges = split_ranges(&split);
        assert_eq!(ranges.first().unwrap().0, 0);
        assert_eq!(ranges.last().unwrap().1, 100);
        for w in ranges.windows(2) {
            assert_eq!(w[0].1, w[1].0);
        }
    }

    #[test]
    fn zero_items_ok() {
        let rates = fig4_rates();
        assert_eq!(balanced_split(0, &rates).iter().sum::<usize>(), 0);
        assert_eq!(makespan(&balanced_split(0, &rates), &rates), 0.0);
    }
}
