//! Hardware-driven data reorder (paper §5.1).
//!
//! The paper's central compute idea: pick loop-tiling parameters
//! (e_p, h_p, l_p) from the hardware description (register count,
//! instruction width), then *pre-rearrange* weights at load time and
//! activations at runtime into exactly the layout the GEMM microkernel
//! consumes, so the inner loop streams memory linearly.
//!
//! * [`isa`] — instruction-set descriptions (ARM sdot/i8mm/SME, x86 AVX2…)
//! * [`solver`] — the Eq. 2–4 optimizer that reproduces Table 2
//! * [`pack`] — the [e/e_p, l/l_p, e_p, l_p] activation / weight packers
//! * [`gpu_layout`] — the OpenCL-image layout ([l/l_p, h, l_p], l_p = 32)

pub mod gpu_layout;
pub mod isa;
pub mod pack;
pub mod solver;

pub use isa::IsaProfile;
pub use solver::{solve_tiles, TileConfig};
