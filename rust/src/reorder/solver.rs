//! Tile-size solver implementing the paper's Eq. 2–4 optimization:
//!
//!   min   (e/e_p)·(h/h_p)·(l·e_p + l·h_p + h_p·e_p)        (memory accesses)
//!   s.t.  regs(e_p) + regs(h_p) + regs(acc) ≤ R            (register file)
//!         l_p = instruction_width
//!
//! The objective counts memory traffic: each of the (e/e_p)(h/h_p) output
//! tiles streams an [e_p, l] activation panel, an [h_p, l] weight panel and
//! writes an [e_p, h_p] block; tiling reduces the naive 2ehl + eh traffic
//! because panels are reused from registers within a tile.
//!
//! Register accounting (Eq. 3's units): int8 operand tiles occupy
//! ceil(t·l_p / reg_bytes) registers, the int32 accumulator occupies
//! ceil(e_p·h_p·4 / reg_bytes) — except on outer-product engines (SME)
//! where it lives in dedicated tile storage capped by `acc_slots`.
//!
//! With these constraints the solver reproduces Table 2 exactly:
//! sdot (12,8,4), i8mm (10,8,8), armv7 (4,8,4), SME (4,64,4).

use super::isa::IsaProfile;

/// A solved tiling configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileConfig {
    pub e_p: usize,
    pub h_p: usize,
    pub l_p: usize,
}

/// Number of `reg_bytes`-wide registers needed for `n` bytes.
fn regs_for(bytes: u32, reg_bytes: u32) -> u32 {
    bytes.div_ceil(reg_bytes)
}

/// Register cost of a candidate tile on `isa` (None if infeasible).
pub fn register_cost(isa: &IsaProfile, e_p: u32, h_p: u32) -> Option<u32> {
    let act = regs_for(e_p * isa.instruction_width, isa.reg_bytes);
    let wgt = regs_for(h_p * isa.instruction_width, isa.reg_bytes);
    let acc = match isa.acc_slots {
        Some(cap) => {
            if e_p * h_p > cap {
                return None; // exceeds ZA tile storage
            }
            0
        }
        None => regs_for(e_p * h_p * 4, isa.reg_bytes),
    };
    Some(act + wgt + acc)
}

/// Eq. 2 objective: total memory accesses for an [e,l]×[h,l] GEMM.
pub fn memory_accesses(e: f64, h: f64, l: f64, e_p: f64, h_p: f64) -> f64 {
    (e / e_p) * (h / h_p) * (l * e_p + l * h_p + h_p * e_p)
}

/// Naive (untiled) memory accesses: 2ehl reads + eh writes.
pub fn naive_accesses(e: f64, h: f64, l: f64) -> f64 {
    2.0 * e * h * l + e * h
}

/// Solve Eq. 2–4 for `isa` with a representative problem size.
pub fn solve_tiles(isa: &IsaProfile) -> TileConfig {
    solve_tiles_for(isa, 1024.0, 1024.0, 1024.0)
}

/// Solve with explicit (e, h, l); ties broken toward larger e_p (prefill
/// batches rows, so deeper activation panels amortize the weight stream).
pub fn solve_tiles_for(isa: &IsaProfile, e: f64, h: f64, l: f64) -> TileConfig {
    let mut best: Option<(f64, u32, u32)> = None;
    let mut h_p = isa.h_step;
    while h_p <= 128.max(isa.h_step) {
        let mut e_p = isa.e_step;
        while e_p <= 64 {
            if let Some(cost) = register_cost(isa, e_p, h_p) {
                if cost <= isa.registers {
                    let obj = memory_accesses(e, h, l, e_p as f64, h_p as f64);
                    let better = match best {
                        None => true,
                        Some((bobj, be_p, _)) => {
                            obj < bobj - 1e-9
                                || ((obj - bobj).abs() <= 1e-9 && e_p > be_p)
                        }
                    };
                    if better {
                        best = Some((obj, e_p, h_p));
                    }
                }
            }
            e_p += isa.e_step;
        }
        h_p += isa.h_step;
    }
    let (_, e_p, h_p) = best.expect("register file admits at least the minimal tile");
    TileConfig {
        e_p: e_p as usize,
        h_p: h_p as usize,
        l_p: isa.instruction_width as usize,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reorder::isa::{self, table2_isas};

    /// The headline reproduction: Table 2 of the paper.
    #[test]
    fn reproduces_table2() {
        let expect = [
            ("armv8-sdot", TileConfig { e_p: 12, h_p: 8, l_p: 4 }),
            ("armv8-i8mm", TileConfig { e_p: 10, h_p: 8, l_p: 8 }),
            ("armv7-neon", TileConfig { e_p: 4, h_p: 8, l_p: 4 }),
            ("arm-sme", TileConfig { e_p: 4, h_p: 64, l_p: 4 }),
        ];
        for (isa, want) in table2_isas().iter().zip(expect) {
            let got = solve_tiles(isa);
            assert_eq!(isa.name, want.0);
            assert_eq!(got, want.1, "{}", isa.name);
        }
    }

    #[test]
    fn solutions_respect_register_budget() {
        for isa in table2_isas().iter().chain([&isa::X86_AVX2]) {
            let t = solve_tiles(isa);
            let cost = register_cost(isa, t.e_p as u32, t.h_p as u32).unwrap();
            assert!(cost <= isa.registers, "{}: {cost} > {}", isa.name, isa.registers);
        }
    }

    #[test]
    fn tiling_beats_naive_traffic() {
        // Eq. 2's point: tiled accesses ≪ naive 2ehl + eh.
        for isa in table2_isas() {
            let t = solve_tiles(&isa);
            let tiled = memory_accesses(1024.0, 1024.0, 1024.0, t.e_p as f64, t.h_p as f64);
            let naive = naive_accesses(1024.0, 1024.0, 1024.0);
            assert!(tiled < naive / 3.0, "{}: {tiled} vs {naive}", isa.name);
        }
    }

    #[test]
    fn objective_monotone_in_tile_size() {
        // Bigger tiles (when feasible) never increase the objective.
        let obj = |e_p: f64, h_p: f64| memory_accesses(512.0, 512.0, 512.0, e_p, h_p);
        assert!(obj(8.0, 8.0) < obj(4.0, 8.0));
        assert!(obj(8.0, 16.0) < obj(8.0, 8.0));
    }

    #[test]
    fn x86_avx2_solves_to_the_simd_kernel_tile() {
        // The AVX2 compute backend's fast path is written for exactly
        // this shape: l_p = 8 (the madd lane width) and even h_p (two
        // weight rows per 256-bit accumulator). Feasible set under the
        // 16-register budget is {(4,8), (8,8), (4,16)}; the Eq. 2
        // objective picks (8,8).
        let t = solve_tiles(&isa::X86_AVX2);
        assert_eq!(t, TileConfig { e_p: 8, h_p: 8, l_p: 8 });
    }

    #[test]
    fn x86_baseline_is_solvable_without_avx2() {
        // detect_host falls back to this profile on AVX2-less hosts; the
        // solver must still admit a tile (the scalar backend runs it).
        let t = solve_tiles(&isa::X86_BASELINE);
        let cost = register_cost(&isa::X86_BASELINE, t.e_p as u32, t.h_p as u32).unwrap();
        assert!(cost <= isa::X86_BASELINE.registers);
        assert_eq!(t.l_p, 4);
    }

    #[test]
    fn host_isa_solvable() {
        let t = solve_tiles(&isa::detect_host());
        assert!(t.e_p >= 4 && t.h_p >= 8);
    }

    #[test]
    fn degenerate_small_problem_still_solves() {
        let t = solve_tiles_for(&isa::ARM_SDOT, 1.0, 8.0, 4.0);
        assert!(t.e_p >= 1 && t.h_p >= 1);
    }
}
