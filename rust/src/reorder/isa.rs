//! CPU instruction-set profiles driving tile selection (paper §5.1, Table 2).
//!
//! The Eq. 3 register constraint counts *register slots*: the activation
//! tile (e_p × l_p int8), the weight tile (h_p × l_p int8) and the int32
//! accumulator tile (e_p × h_p) all live in the vector register file, in
//! units of `reg_bytes`-wide registers. Outer-product engines (SME) hold
//! the accumulator in dedicated tile storage instead (`acc_slots`).

/// An instruction set as the solver sees it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct IsaProfile {
    pub name: &'static str,
    /// Vector registers available to the microkernel (Eq. 3's R).
    pub registers: u32,
    /// Bytes per vector register (NEON/SVE128 = 16, AVX2 = 32).
    pub reg_bytes: u32,
    /// Elements consumed along l per MAC instruction → l_p (Eq. 4).
    pub instruction_width: u32,
    /// e_p must be a multiple of this (rows processed per instruction ×
    /// pipeline unroll: sdot kernels step 4 rows, smmla steps 2).
    pub e_step: u32,
    /// h_p must be a multiple of this (output channels per register pair).
    pub h_step: u32,
    /// Outer-product accumulator capacity in int32 slots, if the engine has
    /// dedicated tile storage (SME ZA). None → accumulators use registers.
    pub acc_slots: Option<u32>,
    /// Relative int8 MAC throughput vs sdot (for the perf model).
    pub int8_throughput: f64,
}

/// ARMv8.2 dot-product (`sdot`): 32 NEON regs; 4×int8 per lane.
pub const ARM_SDOT: IsaProfile = IsaProfile {
    name: "armv8-sdot",
    registers: 32,
    reg_bytes: 16,
    instruction_width: 4,
    e_step: 4,
    h_step: 8,
    acc_slots: None,
    int8_throughput: 1.0,
};

/// ARMv8.6 i8mm (`smmla`): 2×8 int8 blocks; double sdot throughput (paper:
/// "the throughput of the smmla instruction on ARM i8mm is twice that of
/// sdot"), and the weight repack uses l_p = 8 (paper §5.1).
pub const ARM_I8MM: IsaProfile = IsaProfile {
    name: "armv8-i8mm",
    registers: 32,
    reg_bytes: 16,
    instruction_width: 8,
    e_step: 2,
    h_step: 8,
    acc_slots: None,
    int8_throughput: 2.0,
};

/// ARMv7 NEON (no dot product): 16 q-registers, widening int8 MACs.
pub const ARM_V7_NEON: IsaProfile = IsaProfile {
    name: "armv7-neon",
    registers: 16,
    reg_bytes: 16,
    instruction_width: 4,
    e_step: 4,
    h_step: 8,
    acc_slots: None,
    int8_throughput: 0.5,
};

/// ARM SME: 16×16-int32 ZA outer-product tiles (256 accumulator slots);
/// streaming operands only need a handful of vector registers, and the
/// engine wants maximally wide h tiles (h_p = 64).
pub const ARM_SME: IsaProfile = IsaProfile {
    name: "arm-sme",
    registers: 32,
    reg_bytes: 16,
    instruction_width: 4,
    e_step: 4,
    h_step: 64,
    acc_slots: Some(256),
    int8_throughput: 4.0,
};

/// x86-64 AVX2 (this testbed's host; not a Table 2 row). The int8 MAC
/// sequence (pmaddubsw + pmaddwd) consumes 8+ int8 per 32-bit result lane,
/// so l_p = 8 — measured 2.5× faster than l_p = 4 at 1024³ on this host
/// (EXPERIMENTS.md §Perf).
pub const X86_AVX2: IsaProfile = IsaProfile {
    name: "x86-avx2",
    registers: 16,
    reg_bytes: 32,
    instruction_width: 8,
    e_step: 4,
    h_step: 8,
    acc_slots: None,
    int8_throughput: 1.2,
};

/// x86-64 without AVX2 (SSE2 baseline): 16 xmm registers, 4-wide int8
/// MAC sequences. Returned by [`detect_host`] when the runtime feature
/// check fails — the SIMD compute backend then degrades to scalar.
pub const X86_BASELINE: IsaProfile = IsaProfile {
    name: "x86-sse2",
    registers: 16,
    reg_bytes: 16,
    instruction_width: 4,
    e_step: 4,
    h_step: 8,
    acc_slots: None,
    int8_throughput: 0.6,
};

/// The rows of Table 2, in paper order.
pub fn table2_isas() -> Vec<IsaProfile> {
    vec![ARM_SDOT, ARM_I8MM, ARM_V7_NEON, ARM_SME]
}

/// Best profile for the host this binary runs on. On x86-64 this is a
/// **runtime** decision (`is_x86_feature_detected!`), not a compile-time
/// one: a binary built on an AVX2 machine and copied to an older box
/// must still solve tiles (and pick compute kernels) for what that box
/// can actually execute.
pub fn detect_host() -> IsaProfile {
    #[cfg(target_arch = "aarch64")]
    {
        ARM_I8MM
    }
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            X86_AVX2
        } else {
            X86_BASELINE
        }
    }
    #[cfg(not(any(target_arch = "aarch64", target_arch = "x86_64")))]
    {
        X86_BASELINE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn i8mm_is_twice_sdot() {
        assert_eq!(ARM_I8MM.int8_throughput / ARM_SDOT.int8_throughput, 2.0);
        assert_eq!(ARM_I8MM.instruction_width, 2 * ARM_SDOT.instruction_width);
    }

    #[test]
    fn host_detection_returns_valid_profile() {
        let isa = detect_host();
        assert!(isa.registers >= 16);
        assert!(isa.instruction_width >= 4);
    }

    #[test]
    fn table2_has_four_rows() {
        assert_eq!(table2_isas().len(), 4);
    }

    #[test]
    fn x86_detection_is_runtime_accurate() {
        // On x86-64 the profile must mirror the actual CPUID answer, not
        // the compile-time target; elsewhere this test is vacuous.
        #[cfg(target_arch = "x86_64")]
        {
            let isa = detect_host();
            if is_x86_feature_detected!("avx2") {
                assert_eq!(isa.name, X86_AVX2.name);
            } else {
                assert_eq!(isa.name, X86_BASELINE.name);
            }
        }
    }

    #[test]
    fn forced_scalar_backend_ignores_detection() {
        // The override contract (satellite of the backend seam): whatever
        // detect_host says, an explicit Scalar choice must win. This is
        // what lets CI force both legs deterministically.
        use crate::cpu::backend::{select, BackendChoice};
        if std::env::var("MNN_BACKEND").is_ok() {
            return; // an env override outranks the choice by design
        }
        assert_eq!(select(BackendChoice::Scalar).name(), "scalar");
    }
}
