//! GPU weight layout (paper §5.1, OpenCL-image path).
//!
//! The paper stores GPU weights as an Image object with layout
//! [l/l_p, h, l_p], l_p = 32: each work item then loads 32 4-bit weights =
//! 128 bits at once (the GPU's maximum vectorized load, one CL_RGBA texel),
//! and consecutive work items (consecutive h) touch consecutive addresses,
//! so the hardware coalesces the loads.
//!
//! We cannot execute OpenCL here (DESIGN.md §Substitutions); instead this
//! module implements the layout transformation + *property checkers* that
//! verify the two claims the layout is chosen for — 128-bit alignment per
//! work-item access and inter-work-item contiguity — and feeds the device
//! model's bandwidth term for the Fig. 5 GPU series.

/// GPU image layout parameters (paper: l_p = 32 int4 values = 128 bits).
pub const GPU_LP: usize = 32;
pub const BITS_PER_WEIGHT: usize = 4;
pub const WORK_ITEM_LOAD_BITS: usize = GPU_LP * BITS_PER_WEIGHT; // 128

/// Rearranged GPU weight buffer: [l/l_p, h, l_p] nibbles, densely packed.
#[derive(Clone, Debug)]
pub struct GpuWeightImage {
    pub h: usize,
    pub l: usize,
    pub l_pad: usize,
    /// Packed nibbles: byte i holds nibbles 2i (low) and 2i+1 (high) in
    /// [l/l_p, h, l_p] element order.
    pub data: Vec<u8>,
}

/// Pack dense int4 rows [h, l] (values 0..15) into the image layout.
pub fn pack_gpu_image(w4: &[u8], h: usize, l: usize) -> GpuWeightImage {
    assert_eq!(w4.len(), h * l, "expect one nibble value per byte on input");
    let l_pad = l.div_ceil(GPU_LP) * GPU_LP;
    let total = (l_pad / GPU_LP) * h * GPU_LP;
    let mut nibbles = vec![0u8; total];
    for r in 0..h {
        for c in 0..l {
            let (bj, jj) = (c / GPU_LP, c % GPU_LP);
            nibbles[(bj * h + r) * GPU_LP + jj] = w4[r * l + c] & 0xF;
        }
    }
    let mut data = vec![0u8; total / 2];
    for (i, pair) in nibbles.chunks(2).enumerate() {
        data[i] = pair[0] | (pair[1] << 4);
    }
    GpuWeightImage { h, l, l_pad, data }
}

impl GpuWeightImage {
    /// Byte offset of work item (r, block bj)'s 128-bit load.
    pub fn load_offset(&self, r: usize, bj: usize) -> usize {
        ((bj * self.h + r) * GPU_LP) / 2
    }

    /// Nibble at dense (r, c) — for correctness checks.
    pub fn get(&self, r: usize, c: usize) -> u8 {
        let (bj, jj) = (c / GPU_LP, c % GPU_LP);
        let n = (bj * self.h + r) * GPU_LP + jj;
        let b = self.data[n / 2];
        if n % 2 == 0 {
            b & 0xF
        } else {
            b >> 4
        }
    }

    /// Claim 1: every work-item load is one aligned 128-bit read.
    pub fn loads_are_128bit_aligned(&self) -> bool {
        let blocks = self.l_pad / GPU_LP;
        (0..self.h).all(|r| {
            (0..blocks).all(|bj| {
                let off = self.load_offset(r, bj);
                off % (WORK_ITEM_LOAD_BITS / 8) == 0
            })
        })
    }

    /// Claim 2: consecutive work items (consecutive h) read consecutive
    /// 16-byte lines — i.e. the hardware can merge them.
    pub fn work_items_coalesce(&self) -> bool {
        let blocks = self.l_pad / GPU_LP;
        (0..blocks).all(|bj| {
            (1..self.h).all(|r| {
                self.load_offset(r, bj) == self.load_offset(r - 1, bj) + WORK_ITEM_LOAD_BITS / 8
            })
        })
    }

    pub fn nbytes(&self) -> usize {
        self.data.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_w4(rng: &mut Rng, h: usize, l: usize) -> Vec<u8> {
        (0..h * l).map(|_| rng.below(16) as u8).collect()
    }

    #[test]
    fn pack_preserves_values() {
        let mut rng = Rng::new(1);
        let (h, l) = (24, 96);
        let w = random_w4(&mut rng, h, l);
        let img = pack_gpu_image(&w, h, l);
        for r in 0..h {
            for c in 0..l {
                assert_eq!(img.get(r, c), w[r * l + c], "({r},{c})");
            }
        }
    }

    #[test]
    fn loads_aligned_and_coalesced() {
        let mut rng = Rng::new(2);
        for (h, l) in [(8, 32), (17, 64), (64, 160)] {
            let w = random_w4(&mut rng, h, l);
            let img = pack_gpu_image(&w, h, l);
            assert!(img.loads_are_128bit_aligned(), "{h}x{l}");
            assert!(img.work_items_coalesce(), "{h}x{l}");
        }
    }

    #[test]
    fn l_gets_padded_to_lp() {
        let mut rng = Rng::new(3);
        let w = random_w4(&mut rng, 4, 40);
        let img = pack_gpu_image(&w, 4, 40);
        assert_eq!(img.l_pad, 64);
        // Bytes: (64/32 blocks) * 4 rows * 32 nibbles / 2.
        assert_eq!(img.nbytes(), 2 * 4 * 32 / 2);
    }

    #[test]
    fn load_bits_match_paper() {
        assert_eq!(WORK_ITEM_LOAD_BITS, 128);
    }
}
