//! Data rearrangement for the tiled GEMM (paper §5.1).
//!
//! Activations [e, l] are packed as [e/e_p, l/l_p, e_p, l_p] and weights
//! [h, l] as [h/h_p, l/l_p, h_p, l_p] (int4: nibble pairs along l_p), so the
//! microkernel reads both operands strictly sequentially. Dimensions are
//! zero-padded up to tile multiples; zero int8 values contribute zero to the
//! integer accumulator, and the affine corrections use the *true* l, so
//! padding never changes results.

use crate::quant::asym::{self, AsymParams, QuantizedMatrix, WeightBits};
use crate::reorder::solver::TileConfig;

/// Round `x` up to a multiple of `m`.
#[inline]
pub fn round_up(x: usize, m: usize) -> usize {
    x.div_ceil(m) * m
}

/// Activations packed for the microkernel, already int8-quantized per row.
#[derive(Clone, Debug)]
pub struct PackedActivations {
    pub e: usize,
    pub l: usize,
    pub e_pad: usize,
    pub l_pad: usize,
    pub tile: TileConfig,
    /// [e_pad/e_p, l_pad/l_p, e_p, l_p] int8.
    pub data: Vec<i8>,
    /// Per true row: dynamic quant params + Σ x_q (affine corrections).
    pub params: Vec<AsymParams>,
    pub row_sums: Vec<i32>,
}

/// Pack + dynamically quantize an [e, l] f32 activation block.
pub fn pack_activations(x: &[f32], e: usize, l: usize, tile: TileConfig) -> PackedActivations {
    assert_eq!(x.len(), e * l);
    let (q, params, row_sums) = asym::quantize_activations(x, e, l);
    pack_quantized_activations(&q, e, l, tile, params, row_sums)
}

/// Pack activations that are already int8 (used when the caller fuses the
/// quantization elsewhere).
pub fn pack_quantized_activations(
    q: &[i8],
    e: usize,
    l: usize,
    tile: TileConfig,
    params: Vec<AsymParams>,
    row_sums: Vec<i32>,
) -> PackedActivations {
    let e_pad = round_up(e, tile.e_p);
    let l_pad = round_up(l, tile.l_p);
    let mut data = vec![0i8; e_pad * l_pad];
    let tiles_l = l_pad / tile.l_p;
    for r in 0..e {
        let (bi, ii) = (r / tile.e_p, r % tile.e_p);
        for c in 0..l {
            let (bj, jj) = (c / tile.l_p, c % tile.l_p);
            let idx = ((bi * tiles_l + bj) * tile.e_p + ii) * tile.l_p + jj;
            data[idx] = q[r * l + c];
        }
    }
    PackedActivations { e, l, e_pad, l_pad, tile, data, params, row_sums }
}

/// Weights packed for the microkernel (done once at model load — the paper
/// repacks according to the detected ISA, e.g. l_p = 8 when i8mm exists).
#[derive(Clone, Debug)]
pub struct PackedWeights {
    pub h: usize,
    pub l: usize,
    pub h_pad: usize,
    pub l_pad: usize,
    pub tile: TileConfig,
    pub bits: WeightBits,
    /// int8: [h_pad/h_p, l_pad/l_p, h_p, l_p] bytes;
    /// int4: same order, two values per byte along l_p (l_p/2 bytes).
    pub data: Vec<u8>,
    pub params: Vec<AsymParams>,
    pub row_sums: Vec<i32>,
}

/// Repack a quantized matrix [h, l] into tile order.
pub fn pack_weights(w: &QuantizedMatrix, tile: TileConfig) -> PackedWeights {
    assert!(
        w.bits == WeightBits::Int8 || tile.l_p % 2 == 0,
        "int4 packing needs even l_p"
    );
    let (h, l) = (w.n, w.k);
    let h_pad = round_up(h, tile.h_p);
    let l_pad = round_up(l, tile.l_p);
    let tiles_l = l_pad / tile.l_p;
    // Materialize rows via for_row (handles the nibble layout), then place.
    let mut dense = vec![0i32; l];
    let mut data = match w.bits {
        WeightBits::Int8 => vec![0u8; h_pad * l_pad],
        WeightBits::Int4 => vec![0u8; h_pad * l_pad / 2],
    };
    for r in 0..h {
        let mut i = 0;
        w.for_row(r, |q| {
            dense[i] = q;
            i += 1;
        });
        let (bi, ii) = (r / tile.h_p, r % tile.h_p);
        for c in 0..l {
            let (bj, jj) = (c / tile.l_p, c % tile.l_p);
            match w.bits {
                WeightBits::Int8 => {
                    let idx = ((bi * tiles_l + bj) * tile.h_p + ii) * tile.l_p + jj;
                    data[idx] = dense[c] as i8 as u8;
                }
                WeightBits::Int4 => {
                    let idx = (((bi * tiles_l + bj) * tile.h_p + ii) * tile.l_p + jj) / 2;
                    let nib = (dense[c] as u8) & 0xF;
                    if jj % 2 == 0 {
                        data[idx] |= nib;
                    } else {
                        data[idx] |= nib << 4;
                    }
                }
            }
        }
    }
    PackedWeights {
        h,
        l,
        h_pad,
        l_pad,
        tile,
        bits: w.bits,
        data,
        params: w.params.clone(),
        row_sums: w.row_sums.clone(),
    }
}

impl PackedWeights {
    /// Read back row `r` in dense k order (tests / fallback paths).
    pub fn unpack_row(&self, r: usize) -> Vec<i32> {
        assert!(
            r < self.h,
            "unpack_row: row {r} out of bounds for {} true rows",
            self.h
        );
        let tiles_l = self.l_pad / self.tile.l_p;
        let (bi, ii) = (r / self.tile.h_p, r % self.tile.h_p);
        let mut out = vec![0i32; self.l];
        for c in 0..self.l {
            let (bj, jj) = (c / self.tile.l_p, c % self.tile.l_p);
            out[c] = match self.bits {
                WeightBits::Int8 => {
                    let idx = ((bi * tiles_l + bj) * self.tile.h_p + ii) * self.tile.l_p + jj;
                    self.data[idx] as i8 as i32
                }
                WeightBits::Int4 => {
                    let idx =
                        (((bi * tiles_l + bj) * self.tile.h_p + ii) * self.tile.l_p + jj) / 2;
                    let b = self.data[idx];
                    if jj % 2 == 0 {
                        (b & 0xF) as i32
                    } else {
                        (b >> 4) as i32
                    }
                }
            };
        }
        out
    }

    pub fn nbytes(&self) -> usize {
        self.data.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    const TILE: TileConfig = TileConfig { e_p: 4, h_p: 8, l_p: 4 };

    #[test]
    fn activation_pack_roundtrip() {
        prop_check(100, |rng| {
            let e = rng.range(1, 20);
            let l = rng.range(1, 40);
            let x = rng.normal_vec(e * l);
            let p = pack_activations(&x, e, l, TILE);
            // Unpack and compare against direct quantization.
            let (q, _, _) = asym::quantize_activations(&x, e, l);
            let tiles_l = p.l_pad / TILE.l_p;
            for r in 0..e {
                for c in 0..l {
                    let (bi, ii) = (r / TILE.e_p, r % TILE.e_p);
                    let (bj, jj) = (c / TILE.l_p, c % TILE.l_p);
                    let idx = ((bi * tiles_l + bj) * TILE.e_p + ii) * TILE.l_p + jj;
                    if p.data[idx] != q[r * l + c] {
                        return Err(format!("mismatch at ({r},{c})"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn weight_pack_roundtrip_int8_and_int4() {
        prop_check(60, |rng| {
            let h = rng.range(1, 24);
            let l = rng.range(1, 16) * 2;
            let w = rng.normal_vec(h * l);
            for bits in [WeightBits::Int8, WeightBits::Int4] {
                let qm = QuantizedMatrix::from_f32(&w, h, l, bits);
                let packed = pack_weights(&qm, TILE);
                for r in 0..h {
                    let mut want = Vec::new();
                    qm.for_row(r, |v| want.push(v));
                    if packed.unpack_row(r) != want {
                        return Err(format!("{bits:?} row {r} mismatch"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn row_quantization_is_batch_size_invariant() {
        // Dynamic activation quantization is strictly per-row: packing a
        // row inside an m-row block yields the same codes, params and row
        // sum as packing it alone — the precondition for fused batched
        // decode's bit-identity to sequential decode.
        prop_check(60, |rng| {
            let e = rng.range(2, 10);
            let l = rng.range(1, 40);
            let x = rng.normal_vec(e * l);
            let full = pack_activations(&x, e, l, TILE);
            for r in 0..e {
                let one = pack_activations(&x[r * l..(r + 1) * l], 1, l, TILE);
                if one.params[0] != full.params[r] {
                    return Err(format!("row {r}: params diverge"));
                }
                if one.row_sums[0] != full.row_sums[r] {
                    return Err(format!("row {r}: row sums diverge"));
                }
                // And the packed codes themselves.
                let tiles_l = full.l_pad / TILE.l_p;
                for c in 0..l {
                    let (bi, ii) = (r / TILE.e_p, r % TILE.e_p);
                    let (bj, jj) = (c / TILE.l_p, c % TILE.l_p);
                    let idx = ((bi * tiles_l + bj) * TILE.e_p + ii) * TILE.l_p + jj;
                    let one_idx = (c / TILE.l_p) * TILE.e_p * TILE.l_p + c % TILE.l_p;
                    if full.data[idx] != one.data[one_idx] {
                        return Err(format!("row {r} col {c}: codes diverge"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn padding_regions_are_zero() {
        let x = vec![1.0f32; 3 * 5];
        let p = pack_activations(&x, 3, 5, TILE);
        assert_eq!(p.e_pad, 4);
        assert_eq!(p.l_pad, 8);
        // Padded row 3 must be all zeros.
        let tiles_l = p.l_pad / TILE.l_p;
        for bj in 0..tiles_l {
            for jj in 0..TILE.l_p {
                let idx = ((0 * tiles_l + bj) * TILE.e_p + 3) * TILE.l_p + jj;
                assert_eq!(p.data[idx], 0);
            }
        }
    }

    #[test]
    #[should_panic(expected = "unpack_row")]
    fn unpack_row_out_of_bounds_panics_with_message() {
        // Regression: an out-of-range row used to fail deep inside the
        // index math (or silently return padding zeros for r < h_pad);
        // both compute backends unpack through here, so the contract
        // must be a named assert on true rows.
        let mut rng = crate::util::rng::Rng::new(3);
        let w = rng.normal_vec(3 * 8);
        let q = QuantizedMatrix::from_f32(&w, 3, 8, WeightBits::Int8);
        let p = pack_weights(&q, TILE);
        let _ = p.unpack_row(3); // rows are 0..3; 3 is padding
    }

    #[test]
    fn zero_row_activation_pack_is_an_empty_no_op() {
        // e == 0 packs to an empty panel (e_pad == 0) rather than
        // panicking — the fused tick can momentarily have no rows.
        let p = pack_activations(&[], 0, 8, TILE);
        assert_eq!(p.e, 0);
        assert_eq!(p.e_pad, 0);
        assert!(p.data.is_empty());
        assert!(p.params.is_empty());
        assert!(p.row_sums.is_empty());
    }

    #[test]
    fn int4_packed_half_the_bytes() {
        let mut rng = crate::util::rng::Rng::new(2);
        let w = rng.normal_vec(16 * 32);
        let q8 = QuantizedMatrix::from_f32(&w, 16, 32, WeightBits::Int8);
        let q4 = QuantizedMatrix::from_f32(&w, 16, 32, WeightBits::Int4);
        let p8 = pack_weights(&q8, TILE);
        let p4 = pack_weights(&q4, TILE);
        assert_eq!(p4.nbytes() * 2, p8.nbytes());
    }
}
