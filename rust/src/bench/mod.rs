//! Bench harness (criterion is not vendored offline): adaptive timing with
//! mean/σ reporting and aligned table printing for the paper's tables and
//! figures.

use crate::util::{stats, timer};

/// One measured series entry.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub mean_s: f64,
    pub std_s: f64,
    pub samples: usize,
}

/// Measure a closure adaptively (≥0.3 s or ≤64 iters) and report.
pub fn bench(name: &str, mut f: impl FnMut()) -> Measurement {
    let samples = timer::time_adaptive(0.3, 64, &mut f);
    let m = Measurement {
        name: name.to_string(),
        mean_s: stats::mean(&samples),
        std_s: stats::stddev(&samples),
        samples: samples.len(),
    };
    println!(
        "  {:<42} {:>12.3} ms ± {:>8.3} ms  (n={})",
        m.name,
        m.mean_s * 1e3,
        m.std_s * 1e3,
        m.samples
    );
    m
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Print an aligned table: header row + rows of cells.
pub fn table(headers: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: Vec<String>| {
        let mut s = String::from("| ");
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$} | ", c, w = widths[i]));
        }
        s
    };
    println!("{}", line(headers.iter().map(|s| s.to_string()).collect()));
    println!(
        "|{}|",
        widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
    );
    for row in rows {
        println!("{}", line(row.clone()));
    }
}

/// Write a machine-readable bench artifact (`BENCH_*.json`): one JSON
/// document + trailing newline, and say where it landed. Values are
/// assembled with [`crate::util::json::Json`] (its `render` emits what its
/// own parser accepts).
pub fn write_json(path: &str, value: &crate::util::json::Json) {
    let body = format!("{}\n", value.render());
    match std::fs::write(path, body) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

/// Format a float with engineering precision.
pub fn fmt(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 100.0 {
        format!("{v:.0}")
    } else if v.abs() >= 1.0 {
        format!("{v:.2}")
    } else {
        format!("{v:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_stats() {
        let m = bench("noop", || {
            std::hint::black_box(1 + 1);
        });
        assert!(m.samples >= 3);
        assert!(m.mean_s >= 0.0);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt(0.0), "0");
        assert_eq!(fmt(123.4), "123");
        assert_eq!(fmt(1.234), "1.23");
        assert_eq!(fmt(0.1234), "0.1234");
    }

    #[test]
    fn table_prints() {
        table(&["a", "bb"], &[vec!["1".into(), "2".into()]]);
    }

    #[test]
    fn write_json_emits_parseable_artifact() {
        use crate::util::json::Json;
        let path = crate::util::tmpname::unique_temp_path("bench-json", ".json");
        let v = Json::obj(vec![
            ("name", Json::Str("table2".into())),
            ("speedup", Json::Num(2.5)),
        ]);
        write_json(path.to_str().unwrap(), &v);
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.ends_with('\n'));
        let parsed = Json::parse(body.trim_end()).unwrap();
        assert_eq!(parsed.path(&["speedup"]).unwrap().as_f64(), Some(2.5));
        std::fs::remove_file(&path).ok();
    }
}
