//! `pallas-lint`: a dependency-free static-analysis pass over this crate's
//! own source tree, run as a ratcheted CI gate (`cargo run --bin pallas-lint
//! -- --check`).
//!
//! The engine's headline robustness guarantees — no-panic hot paths,
//! SAFETY-documented `unsafe`, NaN-safe comparisons, overflow-checked byte
//! accounting — were each earned by fixing a real bug once. This subsystem
//! keeps those bug classes from reappearing: a hand-rolled lexer
//! ([`lexer`]), structural context ([`context`]: `#[cfg(test)]` regions and
//! `// lint: allow(rule): reason` waivers), the rule catalog ([`rules`]),
//! and a ratcheting baseline ([`baseline`]) so pre-existing `unwrap` debt
//! shrinks monotonically instead of blocking the gate. See DESIGN.md
//! ("Static analysis") for the rule catalog and waiver semantics.

pub mod baseline;
pub mod context;
pub mod lexer;
pub mod report;
pub mod rules;

pub use baseline::{Baseline, Regression};
pub use rules::{check_file, Finding, LintConfig, Severity, RULES};

use std::io;
use std::path::{Path, PathBuf};

/// Recursively collect `.rs` files under `root`, sorted for determinism.
pub fn rust_files(root: &Path) -> io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        for entry in std::fs::read_dir(&dir)? {
            let path = entry?.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                out.push(path);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Lint every `.rs` file under `root`. Paths in findings are relative to
/// `root`, `/`-normalized (so hot-module suffix matching and baseline keys
/// are OS-independent).
pub fn run(root: &Path, cfg: &LintConfig) -> io::Result<Vec<Finding>> {
    let mut findings = Vec::new();
    for path in rust_files(root)? {
        let rel = path.strip_prefix(root).unwrap_or(&path);
        let rel = rel.to_string_lossy().replace('\\', "/");
        let src = std::fs::read_to_string(&path)?;
        findings.extend(check_file(&rel, &src, cfg));
    }
    Ok(findings)
}

/// Split findings into (deny, ratchet) tiers.
pub fn partition(findings: Vec<Finding>) -> (Vec<Finding>, Vec<Finding>) {
    findings.into_iter().partition(|f| f.severity == Severity::Deny)
}
