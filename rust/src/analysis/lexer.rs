//! A minimal hand-rolled Rust lexer for `pallas-lint`.
//!
//! The offline toolchain has no `syn`/`proc-macro2`, so the lint works on a
//! flat token stream produced here. The lexer understands exactly as much
//! Rust as the rules need to be sound on this crate:
//!
//! * line comments (`//`, `///`, `//!`) and **nested** block comments,
//!   preserved as trivia tokens (the SAFETY and waiver rules read them);
//! * string/char/byte literals, including raw strings `r#"..."#` with any
//!   number of `#`s (so `unwrap` inside a string never looks like a call);
//! * char-literal vs lifetime disambiguation (`'a'` vs `'a`);
//! * numbers with suffixes (`1.0f32`, `0xFF_u8`) without eating `..`.
//!
//! Everything else is an `Ident` or a single-char `Punct`. That is enough:
//! the rules match short token patterns (`.` `unwrap` `(`) rather than a
//! grammar.

/// Token classification. `Comment` tokens are trivia but are kept in the
/// stream because two rules (SAFETY adjacency, waivers) are *about* comments.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Punct,
    Literal,
    Lifetime,
    Comment,
}

/// One token. `line` is 1-based and points at the token's first character.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

impl Tok {
    pub fn is(&self, kind: TokKind, text: &str) -> bool {
        self.kind == kind && self.text == text
    }
}

fn is_ident_start(c: u8) -> bool {
    c.is_ascii_alphabetic() || c == b'_' || c >= 0x80
}

fn is_ident_continue(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_' || c >= 0x80
}

/// Lex `src` into a token stream. Never fails: malformed input (unterminated
/// string, stray byte) degrades to best-effort tokens — the lint runs on a
/// tree that `rustc` already accepted, so this only matters for fixtures.
pub fn lex(src: &str) -> Vec<Tok> {
    Lexer { b: src.as_bytes(), i: 0, line: 1, out: Vec::new() }.run()
}

struct Lexer<'a> {
    b: &'a [u8],
    i: usize,
    line: u32,
    out: Vec<Tok>,
}

impl<'a> Lexer<'a> {
    fn peek(&self, off: usize) -> u8 {
        *self.b.get(self.i + off).unwrap_or(&0)
    }

    fn push(&mut self, kind: TokKind, start: usize, line: u32) {
        let text = String::from_utf8_lossy(&self.b[start..self.i]).into_owned();
        self.out.push(Tok { kind, text, line });
    }

    /// Advance one byte, counting newlines. Used inside multi-line tokens.
    fn bump(&mut self) {
        if self.peek(0) == b'\n' {
            self.line += 1;
        }
        self.i += 1;
    }

    fn run(mut self) -> Vec<Tok> {
        while self.i < self.b.len() {
            let c = self.peek(0);
            match c {
                b'\n' => {
                    self.line += 1;
                    self.i += 1;
                }
                b' ' | b'\t' | b'\r' => self.i += 1,
                b'/' if self.peek(1) == b'/' => self.line_comment(),
                b'/' if self.peek(1) == b'*' => self.block_comment(),
                b'"' => self.string(),
                b'\'' => self.char_or_lifetime(),
                b'0'..=b'9' => self.number(),
                _ if is_ident_start(c) => self.ident_or_prefixed_literal(),
                _ => {
                    let (start, line) = (self.i, self.line);
                    self.i += 1;
                    self.push(TokKind::Punct, start, line);
                }
            }
        }
        self.out
    }

    fn line_comment(&mut self) {
        let (start, line) = (self.i, self.line);
        while self.i < self.b.len() && self.peek(0) != b'\n' {
            self.i += 1;
        }
        self.push(TokKind::Comment, start, line);
    }

    fn block_comment(&mut self) {
        let (start, line) = (self.i, self.line);
        self.i += 2;
        let mut depth = 1u32;
        while self.i < self.b.len() && depth > 0 {
            if self.peek(0) == b'/' && self.peek(1) == b'*' {
                depth += 1;
                self.i += 2;
            } else if self.peek(0) == b'*' && self.peek(1) == b'/' {
                depth -= 1;
                self.i += 2;
            } else {
                self.bump();
            }
        }
        self.push(TokKind::Comment, start, line);
    }

    /// Normal (non-raw) string body, cursor on the opening `"`.
    fn string(&mut self) {
        let (start, line) = (self.i, self.line);
        self.i += 1;
        while self.i < self.b.len() {
            match self.peek(0) {
                b'\\' => {
                    self.i += 1;
                    self.bump(); // escaped char (may be a newline continuation)
                }
                b'"' => {
                    self.i += 1;
                    break;
                }
                _ => self.bump(),
            }
        }
        self.push(TokKind::Literal, start, line);
    }

    /// Raw string body, cursor on the first `#` or `"` after the prefix.
    fn raw_string(&mut self, start: usize, line: u32) {
        let mut hashes = 0usize;
        while self.peek(0) == b'#' {
            hashes += 1;
            self.i += 1;
        }
        self.i += 1; // opening quote
        loop {
            if self.i >= self.b.len() {
                break;
            }
            if self.peek(0) == b'"' {
                let mut ok = true;
                for k in 0..hashes {
                    if self.peek(1 + k) != b'#' {
                        ok = false;
                        break;
                    }
                }
                if ok {
                    self.i += 1 + hashes;
                    break;
                }
            }
            self.bump();
        }
        self.push(TokKind::Literal, start, line);
    }

    /// `'a'` vs `'a` vs `'\n'`: a lifetime is `'` + ident not closed by `'`.
    fn char_or_lifetime(&mut self) {
        let (start, line) = (self.i, self.line);
        let c1 = self.peek(1);
        if is_ident_start(c1) && self.peek(2) != b'\'' {
            // lifetime: consume '<ident>
            self.i += 2;
            while is_ident_continue(self.peek(0)) {
                self.i += 1;
            }
            self.push(TokKind::Lifetime, start, line);
            return;
        }
        // char literal (possibly escaped)
        self.i += 1;
        if self.peek(0) == b'\\' {
            self.i += 2; // backslash + escape head ('\u{..}' closed below)
        } else {
            self.i += 1;
        }
        while self.i < self.b.len() && self.peek(0) != b'\'' {
            self.bump();
        }
        self.i += 1; // closing quote
        self.push(TokKind::Literal, start, line);
    }

    fn number(&mut self) {
        let (start, line) = (self.i, self.line);
        // integer part: digits, `_`, radix letters, type suffixes
        while is_ident_continue(self.peek(0)) {
            self.i += 1;
        }
        // fraction: only if `.` is followed by a digit (so `0..n` stays `..`)
        if self.peek(0) == b'.' && self.peek(1).is_ascii_digit() {
            self.i += 1;
            while is_ident_continue(self.peek(0)) {
                self.i += 1;
            }
        }
        self.push(TokKind::Literal, start, line);
    }

    /// An identifier — unless it is a raw/byte string prefix (`r"`, `r#"`,
    /// `b"`, `br#"`, `c"`) or a byte char (`b'x'`).
    fn ident_or_prefixed_literal(&mut self) {
        let (start, line) = (self.i, self.line);
        let c = self.peek(0);
        if c == b'r' || c == b'b' || c == b'c' {
            // scan the full prefix run (at most 2 chars: r, b, c, br, cr)
            let mut p = 1usize;
            if (c == b'b' || c == b'c') && self.peek(1) == b'r' {
                p = 2;
            }
            let after = self.peek(p);
            if after == b'"' && p == 1 && (c == b'b' || c == b'c') {
                // b"..." / c"...": normal-style body with escapes
                self.i += 1;
                self.string();
                // string() pushed with start at the quote; fix span start
                if let Some(t) = self.out.last_mut() {
                    t.text.insert(0, c as char);
                    t.line = line;
                }
                return;
            }
            if after == b'"' || after == b'#' {
                // raw string: r"..", r#".."#, br#".."#, cr".."
                self.i += p;
                self.raw_string(start, line);
                return;
            }
            if c == b'b' && self.peek(1) == b'\'' {
                // byte char b'x'
                self.i += 1;
                self.char_or_lifetime();
                if let Some(t) = self.out.last_mut() {
                    t.text.insert(0, 'b');
                    t.line = line;
                }
                return;
            }
        }
        while is_ident_continue(self.peek(0)) {
            self.i += 1;
        }
        self.push(TokKind::Ident, start, line);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_puncts_numbers() {
        let ts = kinds("let x = a.b(3) + 0x1F_u8;");
        let texts: Vec<&str> = ts.iter().map(|(_, s)| s.as_str()).collect();
        assert_eq!(texts, ["let", "x", "=", "a", ".", "b", "(", "3", ")", "+", "0x1F_u8", ";"]);
        assert_eq!(ts[7].0, TokKind::Literal);
        assert_eq!(ts[10].0, TokKind::Literal);
    }

    #[test]
    fn float_does_not_eat_range() {
        let texts: Vec<String> = lex("for i in 0..n { a = 1.5e3; }").into_iter().map(|t| t.text).collect();
        assert!(texts.contains(&"0".to_string()));
        assert!(texts.contains(&"1.5e3".to_string()));
        assert_eq!(texts.iter().filter(|t| *t == ".").count(), 2, "0..n keeps two dot puncts");
    }

    #[test]
    fn raw_string_hides_tokens() {
        let ts = kinds(r###"let s = r#"a.unwrap() "quoted" "#; done"###);
        assert!(ts.iter().any(|(k, s)| *k == TokKind::Literal && s.contains("unwrap")));
        assert!(ts.iter().any(|(k, s)| *k == TokKind::Ident && s == "done"));
        assert!(!ts.iter().any(|(k, s)| *k == TokKind::Ident && s == "unwrap"));
    }

    #[test]
    fn nested_block_comment() {
        let ts = kinds("a /* outer /* inner */ still comment */ b");
        assert_eq!(ts.len(), 3);
        assert_eq!(ts[1].0, TokKind::Comment);
        assert!(ts[1].1.contains("inner"));
        assert_eq!(ts[2].1, "b");
    }

    #[test]
    fn line_comment_and_line_numbers() {
        let ts = lex("a // one\nb /* two\nlines */ c");
        let c: Vec<(&str, u32)> = ts.iter().map(|t| (t.text.as_str(), t.line)).collect();
        assert_eq!(c[0], ("a", 1));
        assert_eq!(c[1], ("// one", 1));
        assert_eq!(c[2], ("b", 2));
        assert_eq!(c[4].1, 3, "token after multi-line comment lands on line 3");
    }

    #[test]
    fn char_vs_lifetime() {
        let ts = kinds("fn f<'a>(x: &'a str) { let c = 'x'; let n = '\\n'; let s: &'static str = \"\"; }");
        let lifetimes: Vec<&str> =
            ts.iter().filter(|(k, _)| *k == TokKind::Lifetime).map(|(_, s)| s.as_str()).collect();
        assert_eq!(lifetimes, ["'a", "'a", "'static"]);
        let chars: Vec<&str> = ts
            .iter()
            .filter(|(k, s)| *k == TokKind::Literal && s.starts_with('\''))
            .map(|(_, s)| s.as_str())
            .collect();
        assert_eq!(chars, ["'x'", "'\\n'"]);
    }

    #[test]
    fn byte_literals() {
        let ts = kinds(r##"let a = b"raw"; let c = b'\n'; let d = br#"x.unwrap()"#;"##);
        assert!(ts.iter().any(|(k, s)| *k == TokKind::Literal && s.starts_with("b\"")));
        assert!(ts.iter().any(|(k, s)| *k == TokKind::Literal && s.starts_with("b'")));
        assert!(!ts.iter().any(|(k, s)| *k == TokKind::Ident && s == "unwrap"));
    }

    #[test]
    fn string_with_escapes_and_newlines() {
        let ts = lex("let s = \"a \\\" b\nc\"; z");
        let z = ts.iter().find(|t| t.text == "z").expect("z survives");
        assert_eq!(z.line, 2);
        assert!(ts.iter().any(|t| t.kind == TokKind::Literal && t.text.contains("a \\\" b")));
    }
}
