//! Diagnostic formatting for `pallas-lint`: stable `file:line: rule: msg`
//! lines (sorted, deterministic) plus a per-rule summary table.

use std::collections::BTreeMap;

use super::baseline::Regression;
use super::rules::{Finding, Severity};

/// `src/kv/mod.rs:124: hot-panic: ...` — one line per finding, sorted by
/// (path, line, rule) so output is diff-stable.
pub fn format_findings(findings: &[Finding]) -> String {
    let mut fs: Vec<&Finding> = findings.iter().collect();
    fs.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    let mut out = String::new();
    for f in fs {
        out.push_str(&format!("{}:{}: {}: {}\n", f.path, f.line, f.rule, f.msg));
    }
    out
}

pub fn format_regressions(regs: &[Regression]) -> String {
    let mut out = String::new();
    for r in regs {
        out.push_str(&format!(
            "{}: {}: ratchet regression: {} -> {} sites (baseline allows {})\n",
            r.path, r.rule, r.was, r.now, r.was
        ));
    }
    out
}

/// Per-rule counts, deny rules first.
pub fn summary(findings: &[Finding]) -> String {
    let mut by_rule: BTreeMap<(bool, &'static str), usize> = BTreeMap::new();
    for f in findings {
        *by_rule.entry((f.severity == Severity::Ratchet, f.rule)).or_insert(0) += 1;
    }
    let mut out = String::new();
    for ((ratchet, rule), n) in by_rule {
        let tier = if ratchet { "ratchet" } else { "deny" };
        out.push_str(&format!("  {rule:<16} {tier:<8} {n}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::rules::severity_of;
    use super::*;

    #[test]
    fn findings_are_sorted_and_formatted() {
        let fs = vec![
            Finding {
                rule: "hot-panic",
                severity: severity_of("hot-panic"),
                path: "b.rs".into(),
                line: 2,
                msg: "m1".into(),
            },
            Finding {
                rule: "nan-cmp",
                severity: severity_of("nan-cmp"),
                path: "a.rs".into(),
                line: 9,
                msg: "m2".into(),
            },
        ];
        let text = format_findings(&fs);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "a.rs:9: nan-cmp: m2");
        assert_eq!(lines[1], "b.rs:2: hot-panic: m1");
        assert!(summary(&fs).contains("hot-panic"));
    }
}
