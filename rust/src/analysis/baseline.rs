//! The ratchet baseline: committed per-(rule, file) counts for `Ratchet`
//! severity rules. `--check` fails when any count grows; `--write-baseline`
//! records the current counts (intentional ratchet updates go through code
//! review like any other diff).

use std::collections::BTreeMap;
use std::io;
use std::path::Path;

use super::rules::{severity_of, Finding, Severity, RULES};

/// `(rule, path) -> count`, ordered for deterministic serialization.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct Baseline {
    pub counts: BTreeMap<(String, String), usize>,
}

/// One ratchet regression: a (rule, file) pair whose count grew.
#[derive(Debug, Clone)]
pub struct Regression {
    pub rule: String,
    pub path: String,
    pub was: usize,
    pub now: usize,
}

impl Baseline {
    /// Count the `Ratchet`-severity findings in `findings`.
    pub fn from_findings(findings: &[Finding]) -> Baseline {
        let mut counts: BTreeMap<(String, String), usize> = BTreeMap::new();
        for f in findings {
            if f.severity == Severity::Ratchet {
                *counts.entry((f.rule.to_string(), f.path.clone())).or_insert(0) += 1;
            }
        }
        Baseline { counts }
    }

    /// Parse the committed baseline file. A missing file is an empty
    /// baseline (every ratchet site then reads as a regression, which is
    /// the safe failure mode for a gate).
    pub fn load(path: &Path) -> io::Result<Baseline> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(Baseline::default()),
            Err(e) => return Err(e),
        };
        Self::parse(&text).map_err(|msg| io::Error::new(io::ErrorKind::InvalidData, msg))
    }

    pub fn parse(text: &str) -> Result<Baseline, String> {
        let mut counts = BTreeMap::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let (rule, count, path) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
                (Some(r), Some(c), Some(p), None) => (r, c, p),
                _ => return Err(format!("baseline line {}: expected `<rule> <count> <path>`", ln + 1)),
            };
            if !RULES.contains(&rule) {
                return Err(format!("baseline line {}: unknown rule `{rule}`", ln + 1));
            }
            if severity_of(rule) != Severity::Ratchet {
                return Err(format!(
                    "baseline line {}: `{rule}` is a deny rule and cannot be baselined",
                    ln + 1
                ));
            }
            let n: usize = count
                .parse()
                .map_err(|_| format!("baseline line {}: bad count `{count}`", ln + 1))?;
            counts.insert((rule.to_string(), path.to_string()), n);
        }
        Ok(Baseline { counts })
    }

    pub fn serialize(&self) -> String {
        let mut out = String::from(
            "# pallas-lint ratchet baseline: `<rule> <count> <path>` per line.\n\
             # Counts may only decrease. Regenerate intentionally with:\n\
             #   cargo run --bin pallas-lint -- --write-baseline\n",
        );
        for ((rule, path), n) in &self.counts {
            out.push_str(&format!("{rule} {n} {path}\n"));
        }
        out
    }

    /// Ratchet comparison: every (rule, file) whose current count exceeds
    /// the baselined count (absent entries baseline at 0).
    pub fn regressions(&self, current: &Baseline) -> Vec<Regression> {
        let mut out = Vec::new();
        for ((rule, path), &now) in &current.counts {
            let was = self.counts.get(&(rule.clone(), path.clone())).copied().unwrap_or(0);
            if now > was {
                out.push(Regression { rule: rule.clone(), path: path.clone(), was, now });
            }
        }
        out
    }

    /// Entries whose counts dropped (or whose files went clean) — candidates
    /// for a `--write-baseline` tightening pass.
    pub fn improvements(&self, current: &Baseline) -> Vec<(String, String, usize, usize)> {
        let mut out = Vec::new();
        for ((rule, path), &was) in &self.counts {
            let now = current.counts.get(&(rule.clone(), path.clone())).copied().unwrap_or(0);
            if now < was {
                out.push((rule.clone(), path.clone(), was, now));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finding(rule: &'static str, path: &str) -> Finding {
        Finding { rule, severity: severity_of(rule), path: path.to_string(), line: 1, msg: String::new() }
    }

    #[test]
    fn roundtrip() {
        let fs = vec![
            finding("unwrap-ratchet", "a.rs"),
            finding("unwrap-ratchet", "a.rs"),
            finding("narrow-cast", "b.rs"),
            finding("hot-panic", "c.rs"), // deny: not baselined
        ];
        let b = Baseline::from_findings(&fs);
        assert_eq!(b.counts.len(), 2);
        let text = b.serialize();
        let b2 = Baseline::parse(&text).expect("parse back");
        assert_eq!(b, b2);
    }

    #[test]
    fn ratchet_detects_growth_only() {
        let old = Baseline::parse("unwrap-ratchet 2 a.rs\nnarrow-cast 3 b.rs\n").expect("old");
        // a.rs grew 2 -> 3; b.rs shrank 3 -> 1; c.rs is new.
        let cur = Baseline::parse("unwrap-ratchet 3 a.rs\nnarrow-cast 1 b.rs\nunwrap-ratchet 1 c.rs\n")
            .expect("cur");
        let regs = old.regressions(&cur);
        let keys: Vec<(&str, &str, usize, usize)> =
            regs.iter().map(|r| (r.rule.as_str(), r.path.as_str(), r.was, r.now)).collect();
        assert_eq!(keys, [("unwrap-ratchet", "a.rs", 2, 3), ("unwrap-ratchet", "c.rs", 0, 1)]);
        let imps = old.improvements(&cur);
        assert_eq!(imps.len(), 1);
        assert_eq!(imps[0].3, 1);
    }

    #[test]
    fn deny_rules_rejected_in_baseline() {
        assert!(Baseline::parse("hot-panic 1 a.rs\n").is_err());
        assert!(Baseline::parse("no-such-rule 1 a.rs\n").is_err());
        assert!(Baseline::parse("unwrap-ratchet nope a.rs\n").is_err());
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let b = Baseline::parse("# header\n\nunwrap-ratchet 4 x.rs\n").expect("parse");
        assert_eq!(b.counts.get(&("unwrap-ratchet".into(), "x.rs".into())), Some(&4));
    }
}
