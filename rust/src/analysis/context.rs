//! Structural context over the flat token stream: which tokens live inside
//! `#[cfg(test)]`/`#[test]` code, and which lines are covered by inline
//! `// lint: allow(<rules>): <reason>` waivers.
//!
//! Both are computed with a single brace-tracking pass — no parser. The
//! tracking is deliberately conservative in the directions that matter for a
//! gate: unknown attribute shapes never *exempt* code, and malformed waivers
//! are themselves diagnostics (`bad-waiver`) rather than silent no-ops.

use super::lexer::{Tok, TokKind};

/// A parsed `// lint: allow(rule-a, rule-b): reason` waiver and the line
/// range it suppresses.
///
/// * A **trailing** waiver (comment after code on the same line) covers
///   exactly that line.
/// * An **own-line** waiver covers from its line through the end of the next
///   braced block that opens after it (a whole `fn`, `impl`, loop, ...), or
///   through the next `;` at the same depth if one comes first (a single
///   statement). This mirrors how `#[allow]` attaches to the next item.
#[derive(Debug, Clone)]
pub struct Waiver {
    pub rules: Vec<String>,
    pub reason: String,
    pub start_line: u32,
    pub end_line: u32,
}

impl Waiver {
    pub fn covers(&self, rule: &str, line: u32) -> bool {
        line >= self.start_line
            && line <= self.end_line
            && self.rules.iter().any(|r| r == rule)
    }
}

/// Context for one file: per-token test flags plus the waiver table.
pub struct FileContext {
    /// Parallel to the token stream: `true` when the token is inside a
    /// `#[cfg(test)]` / `#[test]` region.
    pub in_test: Vec<bool>,
    pub waivers: Vec<Waiver>,
    /// Malformed waivers: `(line, message)`. Reported as `bad-waiver`.
    pub bad_waivers: Vec<(u32, String)>,
}

struct Scope {
    test: bool,
    /// Indices into `waivers` that close when this scope's `}` closes.
    waiver_ids: Vec<usize>,
}

/// `// lint: allow(rule-a, rule-b): reason` → rules + reason.
/// Returns `Err(message)` on anything that *looks* like a waiver (starts
/// with `lint:`) but doesn't parse — those become `bad-waiver` findings so a
/// typo can't silently disable a rule.
fn parse_waiver(comment: &str, known_rules: &[&str]) -> Option<Result<(Vec<String>, String), String>> {
    let body = comment.trim_start_matches('/').trim();
    let rest = body.strip_prefix("lint:")?.trim();
    let inner = match rest.strip_prefix("allow") {
        Some(r) => r.trim(),
        None => return Some(Err(format!("expected `allow(...)` after `lint:`, got `{rest}`"))),
    };
    let Some(open) = inner.strip_prefix('(') else {
        return Some(Err("expected `(` after `allow`".into()));
    };
    let Some(close) = open.find(')') else {
        return Some(Err("unclosed `allow(`".into()));
    };
    let rules: Vec<String> =
        open[..close].split(',').map(|r| r.trim().to_string()).filter(|r| !r.is_empty()).collect();
    if rules.is_empty() {
        return Some(Err("empty rule list in `allow()`".into()));
    }
    for r in &rules {
        if !known_rules.contains(&r.as_str()) {
            return Some(Err(format!("unknown rule `{r}` in waiver")));
        }
    }
    let after = open[close + 1..].trim();
    let Some(reason) = after.strip_prefix(':') else {
        return Some(Err("waiver must carry a reason: `allow(rule): why this is sound`".into()));
    };
    let reason = reason.trim();
    if reason.is_empty() {
        return Some(Err("waiver reason is empty".into()));
    }
    Some(Ok((rules, reason.to_string())))
}

/// One pass over the token stream computing test regions and waiver spans.
pub fn analyze(tokens: &[Tok], known_rules: &[&str]) -> FileContext {
    let mut in_test = vec![false; tokens.len()];
    let mut waivers: Vec<Waiver> = Vec::new();
    let mut bad_waivers: Vec<(u32, String)> = Vec::new();

    let mut stack: Vec<Scope> = Vec::new();
    let mut cur_test = false;
    // `#[cfg(test)]` seen, waiting for the `{` (or `;`) it attaches to.
    let mut pending_test = false;
    // Own-line waivers waiting for their first `{` or `;`.
    let mut pending_waivers: Vec<usize> = Vec::new();
    let mut last_code_line = 0u32;

    let mut i = 0usize;
    while i < tokens.len() {
        let t = &tokens[i];
        if t.kind == TokKind::Comment {
            if let Some(parsed) = parse_waiver(&t.text, known_rules) {
                match parsed {
                    Ok((rules, reason)) => {
                        let trailing = t.line == last_code_line;
                        let w = Waiver {
                            rules,
                            reason,
                            start_line: t.line,
                            // Trailing waivers cover their own line only.
                            // Own-line spans are extended when the block they
                            // attach to closes; EOF leaves them open-ended.
                            end_line: if trailing { t.line } else { u32::MAX },
                        };
                        waivers.push(w);
                        if !trailing {
                            pending_waivers.push(waivers.len() - 1);
                        }
                    }
                    Err(msg) => bad_waivers.push((t.line, msg)),
                }
            }
            // Comments inherit the current region for uniformity.
            in_test[i] = cur_test;
            i += 1;
            continue;
        }

        in_test[i] = cur_test;
        last_code_line = t.line;

        match (t.kind, t.text.as_str()) {
            (TokKind::Punct, "#") => {
                // Attribute: `#[...]` or `#![...]`. Scan the bracket group
                // without brace tracking (attrs may contain arbitrary
                // tokens) and look for a `test` ident, which covers both
                // `#[cfg(test)]` and `#[test]`. `not` anywhere in the group
                // (`#[cfg(not(test))]`) keeps the region non-test — the
                // conservative direction for a lint gate.
                let mut j = i + 1;
                if j < tokens.len() && tokens[j].is(TokKind::Punct, "!") {
                    j += 1;
                }
                if j < tokens.len() && tokens[j].is(TokKind::Punct, "[") {
                    let mut depth = 0i32;
                    let mut has_test = false;
                    let mut has_not = false;
                    while j < tokens.len() {
                        let a = &tokens[j];
                        in_test[j] = cur_test;
                        match (a.kind, a.text.as_str()) {
                            (TokKind::Punct, "[") => depth += 1,
                            (TokKind::Punct, "]") => {
                                depth -= 1;
                                if depth == 0 {
                                    break;
                                }
                            }
                            (TokKind::Ident, "test") => has_test = true,
                            (TokKind::Ident, "not") => has_not = true,
                            _ => {}
                        }
                        j += 1;
                    }
                    if has_test && !has_not {
                        pending_test = true;
                    }
                    i = j + 1;
                    continue;
                }
            }
            (TokKind::Punct, "{") => {
                stack.push(Scope {
                    test: cur_test,
                    waiver_ids: std::mem::take(&mut pending_waivers),
                });
                cur_test = cur_test || pending_test;
                pending_test = false;
            }
            (TokKind::Punct, "}") => {
                if let Some(sc) = stack.pop() {
                    cur_test = sc.test;
                    for id in sc.waiver_ids {
                        if let Some(w) = waivers.get_mut(id) {
                            w.end_line = t.line;
                        }
                    }
                }
            }
            (TokKind::Punct, ";") => {
                // An item ended without a body: `#[cfg(test)] use x;` etc.
                pending_test = false;
                for id in pending_waivers.drain(..) {
                    if let Some(w) = waivers.get_mut(id) {
                        w.end_line = t.line;
                    }
                }
            }
            _ => {}
        }
        i += 1;
    }

    FileContext { in_test, waivers, bad_waivers }
}

impl FileContext {
    pub fn is_waived(&self, rule: &str, line: u32) -> bool {
        self.waivers.iter().any(|w| w.covers(rule, line))
    }
}

#[cfg(test)]
mod tests {
    use super::super::lexer::lex;
    use super::*;

    const RULES: &[&str] = &["hot-panic", "hot-index", "nan-cmp"];

    fn ctx(src: &str) -> (Vec<crate::analysis::lexer::Tok>, FileContext) {
        let ts = lex(src);
        let c = analyze(&ts, RULES);
        (ts, c)
    }

    fn test_flag_of(src: &str, ident: &str) -> bool {
        let (ts, c) = ctx(src);
        let idx = ts
            .iter()
            .position(|t| t.text == ident)
            .unwrap_or_else(|| panic!("ident {ident} not found"));
        c.in_test[idx]
    }

    #[test]
    fn cfg_test_module_is_test() {
        let src = "fn live() { a(); }\n#[cfg(test)]\nmod tests { fn t() { b(); } }\nfn live2() { c(); }";
        assert!(!test_flag_of(src, "a"));
        assert!(test_flag_of(src, "b"));
        assert!(!test_flag_of(src, "c"));
    }

    #[test]
    fn test_attr_fn_is_test_and_nested_braces_stay_test() {
        let src = "#[test]\nfn t() { if x { y(); } }\nfn live() { z(); }";
        assert!(test_flag_of(src, "y"));
        assert!(!test_flag_of(src, "z"));
    }

    #[test]
    fn cfg_not_test_is_not_test() {
        let src = "#[cfg(not(test))]\nfn live() { a(); }";
        assert!(!test_flag_of(src, "a"));
    }

    #[test]
    fn attr_use_semicolon_does_not_leak() {
        let src = "#[cfg(test)]\nuse foo::bar;\nfn live() { a(); }";
        assert!(!test_flag_of(src, "a"));
    }

    #[test]
    fn trailing_waiver_covers_its_line_only() {
        let src = "fn f() {\n  x(); // lint: allow(hot-panic): startup only\n  y();\n}";
        let (_, c) = ctx(src);
        assert!(c.is_waived("hot-panic", 2));
        assert!(!c.is_waived("hot-panic", 3));
        assert!(!c.is_waived("hot-index", 2), "only the named rule is waived");
    }

    #[test]
    fn own_line_waiver_covers_next_block() {
        let src = "// lint: allow(hot-index): bounds documented below\nfn kernel() {\n  a[i];\n}\nfn next() { b[i]; }";
        let (_, c) = ctx(src);
        assert!(c.is_waived("hot-index", 3));
        assert!(!c.is_waived("hot-index", 5), "waiver ends at the fn's closing brace");
    }

    #[test]
    fn own_line_waiver_before_statement_ends_at_semicolon() {
        let src = "fn f() {\n  // lint: allow(hot-panic): const table\n  let x = t.unwrap();\n  let y = u.unwrap();\n}";
        let (_, c) = ctx(src);
        assert!(c.is_waived("hot-panic", 3));
        assert!(!c.is_waived("hot-panic", 4));
    }

    #[test]
    fn bad_waivers_are_reported() {
        for (src, needle) in [
            ("// lint: allow(hot-panic)\nfn f() {}", "reason"),
            ("// lint: allow(no-such-rule): x\nfn f() {}", "unknown rule"),
            ("// lint: allow(): x\nfn f() {}", "empty rule list"),
            ("// lint: deny(hot-panic): x\nfn f() {}", "expected `allow"),
        ] {
            let (_, c) = ctx(src);
            assert_eq!(c.bad_waivers.len(), 1, "src: {src}");
            assert!(c.bad_waivers[0].1.contains(needle), "{} !~ {}", c.bad_waivers[0].1, needle);
            assert!(!c.is_waived("hot-panic", 1) && !c.is_waived("hot-panic", 2));
        }
    }

    #[test]
    fn multi_rule_waiver() {
        let src = "fn f() {\n  a[i].unwrap(); // lint: allow(hot-panic, hot-index): fixture setup\n}";
        let (_, c) = ctx(src);
        assert!(c.is_waived("hot-panic", 2));
        assert!(c.is_waived("hot-index", 2));
    }
}
