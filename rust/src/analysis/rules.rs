//! The repo-specific rule catalog.
//!
//! Two tiers:
//!
//! * **Deny** rules must be at zero (after explicit waivers) for the tree to
//!   pass: `hot-panic`, `hot-index`, `safety-comment`, `nan-cmp`,
//!   `bad-waiver`.
//! * **Ratchet** rules (`unwrap-ratchet`, `narrow-cast`) are counted against
//!   the committed baseline: counts may only decrease. New code can't add
//!   sites, old code doesn't block landing.
//!
//! Rules are token-pattern matchers over the lexer stream — no type info.
//! Where that forces a judgment call the rule takes the conservative
//! direction for a gate (flag it; a waiver with a written reason is the
//! escape hatch).

use super::context::{analyze, FileContext};
use super::lexer::{lex, Tok, TokKind};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    /// Must be zero (modulo waivers) — the build gate fails on any hit.
    Deny,
    /// Counted per (rule, file) against the ratchet baseline.
    Ratchet,
}

#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub severity: Severity,
    pub path: String,
    pub line: u32,
    pub msg: String,
}

/// All rule names, for waiver validation and baseline sanity checks.
pub const RULES: &[&str] = &[
    "hot-panic",
    "hot-index",
    "safety-comment",
    "nan-cmp",
    "narrow-cast",
    "unwrap-ratchet",
    "bad-waiver",
];

pub fn severity_of(rule: &str) -> Severity {
    match rule {
        "unwrap-ratchet" | "narrow-cast" => Severity::Ratchet,
        _ => Severity::Deny,
    }
}

/// Which files get which rules. Paths are matched as `/`-normalized
/// suffixes, so the same config works for the real tree (`kv/mod.rs`
/// relative to `src/`) and for fixture trees that mirror the layout.
pub struct LintConfig {
    /// No-panic hot paths: scheduler tick loop, native forward pass,
    /// compute kernels, KV append/spill paths.
    pub hot_modules: Vec<&'static str>,
    /// Byte-accounting / serialization modules where a silently narrowing
    /// `as` cast re-introduces the PR 2 header-overflow bug class.
    pub accounting_modules: Vec<&'static str>,
}

impl Default for LintConfig {
    fn default() -> Self {
        LintConfig {
            hot_modules: vec![
                "coordinator/scheduler.rs",
                "model/native.rs",
                "cpu/attention.rs",
                "cpu/gemm_q.rs",
                "cpu/backend.rs",
                "kv/mod.rs",
                "kv/paged.rs",
                "memory/hybrid.rs",
            ],
            accounting_modules: vec!["model/weights.rs", "memory/weight_store.rs", "kv/paged.rs"],
        }
    }
}

fn suffix_match(path: &str, suffixes: &[&str]) -> bool {
    suffixes.iter().any(|s| path == *s || path.ends_with(&format!("/{s}")))
}

impl LintConfig {
    pub fn is_hot(&self, path: &str) -> bool {
        suffix_match(path, &self.hot_modules)
    }
    pub fn is_accounting(&self, path: &str) -> bool {
        suffix_match(path, &self.accounting_modules)
    }
}

/// Idents that can legally precede `[` without it being an index expression.
const PRE_BRACKET_KEYWORDS: &[&str] = &[
    "mut", "ref", "in", "as", "return", "else", "match", "if", "while", "for", "loop", "move",
    "dyn", "impl", "where", "break", "continue", "unsafe", "let", "const", "static", "box",
];

/// Modifier idents allowed between a `// SAFETY:` comment and the `unsafe`
/// keyword it documents (`pub const unsafe fn`, `pub(crate) unsafe`, ...).
const UNSAFE_MODIFIERS: &[&str] = &["pub", "crate", "super", "self", "in", "const", "extern"];

/// Lint one file's source. `path` is the `/`-normalized path used both for
/// module matching and in diagnostics.
pub fn check_file(path: &str, src: &str, cfg: &LintConfig) -> Vec<Finding> {
    let tokens = lex(src);
    let ctx = analyze(&tokens, RULES);
    let hot = cfg.is_hot(path);
    let accounting = cfg.is_accounting(path);

    let mut out: Vec<Finding> = Vec::new();
    let mut push = |rule: &'static str, line: u32, msg: String| {
        out.push(Finding { rule, severity: severity_of(rule), path: path.to_string(), line, msg });
    };

    for (line, msg) in &ctx.bad_waivers {
        push("bad-waiver", *line, msg.clone());
    }

    // Code-token view: indices into `tokens` with comments stripped, so the
    // pattern matchers can look at real neighbors.
    let code: Vec<usize> = (0..tokens.len()).filter(|&i| tokens[i].kind != TokKind::Comment).collect();
    let tok = |ci: usize| -> &Tok { &tokens[code[ci]] };
    let in_test = |ci: usize| -> bool { ctx.in_test[code[ci]] };

    for ci in 0..code.len() {
        let t = tok(ci);

        // --- panic-family calls: `.unwrap(` / `.expect(` --------------------
        if t.kind == TokKind::Ident && (t.text == "unwrap" || t.text == "expect") {
            let dotted = ci > 0 && tok(ci - 1).is(TokKind::Punct, ".");
            let called = ci + 1 < code.len() && tok(ci + 1).is(TokKind::Punct, "(");
            if dotted && called {
                // `partial_cmp(..).unwrap()` is its own (stricter) rule:
                // NaN panics, and it bites test code too.
                let nan = preceding_call_is(&tokens, &code, ci - 1, "partial_cmp");
                if nan {
                    push(
                        "nan-cmp",
                        t.line,
                        format!("`partial_cmp(..).{}()` panics on NaN; use `total_cmp`", t.text),
                    );
                } else if !in_test(ci) {
                    if hot {
                        push(
                            "hot-panic",
                            t.line,
                            format!(
                                "`.{}()` in a no-panic hot path; propagate an error or fall back",
                                t.text
                            ),
                        );
                    } else {
                        push("unwrap-ratchet", t.line, format!("`.{}()` outside tests", t.text));
                    }
                }
            }
        }

        // --- panic-family macros -------------------------------------------
        if hot
            && !in_test(ci)
            && t.kind == TokKind::Ident
            && matches!(t.text.as_str(), "panic" | "unreachable" | "todo" | "unimplemented")
            && ci + 1 < code.len()
            && tok(ci + 1).is(TokKind::Punct, "!")
        {
            push(
                "hot-panic",
                t.line,
                format!("`{}!` in a no-panic hot path; use `debug_assert!` + graceful fallback", t.text),
            );
        }

        // --- direct slice indexing in hot paths ----------------------------
        if hot && !in_test(ci) && t.is(TokKind::Punct, "[") && ci > 0 {
            let p = tok(ci - 1);
            let indexes_expr = match p.kind {
                TokKind::Ident => !PRE_BRACKET_KEYWORDS.contains(&p.text.as_str()),
                TokKind::Punct => p.text == ")" || p.text == "]" || p.text == "?",
                _ => false,
            };
            if indexes_expr && !bracket_contains_range(&tokens, &code, ci) {
                push(
                    "hot-index",
                    t.line,
                    "direct indexing in a no-panic hot path; use `.get()`/iterators or waive with \
                     documented bounds"
                        .to_string(),
                );
            }
        }

        // --- SAFETY comments on unsafe -------------------------------------
        if t.is(TokKind::Ident, "unsafe") && !has_safety_comment(&tokens, code[ci]) {
            push(
                "safety-comment",
                t.line,
                "`unsafe` must be immediately preceded by a `// SAFETY:` comment stating its \
                 preconditions"
                    .to_string(),
            );
        }

        // --- narrowing `as` casts in accounting modules --------------------
        if accounting && !in_test(ci) && t.is(TokKind::Ident, "as") && ci + 1 < code.len() {
            let target = tok(ci + 1);
            if target.kind == TokKind::Ident
                && matches!(target.text.as_str(), "u8" | "u16" | "u32" | "i8" | "i16" | "i32")
            {
                push(
                    "narrow-cast",
                    t.line,
                    format!("narrowing `as {}` in an accounting module; use `try_from`", target.text),
                );
            }
        }
    }

    // Apply waivers (bad-waiver itself cannot be waived).
    out.retain(|f| f.rule == "bad-waiver" || !ctx.is_waived(f.rule, f.line));
    out
}

/// Walking back from the `.` before `unwrap`, was the receiver a
/// `partial_cmp(...)` call? Handles the common shapes
/// `a.partial_cmp(b).unwrap()` and `partial_cmp(&x).unwrap()`.
fn preceding_call_is(tokens: &[Tok], code: &[usize], dot_ci: usize, callee: &str) -> bool {
    // Expect `)` right before the dot, then match backwards to its `(`, then
    // the callee ident.
    if dot_ci == 0 {
        return false;
    }
    let mut ci = dot_ci - 1;
    if !tokens[code[ci]].is(TokKind::Punct, ")") {
        return false;
    }
    let mut depth = 0i32;
    loop {
        let t = &tokens[code[ci]];
        if t.is(TokKind::Punct, ")") {
            depth += 1;
        } else if t.is(TokKind::Punct, "(") {
            depth -= 1;
            if depth == 0 {
                break;
            }
        }
        if ci == 0 {
            return false;
        }
        ci -= 1;
    }
    ci > 0 && tokens[code[ci - 1]].is(TokKind::Ident, callee)
}

/// Does the bracket group opening at code index `open_ci` contain a `..`
/// (two adjacent `.` puncts) at depth 1? Range slicing (`buf[a..b]`) panics
/// too, but it is how every kernel expresses tile windows — the hot-index
/// rule targets scalar element access, where `.get()` is a drop-in.
fn bracket_contains_range(tokens: &[Tok], code: &[usize], open_ci: usize) -> bool {
    let mut depth = 0i32;
    let mut ci = open_ci;
    while ci < code.len() {
        let t = &tokens[code[ci]];
        match (t.kind, t.text.as_str()) {
            (TokKind::Punct, "[") | (TokKind::Punct, "(") => depth += 1,
            (TokKind::Punct, "]") | (TokKind::Punct, ")") => {
                depth -= 1;
                if depth == 0 {
                    return false;
                }
            }
            (TokKind::Punct, ".") if depth == 1 => {
                if ci + 1 < code.len() && tokens[code[ci + 1]].is(TokKind::Punct, ".") {
                    return true;
                }
            }
            _ => {}
        }
        ci += 1;
    }
    false
}

/// Is the `unsafe` token at absolute index `ti` immediately preceded by a
/// `// SAFETY:` (or `/* SAFETY: */`) comment? Attributes
/// (`#[target_feature(...)]`) and visibility/linkage modifiers may sit
/// between the comment and the keyword, and — matching clippy's
/// `undocumented_unsafe_blocks` — so may the rest of the `unsafe` token's
/// own line (`let y = unsafe { .. }` documents above the `let`).
fn has_safety_comment(tokens: &[Tok], ti: usize) -> bool {
    let uline = tokens.get(ti).map_or(0, |t| t.line);
    let mut i = ti;
    while i > 0 {
        i -= 1;
        let t = &tokens[i];
        match t.kind {
            TokKind::Comment => {
                if t.text.contains("SAFETY:") {
                    return true;
                }
                // A non-SAFETY comment between: keep looking upward — doc
                // comments often sit above the SAFETY line.
                continue;
            }
            // A statement boundary ends the search even mid-line: the second
            // `unsafe` in `unsafe { a() } unsafe { b() }` documents itself.
            TokKind::Punct if t.text == ";" || t.text == "}" => return false,
            _ if t.line == uline => continue,
            TokKind::Ident if UNSAFE_MODIFIERS.contains(&t.text.as_str()) => continue,
            TokKind::Punct if t.text == ")" || t.text == "(" => continue, // pub(crate)
            TokKind::Punct if t.text == "]" => {
                // Skip a whole attribute group `#[...]` backwards.
                let mut depth = 0i32;
                loop {
                    let a = &tokens[i];
                    if a.is(TokKind::Punct, "]") {
                        depth += 1;
                    } else if a.is(TokKind::Punct, "[") {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    if i == 0 {
                        return false;
                    }
                    i -= 1;
                }
                // Optional `!` then `#`.
                if i > 0 && tokens[i - 1].is(TokKind::Punct, "!") {
                    i -= 1;
                }
                if i > 0 && tokens[i - 1].is(TokKind::Punct, "#") {
                    i -= 1;
                    continue;
                }
                return false;
            }
            TokKind::Literal if t.text.starts_with('"') => continue, // extern "C"
            _ => return false,
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_hot(src: &str) -> Vec<Finding> {
        check_file("kv/mod.rs", src, &LintConfig::default())
    }
    fn run_cold(src: &str) -> Vec<Finding> {
        check_file("util/stats.rs", src, &LintConfig::default())
    }
    fn rules_of(fs: &[Finding]) -> Vec<&'static str> {
        fs.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn unwrap_in_hot_path_denied() {
        let fs = run_hot("fn f() { x.unwrap(); y.expect(\"m\"); }");
        assert_eq!(rules_of(&fs), ["hot-panic", "hot-panic"]);
        assert_eq!(fs[0].severity, Severity::Deny);
    }

    #[test]
    fn unwrap_in_cold_path_is_ratcheted() {
        let fs = run_cold("fn f() { x.unwrap(); }");
        assert_eq!(rules_of(&fs), ["unwrap-ratchet"]);
        assert_eq!(fs[0].severity, Severity::Ratchet);
    }

    #[test]
    fn unwrap_in_tests_exempt() {
        let src = "#[cfg(test)]\nmod tests { fn t() { x.unwrap(); a[i]; panic!(); } }";
        assert!(run_hot(src).is_empty());
    }

    #[test]
    fn unwrap_or_variants_not_flagged() {
        let src = "fn f() { x.unwrap_or(0); y.unwrap_or_else(|| 0); z.unwrap_or_default(); }";
        assert!(run_hot(src).is_empty());
    }

    #[test]
    fn panic_macros_denied_assert_allowed() {
        let fs = run_hot("fn f() { assert!(x); debug_assert!(y); unreachable!(); todo!(); }");
        assert_eq!(rules_of(&fs), ["hot-panic", "hot-panic"]);
    }

    #[test]
    fn scalar_index_denied_ranges_allowed() {
        let fs = run_hot("fn f(a: &[f32]) { let x = a[i]; let s = &a[b..e]; let t = &a[..n]; }");
        assert_eq!(rules_of(&fs), ["hot-index"]);
        assert_eq!(fs[0].line, 1);
    }

    #[test]
    fn non_index_brackets_not_flagged() {
        let src = "fn f() -> [f32; 4] { let v: Vec<[u8; 2]> = vec![[0; 2]; 3]; let a = [1, 2]; \
                   let b: &mut [f32] = c; #[allow(dead_code)] struct S; a }";
        let fs = run_hot(src);
        assert!(fs.is_empty(), "got: {fs:?}");
    }

    #[test]
    fn chained_and_nested_index() {
        let fs = run_hot("fn f() { m[i][j]; g(h[k]); }");
        assert_eq!(rules_of(&fs), ["hot-index", "hot-index", "hot-index"]);
    }

    #[test]
    fn nan_cmp_denied_everywhere_even_tests() {
        let fs = run_cold("fn f() { v.sort_by(|a, b| a.partial_cmp(b).unwrap()); }");
        assert_eq!(rules_of(&fs), ["nan-cmp"]);
        let fs = run_cold("#[cfg(test)]\nmod t { fn f() { a.partial_cmp(&b).unwrap(); } }");
        assert_eq!(rules_of(&fs), ["nan-cmp"]);
    }

    #[test]
    fn nan_cmp_not_confused_by_other_calls() {
        let fs = run_cold("fn f() { total_cmp(a).unwrap(); x.partial_cmp(b); }");
        assert_eq!(rules_of(&fs), ["unwrap-ratchet"]);
    }

    #[test]
    fn safety_comment_required_and_satisfied() {
        let bad = run_cold("fn f() { unsafe { g(); } }");
        assert_eq!(rules_of(&bad), ["safety-comment"]);
        let good = run_cold("fn f() { // SAFETY: g is sound because reasons\n unsafe { g(); } }");
        assert!(good.is_empty());
    }

    #[test]
    fn safety_comment_skips_attrs_and_modifiers() {
        let src = "// SAFETY: caller guarantees AVX2\n#[target_feature(enable = \"avx2\")]\n\
                   pub unsafe fn gemm() {}";
        assert!(run_cold(src).is_empty());
        let src2 = "/// docs\n// SAFETY: single writer\n pub(crate) unsafe fn g() {}";
        assert!(run_cold(src2).is_empty());
    }

    #[test]
    fn safety_comment_covers_same_line_binding() {
        // clippy-style: the comment sits above the statement, not above the
        // keyword itself.
        let src = "fn f() { // SAFETY: disjoint columns\n let o = unsafe { s(p, n) }; }";
        assert!(run_cold(src).is_empty());
        // ...but it must not leak across a statement boundary on one line.
        let src2 = "fn f() { // SAFETY: a\n unsafe { g(); } unsafe { h(); } }";
        assert_eq!(rules_of(&run_cold(src2)), ["safety-comment"]);
    }

    #[test]
    fn second_unsafe_needs_its_own_comment() {
        let src = "fn f() { // SAFETY: a\n unsafe { g(); } unsafe { h(); } }";
        let fs = run_cold(src);
        assert_eq!(rules_of(&fs), ["safety-comment"]);
        assert_eq!(fs[0].line, 2);
    }

    #[test]
    fn narrow_cast_in_accounting_only() {
        let cfg = LintConfig::default();
        let src = "fn f() { let a = x as u32; let b = y as usize; let c = z as f32; }";
        let fs = check_file("model/weights.rs", src, &cfg);
        assert_eq!(rules_of(&fs), ["narrow-cast"]);
        assert!(check_file("util/stats.rs", src, &cfg).is_empty());
    }

    #[test]
    fn waiver_suppresses_and_bad_waiver_reports() {
        let src = "fn f() { x.unwrap(); // lint: allow(hot-panic): poisoning handled upstream\n }";
        assert!(run_hot(src).is_empty());
        let src2 = "fn f() { x.unwrap(); // lint: allow(hot-panic)\n }";
        let fs = run_hot(src2);
        assert_eq!(rules_of(&fs), ["bad-waiver", "hot-panic"]);
    }

    #[test]
    fn strings_and_comments_do_not_fire() {
        let src = "fn f() { let s = \"x.unwrap()\"; let r = r#\"a[i] panic!\"#; }\n\
                   // doc note: partial_cmp(..).unwrap() would be bad";
        assert!(run_hot(src).is_empty());
    }

    #[test]
    fn hot_module_matching_is_suffix_based() {
        let cfg = LintConfig::default();
        assert!(cfg.is_hot("kv/mod.rs"));
        assert!(cfg.is_hot("fixtures/bad/kv/mod.rs"));
        assert!(!cfg.is_hot("util/stats.rs"));
        assert!(!cfg.is_hot("archive/mod.rs"), "suffix must match at a path boundary");
    }
}
