//! LoRA support (paper §5.5): online multi-LoRA with the associative
//! computation-order optimization.
//!
//! A LoRA layer adds a low-rank bypass: y = W·x + A·(B·x) with A:[h,r],
//! B:[r,h], r ≪ h. Computing (A·B)·x first materializes an [h,h] product —
//! O(r·h² + h²) memory traffic; computing A·(B·x) only touches the two
//! skinny factors — Table 3's ~0.5% of the original at h=3584, r=8.
//!
//! `LoraManager` holds many adapters sharing one base model (the paper's
//! multitask deployment: base weights loaded once, per-task bypasses).

use std::collections::HashMap;

/// One low-rank adapter for one Linear layer.
#[derive(Clone, Debug)]
pub struct LoraAdapter {
    pub h_out: usize,
    pub h_in: usize,
    pub r: usize,
    /// A: [h_out, r], row-major.
    pub a: Vec<f32>,
    /// B: [r, h_in], row-major.
    pub b: Vec<f32>,
    /// Scaling (alpha / r in HF convention).
    pub scale: f32,
}

impl LoraAdapter {
    pub fn new(h_out: usize, h_in: usize, r: usize, a: Vec<f32>, b: Vec<f32>, scale: f32) -> Self {
        assert_eq!(a.len(), h_out * r);
        assert_eq!(b.len(), r * h_in);
        LoraAdapter { h_out, h_in, r, a, b, scale }
    }

    /// Random adapter (examples/benches).
    pub fn random(rng: &mut crate::util::rng::Rng, h_out: usize, h_in: usize, r: usize) -> Self {
        let a = rng.normal_vec(h_out * r);
        let b = rng.normal_vec(r * h_in);
        Self::new(h_out, h_in, r, a, b, 1.0 / r as f32)
    }

    /// Optimized order: out += scale · A·(B·x), for a batch x:[e, h_in],
    /// out:[e, h_out]. O(e·r·(h_in + h_out)) work and memory traffic.
    pub fn apply(&self, x: &[f32], e: usize, out: &mut [f32]) {
        assert_eq!(x.len(), e * self.h_in);
        assert_eq!(out.len(), e * self.h_out);
        let (h_in, h_out, r) = (self.h_in, self.h_out, self.r);
        let mut bx = vec![0f32; e * r];
        for row in 0..e {
            let xr = &x[row * h_in..(row + 1) * h_in];
            for j in 0..r {
                let brow = &self.b[j * h_in..(j + 1) * h_in];
                let mut acc = 0f32;
                for i in 0..h_in {
                    acc += brow[i] * xr[i];
                }
                bx[row * r + j] = acc;
            }
        }
        for row in 0..e {
            let o = &mut out[row * h_out..(row + 1) * h_out];
            for c in 0..h_out {
                let arow = &self.a[c * r..(c + 1) * r];
                let mut acc = 0f32;
                for j in 0..r {
                    acc += arow[j] * bx[row * r + j];
                }
                o[c] += self.scale * acc;
            }
        }
    }

    /// Naive order: materialize ΔW = A·B, then out += scale · ΔW·x —
    /// Table 3's left column; kept as the measured baseline.
    pub fn apply_materialized(&self, x: &[f32], e: usize, out: &mut [f32]) {
        let (h_in, h_out, r) = (self.h_in, self.h_out, self.r);
        let mut dw = vec![0f32; h_out * h_in];
        for c in 0..h_out {
            for i in 0..h_in {
                let mut acc = 0f32;
                for j in 0..r {
                    acc += self.a[c * r + j] * self.b[j * h_in + i];
                }
                dw[c * h_in + i] = acc;
            }
        }
        for row in 0..e {
            let xr = &x[row * h_in..(row + 1) * h_in];
            let o = &mut out[row * h_out..(row + 1) * h_out];
            for c in 0..h_out {
                let wrow = &dw[c * h_in..(c + 1) * h_in];
                let mut acc = 0f32;
                for i in 0..h_in {
                    acc += wrow[i] * xr[i];
                }
                o[c] += self.scale * acc;
            }
        }
    }

    /// Table 3 analytics (h = h_in = h_out, batch 1): (compute MACs,
    /// memory accesses) for each order.
    pub fn table3_costs(h: usize, r: usize) -> Table3Row {
        let (h, r) = (h as u64, r as u64);
        Table3Row {
            // (LoRA_A · LoRA_B) · x : r·h² to form ΔW, h² (≈h³ for x a
            // matrix; the paper's column uses matrix activations — we report
            // both interpretations; vector x shown here).
            naive_compute: r * h * h + h * h,
            naive_memory: 2 * (r * h * h + h * h + h * h),
            // LoRA_A · (LoRA_B · x): r·h + r·h = 2rh MACs for vector x;
            // paper's matrix-activation form is 2rh².
            opt_compute: 2 * r * h,
            opt_memory: 4 * r * h + h + r,
        }
    }

    /// Extra bytes this adapter keeps resident (the paper: "LoRA weights
    /// are generally small, the memory overhead is minimal").
    pub fn resident_bytes(&self) -> usize {
        (self.a.len() + self.b.len()) * 4
    }
}

/// Analytic Table 3 row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Table3Row {
    pub naive_compute: u64,
    pub naive_memory: u64,
    pub opt_compute: u64,
    pub opt_memory: u64,
}

/// Multiple adapters sharing one base model; selected per request.
#[derive(Default)]
pub struct LoraManager {
    /// task name → (layer name → adapter).
    adapters: HashMap<String, HashMap<String, LoraAdapter>>,
}

impl LoraManager {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a task's adapter set (online loading, no engine restart).
    pub fn load_task(&mut self, task: &str, layers: HashMap<String, LoraAdapter>) {
        self.adapters.insert(task.to_string(), layers);
    }

    pub fn unload_task(&mut self, task: &str) -> bool {
        self.adapters.remove(task).is_some()
    }

    pub fn tasks(&self) -> Vec<&str> {
        self.adapters.keys().map(|s| s.as_str()).collect()
    }

    /// The adapter for (task, layer) if present.
    pub fn get(&self, task: &str, layer: &str) -> Option<&LoraAdapter> {
        self.adapters.get(task)?.get(layer)
    }

    /// Apply a task's adapter for `layer` on top of the base output
    /// (no-op when the task or layer has no adapter).
    pub fn apply(&self, task: Option<&str>, layer: &str, x: &[f32], e: usize, out: &mut [f32]) {
        if let Some(t) = task {
            if let Some(a) = self.get(t, layer) {
                a.apply(x, e, out);
            }
        }
    }

    /// Total resident bytes across all adapters.
    pub fn resident_bytes(&self) -> usize {
        self.adapters
            .values()
            .flat_map(|m| m.values())
            .map(|a| a.resident_bytes())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;
    use crate::util::rng::Rng;

    #[test]
    fn orders_agree_numerically() {
        // The associativity rewrite must not change results (Table 3 is a
        // pure cost optimization).
        prop_check(60, |rng: &mut Rng| {
            let h_in = rng.range(4, 48);
            let h_out = rng.range(4, 48);
            let r = rng.range(1, 8);
            let e = rng.range(1, 6);
            let ad = LoraAdapter::random(rng, h_out, h_in, r);
            let x = rng.normal_vec(e * h_in);
            let mut a = vec![0f32; e * h_out];
            let mut b = vec![0f32; e * h_out];
            ad.apply(&x, e, &mut a);
            ad.apply_materialized(&x, e, &mut b);
            for (p, q) in a.iter().zip(&b) {
                if (p - q).abs() > 1e-3 * (1.0 + p.abs()) {
                    return Err(format!("{p} vs {q}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn table3_qwen7b_ratio() {
        // Paper: h = 3584, r = 8 → optimized memory ≈ 0.5% of naive.
        let row = LoraAdapter::table3_costs(3584, 8);
        let ratio = row.opt_memory as f64 / row.naive_memory as f64;
        assert!(ratio < 0.005, "ratio {ratio}");
        assert!(row.opt_compute < row.naive_compute / 100);
    }

    #[test]
    fn adapter_overhead_is_small() {
        // h=3584, r=8 adapter ≈ 2 × 3584 × 8 × 4B ≈ 229 KB vs 12.8 MB ΔW.
        let mut rng = Rng::new(1);
        let ad = LoraAdapter::random(&mut rng, 3584, 3584, 8);
        assert!(ad.resident_bytes() < 3584 * 3584 / 4);
    }

    #[test]
    fn manager_task_lifecycle() {
        let mut rng = Rng::new(2);
        let mut mgr = LoraManager::new();
        let mut layers = HashMap::new();
        layers.insert("L0.wq".to_string(), LoraAdapter::random(&mut rng, 8, 8, 2));
        mgr.load_task("translate", layers);
        assert!(mgr.get("translate", "L0.wq").is_some());
        assert!(mgr.get("translate", "L0.wk").is_none());
        assert!(mgr.get("chat", "L0.wq").is_none());

        // apply() with no task or missing adapter is identity.
        let x = rng.normal_vec(8);
        let mut out = vec![1.0f32; 8];
        mgr.apply(None, "L0.wq", &x, 1, &mut out);
        assert_eq!(out, vec![1.0; 8]);
        mgr.apply(Some("chat"), "L0.wq", &x, 1, &mut out);
        assert_eq!(out, vec![1.0; 8]);
        // With the right task it modifies the output.
        mgr.apply(Some("translate"), "L0.wq", &x, 1, &mut out);
        assert_ne!(out, vec![1.0; 8]);

        assert!(mgr.unload_task("translate"));
        assert!(!mgr.unload_task("translate"));
    }

    #[test]
    fn rank_zero_edge_rejected_by_construction() {
        // r ≥ 1 enforced by sizes; a rank-1 adapter works.
        let mut rng = Rng::new(3);
        let ad = LoraAdapter::random(&mut rng, 4, 4, 1);
        let x = rng.normal_vec(4);
        let mut out = vec![0f32; 4];
        ad.apply(&x, 1, &mut out);
        assert!(out.iter().any(|v| *v != 0.0));
    }
}
