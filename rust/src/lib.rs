//! MNN-LLM reproduction: a generic inference engine for fast LLM deployment
//! on (simulated) mobile devices.
//!
//! Three-layer architecture (see DESIGN.md):
//! * Layer 1/2 (build time, Python): Pallas kernels + JAX model, AOT-lowered
//!   to `artifacts/*.hlo.txt`.
//! * Layer 3 (this crate): the serving engine — PJRT runtime, DRAM-Flash
//!   hybrid storage, combined quantization, hardware-driven data reorder,
//!   multicore balancing, geometry compute, LoRA, scheduler/batcher.

pub mod baselines;
pub mod bench;
pub mod coordinator;
pub mod cpu;
pub mod device;
pub mod geometry;
pub mod kv;
pub mod lora;
pub mod memory;
pub mod model;
pub mod parallel;
pub mod quant;
pub mod reorder;
pub mod runtime;
pub mod util;
