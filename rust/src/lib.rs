//! MNN-LLM reproduction: a generic inference engine for fast LLM deployment
//! on (simulated) mobile devices.
//!
//! Three-layer architecture (see DESIGN.md in this directory):
//! * Layer 1/2 (build time, Python): Pallas kernels + JAX model, AOT-lowered
//!   to `artifacts/*.hlo.txt`.
//! * Layer 3 (this crate): the serving engine — PJRT runtime (behind the
//!   `pjrt` feature), DRAM-Flash hybrid storage, combined quantization,
//!   hardware-driven data reorder, multicore balancing, geometry compute,
//!   LoRA, and an **event-driven streaming scheduler** over one
//!   `InferenceBackend` trait: `Engine::step()` admits/decodes one tick at
//!   a time, emits typed `EngineEvent`s in decode order, and supports
//!   mid-flight submission and cancellation. Per-request state lives in
//!   sessions drawing fixed-size KV pages from a budgeted shared pool
//!   (`kv::paged`), spilling to flash under pressure, which is what makes
//!   continuous batching work on the native backend.

// The codebase favors explicit index loops where they mirror the paper's
// tiling math; keep clippy focused on real defects.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::manual_memcpy)]

pub mod analysis;
pub mod baselines;
pub mod bench;
pub mod cluster;
pub mod coordinator;
pub mod cpu;
pub mod device;
pub mod geometry;
pub mod kv;
pub mod lora;
pub mod memory;
pub mod model;
pub mod parallel;
pub mod quant;
pub mod reorder;
pub mod runtime;
pub mod util;
