//! Pointwise ops with the paper's mixed-precision rules (§5.3): everything
//! here accumulates in fp32; softmax is always fp32 ("the Softmax
//! calculation in Attention is particularly sensitive to data precision").

/// SiLU (swish): x * sigmoid(x).
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// out = silu(gate) * up, elementwise (the SwiGLU MLP joint).
pub fn swiglu(gate: &[f32], up: &[f32], out: &mut [f32]) {
    assert_eq!(gate.len(), up.len());
    assert_eq!(gate.len(), out.len());
    for ((o, &g), &u) in out.iter_mut().zip(gate).zip(up) {
        *o = silu(g) * u;
    }
}

/// In-place a += b.
pub fn add_inplace(a: &mut [f32], b: &[f32]) {
    assert_eq!(a.len(), b.len());
    for (x, &y) in a.iter_mut().zip(b) {
        *x += y;
    }
}

/// RMSNorm in fp32: x * rsqrt(mean(x²)+eps) * w, row-wise over [rows, h].
///
/// Degenerate shapes are explicit no-ops: with `h == 0` (or `rows == 0`)
/// there is nothing to normalize and nothing is written — both compute
/// backends share this entry point, so a `0/0` NaN here would poison
/// every path at once.
pub fn rmsnorm(x: &[f32], w: &[f32], out: &mut [f32], rows: usize, eps: f32) {
    let h = w.len();
    assert_eq!(x.len(), rows * h);
    assert_eq!(out.len(), rows * h);
    if h == 0 {
        return;
    }
    for r in 0..rows {
        let row = &x[r * h..(r + 1) * h];
        let ms = row.iter().map(|v| v * v).sum::<f32>() / h as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        for c in 0..h {
            out[r * h + c] = row[c] * inv * w[c];
        }
    }
}

/// Numerically-safe fp32 softmax over a slice (max-subtracted).
pub fn softmax_inplace(xs: &mut [f32]) {
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        // All -inf (fully masked): define as uniform-zero to avoid NaN.
        xs.fill(0.0);
        return;
    }
    let mut sum = 0f32;
    for x in xs.iter_mut() {
        *x = (*x - m).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    for x in xs.iter_mut() {
        *x *= inv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn silu_fixed_points() {
        assert_eq!(silu(0.0), 0.0);
        assert!((silu(1.0) - 0.7310586).abs() < 1e-6);
        assert!(silu(-20.0).abs() < 1e-6);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut xs = vec![1.0f32, 2.0, 3.0, 4.0];
        softmax_inplace(&mut xs);
        let s: f32 = xs.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(xs.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn softmax_survives_large_values() {
        // §5.3: pre-scaled queries keep scores < overflow; softmax itself
        // must also be stable at fp16-overflow-scale inputs.
        let mut xs = vec![65504.0f32, 65504.0, 65503.0];
        softmax_inplace(&mut xs);
        assert!(xs.iter().all(|v| v.is_finite()));
        assert!((xs.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn softmax_fully_masked_is_zero() {
        let mut xs = vec![f32::NEG_INFINITY; 4];
        softmax_inplace(&mut xs);
        assert!(xs.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn rmsnorm_unit_rms() {
        let mut rng = crate::util::rng::Rng::new(1);
        let x = rng.normal_vec(3 * 64);
        let w = vec![1.0f32; 64];
        let mut out = vec![0f32; 3 * 64];
        rmsnorm(&x, &w, &mut out, 3, 1e-6);
        for r in 0..3 {
            let row = &out[r * 64..(r + 1) * 64];
            let rms = (row.iter().map(|v| v * v).sum::<f32>() / 64.0).sqrt();
            assert!((rms - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn empty_slices_are_no_ops() {
        // Shared backend entry points must tolerate degenerate shapes:
        // an empty softmax has no max (fold yields -inf) and must not
        // fill-or-divide; h == 0 rmsnorm must not compute 0/0; empty
        // swiglu/add must simply do nothing.
        let mut xs: Vec<f32> = vec![];
        softmax_inplace(&mut xs);
        assert!(xs.is_empty());

        let mut out: Vec<f32> = vec![];
        rmsnorm(&[], &[], &mut out, 3, 1e-6); // rows > 0, h == 0
        rmsnorm(&[], &[1.0], &mut out[..0], 0, 1e-6); // rows == 0, h > 0
        assert!(out.is_empty());

        swiglu(&[], &[], &mut []);
        add_inplace(&mut [], &[]);
    }

    #[test]
    fn rmsnorm_zero_h_leaves_no_nans_anywhere() {
        // Regression: before the h == 0 early return, the mean-square was
        // 0/0 = NaN; it never reached `out`, but the guard makes the
        // no-op explicit rather than accidental.
        let mut out: Vec<f32> = vec![];
        rmsnorm(&[], &[], &mut out, 17, 0.0);
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn swiglu_matches_manual() {
        let gate = [1.0f32, -2.0];
        let up = [3.0f32, 4.0];
        let mut out = [0f32; 2];
        swiglu(&gate, &up, &mut out);
        assert!((out[0] - silu(1.0) * 3.0).abs() < 1e-6);
        assert!((out[1] - silu(-2.0) * 4.0).abs() < 1e-6);
    }
}
