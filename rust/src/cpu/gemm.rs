//! Dense f32 GEMM reference (correctness oracle + fp16-class baseline path).
//!
//! `matmul_f32` is the naive row-major oracle; `matmul_f32_tiled` applies
//! the same loop tiling the quantized path uses, so benches can isolate the
//! benefit of (a) tiling and (b) int8 — the two ingredients of §5.1.

/// out[m,n] = x[m,k] · w[n,k]^T (naive; oracle for tests).
pub fn matmul_f32(x: &[f32], w: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(x.len(), m * k);
    assert_eq!(w.len(), n * k);
    assert_eq!(out.len(), m * n);
    for r in 0..m {
        for c in 0..n {
            let mut acc = 0f32;
            for i in 0..k {
                acc += x[r * k + i] * w[c * k + i];
            }
            out[r * n + c] = acc;
        }
    }
}

/// Tiled f32 GEMM with an (mt × nt) register block; demonstrates the
/// locality win of Eq. 2 without quantization.
pub fn matmul_f32_tiled(
    x: &[f32],
    w: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    mt: usize,
    nt: usize,
) {
    assert_eq!(x.len(), m * k);
    assert_eq!(w.len(), n * k);
    assert_eq!(out.len(), m * n);
    out.fill(0.0);
    for r0 in (0..m).step_by(mt) {
        let r1 = (r0 + mt).min(m);
        for c0 in (0..n).step_by(nt) {
            let c1 = (c0 + nt).min(n);
            for r in r0..r1 {
                for c in c0..c1 {
                    let mut acc = 0f32;
                    let xr = &x[r * k..(r + 1) * k];
                    let wc = &w[c * k..(c + 1) * k];
                    for i in 0..k {
                        acc += xr[i] * wc[i];
                    }
                    out[r * n + c] = acc;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn tiled_matches_naive() {
        let mut rng = Rng::new(1);
        let (m, k, n) = (7, 33, 19);
        let x = rng.normal_vec(m * k);
        let w = rng.normal_vec(n * k);
        let mut a = vec![0f32; m * n];
        let mut b = vec![0f32; m * n];
        matmul_f32(&x, &w, &mut a, m, k, n);
        matmul_f32_tiled(&x, &w, &mut b, m, k, n, 4, 8);
        for (p, q) in a.iter().zip(&b) {
            assert!((p - q).abs() < 1e-4);
        }
    }

    #[test]
    fn identity_weight() {
        let k = 8;
        let x: Vec<f32> = (0..k).map(|i| i as f32).collect();
        let mut w = vec![0f32; k * k];
        for i in 0..k {
            w[i * k + i] = 1.0;
        }
        let mut out = vec![0f32; k];
        matmul_f32(&x, &w, &mut out, 1, k, k);
        assert_eq!(out, x);
    }
}
