//! Native attention over the quantized KV cache, with the paper's §5.3
//! mixed-precision rules: the query is pre-scaled by 1/sqrt(d) *before*
//! QK^T (so accumulations stay in range even on fp16-class hardware) and
//! softmax runs in fp32.

use crate::cpu::backend::{ComputeBackend, ScalarBackend};
use crate::kv::KvLayer;

/// GQA decode attention for one token (scalar reference backend).
///
/// * `q` — [heads * d], already projected + roped, NOT yet scaled (this
///   function applies the 1/sqrt(d) pre-scale to q, per §5.3).
/// * `cache` — the layer's quantized KV (len = tokens to attend over).
/// * `out` — [heads * d].
pub fn decode_attention(q: &[f32], heads: usize, cache: &KvLayer, out: &mut [f32]) {
    decode_attention_with(&ScalarBackend, q, heads, cache, out);
}

/// [`decode_attention`] on an explicit compute backend. The KV dot and
/// value accumulate live in `KvLayer` (they dequantize inline); the
/// softmax goes through the backend — whose float ops keep the scalar
/// reduction order, so all backends are bit-identical here.
pub fn decode_attention_with(
    be: &dyn ComputeBackend,
    q: &[f32],
    heads: usize,
    cache: &KvLayer,
    out: &mut [f32],
) {
    let d = cache.head_dim;
    assert_eq!(q.len(), heads * d);
    assert_eq!(out.len(), heads * d);
    assert!(heads % cache.kv_heads == 0, "GQA requires heads % kv_heads == 0");
    let group = heads / cache.kv_heads;
    let t = cache.len();
    assert!(t > 0, "decode needs at least one cached token");
    let scale = 1.0 / (d as f32).sqrt();
    let mut scores = vec![0f32; t];
    let mut qs = vec![0f32; d];
    for h in 0..heads {
        let kvh = h / group;
        // Pre-scale the query once (not each score) — same math, fewer
        // multiplies, and bounded magnitudes before accumulation (§5.3).
        for (qv, &xv) in qs.iter_mut().zip(&q[h * d..(h + 1) * d]) {
            *qv = xv * scale;
        }
        for (tok, sc) in scores.iter_mut().enumerate() {
            *sc = cache.key_dot(kvh, tok, &qs);
        }
        be.softmax_inplace(&mut scores);
        let o = &mut out[h * d..(h + 1) * d];
        o.fill(0.0);
        for (tok, &sc) in scores.iter().enumerate() {
            cache.accum_value(kvh, tok, sc, o);
        }
    }
}

/// Multi-position speculative **verify** attention: `s` consecutive
/// decode positions (the newest committed token followed by draft
/// proposals) attend causally over the quantized cache.
///
/// * `q` — [s, heads, d] roped, unscaled; `k`, `v` — [s, kv_heads, d]
///   fresh rows for the verify positions.
/// * `out` — [s, heads, d].
///
/// Each position's K/V is appended **before** its own scores — the exact
/// append-then-score sequence `s` one-token decode calls perform, which
/// is the entire bit-identity argument: position `t` attends over cached
/// tokens `0..len+t+1` and never its successors, so a verify row's
/// outputs equal sequential decode's bit for bit. The native fused walk
/// interleaves the same append/stream pair per position over the hybrid
/// (spillable) cache; this dense form is the reference the verify tests
/// oracle against.
pub fn verify_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    s: usize,
    heads: usize,
    cache: &mut KvLayer,
    out: &mut [f32],
) {
    verify_attention_with(&ScalarBackend, q, k, v, s, heads, cache, out);
}

/// [`verify_attention`] on an explicit compute backend.
#[allow(clippy::too_many_arguments)]
pub fn verify_attention_with(
    be: &dyn ComputeBackend,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    s: usize,
    heads: usize,
    cache: &mut KvLayer,
    out: &mut [f32],
) {
    let d = cache.head_dim;
    let row = cache.kv_heads * d;
    assert_eq!(q.len(), s * heads * d);
    assert_eq!(k.len(), s * row);
    assert_eq!(v.len(), s * row);
    assert_eq!(out.len(), s * heads * d);
    for t in 0..s {
        cache.append(&k[t * row..(t + 1) * row], &v[t * row..(t + 1) * row]);
        decode_attention_with(
            be,
            &q[t * heads * d..(t + 1) * heads * d],
            heads,
            cache,
            &mut out[t * heads * d..(t + 1) * heads * d],
        );
    }
}

/// Causal prefill attention over fresh (unquantized) K/V.
///
/// * `q` — [s, heads, d] roped, unscaled; `k`, `v` — [s, kv_heads, d].
/// * `out` — [s, heads, d].
///
/// A chunk with an empty prefix: see [`chunked_prefill_attention`], which
/// this delegates to so monolithic and chunked prefill share one code
/// path (the bit-identity argument needs no "two implementations agree"
/// step).
pub fn prefill_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    s: usize,
    heads: usize,
    kv_heads: usize,
    d: usize,
    out: &mut [f32],
) {
    prefill_attention_with(&ScalarBackend, q, k, v, s, heads, kv_heads, d, out);
}

/// [`prefill_attention`] on an explicit compute backend.
#[allow(clippy::too_many_arguments)]
pub fn prefill_attention_with(
    be: &dyn ComputeBackend,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    s: usize,
    heads: usize,
    kv_heads: usize,
    d: usize,
    out: &mut [f32],
) {
    chunked_prefill_attention_with(be, q, &[], &[], k, v, 0, s, heads, kv_heads, d, out);
}

/// Causal attention for one prefill **chunk**: `s` fresh tokens whose
/// sequence already holds `base` earlier prompt tokens, attending over the
/// retained fp32 prefix K/V (`pk`/`pv` — [base, kv_heads, d]) plus the
/// fresh chunk causally.
///
/// * `q` — [s, heads, d] roped, unscaled (the 1/sqrt(d) pre-scale is
///   applied here, §5.3); `k`, `v` — [s, kv_heads, d] fresh chunk rows.
/// * `out` — [s, heads, d].
///
/// Bit-identity across chunk boundaries: the fresh token at chunk-local
/// `qi` (global position `base + qi`) scores the prefix rows first and the
/// chunk rows `0..=qi` second — exactly the `0..=base+qi` order a
/// monolithic [`prefill_attention`] over the whole prompt walks, with the
/// same dot-product accumulation order, one fp32 softmax over the same
/// contiguous score slice, and the same value-accumulation order. Given a
/// prefix K/V that is bit-equal to the monolithic pass's rows (projection
/// is row-independent), every output row is therefore bit-identical to
/// the monolithic pass's row `base + qi` — the correctness argument the
/// chunked-prefill property tests pin down.
#[allow(clippy::too_many_arguments)]
pub fn chunked_prefill_attention(
    q: &[f32],
    pk: &[f32],
    pv: &[f32],
    k: &[f32],
    v: &[f32],
    base: usize,
    s: usize,
    heads: usize,
    kv_heads: usize,
    d: usize,
    out: &mut [f32],
) {
    chunked_prefill_attention_with(
        &ScalarBackend,
        q,
        pk,
        pv,
        k,
        v,
        base,
        s,
        heads,
        kv_heads,
        d,
        out,
    );
}

/// [`chunked_prefill_attention`] on an explicit compute backend.
#[allow(clippy::too_many_arguments)]
pub fn chunked_prefill_attention_with(
    be: &dyn ComputeBackend,
    q: &[f32],
    pk: &[f32],
    pv: &[f32],
    k: &[f32],
    v: &[f32],
    base: usize,
    s: usize,
    heads: usize,
    kv_heads: usize,
    d: usize,
    out: &mut [f32],
) {
    assert_eq!(pk.len(), base * kv_heads * d);
    assert_eq!(pv.len(), base * kv_heads * d);
    segmented_prefill_attention_with(be, q, &[(pk, pv)], k, v, s, heads, kv_heads, d, out);
}

/// [`chunked_prefill_attention`] generalized to a prefix stored in
/// several contiguous fp32 segments: a warm (prefix-cache-hit) session's
/// chunk attends over the **shared** cached-prefix stash, then its own
/// suffix stash, then the fresh chunk causally — without concatenating
/// buffers. Each `prefix` element is `(k, v)`, both `[n, kv_heads, d]`.
///
/// Segment rows are walked in global order with the same per-row dot,
/// one softmax over the same contiguous score slice, and the same
/// value-accumulation order as a single concatenated prefix buffer, so
/// outputs are bit-identical to the cold (one-segment or monolithic)
/// pass — the property the prefix-cache bit-identity tests pin down.
#[allow(clippy::too_many_arguments)]
pub fn segmented_prefill_attention(
    q: &[f32],
    prefix: &[(&[f32], &[f32])],
    k: &[f32],
    v: &[f32],
    s: usize,
    heads: usize,
    kv_heads: usize,
    d: usize,
    out: &mut [f32],
) {
    segmented_prefill_attention_with(&ScalarBackend, q, prefix, k, v, s, heads, kv_heads, d, out);
}

/// [`segmented_prefill_attention`] on an explicit compute backend: the
/// score dots, softmax and value accumulates go through the backend's
/// `dot`/`softmax_inplace`/`axpy` primitives, all of which preserve the
/// scalar reduction order (the bit-identity contract).
#[allow(clippy::too_many_arguments)]
pub fn segmented_prefill_attention_with(
    be: &dyn ComputeBackend,
    q: &[f32],
    prefix: &[(&[f32], &[f32])],
    k: &[f32],
    v: &[f32],
    s: usize,
    heads: usize,
    kv_heads: usize,
    d: usize,
    out: &mut [f32],
) {
    let row = kv_heads * d;
    let mut base = 0usize;
    for (pk, pv) in prefix {
        assert_eq!(pk.len() % row, 0);
        assert_eq!(pk.len(), pv.len());
        base += pk.len() / row;
    }
    assert_eq!(q.len(), s * heads * d);
    assert_eq!(k.len(), s * row);
    assert_eq!(v.len(), s * row);
    assert_eq!(out.len(), s * heads * d);
    let group = heads / kv_heads;
    let scale = 1.0 / (d as f32).sqrt();
    let mut scores = vec![0f32; base + s];
    let mut qs = vec![0f32; d];
    for h in 0..heads {
        let kvh = h / group;
        for qi in 0..s {
            let qrow = &q[(qi * heads + h) * d..(qi * heads + h) * d + d];
            for (qv, &xv) in qs.iter_mut().zip(qrow) {
                *qv = xv * scale;
            }
            // Prefix rows (across segments, in order), then the causal
            // span of the fresh chunk — the same global key order
            // 0..=base+qi as a monolithic pass. One cursor over `scores`
            // walks both spans (the prefix segments cover exactly `base`
            // slots by the asserts above).
            let mut score_wr = scores.iter_mut();
            for (pk, _) in prefix {
                for ki in 0..pk.len() / row {
                    let krow = &pk[(ki * kv_heads + kvh) * d..(ki * kv_heads + kvh) * d + d];
                    if let Some(sc) = score_wr.next() {
                        *sc = be.dot(&qs, krow);
                    }
                }
            }
            let causal = qi + 1;
            for ki in 0..causal {
                let krow = &k[(ki * kv_heads + kvh) * d..(ki * kv_heads + kvh) * d + d];
                if let Some(sc) = score_wr.next() {
                    *sc = be.dot(&qs, krow);
                }
            }
            drop(score_wr);
            be.softmax_inplace(&mut scores[..base + causal]);
            let o = &mut out[(qi * heads + h) * d..(qi * heads + h) * d + d];
            o.fill(0.0);
            let mut score_rd = scores.iter();
            for (_, pv) in prefix {
                for ki in 0..pv.len() / row {
                    let vrow = &pv[(ki * kv_heads + kvh) * d..(ki * kv_heads + kvh) * d + d];
                    if let Some(&w) = score_rd.next() {
                        be.axpy(w, vrow, o);
                    }
                }
            }
            for ki in 0..causal {
                let vrow = &v[(ki * kv_heads + kvh) * d..(ki * kv_heads + kvh) * d + d];
                if let Some(&w) = score_rd.next() {
                    be.axpy(w, vrow, o);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::activation::softmax_inplace;
    use crate::util::rng::Rng;

    /// Oracle: fp32 attention over explicitly dequantized cache tensors.
    fn decode_oracle(q: &[f32], heads: usize, cache: &KvLayer) -> Vec<f32> {
        let d = cache.head_dim;
        let group = heads / cache.kv_heads;
        let t = cache.len();
        let scale = 1.0 / (d as f32).sqrt();
        let mut out = vec![0f32; heads * d];
        for h in 0..heads {
            let kvh = h / group;
            let mut scores: Vec<f32> = (0..t)
                .map(|tok| {
                    let qrow: Vec<f32> =
                        (0..d).map(|i| q[h * d + i] * scale).collect();
                    cache.key_dot(kvh, tok, &qrow)
                })
                .collect();
            softmax_inplace(&mut scores);
            for tok in 0..t {
                cache.accum_value(kvh, tok, scores[tok], &mut out[h * d..(h + 1) * d]);
            }
        }
        out
    }

    #[test]
    fn decode_matches_oracle() {
        let mut rng = Rng::new(1);
        let (heads, kv_heads, d, t) = (4, 2, 16, 12);
        let mut cache = KvLayer::new(kv_heads, d);
        for _ in 0..t {
            let k = rng.normal_vec(kv_heads * d);
            let v = rng.normal_vec(kv_heads * d);
            cache.append(&k, &v);
        }
        let q = rng.normal_vec(heads * d);
        let mut out = vec![0f32; heads * d];
        decode_attention(&q, heads, &cache, &mut out);
        let want = decode_oracle(&q, heads, &cache);
        for (a, b) in out.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn attention_output_is_convex_combination() {
        // Softmax weights are a convex combination → each output coordinate
        // lies within [min, max] of the (dequantized) values.
        let mut rng = Rng::new(2);
        let (heads, kv_heads, d, t) = (2, 1, 8, 20);
        let mut cache = KvLayer::new(kv_heads, d);
        let mut vmin = vec![f32::INFINITY; d];
        let mut vmax = vec![f32::NEG_INFINITY; d];
        for _ in 0..t {
            let k = rng.normal_vec(kv_heads * d);
            let v = rng.normal_vec(kv_heads * d);
            cache.append(&k, &v);
            let mut vd = vec![0f32; d];
            cache.accum_value(0, cache.len() - 1, 1.0, &mut vd);
            for i in 0..d {
                vmin[i] = vmin[i].min(vd[i]);
                vmax[i] = vmax[i].max(vd[i]);
            }
        }
        let q = rng.normal_vec(heads * d);
        let mut out = vec![0f32; heads * d];
        decode_attention(&q, heads, &cache, &mut out);
        for h in 0..heads {
            for i in 0..d {
                let o = out[h * d + i];
                assert!(o >= vmin[i] - 1e-4 && o <= vmax[i] + 1e-4);
            }
        }
    }

    #[test]
    fn prefill_first_row_copies_v0() {
        // Row 0 attends only to itself → output == v[0] exactly.
        let mut rng = Rng::new(3);
        let (s, heads, kv_heads, d) = (4, 2, 2, 8);
        let q = rng.normal_vec(s * heads * d);
        let k = rng.normal_vec(s * kv_heads * d);
        let v = rng.normal_vec(s * kv_heads * d);
        let mut out = vec![0f32; s * heads * d];
        prefill_attention(&q, &k, &v, s, heads, kv_heads, d, &mut out);
        for h in 0..heads {
            for i in 0..d {
                assert!((out[h * d + i] - v[h * d + i]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn prefill_is_causal() {
        let mut rng = Rng::new(4);
        let (s, heads, kv_heads, d) = (6, 2, 1, 8);
        let q = rng.normal_vec(s * heads * d);
        let k = rng.normal_vec(s * kv_heads * d);
        let mut v = rng.normal_vec(s * kv_heads * d);
        let mut out1 = vec![0f32; s * heads * d];
        prefill_attention(&q, &k, &v, s, heads, kv_heads, d, &mut out1);
        // Perturb the last token's value; earlier rows must not change.
        for i in 0..kv_heads * d {
            v[(s - 1) * kv_heads * d + i] += 7.0;
        }
        let mut out2 = vec![0f32; s * heads * d];
        prefill_attention(&q, &k, &v, s, heads, kv_heads, d, &mut out2);
        for r in 0..s - 1 {
            for i in 0..heads * d {
                assert_eq!(out1[r * heads * d + i], out2[r * heads * d + i]);
            }
        }
    }

    #[test]
    fn chunked_prefill_is_bit_identical_to_monolithic() {
        // Split a sequence at every boundary; each chunk attends over the
        // retained prefix + itself. Outputs must equal the monolithic
        // pass bit for bit (the chunk-boundary causal-mask invariant).
        let mut rng = Rng::new(6);
        let (s, heads, kv_heads, d) = (7usize, 4, 2, 8);
        let q = rng.normal_vec(s * heads * d);
        let k = rng.normal_vec(s * kv_heads * d);
        let v = rng.normal_vec(s * kv_heads * d);
        let mut want = vec![0f32; s * heads * d];
        prefill_attention(&q, &k, &v, s, heads, kv_heads, d, &mut want);
        for split in 1..s {
            for (base, len) in [(0usize, split), (split, s - split)] {
                let mut out = vec![0f32; len * heads * d];
                chunked_prefill_attention(
                    &q[base * heads * d..(base + len) * heads * d],
                    &k[..base * kv_heads * d],
                    &v[..base * kv_heads * d],
                    &k[base * kv_heads * d..(base + len) * kv_heads * d],
                    &v[base * kv_heads * d..(base + len) * kv_heads * d],
                    base,
                    len,
                    heads,
                    kv_heads,
                    d,
                    &mut out,
                );
                assert_eq!(
                    out,
                    want[base * heads * d..(base + len) * heads * d].to_vec(),
                    "split {split} chunk at base {base} diverged"
                );
            }
        }
    }

    #[test]
    fn segmented_prefix_is_bit_identical_to_concatenated() {
        // Split the retained prefix at every boundary into two segments;
        // the chunk's outputs must equal the single-segment pass bit for
        // bit (the prefix-cache fork-point invariant).
        let mut rng = Rng::new(7);
        let (base, s, heads, kv_heads, d) = (6usize, 3usize, 4, 2, 8);
        let q = rng.normal_vec(s * heads * d);
        let pk = rng.normal_vec(base * kv_heads * d);
        let pv = rng.normal_vec(base * kv_heads * d);
        let k = rng.normal_vec(s * kv_heads * d);
        let v = rng.normal_vec(s * kv_heads * d);
        let mut want = vec![0f32; s * heads * d];
        chunked_prefill_attention(&q, &pk, &pv, &k, &v, base, s, heads, kv_heads, d, &mut want);
        let row = kv_heads * d;
        for cut in 0..=base {
            let segs = [
                (&pk[..cut * row], &pv[..cut * row]),
                (&pk[cut * row..], &pv[cut * row..]),
            ];
            let mut out = vec![0f32; s * heads * d];
            segmented_prefill_attention(&q, &segs, &k, &v, s, heads, kv_heads, d, &mut out);
            assert_eq!(out, want, "prefix cut at {cut} diverged");
        }
    }

    #[test]
    fn simd_backend_attention_is_bit_identical_to_scalar() {
        // The SIMD backend inherits the scalar float primitives, so
        // attention must agree byte for byte — the contract native.rs's
        // fused walk relies on when the backend handle is threaded in.
        let Some(simd) = crate::cpu::backend::SimdBackend::try_new() else {
            return;
        };
        let mut rng = Rng::new(9);
        let (base, s, heads, kv_heads, d) = (5usize, 4usize, 4, 2, 8);
        let q = rng.normal_vec(s * heads * d);
        let pk = rng.normal_vec(base * kv_heads * d);
        let pv = rng.normal_vec(base * kv_heads * d);
        let k = rng.normal_vec(s * kv_heads * d);
        let v = rng.normal_vec(s * kv_heads * d);
        let segs = [(&pk[..], &pv[..])];
        let mut want = vec![0f32; s * heads * d];
        segmented_prefill_attention(&q, &segs, &k, &v, s, heads, kv_heads, d, &mut want);
        let mut got = vec![0f32; s * heads * d];
        segmented_prefill_attention_with(
            &simd, &q, &segs, &k, &v, s, heads, kv_heads, d, &mut got,
        );
        assert_eq!(
            want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            got.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn verify_attention_matches_sequential_decode_bitwise() {
        // The speculative-verify kernel contract: one multi-position call
        // equals `s` append-then-score decode calls, bit for bit.
        let mut rng = Rng::new(11);
        let (heads, kv_heads, d, hist, s) = (4usize, 2usize, 8usize, 5usize, 3usize);
        let row = kv_heads * d;
        let mut seq = KvLayer::new(kv_heads, d);
        let mut fused = KvLayer::new(kv_heads, d);
        for _ in 0..hist {
            let k = rng.normal_vec(row);
            let v = rng.normal_vec(row);
            seq.append(&k, &v);
            fused.append(&k, &v);
        }
        let q = rng.normal_vec(s * heads * d);
        let k = rng.normal_vec(s * row);
        let v = rng.normal_vec(s * row);
        let mut want = vec![0f32; s * heads * d];
        for t in 0..s {
            seq.append(&k[t * row..(t + 1) * row], &v[t * row..(t + 1) * row]);
            decode_attention(
                &q[t * heads * d..(t + 1) * heads * d],
                heads,
                &seq,
                &mut want[t * heads * d..(t + 1) * heads * d],
            );
        }
        let mut got = vec![0f32; s * heads * d];
        verify_attention(&q, &k, &v, s, heads, &mut fused, &mut got);
        assert_eq!(
            want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            got.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(fused.len(), hist + s, "verify appends all its positions");
    }

    #[test]
    fn verify_attention_is_causal() {
        // Perturbing the last draft's K/V must not change any earlier
        // position's output — drafts never leak backwards.
        let mut rng = Rng::new(12);
        let (heads, kv_heads, d, hist, s) = (2usize, 1usize, 8usize, 4usize, 3usize);
        let row = kv_heads * d;
        let hk: Vec<Vec<f32>> = (0..hist).map(|_| rng.normal_vec(row)).collect();
        let hv: Vec<Vec<f32>> = (0..hist).map(|_| rng.normal_vec(row)).collect();
        let fill = |cache: &mut KvLayer| {
            for (k, v) in hk.iter().zip(&hv) {
                cache.append(k, v);
            }
        };
        let q = rng.normal_vec(s * heads * d);
        let k = rng.normal_vec(s * row);
        let mut v = rng.normal_vec(s * row);
        let mut c1 = KvLayer::new(kv_heads, d);
        fill(&mut c1);
        let mut out1 = vec![0f32; s * heads * d];
        verify_attention(&q, &k, &v, s, heads, &mut c1, &mut out1);
        for x in &mut v[(s - 1) * row..] {
            *x += 7.0;
        }
        let mut c2 = KvLayer::new(kv_heads, d);
        fill(&mut c2);
        let mut out2 = vec![0f32; s * heads * d];
        verify_attention(&q, &k, &v, s, heads, &mut c2, &mut out2);
        for t in 0..s - 1 {
            assert_eq!(
                out1[t * heads * d..(t + 1) * heads * d],
                out2[t * heads * d..(t + 1) * heads * d],
                "position {t} saw a later draft"
            );
        }
    }

    #[test]
    fn large_query_values_stay_finite() {
        // §5.3 overflow guard: huge queries, pre-scaled, survive softmax.
        let (heads, kv_heads, d) = (1, 1, 16);
        let mut cache = KvLayer::new(kv_heads, d);
        let mut rng = Rng::new(5);
        for _ in 0..4 {
            let k: Vec<f32> = rng.normal_vec(d).iter().map(|x| x * 100.0).collect();
            let v = rng.normal_vec(d);
            cache.append(&k, &v);
        }
        let q: Vec<f32> = rng.normal_vec(heads * d).iter().map(|x| x * 500.0).collect();
        let mut out = vec![0f32; heads * d];
        decode_attention(&q, heads, &cache, &mut out);
        assert!(out.iter().all(|v| v.is_finite()));
    }
}
