//! The paper's hot path: tiled integer GEMM over reordered operands
//! (W8A8 / W4A8), with the asymmetric-quantization affine corrections.
//!
//! Operands arrive pre-packed (reorder::pack): activations as
//! [e/e_p, l/l_p, e_p, l_p] int8, weights as [h/h_p, l/l_p, h_p, l_p]
//! int8-or-nibbles. The microkernel walks both panels strictly linearly —
//! that sequential walk *is* the optimization; the layout was chosen by the
//! Eq. 2–4 solver so the panel fits the register file.
//!
//! out = sx·sw·(Σ xq·wq) + sx·bw·Σxq + bx·sw·Σwq + l·bx·bw
//! (padding contributes zero codes to Σ xq·wq and the corrections use true
//! row sums and true l, so padding is value-neutral).
//!
//! **Row independence**: activations are quantized per row, the integer
//! accumulator is exact, and the affine correction of output (r, c) reads
//! only row r's params — so an m-row forward equals m single-row forwards
//! exactly, whatever e_p the activation panel packs to. Fused batched
//! decode (`model::native::decode_batch`) rides on this invariant to run
//! all sessions through one weight pass with bit-identical results.

use crate::cpu::backend::{ComputeBackend, ScalarBackend};
use crate::quant::asym::WeightBits;
use crate::reorder::pack::{pack_activations, pack_weights, PackedActivations, PackedWeights};
use crate::reorder::solver::TileConfig;
use crate::quant::QuantizedMatrix;

/// A ready-to-run quantized Linear layer: packed weights + dims.
#[derive(Clone, Debug)]
pub struct QLinear {
    pub packed: PackedWeights,
    /// Optional fp32 bias added to the output (qkv projections have one).
    pub bias: Option<Vec<f32>>,
}

impl QLinear {
    pub fn new(w: &QuantizedMatrix, tile: TileConfig, bias: Option<Vec<f32>>) -> Self {
        if let Some(b) = &bias {
            assert_eq!(b.len(), w.n);
        }
        QLinear { packed: pack_weights(w, tile), bias }
    }

    pub fn out_features(&self) -> usize {
        self.packed.h
    }

    pub fn in_features(&self) -> usize {
        self.packed.l
    }

    /// The tile used to pack activations for `e` rows: weights are packed
    /// independently of e_p, so the activation panel depth adapts to the
    /// batch — decode (e = 1) runs a GEMV-class microkernel instead of
    /// padding to the prefill tile's e_p (which would waste e_p× compute).
    pub fn activation_tile(&self, e: usize) -> TileConfig {
        TileConfig { e_p: self.packed.tile.e_p.min(e.max(1)), ..self.packed.tile }
    }

    /// y[e, h] = x[e, l] · Wᵀ (+ bias). Quantizes + packs the activations,
    /// runs all h-tiles on the scalar reference backend.
    pub fn forward(&self, x: &[f32], e: usize, out: &mut [f32]) {
        self.forward_with(&ScalarBackend, x, e, out);
    }

    /// [`forward`](Self::forward) on an explicit compute backend.
    pub fn forward_with(&self, be: &dyn ComputeBackend, x: &[f32], e: usize, out: &mut [f32]) {
        let pa = pack_activations(x, e, self.packed.l, self.activation_tile(e));
        self.forward_packed_with(be, &pa, out, 0, self.packed.h_pad / self.packed.tile.h_p);
    }

    /// Run a contiguous range of h tiles [tile_lo, tile_hi) — the unit the
    /// multicore balancer distributes (paper §5.2 parallelizes over h/h_p)
    /// — on the scalar reference backend.
    pub fn forward_packed(
        &self,
        pa: &PackedActivations,
        out: &mut [f32],
        tile_lo: usize,
        tile_hi: usize,
    ) {
        self.forward_packed_with(&ScalarBackend, pa, out, tile_lo, tile_hi);
    }

    /// [`forward_packed`](Self::forward_packed) on an explicit compute
    /// backend. For each output tile (bi, bj) the activation block across
    /// the whole reduce dimension is contiguous (`[tiles_l, e_p, l_p]`),
    /// and so is the weight block (`[tiles_l, h_p, l_p]` rows or nibble
    /// pairs) — the backend's block op owns the full bl walk so a vector
    /// kernel can keep its accumulators in registers and reduce once.
    /// Integer accumulation is exact, so every backend produces the same
    /// i32 accumulators; the affine correction stays in scalar expression
    /// order, so outputs are bit-identical across backends.
    pub fn forward_packed_with(
        &self,
        be: &dyn ComputeBackend,
        pa: &PackedActivations,
        out: &mut [f32],
        tile_lo: usize,
        tile_hi: usize,
    ) {
        let w = &self.packed;
        let t = pa.tile;
        assert_eq!(pa.l, w.l, "reduce dims must match");
        assert_eq!(t.h_p, w.tile.h_p, "operands packed for different h tiles");
        assert_eq!(t.l_p, w.tile.l_p, "operands packed for different l tiles");
        assert_eq!(out.len(), pa.e * w.h);
        let (e_p, h_p, l_p) = (t.e_p, t.h_p, t.l_p);
        let tiles_l = pa.l_pad / l_p;
        let tiles_e = pa.e_pad / e_p;
        let mut acc = vec![0i32; e_p * h_p];
        for bj in tile_lo..tile_hi {
            for bi in 0..tiles_e {
                acc.fill(0);
                let a_base = bi * tiles_l * e_p * l_p;
                let a_block = &pa.data[a_base..a_base + tiles_l * e_p * l_p];
                match w.bits {
                    WeightBits::Int8 => {
                        let w_base = bj * tiles_l * h_p * l_p;
                        let w_block = &w.data[w_base..w_base + tiles_l * h_p * l_p];
                        be.gemm_i8_block(a_block, w_block, &mut acc, tiles_l, e_p, h_p, l_p);
                    }
                    WeightBits::Int4 => {
                        let lp2 = l_p / 2;
                        let w_base = bj * tiles_l * h_p * lp2;
                        let w_block = &w.data[w_base..w_base + tiles_l * h_p * lp2];
                        be.gemm_i4_block(a_block, w_block, &mut acc, tiles_l, e_p, h_p, l_p);
                    }
                }
                // Affine corrections + write-back (true rows/cols only).
                be.affine_correct(&acc, pa, w, self.bias.as_deref(), bi, bj, out);
            }
        }
    }

    /// Total h-tiles (the balancer's work-item count).
    pub fn h_tiles(&self) -> usize {
        self.packed.h_pad / self.packed.tile.h_p
    }

    /// Weight bytes streamed per full forward (decode-phase memory cost).
    pub fn weight_bytes(&self) -> usize {
        self.packed.nbytes()
    }
}

/// Reference implementation over the dequantized matrix (tests only; slow).
// lint: allow(hot-index): test-only oracle, never on the serving path; an out-of-bounds panic here is a test failure, which is the point
pub fn qlinear_reference(w: &QuantizedMatrix, x: &[f32], e: usize, bias: Option<&[f32]>) -> Vec<f32> {
    use crate::quant::asym::quantize_activations;
    let (q, params, sums) = quantize_activations(x, e, w.k);
    let mut out = vec![0f32; e * w.n];
    for r in 0..e {
        for c in 0..w.n {
            let mut acc = 0i64;
            let mut i = 0;
            w.for_row(c, |wq| {
                acc += q[r * w.k + i] as i64 * wq as i64;
                i += 1;
            });
            let sx = params[r].scale;
            let bx = params[r].bias;
            let sw = w.params[c].scale;
            let bw = w.params[c].bias;
            let mut v = sx * sw * acc as f32
                + sx * bw * sums[r] as f32
                + bx * sw * w.row_sums[c] as f32
                + w.k as f32 * bx * bw;
            if let Some(b) = bias {
                v += b[c];
            }
            out[r * w.n + c] = v;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::asym::WeightBits;
    use crate::util::prop::prop_check;
    use crate::util::rng::Rng;

    const TILE: TileConfig = TileConfig { e_p: 4, h_p: 8, l_p: 4 };

    fn close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            if (x - y).abs() > tol * (1.0 + x.abs().max(y.abs())) {
                return Err(format!("idx {i}: {x} vs {y}"));
            }
        }
        Ok(())
    }

    #[test]
    fn tiled_matches_reference_int8() {
        prop_check(80, |rng: &mut Rng| {
            let e = rng.range(1, 20);
            let l = rng.range(1, 24) * 2;
            let h = rng.range(1, 40);
            let wf = rng.normal_vec(h * l);
            let x = rng.normal_vec(e * l);
            let qm = QuantizedMatrix::from_f32(&wf, h, l, WeightBits::Int8);
            let lin = QLinear::new(&qm, TILE, None);
            let mut out = vec![0f32; e * h];
            lin.forward(&x, e, &mut out);
            let want = qlinear_reference(&qm, &x, e, None);
            close(&out, &want, 1e-5)
        });
    }

    #[test]
    fn tiled_matches_reference_int4() {
        prop_check(80, |rng: &mut Rng| {
            let e = rng.range(1, 16);
            let l = rng.range(1, 20) * 2;
            let h = rng.range(1, 32);
            let wf = rng.normal_vec(h * l);
            let x = rng.normal_vec(e * l);
            let qm = QuantizedMatrix::from_f32(&wf, h, l, WeightBits::Int4);
            let lin = QLinear::new(&qm, TILE, None);
            let mut out = vec![0f32; e * h];
            lin.forward(&x, e, &mut out);
            let want = qlinear_reference(&qm, &x, e, None);
            close(&out, &want, 1e-5)
        });
    }

    #[test]
    fn batched_rows_match_single_row_forwards_exactly() {
        // The row-independence invariant fused batched decode relies on:
        // an m-row forward equals m 1-row forwards, value for value, for
        // both weight widths (per-row dynamic quantization + exact integer
        // accumulation + per-row affine corrections).
        prop_check(40, |rng: &mut Rng| {
            let e = rng.range(2, 9);
            let l = rng.range(1, 20) * 2;
            let h = rng.range(1, 32);
            for bits in [WeightBits::Int8, WeightBits::Int4] {
                let wf = rng.normal_vec(h * l);
                let x = rng.normal_vec(e * l);
                let qm = QuantizedMatrix::from_f32(&wf, h, l, bits);
                let lin = QLinear::new(&qm, TILE, None);
                let mut batched = vec![0f32; e * h];
                lin.forward(&x, e, &mut batched);
                for r in 0..e {
                    let mut single = vec![0f32; h];
                    lin.forward(&x[r * l..(r + 1) * l], 1, &mut single);
                    if batched[r * h..(r + 1) * h] != single[..] {
                        return Err(format!("{bits:?}: row {r} of {e} diverged"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn close_to_float_gemm() {
        let mut rng = Rng::new(5);
        let (e, l, h) = (8, 128, 64);
        let wf = rng.normal_vec(h * l);
        let x = rng.normal_vec(e * l);
        let qm = QuantizedMatrix::from_f32(&wf, h, l, WeightBits::Int8);
        let lin = QLinear::new(&qm, TILE, None);
        let mut out = vec![0f32; e * h];
        lin.forward(&x, e, &mut out);
        let mut exact = vec![0f32; e * h];
        crate::cpu::gemm::matmul_f32(&x, &wf, &mut exact, e, l, h);
        let num: f32 = out.iter().zip(&exact).map(|(a, b)| (a - b) * (a - b)).sum();
        let den: f32 = exact.iter().map(|v| v * v).sum();
        assert!((num / den).sqrt() < 0.02, "rel {}", (num / den).sqrt());
    }

    #[test]
    fn bias_applied() {
        let mut rng = Rng::new(6);
        let (e, l, h) = (2, 8, 4);
        let wf = rng.normal_vec(h * l);
        let x = rng.normal_vec(e * l);
        let bias: Vec<f32> = (0..h).map(|i| i as f32).collect();
        let qm = QuantizedMatrix::from_f32(&wf, h, l, WeightBits::Int8);
        let with = QLinear::new(&qm, TILE, Some(bias.clone()));
        let without = QLinear::new(&qm, TILE, None);
        let mut a = vec![0f32; e * h];
        let mut b = vec![0f32; e * h];
        with.forward(&x, e, &mut a);
        without.forward(&x, e, &mut b);
        for r in 0..e {
            for c in 0..h {
                assert!((a[r * h + c] - b[r * h + c] - bias[c]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn partial_tile_ranges_compose() {
        // Computing tile ranges separately must equal the full forward —
        // the invariant the §5.2 balancer relies on.
        let mut rng = Rng::new(7);
        let (e, l, h) = (6, 32, 40);
        let wf = rng.normal_vec(h * l);
        let x = rng.normal_vec(e * l);
        let qm = QuantizedMatrix::from_f32(&wf, h, l, WeightBits::Int8);
        let lin = QLinear::new(&qm, TILE, None);
        let mut full = vec![0f32; e * h];
        lin.forward(&x, e, &mut full);
        let pa = pack_activations(&x, e, l, TILE);
        let mut split = vec![0f32; e * h];
        let tiles = lin.h_tiles();
        let mid = tiles / 2;
        lin.forward_packed(&pa, &mut split, 0, mid);
        lin.forward_packed(&pa, &mut split, mid, tiles);
        assert_eq!(full, split);
    }

    #[test]
    fn different_tiles_same_numbers() {
        // Solver output must not affect numerics, only layout.
        let mut rng = Rng::new(8);
        let (e, l, h) = (5, 24, 20);
        let wf = rng.normal_vec(h * l);
        let x = rng.normal_vec(e * l);
        let qm = QuantizedMatrix::from_f32(&wf, h, l, WeightBits::Int8);
        let t1 = TileConfig { e_p: 4, h_p: 8, l_p: 4 };
        let t2 = TileConfig { e_p: 10, h_p: 8, l_p: 8 };
        let t3 = TileConfig { e_p: 12, h_p: 8, l_p: 4 };
        let mut outs = Vec::new();
        for t in [t1, t2, t3] {
            let lin = QLinear::new(&qm, t, None);
            let mut out = vec![0f32; e * h];
            lin.forward(&x, e, &mut out);
            outs.push(out);
        }
        for o in &outs[1..] {
            for (a, b) in outs[0].iter().zip(o) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        }
    }
}
