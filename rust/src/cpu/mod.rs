//! Native mobile-CPU backend (paper §5): tiled quantized GEMM with the
//! hardware-driven data reorder, fused attention over the quantized KV
//! cache, and the fp32-sensitive pointwise ops. This is the engine the
//! optimization benches measure; numerics are cross-checked against the
//! AOT/PJRT path in rust/tests/.

pub mod activation;
pub mod attention;
pub mod backend;
pub mod gemm;
pub mod gemm_q;

pub use backend::{BackendChoice, ComputeBackend, ScalarBackend, SimdBackend};
pub use gemm_q::QLinear;
