//! The pluggable compute-backend seam (ROADMAP direction 3): every
//! per-tile hot op the forward pass runs — the packed int8 GEMM inner
//! loop, the dequant/affine correction, rmsnorm, softmax, swiglu, RoPE,
//! and the attention dot/accumulate primitives — goes through one
//! [`ComputeBackend`] trait object selected at model load.
//!
//! Two implementations ship today:
//!
//! * [`ScalarBackend`] — the reference. Every trait method's default body
//!   is the scalar loop the engine ran before this seam existed; the
//!   scalar backend overrides nothing.
//! * [`SimdBackend`] — AVX2 (runtime-detected via
//!   `is_x86_feature_detected!`) on x86-64, NEON on aarch64. It overrides
//!   **only the integer GEMM block ops**. Integer accumulation is exact
//!   and order-independent, so vector i8×i8→i32 MACs produce the same
//!   i32 accumulators as the scalar triple loop; every float op (affine
//!   correction, norms, softmax, RoPE, attention reductions) keeps the
//!   scalar implementation and therefore the scalar reduction order.
//!   That is the whole bit-identity argument: SIMD and scalar outputs
//!   are equal byte for byte, which `tests/backend_parity.rs` and the
//!   engine-level cross-backend suite pin down.
//!
//! Backend selection: [`select`] honors the `MNN_BACKEND` env var
//! (`scalar` | `simd` | `auto`) over the [`BackendChoice`] in
//! `EngineOptions`; `Auto` consults `reorder::isa::detect_host`. Forcing
//! `Simd` on a host without vector int8 support degrades gracefully to
//! scalar (this is how CI's SIMD leg skips on old runners without
//! failing).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::cpu::activation;
use crate::reorder::pack::{PackedActivations, PackedWeights};

// ---------------------------------------------------------------------------
// Selection.

/// Which compute backend `NativeModel::load` should instantiate.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BackendChoice {
    /// Use SIMD when `reorder::isa::detect_host` reports vector int8
    /// support on this host, scalar otherwise.
    #[default]
    Auto,
    /// Always the scalar reference backend.
    Scalar,
    /// Request the SIMD backend; falls back to scalar when the host has
    /// no supported vector ISA (never an error).
    Simd,
}

/// `MNN_BACKEND` env override (`scalar` | `simd` | `auto`); unknown
/// values are ignored so a typo cannot silently change numerics — both
/// backends are bit-identical, but perf reports should name the backend
/// that actually ran.
pub fn env_choice() -> Option<BackendChoice> {
    match std::env::var("MNN_BACKEND") {
        Ok(v) => match v.to_ascii_lowercase().as_str() {
            "scalar" => Some(BackendChoice::Scalar),
            "simd" => Some(BackendChoice::Simd),
            "auto" => Some(BackendChoice::Auto),
            _ => None,
        },
        Err(_) => None,
    }
}

/// Instantiate the backend for `choice`, after applying the env
/// override. This is the one constructor the model loader calls.
pub fn select(choice: BackendChoice) -> Arc<dyn ComputeBackend> {
    match env_choice().unwrap_or(choice) {
        BackendChoice::Scalar => Arc::new(ScalarBackend),
        BackendChoice::Simd | BackendChoice::Auto => match SimdBackend::try_new() {
            Some(s) => Arc::new(s),
            None => Arc::new(ScalarBackend),
        },
    }
}

// ---------------------------------------------------------------------------
// The trait.

/// The per-tile hot ops of the forward pass. Default method bodies are
/// the scalar reference; an accelerated backend overrides only what its
/// ISA can do **bit-identically** (integer ops are fair game anywhere;
/// float ops may only be overridden preserving the scalar reduction
/// order).
pub trait ComputeBackend: Send + Sync {
    /// Short stable name for metrics/logs ("scalar", "simd-avx2", ...).
    fn name(&self) -> &'static str;

    /// One output tile's full reduction, int8 weights:
    /// `acc[e_p, h_p] += Σ_bl a[bl, e_p, l_p] · w[bl, h_p, l_p]ᵀ` with
    /// exact i8×i8→i32 accumulation. `w` bytes are i8 bit patterns.
    fn gemm_i8_block(
        &self,
        a: &[i8],
        w: &[u8],
        acc: &mut [i32],
        tiles_l: usize,
        e_p: usize,
        h_p: usize,
        l_p: usize,
    ) {
        gemm_i8_block_scalar(a, w, acc, tiles_l, e_p, h_p, l_p);
    }

    /// Int4 variant: each `w` byte packs two unsigned nibble codes along
    /// l_p (low nibble = even index). Same exact i32 accumulation.
    fn gemm_i4_block(
        &self,
        a: &[i8],
        w: &[u8],
        acc: &mut [i32],
        tiles_l: usize,
        e_p: usize,
        h_p: usize,
        l_p: usize,
    ) {
        gemm_i4_block_scalar(a, w, acc, tiles_l, e_p, h_p, l_p);
    }

    /// Dequantize one output tile: apply the asymmetric-quantization
    /// affine corrections (gemm_q's Eq. above the kernel) to the i32
    /// accumulators and write true rows/cols of `out`. Float — any
    /// override must keep this exact expression order.
    fn affine_correct(
        &self,
        acc: &[i32],
        pa: &PackedActivations,
        w: &PackedWeights,
        bias: Option<&[f32]>,
        bi: usize,
        bj: usize,
        out: &mut [f32],
    ) {
        affine_correct_scalar(acc, pa, w, bias, bi, bj, out);
    }

    /// Row-wise RMS norm (delegates to `cpu::activation::rmsnorm`).
    fn rmsnorm(&self, x: &[f32], w: &[f32], out: &mut [f32], rows: usize, eps: f32) {
        activation::rmsnorm(x, w, out, rows, eps);
    }

    /// In-place fp32 softmax (delegates to `cpu::activation`).
    fn softmax_inplace(&self, xs: &mut [f32]) {
        activation::softmax_inplace(xs);
    }

    /// SwiGLU gate (delegates to `cpu::activation::swiglu`).
    fn swiglu(&self, gate: &[f32], up: &[f32], out: &mut [f32]) {
        activation::swiglu(gate, up, out);
    }

    /// Rotate one head in place: `head` is `[2 * half]`, `cos`/`sin` are
    /// the `[half]` table rows for this position.
    fn rope_apply(&self, head: &mut [f32], cos: &[f32], sin: &[f32]) {
        rope_apply_scalar(head, cos, sin);
    }

    /// Attention score dot product, in index order (the fixed reduction
    /// order the bit-identity contract depends on).
    fn dot(&self, a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = 0f32;
        for (&x, &y) in a.iter().zip(b) {
            acc += x * y;
        }
        acc
    }

    /// Attention value accumulate: `y[i] += w * x[i]`, in index order.
    fn axpy(&self, w: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        for (y, &x) in y.iter_mut().zip(x) {
            *y += w * x;
        }
    }
}

// ---------------------------------------------------------------------------
// Scalar reference bodies (shared by trait defaults and SIMD fallbacks).

pub(crate) fn gemm_i8_block_scalar(
    a: &[i8],
    w: &[u8],
    acc: &mut [i32],
    tiles_l: usize,
    e_p: usize,
    h_p: usize,
    l_p: usize,
) {
    for bl in 0..tiles_l {
        let a_panel = &a[bl * e_p * l_p..(bl + 1) * e_p * l_p];
        let w_panel = &w[bl * h_p * l_p..(bl + 1) * h_p * l_p];
        for ii in 0..e_p {
            let arow = &a_panel[ii * l_p..(ii + 1) * l_p];
            let accrow = &mut acc[ii * h_p..(ii + 1) * h_p];
            for (jj, acc_out) in accrow.iter_mut().enumerate() {
                let wrow = &w_panel[jj * l_p..(jj + 1) * l_p];
                let mut s = 0i32;
                for (&av, &wv) in arow.iter().zip(wrow) {
                    s += av as i32 * (wv as i8) as i32;
                }
                *acc_out += s;
            }
        }
    }
}

pub(crate) fn gemm_i4_block_scalar(
    a: &[i8],
    w: &[u8],
    acc: &mut [i32],
    tiles_l: usize,
    e_p: usize,
    h_p: usize,
    l_p: usize,
) {
    let lp2 = l_p / 2;
    for bl in 0..tiles_l {
        let a_panel = &a[bl * e_p * l_p..(bl + 1) * e_p * l_p];
        let w_panel = &w[bl * h_p * lp2..(bl + 1) * h_p * lp2];
        for ii in 0..e_p {
            let arow = &a_panel[ii * l_p..(ii + 1) * l_p];
            let accrow = &mut acc[ii * h_p..(ii + 1) * h_p];
            for (jj, acc_out) in accrow.iter_mut().enumerate() {
                let wrow = &w_panel[jj * lp2..(jj + 1) * lp2];
                let mut s = 0i32;
                for (ap, &byte) in arow.chunks_exact(2).zip(wrow) {
                    let &[a0, a1] = ap else { continue };
                    s += a0 as i32 * (byte & 0xF) as i32;
                    s += a1 as i32 * (byte >> 4) as i32;
                }
                *acc_out += s;
            }
        }
    }
}

// lint: allow(hot-index): PackedActivations/PackedWeights size params and row_sums to e/h and acc to e_p*h_p by construction (reorder::pack); r/c are bounds-checked against e/h before use
pub(crate) fn affine_correct_scalar(
    acc: &[i32],
    pa: &PackedActivations,
    w: &PackedWeights,
    bias: Option<&[f32]>,
    bi: usize,
    bj: usize,
    out: &mut [f32],
) {
    let e_p = pa.tile.e_p;
    let h_p = w.tile.h_p;
    let l_true = w.l as f32;
    for ii in 0..e_p {
        let r = bi * e_p + ii;
        if r >= pa.e {
            break;
        }
        let sx = pa.params[r].scale;
        let bx = pa.params[r].bias;
        let xsum = pa.row_sums[r] as f32;
        for jj in 0..h_p {
            let c = bj * h_p + jj;
            if c >= w.h {
                break;
            }
            let sw = w.params[c].scale;
            let bw = w.params[c].bias;
            let wsum = w.row_sums[c] as f32;
            let a = acc[ii * h_p + jj] as f32;
            let mut v = sx * sw * a + sx * bw * xsum + bx * sw * wsum + l_true * bx * bw;
            if let Some(b) = bias {
                v += b[c];
            }
            out[r * w.h + c] = v;
        }
    }
}

pub(crate) fn rope_apply_scalar(head: &mut [f32], cos: &[f32], sin: &[f32]) {
    let half = cos.len();
    debug_assert_eq!(sin.len(), half);
    debug_assert_eq!(head.len(), 2 * half);
    let (lo, hi) = head.split_at_mut(half.min(head.len()));
    for (((a, b), &c), &s) in lo.iter_mut().zip(hi).zip(cos).zip(sin) {
        let (av, bv) = (*a, *b);
        *a = av * c - bv * s;
        *b = bv * c + av * s;
    }
}

// ---------------------------------------------------------------------------
// The backends.

/// The scalar reference backend: every method keeps its default body.
#[derive(Clone, Copy, Debug, Default)]
pub struct ScalarBackend;

impl ComputeBackend for ScalarBackend {
    fn name(&self) -> &'static str {
        "scalar"
    }
}

#[cfg(target_arch = "aarch64")]
const SIMD_NAME: &str = "simd-neon";
#[cfg(not(target_arch = "aarch64"))]
const SIMD_NAME: &str = "simd-avx2";

/// Vector int8 GEMM backend. Overrides only the integer block ops (see
/// module docs for why that is exactly the bit-identity-preserving
/// subset); tile shapes the vector kernels do not cover (l_p ≠ 8, odd
/// h_p) fall back to the scalar bodies inside the same backend, so
/// numerics never depend on shape.
#[derive(Clone, Copy, Debug)]
pub struct SimdBackend;

impl SimdBackend {
    /// `Some` only when this host can actually run the vector kernels:
    /// x86-64 with AVX2 (checked at runtime — `reorder::isa::detect_host`
    /// must agree and `is_x86_feature_detected!` must confirm), or any
    /// aarch64 (NEON is baseline). Everything else gets `None` and the
    /// caller degrades to [`ScalarBackend`].
    pub fn try_new() -> Option<SimdBackend> {
        #[cfg(target_arch = "x86_64")]
        {
            let isa = crate::reorder::isa::detect_host();
            if isa.name == crate::reorder::isa::X86_AVX2.name
                && is_x86_feature_detected!("avx2")
            {
                return Some(SimdBackend);
            }
            None
        }
        #[cfg(target_arch = "aarch64")]
        {
            Some(SimdBackend)
        }
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        {
            None
        }
    }
}

impl ComputeBackend for SimdBackend {
    fn name(&self) -> &'static str {
        SIMD_NAME
    }

    fn gemm_i8_block(
        &self,
        a: &[i8],
        w: &[u8],
        acc: &mut [i32],
        tiles_l: usize,
        e_p: usize,
        h_p: usize,
        l_p: usize,
    ) {
        #[cfg(target_arch = "x86_64")]
        if l_p == 8 && h_p % 2 == 0 {
            // SAFETY: SimdBackend is constructed only after the AVX2
            // runtime check passed (`detect`), and the l_p == 8 /
            // even-h_p guards above establish the kernel's layout
            // preconditions.
            unsafe { simd_x86::gemm_i8_block(a, w, acc, tiles_l, e_p, h_p) };
            return;
        }
        #[cfg(target_arch = "aarch64")]
        if l_p == 8 {
            // SAFETY: NEON is baseline on aarch64; l_p == 8 is the
            // kernel's only layout precondition.
            unsafe { simd_neon::gemm_i8_block(a, w, acc, tiles_l, e_p, h_p) };
            return;
        }
        gemm_i8_block_scalar(a, w, acc, tiles_l, e_p, h_p, l_p);
    }

    fn gemm_i4_block(
        &self,
        a: &[i8],
        w: &[u8],
        acc: &mut [i32],
        tiles_l: usize,
        e_p: usize,
        h_p: usize,
        l_p: usize,
    ) {
        #[cfg(target_arch = "x86_64")]
        if l_p == 8 && h_p % 2 == 0 {
            // SAFETY: same contract as gemm_i8_block above — AVX2 verified
            // at construction, l_p == 8 and even h_p guaranteed here.
            unsafe { simd_x86::gemm_i4_block(a, w, acc, tiles_l, e_p, h_p) };
            return;
        }
        gemm_i4_block_scalar(a, w, acc, tiles_l, e_p, h_p, l_p);
    }
}

// ---------------------------------------------------------------------------
// AVX2 kernels. Exactness note: we deliberately avoid the classic
// pmaddubsw trick (sign-transfer via _mm256_sign_epi8 wraps the weight
// code -128), and instead widen both operands to i16 and use madd_epi16:
// i8×i8 products fit i16×i16→i32 pairwise sums with no saturation for
// the whole code range, so the vector accumulators hold exactly the
// scalar triple loop's integers.

#[cfg(target_arch = "x86_64")]
mod simd_x86 {
    use std::arch::x86_64::*;

    /// Sum the four i32 lanes of an SSE register.
    // SAFETY: uses only SSE2 intrinsics, baseline on every x86_64 target;
    // `unsafe fn` solely so it can inline into the target_feature callers.
    #[inline]
    unsafe fn hsum4(v: __m128i) -> i32 {
        let s = _mm_add_epi32(v, _mm_unpackhi_epi64(v, v));
        let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b01));
        _mm_cvtsi128_si32(s)
    }

    /// Int8 block kernel, l_p == 8, even h_p. Per (row, weight-row-pair):
    /// broadcast the 8 activation codes to both 128-bit lanes, widen a
    /// 16-byte load covering two weight rows, madd, and keep the 8-lane
    /// i32 accumulator live across the whole bl walk; lanes 0–3 reduce to
    /// weight row jj, lanes 4–7 to row jj+1.
    // lint: allow(hot-index): acc is e_p*h_p by the packed-tile contract and jj+1 < h_p because h_p is even; same bounds the pointer reads rely on
    // SAFETY: caller must have verified AVX2 at runtime and uphold the
    // packed-tile layout — a holds tiles_l*e_p*8 i8 codes, w holds
    // tiles_l*h_p*8 weight codes, acc holds e_p*h_p i32, h_p is even
    // (the 16-byte weight load covers rows jj and jj+1).
    #[target_feature(enable = "avx2")]
    pub unsafe fn gemm_i8_block(
        a: &[i8],
        w: &[u8],
        acc: &mut [i32],
        tiles_l: usize,
        e_p: usize,
        h_p: usize,
    ) {
        const L_P: usize = 8;
        let ap = a.as_ptr();
        let wp = w.as_ptr();
        for ii in 0..e_p {
            for jp in 0..h_p / 2 {
                let mut vacc = _mm256_setzero_si256();
                for bl in 0..tiles_l {
                    let arow = ap.add((bl * e_p + ii) * L_P);
                    let wrow = wp.add((bl * h_p + 2 * jp) * L_P);
                    let a8 = _mm_loadl_epi64(arow as *const __m128i);
                    let a16 = _mm256_cvtepi8_epi16(_mm_unpacklo_epi64(a8, a8));
                    let w16 = _mm256_cvtepi8_epi16(_mm_loadu_si128(wrow as *const __m128i));
                    vacc = _mm256_add_epi32(vacc, _mm256_madd_epi16(a16, w16));
                }
                let jj = 2 * jp;
                acc[ii * h_p + jj] += hsum4(_mm256_castsi256_si128(vacc));
                acc[ii * h_p + jj + 1] += hsum4(_mm256_extracti128_si256(vacc, 1));
            }
        }
    }

    /// Int4 block kernel, l_p == 8, even h_p. Two packed weight rows are
    /// 8 bytes; split nibbles and interleave (`unpacklo(lo, hi)`) to
    /// recover element order (low nibble = even l index), then run the
    /// same widen+madd pipeline. Nibbles are 0..15, so the i8→i16
    /// sign-extension equals the scalar zero-extension.
    // lint: allow(hot-index): acc is e_p*h_p by the packed-tile contract and jj+1 < h_p because h_p is even; same bounds the pointer reads rely on
    // SAFETY: caller must have verified AVX2 at runtime and uphold the
    // packed-tile layout — a holds tiles_l*e_p*8 i8 codes, w holds
    // tiles_l*h_p*4 packed nibble bytes, acc holds e_p*h_p i32, h_p is
    // even (each 8-byte weight load covers packed rows jj and jj+1).
    #[target_feature(enable = "avx2")]
    pub unsafe fn gemm_i4_block(
        a: &[i8],
        w: &[u8],
        acc: &mut [i32],
        tiles_l: usize,
        e_p: usize,
        h_p: usize,
    ) {
        const L_P: usize = 8;
        const LP2: usize = 4;
        let ap = a.as_ptr();
        let wp = w.as_ptr();
        let nib = _mm_set1_epi8(0x0F);
        for ii in 0..e_p {
            for jp in 0..h_p / 2 {
                let mut vacc = _mm256_setzero_si256();
                for bl in 0..tiles_l {
                    let arow = ap.add((bl * e_p + ii) * L_P);
                    let wrow = wp.add((bl * h_p + 2 * jp) * LP2);
                    let a8 = _mm_loadl_epi64(arow as *const __m128i);
                    let a16 = _mm256_cvtepi8_epi16(_mm_unpacklo_epi64(a8, a8));
                    let packed = _mm_loadl_epi64(wrow as *const __m128i);
                    let lo = _mm_and_si128(packed, nib);
                    let hi = _mm_and_si128(_mm_srli_epi16(packed, 4), nib);
                    let w16 = _mm256_cvtepi8_epi16(_mm_unpacklo_epi8(lo, hi));
                    vacc = _mm256_add_epi32(vacc, _mm256_madd_epi16(a16, w16));
                }
                let jj = 2 * jp;
                acc[ii * h_p + jj] += hsum4(_mm256_castsi256_si128(vacc));
                acc[ii * h_p + jj + 1] += hsum4(_mm256_extracti128_si256(vacc, 1));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// NEON kernel (aarch64): widening multiply (`vmull_s8`) + widening
// horizontal add — exact for the whole i8 range, like the AVX2 path.
// Int4 stays scalar on NEON for now (still bit-identical by the same
// shared-accumulator argument).

#[cfg(target_arch = "aarch64")]
mod simd_neon {
    use std::arch::aarch64::*;

    // lint: allow(hot-index): acc is e_p*h_p by the packed-tile contract; same bounds the pointer reads rely on
    // SAFETY: caller must uphold the packed-tile layout — a holds
    // tiles_l*e_p*8 i8 codes, w holds tiles_l*h_p*8 weight codes, acc
    // holds e_p*h_p i32 (NEON itself is baseline on aarch64).
    #[target_feature(enable = "neon")]
    pub unsafe fn gemm_i8_block(
        a: &[i8],
        w: &[u8],
        acc: &mut [i32],
        tiles_l: usize,
        e_p: usize,
        h_p: usize,
    ) {
        const L_P: usize = 8;
        let ap = a.as_ptr();
        let wp = w.as_ptr() as *const i8;
        for ii in 0..e_p {
            for jj in 0..h_p {
                let mut s = 0i32;
                for bl in 0..tiles_l {
                    let av = vld1_s8(ap.add((bl * e_p + ii) * L_P));
                    let wv = vld1_s8(wp.add((bl * h_p + jj) * L_P));
                    s += vaddlvq_s16(vmull_s8(av, wv));
                }
                acc[ii * h_p + jj] += s;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Per-backend op counters, surfaced through `EngineMetrics`.

/// Snapshot of the live backend + its op counts (coordinator metrics).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ComputeBackendMetrics {
    /// `ComputeBackend::name()` of the live backend; empty when no
    /// compute-backend-aware model is attached (e.g. the PJRT runtime).
    pub backend: &'static str,
    /// Packed GEMM forwards dispatched (one per linear-layer call).
    pub gemm_calls: u64,
    /// Output tiles those forwards covered (the balancer's work items).
    pub gemm_tiles: u64,
    /// Attention rows computed (decode tokens + prefill chunk rows).
    pub attention_rows: u64,
    /// RMS-norm rows.
    pub norm_rows: u64,
    /// SwiGLU rows.
    pub activation_rows: u64,
    /// Heads rotated by RoPE.
    pub rope_heads: u64,
}

/// Lock-free counters the model increments at its backend call sites.
#[derive(Debug, Default)]
pub struct OpCounters {
    pub gemm_calls: AtomicU64,
    pub gemm_tiles: AtomicU64,
    pub attention_rows: AtomicU64,
    pub norm_rows: AtomicU64,
    pub activation_rows: AtomicU64,
    pub rope_heads: AtomicU64,
}

impl OpCounters {
    pub fn snapshot(&self, backend: &'static str) -> ComputeBackendMetrics {
        ComputeBackendMetrics {
            backend,
            gemm_calls: self.gemm_calls.load(Ordering::Relaxed),
            gemm_tiles: self.gemm_tiles.load(Ordering::Relaxed),
            attention_rows: self.attention_rows.load(Ordering::Relaxed),
            norm_rows: self.norm_rows.load(Ordering::Relaxed),
            activation_rows: self.activation_rows.load(Ordering::Relaxed),
            rope_heads: self.rope_heads.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_codes(rng: &mut Rng, n: usize) -> Vec<i8> {
        (0..n).map(|_| (rng.below(256) as i32 - 128) as i8).collect()
    }

    /// Raw-block parity: the SIMD integer kernels must reproduce the
    /// scalar accumulators exactly, including the weight code -128 (the
    /// value the pmaddubsw sign trick would corrupt).
    #[test]
    fn simd_gemm_i8_block_matches_scalar_exactly() {
        let Some(simd) = SimdBackend::try_new() else {
            return; // host without vector int8 — nothing to compare
        };
        let mut rng = Rng::new(11);
        for &(tiles_l, e_p, h_p) in &[(1usize, 1usize, 2usize), (3, 4, 8), (7, 8, 8), (2, 5, 6)] {
            let l_p = 8usize;
            let a = rand_codes(&mut rng, tiles_l * e_p * l_p);
            let mut w: Vec<u8> =
                (0..tiles_l * h_p * l_p).map(|_| rng.below(256) as u8).collect();
            // Force some -128 weight codes into every row pair.
            for i in (0..w.len()).step_by(5) {
                w[i] = 0x80;
            }
            let mut want = vec![7i32; e_p * h_p]; // nonzero: += semantics
            let mut got = want.clone();
            gemm_i8_block_scalar(&a, &w, &mut want, tiles_l, e_p, h_p, l_p);
            simd.gemm_i8_block(&a, &w, &mut got, tiles_l, e_p, h_p, l_p);
            assert_eq!(want, got, "shape ({tiles_l},{e_p},{h_p})");
        }
    }

    #[test]
    fn simd_gemm_i4_block_matches_scalar_exactly() {
        let Some(simd) = SimdBackend::try_new() else {
            return;
        };
        let mut rng = Rng::new(12);
        for &(tiles_l, e_p, h_p) in &[(1usize, 1usize, 2usize), (4, 3, 8), (6, 8, 4)] {
            let l_p = 8usize;
            let a = rand_codes(&mut rng, tiles_l * e_p * l_p);
            let w: Vec<u8> =
                (0..tiles_l * h_p * l_p / 2).map(|_| rng.below(256) as u8).collect();
            let mut want = vec![-3i32; e_p * h_p];
            let mut got = want.clone();
            gemm_i4_block_scalar(&a, &w, &mut want, tiles_l, e_p, h_p, l_p);
            simd.gemm_i4_block(&a, &w, &mut got, tiles_l, e_p, h_p, l_p);
            assert_eq!(want, got, "shape ({tiles_l},{e_p},{h_p})");
        }
    }

    /// Shapes outside the vector kernels' fast path (l_p ≠ 8, odd h_p)
    /// must still be exact — they take the in-backend scalar fallback.
    #[test]
    fn simd_fallback_shapes_match_scalar_exactly() {
        let Some(simd) = SimdBackend::try_new() else {
            return;
        };
        let mut rng = Rng::new(13);
        for &(tiles_l, e_p, h_p, l_p) in &[(2usize, 3usize, 5usize, 8usize), (3, 4, 8, 4), (2, 2, 7, 16)] {
            let a = rand_codes(&mut rng, tiles_l * e_p * l_p);
            let w: Vec<u8> =
                (0..tiles_l * h_p * l_p).map(|_| rng.below(256) as u8).collect();
            let mut want = vec![0i32; e_p * h_p];
            let mut got = vec![0i32; e_p * h_p];
            gemm_i8_block_scalar(&a, &w, &mut want, tiles_l, e_p, h_p, l_p);
            simd.gemm_i8_block(&a, &w, &mut got, tiles_l, e_p, h_p, l_p);
            assert_eq!(want, got, "shape ({tiles_l},{e_p},{h_p},{l_p})");
        }
    }

    #[test]
    fn forced_scalar_choice_always_selects_scalar() {
        // The override every CI leg and parity test depends on: Scalar
        // must win regardless of what the host supports. (An MNN_BACKEND
        // env var outranks the choice by design — skip under one.)
        if std::env::var("MNN_BACKEND").is_ok() {
            return;
        }
        assert_eq!(select(BackendChoice::Scalar).name(), "scalar");
    }

    #[test]
    fn forced_simd_degrades_gracefully_without_vector_isa() {
        if std::env::var("MNN_BACKEND").is_ok() {
            return;
        }
        let b = select(BackendChoice::Simd);
        match SimdBackend::try_new() {
            Some(s) => assert_eq!(b.name(), s.name()),
            None => assert_eq!(b.name(), "scalar"),
        }
    }

    #[test]
    fn env_override_outranks_the_engine_choice() {
        // Mutating the process env would race the parallel test harness;
        // instead pin the resolution rule itself: when MNN_BACKEND is set
        // (the CI legs), every choice resolves to the env's backend.
        match env_choice() {
            Some(BackendChoice::Scalar) => {
                for c in [BackendChoice::Auto, BackendChoice::Simd, BackendChoice::Scalar] {
                    assert_eq!(select(c).name(), "scalar");
                }
            }
            Some(BackendChoice::Simd) | Some(BackendChoice::Auto) => {
                let want = match SimdBackend::try_new() {
                    Some(s) => s.name(),
                    None => "scalar",
                };
                assert_eq!(select(BackendChoice::Scalar).name(), want);
            }
            None => {
                // No env var: choices resolve independently.
                assert_eq!(select(BackendChoice::Scalar).name(), "scalar");
            }
        }
    }

    #[test]
    fn op_counters_snapshot_carries_backend_name() {
        let c = OpCounters::default();
        c.gemm_calls.fetch_add(3, Ordering::Relaxed);
        c.rope_heads.fetch_add(8, Ordering::Relaxed);
        let m = c.snapshot("scalar");
        assert_eq!(m.backend, "scalar");
        assert_eq!(m.gemm_calls, 3);
        assert_eq!(m.rope_heads, 8);
        assert_eq!(m.attention_rows, 0);
    }
}
