//! The serving coordinator: request queue, prefill/decode scheduler,
//! session management, metrics.
//!
//! Mobile deployment is single-device, so there is no distributed router;
//! the coordinator's job (mirroring MNN-LLM's engine loop) is to (a) queue
//! and admit requests, (b) schedule the two phases — prefill is
//! compute-bound, decode is memory-bound (§2.1) — and (c) track per-request
//! and engine-wide metrics. The PJRT backend keeps one KV state per
//! session, so decode steps from concurrent sessions interleave
//! round-robin; the native backend owns its KV and serves FIFO.

pub mod metrics;
pub mod request;
pub mod scheduler;

pub use metrics::{EngineMetrics, RequestMetrics};
pub use request::{Request, RequestId, Response};
pub use scheduler::{Coordinator, SchedulePolicy};
