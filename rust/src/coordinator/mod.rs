//! The serving coordinator: request queue, prefill/decode scheduler,
//! session management, metrics.
//!
//! Mobile deployment is single-device, so there is no distributed router;
//! the coordinator's job (mirroring MNN-LLM's engine loop) is to (a) queue
//! and admit requests — on the native backend, admission consults the
//! shared KV page pool's byte budget and preempts sessions to flash under
//! pressure — (b) schedule the two phases — prefill is compute-bound,
//! decode is memory-bound (§2.1) — and (c) track per-request and
//! engine-wide metrics, including KV spill/restore/preemption counts.
//! Both backends support `Interleaved` round-robin decode (continuous
//! batching): the PJRT backend threads one `KvState` per session, the
//! native backend one `NativeSession` over the paged KV pool.

pub mod metrics;
pub mod request;
pub mod scheduler;

pub use metrics::{EngineMetrics, KvPressureMetrics, RequestMetrics};
pub use request::{Request, RequestId, Response};
pub use scheduler::{Coordinator, SchedulePolicy};
