//! The serving coordinator: request queue, event-driven step scheduler,
//! session management, metrics.
//!
//! Mobile deployment is single-device, so there is no distributed router;
//! the engine's job (mirroring MNN-LLM's engine loop) is to (a) queue and
//! admit requests — mid-flight submission included; on the native backend
//! admission consults the shared KV page pool's byte budget and preempts
//! sessions to flash under pressure — (b) schedule the two phases one
//! [`scheduler::Engine::step`] at a time — prefill is compute-bound,
//! decode is memory-bound (§2.1) — emitting typed [`events::EngineEvent`]s
//! in decode order, and (c) track per-request and engine-wide metrics,
//! including KV spill/restore/preemption counts.
//!
//! Both runtimes sit behind one [`backend::InferenceBackend`] trait
//! (`NativeModel` with `NativeSession`s over the paged KV pool;
//! `PjrtRuntime` threading one `KvState` per session), so the sample/decode
//! loop exists exactly once, policy-parameterized (`Fifo` / `Interleaved`
//! round-robin continuous batching).

pub mod backend;
pub mod events;
pub mod metrics;
pub mod request;
pub mod scheduler;

pub use backend::{AnySession, Backend, InferenceBackend, RowOutcome, RowWork, TickLimits};
pub use events::{EngineEvent, FinishReason, TokenStream};
pub use metrics::{EngineMetrics, KvPressureMetrics, RequestMetrics};
pub use request::{Request, RequestId, Response};
pub use scheduler::{Coordinator, Engine, SchedulePolicy};
