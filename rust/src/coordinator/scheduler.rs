//! The engine loop: admission queue + prefill/decode scheduling over either
//! backend.
//!
//! Two policies:
//! * `Fifo` — complete each request before starting the next (the native
//!   backend's mode: its KV cache is engine-resident).
//! * `Interleaved` — prefill on arrival, then round-robin single-token
//!   decode across all active sessions (PJRT backend: one `KvState` per
//!   session). This keeps TTFT low for late arrivals while decode
//!   bandwidth is shared — the mobile analogue of continuous batching.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::metrics::{EngineMetrics, RequestMetrics};
use crate::coordinator::request::{Request, Response};
use crate::model::native::NativeModel;
use crate::model::sampler;
use crate::model::tokenizer::EOS;
use crate::runtime::{KvState, PjrtRuntime};
use crate::util::rng::Rng;

/// Scheduling policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulePolicy {
    Fifo,
    Interleaved,
}

/// The serving backend.
pub enum Backend {
    Native(Box<NativeModel>),
    Pjrt(Box<PjrtRuntime>),
}

impl Backend {
    pub fn max_len(&self) -> usize {
        match self {
            Backend::Native(m) => m.config.max_len,
            Backend::Pjrt(rt) => rt.manifest.model.max_len,
        }
    }
}

struct ActiveSession {
    req: Request,
    kv: KvState,
    tokens: Vec<usize>,
    last: usize,
    admitted: Instant,
    prefill_s: f64,
    decode_started: Instant,
    done: bool,
}

/// The coordinator: queue + scheduler + metrics.
pub struct Coordinator {
    backend: Backend,
    pub policy: SchedulePolicy,
    queue: VecDeque<Request>,
    next_id: u64,
    pub metrics: EngineMetrics,
    rng: Rng,
}

impl Coordinator {
    pub fn new(backend: Backend, policy: SchedulePolicy) -> Self {
        Coordinator {
            backend,
            policy,
            queue: VecDeque::new(),
            next_id: 1,
            metrics: EngineMetrics::default(),
            rng: Rng::new(0x5e5510),
        }
    }

    /// Queue a request; returns its id.
    pub fn submit(&mut self, prompt: Vec<usize>, max_new_tokens: usize) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Request::new(id, prompt, max_new_tokens));
        id
    }

    /// Queue a fully-specified request.
    pub fn submit_request(&mut self, mut req: Request) -> u64 {
        req.id = self.next_id;
        self.next_id += 1;
        let id = req.id;
        self.queue.push_back(req);
        id
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Drain the queue to completion; returns responses in completion order.
    pub fn run_all(&mut self) -> Result<Vec<Response>> {
        match self.policy {
            SchedulePolicy::Fifo => self.run_fifo(),
            SchedulePolicy::Interleaved => self.run_interleaved(),
        }
    }

    fn run_fifo(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        while let Some(req) = self.queue.pop_front() {
            let admitted = Instant::now();
            let cap = self.backend.max_len();
            let budget = req.max_new_tokens.min(cap.saturating_sub(req.prompt.len() + 1));
            let (tokens, prefill_s, decode_s) = match &mut self.backend {
                Backend::Native(m) => {
                    m.reset_session();
                    m.lora_task = req.lora_task.clone();
                    let t0 = Instant::now();
                    let logits = m.prefill(&req.prompt);
                    let prefill_s = t0.elapsed().as_secs_f64();
                    let mut tok = sampler::sample(&logits, req.sampler, &mut self.rng);
                    let mut tokens = vec![tok];
                    let t1 = Instant::now();
                    for _ in 1..budget {
                        if tok == EOS {
                            break;
                        }
                        let logits = m.decode(tok);
                        tok = sampler::sample(&logits, req.sampler, &mut self.rng);
                        tokens.push(tok);
                    }
                    (tokens, prefill_s, t1.elapsed().as_secs_f64())
                }
                Backend::Pjrt(rt) => {
                    let t0 = Instant::now();
                    let (logits, mut kv) = rt.prefill(&req.prompt)?;
                    let prefill_s = t0.elapsed().as_secs_f64();
                    let mut tok = sampler::sample(&logits, req.sampler, &mut self.rng);
                    let mut tokens = vec![tok];
                    let t1 = Instant::now();
                    for _ in 1..budget {
                        if tok == EOS {
                            break;
                        }
                        let logits = rt.decode(tok, &mut kv)?;
                        tok = sampler::sample(&logits, req.sampler, &mut self.rng);
                        tokens.push(tok);
                    }
                    (tokens, prefill_s, t1.elapsed().as_secs_f64())
                }
            };
            let m = RequestMetrics {
                prompt_tokens: req.prompt.len(),
                new_tokens: tokens.len(),
                ttft_s: prefill_s,
                prefill_s,
                decode_s,
                e2e_s: admitted.elapsed().as_secs_f64(),
            };
            self.metrics.push(m);
            out.push(Response { id: req.id, tokens, metrics: m });
        }
        Ok(out)
    }

    fn run_interleaved(&mut self) -> Result<Vec<Response>> {
        let Backend::Pjrt(rt) = &self.backend else {
            // The native backend owns one KV; fall back to FIFO.
            return self.run_fifo();
        };
        let cap = rt.manifest.model.max_len;
        // Phase 1: prefill every queued request (compute-bound; run first
        // so every session has a first token — lowest aggregate TTFT).
        let mut active: Vec<ActiveSession> = Vec::new();
        while let Some(req) = self.queue.pop_front() {
            let admitted = Instant::now();
            let t0 = Instant::now();
            let (logits, kv) = rt.prefill(&req.prompt)?;
            let prefill_s = t0.elapsed().as_secs_f64();
            let tok = sampler::sample(&logits, req.sampler, &mut self.rng);
            active.push(ActiveSession {
                last: tok,
                tokens: vec![tok],
                kv,
                admitted,
                prefill_s,
                decode_started: Instant::now(),
                done: tok == EOS || req.max_new_tokens <= 1,
                req,
            });
        }
        // Phase 2: round-robin decode (memory-bound; one token per active
        // session per sweep).
        let mut out = Vec::new();
        while active.iter().any(|s| !s.done) {
            for s in active.iter_mut().filter(|s| !s.done) {
                let logits = rt.decode(s.last, &mut s.kv)?;
                let tok = sampler::sample(&logits, s.req.sampler, &mut self.rng);
                s.tokens.push(tok);
                s.last = tok;
                if tok == EOS
                    || s.tokens.len() >= s.req.max_new_tokens
                    || s.kv.pos + 1 >= cap
                {
                    s.done = true;
                }
            }
        }
        for s in active {
            let m = RequestMetrics {
                prompt_tokens: s.req.prompt.len(),
                new_tokens: s.tokens.len(),
                ttft_s: s.prefill_s,
                prefill_s: s.prefill_s,
                decode_s: s.decode_started.elapsed().as_secs_f64(),
                e2e_s: s.admitted.elapsed().as_secs_f64(),
            };
            self.metrics.push(m);
            out.push(Response { id: s.req.id, tokens: s.tokens, metrics: m });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::native::EngineOptions;
    use std::path::PathBuf;

    fn artifacts() -> Option<PathBuf> {
        let d = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn fifo_native_serves_queue() {
        let Some(dir) = artifacts() else { return };
        let m = NativeModel::load(&dir, EngineOptions::default()).unwrap();
        let mut c = Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Fifo);
        let a = c.submit(vec![1, 2, 3], 4);
        let b = c.submit(vec![9, 8], 3);
        assert_eq!(c.pending(), 2);
        let responses = c.run_all().unwrap();
        assert_eq!(c.pending(), 0);
        assert_eq!(responses.len(), 2);
        assert_eq!(responses[0].id, a);
        assert_eq!(responses[1].id, b);
        assert_eq!(responses[0].tokens.len(), 4);
        assert_eq!(responses[1].tokens.len(), 3);
        assert_eq!(c.metrics.count(), 2);
        assert!(c.metrics.mean_decode_tok_s() > 0.0);
    }

    #[test]
    fn interleaved_pjrt_matches_fifo_tokens() {
        let Some(dir) = artifacts() else { return };
        // Greedy decoding must produce identical tokens under both
        // schedules — interleaving only changes the order of work.
        let rt1 = PjrtRuntime::load(&dir).unwrap();
        let mut fifo = Coordinator::new(Backend::Pjrt(Box::new(rt1)), SchedulePolicy::Fifo);
        fifo.submit(vec![5, 6, 7], 4);
        fifo.submit(vec![100, 101], 4);
        let r_fifo = fifo.run_all().unwrap();

        let rt2 = PjrtRuntime::load(&dir).unwrap();
        let mut inter =
            Coordinator::new(Backend::Pjrt(Box::new(rt2)), SchedulePolicy::Interleaved);
        inter.submit(vec![5, 6, 7], 4);
        inter.submit(vec![100, 101], 4);
        let r_inter = inter.run_all().unwrap();

        for (a, b) in r_fifo.iter().zip(&r_inter) {
            assert_eq!(a.tokens, b.tokens, "schedule must not change greedy output");
        }
    }

    #[test]
    fn generation_respects_max_len() {
        let Some(dir) = artifacts() else { return };
        let m = NativeModel::load(&dir, EngineOptions::default()).unwrap();
        let cap = m.config.max_len;
        let mut c = Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Fifo);
        c.submit(vec![1; 10], cap * 2); // absurd budget gets clamped
        let r = c.run_all().unwrap();
        assert!(r[0].tokens.len() + 10 <= cap);
    }
}
