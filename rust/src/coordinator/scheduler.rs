//! The engine loop: admission queue + prefill/decode scheduling over either
//! backend.
//!
//! Two policies:
//! * `Fifo` — complete each request before starting the next.
//! * `Interleaved` — prefill on arrival, then round-robin single-token
//!   decode across all active sessions. This keeps TTFT low for late
//!   arrivals while decode bandwidth is shared — the mobile analogue of
//!   continuous batching. Works on **both** backends: the PJRT path
//!   threads one `KvState` per session; the native path holds one
//!   `NativeSession` per request, all drawing KV pages from the model's
//!   shared budgeted pool.
//!
//! Native admission control: before prefilling a new request the
//! coordinator asks the KV pool whether the prompt's estimated KV fits in
//! the byte budget; if not, running sessions are **preempted to flash**
//! (their resident pages spilled and released) oldest-first until it fits.
//! Appends under residual pressure degrade the same way, so a budget
//! smaller than the total working set still completes every request —
//! spill/restore/preemption counts land in `EngineMetrics::kv`.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::metrics::{EngineMetrics, RequestMetrics};
use crate::coordinator::request::{Request, Response};
use crate::model::native::{NativeModel, NativeSession};
use crate::model::sampler;
use crate::model::tokenizer::EOS;
use crate::runtime::{KvState, PjrtRuntime};
use crate::util::rng::Rng;

/// Scheduling policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulePolicy {
    Fifo,
    Interleaved,
}

/// The serving backend.
pub enum Backend {
    Native(Box<NativeModel>),
    Pjrt(Box<PjrtRuntime>),
}

impl Backend {
    pub fn max_len(&self) -> usize {
        match self {
            Backend::Native(m) => m.config.max_len,
            Backend::Pjrt(rt) => rt.manifest.model.max_len,
        }
    }
}

/// New-token budget for a request under the backend's context cap.
fn token_budget(req: &Request, cap: usize) -> usize {
    req.max_new_tokens.min(cap.saturating_sub(req.prompt.len() + 1))
}

struct PjrtActive {
    req: Request,
    kv: KvState,
    tokens: Vec<usize>,
    last: usize,
    admitted: Instant,
    prefill_s: f64,
    decode_started: Instant,
    /// Final timings, captured the moment the session finishes — NOT at
    /// batch collection time, which would charge early finishers for the
    /// whole batch's tail.
    decode_s: f64,
    e2e_s: f64,
    done: bool,
}

struct NativeActive {
    req: Request,
    sess: NativeSession,
    tokens: Vec<usize>,
    last: usize,
    admitted: Instant,
    prefill_s: f64,
    decode_started: Instant,
    /// Final timings, captured the moment the session finishes (see
    /// `PjrtActive`).
    decode_s: f64,
    e2e_s: f64,
    done: bool,
}

/// The coordinator: queue + scheduler + metrics.
pub struct Coordinator {
    backend: Backend,
    pub policy: SchedulePolicy,
    queue: VecDeque<Request>,
    next_id: u64,
    pub metrics: EngineMetrics,
    rng: Rng,
}

impl Coordinator {
    pub fn new(backend: Backend, policy: SchedulePolicy) -> Self {
        Coordinator {
            backend,
            policy,
            queue: VecDeque::new(),
            next_id: 1,
            metrics: EngineMetrics::default(),
            rng: Rng::new(0x5e5510),
        }
    }

    /// The backend (e.g. to inspect the native model's KV pool).
    pub fn backend(&self) -> &Backend {
        &self.backend
    }

    /// Queue a request; returns its id.
    pub fn submit(&mut self, prompt: Vec<usize>, max_new_tokens: usize) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Request::new(id, prompt, max_new_tokens));
        id
    }

    /// Queue a fully-specified request.
    pub fn submit_request(&mut self, mut req: Request) -> u64 {
        req.id = self.next_id;
        self.next_id += 1;
        let id = req.id;
        self.queue.push_back(req);
        id
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Drain the queue to completion; returns responses in completion order.
    pub fn run_all(&mut self) -> Result<Vec<Response>> {
        let native = matches!(self.backend, Backend::Native(_));
        match self.policy {
            SchedulePolicy::Fifo => self.run_fifo(),
            SchedulePolicy::Interleaved if native => self.run_interleaved_native(),
            SchedulePolicy::Interleaved => self.run_interleaved_pjrt(),
        }
    }

    fn run_fifo(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        while let Some(req) = self.queue.pop_front() {
            let admitted = Instant::now();
            let cap = self.backend.max_len();
            let budget = token_budget(&req, cap);
            let (tokens, prefill_s, decode_s) = match &mut self.backend {
                Backend::Native(m) => {
                    let mut sess = m.new_session();
                    sess.lora_task = req.lora_task.clone();
                    let t0 = Instant::now();
                    let logits = m.prefill(&mut sess, &req.prompt);
                    let prefill_s = t0.elapsed().as_secs_f64();
                    let mut tok = sampler::sample(&logits, req.sampler, &mut self.rng);
                    let mut tokens = vec![tok];
                    let t1 = Instant::now();
                    for _ in 1..budget {
                        if tok == EOS {
                            break;
                        }
                        let logits = m.decode(&mut sess, tok);
                        tok = sampler::sample(&logits, req.sampler, &mut self.rng);
                        tokens.push(tok);
                    }
                    self.metrics.kv.spilled_records += sess.spilled_records();
                    self.metrics.kv.restored_records += sess.restored_records();
                    (tokens, prefill_s, t1.elapsed().as_secs_f64())
                }
                Backend::Pjrt(rt) => {
                    let t0 = Instant::now();
                    let (logits, mut kv) = rt.prefill(&req.prompt)?;
                    let prefill_s = t0.elapsed().as_secs_f64();
                    let mut tok = sampler::sample(&logits, req.sampler, &mut self.rng);
                    let mut tokens = vec![tok];
                    let t1 = Instant::now();
                    for _ in 1..budget {
                        if tok == EOS {
                            break;
                        }
                        let logits = rt.decode(tok, &mut kv)?;
                        tok = sampler::sample(&logits, req.sampler, &mut self.rng);
                        tokens.push(tok);
                    }
                    (tokens, prefill_s, t1.elapsed().as_secs_f64())
                }
            };
            let m = RequestMetrics {
                prompt_tokens: req.prompt.len(),
                new_tokens: tokens.len(),
                ttft_s: prefill_s,
                prefill_s,
                decode_s,
                e2e_s: admitted.elapsed().as_secs_f64(),
            };
            self.metrics.push(m);
            out.push(Response { id: req.id, tokens, metrics: m });
            // The request's session is gone; drop its spilled records too.
            if let Backend::Native(m) = &self.backend {
                m.reclaim_flash();
            }
        }
        // Weight-residency counters are cumulative on the model; snapshot
        // them into the engine metrics now that the queue is drained.
        if let Backend::Native(m) = &self.backend {
            self.metrics.weights = m.weight_metrics();
        }
        Ok(out)
    }

    /// Continuous batching on the native backend: one `NativeSession` per
    /// request over the shared paged KV pool, with budget-aware admission.
    fn run_interleaved_native(&mut self) -> Result<Vec<Response>> {
        let cap = self.backend.max_len();
        let Backend::Native(model) = &self.backend else {
            unreachable!("run_interleaved_native requires a native backend");
        };
        // Phase 1: admit + prefill every queued request (compute-bound; run
        // first so every session has a first token — lowest aggregate TTFT).
        let mut active: Vec<NativeActive> = Vec::new();
        while let Some(req) = self.queue.pop_front() {
            let admitted = Instant::now();
            // Admission control: will this prompt's KV fit the pool budget?
            // If not, preempt running sessions (oldest first) to flash.
            // Page-granular: the pool hands out whole pages, so short
            // prompts still pin a full page per layer. When the prompt
            // could never fit even in an empty pool, skip the pointless
            // fleet-wide preemption — the new session will degrade by
            // spilling its own KV as it appends.
            let need = model.prefill_kv_page_bytes(req.prompt.len());
            if model.kv_pool().would_exceed(need) && need <= model.kv_pool().budget_bytes() {
                for s in active.iter_mut() {
                    if !model.kv_pool().would_exceed(need) {
                        break;
                    }
                    if s.sess.resident_kv_bytes() > 0 {
                        s.sess.preempt_to_flash()?;
                        self.metrics.kv.preemptions += 1;
                    }
                }
                // If it still doesn't fit, admit anyway: appends degrade
                // gracefully by spilling this session's own KV to flash.
            }
            let mut sess = model.new_session();
            sess.lora_task = req.lora_task.clone();
            let t0 = Instant::now();
            let logits = model.prefill(&mut sess, &req.prompt);
            let prefill_s = t0.elapsed().as_secs_f64();
            let tok = sampler::sample(&logits, req.sampler, &mut self.rng);
            let budget = token_budget(&req, cap);
            let mut entry = NativeActive {
                last: tok,
                tokens: vec![tok],
                sess,
                admitted,
                prefill_s,
                decode_started: Instant::now(),
                decode_s: 0.0,
                e2e_s: 0.0,
                done: tok == EOS || budget <= 1,
                req,
            };
            if entry.done {
                entry.e2e_s = entry.admitted.elapsed().as_secs_f64();
                // Finished already: stop pinning pool pages / flash records.
                entry.sess.release_kv();
            }
            active.push(entry);
        }
        // Phase 2: round-robin decode (memory-bound; one token per active
        // session per sweep). Greedy streams are identical to Fifo's —
        // sessions are isolated, only the order of work changes.
        for s in active.iter_mut().filter(|s| !s.done) {
            s.decode_started = Instant::now();
        }
        while active.iter().any(|s| !s.done) {
            for s in active.iter_mut().filter(|s| !s.done) {
                let logits = model.decode(&mut s.sess, s.last);
                let tok = sampler::sample(&logits, s.req.sampler, &mut self.rng);
                s.tokens.push(tok);
                s.last = tok;
                if tok == EOS || s.tokens.len() >= token_budget(&s.req, cap) {
                    s.done = true;
                    s.decode_s = s.decode_started.elapsed().as_secs_f64();
                    s.e2e_s = s.admitted.elapsed().as_secs_f64();
                    // Release the finished session's KV immediately so its
                    // pages and flash records stop pressuring live sessions.
                    s.sess.release_kv();
                }
            }
        }
        let mut out = Vec::new();
        for s in active {
            self.metrics.kv.spilled_records += s.sess.spilled_records();
            self.metrics.kv.restored_records += s.sess.restored_records();
            let m = RequestMetrics {
                prompt_tokens: s.req.prompt.len(),
                new_tokens: s.tokens.len(),
                ttft_s: s.prefill_s,
                prefill_s: s.prefill_s,
                decode_s: s.decode_s,
                e2e_s: s.e2e_s,
            };
            self.metrics.push(m);
            out.push(Response { id: s.req.id, tokens: s.tokens, metrics: m });
        }
        // Every session is dropped; truncate the shared spill store.
        model.reclaim_flash();
        self.metrics.weights = model.weight_metrics();
        Ok(out)
    }

    fn run_interleaved_pjrt(&mut self) -> Result<Vec<Response>> {
        let Backend::Pjrt(rt) = &self.backend else {
            unreachable!("run_interleaved_pjrt requires a PJRT backend");
        };
        let cap = rt.manifest.model.max_len;
        // Phase 1: prefill every queued request.
        let mut active: Vec<PjrtActive> = Vec::new();
        while let Some(req) = self.queue.pop_front() {
            let admitted = Instant::now();
            let t0 = Instant::now();
            let (logits, kv) = rt.prefill(&req.prompt)?;
            let prefill_s = t0.elapsed().as_secs_f64();
            let tok = sampler::sample(&logits, req.sampler, &mut self.rng);
            let mut entry = PjrtActive {
                last: tok,
                tokens: vec![tok],
                kv,
                admitted,
                prefill_s,
                decode_started: Instant::now(),
                decode_s: 0.0,
                e2e_s: 0.0,
                done: tok == EOS || token_budget(&req, cap) <= 1,
                req,
            };
            if entry.done {
                entry.e2e_s = entry.admitted.elapsed().as_secs_f64();
            }
            active.push(entry);
        }
        // Phase 2: round-robin decode.
        let mut out = Vec::new();
        for s in active.iter_mut().filter(|s| !s.done) {
            s.decode_started = Instant::now();
        }
        while active.iter().any(|s| !s.done) {
            for s in active.iter_mut().filter(|s| !s.done) {
                let logits = rt.decode(s.last, &mut s.kv)?;
                let tok = sampler::sample(&logits, s.req.sampler, &mut self.rng);
                s.tokens.push(tok);
                s.last = tok;
                if tok == EOS
                    || s.tokens.len() >= token_budget(&s.req, cap)
                    || s.kv.pos + 1 >= cap
                {
                    s.done = true;
                    s.decode_s = s.decode_started.elapsed().as_secs_f64();
                    s.e2e_s = s.admitted.elapsed().as_secs_f64();
                }
            }
        }
        for s in active {
            let m = RequestMetrics {
                prompt_tokens: s.req.prompt.len(),
                new_tokens: s.tokens.len(),
                ttft_s: s.prefill_s,
                prefill_s: s.prefill_s,
                decode_s: s.decode_s,
                e2e_s: s.e2e_s,
            };
            self.metrics.push(m);
            out.push(Response { id: s.req.id, tokens: s.tokens, metrics: m });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::fixtures;
    use crate::model::native::EngineOptions;

    fn native() -> NativeModel {
        fixtures::native_model(7, EngineOptions::default()).unwrap().1
    }

    #[test]
    fn fifo_native_serves_queue() {
        let m = native();
        let mut c = Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Fifo);
        let a = c.submit(vec![1, 2, 3], 4);
        let b = c.submit(vec![9, 8], 3);
        assert_eq!(c.pending(), 2);
        let responses = c.run_all().unwrap();
        assert_eq!(c.pending(), 0);
        assert_eq!(responses.len(), 2);
        assert_eq!(responses[0].id, a);
        assert_eq!(responses[1].id, b);
        // Full budget unless the random-weight model greedily emitted EOS.
        for (r, want) in responses.iter().zip([4usize, 3]) {
            assert!(
                r.tokens.len() == want || r.tokens.last() == Some(&EOS),
                "request {}: {} tokens, want {want} (or early EOS)",
                r.id,
                r.tokens.len()
            );
        }
        assert_eq!(c.metrics.count(), 2);
        assert!(c.metrics.mean_decode_tok_s() > 0.0);
    }

    #[test]
    fn interleaved_native_matches_fifo_tokens() {
        // Greedy decoding must produce identical tokens under both
        // schedules — interleaving only changes the order of work. This is
        // the native-backend (session-owned paged KV) parity check.
        let m1 = native();
        let mut fifo = Coordinator::new(Backend::Native(Box::new(m1)), SchedulePolicy::Fifo);
        fifo.submit(vec![5, 6, 7], 4);
        fifo.submit(vec![100, 101], 4);
        fifo.submit(vec![42; 9], 5);
        let r_fifo = fifo.run_all().unwrap();

        let m2 = native();
        let mut inter =
            Coordinator::new(Backend::Native(Box::new(m2)), SchedulePolicy::Interleaved);
        inter.submit(vec![5, 6, 7], 4);
        inter.submit(vec![100, 101], 4);
        inter.submit(vec![42; 9], 5);
        let r_inter = inter.run_all().unwrap();

        assert_eq!(r_fifo.len(), r_inter.len());
        for (a, b) in r_fifo.iter().zip(&r_inter) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "schedule must not change greedy output");
        }
    }

    #[test]
    fn interleaved_native_frees_all_pool_pages() {
        let m = native();
        let mut c = Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Interleaved);
        for i in 0..4 {
            c.submit(vec![10 + i; 6], 4);
        }
        let rs = c.run_all().unwrap();
        assert_eq!(rs.len(), 4);
        let Backend::Native(m) = c.backend() else { unreachable!() };
        assert_eq!(m.kv_pool().resident_bytes(), 0, "all pages returned after run_all");
    }

    #[test]
    #[cfg(feature = "pjrt")]
    #[ignore = "needs real AOT artifacts (python/compile/aot.py) under rust/artifacts"]
    fn interleaved_pjrt_matches_fifo_tokens() {
        use std::path::PathBuf;
        let dir = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        assert!(dir.join("manifest.json").exists(), "run the AOT pipeline first");
        // Greedy decoding must produce identical tokens under both
        // schedules — interleaving only changes the order of work.
        let rt1 = PjrtRuntime::load(&dir).unwrap();
        let mut fifo = Coordinator::new(Backend::Pjrt(Box::new(rt1)), SchedulePolicy::Fifo);
        fifo.submit(vec![5, 6, 7], 4);
        fifo.submit(vec![100, 101], 4);
        let r_fifo = fifo.run_all().unwrap();

        let rt2 = PjrtRuntime::load(&dir).unwrap();
        let mut inter =
            Coordinator::new(Backend::Pjrt(Box::new(rt2)), SchedulePolicy::Interleaved);
        inter.submit(vec![5, 6, 7], 4);
        inter.submit(vec![100, 101], 4);
        let r_inter = inter.run_all().unwrap();

        for (a, b) in r_fifo.iter().zip(&r_inter) {
            assert_eq!(a.tokens, b.tokens, "schedule must not change greedy output");
        }
    }

    #[test]
    fn generation_respects_max_len() {
        let m = native();
        let cap = m.config.max_len;
        let mut c = Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Fifo);
        c.submit(vec![1; 10], cap * 2); // absurd budget gets clamped
        let r = c.run_all().unwrap();
        assert!(r[0].tokens.len() + 10 <= cap);
    }
}
