//! The engine loop: an **incremental, event-driven scheduler** over any
//! [`InferenceBackend`].
//!
//! [`Engine::step`] advances one scheduler tick — admit one queued request
//! (prefill) or run one **fused decode round**: a single
//! `InferenceBackend::decode_batch` call advances every active session by
//! one token (on the native backend, one layer walk and one weight fetch
//! per layer per tick shared by all sessions, instead of one walk per
//! session) — and emits typed [`EngineEvent`]s the moment tokens exist, so
//! callers observe generation in decode order instead of at drain time.
//! Admission pops the **highest-priority** ready request
//! (`Request::priority` class, then earliest arrival, then id; unset
//! priorities all share class 0, where admission is exactly the old FIFO).
//! Requests can be submitted **while the engine is stepping** (mid-flight
//! admission goes through the same KV-pool admission control) and
//! cancelled at any point ([`Engine::cancel`] frees the session's KV pages
//! and flash spill immediately). [`Engine::run_all`] survives as a thin
//! compatibility wrapper: `step()` until idle, then return completed
//! responses in submission order — bit-identical greedy outputs to the old
//! drain-only coordinator (batched rows are value-neutral by the backend
//! contract).
//!
//! Two policies:
//! * `Fifo` — admit a request only when none is active: each request
//!   completes before the next starts.
//! * `Interleaved` — admit (prefill) every queued request before decoding,
//!   then round-robin single-token decode across all active sessions.
//!   This keeps TTFT low for late arrivals while decode bandwidth is
//!   shared — the mobile analogue of continuous batching. Works on both
//!   backends through the one trait; sessions are isolated, so greedy
//!   token streams are identical under either policy.
//!
//! Sampling is **per-request**: each request derives a private RNG stream
//! from `Request::seed` (or deterministically from its id), so
//! temperature > 0 outputs are schedule-invariant — the old shared
//! coordinator RNG made sampled outputs depend on queue order and policy.
//!
//! Native admission control: before prefilling a new request the backend's
//! `make_room` hook asks the KV pool whether the prompt's estimated KV
//! fits the byte budget; if not, running sessions are **preempted to
//! flash** (oldest first). Under `EvictionPolicy::LargestHolder` the
//! engine additionally runs `enforce_kv_budget` before every decode round,
//! shedding the largest-holding session's oldest records instead of
//! letting whichever session appends pay. All spilling is bit-exact
//! value-neutral; counts land in `EngineMetrics::kv`.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

pub use crate::coordinator::backend::{AnySession, Backend, InferenceBackend};
use crate::coordinator::events::{EngineEvent, FinishReason, StreamInner, TokenStream};
use crate::coordinator::metrics::{EngineMetrics, RequestMetrics};
use crate::coordinator::request::{Request, RequestId, Response};
use crate::model::sampler;
use crate::model::tokenizer::EOS;
use crate::util::rng::Rng;

/// Scheduling policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulePolicy {
    Fifo,
    Interleaved,
}

/// Seed base for per-request RNG derivation (requests without an explicit
/// `Request::seed`). Mixed with the request id, never shared across
/// requests — the derived stream depends only on (base, id), not on
/// scheduling.
const SEED_BASE: u64 = 0x5e5510;

/// New-token budget for a request under the backend's context cap.
fn token_budget(req: &Request, cap: usize) -> usize {
    req.max_new_tokens.min(cap.saturating_sub(req.prompt.len() + 1))
}

/// The request's private sampling RNG (schedule-invariant by construction).
fn request_rng(req: &Request) -> Rng {
    let seed = req
        .seed
        .unwrap_or_else(|| SEED_BASE ^ req.id.wrapping_mul(0x9E3779B97F4A7C15));
    Rng::new(seed)
}

/// Why generation must stop after `tok` was produced, if it must.
/// Checked in the order EOS → stop token → stop sequence → token budget →
/// context capacity.
fn stop_reason(
    req: &Request,
    tokens: &[usize],
    tok: usize,
    budget: usize,
    pos: usize,
    cap: usize,
) -> Option<FinishReason> {
    if tok == EOS {
        Some(FinishReason::Eos)
    } else if req.stop_tokens.contains(&tok) {
        Some(FinishReason::StopToken)
    } else if req.matches_stop_sequence(tokens) {
        Some(FinishReason::StopSequence)
    } else if tokens.len() >= budget {
        Some(FinishReason::MaxTokens)
    } else if pos + 1 >= cap {
        Some(FinishReason::ContextCap)
    } else {
        None
    }
}

/// Deliver an event: to the request's `TokenStream` when one is attached
/// (`submit_streaming`), otherwise to the engine-wide queue. Routing is
/// exclusive so a long-running streaming caller that only drains its
/// handles never grows the global queue unboundedly; requests submitted
/// without a stream surface through `next_event`/`drain_events`. Free
/// function so callers can hold disjoint borrows of other engine fields
/// (e.g. the active list) while emitting.
fn deliver(
    events: &mut VecDeque<EngineEvent>,
    streams: &mut HashMap<RequestId, Arc<Mutex<StreamInner>>>,
    ev: EngineEvent,
) {
    let id = ev.id();
    let terminal = ev.is_terminal();
    if let Some(inner) = streams.get(&id) {
        {
            let mut g = inner.lock().unwrap();
            g.events.push_back(ev);
            if terminal {
                g.terminal_seen = true;
            }
        }
        if terminal {
            streams.remove(&id);
        }
        return;
    }
    events.push_back(ev);
}

/// One admitted request's in-flight state.
struct Active<S> {
    req: Request,
    sess: S,
    rng: Rng,
    tokens: Vec<usize>,
    last: usize,
    budget: usize,
    arrival: Instant,
    prefill_s: f64,
    ttft_s: f64,
    decode_started: Instant,
    decoded_any: bool,
}

/// The streaming engine: admission queue + step scheduler + event queue +
/// metrics, generic over the backend. `Engine<Backend>` (the type-erased
/// pair) is aliased as [`Coordinator`] for the batch-style API.
pub struct Engine<B: InferenceBackend> {
    backend: B,
    pub policy: SchedulePolicy,
    queue: VecDeque<Request>,
    active: Vec<Active<B::Session>>,
    next_id: u64,
    pub metrics: EngineMetrics,
    events: VecDeque<EngineEvent>,
    streams: HashMap<RequestId, Arc<Mutex<StreamInner>>>,
    finished: Vec<Response>,
}

/// The classic batch coordinator: the engine over the type-erased backend.
pub type Coordinator = Engine<Backend>;

impl<B: InferenceBackend> Engine<B> {
    pub fn new(backend: B, policy: SchedulePolicy) -> Self {
        Engine {
            backend,
            policy,
            queue: VecDeque::new(),
            active: Vec::new(),
            next_id: 1,
            metrics: EngineMetrics::default(),
            events: VecDeque::new(),
            streams: HashMap::new(),
            finished: Vec::new(),
        }
    }

    /// The backend (e.g. to inspect the native model's KV pool).
    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// Queue a request; returns its id. Valid mid-flight: the next step
    /// admits it through the same admission control.
    pub fn submit(&mut self, prompt: Vec<usize>, max_new_tokens: usize) -> RequestId {
        self.submit_request(Request::new(0, prompt, max_new_tokens))
    }

    /// Queue a fully-specified request; its id is assigned here.
    pub fn submit_request(&mut self, mut req: Request) -> RequestId {
        req.id = self.next_id;
        self.next_id += 1;
        req.arrival = Some(Instant::now());
        let id = req.id;
        self.queue.push_back(req);
        id
    }

    /// Queue a request and get a [`TokenStream`] handle that receives its
    /// events (drain between `step()` calls). Routing is exclusive: a
    /// streaming request's events go to the handle, not the engine-wide
    /// queue, so handle-only consumers never accumulate global events.
    pub fn submit_streaming(&mut self, req: Request) -> TokenStream {
        let id = self.submit_request(req);
        let inner = Arc::new(Mutex::new(StreamInner::default()));
        self.streams.insert(id, inner.clone());
        TokenStream::new(id, inner)
    }

    /// Queued (not yet admitted) requests.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Admitted, still-decoding requests.
    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    /// True while a `step()` would do work.
    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.active.is_empty()
    }

    /// Pop the oldest undelivered event.
    pub fn next_event(&mut self) -> Option<EngineEvent> {
        self.events.pop_front()
    }

    /// Drain all undelivered events.
    pub fn drain_events(&mut self) -> Vec<EngineEvent> {
        self.events.drain(..).collect()
    }

    /// Take the responses completed since the last call (completion order).
    pub fn take_finished(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.finished)
    }

    /// Advance one scheduler tick: admit the best queued request (prefill
    /// and first token) when the policy allows, otherwise run one fused
    /// decode round (one `decode_batch` call, one token per active
    /// session). Returns false when idle — no queued or active work.
    pub fn step(&mut self) -> Result<bool> {
        let may_admit = match self.policy {
            SchedulePolicy::Fifo => self.active.is_empty(),
            SchedulePolicy::Interleaved => true,
        };
        let did = if may_admit && !self.queue.is_empty() {
            self.admit_one()?;
            true
        } else if !self.active.is_empty() {
            self.decode_round()?;
            true
        } else {
            false
        };
        if self.active.is_empty() {
            // No live sessions: completed requests' flash spill is
            // reclaimable (native backend truncates the spill store).
            self.backend.reclaim();
        }
        Ok(did)
    }

    /// Cancel a request by id, queued or mid-decode. An active request's
    /// KV pool pages and flash spill records are freed immediately; a
    /// `Cancelled` terminal event is emitted. Returns false for unknown
    /// (or already-terminal) ids.
    pub fn cancel(&mut self, id: RequestId) -> bool {
        if let Some(qi) = self.queue.iter().position(|r| r.id == id) {
            self.queue.remove(qi);
            self.metrics.cancelled += 1;
            deliver(&mut self.events, &mut self.streams, EngineEvent::Cancelled { id });
            return true;
        }
        if let Some(ai) = self.active.iter().position(|a| a.req.id == id) {
            let mut act = self.active.remove(ai);
            let (spilled, restored) = self.backend.kv_counters(&act.sess);
            self.metrics.kv.spilled_records += spilled;
            self.metrics.kv.restored_records += restored;
            self.backend.release(&mut act.sess);
            drop(act);
            self.metrics.cancelled += 1;
            deliver(&mut self.events, &mut self.streams, EngineEvent::Cancelled { id });
            if self.active.is_empty() {
                self.backend.reclaim();
            }
            return true;
        }
        false
    }

    /// Compatibility wrapper over [`step`](Self::step): drive the engine
    /// until idle and return every response completed since the last
    /// drain, in submission (id) order — bit-identical greedy outputs to
    /// the old batch-only coordinator. Undelivered engine-wide events are
    /// discarded (attached `TokenStream`s keep theirs). Long-running
    /// step() callers should periodically `take_finished()` (and drain
    /// events) — completed responses are buffered until taken.
    pub fn run_all(&mut self) -> Result<Vec<Response>> {
        while self.step()? {}
        self.events.clear();
        let mut out = std::mem::take(&mut self.finished);
        out.sort_by_key(|r| r.id);
        Ok(out)
    }

    /// Pop the highest-priority ready request: priority class first
    /// (higher admitted sooner), then arrival time (earliest first — EDF
    /// with arrival as the deadline proxy), then id. Requests that never
    /// set a priority all share class 0, where the arrival tiebreak
    /// reduces to exactly the old FIFO pop (regression-tested).
    fn pop_ready(&mut self) -> Option<Request> {
        let best = self
            .queue
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                b.priority_class()
                    .cmp(&a.priority_class())
                    .then_with(|| a.arrival.cmp(&b.arrival))
                    .then_with(|| a.id.cmp(&b.id))
            })
            .map(|(i, _)| i)?;
        self.queue.remove(best)
    }

    /// Admit the best ready request: validate, make room (admission
    /// control may preempt running sessions), prefill, sample the first
    /// token, and emit `Started` + the first `Token` (with TTFT).
    fn admit_one(&mut self) -> Result<()> {
        let Some(req) = self.pop_ready() else {
            return Ok(());
        };
        let cap = self.backend.max_len();
        if req.prompt.is_empty() || req.prompt.len() + 1 > cap {
            let reason = if req.prompt.is_empty() {
                "empty prompt".to_string()
            } else {
                format!(
                    "prompt of {} tokens cannot fit context window {} with room to generate",
                    req.prompt.len(),
                    cap
                )
            };
            self.metrics.rejected += 1;
            deliver(
                &mut self.events,
                &mut self.streams,
                EngineEvent::Rejected { id: req.id, reason },
            );
            return Ok(());
        }
        {
            let mut running: Vec<&mut B::Session> =
                self.active.iter_mut().map(|a| &mut a.sess).collect();
            let preempted = self.backend.make_room(req.prompt.len(), &mut running)?;
            self.metrics.kv.preemptions += preempted;
        }
        let arrival = req.arrival.unwrap_or_else(Instant::now);
        let mut sess = self.backend.new_session(&req)?;
        let t0 = Instant::now();
        let logits = self.backend.prefill(&mut sess, &req.prompt)?;
        let prefill_s = t0.elapsed().as_secs_f64();
        let mut rng = request_rng(&req);
        let tok = sampler::sample(&logits, req.sampler, &mut rng);
        let ttft_s = arrival.elapsed().as_secs_f64();
        let id = req.id;
        deliver(&mut self.events, &mut self.streams, EngineEvent::Started { id });
        deliver(
            &mut self.events,
            &mut self.streams,
            EngineEvent::Token { id, tok, index: 0, ttft_s: Some(ttft_s) },
        );
        let budget = token_budget(&req, cap);
        let pos = self.backend.session_pos(&sess);
        let tokens = vec![tok];
        let reason = stop_reason(&req, &tokens, tok, budget.max(1), pos, cap);
        let act = Active {
            last: tok,
            tokens,
            sess,
            rng,
            budget: budget.max(1),
            arrival,
            prefill_s,
            ttft_s,
            decode_started: Instant::now(),
            decoded_any: false,
            req,
        };
        match reason {
            Some(r) => self.finalize(act, r),
            None => self.active.push(act),
        }
        Ok(())
    }

    /// One fused decode round: **one** `decode_batch` call advances every
    /// active session by one token — on the native backend a single layer
    /// walk (one weight fetch per layer per tick) instead of one walk per
    /// session. Rows are value-neutral by the backend contract, and the
    /// results are processed in the same admission order the old
    /// per-session loop used, so events, per-request RNG draws, stop
    /// handling, and greedy outputs are unchanged — only the weight
    /// traffic is. Finished sessions are finalized (and their KV
    /// released) on the spot.
    fn decode_round(&mut self) -> Result<()> {
        {
            let mut running: Vec<&mut B::Session> =
                self.active.iter_mut().map(|a| &mut a.sess).collect();
            let shed = self.backend.enforce_kv_budget(&mut running)?;
            self.metrics.kv.holder_sheds += shed;
        }
        let cap = self.backend.max_len();
        let now = Instant::now();
        let toks: Vec<usize> = self.active.iter().map(|a| a.last).collect();
        for a in &mut self.active {
            if !a.decoded_any {
                a.decode_started = now;
                a.decoded_any = true;
            }
        }
        let rows = {
            let mut sessions: Vec<&mut B::Session> =
                self.active.iter_mut().map(|a| &mut a.sess).collect();
            self.backend.decode_batch(&mut sessions, &toks)?
        };
        debug_assert_eq!(rows.len(), toks.len());
        // Row r belongs to the session admitted r-th this round; finalized
        // sessions shift later rows down by exactly the removals so far.
        let mut i = 0;
        for logits in rows {
            let (id, tok, index, reason) = {
                let a = &mut self.active[i];
                let tok = sampler::sample(&logits, a.req.sampler, &mut a.rng);
                a.tokens.push(tok);
                a.last = tok;
                let pos = self.backend.session_pos(&a.sess);
                let reason = stop_reason(&a.req, &a.tokens, tok, a.budget, pos, cap);
                (a.req.id, tok, a.tokens.len() - 1, reason)
            };
            deliver(
                &mut self.events,
                &mut self.streams,
                EngineEvent::Token { id, tok, index, ttft_s: None },
            );
            match reason {
                Some(r) => {
                    let act = self.active.remove(i);
                    self.finalize(act, r);
                    // The next session shifted into slot i; don't skip it.
                }
                None => i += 1,
            }
        }
        Ok(())
    }

    /// Capture metrics, release the session's KV, emit the terminal
    /// `Finished` event and record the response.
    fn finalize(&mut self, mut act: Active<B::Session>, reason: FinishReason) {
        let decode_s = if act.decoded_any {
            act.decode_started.elapsed().as_secs_f64()
        } else {
            0.0
        };
        let (spilled, restored) = self.backend.kv_counters(&act.sess);
        self.backend.release(&mut act.sess);
        let m = RequestMetrics {
            prompt_tokens: act.req.prompt.len(),
            new_tokens: act.tokens.len(),
            ttft_s: act.ttft_s,
            prefill_s: act.prefill_s,
            decode_s,
            e2e_s: act.arrival.elapsed().as_secs_f64(),
            spilled_records: spilled,
            restored_records: restored,
        };
        self.metrics.kv.spilled_records += spilled;
        self.metrics.kv.restored_records += restored;
        self.metrics.push(m);
        self.metrics.weights = self.backend.weight_metrics();
        let id = act.req.id;
        deliver(
            &mut self.events,
            &mut self.streams,
            EngineEvent::Finished { id, reason },
        );
        self.finished.push(Response {
            id,
            tokens: std::mem::take(&mut act.tokens),
            metrics: m,
            finish_reason: reason,
        });
        // `act` (and its session) drops here: pages return to the pool and
        // the live-session count falls, gating spill-store reclamation.
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::fixtures;
    use crate::model::native::{EngineOptions, NativeModel};

    fn native() -> NativeModel {
        fixtures::native_model(7, EngineOptions::default()).unwrap().1
    }

    #[test]
    fn fifo_native_serves_queue() {
        let m = native();
        let mut c = Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Fifo);
        let a = c.submit(vec![1, 2, 3], 4);
        let b = c.submit(vec![9, 8], 3);
        assert_eq!(c.pending(), 2);
        let responses = c.run_all().unwrap();
        assert_eq!(c.pending(), 0);
        assert_eq!(responses.len(), 2);
        assert_eq!(responses[0].id, a);
        assert_eq!(responses[1].id, b);
        // Full budget unless the random-weight model greedily emitted EOS.
        for (r, want) in responses.iter().zip([4usize, 3]) {
            assert!(
                r.tokens.len() == want || r.tokens.last() == Some(&EOS),
                "request {}: {} tokens, want {want} (or early EOS)",
                r.id,
                r.tokens.len()
            );
        }
        assert_eq!(c.metrics.count(), 2);
        assert!(c.metrics.mean_decode_tok_s() > 0.0);
    }

    #[test]
    fn interleaved_native_matches_fifo_tokens() {
        // Greedy decoding must produce identical tokens under both
        // schedules — interleaving only changes the order of work. This is
        // the native-backend (session-owned paged KV) parity check.
        let m1 = native();
        let mut fifo = Coordinator::new(Backend::Native(Box::new(m1)), SchedulePolicy::Fifo);
        fifo.submit(vec![5, 6, 7], 4);
        fifo.submit(vec![100, 101], 4);
        fifo.submit(vec![42; 9], 5);
        let r_fifo = fifo.run_all().unwrap();

        let m2 = native();
        let mut inter =
            Coordinator::new(Backend::Native(Box::new(m2)), SchedulePolicy::Interleaved);
        inter.submit(vec![5, 6, 7], 4);
        inter.submit(vec![100, 101], 4);
        inter.submit(vec![42; 9], 5);
        let r_inter = inter.run_all().unwrap();

        assert_eq!(r_fifo.len(), r_inter.len());
        for (a, b) in r_fifo.iter().zip(&r_inter) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "schedule must not change greedy output");
        }
    }

    #[test]
    fn interleaved_native_frees_all_pool_pages() {
        let m = native();
        let mut c = Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Interleaved);
        for i in 0..4 {
            c.submit(vec![10 + i; 6], 4);
        }
        let rs = c.run_all().unwrap();
        assert_eq!(rs.len(), 4);
        let m = c.backend().as_native().unwrap();
        assert_eq!(m.kv_pool().resident_bytes(), 0, "all pages returned after run_all");
    }

    #[test]
    fn step_emits_events_in_decode_order() {
        let m = native();
        let mut c = Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Fifo);
        let id = c.submit(vec![3, 4, 5], 3);
        // First step admits: Started + first Token (with TTFT) arrive
        // before any further stepping.
        assert!(c.step().unwrap());
        let mut evs = c.drain_events();
        assert_eq!(evs[0], EngineEvent::Started { id });
        assert!(
            matches!(evs[1], EngineEvent::Token { index: 0, ttft_s: Some(t), .. } if t >= 0.0),
            "{evs:?}"
        );
        // Stepping to idle yields the remaining tokens and one terminal.
        while c.step().unwrap() {}
        evs.extend(c.drain_events());
        let terminals = evs.iter().filter(|e| e.is_terminal()).count();
        assert_eq!(terminals, 1, "{evs:?}");
        assert!(matches!(evs.last().unwrap(), EngineEvent::Finished { .. }));
        // Token indices are consecutive from 0, in decode order.
        let idxs: Vec<usize> = evs
            .iter()
            .filter_map(|e| match e {
                EngineEvent::Token { index, .. } => Some(*index),
                _ => None,
            })
            .collect();
        assert_eq!(idxs, (0..idxs.len()).collect::<Vec<_>>());
        assert!(!c.has_work());
        assert_eq!(c.take_finished().len(), 1);
    }

    #[test]
    fn token_stream_handle_follows_one_request() {
        let m = native();
        let mut c = Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Interleaved);
        c.submit(vec![9; 5], 3); // unrelated traffic
        let stream = c.submit_streaming(Request::new(0, vec![5, 6, 7], 3));
        while c.step().unwrap() {}
        assert!(stream.finished());
        let mut toks = Vec::new();
        let mut saw_terminal = false;
        while let Some(ev) = stream.try_next() {
            assert_eq!(ev.id(), stream.id(), "stream only sees its own request");
            match ev {
                EngineEvent::Token { tok, .. } => toks.push(tok),
                EngineEvent::Finished { .. } => saw_terminal = true,
                _ => {}
            }
        }
        assert!(saw_terminal);
        assert!(stream.drained());
        // Exclusive routing: the streamed request's events never hit the
        // engine-wide queue (no unbounded growth for handle consumers),
        // while the non-streaming request's events do.
        let global = c.drain_events();
        assert!(global.iter().all(|e| e.id() != stream.id()), "{global:?}");
        assert!(!global.is_empty(), "non-streaming request surfaces globally");
        // The stream saw exactly the response's tokens, in order.
        let rs = c.run_all().unwrap();
        let r = rs.iter().find(|r| r.id == stream.id()).unwrap();
        assert_eq!(toks, r.tokens);
    }

    #[test]
    fn cancel_and_reject_are_terminal() {
        let m = native();
        let mut c = Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Interleaved);
        let queued = c.submit(vec![1, 2], 4);
        assert!(c.cancel(queued), "cancel while queued");
        assert!(!c.cancel(queued), "second cancel is a no-op");
        let empty = c.submit_request(Request::new(0, vec![], 4));
        let huge = c.submit(vec![7; 4096], 4);
        let ok = c.submit(vec![1, 2, 3], 2);
        let rs = c.run_all().unwrap();
        assert_eq!(rs.len(), 1, "only the valid request completes");
        assert_eq!(rs[0].id, ok);
        assert_eq!(c.metrics.cancelled, 1);
        assert_eq!(c.metrics.rejected, 2);
        let _ = (empty, huge);
    }

    /// Prompts whose first `n` greedy tokens avoid EOS on the fixture
    /// model (so lifecycle tests can rely on sessions staying alive).
    fn long_running_prompts(m: &NativeModel, want: usize, n: usize) -> Vec<Vec<usize>> {
        let mut out = Vec::new();
        for base in [4usize, 5, 21, 33, 57, 73, 90, 111] {
            let p = vec![base; 8];
            if !m.generate_once(&p, n).contains(&EOS) {
                out.push(p);
            }
            if out.len() == want {
                break;
            }
        }
        assert_eq!(out.len(), want, "fixture yields too few EOS-free prompts");
        out
    }

    #[test]
    fn mid_decode_cancel_frees_kv() {
        let m = native();
        let prompts = long_running_prompts(&m, 2, 4);
        let mut c = Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Interleaved);
        let a = c.submit(prompts[0].clone(), 20);
        let b = c.submit(prompts[1].clone(), 20);
        // Admit both, then a couple of decode rounds.
        for _ in 0..4 {
            assert!(c.step().unwrap());
        }
        assert_eq!(c.active_count(), 2);
        let pool = {
            let m = c.backend().as_native().unwrap();
            m.kv_pool().resident_bytes()
        };
        assert!(pool > 0);
        assert!(c.cancel(a));
        let after = c.backend().as_native().unwrap().kv_pool().resident_bytes();
        assert!(after < pool, "cancel must free the session's pages now");
        while c.step().unwrap() {}
        let rs = c.take_finished();
        assert_eq!(rs.len(), 1, "only b completes");
        assert_eq!(rs[0].id, b);
        let evs = c.drain_events();
        assert!(evs.contains(&EngineEvent::Cancelled { id: a }));
        assert_eq!(c.backend().as_native().unwrap().kv_pool().resident_bytes(), 0);
    }

    #[test]
    fn stop_token_and_stop_sequence_end_generation() {
        // Learn a greedy stream whose first 3 tokens are distinct and
        // EOS-free, then stop on its tokens.
        let probe = native();
        let mut picked = None;
        for base in [11usize, 30, 44, 61, 95, 120] {
            let p = vec![base, base + 1, base + 2];
            let out = probe.generate_once(&p, 6);
            if !out[..3].contains(&EOS) && out[0] != out[1] && out[1] != out[2] && out[0] != out[2]
            {
                picked = Some((p, out));
                break;
            }
        }
        let (prompt, free) = picked.expect("fixture yields a distinct-token stream");

        let m = native();
        let mut c = Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Fifo);
        c.submit_request(Request::new(0, prompt.clone(), 6).with_stop_tokens(vec![free[1]]));
        let r = c.run_all().unwrap().remove(0);
        assert_eq!(r.tokens, free[..2].to_vec(), "stops at the stop token");
        assert_eq!(r.finish_reason, FinishReason::StopToken);

        let m = native();
        let mut c = Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Fifo);
        c.submit_request(
            Request::new(0, prompt, 6).with_stop_sequences(vec![free[1..3].to_vec()]),
        );
        let r = c.run_all().unwrap().remove(0);
        assert_eq!(r.tokens, free[..3].to_vec(), "stops after the sequence");
        assert_eq!(r.finish_reason, FinishReason::StopSequence);
    }

    #[test]
    #[cfg(feature = "pjrt")]
    #[ignore = "needs real AOT artifacts (python/compile/aot.py) under rust/artifacts"]
    fn interleaved_pjrt_matches_fifo_tokens() {
        use crate::runtime::PjrtRuntime;
        use std::path::PathBuf;
        let dir = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        assert!(dir.join("manifest.json").exists(), "run the AOT pipeline first");
        // Greedy decoding must produce identical tokens under both
        // schedules — interleaving only changes the order of work.
        let rt1 = PjrtRuntime::load(&dir).unwrap();
        let mut fifo = Coordinator::new(Backend::Pjrt(Box::new(rt1)), SchedulePolicy::Fifo);
        fifo.submit(vec![5, 6, 7], 4);
        fifo.submit(vec![100, 101], 4);
        let r_fifo = fifo.run_all().unwrap();

        let rt2 = PjrtRuntime::load(&dir).unwrap();
        let mut inter =
            Coordinator::new(Backend::Pjrt(Box::new(rt2)), SchedulePolicy::Interleaved);
        inter.submit(vec![5, 6, 7], 4);
        inter.submit(vec![100, 101], 4);
        let r_inter = inter.run_all().unwrap();

        for (a, b) in r_fifo.iter().zip(&r_inter) {
            assert_eq!(a.tokens, b.tokens, "schedule must not change greedy output");
        }
    }

    /// Started-event order = admission order (one admission per tick).
    fn started_order(c: &mut Coordinator) -> Vec<RequestId> {
        let mut order = Vec::new();
        while c.step().unwrap() {
            for ev in c.drain_events() {
                if let EngineEvent::Started { id } = ev {
                    order.push(id);
                }
            }
        }
        for ev in c.drain_events() {
            if let EngineEvent::Started { id } = ev {
                order.push(id);
            }
        }
        order
    }

    #[test]
    fn priority_classes_admit_before_arrival_order() {
        let m = native();
        let mut c = Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Fifo);
        let low = c.submit(vec![1, 2], 2); // no priority ⇒ class 0
        let hi = c.submit_request(Request::new(0, vec![3, 4], 2).with_priority(5));
        let mid = c.submit_request(Request::new(0, vec![5, 6], 2).with_priority(1));
        assert_eq!(started_order(&mut c), vec![hi, mid, low]);
    }

    #[test]
    fn equal_priority_admission_is_unchanged_fifo() {
        // The regression half of the priority satellite: with no (or all
        // equal) priorities set, admission is exactly the old FIFO pop.
        for prio in [None, Some(3u8)] {
            let m = native();
            let mut c =
                Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Interleaved);
            let ids: Vec<RequestId> = (0..4)
                .map(|i| {
                    let mut req = Request::new(0, vec![10 + i, 20 + i], 2);
                    req.priority = prio;
                    c.submit_request(req)
                })
                .collect();
            assert_eq!(started_order(&mut c), ids, "priority {prio:?}");
        }
    }

    #[test]
    fn batched_round_emits_one_token_per_session_in_admission_order() {
        // Each decode tick is one fused decode_batch call, but the event
        // stream must look exactly like the old per-session loop: one
        // Token per active request per round, in admission order.
        let m = native();
        let prompts = long_running_prompts(&m, 2, 4);
        let mut c = Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Interleaved);
        let a = c.submit(prompts[0].clone(), 4);
        let b = c.submit(prompts[1].clone(), 4);
        // Two admission ticks.
        assert!(c.step().unwrap());
        assert!(c.step().unwrap());
        c.drain_events();
        assert_eq!(c.active_count(), 2);
        // One decode tick: exactly one token for a then one for b.
        assert!(c.step().unwrap());
        let toks: Vec<RequestId> = c
            .drain_events()
            .into_iter()
            .filter_map(|e| match e {
                EngineEvent::Token { id, .. } => Some(id),
                _ => None,
            })
            .collect();
        assert_eq!(toks, vec![a, b]);
    }

    #[test]
    fn generation_respects_max_len() {
        let m = native();
        let cap = m.config.max_len;
        let mut c = Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Fifo);
        c.submit(vec![1; 10], cap * 2); // absurd budget gets clamped
        let r = c.run_all().unwrap();
        assert!(r[0].tokens.len() + 10 <= cap);
    }
}
