//! The engine loop: an **incremental, event-driven scheduler** over any
//! [`InferenceBackend`].
//!
//! [`Engine::step`] advances one scheduler tick: admit ready requests,
//! then run one **fused round** — a single
//! `InferenceBackend::step_batch` call advances every served session by
//! one unit of work, pending **prefill chunks and decode rows sharing
//! the same call** (on the native backend, one layer walk and one weight
//! fetch per layer per tick total, instead of one walk per session) —
//! emitting typed [`EngineEvent`]s the moment tokens exist, so callers
//! observe generation in decode order instead of at drain time.
//!
//! Chunked + batched prefill: long prompts are split into
//! `tick_limits().prefill_chunk`-token chunks (one per tick), so a long
//! prompt never monopolizes a tick and a short prompt admitted alongside
//! gets its first token after one shared walk; several ready prompts are
//! admitted **in one tick** (KV headroom permitting) so their prefills
//! share a single weight pass. `tick_limits().max_rows` caps the rows of
//! one fused call, rotating a window through a large active set so
//! per-token event latency stays bounded. Both knobs are value-neutral:
//! chunking is bit-identical to monolithic prefill by the backend
//! contract, and rows are independent.
//!
//! Admission pops the **highest-priority** ready request
//! (`Request::priority` class, then earliest arrival, then id; unset
//! priorities all share class 0, where admission is exactly the old FIFO).
//! Requests can be submitted **while the engine is stepping** (mid-flight
//! admission goes through the same KV-pool admission control) and
//! cancelled at any point ([`Engine::cancel`] frees the session's KV pages
//! and flash spill immediately). A backend error terminates only the
//! affected requests — their sessions are **released** (no KV leak) and a
//! terminal [`EngineEvent::Failed`] is emitted; the engine keeps serving.
//! [`Engine::run_all`] survives as a thin compatibility wrapper: `step()`
//! until idle, then return completed responses in submission order —
//! bit-identical greedy outputs to the old drain-only coordinator
//! (batched rows are value-neutral by the backend contract).
//!
//! Two policies:
//! * `Fifo` — admit a request only when none is active: each request
//!   completes before the next starts.
//! * `Interleaved` — admit (prefill) every queued request before decoding,
//!   then round-robin single-token decode across all active sessions.
//!   This keeps TTFT low for late arrivals while decode bandwidth is
//!   shared — the mobile analogue of continuous batching. Works on both
//!   backends through the one trait; sessions are isolated, so greedy
//!   token streams are identical under either policy.
//!
//! Sampling is **per-request**: each request derives a private RNG stream
//! from `Request::seed` (or deterministically from its id), so
//! temperature > 0 outputs are schedule-invariant — the old shared
//! coordinator RNG made sampled outputs depend on queue order and policy.
//!
//! Native admission control: before prefilling a new request the backend's
//! `make_room` hook asks the KV pool whether the prompt's estimated KV
//! fits the byte budget; if not, running sessions are **preempted to
//! flash** (oldest first). Under `EvictionPolicy::LargestHolder` the
//! engine additionally runs `enforce_kv_budget` before every decode round,
//! shedding the largest-holding session's oldest records instead of
//! letting whichever session appends pay. All spilling is bit-exact
//! value-neutral; counts land in `EngineMetrics::kv`.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Result};

pub use crate::coordinator::backend::{
    AnySession, Backend, InferenceBackend, RowWork, TickLimits,
};
use crate::coordinator::events::{EngineEvent, FinishReason, StreamInner, TokenStream};
use crate::coordinator::metrics::{EngineMetrics, RequestMetrics, SpecMetrics};
use crate::coordinator::request::{Request, RequestId, Response};
use crate::model::native::{NativeModel, NativeSession};
use crate::model::sampler;
use crate::model::tokenizer::EOS;
use crate::util::rng::Rng;
use crate::util::sync::lock_tolerant;

/// Scheduling policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulePolicy {
    Fifo,
    Interleaved,
}

/// Seed base for per-request RNG derivation (requests without an explicit
/// `Request::seed`). Mixed with the request id, never shared across
/// requests — the derived stream depends only on (base, id), not on
/// scheduling.
const SEED_BASE: u64 = 0x5e5510;

/// New-token budget for a request under the backend's context cap.
fn token_budget(req: &Request, cap: usize) -> usize {
    req.max_new_tokens.min(cap.saturating_sub(req.prompt.len() + 1))
}

/// The request's private sampling RNG (schedule-invariant by construction).
fn request_rng(req: &Request) -> Rng {
    let seed = req
        .seed
        .unwrap_or_else(|| SEED_BASE ^ req.id.wrapping_mul(0x9E3779B97F4A7C15));
    Rng::new(seed)
}

/// Why generation must stop after `tok` was produced, if it must.
/// Checked in the order EOS → stop token → stop sequence → token budget →
/// context capacity.
fn stop_reason(
    req: &Request,
    tokens: &[usize],
    tok: usize,
    budget: usize,
    pos: usize,
    cap: usize,
) -> Option<FinishReason> {
    if tok == EOS {
        Some(FinishReason::Eos)
    } else if req.stop_tokens.contains(&tok) {
        Some(FinishReason::StopToken)
    } else if req.matches_stop_sequence(tokens) {
        Some(FinishReason::StopSequence)
    } else if tokens.len() >= budget {
        Some(FinishReason::MaxTokens)
    } else if pos + 1 >= cap {
        Some(FinishReason::ContextCap)
    } else {
        None
    }
}

/// Deliver an event: to the request's `TokenStream` when one is attached
/// (`submit_streaming`), otherwise to the engine-wide queue. Routing is
/// exclusive so a long-running streaming caller that only drains its
/// handles never grows the global queue unboundedly; requests submitted
/// without a stream surface through `next_event`/`drain_events`. Free
/// function so callers can hold disjoint borrows of other engine fields
/// (e.g. the active list) while emitting; `pub(crate)` because the
/// cluster front end reuses the exact same routing for events arriving
/// over replica channels.
pub(crate) fn deliver(
    events: &mut VecDeque<EngineEvent>,
    streams: &mut HashMap<RequestId, Arc<Mutex<StreamInner>>>,
    ev: EngineEvent,
) {
    let id = ev.id();
    let terminal = ev.is_terminal();
    if let Some(inner) = streams.get(&id) {
        {
            // Poison-tolerant: a consumer thread that panicked mid-drain
            // must not wedge event delivery for the whole engine.
            let mut g = lock_tolerant(inner);
            g.events.push_back(ev);
            if terminal {
                g.terminal_seen = true;
            }
        }
        if terminal {
            streams.remove(&id);
        }
        return;
    }
    events.push_back(ev);
}

/// An attached draft model for speculative decoding. The draft is always
/// the native runtime (a small `NativeModel` with its own KV pool);
/// whatever backend `B` is does the verification through
/// [`InferenceBackend::verify`].
pub struct SpecConfig {
    draft: NativeModel,
    /// Default proposals per verify walk (`Request::spec_depth` overrides
    /// per request).
    depth: usize,
}

/// Per-request draft state, created lazily on the request's first
/// speculative tick and torn down with the request.
struct SpecState {
    sess: NativeSession,
    /// Committed tokens currently in the draft's KV (the catch-up
    /// cursor). Kept strictly below the committed length between walks so
    /// the next catch-up always re-decodes the newest committed token and
    /// gets fresh proposal logits.
    fed: usize,
    /// Forked RNG sub-stream for proposal sampling and accept/reject
    /// draws. Disjoint from the request's main sampling stream by
    /// construction ([`Rng::fork`]), so attaching a draft never perturbs
    /// what the non-speculative path would have drawn.
    rng: Rng,
    /// The verify row: `toks[0]` is the newest committed token, `toks[1..]`
    /// the draft's proposals ([`RowWork::Verify`] borrows this).
    toks: Vec<usize>,
    /// Per-proposal draft distributions (temperature > 0 only), aligned
    /// with `toks[1..]`; the acceptance test needs `q(d)` and the
    /// rejection path needs the full `q` for the residual.
    qdists: Vec<Vec<f32>>,
    /// This request's own walk/accept counters (the per-request mirror of
    /// `EngineMetrics::spec`), feeding [`adaptive_spec_depth`] so one
    /// hard-to-draft request cannot throttle its neighbours' depth.
    stats: SpecMetrics,
}

/// Adaptive speculation depth: start at the configured depth, and once a
/// request has proposed enough tokens to estimate its live acceptance
/// rate, shrink the next walk's depth while the draft is missing (wasted
/// verify positions cost KV headroom and row slots) and grow it back as
/// the draft recovers. Pure function of the per-request stats, re-run
/// between ticks. Value-neutral: greedy verify commits the exact target
/// argmax prefix at ANY depth, so outputs stay bit-identical to plain
/// decode whatever this returns; it only moves the perf point.
fn adaptive_spec_depth(configured: usize, stats: &SpecMetrics) -> usize {
    if configured == 0 || stats.proposed < 4 {
        // Warm-up: trust the configured depth until the estimate means
        // anything (one or two walks' worth of proposals).
        return configured;
    }
    let rate = stats.acceptance_rate();
    if rate >= 0.75 {
        configured
    } else if rate >= 0.4 {
        configured.div_ceil(2)
    } else {
        1
    }
}

/// Run one draft-model row and flatten the outcome to logits.
fn draft_step(
    draft: &NativeModel,
    sess: &mut NativeSession,
    work: RowWork<'_>,
) -> Result<Vec<f32>> {
    let mut rows = draft.forward_tick(&mut [sess], &[work])?;
    match rows.pop() {
        Some(Ok(Some(l))) => Ok(l),
        Some(Ok(None)) => Err(anyhow!("draft walk returned no logits")),
        Some(Err(e)) => Err(e.into()),
        None => Err(anyhow!("draft walk returned no rows")),
    }
}

/// Catch the request's draft session up to the committed history, then
/// autoregressively propose `k` tokens, filling `SpecState::{toks,
/// qdists}` for the verify row. Between walks the draft's KV holds only
/// committed tokens and always fewer than the committed length (the
/// verify pass truncates speculative entries and keeps the cursor one
/// short), so catch-up always ends by decoding the newest committed
/// token — one token per row, never a re-prefill over quantized history
/// — leaving fresh proposal logits. Greedy proposals draw nothing from
/// any RNG; temperature > 0 proposals draw only from the forked
/// sub-stream.
fn propose_drafts(
    sc: &SpecConfig,
    spec: &mut Option<SpecState>,
    req: &Request,
    tokens: &[usize],
    last: usize,
    k: usize,
) -> Result<()> {
    let plen = req.prompt.len();
    let st = match spec {
        Some(st) => st,
        None => spec.insert(SpecState {
            sess: sc.draft.new_session(),
            fed: 0,
            rng: request_rng(req).fork(1),
            toks: Vec::new(),
            qdists: Vec::new(),
            stats: SpecMetrics::default(),
        }),
    };
    st.toks.clear();
    st.qdists.clear();
    st.toks.push(last);
    let mut caught: Option<Vec<f32>> = None;
    if st.fed == 0 {
        // A fresh draft session prefills the whole prompt in one row.
        caught = Some(draft_step(
            &sc.draft,
            &mut st.sess,
            RowWork::Prefill { ids: &req.prompt, last: true },
        )?);
        st.fed = plen;
    }
    while st.fed < plen + tokens.len() {
        let tok = if st.fed < plen {
            req.prompt.get(st.fed).copied().unwrap_or(0)
        } else {
            tokens.get(st.fed - plen).copied().unwrap_or(0)
        };
        caught = Some(draft_step(&sc.draft, &mut st.sess, RowWork::Decode { tok })?);
        st.fed += 1;
    }
    let Some(mut logits) = caught else {
        return Err(anyhow!("draft catch-up produced no logits"));
    };
    for i in 0..k {
        let d = if req.sampler.temperature <= 0.0 {
            sampler::argmax(&logits)
        } else {
            let q = sampler::dist(&logits, req.sampler);
            let d = sampler::sample_from_dist(&q, &mut st.rng);
            st.qdists.push(q);
            d
        };
        st.toks.push(d);
        if i + 1 < k {
            logits = draft_step(&sc.draft, &mut st.sess, RowWork::Decode { tok: d })?;
        }
    }
    Ok(())
}

/// One admitted request's in-flight state. `prefill_done <
/// req.prompt.len()` means the request is still in its prefill phase
/// (chunks pending); once the final chunk lands the first token is
/// sampled and decode rounds take over.
struct Active<S> {
    req: Request,
    sess: S,
    rng: Rng,
    tokens: Vec<usize>,
    last: usize,
    budget: usize,
    arrival: Instant,
    /// Prompt tokens consumed by prefill chunks so far.
    prefill_done: usize,
    /// Accumulated wall time of the ticks that advanced this request's
    /// prefill (a fused walk's time is attributed to each of its
    /// prefilling rows).
    prefill_s: f64,
    ttft_s: f64,
    decode_started: Instant,
    decoded_any: bool,
    /// Draft-model state when speculation has run for this request.
    spec: Option<SpecState>,
    /// Set when the draft failed for this request: it permanently
    /// degrades to plain decode (the draft's state is suspect) without
    /// failing the request itself.
    spec_dead: bool,
}

/// What a tick asked of one selected row (the owned mirror of the
/// [`RowWork`] handed to the backend, for post-walk processing).
#[derive(Clone, Copy)]
enum RowKind {
    Prefill { consumed: usize, last: bool },
    Decode,
    /// A speculative verify row carrying `k` draft proposals on top of
    /// the committed token (the owned tokens live in the request's
    /// [`SpecState`]).
    Verify { k: usize },
}

/// The streaming engine: admission queue + step scheduler + event queue +
/// metrics, generic over the backend. `Engine<Backend>` (the type-erased
/// pair) is aliased as [`Coordinator`] for the batch-style API.
pub struct Engine<B: InferenceBackend> {
    backend: B,
    pub policy: SchedulePolicy,
    queue: VecDeque<Request>,
    active: Vec<Active<B::Session>>,
    /// Speculative decoding: the attached draft model + default depth.
    /// `None` (the default) keeps every path bit-identical to the
    /// pre-speculation engine — no extra RNG draws, rows, or KV traffic.
    spec: Option<SpecConfig>,
    next_id: u64,
    /// Monotone row-window cursor for ticks capped by
    /// `tick_limits().max_rows`: uncapped ticks always serve the whole
    /// active set in admission order; capped ticks rotate the window
    /// start by the rows served, so every session advances within
    /// ⌈active/max_rows⌉ ticks.
    rotate: usize,
    pub metrics: EngineMetrics,
    events: VecDeque<EngineEvent>,
    streams: HashMap<RequestId, Arc<Mutex<StreamInner>>>,
    finished: Vec<Response>,
}

/// The classic batch coordinator: the engine over the type-erased backend.
pub type Coordinator = Engine<Backend>;

impl<B: InferenceBackend> Engine<B> {
    pub fn new(backend: B, policy: SchedulePolicy) -> Self {
        Engine {
            backend,
            policy,
            queue: VecDeque::new(),
            active: Vec::new(),
            spec: None,
            next_id: 1,
            rotate: 0,
            metrics: EngineMetrics::default(),
            events: VecDeque::new(),
            streams: HashMap::new(),
            finished: Vec::new(),
        }
    }

    /// The backend (e.g. to inspect the native model's KV pool).
    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// Attach a draft model for speculative decoding. Every decode tick
    /// then proposes up to `depth` tokens per request (overridable via
    /// [`Request::spec_depth`]) with the draft and verifies all of them
    /// as one multi-position row of the same fused walk, committing the
    /// accepted prefix plus one corrected/bonus token. Greedy outputs are
    /// bit-identical to non-speculative decode; temperature > 0 outputs
    /// are drawn from the exact same per-position distributions (the
    /// standard speculative-sampling accept/reject identity) via a forked
    /// RNG sub-stream. `depth == 0` — or a backend that does not support
    /// verification — detaches.
    pub fn attach_draft(&mut self, draft: NativeModel, depth: usize) {
        self.spec = if depth > 0 && self.backend.supports_speculation() {
            Some(SpecConfig { draft, depth })
        } else {
            None
        };
    }

    /// The attached speculative-decoding draft model, if any.
    pub fn draft_model(&self) -> Option<&NativeModel> {
        self.spec.as_ref().map(|s| &s.draft)
    }

    /// Queue a request; returns its id. Valid mid-flight: the next step
    /// admits it through the same admission control.
    pub fn submit(&mut self, prompt: Vec<usize>, max_new_tokens: usize) -> RequestId {
        self.submit_request(Request::new(0, prompt, max_new_tokens))
    }

    /// Queue a fully-specified request; its id is assigned here.
    pub fn submit_request(&mut self, mut req: Request) -> RequestId {
        req.id = self.next_id;
        self.next_id += 1;
        req.arrival = Some(Instant::now());
        let id = req.id;
        self.queue.push_back(req);
        id
    }

    /// Queue a request that already carries a globally-assigned id (the
    /// cluster router numbers requests across replicas). The id is kept —
    /// per-request RNG streams derive from it, so preserving the global
    /// numbering is what makes cluster outputs bit-identical to a single
    /// engine serving the same submissions — and `next_id` is bumped past
    /// it so locally-submitted requests can never collide. An unset id
    /// (0) is assigned locally, as `submit_request` would.
    pub fn submit_assigned(&mut self, mut req: Request) -> RequestId {
        if req.id == 0 {
            req.id = self.next_id;
        }
        self.next_id = self.next_id.max(req.id + 1);
        if req.arrival.is_none() {
            // Keep a router-side arrival stamp when one exists: TTFT then
            // includes channel transit + queue wait, like any other wait.
            req.arrival = Some(Instant::now());
        }
        let id = req.id;
        self.queue.push_back(req);
        id
    }

    /// Queue a request and get a [`TokenStream`] handle that receives its
    /// events (drain between `step()` calls). Routing is exclusive: a
    /// streaming request's events go to the handle, not the engine-wide
    /// queue, so handle-only consumers never accumulate global events.
    pub fn submit_streaming(&mut self, req: Request) -> TokenStream {
        let id = self.submit_request(req);
        let inner = Arc::new(Mutex::new(StreamInner::default()));
        self.streams.insert(id, inner.clone());
        TokenStream::new(id, inner)
    }

    /// Queued (not yet admitted) requests.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Admitted, still-decoding requests.
    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    /// True while a `step()` would do work.
    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.active.is_empty()
    }

    /// Pop the oldest undelivered event.
    pub fn next_event(&mut self) -> Option<EngineEvent> {
        self.events.pop_front()
    }

    /// Drain all undelivered events.
    pub fn drain_events(&mut self) -> Vec<EngineEvent> {
        self.events.drain(..).collect()
    }

    /// Take the responses completed since the last call (completion order).
    pub fn take_finished(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.finished)
    }

    /// Advance one scheduler tick: admit ready requests (several under
    /// `Interleaved`, KV headroom permitting, so their prefills share one
    /// walk; one at a time under `Fifo`), then run one fused round — a
    /// single `step_batch` call advancing each served session by its
    /// pending prefill chunk or one decode token. Returns false when idle
    /// — no queued or active work.
    pub fn step(&mut self) -> Result<bool> {
        let mut did = false;
        // Admission loop. Admissions must fit the KV headroom left after
        // charging every **outstanding** prefill reservation — the
        // estimates of prompts admitted this tick AND of still-chunking
        // prompts from earlier ticks, whose memory is not yet pool-
        // visible — so a burst of long chunked prompts cannot overcommit
        // the pool across ticks. When nothing is outstanding (the steady
        // state, and always when chunking is off) the tick's first
        // admission is unconditional, going through the backend's
        // `make_room` (which may preempt running sessions) exactly like
        // the old one-admission-per-tick path; outstanding reservations
        // shrink every tick as chunks land, so a gated queue always
        // unblocks — backpressure, not starvation.
        // A second bound: admit at most `max_rows_per_tick` prompts per
        // tick — more could not share this tick's walk anyway, so with a
        // finite row cap a co-arrival burst smooths into the cap per tick
        // (bounding the fused walk's transient activation memory and the
        // wait until the burst's first tokens) while concurrency beyond
        // the cap still builds up across ticks for rotation to serve.
        // The default (unlimited) keeps whole-queue fused admission;
        // `prefill_chunk_tokens` / `max_rows_per_tick` are the opt-in
        // knobs for bounding burst ticks.
        let admit_cap = self.backend.tick_limits().max_rows.max(1);
        let mut admitted = 0usize;
        // Decode-phase speculative requests are charged their verify-walk
        // KV transient too: a rejected draft's pages are truncated right
        // back, but mid-walk they are real pool pages an admission must
        // not plan over.
        let mut reserved = self
            .outstanding_prefill_reservation()
            .saturating_add(self.speculation_reservation());
        while admitted < admit_cap {
            let may_admit = match self.policy {
                SchedulePolicy::Fifo => self.active.is_empty(),
                SchedulePolicy::Interleaved => true,
            };
            if !may_admit {
                break;
            }
            // One priority scan per admission: the request whose cost is
            // charged is, by construction, the request admitted.
            let Some(best) = self.best_ready_index() else {
                break;
            };
            if admitted > 0 || reserved > 0 {
                // best_ready_index() returned an in-range index; stay
                // panic-free in the tick loop anyway.
                let Some(next) = self.queue.get(best) else {
                    break;
                };
                let next_cost = self.backend.prefill_reserve_bytes(&next.prompt);
                if reserved.saturating_add(next_cost) > self.backend.kv_headroom() {
                    break;
                }
            }
            if let Some(cost) = self.admit_at(best)? {
                reserved = reserved.saturating_add(cost);
                admitted += 1;
            }
            did = true;
        }
        if !self.active.is_empty() {
            self.run_tick()?;
            did = true;
        }
        if self.active.is_empty() {
            // No live sessions: completed requests' flash spill is
            // reclaimable (native backend truncates the spill store).
            self.backend.reclaim();
            if let Some(sc) = &self.spec {
                // Draft sessions died with their requests; reclaim the
                // draft model's spill store too.
                sc.draft.reclaim_flash();
            }
        }
        Ok(did)
    }

    /// Cancel a request by id, queued or mid-decode. An active request's
    /// KV pool pages and flash spill records are freed immediately; a
    /// `Cancelled` terminal event is emitted. Returns false for unknown
    /// (or already-terminal) ids.
    pub fn cancel(&mut self, id: RequestId) -> bool {
        if let Some(qi) = self.queue.iter().position(|r| r.id == id) {
            self.queue.remove(qi);
            self.metrics.cancelled += 1;
            deliver(&mut self.events, &mut self.streams, EngineEvent::Cancelled { id });
            return true;
        }
        if let Some(ai) = self.active.iter().position(|a| a.req.id == id) {
            self.teardown_active(ai);
            self.metrics.cancelled += 1;
            deliver(&mut self.events, &mut self.streams, EngineEvent::Cancelled { id });
            return true;
        }
        false
    }

    /// Tear down the active request at `ai`: capture its KV counters,
    /// **release the session** (pool pages + flash spill free
    /// immediately), and reclaim shared stores once nothing is active.
    /// Shared by cancellation and the backend-failure path; the caller
    /// emits the terminal event and bumps its counter.
    fn teardown_active(&mut self, ai: usize) {
        let mut act = self.active.remove(ai);
        if let Some(mut sp) = act.spec.take() {
            // The request's draft session goes with it: its pool pages
            // free now, not at drop time.
            sp.sess.release_kv();
        }
        let (spilled, restored) = self.backend.kv_counters(&act.sess);
        self.metrics.kv.spilled_records += spilled;
        self.metrics.kv.restored_records += restored;
        self.backend.release(&mut act.sess);
        drop(act);
        // Keep the weight-residency and prefix-cache gauges current even
        // when requests end by cancellation or failure (finalize refreshes
        // them too) — the flash traffic those requests caused is already
        // counted, and released shared pages change the cache's footprint.
        self.metrics.weights = self.backend.weight_metrics();
        self.metrics.prefix = self.backend.prefix_metrics();
        self.metrics.compute = self.backend.compute_metrics();
        if self.active.is_empty() {
            self.backend.reclaim();
        }
    }

    /// Compatibility wrapper over [`step`](Self::step): drive the engine
    /// until idle and return every response completed since the last
    /// drain, in submission (id) order — bit-identical greedy outputs to
    /// the old batch-only coordinator. Undelivered engine-wide events are
    /// discarded (attached `TokenStream`s keep theirs). Long-running
    /// step() callers should periodically `take_finished()` (and drain
    /// events) — completed responses are buffered until taken.
    ///
    /// Backend failures surface here as `Err` (the old coordinator
    /// propagated them too): requests the step loop terminated with
    /// `Failed` events would otherwise vanish silently from the batch
    /// result. Responses completed before the failure stay buffered for
    /// [`take_finished`](Self::take_finished); callers needing
    /// per-request failure handling should drive `step()` and observe
    /// events instead.
    pub fn run_all(&mut self) -> Result<Vec<Response>> {
        let failed_before = self.metrics.failed;
        while self.step()? {}
        self.events.clear();
        let failed = self.metrics.failed - failed_before;
        if failed > 0 {
            return Err(anyhow!(
                "{failed} request(s) terminated by backend failures during the drain \
                 (completed responses remain available via take_finished())"
            ));
        }
        let mut out = std::mem::take(&mut self.finished);
        out.sort_by_key(|r| r.id);
        Ok(out)
    }

    /// Queue index of the highest-priority ready request: priority class
    /// first (higher admitted sooner), then arrival time (earliest first
    /// — EDF with arrival as the deadline proxy), then id. Requests that
    /// never set a priority all share class 0, where the arrival tiebreak
    /// reduces to exactly the old FIFO pop (regression-tested). The
    /// admission loop charges this request's reservation and then admits
    /// this same index, so cost and admission cannot diverge.
    fn best_ready_index(&self) -> Option<usize> {
        self.queue
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                b.priority_class()
                    .cmp(&a.priority_class())
                    .then_with(|| a.arrival.cmp(&b.arrival))
                    .then_with(|| a.id.cmp(&b.id))
            })
            .map(|(i, _)| i)
    }

    /// Reservation bytes still outstanding for prompts admitted in
    /// earlier ticks whose chunked prefill has not finished: their full
    /// estimate minus only the **pool-visible** consumed portion
    /// (appended pages — `prefill_visible_bytes`; retained-until-
    /// completion memory like the native fp32 stash stays charged in
    /// full). Zero whenever chunking is off — prompts then prefill in
    /// their admission tick.
    fn outstanding_prefill_reservation(&self) -> usize {
        self.active
            .iter()
            .filter(|a| a.prefill_done < a.req.prompt.len())
            .map(|a| {
                self.backend
                    .prefill_reserve_bytes(&a.req.prompt)
                    .saturating_sub(
                        self.backend.prefill_visible_bytes(&a.req.prompt, a.prefill_done),
                    )
            })
            .fold(0usize, usize::saturating_add)
    }

    /// KV bytes a tick's verify rows may transiently append beyond plain
    /// decode: one reservation per live decode-phase speculative request
    /// at its effective depth. Zero without an attached draft.
    fn speculation_reservation(&self) -> usize {
        let Some(sc) = &self.spec else {
            return 0;
        };
        self.active
            .iter()
            .filter(|a| !a.spec_dead && a.prefill_done >= a.req.prompt.len())
            .map(|a| {
                self.backend
                    .verify_reserve_bytes(a.req.spec_depth.unwrap_or(sc.depth))
            })
            .fold(0usize, usize::saturating_add)
    }

    /// Admit the queued request at `qi`: validate, make room (admission
    /// control may preempt running sessions), open its session and queue
    /// it for prefill — the actual prefill (chunked, fused with other
    /// rows) happens in the tick's `step_batch` walk, and `Started` + the
    /// first `Token` are emitted when its final chunk lands. Returns the
    /// admitted prompt's KV reservation estimate; `None` when the request
    /// was rejected, failed to open, or completed on the spot (zero token
    /// budget) — every such path still emits its one terminal event.
    fn admit_at(&mut self, qi: usize) -> Result<Option<usize>> {
        let Some(req) = self.queue.remove(qi) else {
            return Ok(None);
        };
        let cap = self.backend.max_len();
        if req.prompt.is_empty() || req.prompt.len() + 1 > cap {
            let reason = if req.prompt.is_empty() {
                "empty prompt".to_string()
            } else {
                format!(
                    "prompt of {} tokens cannot fit context window {} with room to generate",
                    req.prompt.len(),
                    cap
                )
            };
            self.metrics.rejected += 1;
            deliver(
                &mut self.events,
                &mut self.streams,
                EngineEvent::Rejected { id: req.id, reason },
            );
            return Ok(None);
        }
        if req.max_new_tokens == 0 {
            // Honor a zero token budget: no prefill, no KV, no sampled
            // token — the request completes immediately with `MaxTokens`.
            // (The old path always sampled token 0, then clamped the
            // budget to 1.)
            let arrival = req.arrival.unwrap_or_else(Instant::now);
            let id = req.id;
            let m = RequestMetrics {
                prompt_tokens: req.prompt.len(),
                e2e_s: arrival.elapsed().as_secs_f64(),
                ..RequestMetrics::default()
            };
            self.metrics.push(m);
            deliver(&mut self.events, &mut self.streams, EngineEvent::Started { id });
            deliver(
                &mut self.events,
                &mut self.streams,
                EngineEvent::Finished { id, reason: FinishReason::MaxTokens },
            );
            self.finished.push(Response {
                id,
                tokens: Vec::new(),
                metrics: m,
                finish_reason: FinishReason::MaxTokens,
            });
            return Ok(None);
        }
        // From here the request is popped, so every failure must still
        // produce its one terminal event (the lifecycle invariant) —
        // backend errors become `Failed`, not a lost request.
        let room = {
            let mut running: Vec<&mut B::Session> =
                self.active.iter_mut().map(|a| &mut a.sess).collect();
            self.backend.make_room(&req.prompt, &mut running)
        };
        match room {
            Ok(preempted) => self.metrics.kv.preemptions += preempted,
            Err(e) => {
                self.metrics.failed += 1;
                deliver(
                    &mut self.events,
                    &mut self.streams,
                    EngineEvent::Failed {
                        id: req.id,
                        reason: format!("admission make_room failed: {e}"),
                    },
                );
                return Ok(None);
            }
        }
        let arrival = req.arrival.unwrap_or_else(Instant::now);
        let mut sess = match self.backend.new_session(&req) {
            Ok(s) => s,
            Err(e) => {
                self.metrics.failed += 1;
                deliver(
                    &mut self.events,
                    &mut self.streams,
                    EngineEvent::Failed {
                        id: req.id,
                        reason: format!("session open failed: {e}"),
                    },
                );
                return Ok(None);
            }
        };
        // Prefix-cache hit: the fresh session attaches the cached pages
        // (shared, no new KV) and prefill starts at the fork — the
        // cached-prefix tokens are never re-prefilled. `fork` is 0 on a
        // miss or on cache-less backends, which is the cold path exactly.
        let fork = self.backend.prefix_attach(&mut sess, &req.prompt);
        self.metrics.prefix = self.backend.prefix_metrics();
        let rng = request_rng(&req);
        // A context-cap-clamped budget of 0 keeps the pre-existing "one
        // free token from the prefill logits" semantics via max(1); an
        // explicit zero request was handled above.
        let budget = token_budget(&req, cap).max(1);
        let cost = self.backend.prefill_reserve_bytes(&req.prompt);
        self.active.push(Active {
            last: 0,
            tokens: Vec::new(),
            sess,
            rng,
            budget,
            arrival,
            prefill_done: fork,
            prefill_s: 0.0,
            ttft_s: 0.0,
            decode_started: Instant::now(),
            decoded_any: false,
            spec: None,
            spec_dead: false,
            req,
        });
        Ok(Some(cost))
    }

    /// One fused tick round: select up to `tick_limits().max_rows` active
    /// sessions (rotating window; uncapped ticks take everyone in
    /// admission order), hand each its pending work — the next prefill
    /// chunk of at most `tick_limits().prefill_chunk` prompt tokens, or
    /// one decode token — to a **single** `step_batch` call, then process
    /// the rows in window order: non-final chunks just advance, final
    /// chunks sample the first token (`Started` + `Token` with TTFT),
    /// decode rows sample the next token; stop handling, per-request RNG
    /// draws and event order are exactly the old per-phase loops'.
    /// Failed rows (or a failed walk) release their sessions and emit
    /// terminal `Failed` events — the KV-leak fix — without stopping the
    /// engine.
    fn run_tick(&mut self) -> Result<()> {
        self.budget_pass()?;
        let cap = self.backend.max_len();
        let limits = self.backend.tick_limits();
        let chunk_cap = limits.prefill_chunk.max(1);
        let n = self.active.len();
        let take = n.min(limits.max_rows.max(1));
        let start = if take == n { 0 } else { self.rotate % n };
        self.rotate = self.rotate.wrapping_add(take);
        let now = Instant::now();
        let mut sel: Vec<(RequestId, RowKind)> = Vec::with_capacity(take);
        let outcomes = {
            let mut slots: Vec<Option<&mut Active<B::Session>>> =
                self.active.iter_mut().map(Some).collect();
            let mut sessions: Vec<&mut B::Session> = Vec::with_capacity(take);
            let mut works: Vec<RowWork> = Vec::with_capacity(take);
            // Verify rows count their draft positions against the row
            // cap (a width-(k+1) verify row does k+1 rows' worth of walk
            // work), so `max_rows_per_tick` keeps bounding per-tick
            // compute with speculation on.
            let mut row_slots = limits.max_rows.max(1);
            for i in 0..take {
                // The rotating window visits each slot at most once per
                // tick (take <= n), so the slot is always still occupied;
                // a double-select is a logic bug — skip the row rather
                // than panic mid-tick.
                let Some(a) = slots.get_mut((start + i) % n).and_then(Option::take) else {
                    debug_assert!(false, "tick row selected twice");
                    continue;
                };
                let Active {
                    req,
                    sess,
                    prefill_done,
                    decoded_any,
                    decode_started,
                    last,
                    tokens,
                    budget,
                    spec,
                    spec_dead,
                    ..
                } = a;
                let plen = req.prompt.len();
                if *prefill_done < plen {
                    let end = (*prefill_done + chunk_cap).min(plen);
                    sel.push((
                        req.id,
                        RowKind::Prefill { consumed: end - *prefill_done, last: end == plen },
                    ));
                    works.push(RowWork::Prefill {
                        ids: &req.prompt[*prefill_done..end],
                        last: end == plen,
                    });
                    row_slots = row_slots.saturating_sub(1);
                } else {
                    if !*decoded_any {
                        *decode_started = now;
                        *decoded_any = true;
                    }
                    let mut k = 0usize;
                    if let Some(sc) = &self.spec {
                        if !*spec_dead {
                            // Clamp the proposal depth so the verify row
                            // (a) leaves one row slot for every other
                            // windowed session, (b) cannot commit past
                            // the token budget (at most k + 1 commits),
                            // (c) fits the context window, and (d) has
                            // KV headroom for the draft positions (they
                            // are truncated back on rejection, but are
                            // real pool pages mid-walk).
                            let avail = row_slots.saturating_sub(take - i - 1);
                            let pos = self.backend.session_pos(sess);
                            // Adaptive depth: the configured depth is the
                            // ceiling ([`Request::with_spec_depth`]), the
                            // request's live acceptance rate shrinks it.
                            let configured = req.spec_depth.unwrap_or(sc.depth);
                            k = spec
                                .as_ref()
                                .map_or(configured, |st| {
                                    adaptive_spec_depth(configured, &st.stats)
                                })
                                .min(avail.saturating_sub(1))
                                .min(budget.saturating_sub(tokens.len()).saturating_sub(1))
                                .min(cap.saturating_sub(pos + 1));
                            if k > 0
                                && self.backend.kv_headroom()
                                    < self.backend.verify_reserve_bytes(k)
                            {
                                k = 0;
                            }
                            if k > 0 {
                                if let Err(_e) =
                                    propose_drafts(sc, spec, req, tokens, *last, k)
                                {
                                    // A draft failure must never fail the
                                    // request: drop the suspect draft
                                    // state and degrade to plain decode
                                    // permanently.
                                    if let Some(mut st) = spec.take() {
                                        st.sess.release_kv();
                                    }
                                    *spec_dead = true;
                                    k = 0;
                                }
                            }
                        }
                    }
                    match spec.as_ref() {
                        Some(st) if k > 0 => {
                            sel.push((req.id, RowKind::Verify { k }));
                            works.push(RowWork::Verify { toks: &st.toks });
                            row_slots = row_slots.saturating_sub(1 + k);
                        }
                        _ => {
                            sel.push((req.id, RowKind::Decode));
                            works.push(RowWork::Decode { tok: *last });
                            row_slots = row_slots.saturating_sub(1);
                        }
                    }
                }
                sessions.push(sess);
            }
            self.backend.step_batch(&mut sessions, &works)
        };
        let walk_s = now.elapsed().as_secs_f64();
        let rows = match outcomes {
            Ok(rows) => rows,
            Err(e) => {
                // The fused walk failed wholesale: every selected
                // session's state is suspect. Release them (KV pages +
                // flash spill — the leak fix) and emit terminal `Failed`s;
                // unselected rows and the queue are untouched.
                let msg = format!("backend tick failed: {e}");
                for (id, _) in &sel {
                    self.fail_active(*id, &msg);
                }
                return self.budget_pass();
            }
        };
        if rows.len() != sel.len() {
            // Contract violation (outcomes ≠ rows): a silent zip would
            // drop the unmatched rows and stall those requests forever.
            // Treat it like a wholesale walk failure.
            let msg = format!(
                "backend returned {} outcomes for {} rows",
                rows.len(),
                sel.len()
            );
            for (id, _) in &sel {
                self.fail_active(*id, &msg);
            }
            return self.budget_pass();
        }
        for ((id, kind), outcome) in sel.into_iter().zip(rows) {
            match (outcome, kind) {
                (Err(e), _) => self.fail_active(id, &format!("backend row failed: {e}")),
                (Ok(logits), RowKind::Verify { k }) => self.advance_verify(id, k, logits, cap),
                (Ok(logits), kind) => self.advance_row(id, kind, logits, walk_s, cap),
            }
        }
        // Enforce the pool budget again **after** the walk: the tick's
        // appends (and any prefix-cache publish) may have pushed resident
        // bytes past the budget, and a registry-exact shed here means no
        // tick boundary ever observes an over-budget pool (satellite 3).
        self.budget_pass()
    }

    /// The cross-session KV budget pass (`EvictionPolicy::LargestHolder`
    /// enforcement; a no-op elsewhere), with sheds counted. Run before
    /// **and after** every fused tick so the pool is at or under budget at
    /// every tick boundary, not just eventually.
    fn budget_pass(&mut self) -> Result<()> {
        let mut running: Vec<&mut B::Session> =
            self.active.iter_mut().map(|a| &mut a.sess).collect();
        let shed = self.backend.enforce_kv_budget(&mut running)?;
        self.metrics.kv.holder_sheds += shed;
        Ok(())
    }

    /// Apply one successful tick row to its request: bookkeeping for a
    /// non-final prefill chunk; first-token sampling + `Started`/`Token`
    /// (TTFT) for a final chunk; next-token sampling + `Token` for a
    /// decode row. Stop conditions finalize (and release) on the spot.
    fn advance_row(
        &mut self,
        id: RequestId,
        kind: RowKind,
        logits: Option<Vec<f32>>,
        walk_s: f64,
        cap: usize,
    ) {
        let Some(ai) = self.active.iter().position(|a| a.req.id == id) else {
            return;
        };
        // One sample/stop/emit path for both row kinds; `first` (a final
        // prefill chunk) additionally emits `Started` and stamps TTFT.
        let first = match kind {
            RowKind::Prefill { consumed, last } => {
                {
                    let Some(a) = self.active.get_mut(ai) else { return };
                    a.prefill_done += consumed;
                    a.prefill_s += walk_s;
                }
                if !last {
                    return;
                }
                true
            }
            RowKind::Decode => false,
            // Verify rows are routed to `advance_verify` by the tick loop;
            // reaching here is a dispatch bug — drop the row, not the tick.
            RowKind::Verify { .. } => {
                debug_assert!(false, "verify row dispatched to advance_row");
                return;
            }
        };
        let Some(logits) = logits else {
            self.fail_active(
                id,
                if first {
                    "backend returned no logits for a final prefill chunk"
                } else {
                    "backend returned no logits for a decode row"
                },
            );
            return;
        };
        let (tok, index, ttft_s, reason) = {
            let Some(a) = self.active.get_mut(ai) else { return };
            let tok = sampler::sample(&logits, a.req.sampler, &mut a.rng);
            a.tokens.push(tok);
            a.last = tok;
            if first {
                a.ttft_s = a.arrival.elapsed().as_secs_f64();
            }
            let pos = self.backend.session_pos(&a.sess);
            let reason = stop_reason(&a.req, &a.tokens, tok, a.budget, pos, cap);
            (tok, a.tokens.len() - 1, a.ttft_s, reason)
        };
        if first {
            deliver(&mut self.events, &mut self.streams, EngineEvent::Started { id });
        }
        deliver(
            &mut self.events,
            &mut self.streams,
            EngineEvent::Token { id, tok, index, ttft_s: first.then_some(ttft_s) },
        );
        if let Some(r) = reason {
            let act = self.active.remove(ai);
            self.finalize(act, r);
        }
    }

    /// Apply one successful verify row: decide the committed tokens from
    /// the `k + 1` verified positions (greedy: commit while the target's
    /// argmax matches the proposal, then one correction/bonus token;
    /// temperature > 0: the speculative-sampling accept/reject identity —
    /// accept proposal `d` with probability `min(1, p(d)/q(d))`, on
    /// rejection draw from the normalized residual `max(p − q, 0)`, after
    /// full acceptance draw the bonus from the last position's `p`), roll
    /// the target's KV back to the committed prefix, roll the draft back
    /// to committed-only tokens, then emit the tokens in order with the
    /// same per-token stop checks sequential decode would have run.
    fn advance_verify(&mut self, id: RequestId, k: usize, logits: Option<Vec<f32>>, cap: usize) {
        let Some(ai) = self.active.iter().position(|a| a.req.id == id) else {
            return;
        };
        let Some(flat) = logits else {
            self.fail_active(id, "backend returned no logits for a verify row");
            return;
        };
        let width = k + 1;
        if flat.is_empty() || flat.len() % width != 0 {
            self.fail_active(id, "verify row returned malformed logits");
            return;
        }
        let vocab = flat.len() / width;
        let mut committed: Vec<usize> = Vec::with_capacity(width);
        let mut accepted = 0usize;
        let mut bad_state = false;
        let mut trunc_err: Option<String> = None;
        let pos_before;
        {
            let Some(a) = self.active.get_mut(ai) else { return };
            // The walk appended `width` positions; the position a
            // sequential decode would have checked for the j-th committed
            // token (1-based) is `pos_before + j`.
            pos_before = self.backend.session_pos(&a.sess).saturating_sub(width);
            match a.spec.as_mut() {
                Some(sp) if sp.toks.len() == width => {
                    let greedy = a.req.sampler.temperature <= 0.0;
                    for i in 0..k {
                        let row = &flat[i * vocab..(i + 1) * vocab];
                        let Some(&d) = sp.toks.get(i + 1) else { break };
                        if greedy {
                            let c = sampler::argmax(row);
                            committed.push(c);
                            if c != d {
                                break;
                            }
                            accepted += 1;
                        } else {
                            let p = sampler::dist(row, a.req.sampler);
                            let Some(q) = sp.qdists.get(i) else { break };
                            let qd = q.get(d).copied().unwrap_or(0.0);
                            let pd = p.get(d).copied().unwrap_or(0.0);
                            let ratio = if qd > 0.0 { (pd / qd).min(1.0) } else { 0.0 };
                            if sp.rng.f32() < ratio {
                                committed.push(d);
                                accepted += 1;
                            } else {
                                committed.push(sampler::residual_sample(&p, q, &mut sp.rng));
                                break;
                            }
                        }
                    }
                    if accepted == k {
                        // Every proposal held: the last verified position's
                        // logits are a free extra token.
                        let row = &flat[k * vocab..(k + 1) * vocab];
                        if greedy {
                            committed.push(sampler::argmax(row));
                        } else {
                            let p = sampler::dist(row, a.req.sampler);
                            committed.push(sampler::sample_from_dist(&p, &mut sp.rng));
                        }
                    }
                    let m = committed.len();
                    if m == 0 {
                        bad_state = true;
                    } else {
                        if m < width {
                            // Roll the target back to the committed
                            // prefix, minus the newest committed token
                            // (the standing never-yet-fed invariant).
                            let keep =
                                self.backend.session_pos(&a.sess).saturating_sub(width - m);
                            if let Err(e) = self.backend.truncate_kv(&mut a.sess, keep) {
                                trunc_err = Some(format!("verify KV rollback failed: {e}"));
                            }
                        }
                        if trunc_err.is_none() {
                            if let Some(sc) = &self.spec {
                                // Draft KV holds `fed` committed tokens
                                // plus proposals d1..d(k-1); keep the
                                // accepted (= committed) proposals, and
                                // stay below the new committed length so
                                // the next catch-up re-decodes the newest
                                // token for fresh logits.
                                let new_fed = (sp.fed + accepted.min(k.saturating_sub(1)))
                                    .min(sp.fed + m.saturating_sub(1));
                                sc.draft.truncate_kv(&mut sp.sess, new_fed);
                                sp.fed = new_fed;
                            }
                        }
                    }
                }
                _ => bad_state = true,
            }
        }
        if bad_state {
            self.fail_active(id, "verify row without matching draft state");
            return;
        }
        self.metrics.spec.walks += 1;
        self.metrics.spec.proposed += k as u64;
        self.metrics.spec.accepted += accepted as u64;
        self.metrics.spec.committed += committed.len() as u64;
        // Mirror into the request's own counters: `adaptive_spec_depth`
        // reads this live acceptance rate to size the next walk.
        if let Some(sp) = self.active.get_mut(ai).and_then(|a| a.spec.as_mut()) {
            sp.stats.walks += 1;
            sp.stats.proposed += k as u64;
            sp.stats.accepted += accepted as u64;
            sp.stats.committed += committed.len() as u64;
        }
        if let Some(e) = trunc_err {
            self.fail_active(id, &e);
            return;
        }
        // Emit the committed tokens in order, running the same stop checks
        // sequential decode would have; a stop discards the rest.
        let mut fired: Option<FinishReason> = None;
        for (j, &tok) in committed.iter().enumerate() {
            let (index, stop) = {
                let Some(a) = self.active.get_mut(ai) else { return };
                a.tokens.push(tok);
                a.last = tok;
                let stop = stop_reason(&a.req, &a.tokens, tok, a.budget, pos_before + j + 1, cap);
                (a.tokens.len() - 1, stop)
            };
            deliver(
                &mut self.events,
                &mut self.streams,
                EngineEvent::Token { id, tok, index, ttft_s: None },
            );
            if let Some(r) = stop {
                fired = Some(r);
                break;
            }
        }
        if let Some(r) = fired {
            let act = self.active.remove(ai);
            self.finalize(act, r);
        }
    }

    /// Terminal failure of an active request (backend error): tear the
    /// session down — pool pages and flash spill records free immediately
    /// instead of leaking until process exit — and emit a terminal
    /// `Failed` event. The engine keeps serving.
    fn fail_active(&mut self, id: RequestId, reason: &str) {
        let Some(ai) = self.active.iter().position(|a| a.req.id == id) else {
            return;
        };
        self.teardown_active(ai);
        self.metrics.failed += 1;
        deliver(
            &mut self.events,
            &mut self.streams,
            EngineEvent::Failed { id, reason: reason.to_string() },
        );
    }

    /// Capture metrics, release the session's KV, emit the terminal
    /// `Finished` event and record the response.
    fn finalize(&mut self, mut act: Active<B::Session>, reason: FinishReason) {
        if let Some(mut sp) = act.spec.take() {
            // Completed requests release their draft session's KV with
            // the rest of their memory.
            sp.sess.release_kv();
        }
        let decode_s = if act.decoded_any {
            act.decode_started.elapsed().as_secs_f64()
        } else {
            0.0
        };
        let (spilled, restored) = self.backend.kv_counters(&act.sess);
        self.backend.release(&mut act.sess);
        let m = RequestMetrics {
            prompt_tokens: act.req.prompt.len(),
            new_tokens: act.tokens.len(),
            ttft_s: act.ttft_s,
            prefill_s: act.prefill_s,
            decode_s,
            e2e_s: act.arrival.elapsed().as_secs_f64(),
            spilled_records: spilled,
            restored_records: restored,
        };
        self.metrics.kv.spilled_records += spilled;
        self.metrics.kv.restored_records += restored;
        self.metrics.push(m);
        self.metrics.weights = self.backend.weight_metrics();
        self.metrics.prefix = self.backend.prefix_metrics();
        self.metrics.compute = self.backend.compute_metrics();
        let id = act.req.id;
        deliver(
            &mut self.events,
            &mut self.streams,
            EngineEvent::Finished { id, reason },
        );
        self.finished.push(Response {
            id,
            tokens: std::mem::take(&mut act.tokens),
            metrics: m,
            finish_reason: reason,
        });
        // `act` (and its session) drops here: pages return to the pool and
        // the live-session count falls, gating spill-store reclamation.
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::fixtures;
    use crate::model::native::{EngineOptions, NativeModel};

    fn native() -> NativeModel {
        fixtures::native_model(7, EngineOptions::default()).unwrap().1
    }

    #[test]
    fn fifo_native_serves_queue() {
        let m = native();
        let mut c = Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Fifo);
        let a = c.submit(vec![1, 2, 3], 4);
        let b = c.submit(vec![9, 8], 3);
        assert_eq!(c.pending(), 2);
        let responses = c.run_all().unwrap();
        assert_eq!(c.pending(), 0);
        assert_eq!(responses.len(), 2);
        assert_eq!(responses[0].id, a);
        assert_eq!(responses[1].id, b);
        // Full budget unless the random-weight model greedily emitted EOS.
        for (r, want) in responses.iter().zip([4usize, 3]) {
            assert!(
                r.tokens.len() == want || r.tokens.last() == Some(&EOS),
                "request {}: {} tokens, want {want} (or early EOS)",
                r.id,
                r.tokens.len()
            );
        }
        assert_eq!(c.metrics.count(), 2);
        assert!(c.metrics.mean_decode_tok_s() > 0.0);
    }

    #[test]
    fn interleaved_native_matches_fifo_tokens() {
        // Greedy decoding must produce identical tokens under both
        // schedules — interleaving only changes the order of work. This is
        // the native-backend (session-owned paged KV) parity check.
        let m1 = native();
        let mut fifo = Coordinator::new(Backend::Native(Box::new(m1)), SchedulePolicy::Fifo);
        fifo.submit(vec![5, 6, 7], 4);
        fifo.submit(vec![100, 101], 4);
        fifo.submit(vec![42; 9], 5);
        let r_fifo = fifo.run_all().unwrap();

        let m2 = native();
        let mut inter =
            Coordinator::new(Backend::Native(Box::new(m2)), SchedulePolicy::Interleaved);
        inter.submit(vec![5, 6, 7], 4);
        inter.submit(vec![100, 101], 4);
        inter.submit(vec![42; 9], 5);
        let r_inter = inter.run_all().unwrap();

        assert_eq!(r_fifo.len(), r_inter.len());
        for (a, b) in r_fifo.iter().zip(&r_inter) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "schedule must not change greedy output");
        }
    }

    #[test]
    fn interleaved_native_frees_all_pool_pages() {
        let m = native();
        let mut c = Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Interleaved);
        for i in 0..4 {
            c.submit(vec![10 + i; 6], 4);
        }
        let rs = c.run_all().unwrap();
        assert_eq!(rs.len(), 4);
        let m = c.backend().as_native().unwrap();
        assert_eq!(m.kv_pool().resident_bytes(), 0, "all pages returned after run_all");
    }

    #[test]
    fn step_emits_events_in_decode_order() {
        let m = native();
        let mut c = Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Fifo);
        let id = c.submit(vec![3, 4, 5], 3);
        // First step admits: Started + first Token (with TTFT) arrive
        // before any further stepping.
        assert!(c.step().unwrap());
        let mut evs = c.drain_events();
        assert_eq!(evs[0], EngineEvent::Started { id });
        assert!(
            matches!(evs[1], EngineEvent::Token { index: 0, ttft_s: Some(t), .. } if t >= 0.0),
            "{evs:?}"
        );
        // Stepping to idle yields the remaining tokens and one terminal.
        while c.step().unwrap() {}
        evs.extend(c.drain_events());
        let terminals = evs.iter().filter(|e| e.is_terminal()).count();
        assert_eq!(terminals, 1, "{evs:?}");
        assert!(matches!(evs.last().unwrap(), EngineEvent::Finished { .. }));
        // Token indices are consecutive from 0, in decode order.
        let idxs: Vec<usize> = evs
            .iter()
            .filter_map(|e| match e {
                EngineEvent::Token { index, .. } => Some(*index),
                _ => None,
            })
            .collect();
        assert_eq!(idxs, (0..idxs.len()).collect::<Vec<_>>());
        assert!(!c.has_work());
        assert_eq!(c.take_finished().len(), 1);
    }

    #[test]
    fn token_stream_handle_follows_one_request() {
        let m = native();
        let mut c = Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Interleaved);
        c.submit(vec![9; 5], 3); // unrelated traffic
        let stream = c.submit_streaming(Request::new(0, vec![5, 6, 7], 3));
        while c.step().unwrap() {}
        assert!(stream.finished());
        let mut toks = Vec::new();
        let mut saw_terminal = false;
        while let Some(ev) = stream.try_next() {
            assert_eq!(ev.id(), stream.id(), "stream only sees its own request");
            match ev {
                EngineEvent::Token { tok, .. } => toks.push(tok),
                EngineEvent::Finished { .. } => saw_terminal = true,
                _ => {}
            }
        }
        assert!(saw_terminal);
        assert!(stream.drained());
        // Exclusive routing: the streamed request's events never hit the
        // engine-wide queue (no unbounded growth for handle consumers),
        // while the non-streaming request's events do.
        let global = c.drain_events();
        assert!(global.iter().all(|e| e.id() != stream.id()), "{global:?}");
        assert!(!global.is_empty(), "non-streaming request surfaces globally");
        // The stream saw exactly the response's tokens, in order.
        let rs = c.run_all().unwrap();
        let r = rs.iter().find(|r| r.id == stream.id()).unwrap();
        assert_eq!(toks, r.tokens);
    }

    #[test]
    fn cancel_and_reject_are_terminal() {
        let m = native();
        let mut c = Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Interleaved);
        let queued = c.submit(vec![1, 2], 4);
        assert!(c.cancel(queued), "cancel while queued");
        assert!(!c.cancel(queued), "second cancel is a no-op");
        let empty = c.submit_request(Request::new(0, vec![], 4));
        let huge = c.submit(vec![7; 4096], 4);
        let ok = c.submit(vec![1, 2, 3], 2);
        let rs = c.run_all().unwrap();
        assert_eq!(rs.len(), 1, "only the valid request completes");
        assert_eq!(rs[0].id, ok);
        assert_eq!(c.metrics.cancelled, 1);
        assert_eq!(c.metrics.rejected, 2);
        let _ = (empty, huge);
    }

    /// Prompts whose first `n` greedy tokens avoid EOS on the fixture
    /// model (so lifecycle tests can rely on sessions staying alive).
    fn long_running_prompts(m: &NativeModel, want: usize, n: usize) -> Vec<Vec<usize>> {
        let mut out = Vec::new();
        for base in [4usize, 5, 21, 33, 57, 73, 90, 111] {
            let p = vec![base; 8];
            if !m.generate_once(&p, n).contains(&EOS) {
                out.push(p);
            }
            if out.len() == want {
                break;
            }
        }
        assert_eq!(out.len(), want, "fixture yields too few EOS-free prompts");
        out
    }

    #[test]
    fn mid_decode_cancel_frees_kv() {
        let m = native();
        let prompts = long_running_prompts(&m, 2, 4);
        let mut c = Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Interleaved);
        let a = c.submit(prompts[0].clone(), 20);
        let b = c.submit(prompts[1].clone(), 20);
        // Admit both, then a couple of decode rounds.
        for _ in 0..4 {
            assert!(c.step().unwrap());
        }
        assert_eq!(c.active_count(), 2);
        let pool = {
            let m = c.backend().as_native().unwrap();
            m.kv_pool().resident_bytes()
        };
        assert!(pool > 0);
        assert!(c.cancel(a));
        let after = c.backend().as_native().unwrap().kv_pool().resident_bytes();
        assert!(after < pool, "cancel must free the session's pages now");
        while c.step().unwrap() {}
        let rs = c.take_finished();
        assert_eq!(rs.len(), 1, "only b completes");
        assert_eq!(rs[0].id, b);
        let evs = c.drain_events();
        assert!(evs.contains(&EngineEvent::Cancelled { id: a }));
        assert_eq!(c.backend().as_native().unwrap().kv_pool().resident_bytes(), 0);
    }

    #[test]
    fn stop_token_and_stop_sequence_end_generation() {
        // Learn a greedy stream whose first 3 tokens are distinct and
        // EOS-free, then stop on its tokens.
        let probe = native();
        let mut picked = None;
        for base in [11usize, 30, 44, 61, 95, 120] {
            let p = vec![base, base + 1, base + 2];
            let out = probe.generate_once(&p, 6);
            if !out[..3].contains(&EOS) && out[0] != out[1] && out[1] != out[2] && out[0] != out[2]
            {
                picked = Some((p, out));
                break;
            }
        }
        let (prompt, free) = picked.expect("fixture yields a distinct-token stream");

        let m = native();
        let mut c = Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Fifo);
        c.submit_request(Request::new(0, prompt.clone(), 6).with_stop_tokens(vec![free[1]]));
        let r = c.run_all().unwrap().remove(0);
        assert_eq!(r.tokens, free[..2].to_vec(), "stops at the stop token");
        assert_eq!(r.finish_reason, FinishReason::StopToken);

        let m = native();
        let mut c = Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Fifo);
        c.submit_request(
            Request::new(0, prompt, 6).with_stop_sequences(vec![free[1..3].to_vec()]),
        );
        let r = c.run_all().unwrap().remove(0);
        assert_eq!(r.tokens, free[..3].to_vec(), "stops after the sequence");
        assert_eq!(r.finish_reason, FinishReason::StopSequence);
    }

    #[test]
    #[cfg(feature = "pjrt")]
    #[ignore = "needs real AOT artifacts (python/compile/aot.py) under rust/artifacts"]
    fn interleaved_pjrt_matches_fifo_tokens() {
        use crate::runtime::PjrtRuntime;
        use std::path::PathBuf;
        let dir = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        assert!(dir.join("manifest.json").exists(), "run the AOT pipeline first");
        // Greedy decoding must produce identical tokens under both
        // schedules — interleaving only changes the order of work.
        let rt1 = PjrtRuntime::load(&dir).unwrap();
        let mut fifo = Coordinator::new(Backend::Pjrt(Box::new(rt1)), SchedulePolicy::Fifo);
        fifo.submit(vec![5, 6, 7], 4);
        fifo.submit(vec![100, 101], 4);
        let r_fifo = fifo.run_all().unwrap();

        let rt2 = PjrtRuntime::load(&dir).unwrap();
        let mut inter =
            Coordinator::new(Backend::Pjrt(Box::new(rt2)), SchedulePolicy::Interleaved);
        inter.submit(vec![5, 6, 7], 4);
        inter.submit(vec![100, 101], 4);
        let r_inter = inter.run_all().unwrap();

        for (a, b) in r_fifo.iter().zip(&r_inter) {
            assert_eq!(a.tokens, b.tokens, "schedule must not change greedy output");
        }
    }

    /// Started-event order = admission order (one admission per tick).
    fn started_order(c: &mut Coordinator) -> Vec<RequestId> {
        let mut order = Vec::new();
        while c.step().unwrap() {
            for ev in c.drain_events() {
                if let EngineEvent::Started { id } = ev {
                    order.push(id);
                }
            }
        }
        for ev in c.drain_events() {
            if let EngineEvent::Started { id } = ev {
                order.push(id);
            }
        }
        order
    }

    #[test]
    fn priority_classes_admit_before_arrival_order() {
        let m = native();
        let mut c = Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Fifo);
        let low = c.submit(vec![1, 2], 2); // no priority ⇒ class 0
        let hi = c.submit_request(Request::new(0, vec![3, 4], 2).with_priority(5));
        let mid = c.submit_request(Request::new(0, vec![5, 6], 2).with_priority(1));
        assert_eq!(started_order(&mut c), vec![hi, mid, low]);
    }

    #[test]
    fn equal_priority_admission_is_unchanged_fifo() {
        // The regression half of the priority satellite: with no (or all
        // equal) priorities set, admission is exactly the old FIFO pop.
        for prio in [None, Some(3u8)] {
            let m = native();
            let mut c =
                Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Interleaved);
            let ids: Vec<RequestId> = (0..4)
                .map(|i| {
                    let mut req = Request::new(0, vec![10 + i, 20 + i], 2);
                    req.priority = prio;
                    c.submit_request(req)
                })
                .collect();
            assert_eq!(started_order(&mut c), ids, "priority {prio:?}");
        }
    }

    #[test]
    fn batched_round_emits_one_token_per_session_in_admission_order() {
        // Each decode tick is one fused decode_batch call, but the event
        // stream must look exactly like the old per-session loop: one
        // Token per active request per round, in admission order.
        let m = native();
        let prompts = long_running_prompts(&m, 2, 4);
        let mut c = Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Interleaved);
        let a = c.submit(prompts[0].clone(), 4);
        let b = c.submit(prompts[1].clone(), 4);
        // Two admission ticks.
        assert!(c.step().unwrap());
        assert!(c.step().unwrap());
        c.drain_events();
        assert_eq!(c.active_count(), 2);
        // One decode tick: exactly one token for a then one for b.
        assert!(c.step().unwrap());
        let toks: Vec<RequestId> = c
            .drain_events()
            .into_iter()
            .filter_map(|e| match e {
                EngineEvent::Token { id, .. } => Some(id),
                _ => None,
            })
            .collect();
        assert_eq!(toks, vec![a, b]);
    }

    #[test]
    fn zero_token_budget_finishes_without_tokens() {
        // The max_new_tokens == 0 satellite: honor the zero budget — no
        // prefill, no sampled token, terminal `Finished(MaxTokens)`.
        let m = native();
        let mut c = Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Interleaved);
        let zero = c.submit(vec![1, 2, 3], 0);
        let one = c.submit(vec![1, 2, 3], 1);
        let mut events = Vec::new();
        while c.step().unwrap() {
            events.extend(c.drain_events());
        }
        events.extend(c.drain_events());
        let rs = c.take_finished();
        let rz = rs.iter().find(|r| r.id == zero).unwrap();
        assert!(rz.tokens.is_empty(), "zero budget must not generate");
        assert_eq!(rz.finish_reason, FinishReason::MaxTokens);
        let ro = rs.iter().find(|r| r.id == one).unwrap();
        assert_eq!(ro.tokens.len(), 1, "budget 1 still gets its free prefill token");
        // No Token event for the zero-budget id; exactly one terminal.
        assert!(events
            .iter()
            .all(|e| !matches!(e, EngineEvent::Token { id, .. } if *id == zero)));
        let terminals = events.iter().filter(|e| e.is_terminal() && e.id() == zero).count();
        assert_eq!(terminals, 1, "{events:?}");
        // And no KV was pinned for it.
        let m = c.backend().as_native().unwrap();
        assert_eq!(m.kv_pool().resident_bytes(), 0);
    }

    #[test]
    fn row_cap_rotates_and_is_value_neutral() {
        // max_rows_per_tick bounds each tick to one row; every session
        // still completes with exactly the tokens the uncapped engine
        // produces, and each capped tick emits at most one Token event.
        let capped_model = fixtures::native_model(
            7,
            EngineOptions { max_rows_per_tick: 1, ..EngineOptions::default() },
        )
        .unwrap()
        .1;
        let prompts: Vec<Vec<usize>> = vec![vec![5, 6, 7], vec![100, 101], vec![42; 5]];
        let mut capped =
            Coordinator::new(Backend::Native(Box::new(capped_model)), SchedulePolicy::Interleaved);
        for p in &prompts {
            capped.submit(p.clone(), 4);
        }
        let mut max_tokens_per_tick = 0usize;
        while capped.step().unwrap() {
            let toks = capped
                .drain_events()
                .iter()
                .filter(|e| matches!(e, EngineEvent::Token { .. }))
                .count();
            max_tokens_per_tick = max_tokens_per_tick.max(toks);
        }
        let mut got = capped.take_finished();
        got.sort_by_key(|r| r.id);
        assert_eq!(got.len(), 3, "rotation must reach every session");
        assert!(
            max_tokens_per_tick <= 1,
            "a 1-row tick emitted {max_tokens_per_tick} tokens"
        );

        let mut plain = Coordinator::new(
            Backend::Native(Box::new(native())),
            SchedulePolicy::Interleaved,
        );
        for p in &prompts {
            plain.submit(p.clone(), 4);
        }
        let want = plain.run_all().unwrap();
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "row cap changed outputs");
        }
    }

    #[test]
    fn generation_respects_max_len() {
        let m = native();
        let cap = m.config.max_len;
        let mut c = Coordinator::new(Backend::Native(Box::new(m)), SchedulePolicy::Fifo);
        c.submit(vec![1; 10], cap * 2); // absurd budget gets clamped
        let r = c.run_all().unwrap();
        assert!(r[0].tokens.len() + 10 <= cap);
    }
}
