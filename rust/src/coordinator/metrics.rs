//! Serving metrics: the quantities Figure 5 reports (prefill speed in
//! tok/s, decode speed in tok/s) plus latency percentiles for the e2e
//! example, KV-pressure counters, and weight-residency counters.

use crate::cpu::backend::ComputeBackendMetrics;
use crate::kv::PrefixCacheMetrics;
use crate::memory::weight_store::WeightResidencyMetrics;
use crate::util::stats;

/// Per-request timings and pressure counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct RequestMetrics {
    pub prompt_tokens: usize,
    pub new_tokens: usize,
    /// Queue arrival → first token (TTFT), seconds. Includes queue wait,
    /// so under load it exceeds `prefill_s`.
    pub ttft_s: f64,
    /// Prefill wall time.
    pub prefill_s: f64,
    /// Total decode wall time.
    pub decode_s: f64,
    /// Arrival → completion.
    pub e2e_s: f64,
    /// KV records this request's session spilled to flash.
    pub spilled_records: u64,
    /// KV records this request's session restored from flash.
    pub restored_records: u64,
}

impl RequestMetrics {
    pub fn prefill_tok_s(&self) -> f64 {
        if self.prefill_s > 0.0 {
            self.prompt_tokens as f64 / self.prefill_s
        } else {
            0.0
        }
    }

    pub fn decode_tok_s(&self) -> f64 {
        if self.decode_s > 0.0 {
            self.new_tokens as f64 / self.decode_s
        } else {
            0.0
        }
    }
}

/// KV-memory pressure counters (the paged-pool + DRAM-Flash spill path):
/// how often the engine had to degrade to flash to stay inside the KV
/// byte budget.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KvPressureMetrics {
    /// Token records written to flash (per-layer token budget or pool
    /// byte-budget eviction, plus preemptions).
    pub spilled_records: u64,
    /// Token records read back from flash (staging or streaming attention).
    pub restored_records: u64,
    /// Whole sessions preempted to flash by admission control.
    pub preemptions: u64,
    /// Records shed from the largest-holding session by the
    /// `EvictionPolicy::LargestHolder` cross-session policy (subset of
    /// `spilled_records`).
    pub holder_sheds: u64,
}

/// Speculative-decoding counters (draft-propose / target-verify walks).
/// All-zero when no draft model is attached or speculation never ran.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpecMetrics {
    /// Verify walks issued (each carries 1 committed token + k drafts).
    pub walks: u64,
    /// Draft tokens proposed across all walks.
    pub proposed: u64,
    /// Draft tokens accepted by the target (excludes bonus tokens).
    pub accepted: u64,
    /// Tokens committed by verify walks (accepted + correction/bonus;
    /// includes commits discarded past a stop condition's cut).
    pub committed: u64,
}

impl SpecMetrics {
    /// Tokens committed per verify walk. Non-speculative decode commits
    /// exactly 1 token per walk, so > 1.0 means speculation is paying.
    pub fn committed_per_walk(&self) -> f64 {
        if self.walks > 0 {
            self.committed as f64 / self.walks as f64
        } else {
            0.0
        }
    }

    /// Fraction of proposed draft tokens the target accepted.
    pub fn acceptance_rate(&self) -> f64 {
        if self.proposed > 0 {
            self.accepted as f64 / self.proposed as f64
        } else {
            0.0
        }
    }
}

/// Aggregate over a batch of completed requests.
#[derive(Clone, Debug, Default)]
pub struct EngineMetrics {
    pub completed: Vec<RequestMetrics>,
    /// Requests cancelled via `Engine::cancel` (queued or mid-decode).
    pub cancelled: u64,
    /// Requests rejected at admission (never ran).
    pub rejected: u64,
    /// Requests terminated by a backend failure mid-flight (their
    /// sessions' KV was released on the error path; terminal event
    /// `Failed`).
    pub failed: u64,
    /// KV spill/restore/preemption accounting across all requests.
    pub kv: KvPressureMetrics,
    /// Weight residency accounting (native backend): cumulative snapshot
    /// taken from the model as requests finish.
    pub weights: WeightResidencyMetrics,
    /// Shared-prefix KV cache accounting (native backend): hits, prompt
    /// tokens and page bytes saved, copy-on-write privatizations, and the
    /// cache's current footprint. Snapshot refreshed at every admission
    /// and completion; all-zero when the cache is disabled (the default).
    pub prefix: PrefixCacheMetrics,
    /// Compute-backend snapshot (native backend): which kernel set is
    /// live (`scalar` / `simd-avx2` / `simd-neon`) and per-op invocation
    /// counts. Default (empty name) on backends without the seam.
    pub compute: ComputeBackendMetrics,
    /// Speculative-decoding accounting: verify walks, draft tokens
    /// proposed/accepted, tokens committed. All-zero without a draft.
    pub spec: SpecMetrics,
}

impl EngineMetrics {
    pub fn push(&mut self, m: RequestMetrics) {
        self.completed.push(m);
    }

    pub fn count(&self) -> usize {
        self.completed.len()
    }

    /// Mean prefill speed across requests, tok/s.
    pub fn mean_prefill_tok_s(&self) -> f64 {
        stats::mean(&self.completed.iter().map(|m| m.prefill_tok_s()).collect::<Vec<_>>())
    }

    pub fn mean_decode_tok_s(&self) -> f64 {
        stats::mean(&self.completed.iter().map(|m| m.decode_tok_s()).collect::<Vec<_>>())
    }

    pub fn p50_ttft_s(&self) -> f64 {
        stats::median(&self.completed.iter().map(|m| m.ttft_s).collect::<Vec<_>>())
    }

    pub fn p95_e2e_s(&self) -> f64 {
        stats::percentile(&self.completed.iter().map(|m| m.e2e_s).collect::<Vec<_>>(), 95.0)
    }

    /// Engine throughput: total new tokens / total wall time.
    pub fn throughput_tok_s(&self, wall_s: f64) -> f64 {
        let total: usize = self.completed.iter().map(|m| m.new_tokens).sum();
        if wall_s > 0.0 {
            total as f64 / wall_s
        } else {
            0.0
        }
    }

    /// One summary line for logs/examples.
    pub fn summary(&self, wall_s: f64) -> String {
        let mut s = format!(
            "{} requests | prefill {:.1} tok/s | decode {:.1} tok/s | p50 TTFT {:.1} ms | p95 e2e {:.1} ms | engine {:.1} tok/s",
            self.count(),
            self.mean_prefill_tok_s(),
            self.mean_decode_tok_s(),
            self.p50_ttft_s() * 1e3,
            self.p95_e2e_s() * 1e3,
            self.throughput_tok_s(wall_s),
        );
        if self.cancelled > 0 || self.rejected > 0 {
            s.push_str(&format!(
                " | {} cancelled / {} rejected",
                self.cancelled, self.rejected
            ));
        }
        if self.failed > 0 {
            s.push_str(&format!(" | {} failed", self.failed));
        }
        if self.kv != KvPressureMetrics::default() {
            s.push_str(&format!(
                " | kv spill {} rec / restore {} rec / {} preempt",
                self.kv.spilled_records, self.kv.restored_records, self.kv.preemptions
            ));
            if self.kv.holder_sheds > 0 {
                s.push_str(&format!(" / {} holder-shed", self.kv.holder_sheds));
            }
        }
        if self.weights.under_pressure() {
            s.push_str(&format!(
                " | weights {} fetch / {} evict / {} pf hit / {} pf stall / depth {}",
                self.weights.demand_fetches,
                self.weights.evictions,
                self.weights.prefetch_hits,
                self.weights.prefetch_stalls,
                self.weights.prefetch_depth
            ));
            if self.weights.tokens_generated > 0 {
                // The batched-decode amortization gauge: flash blob reads
                // per generated decode token (fused rounds divide this by
                // the batch size).
                s.push_str(&format!(
                    " / {:.2} fetch/tok",
                    self.weights.fetches_per_token()
                ));
            }
            if self.weights.prefill_fetches > 0 {
                // The prefill amortization gauge: prefill-phase flash blob
                // reads per prompt token (shared admission walks divide
                // this by the number of co-admitted prompts; mixed ticks
                // contribute their proportional share).
                s.push_str(&format!(
                    " / {:.2} fetch/ptok",
                    self.weights.fetches_per_prompt_token()
                ));
            }
        }
        if self.prefix.lookups > 0 {
            s.push_str(&format!(
                " | prefix {}/{} hit / {} ptok saved / {} cow",
                self.prefix.hits,
                self.prefix.lookups,
                self.prefix.prefill_tokens_saved,
                self.prefix.cow_copies
            ));
        }
        if !self.compute.backend.is_empty() && self.compute.gemm_calls > 0 {
            s.push_str(&format!(
                " | compute {} / {} gemm ({} tiles)",
                self.compute.backend, self.compute.gemm_calls, self.compute.gemm_tiles
            ));
        }
        if self.spec.walks > 0 {
            s.push_str(&format!(
                " | spec {} walks / {:.2} tok/walk / {:.0}% accept",
                self.spec.walks,
                self.spec.committed_per_walk(),
                self.spec.acceptance_rate() * 100.0
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(prompt: usize, new: usize, prefill: f64, decode: f64) -> RequestMetrics {
        RequestMetrics {
            prompt_tokens: prompt,
            new_tokens: new,
            ttft_s: prefill,
            prefill_s: prefill,
            decode_s: decode,
            e2e_s: prefill + decode,
            ..Default::default()
        }
    }

    #[test]
    fn rates() {
        let r = m(64, 16, 0.5, 2.0);
        assert!((r.prefill_tok_s() - 128.0).abs() < 1e-9);
        assert!((r.decode_tok_s() - 8.0).abs() < 1e-9);
    }

    #[test]
    fn zero_division_safe() {
        let r = RequestMetrics::default();
        assert_eq!(r.prefill_tok_s(), 0.0);
        assert_eq!(r.decode_tok_s(), 0.0);
    }

    #[test]
    fn aggregates() {
        let mut e = EngineMetrics::default();
        e.push(m(64, 16, 0.5, 2.0));
        e.push(m(64, 16, 0.25, 1.0));
        assert_eq!(e.count(), 2);
        assert!((e.mean_prefill_tok_s() - (128.0 + 256.0) / 2.0).abs() < 1e-9);
        assert!((e.throughput_tok_s(4.0) - 8.0).abs() < 1e-9);
        assert!(e.summary(4.0).contains("2 requests"));
    }

    #[test]
    fn weight_pressure_appears_in_summary_only_under_pressure() {
        let mut e = EngineMetrics::default();
        e.push(m(8, 4, 0.1, 0.2));
        // Residency snapshots alone (bytes) are not pressure.
        e.weights.resident_bytes = 1 << 20;
        e.weights.packed_bytes = 1 << 20;
        assert!(!e.summary(1.0).contains("weights"));
        e.weights.demand_fetches = 3;
        e.weights.evictions = 2;
        let s = e.summary(1.0);
        assert!(s.contains("weights 3 fetch"), "{s}");
        assert!(s.contains("2 evict"), "{s}");
        // fetch/tok appears only once decode tokens were generated, and is
        // computed from decode-phase fetches only.
        assert!(!s.contains("fetch/tok"), "{s}");
        e.weights.decode_fetches = 6;
        e.weights.tokens_generated = 4;
        let s = e.summary(1.0);
        assert!(s.contains("1.50 fetch/tok"), "{s}");
    }

    #[test]
    fn kv_pressure_appears_in_summary_only_under_pressure() {
        let mut e = EngineMetrics::default();
        e.push(m(8, 4, 0.1, 0.2));
        assert!(!e.summary(1.0).contains("kv spill"));
        e.kv.spilled_records = 12;
        e.kv.restored_records = 7;
        e.kv.preemptions = 1;
        let s = e.summary(1.0);
        assert!(s.contains("kv spill 12 rec"), "{s}");
        assert!(s.contains("restore 7 rec"), "{s}");
        assert!(s.contains("1 preempt"), "{s}");
        assert!(!s.contains("holder-shed"), "{s}");
        e.kv.holder_sheds = 5;
        assert!(e.summary(1.0).contains("5 holder-shed"));
    }

    #[test]
    fn lifecycle_counters_appear_in_summary() {
        let mut e = EngineMetrics::default();
        e.push(m(8, 4, 0.1, 0.2));
        assert!(!e.summary(1.0).contains("cancelled"));
        assert!(!e.summary(1.0).contains("failed"));
        e.cancelled = 2;
        e.rejected = 1;
        assert!(e.summary(1.0).contains("2 cancelled / 1 rejected"));
        e.failed = 3;
        assert!(e.summary(1.0).contains("3 failed"));
    }

    #[test]
    fn prefix_cache_appears_in_summary_only_when_used() {
        let mut e = EngineMetrics::default();
        e.push(m(8, 4, 0.1, 0.2));
        assert!(!e.summary(1.0).contains("prefix"), "disabled cache stays silent");
        e.prefix.lookups = 4;
        e.prefix.hits = 3;
        e.prefix.prefill_tokens_saved = 96;
        e.prefix.cow_copies = 2;
        let s = e.summary(1.0);
        assert!(s.contains("prefix 3/4 hit"), "{s}");
        assert!(s.contains("96 ptok saved"), "{s}");
        assert!(s.contains("2 cow"), "{s}");
    }

    #[test]
    fn compute_backend_appears_in_summary_once_it_ran() {
        let mut e = EngineMetrics::default();
        e.push(m(8, 4, 0.1, 0.2));
        assert!(!e.summary(1.0).contains("compute"), "no backend yet");
        e.compute.backend = "simd-avx2";
        assert!(!e.summary(1.0).contains("compute"), "no gemm calls yet");
        e.compute.gemm_calls = 9;
        e.compute.gemm_tiles = 72;
        let s = e.summary(1.0);
        assert!(s.contains("compute simd-avx2 / 9 gemm (72 tiles)"), "{s}");
    }

    #[test]
    fn speculation_appears_in_summary_only_after_walks() {
        let mut e = EngineMetrics::default();
        e.push(m(8, 4, 0.1, 0.2));
        assert!(!e.summary(1.0).contains("spec"), "no walks yet");
        e.spec.walks = 4;
        e.spec.proposed = 12;
        e.spec.accepted = 6;
        e.spec.committed = 10;
        let s = e.summary(1.0);
        assert!(s.contains("spec 4 walks"), "{s}");
        assert!(s.contains("2.50 tok/walk"), "{s}");
        assert!(s.contains("50% accept"), "{s}");
        assert!((e.spec.committed_per_walk() - 2.5).abs() < 1e-12);
        assert!((e.spec.acceptance_rate() - 0.5).abs() < 1e-12);
        // Zero-division safety.
        let z = SpecMetrics::default();
        assert_eq!(z.committed_per_walk(), 0.0);
        assert_eq!(z.acceptance_rate(), 0.0);
    }

    #[test]
    fn prefill_fetch_gauge_appears_under_pressure() {
        let mut e = EngineMetrics::default();
        e.push(m(8, 4, 0.1, 0.2));
        e.weights.demand_fetches = 3;
        assert!(!e.summary(1.0).contains("fetch/ptok"));
        e.weights.prefill_fetches = 6;
        e.weights.prompt_tokens_prefilled = 12;
        let s = e.summary(1.0);
        assert!(s.contains("0.50 fetch/ptok"), "{s}");
    }
}
