//! Request/response types for the serving API.

use std::time::Instant;

use crate::model::sampler::SamplerConfig;

pub type RequestId = u64;

/// One inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<usize>,
    pub max_new_tokens: usize,
    /// Select a loaded LoRA task for this request (§5.5 multitask).
    pub lora_task: Option<String>,
    pub sampler: SamplerConfig,
    /// Generation stops (with `FinishReason::StopToken`) when any of these
    /// tokens is produced. The tokenizer's EOS always stops, independently
    /// of this list.
    pub stop_tokens: Vec<usize>,
    /// Generation stops (with `FinishReason::StopSequence`) when the
    /// generated tail matches any of these sequences. The matched sequence
    /// is included in the output tokens.
    pub stop_sequences: Vec<Vec<usize>>,
    /// Admission priority class: higher classes are admitted first; ties
    /// break by arrival time (earliest first — EDF with arrival as the
    /// deadline proxy), then id. `None` is the default class 0, so
    /// requests that never set a priority are admitted in strict FIFO
    /// order, exactly as before the field existed.
    pub priority: Option<u8>,
    /// Seed for this request's private sampling RNG. `None` derives a
    /// deterministic per-request stream from the request id, so sampled
    /// (temperature > 0) outputs are schedule-invariant either way.
    pub seed: Option<u64>,
    /// Per-request speculative-decoding depth override: how many draft
    /// tokens to propose per verify walk. `None` uses the engine's
    /// configured default; `Some(0)` disables speculation for this
    /// request. Ignored when the engine has no draft model attached.
    pub spec_depth: Option<usize>,
    /// Logical session/conversation identity, chosen by the caller. A
    /// single `Engine` ignores it; the cluster `Router` uses it for
    /// session affinity — every request carrying the same session id is
    /// placed on the replica that served the session before, so its KV
    /// spill files and prefix-cache entries stay local.
    pub session_id: Option<u64>,
    /// Set by the engine when the request is submitted; TTFT and e2e
    /// latency are measured from here (queue wait included).
    pub arrival: Option<Instant>,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<usize>, max_new_tokens: usize) -> Self {
        Request {
            id,
            prompt,
            max_new_tokens,
            lora_task: None,
            sampler: SamplerConfig::default(),
            stop_tokens: Vec::new(),
            stop_sequences: Vec::new(),
            priority: None,
            seed: None,
            spec_depth: None,
            session_id: None,
            arrival: None,
        }
    }

    /// Builder-style: set the admission priority class (higher = sooner).
    pub fn with_priority(mut self, class: u8) -> Self {
        self.priority = Some(class);
        self
    }

    /// The effective admission class (`None` ≡ class 0).
    pub fn priority_class(&self) -> u8 {
        self.priority.unwrap_or(0)
    }

    /// Builder-style: set the sampling seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    /// Builder-style: add stop tokens.
    pub fn with_stop_tokens(mut self, toks: Vec<usize>) -> Self {
        self.stop_tokens = toks;
        self
    }

    /// Builder-style: add stop sequences.
    pub fn with_stop_sequences(mut self, seqs: Vec<Vec<usize>>) -> Self {
        self.stop_sequences = seqs;
        self
    }

    /// Builder-style: set the sampler configuration.
    pub fn with_sampler(mut self, sampler: SamplerConfig) -> Self {
        self.sampler = sampler;
        self
    }

    /// Builder-style: override the speculative-decoding depth (0 disables
    /// speculation for this request even when the engine default is on).
    pub fn with_spec_depth(mut self, depth: usize) -> Self {
        self.spec_depth = Some(depth);
        self
    }

    /// Builder-style: tag this request with a logical session id so the
    /// cluster router keeps the whole conversation on one replica.
    pub fn with_session(mut self, session: u64) -> Self {
        self.session_id = Some(session);
        self
    }

    /// True when the token stream — prompt followed by `generated` — ends
    /// with one of this request's stop sequences. Matching spans the
    /// prompt/generation boundary: a sequence whose prefix ends the
    /// prompt fires as soon as generation completes it (the old
    /// generated-only match could never fire for those). The match must
    /// end at (and therefore include) the newest generated token, so a
    /// sequence lying wholly inside the prompt never stops generation.
    pub fn matches_stop_sequence(&self, generated: &[usize]) -> bool {
        if generated.is_empty() {
            return false;
        }
        self.stop_sequences.iter().any(|seq| {
            if seq.is_empty() {
                return false;
            }
            if generated.len() >= seq.len() {
                generated.ends_with(seq)
            } else {
                // The sequence reaches back across the boundary: all of
                // `generated` must match its tail and the prompt must end
                // with the remainder.
                let split = seq.len() - generated.len();
                generated == &seq[split..] && self.prompt.ends_with(&seq[..split])
            }
        })
    }
}

/// Completed request with metrics.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: RequestId,
    pub tokens: Vec<usize>,
    pub metrics: crate::coordinator::metrics::RequestMetrics,
    /// Why generation stopped.
    pub finish_reason: crate::coordinator::events::FinishReason,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_defaults() {
        let r = Request::new(1, vec![1, 2, 3], 8);
        assert_eq!(r.id, 1);
        assert_eq!(r.max_new_tokens, 8);
        assert!(r.lora_task.is_none());
        assert_eq!(r.sampler.temperature, 0.0);
        assert!(r.stop_tokens.is_empty());
        assert!(r.stop_sequences.is_empty());
        assert!(r.priority.is_none());
        assert_eq!(r.priority_class(), 0);
        assert!(r.seed.is_none());
        assert!(r.spec_depth.is_none());
        assert!(r.session_id.is_none());
        assert!(r.arrival.is_none());
    }

    #[test]
    fn builders_set_fields() {
        let r = Request::new(1, vec![1], 4)
            .with_seed(42)
            .with_stop_tokens(vec![9])
            .with_stop_sequences(vec![vec![1, 2]])
            .with_priority(3)
            .with_spec_depth(4)
            .with_session(11);
        assert_eq!(r.seed, Some(42));
        assert_eq!(r.stop_tokens, vec![9]);
        assert_eq!(r.stop_sequences, vec![vec![1, 2]]);
        assert_eq!(r.priority, Some(3));
        assert_eq!(r.priority_class(), 3);
        assert_eq!(r.spec_depth, Some(4));
        assert_eq!(r.session_id, Some(11));
    }

    #[test]
    fn stop_sequence_matches_tail_only() {
        let r = Request::new(1, vec![1], 8).with_stop_sequences(vec![vec![4, 5], vec![7]]);
        assert!(!r.matches_stop_sequence(&[4, 5, 6]));
        assert!(r.matches_stop_sequence(&[3, 4, 5]));
        assert!(r.matches_stop_sequence(&[7]));
        assert!(!r.matches_stop_sequence(&[]));
        // Empty stop sequences never match.
        let e = Request::new(2, vec![1], 8).with_stop_sequences(vec![vec![]]);
        assert!(!e.matches_stop_sequence(&[1, 2]));
    }

    #[test]
    fn stop_sequence_spans_prompt_generation_boundary() {
        // Prompt ends with the sequence's prefix; the first generated
        // tokens complete it — the match must fire (regression: the old
        // generated-only match never could).
        let r = Request::new(1, vec![9, 4, 5], 8).with_stop_sequences(vec![vec![4, 5, 6, 7]]);
        assert!(r.matches_stop_sequence(&[6, 7]), "prefix in prompt, suffix generated");
        assert!(!r.matches_stop_sequence(&[6]), "sequence not complete yet");
        assert!(!r.matches_stop_sequence(&[7]), "generated tail mismatches");
        assert!(!r.matches_stop_sequence(&[6, 7, 8]), "match must end at the newest token");
        // A sequence lying wholly inside the prompt must NOT stop
        // generation: the match has to include a generated token.
        let p = Request::new(2, vec![4, 5], 8).with_stop_sequences(vec![vec![4, 5]]);
        assert!(!p.matches_stop_sequence(&[1]));
        assert!(!p.matches_stop_sequence(&[]));
        // Boundary match where the prompt is shorter than the sequence
        // remainder: no panic, no match.
        let s = Request::new(3, vec![5], 8).with_stop_sequences(vec![vec![3, 4, 5, 6]]);
        assert!(!s.matches_stop_sequence(&[6]));
    }
}
