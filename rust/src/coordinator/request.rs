//! Request/response types for the serving API.

use crate::model::sampler::SamplerConfig;

pub type RequestId = u64;

/// One inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<usize>,
    pub max_new_tokens: usize,
    /// Select a loaded LoRA task for this request (§5.5 multitask).
    pub lora_task: Option<String>,
    pub sampler: SamplerConfig,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<usize>, max_new_tokens: usize) -> Self {
        Request {
            id,
            prompt,
            max_new_tokens,
            lora_task: None,
            sampler: SamplerConfig::default(),
        }
    }
}

/// Completed request with metrics.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: RequestId,
    pub tokens: Vec<usize>,
    pub metrics: crate::coordinator::metrics::RequestMetrics,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_defaults() {
        let r = Request::new(1, vec![1, 2, 3], 8);
        assert_eq!(r.id, 1);
        assert_eq!(r.max_new_tokens, 8);
        assert!(r.lora_task.is_none());
        assert_eq!(r.sampler.temperature, 0.0);
    }
}
