//! The one backend abstraction the engine schedules over.
//!
//! PR 1's coordinator carried four copies of the sample/decode loop —
//! `{Fifo, Interleaved} × {Native, Pjrt}` — because the two runtimes had
//! different shapes: the native model owns sessions (paged KV over the
//! shared pool), the PJRT runtime threads a host-side [`KvState`] per
//! request. [`InferenceBackend`] is the common surface: a backend knows
//! how to open a session, prefill it — monolithically, in incremental
//! [`RowWork::Prefill`] chunks, or fused with decode rows in one
//! [`InferenceBackend::step_batch`] tick (all value-neutral by contract,
//! defaulting to loops) — decode one token (or one fused `decode_batch`
//! round for every active session), report its position, and release its
//! resources; everything scheduling-related (admission, batched rounds,
//! stop conditions, events, cancellation) lives once in
//! `scheduler::Engine`.
//!
//! Native-only mechanisms — KV-pool admission preemption, the
//! largest-holder eviction pass, weight-residency metrics — are trait
//! hooks with no-op defaults, so the PJRT impl stays trivial and the
//! engine never matches on the backend kind.

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::request::Request;
use crate::cpu::backend::ComputeBackendMetrics;
use crate::kv::{PrefixCache, PrefixCacheMetrics};
use crate::memory::weight_store::WeightResidencyMetrics;
use crate::model::native::{NativeModel, NativeSession};
use crate::runtime::{KvState, PjrtRuntime};

/// Per-tick scheduling limits a backend advertises to the engine. Both
/// default to "unlimited", which reproduces the pre-chunking behavior
/// exactly: whole-prompt admission, every active session in every tick.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TickLimits {
    /// Most rows (sessions) one fused [`InferenceBackend::step_batch`]
    /// call may advance; when the active set is larger the engine rotates
    /// a window through it, bounding per-tick latency at large B.
    pub max_rows: usize,
    /// Longest prompt slice one tick may prefill for a single request;
    /// `usize::MAX` disables chunking (whole-prompt admission), which is
    /// what backends without [`InferenceBackend::prefill_chunk`] support
    /// (PJRT) must advertise.
    pub prefill_chunk: usize,
}

impl TickLimits {
    pub fn unlimited() -> Self {
        TickLimits { max_rows: usize::MAX, prefill_chunk: usize::MAX }
    }
}

impl Default for TickLimits {
    fn default() -> Self {
        Self::unlimited()
    }
}

/// One session's work item in a fused scheduler tick.
#[derive(Clone, Copy, Debug)]
pub enum RowWork<'a> {
    /// Consume `ids`, the next contiguous slice of the session's prompt;
    /// `last` marks the prompt's final chunk (logits required).
    Prefill { ids: &'a [usize], last: bool },
    /// One decode step consuming `tok` at the session's position.
    Decode { tok: usize },
    /// Speculative verify: `toks[0]` is the newest committed token,
    /// `toks[1..]` a draft model's proposed continuation — `toks.len()`
    /// consecutive decode positions advanced in one walk. The outcome
    /// carries the per-position logits concatenated row-major
    /// (`toks.len() * vocab`), each slice bit-identical to what a
    /// sequential [`RowWork::Decode`] at that position would return.
    Verify { toks: &'a [usize] },
}

/// Per-row outcome of a fused tick: `Ok(Some(logits))` for a decode row
/// or a final prefill chunk, `Ok(None)` for a non-final prefill chunk,
/// `Err` when this row's session failed — the engine releases that
/// session and emits a terminal `Failed` event without touching the
/// batch's other rows.
pub type RowOutcome = Result<Option<Vec<f32>>>;

/// A runtime the engine can schedule requests onto. `Session` holds all
/// per-request state; the backend itself stays shared and immutable
/// during stepping.
pub trait InferenceBackend {
    type Session;

    /// Context window (prompt + generated tokens).
    fn max_len(&self) -> usize;

    /// Open a session for `req` (LoRA task selected, no KV yet).
    fn new_session(&self, req: &Request) -> Result<Self::Session>;

    /// Run prefill over `ids`; returns last-token logits and leaves the
    /// session's KV filled and its position advanced.
    fn prefill(&self, sess: &mut Self::Session, ids: &[usize]) -> Result<Vec<f32>>;

    /// One decode step at the session's position; returns logits.
    fn decode(&self, sess: &mut Self::Session, tok: usize) -> Result<Vec<f32>>;

    /// One decode step for a whole batch: row r consumes `toks[r]` on
    /// `sessions[r]` and receives its logits in returned row r. The
    /// contract is **value-neutrality**: any implementation must produce
    /// exactly the logits `decode` would produce row by row — batching may
    /// only change how the work is scheduled (e.g. the native backend runs
    /// one fused layer walk, paying one weight fetch per layer per round
    /// instead of one per layer per session). The default is the loop
    /// itself, so backends without a fused path (PJRT) are batched-decode
    /// correct for free.
    fn decode_batch(
        &self,
        sessions: &mut [&mut Self::Session],
        toks: &[usize],
    ) -> Result<Vec<Vec<f32>>> {
        assert_eq!(sessions.len(), toks.len(), "one token per session");
        let mut out = Vec::with_capacity(toks.len());
        for (sess, &tok) in sessions.iter_mut().zip(toks) {
            out.push(self.decode(sess, tok)?);
        }
        Ok(out)
    }

    /// Multi-position speculative verify: consume `toks` — the newest
    /// committed token followed by draft proposals — and return the
    /// per-position logits concatenated (`toks.len() * vocab`). The
    /// value-neutrality contract is strict: slice `i` must be
    /// bit-identical to what [`decode`](Self::decode) would return after
    /// sequentially decoding `toks[..=i]`, which is exactly what the
    /// default loop produces — so any backend with a correct `decode` can
    /// verify drafts, just without the fused-walk amortization.
    fn verify(&self, sess: &mut Self::Session, toks: &[usize]) -> Result<Option<Vec<f32>>> {
        let mut flat = Vec::new();
        for &tok in toks {
            flat.extend_from_slice(&self.decode(sess, tok)?);
        }
        Ok(Some(flat))
    }

    /// Roll the session's KV back to its first `keep` positions,
    /// discarding rejected draft appends. Backends that cannot roll back
    /// must keep the default (an error) AND leave
    /// [`supports_speculation`](Self::supports_speculation) false so the
    /// engine never schedules verify rows onto them.
    fn truncate_kv(&self, _sess: &mut Self::Session, _keep: usize) -> Result<()> {
        anyhow::bail!("backend cannot roll back KV")
    }

    /// Whether the engine may schedule [`RowWork::Verify`] rows and rely
    /// on [`truncate_kv`](Self::truncate_kv) for rejected drafts. False by
    /// default so existing backends (PJRT: no KV rollback) are untouched
    /// by speculation.
    fn supports_speculation(&self) -> bool {
        false
    }

    /// KV bytes a verify row of `depth` draft tokens may pin beyond the
    /// plain decode append — counted against
    /// [`kv_headroom`](Self::kv_headroom) before the engine speculates,
    /// exactly like prefill reservations. 0 (the default) means "no
    /// accounting".
    fn verify_reserve_bytes(&self, _depth: usize) -> usize {
        0
    }

    /// Per-tick scheduling limits (row cap, prefill chunk size). The
    /// defaults reproduce the pre-chunking engine exactly; the native
    /// backend forwards `EngineOptions::{max_rows_per_tick,
    /// prefill_chunk_tokens}`.
    fn tick_limits(&self) -> TickLimits {
        TickLimits::unlimited()
    }

    /// One incremental prefill chunk: consume `ids` — the next contiguous
    /// slice of the session's prompt — advancing the session's position;
    /// returns last-row logits for the final chunk (`last`), `None`
    /// otherwise. The engine only splits prompts when
    /// [`tick_limits`](Self::tick_limits) advertises a finite
    /// `prefill_chunk`, so the default — whole-prompt delegation to
    /// [`prefill`](Self::prefill) — keeps chunk-less backends (PJRT)
    /// correct.
    fn prefill_chunk(
        &self,
        sess: &mut Self::Session,
        ids: &[usize],
        last: bool,
    ) -> Result<Option<Vec<f32>>> {
        assert!(last, "backend without chunked prefill was handed a partial chunk");
        Ok(Some(self.prefill(sess, ids)?))
    }

    /// Fused batched prefill: row r consumes chunk `chunks[r]` (`(ids,
    /// last)`) on `sessions[r]`. A convenience shape of
    /// [`step_batch`](Self::step_batch) — it IS an all-`Prefill` tick, so
    /// this delegates there and inherits whatever fusion and per-row
    /// failure isolation the backend's `step_batch` provides (one walk on
    /// the native backend, the row loop elsewhere). Not overridden by any
    /// backend, so the two batched-prefill surfaces cannot diverge.
    fn prefill_batch(
        &self,
        sessions: &mut [&mut Self::Session],
        chunks: &[(&[usize], bool)],
    ) -> Result<Vec<RowOutcome>> {
        let works: Vec<RowWork> = chunks
            .iter()
            .map(|&(ids, last)| RowWork::Prefill { ids, last })
            .collect();
        self.step_batch(sessions, &works)
    }

    /// One fused scheduler tick: advance row r by `works[r]` — prefill
    /// chunks and decode steps **share the call**, so a fused backend can
    /// serve them all from one layer walk (one weight fetch + prefetch
    /// per layer per tick on the native backend). Value-neutral by the
    /// same contract as [`decode_batch`](Self::decode_batch). Per-row
    /// failures are isolated as inner `Err`s; an outer `Err` means every
    /// row's session state is suspect (the engine releases them all).
    /// The default loops [`prefill_chunk`] / [`decode`](Self::decode).
    fn step_batch(
        &self,
        sessions: &mut [&mut Self::Session],
        works: &[RowWork<'_>],
    ) -> Result<Vec<RowOutcome>> {
        assert_eq!(sessions.len(), works.len(), "one work item per session");
        let mut out = Vec::with_capacity(works.len());
        for (sess, w) in sessions.iter_mut().zip(works) {
            out.push(match *w {
                RowWork::Prefill { ids, last } => self.prefill_chunk(sess, ids, last),
                RowWork::Decode { tok } => self.decode(sess, tok).map(Some),
                RowWork::Verify { toks } => self.verify(sess, toks),
            });
        }
        Ok(out)
    }

    /// KV bytes admitting `prompt` will pin — the engine's per-tick
    /// admission loop reserves this much headroom per
    /// admitted-but-not-yet-prefilled prompt so a burst of admissions
    /// cannot overcommit the pool in one tick. Takes the prompt ids, not
    /// just a length, so backends with a prefix cache can subtract the
    /// shared-prefix pages a hit would attach (already resident). 0 (the
    /// default) means "no accounting" (backends without a shared pool).
    fn prefill_reserve_bytes(&self, _prompt: &[usize]) -> usize {
        0
    }

    /// The portion of an in-flight prefill's reservation the pool-side
    /// headroom already observes after `consumed` tokens of `prompt` —
    /// their appended pages (minus any shared-prefix pages, which were
    /// resident before admission). Subtracted from the full estimate when
    /// the engine computes outstanding reservations; memory retained
    /// until prefill completes (the native fp32 stash) must NOT be
    /// included here, since it stays allocated and pool-invisible. 0 (the
    /// default) pairs with the 0 default of
    /// [`prefill_reserve_bytes`](Self::prefill_reserve_bytes).
    fn prefill_visible_bytes(&self, _prompt: &[usize], _consumed: usize) -> usize {
        0
    }

    /// Unreserved KV-pool headroom (budget − resident bytes). Paired with
    /// [`prefill_reserve_bytes`](Self::prefill_reserve_bytes); the
    /// default is unlimited.
    fn kv_headroom(&self) -> usize {
        usize::MAX
    }

    /// Tokens the session has consumed/produced so far (== KV length).
    fn session_pos(&self, sess: &Self::Session) -> usize;

    /// Terminal release of the session's per-request memory (KV pool
    /// pages, spilled flash records, host buffers). Called the moment a
    /// request finishes or is cancelled, so dead requests stop pressuring
    /// live ones.
    fn release(&self, sess: &mut Self::Session);

    /// Reclaim shared stores once no session references them (e.g. the
    /// native flash spill store). Called when the engine goes idle.
    fn reclaim(&self);

    /// (spilled, restored) KV flash-record counters for this session.
    fn kv_counters(&self, _sess: &Self::Session) -> (u64, u64) {
        (0, 0)
    }

    /// Admission hook: make room for prefilling `prompt`, e.g. by
    /// preempting `running` sessions to flash. Returns sessions preempted.
    fn make_room(
        &self,
        _prompt: &[usize],
        _running: &mut [&mut Self::Session],
    ) -> Result<u64> {
        Ok(0)
    }

    /// Admission hook: attach the longest cached prefix of `prompt` to the
    /// freshly opened session (shared, refcounted pages — no new KV
    /// bytes). Returns the fork point: prompt tokens already covered, so
    /// the engine starts prefill there. 0 (the default, and always on
    /// backends without a prefix cache) means a cold prefill from the
    /// prompt's first token.
    fn prefix_attach(&self, _sess: &mut Self::Session, _prompt: &[usize]) -> usize {
        0
    }

    /// Prefix-cache counters snapshot (native backend only).
    fn prefix_metrics(&self) -> PrefixCacheMetrics {
        PrefixCacheMetrics::default()
    }

    /// A shareable handle on the backend's prefix cache, if it has one.
    /// The cluster router clones this per replica and snapshots
    /// fingerprint indices from it for shared-prefix-affinity placement
    /// (`PrefixCache` is internally synchronized). `None` (the default)
    /// means the backend has no prompt locality to exploit.
    fn prefix_cache_handle(&self) -> Option<Arc<PrefixCache>> {
        None
    }

    /// Cross-session KV budget enforcement between scheduler ticks (the
    /// `EvictionPolicy::LargestHolder` pass). Returns records shed.
    fn enforce_kv_budget(&self, _running: &mut [&mut Self::Session]) -> Result<u64> {
        Ok(0)
    }

    /// Weight-residency counters snapshot (native backend only).
    fn weight_metrics(&self) -> WeightResidencyMetrics {
        WeightResidencyMetrics::default()
    }

    /// Compute-backend snapshot: which kernel set is live plus per-op
    /// invocation counts (native backend only).
    fn compute_metrics(&self) -> ComputeBackendMetrics {
        ComputeBackendMetrics::default()
    }
}

impl InferenceBackend for NativeModel {
    type Session = NativeSession;

    fn max_len(&self) -> usize {
        self.config.max_len
    }

    fn new_session(&self, req: &Request) -> Result<NativeSession> {
        let mut sess = NativeModel::new_session(self);
        sess.lora_task = req.lora_task.clone();
        // Carried onto the session so `make_room` can preempt the lowest
        // class first under pool pressure.
        sess.priority_class = req.priority_class();
        Ok(sess)
    }

    fn prefill(&self, sess: &mut NativeSession, ids: &[usize]) -> Result<Vec<f32>> {
        Ok(NativeModel::prefill(self, sess, ids))
    }

    fn decode(&self, sess: &mut NativeSession, tok: usize) -> Result<Vec<f32>> {
        Ok(NativeModel::decode(self, sess, tok))
    }

    fn decode_batch(
        &self,
        sessions: &mut [&mut NativeSession],
        toks: &[usize],
    ) -> Result<Vec<Vec<f32>>> {
        Ok(NativeModel::decode_batch(self, sessions, toks))
    }

    fn tick_limits(&self) -> TickLimits {
        TickLimits {
            max_rows: self.options.max_rows_per_tick,
            prefill_chunk: self.options.prefill_chunk_tokens,
        }
    }

    fn prefill_chunk(
        &self,
        sess: &mut NativeSession,
        ids: &[usize],
        last: bool,
    ) -> Result<Option<Vec<f32>>> {
        Ok(NativeModel::prefill_chunk(self, sess, ids, last))
    }

    fn step_batch(
        &self,
        sessions: &mut [&mut NativeSession],
        works: &[RowWork<'_>],
    ) -> Result<Vec<RowOutcome>> {
        let rows = NativeModel::forward_tick(self, sessions, works)?;
        Ok(rows.into_iter().map(|r| r.map_err(anyhow::Error::from)).collect())
    }

    fn verify(&self, sess: &mut NativeSession, toks: &[usize]) -> Result<Option<Vec<f32>>> {
        // One fused walk instead of the default decode loop; bit-identical
        // by the forward_tick verify-row contract.
        let mut rows =
            NativeModel::forward_tick(self, &mut [sess], &[RowWork::Verify { toks }])?;
        match rows.pop() {
            Some(row) => Ok(row?),
            None => anyhow::bail!("verify walk returned no rows"),
        }
    }

    fn truncate_kv(&self, sess: &mut NativeSession, keep: usize) -> Result<()> {
        NativeModel::truncate_kv(self, sess, keep);
        Ok(())
    }

    fn supports_speculation(&self) -> bool {
        true
    }

    fn verify_reserve_bytes(&self, depth: usize) -> usize {
        NativeModel::verify_reserve_bytes(self, depth)
    }

    fn prefill_reserve_bytes(&self, prompt: &[usize]) -> usize {
        NativeModel::prefill_reserve_bytes(self, prompt)
    }

    fn prefill_visible_bytes(&self, prompt: &[usize], consumed: usize) -> usize {
        // Only the appended quantized pages become pool-visible; the fp32
        // stash stays allocated (and charged) until the final chunk.
        NativeModel::prefill_visible_bytes(self, prompt, consumed)
    }

    fn kv_headroom(&self) -> usize {
        NativeModel::kv_headroom(self)
    }

    fn session_pos(&self, sess: &NativeSession) -> usize {
        sess.pos
    }

    fn release(&self, sess: &mut NativeSession) {
        sess.release_kv();
    }

    fn reclaim(&self) {
        self.reclaim_flash();
    }

    fn kv_counters(&self, sess: &NativeSession) -> (u64, u64) {
        (sess.spilled_records(), sess.restored_records())
    }

    fn make_room(
        &self,
        prompt: &[usize],
        running: &mut [&mut NativeSession],
    ) -> Result<u64> {
        Ok(NativeModel::make_room(self, prompt, running)?)
    }

    fn prefix_attach(&self, sess: &mut NativeSession, prompt: &[usize]) -> usize {
        NativeModel::prefix_attach(self, sess, prompt)
    }

    fn prefix_metrics(&self) -> PrefixCacheMetrics {
        NativeModel::prefix_metrics(self)
    }

    fn prefix_cache_handle(&self) -> Option<Arc<PrefixCache>> {
        Some(self.prefix_cache().clone())
    }

    fn enforce_kv_budget(&self, running: &mut [&mut NativeSession]) -> Result<u64> {
        Ok(NativeModel::enforce_kv_budget(self, running)?)
    }

    fn weight_metrics(&self) -> WeightResidencyMetrics {
        NativeModel::weight_metrics(self)
    }

    fn compute_metrics(&self) -> ComputeBackendMetrics {
        NativeModel::compute_metrics(self)
    }
}

impl InferenceBackend for PjrtRuntime {
    type Session = KvState;

    fn max_len(&self) -> usize {
        self.manifest.model.max_len
    }

    fn new_session(&self, _req: &Request) -> Result<KvState> {
        Ok(KvState::empty())
    }

    fn prefill(&self, sess: &mut KvState, ids: &[usize]) -> Result<Vec<f32>> {
        let (logits, kv) = PjrtRuntime::prefill(self, ids)?;
        *sess = kv;
        Ok(logits)
    }

    fn decode(&self, sess: &mut KvState, tok: usize) -> Result<Vec<f32>> {
        PjrtRuntime::decode(self, tok, sess)
    }

    fn session_pos(&self, sess: &KvState) -> usize {
        sess.pos
    }

    fn release(&self, sess: &mut KvState) {
        // Host-side buffers are the session's only resource.
        *sess = KvState::empty();
    }

    fn reclaim(&self) {}
}

/// The serving backend, type-erased over the two runtimes so callers can
/// pick one at run time (`Engine<Backend>` — the default `Coordinator`).
/// Code generic over [`InferenceBackend`] can also use `NativeModel` or
/// `PjrtRuntime` directly.
pub enum Backend {
    Native(Box<NativeModel>),
    Pjrt(Box<PjrtRuntime>),
}

/// Session type for the type-erased [`Backend`].
pub enum AnySession {
    Native(NativeSession),
    Pjrt(KvState),
}

impl AnySession {
    fn native(&mut self) -> &mut NativeSession {
        match self {
            AnySession::Native(s) => s,
            AnySession::Pjrt(_) => unreachable!("pjrt session on native backend"),
        }
    }

    fn pjrt(&mut self) -> &mut KvState {
        match self {
            AnySession::Pjrt(s) => s,
            AnySession::Native(_) => unreachable!("native session on pjrt backend"),
        }
    }
}

impl Backend {
    /// The native model, when this is the native backend (e.g. to inspect
    /// the KV pool).
    pub fn as_native(&self) -> Option<&NativeModel> {
        match self {
            Backend::Native(m) => Some(m),
            Backend::Pjrt(_) => None,
        }
    }
}

impl InferenceBackend for Backend {
    type Session = AnySession;

    fn max_len(&self) -> usize {
        match self {
            Backend::Native(m) => InferenceBackend::max_len(m.as_ref()),
            Backend::Pjrt(rt) => InferenceBackend::max_len(rt.as_ref()),
        }
    }

    fn new_session(&self, req: &Request) -> Result<AnySession> {
        match self {
            Backend::Native(m) => {
                Ok(AnySession::Native(InferenceBackend::new_session(m.as_ref(), req)?))
            }
            Backend::Pjrt(rt) => {
                Ok(AnySession::Pjrt(InferenceBackend::new_session(rt.as_ref(), req)?))
            }
        }
    }

    fn prefill(&self, sess: &mut AnySession, ids: &[usize]) -> Result<Vec<f32>> {
        match self {
            Backend::Native(m) => InferenceBackend::prefill(m.as_ref(), sess.native(), ids),
            Backend::Pjrt(rt) => InferenceBackend::prefill(rt.as_ref(), sess.pjrt(), ids),
        }
    }

    fn decode(&self, sess: &mut AnySession, tok: usize) -> Result<Vec<f32>> {
        match self {
            Backend::Native(m) => InferenceBackend::decode(m.as_ref(), sess.native(), tok),
            Backend::Pjrt(rt) => InferenceBackend::decode(rt.as_ref(), sess.pjrt(), tok),
        }
    }

    fn decode_batch(
        &self,
        sessions: &mut [&mut AnySession],
        toks: &[usize],
    ) -> Result<Vec<Vec<f32>>> {
        match self {
            Backend::Native(m) => {
                let mut native: Vec<&mut NativeSession> =
                    sessions.iter_mut().map(|s| s.native()).collect();
                InferenceBackend::decode_batch(m.as_ref(), &mut native, toks)
            }
            Backend::Pjrt(rt) => {
                // The trait's default loop-over-decode fallback: PJRT has
                // no fused path, and the contract makes that pure policy.
                assert_eq!(sessions.len(), toks.len(), "one token per session");
                let mut out = Vec::with_capacity(toks.len());
                for (sess, &tok) in sessions.iter_mut().zip(toks) {
                    out.push(InferenceBackend::decode(rt.as_ref(), sess.pjrt(), tok)?);
                }
                Ok(out)
            }
        }
    }

    fn tick_limits(&self) -> TickLimits {
        match self {
            Backend::Native(m) => InferenceBackend::tick_limits(m.as_ref()),
            Backend::Pjrt(rt) => InferenceBackend::tick_limits(rt.as_ref()),
        }
    }

    fn prefill_chunk(
        &self,
        sess: &mut AnySession,
        ids: &[usize],
        last: bool,
    ) -> Result<Option<Vec<f32>>> {
        match self {
            Backend::Native(m) => {
                InferenceBackend::prefill_chunk(m.as_ref(), sess.native(), ids, last)
            }
            Backend::Pjrt(rt) => {
                InferenceBackend::prefill_chunk(rt.as_ref(), sess.pjrt(), ids, last)
            }
        }
    }

    fn step_batch(
        &self,
        sessions: &mut [&mut AnySession],
        works: &[RowWork<'_>],
    ) -> Result<Vec<RowOutcome>> {
        match self {
            Backend::Native(m) => {
                let mut native: Vec<&mut NativeSession> =
                    sessions.iter_mut().map(|s| s.native()).collect();
                InferenceBackend::step_batch(m.as_ref(), &mut native, works)
            }
            Backend::Pjrt(rt) => {
                // PjrtRuntime keeps the trait's default loop — delegate to
                // it so its per-row isolation semantics stay in one place.
                let mut pjrt: Vec<&mut KvState> =
                    sessions.iter_mut().map(|s| s.pjrt()).collect();
                InferenceBackend::step_batch(rt.as_ref(), &mut pjrt, works)
            }
        }
    }

    fn verify(&self, sess: &mut AnySession, toks: &[usize]) -> Result<Option<Vec<f32>>> {
        match self {
            Backend::Native(m) => InferenceBackend::verify(m.as_ref(), sess.native(), toks),
            Backend::Pjrt(rt) => InferenceBackend::verify(rt.as_ref(), sess.pjrt(), toks),
        }
    }

    fn truncate_kv(&self, sess: &mut AnySession, keep: usize) -> Result<()> {
        match self {
            Backend::Native(m) => InferenceBackend::truncate_kv(m.as_ref(), sess.native(), keep),
            Backend::Pjrt(rt) => InferenceBackend::truncate_kv(rt.as_ref(), sess.pjrt(), keep),
        }
    }

    fn supports_speculation(&self) -> bool {
        match self {
            Backend::Native(m) => InferenceBackend::supports_speculation(m.as_ref()),
            Backend::Pjrt(rt) => InferenceBackend::supports_speculation(rt.as_ref()),
        }
    }

    fn verify_reserve_bytes(&self, depth: usize) -> usize {
        match self {
            Backend::Native(m) => InferenceBackend::verify_reserve_bytes(m.as_ref(), depth),
            Backend::Pjrt(rt) => InferenceBackend::verify_reserve_bytes(rt.as_ref(), depth),
        }
    }

    fn prefill_reserve_bytes(&self, prompt: &[usize]) -> usize {
        match self {
            Backend::Native(m) => InferenceBackend::prefill_reserve_bytes(m.as_ref(), prompt),
            Backend::Pjrt(rt) => InferenceBackend::prefill_reserve_bytes(rt.as_ref(), prompt),
        }
    }

    fn prefill_visible_bytes(&self, prompt: &[usize], consumed: usize) -> usize {
        match self {
            Backend::Native(m) => {
                InferenceBackend::prefill_visible_bytes(m.as_ref(), prompt, consumed)
            }
            Backend::Pjrt(rt) => {
                InferenceBackend::prefill_visible_bytes(rt.as_ref(), prompt, consumed)
            }
        }
    }

    fn kv_headroom(&self) -> usize {
        match self {
            Backend::Native(m) => InferenceBackend::kv_headroom(m.as_ref()),
            Backend::Pjrt(rt) => InferenceBackend::kv_headroom(rt.as_ref()),
        }
    }

    fn session_pos(&self, sess: &AnySession) -> usize {
        match sess {
            AnySession::Native(s) => s.pos,
            AnySession::Pjrt(s) => s.pos,
        }
    }

    fn release(&self, sess: &mut AnySession) {
        match self {
            Backend::Native(m) => InferenceBackend::release(m.as_ref(), sess.native()),
            Backend::Pjrt(rt) => InferenceBackend::release(rt.as_ref(), sess.pjrt()),
        }
    }

    fn reclaim(&self) {
        match self {
            Backend::Native(m) => InferenceBackend::reclaim(m.as_ref()),
            Backend::Pjrt(rt) => InferenceBackend::reclaim(rt.as_ref()),
        }
    }

    fn kv_counters(&self, sess: &AnySession) -> (u64, u64) {
        match (self, sess) {
            (Backend::Native(m), AnySession::Native(s)) => {
                InferenceBackend::kv_counters(m.as_ref(), s)
            }
            _ => (0, 0),
        }
    }

    fn make_room(
        &self,
        prompt: &[usize],
        running: &mut [&mut AnySession],
    ) -> Result<u64> {
        match self {
            Backend::Native(m) => {
                let mut native: Vec<&mut NativeSession> =
                    running.iter_mut().map(|s| s.native()).collect();
                InferenceBackend::make_room(m.as_ref(), prompt, &mut native)
            }
            Backend::Pjrt(_) => Ok(0),
        }
    }

    fn prefix_attach(&self, sess: &mut AnySession, prompt: &[usize]) -> usize {
        match self {
            Backend::Native(m) => {
                InferenceBackend::prefix_attach(m.as_ref(), sess.native(), prompt)
            }
            Backend::Pjrt(_) => 0,
        }
    }

    fn prefix_metrics(&self) -> PrefixCacheMetrics {
        match self {
            Backend::Native(m) => NativeModel::prefix_metrics(m),
            Backend::Pjrt(_) => PrefixCacheMetrics::default(),
        }
    }

    fn prefix_cache_handle(&self) -> Option<Arc<PrefixCache>> {
        match self {
            Backend::Native(m) => Some(m.prefix_cache().clone()),
            Backend::Pjrt(_) => None,
        }
    }

    fn enforce_kv_budget(&self, running: &mut [&mut AnySession]) -> Result<u64> {
        match self {
            Backend::Native(m) => {
                let mut native: Vec<&mut NativeSession> =
                    running.iter_mut().map(|s| s.native()).collect();
                InferenceBackend::enforce_kv_budget(m.as_ref(), &mut native)
            }
            Backend::Pjrt(_) => Ok(0),
        }
    }

    fn weight_metrics(&self) -> WeightResidencyMetrics {
        match self {
            Backend::Native(m) => NativeModel::weight_metrics(m),
            Backend::Pjrt(_) => WeightResidencyMetrics::default(),
        }
    }

    fn compute_metrics(&self) -> ComputeBackendMetrics {
        match self {
            Backend::Native(m) => NativeModel::compute_metrics(m),
            Backend::Pjrt(_) => ComputeBackendMetrics::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::fixtures;
    use crate::model::native::EngineOptions;

    #[test]
    fn native_model_implements_the_trait_directly() {
        // The trait surface alone is enough to run a request end to end —
        // what the generic engine relies on.
        let (_fx, m) = fixtures::native_model(7, EngineOptions::default()).unwrap();
        let req = Request::new(1, vec![5, 6, 7], 4);
        let cap = InferenceBackend::max_len(&m);
        assert!(cap > 0);
        let mut sess = InferenceBackend::new_session(&m, &req).unwrap();
        let logits = InferenceBackend::prefill(&m, &mut sess, &req.prompt).unwrap();
        assert_eq!(InferenceBackend::session_pos(&m, &sess), 3);
        let tok = crate::model::sampler::argmax(&logits);
        let _ = InferenceBackend::decode(&m, &mut sess, tok).unwrap();
        assert_eq!(InferenceBackend::session_pos(&m, &sess), 4);
        InferenceBackend::release(&m, &mut sess);
        assert_eq!(sess.resident_kv_bytes(), 0);
        drop(sess);
        InferenceBackend::reclaim(&m);
        assert_eq!(m.spill_store_bytes(), 0);
    }

    #[test]
    fn erased_backend_matches_direct_native_calls() {
        let (_fx, m1) = fixtures::native_model(7, EngineOptions::default()).unwrap();
        let (_fx2, m2) = fixtures::native_model(7, EngineOptions::default()).unwrap();
        let req = Request::new(1, vec![10, 20, 30], 4);
        let direct = {
            let mut s = InferenceBackend::new_session(&m1, &req).unwrap();
            InferenceBackend::prefill(&m1, &mut s, &req.prompt).unwrap()
        };
        let be = Backend::Native(Box::new(m2));
        let erased = {
            let mut s = be.new_session(&req).unwrap();
            be.prefill(&mut s, &req.prompt).unwrap()
        };
        assert_eq!(direct, erased, "type erasure must not change numbers");
        assert!(be.as_native().is_some());
    }
}
