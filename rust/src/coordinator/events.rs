//! Typed engine events and per-request token streams — the observable
//! surface of the step()-based serving API.
//!
//! Every submitted request produces exactly one **terminal** event
//! ([`EngineEvent::Finished`], [`EngineEvent::Cancelled`],
//! [`EngineEvent::Rejected`] or [`EngineEvent::Failed`]); tokens are
//! emitted in decode order as
//! [`EngineEvent::Token`] the moment the scheduler produces them, not at
//! drain time. Callers observe events globally (`Engine::next_event` /
//! `Engine::drain_events`) or per request through a [`TokenStream`]
//! handle returned by `Engine::submit_streaming`; routing is exclusive —
//! a streaming request's events go to its handle only, so handle-driven
//! consumers never grow the engine-wide queue.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use crate::coordinator::request::RequestId;
use crate::util::sync::lock_tolerant;

/// Why a request finished normally.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// The model emitted the tokenizer's EOS token.
    Eos,
    /// A token in `Request::stop_tokens` was generated.
    StopToken,
    /// The generated tail matched one of `Request::stop_sequences`.
    StopSequence,
    /// `Request::max_new_tokens` (clamped by the context cap) was reached.
    MaxTokens,
    /// The backend's context window is full.
    ContextCap,
}

/// One scheduler-observable event. `Token::index` counts generated tokens
/// from 0; `ttft_s` is set only on the first token (arrival → first token).
///
/// Ordering under fused ticks: a tick computes its rows in **one**
/// `step_batch` call, then emits each row's events one request at a time
/// in the tick's row order — admission order when every active session is
/// served (the default), window order when `max_rows_per_tick` rotates a
/// subset, with a request's `Started` + first `Token` landing among the
/// tick's other rows' events once its final prefill chunk completes.
/// Per-request streams are always in order (`index` is consecutive from
/// 0); cross-request interleaving within a tick is a scheduling detail,
/// not a contract.
#[derive(Clone, Debug, PartialEq)]
pub enum EngineEvent {
    /// The request was admitted and its prefill completed.
    Started { id: RequestId },
    /// One generated token, in decode order.
    Token { id: RequestId, tok: usize, index: usize, ttft_s: Option<f64> },
    /// Terminal: the request completed; its `Response` is available.
    Finished { id: RequestId, reason: FinishReason },
    /// Terminal: the request was cancelled (queued or mid-decode).
    Cancelled { id: RequestId },
    /// Terminal: the request could not be admitted (e.g. empty prompt, or
    /// a prompt that cannot fit the context window at all).
    Rejected { id: RequestId, reason: String },
    /// Terminal: the backend failed while serving the request (prefill or
    /// decode error). The session's memory — KV pool pages and flash
    /// spill — has been released; the engine keeps serving other
    /// requests.
    Failed { id: RequestId, reason: String },
}

impl EngineEvent {
    /// The request this event belongs to.
    pub fn id(&self) -> RequestId {
        match self {
            EngineEvent::Started { id }
            | EngineEvent::Token { id, .. }
            | EngineEvent::Finished { id, .. }
            | EngineEvent::Cancelled { id }
            | EngineEvent::Rejected { id, .. }
            | EngineEvent::Failed { id, .. } => *id,
        }
    }

    /// True for events that end a request's lifecycle. Every submitted id
    /// receives exactly one terminal event.
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            EngineEvent::Finished { .. }
                | EngineEvent::Cancelled { .. }
                | EngineEvent::Rejected { .. }
                | EngineEvent::Failed { .. }
        )
    }
}

#[derive(Default)]
pub(crate) struct StreamInner {
    pub(crate) events: VecDeque<EngineEvent>,
    /// Set when a terminal event has been delivered into `events`.
    pub(crate) terminal_seen: bool,
}

/// A per-request handle over the engine's event flow: the engine routes
/// every event for this request id here (instead of the engine-wide
/// queue) as it steps; the caller drains with [`TokenStream::try_next`]
/// between `Engine::step` calls. Purely pull-based — no threads, no
/// async runtime.
pub struct TokenStream {
    id: RequestId,
    pub(crate) inner: Arc<Mutex<StreamInner>>,
}

impl TokenStream {
    pub(crate) fn new(id: RequestId, inner: Arc<Mutex<StreamInner>>) -> Self {
        TokenStream { id, inner }
    }

    pub fn id(&self) -> RequestId {
        self.id
    }

    /// Next undelivered event for this request, if any. Poison-tolerant:
    /// event queues hold plain data, so a panic elsewhere never wedges the
    /// consumer side of a stream.
    pub fn try_next(&self) -> Option<EngineEvent> {
        lock_tolerant(&self.inner).events.pop_front()
    }

    /// True once the terminal event has been queued (there may still be
    /// undrained events before it).
    pub fn finished(&self) -> bool {
        lock_tolerant(&self.inner).terminal_seen
    }

    /// True when the terminal event has been queued *and* every event has
    /// been drained.
    pub fn drained(&self) -> bool {
        let g = lock_tolerant(&self.inner);
        g.terminal_seen && g.events.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_ids_and_terminality() {
        let evs = [
            EngineEvent::Started { id: 3 },
            EngineEvent::Token { id: 3, tok: 7, index: 0, ttft_s: Some(0.1) },
            EngineEvent::Finished { id: 3, reason: FinishReason::MaxTokens },
            EngineEvent::Cancelled { id: 3 },
            EngineEvent::Rejected { id: 3, reason: "no".into() },
            EngineEvent::Failed { id: 3, reason: "backend".into() },
        ];
        for e in &evs {
            assert_eq!(e.id(), 3);
        }
        assert!(!evs[0].is_terminal());
        assert!(!evs[1].is_terminal());
        assert!(evs[2].is_terminal());
        assert!(evs[3].is_terminal());
        assert!(evs[4].is_terminal());
        assert!(evs[5].is_terminal());
    }

    #[test]
    fn stream_delivers_in_order_and_tracks_terminal() {
        let inner = Arc::new(Mutex::new(StreamInner::default()));
        let s = TokenStream::new(9, inner.clone());
        assert!(!s.finished());
        assert_eq!(s.try_next(), None);
        {
            let mut g = inner.lock().unwrap();
            g.events.push_back(EngineEvent::Started { id: 9 });
            g.events
                .push_back(EngineEvent::Token { id: 9, tok: 1, index: 0, ttft_s: Some(0.5) });
            g.events
                .push_back(EngineEvent::Finished { id: 9, reason: FinishReason::Eos });
            g.terminal_seen = true;
        }
        assert!(s.finished());
        assert!(!s.drained());
        assert_eq!(s.try_next(), Some(EngineEvent::Started { id: 9 }));
        assert!(matches!(s.try_next(), Some(EngineEvent::Token { index: 0, .. })));
        assert!(matches!(s.try_next(), Some(EngineEvent::Finished { .. })));
        assert!(s.drained());
    }

    #[test]
    fn poisoned_stream_lock_keeps_delivering() {
        // Regression: try_next()/finished()/drained() used lock().unwrap(),
        // so one panicking producer thread bricked the consumer side.
        let inner = Arc::new(Mutex::new(StreamInner::default()));
        let s = TokenStream::new(4, inner.clone());
        let i2 = inner.clone();
        let _ = std::thread::spawn(move || {
            let mut g = i2.lock().unwrap();
            g.events.push_back(EngineEvent::Started { id: 4 });
            g.terminal_seen = true;
            panic!("poison while holding the stream lock");
        })
        .join();
        assert!(inner.is_poisoned());
        assert!(s.finished());
        assert_eq!(s.try_next(), Some(EngineEvent::Started { id: 4 }));
        assert!(s.drained());
    }
}
