//! Byte-level tokenizer (DESIGN.md §Substitutions: no pretrained BPE
//! vocabulary offline, and the model weights are random anyway — the paper
//! measures speed, not text quality). Token ids 0..255 are raw bytes;
//! 256.. are reserved special ids; the rest of the vocab is unused.

pub const BOS: usize = 256;
pub const EOS: usize = 257;
pub const FIRST_UNUSED: usize = 258;

/// Stateless byte tokenizer bounded by the model vocab.
#[derive(Clone, Debug)]
pub struct ByteTokenizer {
    pub vocab: usize,
}

impl ByteTokenizer {
    pub fn new(vocab: usize) -> Self {
        assert!(vocab >= FIRST_UNUSED, "vocab must cover bytes + specials");
        ByteTokenizer { vocab }
    }

    /// Encode text (optionally wrapped in BOS).
    pub fn encode(&self, text: &str, add_bos: bool) -> Vec<usize> {
        let mut out = Vec::with_capacity(text.len() + 1);
        if add_bos {
            out.push(BOS);
        }
        out.extend(text.bytes().map(|b| b as usize));
        out
    }

    /// Decode ids back to text (specials and out-of-byte ids are skipped —
    /// random-weight models emit arbitrary ids).
    pub fn decode(&self, ids: &[usize]) -> String {
        let bytes: Vec<u8> = ids.iter().filter(|&&t| t < 256).map(|&t| t as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    pub fn is_eos(&self, id: usize) -> bool {
        id == EOS
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer::new(2048);
        let ids = t.encode("hello", false);
        assert_eq!(ids, vec![104, 101, 108, 108, 111]);
        assert_eq!(t.decode(&ids), "hello");
    }

    #[test]
    fn bos_prepended() {
        let t = ByteTokenizer::new(2048);
        let ids = t.encode("a", true);
        assert_eq!(ids, vec![BOS, 97]);
        assert_eq!(t.decode(&ids), "a");
    }

    #[test]
    fn utf8_roundtrip() {
        let t = ByteTokenizer::new(2048);
        let s = "héllo ☃";
        assert_eq!(t.decode(&t.encode(s, false)), s);
    }

    #[test]
    fn skips_non_byte_ids() {
        let t = ByteTokenizer::new(2048);
        assert_eq!(t.decode(&[104, 1000, 105]), "hi");
    }

    #[test]
    #[should_panic(expected = "vocab")]
    fn tiny_vocab_rejected() {
        ByteTokenizer::new(100);
    }
}
