//! Model substrate: configs, artifact manifest, weight container, byte
//! tokenizer, sampler, and the native (pure-Rust) execution engine that
//! exercises the paper's CPU optimizations end-to-end.

pub mod config;
pub mod fixtures;
pub mod graph;
pub mod manifest;
pub mod native;
pub mod sampler;
pub mod tokenizer;
pub mod weights;

pub use config::ModelConfig;
pub use manifest::Manifest;
pub use native::{NativeModel, NativeSession};
pub use tokenizer::ByteTokenizer;
pub use weights::WeightFile;
