//! artifacts/manifest.json parser (emitted by python/compile/aot.py).

use std::path::{Path, PathBuf};

use crate::model::config::ModelConfig;
use crate::util::json::Json;

/// One tensor entry in weights.bin.
#[derive(Clone, Debug)]
pub struct WeightEntry {
    pub name: String,
    /// dtype code: 0=f32, 1=i8, 2=u8, 3=bf16, 4=i32.
    pub dtype: u8,
    pub shape: Vec<usize>,
    pub nbytes: usize,
}

/// One lowered graph.
#[derive(Clone, Debug)]
pub struct GraphEntry {
    pub key: String,
    pub file: String,
    pub args: Vec<String>,
    pub results: Vec<String>,
    /// Prefill bucket length (None for decode).
    pub bucket: Option<usize>,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelConfig,
    pub prefill_buckets: Vec<usize>,
    pub weights: Vec<WeightEntry>,
    pub graphs: Vec<GraphEntry>,
    pub embedding_file: String,
    pub seed: u64,
}

fn err(msg: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, format!("manifest: {msg}"))
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> std::io::Result<Manifest> {
        let src = std::fs::read_to_string(dir.join("manifest.json"))?;
        let j = Json::parse(&src).map_err(|e| err(&e.to_string()))?;
        let m = j.get("model").ok_or_else(|| err("missing model"))?;
        let get_usize = |k: &str| -> std::io::Result<usize> {
            m.get(k).and_then(Json::as_usize).ok_or_else(|| err(k))
        };
        let model = ModelConfig {
            name: m.get("name").and_then(Json::as_str).ok_or_else(|| err("name"))?.to_string(),
            vocab: get_usize("vocab")?,
            hidden: get_usize("hidden")?,
            inter: get_usize("inter")?,
            layers: get_usize("layers")?,
            heads: get_usize("heads")?,
            kv_heads: get_usize("kv_heads")?,
            max_len: get_usize("max_len")?,
            rope_theta: m.get("rope_theta").and_then(Json::as_f64).unwrap_or(1e4),
            rms_eps: m.get("rms_eps").and_then(Json::as_f64).unwrap_or(1e-6) as f32,
        };
        let prefill_buckets = j
            .get("prefill_buckets")
            .and_then(Json::as_arr)
            .ok_or_else(|| err("prefill_buckets"))?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let weights = j
            .get("weights")
            .and_then(Json::as_arr)
            .ok_or_else(|| err("weights"))?
            .iter()
            .map(|w| -> std::io::Result<WeightEntry> {
                Ok(WeightEntry {
                    name: w.get("name").and_then(Json::as_str).ok_or_else(|| err("w.name"))?.into(),
                    dtype: w.get("dtype").and_then(Json::as_usize).ok_or_else(|| err("w.dtype"))? as u8,
                    shape: w
                        .get("shape")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| err("w.shape"))?
                        .iter()
                        .filter_map(Json::as_usize)
                        .collect(),
                    nbytes: w.get("nbytes").and_then(Json::as_usize).ok_or_else(|| err("w.nbytes"))?,
                })
            })
            .collect::<std::io::Result<Vec<_>>>()?;
        let graphs = j
            .get("graphs")
            .and_then(Json::as_obj)
            .ok_or_else(|| err("graphs"))?
            .iter()
            .map(|(key, g)| -> std::io::Result<GraphEntry> {
                let strs = |k: &str| -> std::io::Result<Vec<String>> {
                    Ok(g.get(k)
                        .and_then(Json::as_arr)
                        .ok_or_else(|| err(k))?
                        .iter()
                        .filter_map(Json::as_str)
                        .map(String::from)
                        .collect())
                };
                Ok(GraphEntry {
                    key: key.clone(),
                    file: g.get("file").and_then(Json::as_str).ok_or_else(|| err("g.file"))?.into(),
                    args: strs("args")?,
                    results: strs("results")?,
                    bucket: g.get("bucket").and_then(Json::as_usize),
                })
            })
            .collect::<std::io::Result<Vec<_>>>()?;
        let embedding_file = j
            .path(&["embedding", "file"])
            .and_then(Json::as_str)
            .ok_or_else(|| err("embedding.file"))?
            .to_string();
        Ok(Manifest {
            dir: dir.to_path_buf(),
            model,
            prefill_buckets,
            weights,
            graphs,
            embedding_file,
            seed: j.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64,
        })
    }

    pub fn graph(&self, key: &str) -> Option<&GraphEntry> {
        self.graphs.iter().find(|g| g.key == key)
    }

    /// Smallest prefill bucket ≥ `len` (or the largest if none fit).
    pub fn bucket_for(&self, len: usize) -> usize {
        self.prefill_buckets
            .iter()
            .copied()
            .filter(|&b| b >= len)
            .min()
            .unwrap_or_else(|| self.prefill_buckets.iter().copied().max().unwrap_or(len))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn parses_real_manifest() {
        let Some(dir) = artifacts_dir() else { return };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model.name, "tiny-qwen2");
        assert_eq!(m.model.vocab, 2048);
        assert!(!m.prefill_buckets.is_empty());
        assert!(m.graph("decode").is_some());
        for b in &m.prefill_buckets {
            assert!(m.graph(&format!("prefill_{b}")).is_some());
        }
        // Weight table order must match graph arg suffix.
        let names: Vec<&str> = m.weights.iter().map(|w| w.name.as_str()).collect();
        let decode = m.graph("decode").unwrap();
        assert_eq!(&decode.args[decode.args.len() - names.len()..], &names[..]);
    }

    #[test]
    fn bucket_selection() {
        let Some(dir) = artifacts_dir() else { return };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.bucket_for(1), 16);
        assert_eq!(m.bucket_for(16), 16);
        assert_eq!(m.bucket_for(17), 64);
        assert_eq!(m.bucket_for(900), 256, "falls back to largest");
    }
}
