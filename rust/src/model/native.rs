//! Native pure-Rust execution engine: the paper's optimized CPU pipeline.
//!
//! Composes every §4/§5 mechanism end-to-end:
//! * combined quantization — int8 attention/lm_head, int4 MLP, dynamic int8
//!   activations (weights arrive pre-quantized from artifacts/weights.bin);
//! * hardware-driven reorder — weights repacked at load for the detected
//!   ISA's solved tile (§5.1);
//! * flash-resident bf16 embedding + KV spill with prefetch (§4.1);
//! * layer-granular **weight residency** (§4.1, the weight half):
//!   `weights.bin` is streamed onto flash at load (never fully in DRAM),
//!   each layer is packed into a relocatable blob, and forward passes pull
//!   layers through a byte-budgeted LRU arena
//!   ([`EngineOptions::weight_dram_bytes`]) with async one-layer-ahead
//!   prefetch — bit-identical at any budget;
//! * multicore balanced GEMM splits (§5.2);
//! * fp32 softmax + pre-scaled queries (§5.3);
//! * per-request LoRA bypass in the associative order (§5.5).
//!
//! Ownership: the model is **stateless over sessions**. All per-request
//! state — the paged KV cache, the position counter, the selected LoRA
//! task — lives in a [`NativeSession`] created by
//! [`NativeModel::new_session`]. Sessions draw KV pages from the model's
//! shared [`KvPool`] (budgeted via [`EngineOptions::kv_pool_bytes`]) and
//! spill to the model's shared flash device under pressure, which is what
//! lets the coordinator interleave decode across concurrent requests
//! (continuous batching) on this backend.

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::cpu::activation::{add_inplace, rmsnorm, swiglu};
use crate::cpu::attention::prefill_attention;
use crate::cpu::gemm_q::QLinear;
use crate::device::SocProfile;
use crate::kv::{EvictionPolicy, KvPool, PAGE_TOKENS};
use crate::lora::LoraManager;
use crate::memory::embedding::FlashEmbedding;
use crate::memory::flash::FlashSim;
use crate::memory::hybrid::HybridKvLayer;
use crate::memory::weight_store::{
    FlashTensorStore, LayerWeights, WeightResidencyMetrics, WeightStore, WeightStoreBuilder,
};
use crate::model::config::ModelConfig;
use crate::model::manifest::Manifest;
use crate::model::weights::{DT_I8, DT_U8};
use crate::parallel::pool::{run_balanced, BackgroundWorker, WorkerConfig};
use crate::quant::asym::{QuantizedMatrix, WeightBits};
use crate::reorder::solver::TileConfig;

/// Tokens per flash chunk when streaming spilled KV through attention.
pub const KV_STREAM_CHUNK: usize = 32;

/// Engine construction options.
#[derive(Clone, Debug)]
pub struct EngineOptions {
    pub tile: TileConfig,
    pub workers: WorkerConfig,
    /// Per-layer DRAM budget for KV, in tokens, before spilling to flash.
    pub kv_budget_tokens: usize,
    /// Byte budget of the shared KV page pool across *all* sessions and
    /// layers. Under pressure, appends evict to flash and the coordinator
    /// preempts sessions instead of admitting past the budget.
    pub kv_pool_bytes: usize,
    /// DRAM byte budget for packed transformer-layer weights. Layers
    /// beyond the budget live on flash as relocatable blobs and are
    /// fetched — one layer ahead, asynchronously — during forward;
    /// `usize::MAX` (the default) keeps every layer resident. The lm_head,
    /// final norm and embedding are pinned outside the budget. Residency
    /// is bit-exact value-neutral at any budget.
    pub weight_dram_bytes: usize,
    /// If false, the embedding is copied to DRAM (baseline configuration).
    pub embedding_in_flash: bool,
    /// Who sheds KV when concurrent sessions exceed the pool byte budget:
    /// the appending layer itself (`ShedSelf`, the default), or the
    /// engine's cross-session largest-holder pass between scheduler ticks
    /// (`LargestHolder`, see [`NativeModel::enforce_kv_budget`]). Both are
    /// bit-exact value-neutral; only who pays the flash traffic changes.
    pub eviction: EvictionPolicy,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            tile: crate::reorder::solver::solve_tiles(&crate::reorder::isa::detect_host()),
            workers: WorkerConfig::uniform(1),
            kv_budget_tokens: usize::MAX / 2,
            kv_pool_bytes: usize::MAX,
            weight_dram_bytes: usize::MAX,
            embedding_in_flash: true,
            eviction: EvictionPolicy::ShedSelf,
        }
    }
}

/// Per-request generation state: paged KV (one hybrid layer per decoder
/// layer), position, and the request's LoRA task. Created by
/// [`NativeModel::new_session`]; dropping it returns every KV page to the
/// model's pool.
pub struct NativeSession {
    pub kv: Vec<HybridKvLayer>,
    /// Positions generated so far (== sequence length).
    pub pos: usize,
    /// Select a loaded LoRA task for this session (§5.5 multitask).
    pub lora_task: Option<String>,
    /// Decrements the model's live-session count on drop (gates flash
    /// spill-store reclamation).
    _live: SessionGuard,
}

struct SessionGuard(Arc<AtomicUsize>);

impl Drop for SessionGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

impl NativeSession {
    /// Cached sequence length (uniform across layers by construction).
    pub fn kv_len(&self) -> usize {
        self.kv.first().map_or(0, |l| l.len())
    }

    /// Pool-accounted DRAM bytes of this session's resident KV.
    pub fn resident_kv_bytes(&self) -> usize {
        self.kv.iter().map(|l| l.resident_kv_bytes()).sum()
    }

    /// Records this session ever spilled to flash.
    pub fn spilled_records(&self) -> u64 {
        self.kv.iter().map(|l| l.spill_count()).sum()
    }

    /// Records this session ever restored from flash.
    pub fn restored_records(&self) -> u64 {
        self.kv.iter().map(|l| l.restore_count()).sum()
    }

    /// Terminal release of all KV (pool pages and spilled flash offsets):
    /// call once the session has produced its last token, so finished
    /// requests stop pressuring live ones. Spill/restore counters survive.
    pub fn release_kv(&mut self) {
        for l in &mut self.kv {
            l.release();
        }
    }

    /// Preempt: push every resident KV record to flash and release all
    /// pages. Value-neutral — decode resumes via the streaming path.
    /// Returns records spilled.
    pub fn preempt_to_flash(&mut self) -> std::io::Result<usize> {
        let mut n = 0;
        for l in &mut self.kv {
            n += l.spill_all()?;
        }
        Ok(n)
    }

    /// Spill up to `records_per_layer` of the oldest resident records from
    /// *every* layer (KV grows uniformly across layers, so uniform
    /// shedding is the natural eviction unit). Returns total records
    /// spilled; 0 means nothing was resident. Value-neutral.
    pub fn shed_oldest(&mut self, records_per_layer: usize) -> std::io::Result<usize> {
        let mut n = 0;
        for l in &mut self.kv {
            n += l.shed_oldest(records_per_layer)?;
        }
        Ok(n)
    }
}

/// A loaded model (weights, embedding, LoRA bank, shared KV pool + flash).
/// Stateless over sessions: all forward methods take a [`NativeSession`].
pub struct NativeModel {
    pub config: ModelConfig,
    pub options: EngineOptions,
    /// Declared before `weights` so drop order joins in-flight prefetch
    /// jobs while the store they reference is still alive.
    prefetcher: BackgroundWorker,
    /// Layer-residency arena over flash-resident packed blobs. The
    /// lm_head, final norm and embedding below are pinned outside it.
    weights: WeightStore,
    fnorm: Vec<f32>,
    lm_head: QLinear,
    embedding: FlashEmbedding,
    embedding_dram: Option<Vec<f32>>,
    pub lora: LoraManager,
    /// Shared flash device all sessions spill KV to. Distinct from the
    /// weight store's device: `reclaim_flash` truncates this one, which
    /// must never eat weight blobs.
    flash: Arc<FlashSim>,
    /// Shared paged-KV arena all sessions draw from.
    kv_pool: Arc<KvPool>,
    /// Live sessions (spill-store reclamation is only safe at zero).
    live_sessions: Arc<AtomicUsize>,
    /// θ^(-2i/d) — kept for positions past `max_len` (rare overrun guard).
    inv_freq: Vec<f32>,
    /// Precomputed RoPE tables, `[max_len, head_dim/2]` row-major: paid
    /// once at load instead of a `powf`-derived `sin_cos` per element per
    /// token in the decode hot loop. Entries are computed exactly as the
    /// on-the-fly path did (`sin_cos(pos · inv_freq[i])`), so the lookup
    /// is bit-identical to recomputation.
    rope_sin: Vec<f32>,
    rope_cos: Vec<f32>,
}

fn invalid(msg: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, format!("weights.bin: {msg}"))
}

fn qlin(
    store: &FlashTensorStore,
    name: &str,
    bits: WeightBits,
    tile: TileConfig,
    bias: Option<Vec<f32>>,
) -> std::io::Result<QLinear> {
    let q = store.read(&format!("{name}.q"))?;
    let s = store.read(&format!("{name}.s"))?;
    let b = store.read(&format!("{name}.b"))?;
    if q.shape.len() != 2 {
        return Err(invalid(&format!("{name}: expected 2-D weights, shape {:?}", q.shape)));
    }
    let (n, k) = match bits {
        WeightBits::Int8 => {
            if q.dtype != DT_I8 {
                return Err(invalid(&format!("{name}: expected i8 weights")));
            }
            (q.shape[0], q.shape[1])
        }
        WeightBits::Int4 => {
            if q.dtype != DT_U8 {
                return Err(invalid(&format!("{name}: expected packed u8 weights")));
            }
            (q.shape[0], q.shape[1] * 2)
        }
    };
    let scales = s.try_f32()?;
    let biases = b.try_f32()?;
    if scales.len() != n || biases.len() != n {
        return Err(invalid(&format!(
            "{name}: {} scales / {} biases for {n} output rows",
            scales.len(),
            biases.len()
        )));
    }
    let qm = QuantizedMatrix::from_parts(bits, n, k, q.data, &scales, &biases);
    Ok(QLinear::new(&qm, tile, bias))
}

/// Stream a bf16 table file into an f32 DRAM table in bounded chunks (the
/// baseline embedding config — no transient second copy of the table).
fn read_bf16_table(path: &Path, elems: usize) -> std::io::Result<Vec<f32>> {
    const CHUNK_ELEMS: usize = 128 << 10;
    let file = std::fs::File::open(path)?;
    let have = file.metadata()?.len();
    if have != (elems * 2) as u64 {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("{}: {have} bytes, expected {}", path.display(), elems * 2),
        ));
    }
    let mut r = std::io::BufReader::new(file);
    let mut table = vec![0f32; elems];
    let mut buf = vec![0u8; CHUNK_ELEMS * 2];
    let mut done = 0usize;
    while done < elems {
        let n = (elems - done).min(CHUNK_ELEMS);
        std::io::Read::read_exact(&mut r, &mut buf[..n * 2])?;
        crate::util::bf16::bytes_to_f32(&buf[..n * 2], &mut table[done..done + n]);
        done += n;
    }
    Ok(table)
}

impl NativeModel {
    /// Load from an artifacts directory (manifest + weights + embedding).
    ///
    /// The weight path is fully streaming: `weights.bin` goes file → flash
    /// in bounded chunks, layers are packed one at a time into blobs, and
    /// at most [`EngineOptions::weight_dram_bytes`] of packed layers stay
    /// resident — peak load DRAM is one layer's tensors plus the budget,
    /// never two copies of the weights.
    pub fn load(dir: &Path, options: EngineOptions) -> std::io::Result<NativeModel> {
        let manifest = Manifest::load(dir)?;
        let cfg = manifest.model.clone();
        let tile = options.tile;
        let soc = SocProfile::snapdragon_8gen3();
        // Raw tensors are staged on their own device, dropped after
        // packing; only the packed blobs live on the long-lived weight
        // device — the model doesn't carry the raw container around.
        let staging_flash = Arc::new(FlashSim::temp(soc.flash)?);
        let store =
            FlashTensorStore::stream_from_file(&dir.join("weights.bin"), staging_flash)?;
        let weight_flash = Arc::new(FlashSim::temp(soc.flash)?);
        let mut builder = WeightStoreBuilder::new(weight_flash, options.weight_dram_bytes);
        for i in 0..cfg.layers {
            let p = format!("L{i}.");
            let layer = LayerWeights {
                wq: qlin(&store, &format!("{p}wq"), WeightBits::Int8, tile,
                         Some(store.read(&format!("{p}bq"))?.try_f32()?))?,
                wk: qlin(&store, &format!("{p}wk"), WeightBits::Int8, tile,
                         Some(store.read(&format!("{p}bk"))?.try_f32()?))?,
                wv: qlin(&store, &format!("{p}wv"), WeightBits::Int8, tile,
                         Some(store.read(&format!("{p}bv"))?.try_f32()?))?,
                wo: qlin(&store, &format!("{p}wo"), WeightBits::Int8, tile, None)?,
                gate: qlin(&store, &format!("{p}gate"), WeightBits::Int4, tile, None)?,
                up: qlin(&store, &format!("{p}up"), WeightBits::Int4, tile, None)?,
                down: qlin(&store, &format!("{p}down"), WeightBits::Int4, tile, None)?,
                ln1: store.read(&format!("{p}ln1"))?.try_f32()?,
                ln2: store.read(&format!("{p}ln2"))?.try_f32()?,
            };
            builder.push_layer(layer)?;
        }
        let weights = builder.finish();
        let fnorm = store.read("fnorm")?.try_f32()?;
        let lm_head = qlin(&store, "lm_head", WeightBits::Int8, tile, None)?;
        drop(store);
        let flash = Arc::new(FlashSim::temp(soc.flash)?);
        let embedding = FlashEmbedding::from_file(
            &dir.join(&manifest.embedding_file),
            cfg.vocab,
            cfg.hidden,
            FlashSim::temp(soc.flash)?,
        )?;
        let embedding_dram = if options.embedding_in_flash {
            None
        } else {
            // Baseline: decode-path DRAM residency.
            Some(read_bf16_table(&dir.join(&manifest.embedding_file), cfg.vocab * cfg.hidden)?)
        };
        let kv_pool = Arc::new(KvPool::new(options.kv_pool_bytes));
        let half = cfg.head_dim() / 2;
        let inv_freq: Vec<f32> = (0..half)
            .map(|i| (1.0 / cfg.rope_theta.powf(i as f64 / half as f64)) as f32)
            .collect();
        let mut rope_sin = vec![0f32; cfg.max_len * half];
        let mut rope_cos = vec![0f32; cfg.max_len * half];
        for pos in 0..cfg.max_len {
            for (i, &f) in inv_freq.iter().enumerate() {
                let (s, c) = (pos as f32 * f).sin_cos();
                rope_sin[pos * half + i] = s;
                rope_cos[pos * half + i] = c;
            }
        }
        Ok(NativeModel {
            config: cfg,
            options,
            prefetcher: BackgroundWorker::new("mnn-weight-prefetch"),
            weights,
            fnorm,
            lm_head,
            embedding,
            embedding_dram,
            lora: LoraManager::new(),
            flash,
            kv_pool,
            live_sessions: Arc::new(AtomicUsize::new(0)),
            inv_freq,
            rope_sin,
            rope_cos,
        })
    }

    /// The shared paged-KV arena (admission control consults its budget).
    pub fn kv_pool(&self) -> &Arc<KvPool> {
        &self.kv_pool
    }

    /// Page-granular KV bytes a prompt of `len` tokens will pin across all
    /// layers — what admission control must budget for, since the pool
    /// allocates whole [`PAGE_TOKENS`]-record pages per layer (record-level
    /// byte math would under-estimate pinned DRAM).
    pub fn prefill_kv_page_bytes(&self, len: usize) -> usize {
        let cfg = &self.config;
        let pages = len.div_ceil(PAGE_TOKENS);
        cfg.layers * pages * KvPool::page_bytes(cfg.kv_heads, cfg.head_dim())
    }

    /// Bytes currently held by the shared KV spill store (flash tier).
    pub fn spill_store_bytes(&self) -> u64 {
        self.flash.len()
    }

    /// Reclaim the spill store once no session references it: truncates
    /// the flash file so completed requests' spilled KV doesn't accumulate
    /// forever (the store is append-only while sessions are live). The
    /// coordinator calls this after requests complete. Returns true if the
    /// store was actually reclaimed.
    pub fn reclaim_flash(&self) -> bool {
        // Explicit live-session count (incremented in new_session,
        // decremented by the session guard's Drop): zero ⟺ no session
        // still owns spilled offsets into the store.
        self.live_sessions.load(Ordering::Relaxed) == 0 && self.flash.reset().is_ok()
    }

    /// Start a new generation session drawing pages from the shared pool.
    pub fn new_session(&self) -> NativeSession {
        let cfg = &self.config;
        let kv = (0..cfg.layers)
            .map(|_| {
                HybridKvLayer::with_pool_policy(
                    cfg.kv_heads,
                    cfg.head_dim(),
                    self.flash.clone(),
                    self.options.kv_budget_tokens,
                    self.kv_pool.clone(),
                    self.options.eviction,
                )
            })
            .collect();
        self.live_sessions.fetch_add(1, Ordering::Relaxed);
        NativeSession {
            kv,
            pos: 0,
            lora_task: None,
            _live: SessionGuard(self.live_sessions.clone()),
        }
    }

    /// Admission control: make room in the KV pool for a `prompt_len`-token
    /// prefill by preempting `running` sessions (oldest first) to flash
    /// until the prompt's page-granular KV estimate fits the budget. When
    /// the prompt could never fit even an empty pool, fleet-wide preemption
    /// is pointless and skipped — the new session degrades by spilling its
    /// own KV as it appends. Returns sessions preempted.
    pub fn make_room(
        &self,
        prompt_len: usize,
        running: &mut [&mut NativeSession],
    ) -> std::io::Result<u64> {
        let need = self.prefill_kv_page_bytes(prompt_len);
        let mut preempted = 0;
        if self.kv_pool.would_exceed(need) && need <= self.kv_pool.budget_bytes() {
            for s in running.iter_mut() {
                if !self.kv_pool.would_exceed(need) {
                    break;
                }
                if s.resident_kv_bytes() > 0 {
                    s.preempt_to_flash()?;
                    preempted += 1;
                }
            }
            // If it still doesn't fit, admit anyway: appends degrade
            // gracefully by spilling to flash.
        }
        Ok(preempted)
    }

    /// The `EvictionPolicy::LargestHolder` enforcement pass: while the KV
    /// pool is over budget, spill one page-worth of oldest records per
    /// layer from the session holding the most resident KV. The engine
    /// calls this between scheduler ticks (after admissions and before
    /// each decode round), so under `LargestHolder` the pool exceeds its
    /// budget by at most one tick's appends. A no-op under `ShedSelf`
    /// (appends restore the budget themselves). Returns records shed.
    pub fn enforce_kv_budget(
        &self,
        running: &mut [&mut NativeSession],
    ) -> std::io::Result<u64> {
        if self.options.eviction != EvictionPolicy::LargestHolder {
            return Ok(0);
        }
        let mut shed = 0u64;
        while self.kv_pool.over_budget() {
            let victim = running
                .iter_mut()
                .filter(|s| s.resident_kv_bytes() > 0)
                .max_by_key(|s| s.resident_kv_bytes());
            let Some(victim) = victim else { break };
            let n = victim.shed_oldest(PAGE_TOKENS)?;
            if n == 0 {
                break; // nothing sheddable left anywhere
            }
            shed += n as u64;
        }
        Ok(shed)
    }

    fn embed(&self, ids: &[usize], out: &mut [f32]) {
        if let Some(table) = &self.embedding_dram {
            let h = self.config.hidden;
            for (i, &id) in ids.iter().enumerate() {
                out[i * h..(i + 1) * h].copy_from_slice(&table[id * h..(id + 1) * h]);
            }
        } else {
            self.embedding.lookup_batch(ids, out).expect("flash embedding");
        }
    }

    /// Rotate-half RoPE at position `pos` on one head vector in place.
    /// Sin/cos come from the load-time tables; positions past `max_len`
    /// (only reachable by driving the model outside the engine's context
    /// cap) fall back to direct computation, bit-identically.
    fn rope(&self, x: &mut [f32], pos: usize) {
        let half = x.len() / 2;
        if pos < self.config.max_len {
            let sin = &self.rope_sin[pos * half..(pos + 1) * half];
            let cos = &self.rope_cos[pos * half..(pos + 1) * half];
            for i in 0..half {
                let a = x[i];
                let b = x[i + half];
                x[i] = a * cos[i] - b * sin[i];
                x[i + half] = b * cos[i] + a * sin[i];
            }
        } else {
            for i in 0..half {
                let (s, c) = (pos as f32 * self.inv_freq[i]).sin_cos();
                let a = x[i];
                let b = x[i + half];
                x[i] = a * c - b * s;
                x[i + half] = b * c + a * s;
            }
        }
    }

    /// Parallel quantized Linear: y[e, h] = x·Wᵀ (+bias), balanced over
    /// h-tiles per §5.2. Disjoint output columns per worker — see safety
    /// comment.
    fn linear(&self, lin: &QLinear, x: &[f32], e: usize, out: &mut [f32]) {
        let pa =
            crate::reorder::pack::pack_activations(x, e, lin.in_features(), lin.activation_tile(e));
        let tiles = lin.h_tiles();
        let workers = &self.options.workers;
        if workers.threads() <= 1 || tiles < 2 * workers.threads() {
            lin.forward_packed(&pa, out, 0, tiles);
            return;
        }
        // SAFETY: each h-tile range writes a disjoint set of output columns
        // (c in [lo*h_p, hi*h_p)), every (r, c) exactly once; no two workers
        // alias any element.
        struct Ptr(*mut f32, usize);
        unsafe impl Sync for Ptr {}
        let ptr = Ptr(out.as_mut_ptr(), out.len());
        let ptr = &ptr; // capture the Sync wrapper, not the raw field
        run_balanced(workers, tiles, move |_, lo, hi| {
            let out = unsafe { std::slice::from_raw_parts_mut(ptr.0, ptr.1) };
            lin.forward_packed(&pa, out, lo, hi);
        });
    }

    fn lora_apply(
        &self,
        task: Option<&str>,
        layer: usize,
        which: &str,
        x: &[f32],
        e: usize,
        out: &mut [f32],
    ) {
        if let Some(task) = task {
            self.lora.apply(Some(task), &format!("L{layer}.{which}"), x, e, out);
        }
    }

    /// Prefill `ids`; returns logits for the **last** token ([vocab]).
    /// Leaves the session's KV cache filled and `pos` advanced.
    pub fn prefill(&self, sess: &mut NativeSession, ids: &[usize]) -> Vec<f32> {
        let s = ids.len();
        assert!(s > 0);
        let cfg = self.config.clone();
        let (h, hd, heads, kvh) = (cfg.hidden, cfg.head_dim(), cfg.heads, cfg.kv_heads);
        let kv_dim = cfg.kv_dim();
        // Borrow, don't clone: `lora_task` and the fields mutated below
        // (`kv`, `pos`) are disjoint, so no per-call String allocation.
        let task = sess.lora_task.as_deref();
        let mut x = vec![0f32; s * h];
        self.embed(ids, &mut x);
        let base_pos = sess.pos;
        let mut norm = vec![0f32; s * h];
        let mut q = vec![0f32; s * h];
        let mut k = vec![0f32; s * kv_dim];
        let mut v = vec![0f32; s * kv_dim];
        let mut attn = vec![0f32; s * h];
        let mut attn_out = vec![0f32; s * h];
        let mut gate = vec![0f32; s * cfg.inter];
        let mut up = vec![0f32; s * cfg.inter];
        let mut act = vec![0f32; s * cfg.inter];
        let mut mlp = vec![0f32; s * h];
        for li in 0..cfg.layers {
            // Kick upcoming layers' flash fetches before touching this one
            // so the reads overlap this layer's compute (§4.1 overlap,
            // weights edition). Depth is budget-aware: as many layers ahead
            // as the arena can hold next to the current one. No-op when
            // everything is already resident.
            self.weights.prefetch_ahead(&self.prefetcher, li + 1);
            let layer = self.weights.layer(li).expect("weight residency");
            rmsnorm(&x, &layer.ln1, &mut norm, s, cfg.rms_eps);
            self.linear(&layer.wq, &norm, s, &mut q);
            self.linear(&layer.wk, &norm, s, &mut k);
            self.linear(&layer.wv, &norm, s, &mut v);
            self.lora_apply(task, li, "wq", &norm, s, &mut q);
            self.lora_apply(task, li, "wk", &norm, s, &mut k);
            self.lora_apply(task, li, "wv", &norm, s, &mut v);
            // RoPE per token/head ([s, heads, hd] layout == [s, h]).
            for t in 0..s {
                for hh in 0..heads {
                    self.rope(&mut q[(t * heads + hh) * hd..(t * heads + hh + 1) * hd], base_pos + t);
                }
                for hh in 0..kvh {
                    self.rope(&mut k[(t * kvh + hh) * hd..(t * kvh + hh + 1) * hd], base_pos + t);
                }
            }
            prefill_attention(&q, &k, &v, s, heads, kvh, hd, &mut attn);
            // Cache the fresh K/V (quantized append per token).
            for t in 0..s {
                sess.kv[li]
                    .append(&k[t * kv_dim..(t + 1) * kv_dim], &v[t * kv_dim..(t + 1) * kv_dim])
                    .expect("kv append");
            }
            self.linear(&layer.wo, &attn, s, &mut attn_out);
            self.lora_apply(task, li, "wo", &attn, s, &mut attn_out);
            add_inplace(&mut x, &attn_out);
            rmsnorm(&x, &layer.ln2, &mut norm, s, cfg.rms_eps);
            self.linear(&layer.gate, &norm, s, &mut gate);
            self.linear(&layer.up, &norm, s, &mut up);
            swiglu(&gate, &up, &mut act);
            self.linear(&layer.down, &act, s, &mut mlp);
            add_inplace(&mut x, &mlp);
        }
        sess.pos = base_pos + s;
        // Final norm + lm_head on the last row only.
        let last = &x[(s - 1) * h..s * h];
        let mut fin = vec![0f32; h];
        rmsnorm(last, &self.fnorm, &mut fin, 1, cfg.rms_eps);
        let mut logits = vec![0f32; cfg.vocab];
        self.linear(&self.lm_head, &fin, 1, &mut logits);
        logits
    }

    /// One decode step for `id` at the session's position; returns logits.
    /// A batch-of-one [`decode_batch`](Self::decode_batch): single-session
    /// and fused decode share one code path, which is what makes the
    /// batched round bit-identical to sequential decode by construction.
    pub fn decode(&self, sess: &mut NativeSession, id: usize) -> Vec<f32> {
        self.decode_batch(&mut [sess], &[id]).pop().expect("one row")
    }

    /// One fused decode step for every session in the batch: a **single
    /// layer walk** serves all rows — one `weight_store` fetch (+ lookahead
    /// prefetch) per layer per call instead of one per layer per session,
    /// which is the §4.1 decode-bandwidth amortization continuous batching
    /// buys on this backend. Row r consumes `ids[r]` at `sessions[r]`'s own
    /// position and gets `sessions[r]`'s logits in the returned row r.
    ///
    /// Value-neutrality: rows are computed independently and row-major —
    /// per-row dynamic activation quantization, exact integer GEMM
    /// accumulation and per-row affine corrections (`cpu::gemm_q`), per-row
    /// RoPE at each session's own position, per-session KV append +
    /// online-softmax attention over that session's (possibly spilled)
    /// cache, and per-row LoRA deltas keyed by each session's task. The
    /// batch therefore produces **bit-identical** logits to decoding the
    /// sessions one at a time, in any batch composition — the invariant
    /// the engine's batched rounds and the parity tests rely on.
    pub fn decode_batch(&self, sessions: &mut [&mut NativeSession], ids: &[usize]) -> Vec<Vec<f32>> {
        let m = sessions.len();
        assert_eq!(m, ids.len(), "one token per session");
        if m == 0 {
            return Vec::new();
        }
        let cfg = self.config.clone();
        let (h, hd, heads, kvh) = (cfg.hidden, cfg.head_dim(), cfg.heads, cfg.kv_heads);
        let kv_dim = cfg.kv_dim();
        // Attribute this walk's flash fetches to the decode gauge only —
        // load warm-up and prefill traffic must not pollute fetch/token.
        let fetches_before = self.weights.metrics().total_fetches();
        let mut x = vec![0f32; m * h];
        self.embed(ids, &mut x);
        let mut norm = vec![0f32; m * h];
        let mut q = vec![0f32; m * h];
        let mut k = vec![0f32; m * kv_dim];
        let mut v = vec![0f32; m * kv_dim];
        let mut attn = vec![0f32; m * h];
        let mut attn_out = vec![0f32; m * h];
        let mut gate = vec![0f32; m * cfg.inter];
        let mut up = vec![0f32; m * cfg.inter];
        let mut act = vec![0f32; m * cfg.inter];
        let mut mlp = vec![0f32; m * h];
        for li in 0..cfg.layers {
            // Budget-aware lookahead prefetch, same contract as in prefill
            // — issued once per layer per *batch*, not per session.
            self.weights.prefetch_ahead(&self.prefetcher, li + 1);
            let layer = self.weights.layer(li).expect("weight residency");
            rmsnorm(&x, &layer.ln1, &mut norm, m, cfg.rms_eps);
            // m-row packed GEMMs: the same batched path prefill rows use.
            self.linear(&layer.wq, &norm, m, &mut q);
            self.linear(&layer.wk, &norm, m, &mut k);
            self.linear(&layer.wv, &norm, m, &mut v);
            // Per-row LoRA bypass, keyed by each session's own task.
            for (r, sess) in sessions.iter().enumerate() {
                let task = sess.lora_task.as_deref();
                if task.is_some() {
                    self.lora_apply(task, li, "wq", &norm[r * h..(r + 1) * h], 1,
                                    &mut q[r * h..(r + 1) * h]);
                    self.lora_apply(task, li, "wk", &norm[r * h..(r + 1) * h], 1,
                                    &mut k[r * kv_dim..(r + 1) * kv_dim]);
                    self.lora_apply(task, li, "wv", &norm[r * h..(r + 1) * h], 1,
                                    &mut v[r * kv_dim..(r + 1) * kv_dim]);
                }
            }
            // Per-row RoPE at each session's own position, then that
            // session's KV append + online-softmax attention that streams
            // any spilled prefix from flash in bounded chunks (§4.1): DRAM
            // stays O(resident + chunk) at any context length. With nothing
            // spilled it reduces to a pure in-DRAM pass over the resident
            // pages — one code path, so spilling (token budget, pool
            // pressure, preemption) is *bit-exact* value-neutral, not
            // merely numerically close.
            for (r, sess) in sessions.iter_mut().enumerate() {
                let pos = sess.pos;
                let qr = &mut q[r * h..(r + 1) * h];
                for hh in 0..heads {
                    self.rope(&mut qr[hh * hd..(hh + 1) * hd], pos);
                }
                let kr = &mut k[r * kv_dim..(r + 1) * kv_dim];
                for hh in 0..kvh {
                    self.rope(&mut kr[hh * hd..(hh + 1) * hd], pos);
                }
                sess.kv[li]
                    .append(&k[r * kv_dim..(r + 1) * kv_dim], &v[r * kv_dim..(r + 1) * kv_dim])
                    .expect("kv append");
                sess.kv[li]
                    .decode_attention_streaming(
                        &q[r * h..(r + 1) * h],
                        heads,
                        &mut attn[r * h..(r + 1) * h],
                        KV_STREAM_CHUNK,
                    )
                    .expect("kv stream");
            }
            self.linear(&layer.wo, &attn, m, &mut attn_out);
            for (r, sess) in sessions.iter().enumerate() {
                let task = sess.lora_task.as_deref();
                if task.is_some() {
                    self.lora_apply(task, li, "wo", &attn[r * h..(r + 1) * h], 1,
                                    &mut attn_out[r * h..(r + 1) * h]);
                }
            }
            add_inplace(&mut x, &attn_out);
            rmsnorm(&x, &layer.ln2, &mut norm, m, cfg.rms_eps);
            self.linear(&layer.gate, &norm, m, &mut gate);
            self.linear(&layer.up, &norm, m, &mut up);
            swiglu(&gate, &up, &mut act);
            self.linear(&layer.down, &act, m, &mut mlp);
            add_inplace(&mut x, &mlp);
        }
        for sess in sessions.iter_mut() {
            sess.pos += 1;
        }
        // One decode token per row, plus this walk's fetch delta, against
        // the store's amortization gauge.
        let fetches = self.weights.metrics().total_fetches() - fetches_before;
        self.weights.note_decode_pass(m as u64, fetches);
        let mut fin = vec![0f32; m * h];
        rmsnorm(&x, &self.fnorm, &mut fin, m, cfg.rms_eps);
        let mut logits = vec![0f32; m * cfg.vocab];
        self.linear(&self.lm_head, &fin, m, &mut logits);
        if m == 1 {
            // Batch of one (the `decode` wrapper): the buffer is exactly
            // the single row — hand it back without a vocab-sized copy.
            return vec![logits];
        }
        logits.chunks_exact(cfg.vocab).map(|row| row.to_vec()).collect()
    }

    /// Greedy generation convenience: prefill + n decode steps on `sess`.
    pub fn generate(&self, sess: &mut NativeSession, prompt: &[usize], n: usize) -> Vec<usize> {
        let logits = self.prefill(sess, prompt);
        let mut tok = crate::model::sampler::argmax(&logits);
        let mut out = vec![tok];
        for _ in 1..n {
            let logits = self.decode(sess, tok);
            tok = crate::model::sampler::argmax(&logits);
            out.push(tok);
        }
        out
    }

    /// Greedy generation on a fresh session (one-shot convenience).
    pub fn generate_once(&self, prompt: &[usize], n: usize) -> Vec<usize> {
        let mut sess = self.new_session();
        self.generate(&mut sess, prompt, n)
    }

    /// DRAM resident bytes of weights — memory accounting: the residency
    /// arena's current occupancy plus the pinned lm_head (and the DRAM
    /// embedding table in the baseline configuration).
    pub fn weight_dram_bytes(&self) -> usize {
        let emb = self.embedding_dram.as_ref().map_or(0, |t| t.len() * 4);
        self.weights.resident_bytes() + self.lm_head.weight_bytes() + emb
    }

    /// The layer-residency arena (budget / residency introspection).
    pub fn weight_store(&self) -> &WeightStore {
        &self.weights
    }

    /// Cumulative weight-residency counters + residency snapshot. The
    /// coordinator copies this into `EngineMetrics` after each drain.
    pub fn weight_metrics(&self) -> WeightResidencyMetrics {
        self.weights.metrics()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::fixtures;

    fn load() -> (fixtures::Fixture, NativeModel) {
        fixtures::native_model(7, EngineOptions::default()).unwrap()
    }

    #[test]
    fn loads_and_generates_deterministically() {
        let (_fx, m) = load();
        let prompt = [104usize, 101, 108, 108, 111];
        let mut s1 = m.new_session();
        let a = m.generate(&mut s1, &prompt, 6);
        let mut s2 = m.new_session();
        let b = m.generate(&mut s2, &prompt, 6);
        assert_eq!(a, b);
        assert_eq!(a.len(), 6);
        assert!(a.iter().all(|&t| t < m.config.vocab));
    }

    #[test]
    fn decode_matches_prefill_rows() {
        // Same invariant as python/tests/test_model.py: prefill(x..y) last
        // logits == prefill(x) then decode(y..) last logits (up to the
        // batched-vs-single-row activation-quantization difference).
        let (_fx, m) = load();
        let ids = [3usize, 1, 4, 1, 5];
        let mut full_sess = m.new_session();
        let full = m.prefill(&mut full_sess, &ids);
        let mut step_sess = m.new_session();
        let mut step = m.prefill(&mut step_sess, &ids[..1]);
        for &t in &ids[1..] {
            step = m.decode(&mut step_sess, t);
        }
        let dot: f32 = full.iter().zip(&step).map(|(a, b)| a * b).sum();
        let na: f32 = full.iter().map(|v| v * v).sum::<f32>().sqrt();
        let nb: f32 = step.iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!(dot / (na * nb) > 0.995, "cos {}", dot / (na * nb));
        // The prefill top-1 must rank at the very top of the decode-path
        // logits too. (Exact argmax equality is too brittle for the
        // random-weight fixture: decode attends over the quantized KV while
        // batched prefill uses the raw fp32 K/V.)
        let top_full = crate::model::sampler::argmax(&full);
        let mut order: Vec<usize> = (0..step.len()).collect();
        order.sort_by(|&a, &b| step[b].partial_cmp(&step[a]).unwrap());
        assert!(
            order[..3].contains(&top_full),
            "prefill top-1 {top_full} not in decode top-3 {:?}",
            &order[..3]
        );
    }

    #[test]
    fn decode_batch_rows_match_sequential_decode_bitwise() {
        // The fused-round invariant at model level: one decode_batch call
        // produces, row for row, exactly the logits sequential decode
        // produces — across batch sizes, on fresh models from one fixture.
        let (fx, seq) = load();
        let bat = NativeModel::load(fx.dir(), EngineOptions::default()).unwrap();
        let prompts: [&[usize]; 3] = [&[5, 6, 7], &[100, 101], &[42, 43, 44, 45]];
        for take in 1..=prompts.len() {
            let mut seq_sessions: Vec<NativeSession> = Vec::new();
            let mut bat_sessions: Vec<NativeSession> = Vec::new();
            let mut toks = Vec::new();
            for p in &prompts[..take] {
                let mut s1 = seq.new_session();
                let l1 = seq.prefill(&mut s1, p);
                let mut s2 = bat.new_session();
                let l2 = bat.prefill(&mut s2, p);
                assert_eq!(l1, l2, "prefill parity");
                toks.push(crate::model::sampler::argmax(&l1));
                seq_sessions.push(s1);
                bat_sessions.push(s2);
            }
            for step in 0..4 {
                let batched = {
                    let mut refs: Vec<&mut NativeSession> =
                        bat_sessions.iter_mut().collect();
                    bat.decode_batch(&mut refs, &toks)
                };
                for (r, sess) in seq_sessions.iter_mut().enumerate() {
                    let single = seq.decode(sess, toks[r]);
                    assert_eq!(
                        single, batched[r],
                        "batch {take} step {step} row {r} diverged"
                    );
                    toks[r] = crate::model::sampler::argmax(&single);
                }
            }
        }
    }

    #[test]
    fn kv_grows_with_tokens() {
        let (_fx, m) = load();
        let mut sess = m.new_session();
        m.prefill(&mut sess, &[1, 2, 3]);
        assert_eq!(sess.kv[0].len(), 3);
        assert_eq!(sess.pos, 3);
        m.decode(&mut sess, 9);
        assert_eq!(sess.kv[0].len(), 4);
        assert_eq!(sess.pos, 4);
    }

    #[test]
    fn sessions_are_isolated() {
        // Interleaving another session must not change a session's output:
        // the invariant continuous batching rests on.
        let (_fx, m) = load();
        let mut alone = m.new_session();
        let solo = m.generate(&mut alone, &[5, 6, 7], 4);
        let mut a = m.new_session();
        let mut b = m.new_session();
        let la = m.prefill(&mut a, &[5, 6, 7]);
        let _lb = m.prefill(&mut b, &[200, 201, 202, 203]);
        let mut tok = crate::model::sampler::argmax(&la);
        let mut interleaved = vec![tok];
        for _ in 1..4 {
            let _ = m.decode(&mut b, 9); // foreign session activity
            let l = m.decode(&mut a, tok);
            tok = crate::model::sampler::argmax(&l);
            interleaved.push(tok);
        }
        assert_eq!(solo, interleaved, "session isolation");
    }

    #[test]
    fn kv_spill_does_not_change_output() {
        let (fx, plain) = load();
        let spilled_model = NativeModel::load(
            fx.dir(),
            EngineOptions { kv_budget_tokens: 2, ..EngineOptions::default() },
        )
        .unwrap();
        let prompt = [10usize, 20, 30, 40, 50, 60];
        let a = plain.generate_once(&prompt, 4);
        let mut sess = spilled_model.new_session();
        let b = spilled_model.generate(&mut sess, &prompt, 4);
        assert_eq!(a, b, "spilling is value-neutral");
        assert!(sess.kv[0].spilled_tokens() > 0, "budget actually spilled");
    }

    #[test]
    fn pool_budget_spill_does_not_change_output() {
        // Byte-budget pressure on the shared pool must also be
        // value-neutral: same tokens, pages within budget after appends.
        let (fx, plain) = load();
        let page = crate::kv::KvPool::page_bytes(
            plain.config.kv_heads,
            plain.config.head_dim(),
        );
        // One page for a 2-layer model: the second layer's page always
        // tips the pool over budget, forcing eviction to flash.
        let tight = NativeModel::load(
            fx.dir(),
            EngineOptions { kv_pool_bytes: page, ..EngineOptions::default() },
        )
        .unwrap();
        let prompt = [10usize, 20, 30, 40, 50, 60];
        let a = plain.generate_once(&prompt, 4);
        let mut sess = tight.new_session();
        let b = tight.generate(&mut sess, &prompt, 4);
        assert_eq!(a, b, "pool pressure is value-neutral");
        assert!(sess.spilled_records() > 0);
        assert!(tight.kv_pool().resident_bytes() <= tight.kv_pool().budget_bytes());
    }

    #[test]
    fn weight_budget_below_packed_total_is_bit_identical() {
        // The weight-residency acceptance invariant at model level: a DRAM
        // budget smaller than the packed weights produces the exact same
        // tokens, with flash traffic and evictions visible in metrics.
        let (fx, plain) = load();
        let total = plain.weight_metrics().packed_bytes;
        assert!(total > 0);
        let tight = NativeModel::load(
            fx.dir(),
            EngineOptions { weight_dram_bytes: total / 2, ..EngineOptions::default() },
        )
        .unwrap();
        let prompt = [10usize, 20, 30, 40, 50];
        assert_eq!(
            plain.generate_once(&prompt, 4),
            tight.generate_once(&prompt, 4),
            "weight residency is bit-exact value-neutral"
        );
        let wm = tight.weight_metrics();
        assert!(wm.under_pressure(), "{wm:?}");
        assert!(wm.flash_read_s > 0.0);
        assert!(tight.weight_store().resident_bytes() <= total / 2);
        // The unlimited model never touched flash for weights after load.
        let um = plain.weight_metrics();
        assert_eq!(um.demand_fetches, 0);
        assert_eq!(um.evictions, 0);
        assert_eq!(um.resident_bytes, total);
    }

    #[test]
    fn flash_spill_store_reclaimed_after_sessions_end() {
        let (_fx, m) = fixtures::native_model(
            7,
            EngineOptions { kv_budget_tokens: 2, ..EngineOptions::default() },
        )
        .unwrap();
        {
            let mut sess = m.new_session();
            m.prefill(&mut sess, &[1, 2, 3, 4, 5, 6]);
            assert!(m.spill_store_bytes() > 0, "token budget spilled to flash");
            assert!(!m.reclaim_flash(), "live session blocks reclamation");
        }
        assert!(m.reclaim_flash(), "no sessions left: store reclaimable");
        assert_eq!(m.spill_store_bytes(), 0);
        // The engine still serves correctly from a reclaimed store.
        let out = m.generate_once(&[1, 2, 3, 4, 5, 6], 3);
        assert_eq!(out.len(), 3);
    }

    #[test]
    fn session_drop_returns_pages_to_pool() {
        let (_fx, m) = load();
        {
            let mut sess = m.new_session();
            m.prefill(&mut sess, &[1, 2, 3, 4, 5]);
            assert!(m.kv_pool().resident_bytes() > 0);
        }
        assert_eq!(m.kv_pool().resident_bytes(), 0);
    }

    #[test]
    fn flash_vs_dram_embedding_identical() {
        let (fx, flash) = load();
        let dram = NativeModel::load(
            fx.dir(),
            EngineOptions { embedding_in_flash: false, ..EngineOptions::default() },
        )
        .unwrap();
        let prompt = [7usize, 8, 9];
        assert_eq!(flash.generate_once(&prompt, 3), dram.generate_once(&prompt, 3));
        assert!(dram.weight_dram_bytes() > flash.weight_dram_bytes());
    }

    #[test]
    fn multithread_matches_single_thread() {
        let (fx, one) = load();
        let four = NativeModel::load(
            fx.dir(),
            EngineOptions {
                workers: WorkerConfig { rates: vec![1.0, 0.72, 0.72, 0.72] },
                ..EngineOptions::default()
            },
        )
        .unwrap();
        let prompt = [42usize, 43, 44, 45];
        assert_eq!(one.generate_once(&prompt, 4), four.generate_once(&prompt, 4));
    }

    #[test]
    fn lora_changes_output_only_for_its_task() {
        let (_fx, mut m) = load();
        let mut base_sess = m.new_session();
        let base = m.prefill(&mut base_sess, &[5, 6, 7]);
        // Load an adapter but don't select it: output unchanged.
        let mut rng = crate::util::rng::Rng::new(9);
        let h = m.config.hidden;
        let mut layers = std::collections::HashMap::new();
        layers.insert("L0.wq".to_string(),
                      crate::lora::LoraAdapter::random(&mut rng, h, h, 4));
        m.lora.load_task("style", layers);
        let mut same_sess = m.new_session();
        let same = m.prefill(&mut same_sess, &[5, 6, 7]);
        assert_eq!(base, same);
        // Select it: output changes.
        let mut changed_sess = m.new_session();
        changed_sess.lora_task = Some("style".into());
        let changed = m.prefill(&mut changed_sess, &[5, 6, 7]);
        assert_ne!(base, changed);
    }
}
