//! Native pure-Rust execution engine: the paper's optimized CPU pipeline.
//!
//! Composes every §4/§5 mechanism end-to-end:
//! * combined quantization — int8 attention/lm_head, int4 MLP, dynamic int8
//!   activations (weights arrive pre-quantized from artifacts/weights.bin);
//! * hardware-driven reorder — weights repacked at load for the detected
//!   ISA's solved tile (§5.1);
//! * flash-resident bf16 embedding + KV spill with prefetch (§4.1);
//! * multicore balanced GEMM splits (§5.2);
//! * fp32 softmax + pre-scaled queries (§5.3);
//! * per-request LoRA bypass in the associative order (§5.5).

use std::path::Path;
use std::sync::Arc;

use crate::cpu::activation::{add_inplace, rmsnorm, swiglu};
use crate::cpu::attention::prefill_attention;
use crate::cpu::gemm_q::QLinear;
use crate::device::SocProfile;
use crate::lora::LoraManager;
use crate::memory::flash::FlashSim;
use crate::memory::hybrid::HybridKvLayer;
use crate::memory::embedding::FlashEmbedding;
use crate::model::config::ModelConfig;
use crate::model::manifest::Manifest;
use crate::model::weights::{WeightFile, DT_I8, DT_U8};
use crate::parallel::pool::{run_balanced, WorkerConfig};
use crate::quant::asym::{QuantizedMatrix, WeightBits};
use crate::reorder::solver::TileConfig;

/// Tokens per flash chunk when streaming spilled KV through attention.
pub const KV_STREAM_CHUNK: usize = 32;

/// Engine construction options.
#[derive(Clone, Debug)]
pub struct EngineOptions {
    pub tile: TileConfig,
    pub workers: WorkerConfig,
    /// Per-layer DRAM budget for KV, in tokens, before spilling to flash.
    pub kv_budget_tokens: usize,
    /// If false, the embedding is copied to DRAM (baseline configuration).
    pub embedding_in_flash: bool,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            tile: crate::reorder::solver::solve_tiles(&crate::reorder::isa::detect_host()),
            workers: WorkerConfig::uniform(1),
            kv_budget_tokens: usize::MAX / 2,
            embedding_in_flash: true,
        }
    }
}

struct Layer {
    wq: QLinear,
    wk: QLinear,
    wv: QLinear,
    wo: QLinear,
    gate: QLinear,
    up: QLinear,
    down: QLinear,
    ln1: Vec<f32>,
    ln2: Vec<f32>,
}

/// A loaded model + one generation session's KV state.
pub struct NativeModel {
    pub config: ModelConfig,
    pub options: EngineOptions,
    layers: Vec<Layer>,
    fnorm: Vec<f32>,
    lm_head: QLinear,
    embedding: FlashEmbedding,
    embedding_dram: Option<Vec<f32>>,
    pub kv: Vec<HybridKvLayer>,
    pub lora: LoraManager,
    pub lora_task: Option<String>,
    /// Positions generated so far (== sequence length).
    pub pos: usize,
    /// Rope tables are computed on the fly (θ^(-2i/d)).
    inv_freq: Vec<f32>,
}

fn qlin(
    wf: &WeightFile,
    name: &str,
    bits: WeightBits,
    tile: TileConfig,
    bias: Option<Vec<f32>>,
) -> std::io::Result<QLinear> {
    let q = wf.require(&format!("{name}.q"))?;
    let s = wf.require(&format!("{name}.s"))?;
    let b = wf.require(&format!("{name}.b"))?;
    let (n, k) = match bits {
        WeightBits::Int8 => {
            assert_eq!(q.dtype, DT_I8, "{name}: expected i8");
            (q.shape[0], q.shape[1])
        }
        WeightBits::Int4 => {
            assert_eq!(q.dtype, DT_U8, "{name}: expected packed u8");
            (q.shape[0], q.shape[1] * 2)
        }
    };
    let qm = QuantizedMatrix::from_parts(bits, n, k, q.data.clone(), &s.as_f32(), &b.as_f32());
    Ok(QLinear::new(&qm, tile, bias))
}

impl NativeModel {
    /// Load from an artifacts directory (manifest + weights + embedding).
    pub fn load(dir: &Path, options: EngineOptions) -> std::io::Result<NativeModel> {
        let manifest = Manifest::load(dir)?;
        let wf = WeightFile::load(&dir.join("weights.bin"))?;
        Self::from_parts(&manifest, &wf, dir, options)
    }

    pub fn from_parts(
        manifest: &Manifest,
        wf: &WeightFile,
        dir: &Path,
        options: EngineOptions,
    ) -> std::io::Result<NativeModel> {
        let cfg = manifest.model.clone();
        let tile = options.tile;
        let mut layers = Vec::with_capacity(cfg.layers);
        for i in 0..cfg.layers {
            let p = format!("L{i}.");
            layers.push(Layer {
                wq: qlin(wf, &format!("{p}wq"), WeightBits::Int8, tile,
                         Some(wf.require(&format!("{p}bq"))?.as_f32()))?,
                wk: qlin(wf, &format!("{p}wk"), WeightBits::Int8, tile,
                         Some(wf.require(&format!("{p}bk"))?.as_f32()))?,
                wv: qlin(wf, &format!("{p}wv"), WeightBits::Int8, tile,
                         Some(wf.require(&format!("{p}bv"))?.as_f32()))?,
                wo: qlin(wf, &format!("{p}wo"), WeightBits::Int8, tile, None)?,
                gate: qlin(wf, &format!("{p}gate"), WeightBits::Int4, tile, None)?,
                up: qlin(wf, &format!("{p}up"), WeightBits::Int4, tile, None)?,
                down: qlin(wf, &format!("{p}down"), WeightBits::Int4, tile, None)?,
                ln1: wf.require(&format!("{p}ln1"))?.as_f32(),
                ln2: wf.require(&format!("{p}ln2"))?.as_f32(),
            });
        }
        let fnorm = wf.require("fnorm")?.as_f32();
        let lm_head = qlin(wf, "lm_head", WeightBits::Int8, tile, None)?;
        let soc = SocProfile::snapdragon_8gen3();
        let flash = Arc::new(FlashSim::temp(soc.flash).map_err(std::io::Error::from)?);
        let embedding = FlashEmbedding::from_file(
            &dir.join(&manifest.embedding_file),
            cfg.vocab,
            cfg.hidden,
            FlashSim::temp(soc.flash)?,
        )?;
        let embedding_dram = if options.embedding_in_flash {
            None
        } else {
            // Baseline: decode-path DRAM residency.
            let bytes = std::fs::read(dir.join(&manifest.embedding_file))?;
            let mut table = vec![0f32; cfg.vocab * cfg.hidden];
            crate::util::bf16::bytes_to_f32(&bytes, &mut table);
            Some(table)
        };
        let kv = (0..cfg.layers)
            .map(|_| {
                HybridKvLayer::new(cfg.kv_heads, cfg.head_dim(), flash.clone(),
                                   options.kv_budget_tokens)
            })
            .collect();
        let half = cfg.head_dim() / 2;
        let inv_freq = (0..half)
            .map(|i| (1.0 / cfg.rope_theta.powf(i as f64 / half as f64)) as f32)
            .collect();
        Ok(NativeModel {
            config: cfg,
            options,
            layers,
            fnorm,
            lm_head,
            embedding,
            embedding_dram,
            kv,
            lora: LoraManager::new(),
            lora_task: None,
            pos: 0,
            inv_freq,
        })
    }

    /// Reset the generation session (new request).
    pub fn reset_session(&mut self) {
        let cfg = &self.config;
        let soc = SocProfile::snapdragon_8gen3();
        let flash = Arc::new(FlashSim::temp(soc.flash).expect("flash temp"));
        self.kv = (0..cfg.layers)
            .map(|_| {
                HybridKvLayer::new(cfg.kv_heads, cfg.head_dim(), flash.clone(),
                                   self.options.kv_budget_tokens)
            })
            .collect();
        self.pos = 0;
    }

    fn embed(&self, ids: &[usize], out: &mut [f32]) {
        if let Some(table) = &self.embedding_dram {
            let h = self.config.hidden;
            for (i, &id) in ids.iter().enumerate() {
                out[i * h..(i + 1) * h].copy_from_slice(&table[id * h..(id + 1) * h]);
            }
        } else {
            self.embedding.lookup_batch(ids, out).expect("flash embedding");
        }
    }

    /// Rotate-half RoPE at position `pos` on one head vector in place.
    fn rope(&self, x: &mut [f32], pos: usize) {
        let half = x.len() / 2;
        for i in 0..half {
            let ang = pos as f32 * self.inv_freq[i];
            let (s, c) = ang.sin_cos();
            let a = x[i];
            let b = x[i + half];
            x[i] = a * c - b * s;
            x[i + half] = b * c + a * s;
        }
    }

    /// Parallel quantized Linear: y[e, h] = x·Wᵀ (+bias), balanced over
    /// h-tiles per §5.2. Disjoint output columns per worker — see safety
    /// comment.
    fn linear(&self, lin: &QLinear, x: &[f32], e: usize, out: &mut [f32]) {
        let pa =
            crate::reorder::pack::pack_activations(x, e, lin.in_features(), lin.activation_tile(e));
        let tiles = lin.h_tiles();
        let workers = &self.options.workers;
        if workers.threads() <= 1 || tiles < 2 * workers.threads() {
            lin.forward_packed(&pa, out, 0, tiles);
            return;
        }
        // SAFETY: each h-tile range writes a disjoint set of output columns
        // (c in [lo*h_p, hi*h_p)), every (r, c) exactly once; no two workers
        // alias any element.
        struct Ptr(*mut f32, usize);
        unsafe impl Sync for Ptr {}
        let ptr = Ptr(out.as_mut_ptr(), out.len());
        let ptr = &ptr; // capture the Sync wrapper, not the raw field
        run_balanced(workers, tiles, move |_, lo, hi| {
            let out = unsafe { std::slice::from_raw_parts_mut(ptr.0, ptr.1) };
            lin.forward_packed(&pa, out, lo, hi);
        });
    }

    fn lora_apply(&self, layer: usize, which: &str, x: &[f32], e: usize, out: &mut [f32]) {
        if let Some(task) = &self.lora_task {
            self.lora.apply(Some(task), &format!("L{layer}.{which}"), x, e, out);
        }
    }

    /// Prefill `ids`; returns logits for the **last** token ([vocab]).
    /// Leaves the KV cache filled and `pos` advanced.
    pub fn prefill(&mut self, ids: &[usize]) -> Vec<f32> {
        let s = ids.len();
        assert!(s > 0);
        let cfg = self.config.clone();
        let (h, hd, heads, kvh) = (cfg.hidden, cfg.head_dim(), cfg.heads, cfg.kv_heads);
        let kv_dim = cfg.kv_dim();
        let mut x = vec![0f32; s * h];
        self.embed(ids, &mut x);
        let base_pos = self.pos;
        let mut norm = vec![0f32; s * h];
        let mut q = vec![0f32; s * h];
        let mut k = vec![0f32; s * kv_dim];
        let mut v = vec![0f32; s * kv_dim];
        let mut attn = vec![0f32; s * h];
        let mut attn_out = vec![0f32; s * h];
        let mut gate = vec![0f32; s * cfg.inter];
        let mut up = vec![0f32; s * cfg.inter];
        let mut act = vec![0f32; s * cfg.inter];
        let mut mlp = vec![0f32; s * h];
        for li in 0..cfg.layers {
            let layer = &self.layers[li];
            rmsnorm(&x, &layer.ln1, &mut norm, s, cfg.rms_eps);
            self.linear(&layer.wq, &norm, s, &mut q);
            self.linear(&layer.wk, &norm, s, &mut k);
            self.linear(&layer.wv, &norm, s, &mut v);
            self.lora_apply(li, "wq", &norm, s, &mut q);
            self.lora_apply(li, "wk", &norm, s, &mut k);
            self.lora_apply(li, "wv", &norm, s, &mut v);
            // RoPE per token/head ([s, heads, hd] layout == [s, h]).
            for t in 0..s {
                for hh in 0..heads {
                    self.rope(&mut q[(t * heads + hh) * hd..(t * heads + hh + 1) * hd], base_pos + t);
                }
                for hh in 0..kvh {
                    self.rope(&mut k[(t * kvh + hh) * hd..(t * kvh + hh + 1) * hd], base_pos + t);
                }
            }
            prefill_attention(&q, &k, &v, s, heads, kvh, hd, &mut attn);
            // Cache the fresh K/V (quantized append per token).
            for t in 0..s {
                self.kv[li]
                    .append(&k[t * kv_dim..(t + 1) * kv_dim], &v[t * kv_dim..(t + 1) * kv_dim])
                    .expect("kv append");
            }
            self.linear(&layer.wo, &attn, s, &mut attn_out);
            self.lora_apply(li, "wo", &attn, s, &mut attn_out);
            add_inplace(&mut x, &attn_out);
            rmsnorm(&x, &layer.ln2, &mut norm, s, cfg.rms_eps);
            self.linear(&layer.gate, &norm, s, &mut gate);
            self.linear(&layer.up, &norm, s, &mut up);
            swiglu(&gate, &up, &mut act);
            self.linear(&layer.down, &act, s, &mut mlp);
            add_inplace(&mut x, &mlp);
        }
        self.pos = base_pos + s;
        // Final norm + lm_head on the last row only.
        let last = &x[(s - 1) * h..s * h];
        let mut fin = vec![0f32; h];
        rmsnorm(last, &self.fnorm, &mut fin, 1, cfg.rms_eps);
        let mut logits = vec![0f32; cfg.vocab];
        self.linear(&self.lm_head, &fin, 1, &mut logits);
        logits
    }

    /// One decode step for `id` at the current position; returns logits.
    pub fn decode(&mut self, id: usize) -> Vec<f32> {
        let cfg = self.config.clone();
        let (h, hd, heads, kvh) = (cfg.hidden, cfg.head_dim(), cfg.heads, cfg.kv_heads);
        let kv_dim = cfg.kv_dim();
        let pos = self.pos;
        let mut x = vec![0f32; h];
        self.embed(&[id], &mut x);
        let mut norm = vec![0f32; h];
        let mut q = vec![0f32; h];
        let mut k = vec![0f32; kv_dim];
        let mut v = vec![0f32; kv_dim];
        let mut attn = vec![0f32; h];
        let mut attn_out = vec![0f32; h];
        let mut gate = vec![0f32; cfg.inter];
        let mut up = vec![0f32; cfg.inter];
        let mut act = vec![0f32; cfg.inter];
        let mut mlp = vec![0f32; h];
        for li in 0..cfg.layers {
            let layer = &self.layers[li];
            rmsnorm(&x, &layer.ln1, &mut norm, 1, cfg.rms_eps);
            self.linear(&layer.wq, &norm, 1, &mut q);
            self.linear(&layer.wk, &norm, 1, &mut k);
            self.linear(&layer.wv, &norm, 1, &mut v);
            self.lora_apply(li, "wq", &norm, 1, &mut q);
            self.lora_apply(li, "wk", &norm, 1, &mut k);
            self.lora_apply(li, "wv", &norm, 1, &mut v);
            for hh in 0..heads {
                self.rope(&mut q[hh * hd..(hh + 1) * hd], pos);
            }
            for hh in 0..kvh {
                self.rope(&mut k[hh * hd..(hh + 1) * hd], pos);
            }
            self.kv[li].append(&k, &v).expect("kv append");
            if self.kv[li].spilled_tokens() > 0 {
                // Stream spilled KV from flash in bounded chunks (§4.1):
                // DRAM stays O(resident + chunk) at any context length.
                self.kv[li]
                    .decode_attention_streaming(&q, heads, &mut attn, KV_STREAM_CHUNK)
                    .expect("kv stream");
            } else {
                self.kv[li].stage().expect("kv stage");
                self.kv[li].decode_attention(&q, heads, &mut attn);
            }
            self.linear(&layer.wo, &attn, 1, &mut attn_out);
            self.lora_apply(li, "wo", &attn, 1, &mut attn_out);
            add_inplace(&mut x, &attn_out);
            rmsnorm(&x, &layer.ln2, &mut norm, 1, cfg.rms_eps);
            self.linear(&layer.gate, &norm, 1, &mut gate);
            self.linear(&layer.up, &norm, 1, &mut up);
            swiglu(&gate, &up, &mut act);
            self.linear(&layer.down, &act, 1, &mut mlp);
            add_inplace(&mut x, &mlp);
        }
        self.pos = pos + 1;
        let mut fin = vec![0f32; h];
        rmsnorm(&x, &self.fnorm, &mut fin, 1, cfg.rms_eps);
        let mut logits = vec![0f32; cfg.vocab];
        self.linear(&self.lm_head, &fin, 1, &mut logits);
        logits
    }

    /// Greedy generation convenience: prefill + n decode steps.
    pub fn generate(&mut self, prompt: &[usize], n: usize) -> Vec<usize> {
        let logits = self.prefill(prompt);
        let mut tok = crate::model::sampler::argmax(&logits);
        let mut out = vec![tok];
        for _ in 1..n {
            let logits = self.decode(tok);
            tok = crate::model::sampler::argmax(&logits);
            out.push(tok);
        }
        out
    }

    /// DRAM resident bytes of weights (packed) — memory accounting.
    pub fn weight_dram_bytes(&self) -> usize {
        let per_layer: usize = self
            .layers
            .iter()
            .map(|l| {
                l.wq.weight_bytes()
                    + l.wk.weight_bytes()
                    + l.wv.weight_bytes()
                    + l.wo.weight_bytes()
                    + l.gate.weight_bytes()
                    + l.up.weight_bytes()
                    + l.down.weight_bytes()
            })
            .sum();
        let emb = self.embedding_dram.as_ref().map_or(0, |t| t.len() * 4);
        per_layer + self.lm_head.weight_bytes() + emb
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts() -> Option<PathBuf> {
        let d = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        d.join("manifest.json").exists().then_some(d)
    }

    fn load() -> Option<NativeModel> {
        artifacts().map(|d| NativeModel::load(&d, EngineOptions::default()).unwrap())
    }

    #[test]
    fn loads_and_generates_deterministically() {
        let Some(mut m) = load() else { return };
        let prompt = [104usize, 101, 108, 108, 111];
        let a = m.generate(&prompt, 6);
        m.reset_session();
        let b = m.generate(&prompt, 6);
        assert_eq!(a, b);
        assert_eq!(a.len(), 6);
        assert!(a.iter().all(|&t| t < m.config.vocab));
    }

    #[test]
    fn decode_matches_prefill_rows() {
        // Same invariant as python/tests/test_model.py: prefill(x..y) last
        // logits == prefill(x) then decode(y..) last logits.
        let Some(mut m) = load() else { return };
        let ids = [3usize, 1, 4, 1, 5];
        let full = m.prefill(&ids);
        m.reset_session();
        let mut step = m.prefill(&ids[..1]);
        for &t in &ids[1..] {
            step = m.decode(t);
        }
        // Both are logits for the same position; quantized activations
        // differ slightly between batched and single-row paths.
        let top_full = crate::model::sampler::argmax(&full);
        let top_step = crate::model::sampler::argmax(&step);
        assert_eq!(top_full, top_step, "top-1 must agree");
        let dot: f32 = full.iter().zip(&step).map(|(a, b)| a * b).sum();
        let na: f32 = full.iter().map(|v| v * v).sum::<f32>().sqrt();
        let nb: f32 = step.iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!(dot / (na * nb) > 0.999, "cos {}", dot / (na * nb));
    }

    #[test]
    fn kv_grows_with_tokens() {
        let Some(mut m) = load() else { return };
        m.prefill(&[1, 2, 3]);
        assert_eq!(m.kv[0].len(), 3);
        assert_eq!(m.pos, 3);
        m.decode(9);
        assert_eq!(m.kv[0].len(), 4);
        assert_eq!(m.pos, 4);
    }

    #[test]
    fn kv_spill_does_not_change_output() {
        let Some(dir) = artifacts() else { return };
        let mut plain = NativeModel::load(&dir, EngineOptions::default()).unwrap();
        let mut spilled = NativeModel::load(
            &dir,
            EngineOptions { kv_budget_tokens: 2, ..EngineOptions::default() },
        )
        .unwrap();
        let prompt = [10usize, 20, 30, 40, 50, 60];
        let a = plain.generate(&prompt, 4);
        let b = spilled.generate(&prompt, 4);
        assert_eq!(a, b, "spilling is value-neutral");
        assert!(spilled.kv[0].spilled_tokens() > 0, "budget actually spilled");
    }

    #[test]
    fn flash_vs_dram_embedding_identical() {
        let Some(dir) = artifacts() else { return };
        let mut flash = NativeModel::load(&dir, EngineOptions::default()).unwrap();
        let mut dram = NativeModel::load(
            &dir,
            EngineOptions { embedding_in_flash: false, ..EngineOptions::default() },
        )
        .unwrap();
        let prompt = [7usize, 8, 9];
        assert_eq!(flash.generate(&prompt, 3), dram.generate(&prompt, 3));
        assert!(dram.weight_dram_bytes() > flash.weight_dram_bytes());
    }

    #[test]
    fn multithread_matches_single_thread() {
        let Some(dir) = artifacts() else { return };
        let mut one = NativeModel::load(&dir, EngineOptions::default()).unwrap();
        let mut four = NativeModel::load(
            &dir,
            EngineOptions {
                workers: WorkerConfig { rates: vec![1.0, 0.72, 0.72, 0.72] },
                ..EngineOptions::default()
            },
        )
        .unwrap();
        let prompt = [42usize, 43, 44, 45];
        assert_eq!(one.generate(&prompt, 4), four.generate(&prompt, 4));
    }

    #[test]
    fn lora_changes_output_only_for_its_task() {
        let Some(dir) = artifacts() else { return };
        let mut m = NativeModel::load(&dir, EngineOptions::default()).unwrap();
        let base = m.prefill(&[5, 6, 7]);
        m.reset_session();
        // Load an adapter but don't select it: output unchanged.
        let mut rng = crate::util::rng::Rng::new(9);
        let h = m.config.hidden;
        let mut layers = std::collections::HashMap::new();
        layers.insert("L0.wq".to_string(),
                      crate::lora::LoraAdapter::random(&mut rng, h, h, 4));
        m.lora.load_task("style", layers);
        let same = m.prefill(&[5, 6, 7]);
        assert_eq!(base, same);
        // Select it: output changes.
        m.reset_session();
        m.lora_task = Some("style".into());
        let changed = m.prefill(&[5, 6, 7]);
        assert_ne!(base, changed);
    }
}
