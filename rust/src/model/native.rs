//! Native pure-Rust execution engine: the paper's optimized CPU pipeline.
//!
//! Composes every §4/§5 mechanism end-to-end:
//! * combined quantization — int8 attention/lm_head, int4 MLP, dynamic int8
//!   activations (weights arrive pre-quantized from artifacts/weights.bin);
//! * hardware-driven reorder — weights repacked at load for the detected
//!   ISA's solved tile (§5.1);
//! * flash-resident bf16 embedding + KV spill with prefetch (§4.1);
//! * layer-granular **weight residency** (§4.1, the weight half):
//!   `weights.bin` is streamed onto flash at load (never fully in DRAM),
//!   each layer is packed into a relocatable blob, and forward passes pull
//!   layers through a byte-budgeted LRU arena
//!   ([`EngineOptions::weight_dram_bytes`]) with async one-layer-ahead
//!   prefetch — bit-identical at any budget;
//! * multicore balanced GEMM splits (§5.2);
//! * fp32 softmax + pre-scaled queries (§5.3);
//! * per-request LoRA bypass in the associative order (§5.5).
//!
//! Ownership: the model is **stateless over sessions**. All per-request
//! state — the paged KV cache, the position counter, the selected LoRA
//! task — lives in a [`NativeSession`] created by
//! [`NativeModel::new_session`]. Sessions draw KV pages from the model's
//! shared [`KvPool`] (budgeted via [`EngineOptions::kv_pool_bytes`]) and
//! spill to the model's shared flash device under pressure, which is what
//! lets the coordinator interleave decode across concurrent requests
//! (continuous batching) on this backend.

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::coordinator::backend::RowWork;
use crate::cpu::activation::add_inplace;
use crate::cpu::attention::segmented_prefill_attention_with;
use crate::cpu::backend::{ComputeBackend, ComputeBackendMetrics, OpCounters};
use crate::cpu::gemm_q::QLinear;
use crate::device::SocProfile;
use crate::kv::{
    CachedStash, EvictionPolicy, HolderId, KvPool, PageHandle, PrefixCache, PrefixCacheMetrics,
    PAGE_TOKENS,
};
use crate::lora::LoraManager;
use crate::memory::embedding::FlashEmbedding;
use crate::memory::flash::FlashSim;
use crate::memory::hybrid::HybridKvLayer;
use crate::memory::weight_store::{
    FlashTensorStore, LayerWeights, WeightResidencyMetrics, WeightStore, WeightStoreBuilder,
};
use crate::model::config::ModelConfig;
use crate::model::manifest::Manifest;
use crate::model::weights::{DT_I8, DT_U8};
use crate::parallel::pool::{run_balanced, BackgroundWorker, WorkerConfig};
use crate::quant::asym::{QuantizedMatrix, WeightBits};
use crate::reorder::solver::TileConfig;

/// Tokens per flash chunk when streaming spilled KV through attention.
pub const KV_STREAM_CHUNK: usize = 32;

/// Engine construction options.
#[derive(Clone, Debug)]
pub struct EngineOptions {
    pub tile: TileConfig,
    pub workers: WorkerConfig,
    /// Per-layer DRAM budget for KV, in tokens, before spilling to flash.
    pub kv_budget_tokens: usize,
    /// Byte budget of the shared KV page pool across *all* sessions and
    /// layers. Under pressure, appends evict to flash and the coordinator
    /// preempts sessions instead of admitting past the budget.
    pub kv_pool_bytes: usize,
    /// DRAM byte budget for packed transformer-layer weights. Layers
    /// beyond the budget live on flash as relocatable blobs and are
    /// fetched — one layer ahead, asynchronously — during forward;
    /// `usize::MAX` (the default) keeps every layer resident. The lm_head,
    /// final norm and embedding are pinned outside the budget. Residency
    /// is bit-exact value-neutral at any budget.
    pub weight_dram_bytes: usize,
    /// If false, the embedding is copied to DRAM (baseline configuration).
    pub embedding_in_flash: bool,
    /// Who sheds KV when concurrent sessions exceed the pool byte budget:
    /// the appending layer itself (`ShedSelf`, the default), or the
    /// engine's cross-session largest-holder pass between scheduler ticks
    /// (`LargestHolder`, see [`NativeModel::enforce_kv_budget`]). Both are
    /// bit-exact value-neutral; only who pays the flash traffic changes.
    pub eviction: EvictionPolicy,
    /// Longest prompt slice one engine tick may prefill for a single
    /// request. The engine splits longer prompts into chunks of this many
    /// tokens, so one long prompt cannot monopolize a tick (bounded
    /// per-tick latency, low TTFT for short prompts arriving alongside).
    /// Chunking is bit-exact value-neutral (the session retains the fp32
    /// prompt K/V until its prefill completes — see
    /// [`NativeModel::forward_tick`]). `usize::MAX` (the default)
    /// disables chunking.
    pub prefill_chunk_tokens: usize,
    /// Most rows (sessions) one fused engine tick may advance; with more
    /// active sessions the engine rotates a window through them, bounding
    /// per-token event latency at large B. `usize::MAX` (the default)
    /// serves every active session each tick. Value-neutral (rows are
    /// independent); only scheduling order changes.
    pub max_rows_per_tick: usize,
    /// Byte budget of the shared-prefix KV cache: finished prefills
    /// publish their prompt's quantized pages (refcounted, copy-on-write)
    /// plus the fp32 prefill stash; admissions attach the longest cached
    /// prefix read-only and prefill only the suffix. 0 (the default)
    /// disables the cache entirely — no lookup, no publish, no retained
    /// pages — preserving the pre-cache engine bit for bit.
    pub prefix_cache_bytes: usize,
    /// Which compute backend executes the per-tile hot ops (int8 GEMM
    /// inner loops, norms, softmax, RoPE). `Auto` (the default) picks the
    /// best kernels the host can execute — SIMD when the runtime feature
    /// check passes, scalar otherwise. The `MNN_BACKEND` environment
    /// variable (`scalar` / `simd` / `auto`) outranks this field so CI can
    /// force both legs without touching call sites. Every backend is
    /// bit-identical: integer accumulation is exact and the float
    /// epilogues keep the scalar reduction order.
    pub backend: crate::cpu::backend::BackendChoice,
    /// Default speculative-decoding depth: how many draft tokens a
    /// request verifies per fused tick once a draft model is attached to
    /// the engine (`Engine::attach_draft`), unless the request sets its
    /// own `Request::spec_depth`. 0 (the default) disables speculation
    /// entirely — no draft sessions, no verify rows, no extra RNG
    /// consumption — keeping the engine bit-identical to its
    /// pre-speculation behavior.
    pub spec_depth: usize,
    /// When set, the weight store's flash device **emulates stall time**:
    /// every blob fetch sleeps the modeled read latency of this tier
    /// (`MemTier::latency_s + bytes / read_bw`) instead of returning
    /// instantly. `None` (the default) keeps weight reads instant,
    /// bit-for-bit and timing-wise identical to before the knob existed.
    /// Cluster scaling tests and the fig5 replica sweep use this to make
    /// ticks I/O-dominated — the regime where data-parallel replicas win
    /// by overlapping their stalls.
    pub weight_flash_stall: Option<crate::device::MemTier>,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            tile: crate::reorder::solver::solve_tiles(&crate::reorder::isa::detect_host()),
            workers: WorkerConfig::uniform(1),
            kv_budget_tokens: usize::MAX / 2,
            kv_pool_bytes: usize::MAX,
            weight_dram_bytes: usize::MAX,
            embedding_in_flash: true,
            eviction: EvictionPolicy::ShedSelf,
            prefill_chunk_tokens: usize::MAX,
            max_rows_per_tick: usize::MAX,
            prefix_cache_bytes: 0,
            backend: crate::cpu::backend::BackendChoice::Auto,
            spec_depth: 0,
            weight_flash_stall: None,
        }
    }
}

/// Per-request generation state: paged KV (one hybrid layer per decoder
/// layer), position, and the request's LoRA task. Created by
/// [`NativeModel::new_session`]; dropping it returns every KV page to the
/// model's pool.
pub struct NativeSession {
    pub kv: Vec<HybridKvLayer>,
    /// Positions generated so far (== sequence length).
    pub pos: usize,
    /// Select a loaded LoRA task for this session (§5.5 multitask).
    pub lora_task: Option<String>,
    /// The owning request's admission priority class
    /// (`Request::priority_class`, stamped by the backend adapter at
    /// session open). Under pool pressure [`NativeModel::make_room`]
    /// preempts the lowest class first, so background sessions absorb
    /// the spill traffic before interactive ones.
    pub priority_class: u8,
    /// fp32 K/V of the prompt tokens prefilled so far, one pair of
    /// buffers per decoder layer — present only **while the prompt is
    /// still being consumed in chunks**. Later chunks attend over this
    /// prefix with exactly the arithmetic a monolithic prefill uses over
    /// its own fresh K/V, which is what makes chunked prefill
    /// bit-identical to monolithic prefill (the quantized KV cache
    /// cannot serve that role: decode dequantization differs from the
    /// fresh fp32 rows). Dropped the moment the final chunk lands, so
    /// the transient DRAM cost — `layers × prompt × kv_dim × 8` bytes —
    /// is bounded by the prefill phase.
    prefill_stash: Option<PrefillStash>,
    /// The shared-prefix fp32 K/V this session attached at admission
    /// (`prefix_attach` hit): the first `fork` prompt tokens' exact
    /// full-precision history, read straight from the cache so the
    /// suffix's chunked attention is bit-identical to a cold prefill.
    /// Dropped with the prefill stash once the final chunk lands.
    shared_stash: Option<SharedPrefix>,
    /// Set at admission when the prefix cache should learn this prompt
    /// (cache enabled, prompt not already fully covered): the full prompt
    /// ids. A publisher stashes **every** chunk — including the last — so
    /// the finished fp32 K/V can be retained alongside the shared pages.
    publish: Option<Vec<usize>>,
    /// fp32 stash bytes currently charged to the pool's stash gauge —
    /// kept in sync with `prefill_stash_bytes()` (satellite 2: the gauge
    /// tracks live stashes at runtime, not just admission estimates).
    stash_charged: usize,
    /// This session's entry in the pool's holder registry (exact
    /// largest-holder eviction); unregistered on drop.
    holder: HolderId,
    /// The shared pool (stash gauge + holder registry bookkeeping).
    pool: Arc<KvPool>,
    /// Decrements the model's live-session count on drop (gates flash
    /// spill-store reclamation).
    _live: SessionGuard,
}

/// The retained fp32 prompt K/V (`[layers][tokens * kv_dim]`, row-major
/// per token) of a partially prefilled session.
struct PrefillStash {
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

/// A cache hit's attached fp32 prefix: the published stash (which may
/// cover more tokens than this session attached) plus this session's
/// fork point — attention reads exactly `fork` tokens of it.
struct SharedPrefix {
    stash: Arc<CachedStash>,
    fork: usize,
}

struct SessionGuard(Arc<AtomicUsize>);

impl Drop for SessionGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

impl NativeSession {
    /// Cached sequence length (uniform across layers by construction).
    pub fn kv_len(&self) -> usize {
        self.kv.first().map_or(0, |l| l.len())
    }

    /// Pool-accounted DRAM bytes of this session's resident KV.
    pub fn resident_kv_bytes(&self) -> usize {
        self.kv.iter().map(|l| l.resident_kv_bytes()).sum()
    }

    /// Records this session ever spilled to flash.
    pub fn spilled_records(&self) -> u64 {
        self.kv.iter().map(|l| l.spill_count()).sum()
    }

    /// Records this session ever restored from flash.
    pub fn restored_records(&self) -> u64 {
        self.kv.iter().map(|l| l.restore_count()).sum()
    }

    /// Terminal release of all KV (pool pages and spilled flash offsets):
    /// call once the session has produced its last token, so finished
    /// requests stop pressuring live ones. Spill/restore counters survive.
    pub fn release_kv(&mut self) {
        for l in &mut self.kv {
            l.release();
        }
        self.prefill_stash = None;
        self.shared_stash = None;
        self.publish = None;
        self.sync_stash_charge();
    }

    /// This session's id in the pool's holder registry.
    pub fn holder_id(&self) -> HolderId {
        self.holder
    }

    /// Pages this session references that are also referenced elsewhere
    /// (prefix-cache entries or sibling sessions).
    pub fn shared_kv_pages(&self) -> usize {
        self.kv.iter().map(|l| l.shared_page_count()).sum()
    }

    /// Reconcile the pool's stash gauge with this session's live fp32
    /// prefill stash (the attached `CachedStash` charges itself). Called
    /// after every stash mutation and on release/drop, so the gauge is
    /// exact at every tick boundary.
    fn sync_stash_charge(&mut self) {
        let now = self.prefill_stash.as_ref().map_or(0, |s| {
            (s.k.iter().map(Vec::len).sum::<usize>() + s.v.iter().map(Vec::len).sum::<usize>()) * 4
        });
        if now > self.stash_charged {
            self.pool.add_stash(now - self.stash_charged);
        } else if now < self.stash_charged {
            self.pool.sub_stash(self.stash_charged - now);
        }
        self.stash_charged = now;
    }

    /// DRAM bytes of the retained fp32 prompt K/V (non-zero only while a
    /// chunked prefill is in flight).
    pub fn prefill_stash_bytes(&self) -> usize {
        self.prefill_stash.as_ref().map_or(0, |s| {
            (s.k.iter().map(Vec::len).sum::<usize>() + s.v.iter().map(Vec::len).sum::<usize>()) * 4
        })
    }

    /// Preempt: push every resident KV record to flash and release all
    /// pages. Value-neutral — decode resumes via the streaming path.
    /// Returns records spilled.
    pub fn preempt_to_flash(&mut self) -> std::io::Result<usize> {
        let mut n = 0;
        for l in &mut self.kv {
            n += l.spill_all()?;
        }
        Ok(n)
    }

    /// Spill up to `records_per_layer` of the oldest resident records from
    /// *every* layer (KV grows uniformly across layers, so uniform
    /// shedding is the natural eviction unit). Returns total records
    /// spilled; 0 means nothing was resident. Value-neutral.
    pub fn shed_oldest(&mut self, records_per_layer: usize) -> std::io::Result<usize> {
        let mut n = 0;
        for l in &mut self.kv {
            n += l.shed_oldest(records_per_layer)?;
        }
        Ok(n)
    }
}

impl Drop for NativeSession {
    fn drop(&mut self) {
        // Uncharge any still-live stash and leave the holder registry —
        // pages themselves return to the pool via their handles' drops.
        self.pool.sub_stash(self.stash_charged);
        self.stash_charged = 0;
        self.pool.unregister_holder(self.holder);
    }
}

/// A loaded model (weights, embedding, LoRA bank, shared KV pool + flash).
/// Stateless over sessions: all forward methods take a [`NativeSession`].
pub struct NativeModel {
    pub config: ModelConfig,
    pub options: EngineOptions,
    /// Declared before `weights` so drop order joins in-flight prefetch
    /// jobs while the store they reference is still alive.
    prefetcher: BackgroundWorker,
    /// Layer-residency arena over flash-resident packed blobs. The
    /// lm_head, final norm and embedding below are pinned outside it.
    weights: WeightStore,
    fnorm: Vec<f32>,
    lm_head: QLinear,
    embedding: FlashEmbedding,
    embedding_dram: Option<Vec<f32>>,
    pub lora: LoraManager,
    /// Shared flash device all sessions spill KV to. Distinct from the
    /// weight store's device: `reclaim_flash` truncates this one, which
    /// must never eat weight blobs.
    flash: Arc<FlashSim>,
    /// Shared paged-KV arena all sessions draw from.
    kv_pool: Arc<KvPool>,
    /// Shared-prefix KV cache (copy-on-write pages + fp32 stash);
    /// disabled (budget 0) unless `EngineOptions::prefix_cache_bytes`
    /// opts in.
    prefix: Arc<PrefixCache>,
    /// Live sessions (spill-store reclamation is only safe at zero).
    live_sessions: Arc<AtomicUsize>,
    /// θ^(-2i/d) — kept for positions past `max_len` (rare overrun guard).
    inv_freq: Vec<f32>,
    /// Precomputed RoPE tables, `[max_len, head_dim/2]` row-major: paid
    /// once at load instead of a `powf`-derived `sin_cos` per element per
    /// token in the decode hot loop. Entries are computed exactly as the
    /// on-the-fly path did (`sin_cos(pos · inv_freq[i])`), so the lookup
    /// is bit-identical to recomputation.
    rope_sin: Vec<f32>,
    rope_cos: Vec<f32>,
    /// The compute backend every per-tile hot op routes through, selected
    /// once at load (`EngineOptions::backend`, overridable via
    /// `MNN_BACKEND`). All backends are bit-identical; only throughput
    /// differs.
    backend: Arc<dyn ComputeBackend>,
    /// Per-op invocation counters for the live backend (metrics only —
    /// never consulted by compute).
    ops: OpCounters,
}

fn invalid(msg: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, format!("weights.bin: {msg}"))
}

fn qlin(
    store: &FlashTensorStore,
    name: &str,
    bits: WeightBits,
    tile: TileConfig,
    bias: Option<Vec<f32>>,
) -> std::io::Result<QLinear> {
    let q = store.read(&format!("{name}.q"))?;
    let s = store.read(&format!("{name}.s"))?;
    let b = store.read(&format!("{name}.b"))?;
    let &[d0, d1] = q.shape.as_slice() else {
        return Err(invalid(&format!("{name}: expected 2-D weights, shape {:?}", q.shape)));
    };
    let (n, k) = match bits {
        WeightBits::Int8 => {
            if q.dtype != DT_I8 {
                return Err(invalid(&format!("{name}: expected i8 weights")));
            }
            (d0, d1)
        }
        WeightBits::Int4 => {
            if q.dtype != DT_U8 {
                return Err(invalid(&format!("{name}: expected packed u8 weights")));
            }
            (d0, d1 * 2)
        }
    };
    let scales = s.try_f32()?;
    let biases = b.try_f32()?;
    if scales.len() != n || biases.len() != n {
        return Err(invalid(&format!(
            "{name}: {} scales / {} biases for {n} output rows",
            scales.len(),
            biases.len()
        )));
    }
    let qm = QuantizedMatrix::from_parts(bits, n, k, q.data, &scales, &biases);
    Ok(QLinear::new(&qm, tile, bias))
}

/// Stream a bf16 table file into an f32 DRAM table in bounded chunks (the
/// baseline embedding config — no transient second copy of the table).
fn read_bf16_table(path: &Path, elems: usize) -> std::io::Result<Vec<f32>> {
    const CHUNK_ELEMS: usize = 128 << 10;
    let file = std::fs::File::open(path)?;
    let have = file.metadata()?.len();
    if have != (elems * 2) as u64 {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("{}: {have} bytes, expected {}", path.display(), elems * 2),
        ));
    }
    let mut r = std::io::BufReader::new(file);
    let mut table = vec![0f32; elems];
    let mut buf = vec![0u8; CHUNK_ELEMS * 2];
    let mut done = 0usize;
    while done < elems {
        let n = (elems - done).min(CHUNK_ELEMS);
        std::io::Read::read_exact(&mut r, &mut buf[..n * 2])?;
        crate::util::bf16::bytes_to_f32(&buf[..n * 2], &mut table[done..done + n]);
        done += n;
    }
    Ok(table)
}

impl NativeModel {
    /// Load from an artifacts directory (manifest + weights + embedding).
    ///
    /// The weight path is fully streaming: `weights.bin` goes file → flash
    /// in bounded chunks, layers are packed one at a time into blobs, and
    /// at most [`EngineOptions::weight_dram_bytes`] of packed layers stay
    /// resident — peak load DRAM is one layer's tensors plus the budget,
    /// never two copies of the weights.
    pub fn load(dir: &Path, options: EngineOptions) -> std::io::Result<NativeModel> {
        let manifest = Manifest::load(dir)?;
        let cfg = manifest.model.clone();
        let tile = options.tile;
        let backend_choice = options.backend;
        let soc = SocProfile::snapdragon_8gen3();
        // Raw tensors are staged on their own device, dropped after
        // packing; only the packed blobs live on the long-lived weight
        // device — the model doesn't carry the raw container around.
        let staging_flash = Arc::new(FlashSim::temp(soc.flash)?);
        let store =
            FlashTensorStore::stream_from_file(&dir.join("weights.bin"), staging_flash)?;
        let weight_flash = match options.weight_flash_stall {
            // Stall emulation: blob fetches sleep the tier's modeled read
            // time (writes during load stay instant — `append` never
            // sleeps), making tick time I/O-dominated on purpose.
            Some(tier) => Arc::new(FlashSim::create(
                &crate::util::unique_temp_path("mnn_flash", ".bin"),
                tier,
                true,
            )?),
            None => Arc::new(FlashSim::temp(soc.flash)?),
        };
        let mut builder = WeightStoreBuilder::new(weight_flash, options.weight_dram_bytes);
        for i in 0..cfg.layers {
            let p = format!("L{i}.");
            let layer = LayerWeights {
                wq: qlin(&store, &format!("{p}wq"), WeightBits::Int8, tile,
                         Some(store.read(&format!("{p}bq"))?.try_f32()?))?,
                wk: qlin(&store, &format!("{p}wk"), WeightBits::Int8, tile,
                         Some(store.read(&format!("{p}bk"))?.try_f32()?))?,
                wv: qlin(&store, &format!("{p}wv"), WeightBits::Int8, tile,
                         Some(store.read(&format!("{p}bv"))?.try_f32()?))?,
                wo: qlin(&store, &format!("{p}wo"), WeightBits::Int8, tile, None)?,
                gate: qlin(&store, &format!("{p}gate"), WeightBits::Int4, tile, None)?,
                up: qlin(&store, &format!("{p}up"), WeightBits::Int4, tile, None)?,
                down: qlin(&store, &format!("{p}down"), WeightBits::Int4, tile, None)?,
                ln1: store.read(&format!("{p}ln1"))?.try_f32()?,
                ln2: store.read(&format!("{p}ln2"))?.try_f32()?,
            };
            builder.push_layer(layer)?;
        }
        let weights = builder.finish();
        let fnorm = store.read("fnorm")?.try_f32()?;
        let lm_head = qlin(&store, "lm_head", WeightBits::Int8, tile, None)?;
        drop(store);
        let flash = Arc::new(FlashSim::temp(soc.flash)?);
        let embedding = FlashEmbedding::from_file(
            &dir.join(&manifest.embedding_file),
            cfg.vocab,
            cfg.hidden,
            FlashSim::temp(soc.flash)?,
        )?;
        let embedding_dram = if options.embedding_in_flash {
            None
        } else {
            // Baseline: decode-path DRAM residency.
            Some(read_bf16_table(&dir.join(&manifest.embedding_file), cfg.vocab * cfg.hidden)?)
        };
        let kv_pool = Arc::new(KvPool::new(options.kv_pool_bytes));
        let prefix = Arc::new(PrefixCache::new(options.prefix_cache_bytes));
        let half = cfg.head_dim() / 2;
        let inv_freq: Vec<f32> = (0..half)
            .map(|i| (1.0 / cfg.rope_theta.powf(i as f64 / half as f64)) as f32)
            .collect();
        let mut rope_sin = vec![0f32; cfg.max_len * half];
        let mut rope_cos = vec![0f32; cfg.max_len * half];
        if half > 0 {
            for (pos, (srow, crow)) in
                rope_sin.chunks_mut(half).zip(rope_cos.chunks_mut(half)).enumerate()
            {
                for ((s, c), &f) in srow.iter_mut().zip(crow.iter_mut()).zip(&inv_freq) {
                    let (sv, cv) = (pos as f32 * f).sin_cos();
                    *s = sv;
                    *c = cv;
                }
            }
        }
        Ok(NativeModel {
            config: cfg,
            options,
            prefetcher: BackgroundWorker::new("mnn-weight-prefetch"),
            weights,
            fnorm,
            lm_head,
            embedding,
            embedding_dram,
            lora: LoraManager::new(),
            flash,
            kv_pool,
            prefix,
            live_sessions: Arc::new(AtomicUsize::new(0)),
            inv_freq,
            rope_sin,
            rope_cos,
            backend: crate::cpu::backend::select(backend_choice),
            ops: OpCounters::default(),
        })
    }

    /// The shared paged-KV arena (admission control consults its budget).
    pub fn kv_pool(&self) -> &Arc<KvPool> {
        &self.kv_pool
    }

    /// The shared-prefix cache (introspection; disabled at budget 0).
    pub fn prefix_cache(&self) -> &Arc<PrefixCache> {
        &self.prefix
    }

    /// Prefix-cache counters with the pool's copy-on-write count folded
    /// in. The coordinator copies this into `EngineMetrics` alongside the
    /// weight-residency snapshot.
    pub fn prefix_metrics(&self) -> PrefixCacheMetrics {
        let mut m = self.prefix.metrics();
        m.cow_copies = self.kv_pool.stats().cow_copies;
        m
    }

    /// Failure injection (tests): make every subsequent KV spill append
    /// fail, as if the spill device went read-only. Already-spilled
    /// records stay readable; `false` heals.
    pub fn poison_kv_spill(&self, poisoned: bool) {
        self.flash.poison_appends(poisoned);
    }

    /// Page-granular KV bytes a prompt of `len` tokens will pin across all
    /// layers — what admission control must budget for, since the pool
    /// allocates whole [`PAGE_TOKENS`]-record pages per layer (record-level
    /// byte math would under-estimate pinned DRAM).
    pub fn prefill_kv_page_bytes(&self, len: usize) -> usize {
        let cfg = &self.config;
        let pages = len.div_ceil(PAGE_TOKENS);
        cfg.layers * pages * KvPool::page_bytes(cfg.kv_heads, cfg.head_dim())
    }

    /// Bytes currently held by the shared KV spill store (flash tier).
    pub fn spill_store_bytes(&self) -> u64 {
        self.flash.len()
    }

    /// Reclaim the spill store once no session references it: truncates
    /// the flash file so completed requests' spilled KV doesn't accumulate
    /// forever (the store is append-only while sessions are live). The
    /// coordinator calls this after requests complete. Returns true if the
    /// store was actually reclaimed.
    pub fn reclaim_flash(&self) -> bool {
        // Explicit live-session count (incremented in new_session,
        // decremented by the session guard's Drop): zero ⟺ no session
        // still owns spilled offsets into the store.
        self.live_sessions.load(Ordering::Relaxed) == 0 && self.flash.reset().is_ok()
    }

    /// Start a new generation session drawing pages from the shared pool.
    pub fn new_session(&self) -> NativeSession {
        let cfg = &self.config;
        let holder = self.kv_pool.register_holder();
        let kv = (0..cfg.layers)
            .map(|_| {
                let mut l = HybridKvLayer::with_pool_policy(
                    cfg.kv_heads,
                    cfg.head_dim(),
                    self.flash.clone(),
                    self.options.kv_budget_tokens,
                    self.kv_pool.clone(),
                    self.options.eviction,
                );
                l.set_holder(holder);
                l
            })
            .collect();
        self.live_sessions.fetch_add(1, Ordering::Relaxed);
        NativeSession {
            kv,
            pos: 0,
            lora_task: None,
            priority_class: 0,
            prefill_stash: None,
            shared_stash: None,
            publish: None,
            stash_charged: 0,
            holder,
            pool: self.kv_pool.clone(),
            _live: SessionGuard(self.live_sessions.clone()),
        }
    }

    /// Attach the longest cached prefix of `prompt` to a **fresh** session
    /// (read-only, refcounted pages — no new KV bytes) and mark the
    /// session a publisher when the cache doesn't already cover the whole
    /// prompt. Returns the fork point: prompt tokens the session may skip
    /// prefilling (`sess.pos` is advanced there; the engine starts the
    /// prompt's chunks at the fork). 0 on a miss, on a disabled cache, or
    /// on a non-empty session.
    pub fn prefix_attach(&self, sess: &mut NativeSession, prompt: &[usize]) -> usize {
        if !self.prefix.enabled() || sess.pos != 0 || !sess.kv.iter().all(|l| l.is_empty()) {
            return 0;
        }
        let hit = self.prefix.lookup(prompt);
        let covered = hit.as_ref().map_or(0, |h| h.covered);
        let fork = match hit {
            Some(h) => {
                for (l, pages) in sess.kv.iter_mut().zip(h.pages) {
                    l.attach_shared(pages, h.fork);
                }
                sess.pos = h.fork;
                let fork = h.fork;
                sess.shared_stash = Some(SharedPrefix { stash: h.stash, fork });
                fork
            }
            None => 0,
        };
        if covered < prompt.len() && prompt.len() >= 2 {
            sess.publish = Some(prompt.to_vec());
        }
        fork
    }

    /// Unreserved KV-pool headroom: budget − resident bytes (saturating).
    /// The engine's per-tick admission loop charges each outstanding
    /// prefill's [`prefill_reserve_bytes`](Self::prefill_reserve_bytes)
    /// against this, so a burst of admissions cannot overcommit the pool
    /// (the first admission of a tick still goes through
    /// [`make_room`](Self::make_room), which may preempt).
    pub fn kv_headroom(&self) -> usize {
        self.kv_pool.budget_bytes().saturating_sub(self.kv_pool.resident_bytes())
    }

    /// Page-granular KV bytes prefilling `prompt` will **newly** pin
    /// across all layers, after subtracting pages a prefix-cache hit
    /// would attach shared (those are already resident and counted).
    /// The fork's partially-filled boundary page still counts in full:
    /// the session's first append into it copy-on-writes a private page.
    fn prefill_suffix_page_bytes(&self, prompt: &[usize]) -> usize {
        let cfg = &self.config;
        let fork = self.prefix.peek_fork(prompt);
        let new_pages = prompt.len().div_ceil(PAGE_TOKENS) - fork / PAGE_TOKENS;
        cfg.layers * new_pages * KvPool::page_bytes(cfg.kv_heads, cfg.head_dim())
    }

    /// Admission-reservation estimate for prefilling `prompt`: the
    /// page-granular quantized-KV footprint of the **non-shared suffix**
    /// (a prefix-cache hit's attached pages are already pool-resident),
    /// plus — when the prompt is long enough that chunking will split it
    /// — the fp32 `PrefillStash` the session retains until its prefill
    /// completes (`layers × prompt × kv_dim × 8` bytes). Charging the
    /// stash here keeps a burst of long chunked prompts from
    /// overcommitting DRAM through memory the pool's page gauge never
    /// sees (the stash gauge tracks it once live).
    pub fn prefill_reserve_bytes(&self, prompt: &[usize]) -> usize {
        let pages = self.prefill_suffix_page_bytes(prompt);
        if prompt.len() > self.options.prefill_chunk_tokens {
            let stash = self.config.layers * prompt.len() * self.config.kv_dim() * 8;
            pages.saturating_add(stash)
        } else {
            pages
        }
    }

    /// Pool-visible portion of an in-flight prefill's reservation after
    /// `consumed` tokens of `prompt` landed: the quantized pages the
    /// session appended, minus pages a prefix-cache hit attached shared
    /// (those were resident before admission and never part of the
    /// reservation). The fp32 stash is deliberately excluded — it stays
    /// allocated (and gauge-charged) until the final chunk.
    pub fn prefill_visible_bytes(&self, prompt: &[usize], consumed: usize) -> usize {
        let cfg = &self.config;
        let fork = self.prefix.peek_fork(prompt).min(consumed);
        let pages = consumed.div_ceil(PAGE_TOKENS) - fork / PAGE_TOKENS;
        cfg.layers * pages * KvPool::page_bytes(cfg.kv_heads, cfg.head_dim())
    }

    /// Admission control: make room in the KV pool for prefilling
    /// `prompt` by preempting `running` sessions to flash until the
    /// prompt's page-granular suffix estimate fits the budget. Victims go
    /// **lowest priority class first** (`NativeSession::priority_class`),
    /// oldest (admission order) within a class — so background sessions
    /// absorb pool pressure before interactive ones, and a fleet with no
    /// priorities set preempts in exactly the old admission order. When
    /// the prompt could never fit even an empty pool, fleet-wide
    /// preemption is pointless and skipped — the new session degrades by
    /// spilling its own KV as it appends. Returns sessions preempted.
    pub fn make_room(
        &self,
        prompt: &[usize],
        running: &mut [&mut NativeSession],
    ) -> std::io::Result<u64> {
        let need = self.prefill_suffix_page_bytes(prompt);
        let mut preempted = 0;
        if self.kv_pool.would_exceed(need) && need <= self.kv_pool.budget_bytes() {
            let mut order: Vec<usize> = (0..running.len()).collect();
            // Stable sort: ties within a class keep admission order.
            order.sort_by_key(|&i| running.get(i).map_or(u8::MAX, |s| s.priority_class));
            for i in order {
                if !self.kv_pool.would_exceed(need) {
                    break;
                }
                let Some(s) = running.get_mut(i) else { continue };
                if s.resident_kv_bytes() > 0 {
                    s.preempt_to_flash()?;
                    preempted += 1;
                }
            }
            // If it still doesn't fit, admit anyway: appends degrade
            // gracefully by spilling to flash.
        }
        Ok(preempted)
    }

    /// The `EvictionPolicy::LargestHolder` enforcement pass: while the KV
    /// pool is over budget, spill one page-worth of oldest records per
    /// layer from the session referencing the most page bytes — chosen by
    /// the pool's **holder registry** (exact, shared pages included),
    /// not a per-session gauge. Refcount-aware: shedding a page a
    /// prefix-cache entry still references frees nothing pool-visible,
    /// so when a pass makes no byte progress the cache's LRU entries are
    /// reclaimed before trying again, and the loop stops once neither
    /// sessions nor the cache can shrink the pool further. The engine
    /// calls this before **and after** each fused tick, so the pool is
    /// back under budget at every tick boundary. A no-op under
    /// `ShedSelf` (appends restore the budget themselves). Returns
    /// records shed.
    pub fn enforce_kv_budget(
        &self,
        running: &mut [&mut NativeSession],
    ) -> std::io::Result<u64> {
        if self.options.eviction != EvictionPolicy::LargestHolder {
            return Ok(0);
        }
        let mut shed = 0u64;
        let mut last = usize::MAX;
        while self.kv_pool.over_budget() {
            let now = self.kv_pool.resident_bytes();
            if now >= last {
                // The previous shed freed nothing pool-visible (shared
                // pages survive at refcount > 0): drop cache entries —
                // a reclaimed entry's unshared pages free immediately —
                // and re-measure; stop when the cache is dry too.
                if !self.prefix.reclaim_lru() {
                    break;
                }
                last = usize::MAX;
                continue;
            }
            last = now;
            let victim = running
                .iter_mut()
                .filter(|s| s.resident_kv_bytes() > 0)
                .max_by_key(|s| self.kv_pool.holder_bytes(s.holder_id()));
            match victim {
                Some(v) => shed += v.shed_oldest(PAGE_TOKENS)? as u64,
                None => {
                    if !self.prefix.reclaim_lru() {
                        break;
                    }
                }
            }
        }
        Ok(shed)
    }

    fn embed(&self, ids: &[usize], out: &mut [f32]) -> std::io::Result<()> {
        if let Some(table) = &self.embedding_dram {
            let h = self.config.hidden;
            if h == 0 {
                return Ok(());
            }
            for (&id, dst) in ids.iter().zip(out.chunks_mut(h)) {
                let row = table
                    .get(id * h..(id + 1) * h)
                    .ok_or_else(|| invalid(&format!("token id {id} outside embedding table")))?;
                dst.copy_from_slice(row);
            }
            Ok(())
        } else {
            self.embedding.lookup_batch(ids, out)
        }
    }

    /// Rotate-half RoPE at position `pos` on one head vector in place.
    /// Sin/cos come from the load-time tables; positions past `max_len`
    /// (only reachable by driving the model outside the engine's context
    /// cap) fall back to direct computation, bit-identically.
    fn rope(&self, x: &mut [f32], pos: usize) {
        let half = x.len() / 2;
        self.ops.rope_heads.fetch_add(1, Ordering::Relaxed);
        if pos < self.config.max_len {
            let sin = &self.rope_sin[pos * half..(pos + 1) * half];
            let cos = &self.rope_cos[pos * half..(pos + 1) * half];
            self.backend.rope_apply(x, cos, sin);
        } else {
            let mut sin = vec![0f32; half];
            let mut cos = vec![0f32; half];
            for ((s, c), &f) in sin.iter_mut().zip(cos.iter_mut()).zip(&self.inv_freq) {
                let (sv, cv) = (pos as f32 * f).sin_cos();
                *s = sv;
                *c = cv;
            }
            self.backend.rope_apply(x, &cos, &sin);
        }
    }

    /// Parallel quantized Linear: y[e, h] = x·Wᵀ (+bias), balanced over
    /// h-tiles per §5.2. Disjoint output columns per worker — see safety
    /// comment.
    fn linear(&self, lin: &QLinear, x: &[f32], e: usize, out: &mut [f32]) {
        let pa =
            crate::reorder::pack::pack_activations(x, e, lin.in_features(), lin.activation_tile(e));
        let tiles = lin.h_tiles();
        self.ops.gemm_calls.fetch_add(1, Ordering::Relaxed);
        self.ops.gemm_tiles.fetch_add(tiles as u64, Ordering::Relaxed);
        let workers = &self.options.workers;
        let be = self.backend.as_ref();
        if workers.threads() <= 1 || tiles < 2 * workers.threads() {
            lin.forward_packed_with(be, &pa, out, 0, tiles);
            return;
        }
        struct Ptr(*mut f32, usize);
        // SAFETY: Ptr is a pointer+len pair shared read-only across workers;
        // each h-tile range writes a disjoint set of output columns
        // (c in [lo*h_p, hi*h_p)), every (r, c) exactly once, so no two
        // workers alias any element through it.
        unsafe impl Sync for Ptr {}
        let ptr = Ptr(out.as_mut_ptr(), out.len());
        let ptr = &ptr; // capture the Sync wrapper, not the raw field
        run_balanced(workers, tiles, move |_, lo, hi| {
            // SAFETY: ptr.0/ptr.1 come from the live `out` slice, which
            // outlives this call (run_balanced joins its workers before
            // returning), and disjoint tile columns mean the re-materialized
            // views never write the same element (see Sync impl above).
            let out = unsafe { std::slice::from_raw_parts_mut(ptr.0, ptr.1) };
            lin.forward_packed_with(be, &pa, out, lo, hi);
        });
    }

    fn lora_apply(
        &self,
        task: Option<&str>,
        layer: usize,
        which: &str,
        x: &[f32],
        e: usize,
        out: &mut [f32],
    ) {
        if let Some(task) = task {
            self.lora.apply(Some(task), &format!("L{layer}.{which}"), x, e, out);
        }
    }

    /// Roll the session back to its first `keep` positions, dropping the
    /// KV of everything newer: per-layer paged truncation (whole freed
    /// tail pages return to the pool immediately; spilled flash offsets
    /// past the cut are forgotten) plus the position counter. A no-op
    /// when `keep` is at or past the current position. Speculative
    /// decoding appends all `k+1` verify positions optimistically and
    /// calls this to keep only the accepted prefix — the page gauges
    /// must return exactly to the committed footprint (pinned by the
    /// rollback tests).
    pub fn truncate_kv(&self, sess: &mut NativeSession, keep: usize) {
        if keep >= sess.pos {
            return;
        }
        for l in &mut sess.kv {
            l.truncate(keep);
        }
        sess.pos = keep;
    }

    /// Page-granular KV bytes a verify row of `depth` draft tokens may
    /// pin beyond the plain decode append — `depth` extra records per
    /// layer, rounded up to whole pages. Zero at `depth == 0`, so
    /// non-speculating engines reserve nothing.
    pub fn verify_reserve_bytes(&self, depth: usize) -> usize {
        let cfg = &self.config;
        cfg.layers * depth.div_ceil(PAGE_TOKENS) * KvPool::page_bytes(cfg.kv_heads, cfg.head_dim())
    }

    /// Prefill `ids`; returns logits for the **last** token ([vocab]).
    /// Leaves the session's KV cache filled and `pos` advanced. A
    /// single-chunk [`prefill_chunk`](Self::prefill_chunk): monolithic
    /// and chunked prefill share one code path, so splitting a prompt is
    /// bit-identical by construction.
    // lint: allow(hot-panic): documented-panicking convenience wrapper; a final chunk always yields logits by forward_tick's contract
    pub fn prefill(&self, sess: &mut NativeSession, ids: &[usize]) -> Vec<f32> {
        assert!(!ids.is_empty());
        self.prefill_chunk(sess, ids, true).expect("final chunk returns logits")
    }

    /// Errors from the walk or its one row surfaced as panics — the
    /// convenience wrappers keep the old infallible signatures; callers
    /// needing per-row failure handling use
    /// [`forward_tick`](Self::forward_tick) directly (the engine does).
    // lint: allow(hot-panic): documented-panicking convenience wrapper over forward_tick; the engine consumes the Results directly
    fn one_row(
        &self,
        sess: &mut NativeSession,
        work: RowWork<'_>,
    ) -> Option<Vec<f32>> {
        self.forward_tick(&mut [sess], &[work])
            .expect("forward walk")
            .pop()
            .expect("one row")
            .expect("kv append")
    }

    /// Consume the next contiguous `ids` slice of the session's prompt
    /// (an incremental **prefill chunk**); returns last-row logits for
    /// the final chunk (`last`), `None` otherwise. Between chunks the
    /// session retains the prompt's fp32 K/V per layer, so every chunk's
    /// causal attention spans the chunk boundary with exactly the
    /// monolithic arithmetic (see [`forward_tick`](Self::forward_tick)).
    /// A batch-of-one `forward_tick`.
    pub fn prefill_chunk(
        &self,
        sess: &mut NativeSession,
        ids: &[usize],
        last: bool,
    ) -> Option<Vec<f32>> {
        self.one_row(sess, RowWork::Prefill { ids, last })
    }

    /// One decode step for `id` at the session's position; returns logits.
    /// A batch-of-one [`decode_batch`](Self::decode_batch): single-session
    /// and fused decode share one code path, which is what makes the
    /// batched round bit-identical to sequential decode by construction.
    // lint: allow(hot-panic): documented-panicking convenience wrapper; decode_batch returns exactly one row per session
    pub fn decode(&self, sess: &mut NativeSession, id: usize) -> Vec<f32> {
        self.decode_batch(&mut [sess], &[id]).pop().expect("one row")
    }

    /// One fused decode step for every session in the batch: a **single
    /// layer walk** serves all rows — one `weight_store` fetch (+ lookahead
    /// prefetch) per layer per call instead of one per layer per session,
    /// which is the §4.1 decode-bandwidth amortization continuous batching
    /// buys on this backend. Row r consumes `ids[r]` at `sessions[r]`'s own
    /// position and gets `sessions[r]`'s logits in the returned row r.
    /// An all-decode [`forward_tick`](Self::forward_tick); see there for
    /// the value-neutrality argument.
    // lint: allow(hot-panic): documented-panicking convenience wrapper over forward_tick; the engine consumes the Results directly
    pub fn decode_batch(&self, sessions: &mut [&mut NativeSession], ids: &[usize]) -> Vec<Vec<f32>> {
        assert_eq!(sessions.len(), ids.len(), "one token per session");
        let works: Vec<RowWork> = ids.iter().map(|&tok| RowWork::Decode { tok }).collect();
        self.forward_tick(sessions, &works)
            .expect("forward walk")
            .into_iter()
            .map(|row| row.expect("kv append").expect("decode rows return logits"))
            .collect()
    }

    /// One fused scheduler tick: a **single layer walk** serves every row
    /// — decode steps *and* prefill chunks — paying one `weight_store`
    /// fetch (+ budget-aware lookahead prefetch) per layer per call
    /// total. Row r performs `works[r]` on `sessions[r]`; the returned
    /// row r holds that session's logits (`None` for a non-final prefill
    /// chunk, whose logits nobody needs).
    ///
    /// Value-neutrality: rows are computed independently and row-major —
    /// per-row dynamic activation quantization, exact integer GEMM
    /// accumulation and per-row affine corrections (`cpu::gemm_q`),
    /// per-row RoPE at each token's own absolute position, per-row LoRA
    /// deltas keyed by each session's task, and per-session attention.
    /// The batch therefore produces **bit-identical** logits to running
    /// the rows one at a time, in any batch composition — the invariant
    /// the engine's fused ticks and the parity tests rely on.
    ///
    /// Chunked-prefill correctness: a prefill chunk's causal attention
    /// must span the chunk boundary with monolithic arithmetic. The
    /// session retains the prompt's fresh **fp32** K/V per layer while
    /// its prefill is in flight (`PrefillStash`); each chunk scores the
    /// stashed prefix first and its own fresh rows second — the exact key
    /// order, dot-product accumulation and one-softmax evaluation a
    /// monolithic [`prefill`](Self::prefill) performs (see
    /// [`chunked_prefill_attention`]) — then appends its K/V to both the
    /// quantized cache (for decode) and the stash (for the next chunk).
    /// The stash is dropped the moment the final chunk lands. Decode
    /// rows attend over the quantized cache through the online-softmax
    /// streaming path exactly as before (spill-neutral, §4.1).
    ///
    /// Shared-prefix sessions (`prefix_attach` hit) extend the same
    /// contract: their chunks attend over the **cached fp32 stash** for
    /// the attached `[0, fork)` region, then their own stash, then the
    /// fresh chunk — the same segment walk in the same global order
    /// ([`segmented_prefill_attention_with`]), so a warm prefill is
    /// bit-identical to a cold one. Publishers stash every chunk
    /// (including the last) and hand pages + stash to the prefix cache
    /// when their final chunk lands.
    ///
    /// Failure containment: errors are **per-row** `Err`s — a KV append
    /// or decode-stream failure poisons only its own row (later layers
    /// skip it; its session keeps `pos` un-advanced so the engine can
    /// release it) — except a weight-residency fetch failure, which is
    /// walk-level (outer `Err`): no row can proceed without the layer.
    // lint: allow(hot-index): per-row vectors (widths/offs/bases/row_err/out_rows) are built to length m at entry and per-layer vecs (kv/stash) to cfg.layers; every index is r < m or li < layers by loop bounds
    pub fn forward_tick(
        &self,
        sessions: &mut [&mut NativeSession],
        works: &[RowWork<'_>],
    ) -> std::io::Result<Vec<std::io::Result<Option<Vec<f32>>>>> {
        let m = sessions.len();
        assert_eq!(m, works.len(), "one work item per session");
        if m == 0 {
            return Ok(Vec::new());
        }
        let cfg = self.config.clone();
        let (h, hd, heads, kvh) = (cfg.hidden, cfg.head_dim(), cfg.heads, cfg.kv_heads);
        let kv_dim = cfg.kv_dim();
        // Attribute this walk's flash fetches to exactly one gauge — see
        // the accounting note at the end of the walk.
        let fetches_before = self.weights.metrics().total_fetches();
        // Row widths (decode rows are width 1), row offsets into the
        // packed [total, h] activation batch, and each row's base
        // position (all tokens of row r sit at `bases[r] + t`).
        let mut widths = Vec::with_capacity(m);
        let mut all_ids: Vec<usize> = Vec::with_capacity(m);
        for w in works {
            match *w {
                RowWork::Prefill { ids, .. } => {
                    assert!(!ids.is_empty(), "empty prefill chunk");
                    widths.push(ids.len());
                    all_ids.extend_from_slice(ids);
                }
                RowWork::Decode { tok } => {
                    widths.push(1);
                    all_ids.push(tok);
                }
                RowWork::Verify { toks } => {
                    assert!(!toks.is_empty(), "empty verify row");
                    widths.push(toks.len());
                    all_ids.extend_from_slice(toks);
                }
            }
        }
        let mut offs = Vec::with_capacity(m);
        let mut total = 0usize;
        for &w in &widths {
            offs.push(total);
            total += w;
        }
        let bases: Vec<usize> = sessions.iter().map(|s| s.pos).collect();
        // First chunk of a still-unfinished prompt: set up the per-layer
        // fp32 stash. A `last` chunk only stashes for **publishers**
        // (their finished fp32 K/V is retained in the prefix cache) —
        // otherwise only *later* chunks read the stash, so a single-chunk
        // (monolithic) prefill allocates none at all, keeping the default
        // path's memory profile unchanged.
        for (sess, w) in sessions.iter_mut().zip(works) {
            if let RowWork::Prefill { last, .. } = *w {
                if (!last || sess.publish.is_some()) && sess.prefill_stash.is_none() {
                    sess.prefill_stash = Some(PrefillStash {
                        k: vec![Vec::new(); cfg.layers],
                        v: vec![Vec::new(); cfg.layers],
                    });
                }
            }
        }
        // Per-row failure slots: a row that errors here is skipped in all
        // later layers (rows are independent) and surfaced as its own
        // `Err` — the engine fails that one request, not the batch.
        let mut row_err: Vec<Option<std::io::Error>> = Vec::with_capacity(m);
        row_err.resize_with(m, || None);
        let mut x = vec![0f32; total * h];
        self.embed(&all_ids, &mut x)?;
        let mut norm = vec![0f32; total * h];
        let mut q = vec![0f32; total * h];
        let mut k = vec![0f32; total * kv_dim];
        let mut v = vec![0f32; total * kv_dim];
        let mut attn = vec![0f32; total * h];
        let mut attn_out = vec![0f32; total * h];
        let mut gate = vec![0f32; total * cfg.inter];
        let mut up = vec![0f32; total * cfg.inter];
        let mut act = vec![0f32; total * cfg.inter];
        let mut mlp = vec![0f32; total * h];
        for li in 0..cfg.layers {
            // Kick upcoming layers' flash fetches before touching this one
            // so the reads overlap this layer's compute (§4.1 overlap,
            // weights edition) — issued once per layer per *tick*, not per
            // session. Depth is budget-aware; no-op when everything is
            // already resident.
            self.weights.prefetch_ahead(&self.prefetcher, li + 1);
            // Walk-level failure: without the layer no row can proceed.
            let layer = self.weights.layer(li)?;
            self.ops.norm_rows.fetch_add(total as u64, Ordering::Relaxed);
            self.backend.rmsnorm(&x, &layer.ln1, &mut norm, total, cfg.rms_eps);
            // total-row packed GEMMs: one pass shared by every row.
            self.linear(&layer.wq, &norm, total, &mut q);
            self.linear(&layer.wk, &norm, total, &mut k);
            self.linear(&layer.wv, &norm, total, &mut v);
            // Per-row LoRA bypass over each row's own slice, keyed by each
            // session's task (row-independent ⇒ equal to a whole-block
            // application).
            for (r, sess) in sessions.iter().enumerate() {
                let task = sess.lora_task.as_deref();
                if task.is_some() {
                    let (o, s_r) = (offs[r], widths[r]);
                    self.lora_apply(task, li, "wq", &norm[o * h..(o + s_r) * h], s_r,
                                    &mut q[o * h..(o + s_r) * h]);
                    self.lora_apply(task, li, "wk", &norm[o * h..(o + s_r) * h], s_r,
                                    &mut k[o * kv_dim..(o + s_r) * kv_dim]);
                    self.lora_apply(task, li, "wv", &norm[o * h..(o + s_r) * h], s_r,
                                    &mut v[o * kv_dim..(o + s_r) * kv_dim]);
                }
            }
            // Per-row RoPE at each token's own absolute position, then the
            // row's attention: chunked causal over the fp32 stash + fresh
            // rows for prefill chunks, online-softmax streaming over the
            // (possibly spilled) quantized cache for decode rows — one
            // code path with the sequential forms, so spilling and
            // batching stay *bit-exact* value-neutral.
            for (r, sess) in sessions.iter_mut().enumerate() {
                if row_err[r].is_some() {
                    continue; // poisoned row: skip its per-session work
                }
                let (o, s_r, base) = (offs[r], widths[r], bases[r]);
                for t in 0..s_r {
                    let qrow = &mut q[(o + t) * h..(o + t + 1) * h];
                    for hh in 0..heads {
                        self.rope(&mut qrow[hh * hd..(hh + 1) * hd], base + t);
                    }
                    let krow = &mut k[(o + t) * kv_dim..(o + t + 1) * kv_dim];
                    for hh in 0..kvh {
                        self.rope(&mut krow[hh * hd..(hh + 1) * hd], base + t);
                    }
                }
                match works[r] {
                    RowWork::Prefill { last, .. } => {
                        {
                            // The causal prefix, in global token order:
                            // the attached shared-prefix fp32 stash (a
                            // prefix-cache hit; sliced to this session's
                            // fork point — the cached entry may cover
                            // more), then whatever this prompt's earlier
                            // chunks stashed. (A fresh prompt — or a
                            // legacy multi-turn `prefill` on a session
                            // that already decoded, which never stashed —
                            // has an empty prefix, preserving the
                            // fresh-only attention semantics `prefill`
                            // always had; RoPE still uses absolute
                            // positions either way.)
                            let mut prefix: Vec<(&[f32], &[f32])> = Vec::with_capacity(2);
                            if let Some(sp) = sess.shared_stash.as_ref() {
                                prefix.push((
                                    &sp.stash.k[li][..sp.fork * kv_dim],
                                    &sp.stash.v[li][..sp.fork * kv_dim],
                                ));
                            }
                            if let Some(stash) = sess.prefill_stash.as_ref() {
                                if !stash.k[li].is_empty() {
                                    prefix.push((&stash.k[li], &stash.v[li]));
                                }
                            }
                            self.ops
                                .attention_rows
                                .fetch_add(s_r as u64, Ordering::Relaxed);
                            segmented_prefill_attention_with(
                                self.backend.as_ref(),
                                &q[o * h..(o + s_r) * h],
                                &prefix,
                                &k[o * kv_dim..(o + s_r) * kv_dim],
                                &v[o * kv_dim..(o + s_r) * kv_dim],
                                s_r,
                                heads,
                                kvh,
                                hd,
                                &mut attn[o * h..(o + s_r) * h],
                            );
                        }
                        // Quantized append (what decode will attend over),
                        // then — when another chunk will follow, or this
                        // session will publish — extend the fp32 stash so
                        // the next chunk's causal span stays exact.
                        for t in 0..s_r {
                            if let Err(e) = sess.kv[li].append(
                                &k[(o + t) * kv_dim..(o + t + 1) * kv_dim],
                                &v[(o + t) * kv_dim..(o + t + 1) * kv_dim],
                            ) {
                                row_err[r] = Some(e);
                                break;
                            }
                        }
                        if row_err[r].is_some() {
                            continue;
                        }
                        if !last || sess.publish.is_some() {
                            // Set up for every such row before the walk; a
                            // missing stash is a bug, contained to this row.
                            let Some(stash) = sess.prefill_stash.as_mut() else {
                                debug_assert!(false, "prefill stash missing");
                                row_err[r] = Some(invalid("prefill stash missing"));
                                continue;
                            };
                            stash.k[li].extend_from_slice(&k[o * kv_dim..(o + s_r) * kv_dim]);
                            stash.v[li].extend_from_slice(&v[o * kv_dim..(o + s_r) * kv_dim]);
                        }
                    }
                    RowWork::Decode { .. } => {
                        if let Err(e) = sess.kv[li].append(
                            &k[o * kv_dim..(o + 1) * kv_dim],
                            &v[o * kv_dim..(o + 1) * kv_dim],
                        ) {
                            row_err[r] = Some(e);
                            continue;
                        }
                        self.ops.attention_rows.fetch_add(1, Ordering::Relaxed);
                        if let Err(e) = sess.kv[li].decode_attention_streaming(
                            &q[o * h..(o + 1) * h],
                            heads,
                            &mut attn[o * h..(o + 1) * h],
                            KV_STREAM_CHUNK,
                        ) {
                            row_err[r] = Some(e);
                            continue;
                        }
                    }
                    RowWork::Verify { .. } => {
                        // Speculative verify: per position, append-then-
                        // stream — exactly the sequence of KV mutations and
                        // online-softmax reductions `s_r` sequential decode
                        // steps would perform. The streaming absorb visits
                        // keys in global token order regardless of chunk or
                        // spill boundaries, so each position's attention
                        // output is bit-identical to sequential decode by
                        // construction (the invariant the speculative
                        // engine's greedy == non-speculative test pins).
                        for t in 0..s_r {
                            if let Err(e) = sess.kv[li].append(
                                &k[(o + t) * kv_dim..(o + t + 1) * kv_dim],
                                &v[(o + t) * kv_dim..(o + t + 1) * kv_dim],
                            ) {
                                row_err[r] = Some(e);
                                break;
                            }
                            self.ops.attention_rows.fetch_add(1, Ordering::Relaxed);
                            if let Err(e) = sess.kv[li].decode_attention_streaming(
                                &q[(o + t) * h..(o + t + 1) * h],
                                heads,
                                &mut attn[(o + t) * h..(o + t + 1) * h],
                                KV_STREAM_CHUNK,
                            ) {
                                row_err[r] = Some(e);
                                break;
                            }
                        }
                    }
                }
            }
            self.linear(&layer.wo, &attn, total, &mut attn_out);
            for (r, sess) in sessions.iter().enumerate() {
                let task = sess.lora_task.as_deref();
                if task.is_some() {
                    let (o, s_r) = (offs[r], widths[r]);
                    self.lora_apply(task, li, "wo", &attn[o * h..(o + s_r) * h], s_r,
                                    &mut attn_out[o * h..(o + s_r) * h]);
                }
            }
            add_inplace(&mut x, &attn_out);
            self.ops.norm_rows.fetch_add(total as u64, Ordering::Relaxed);
            self.backend.rmsnorm(&x, &layer.ln2, &mut norm, total, cfg.rms_eps);
            self.linear(&layer.gate, &norm, total, &mut gate);
            self.linear(&layer.up, &norm, total, &mut up);
            self.ops.activation_rows.fetch_add(total as u64, Ordering::Relaxed);
            self.backend.swiglu(&gate, &up, &mut act);
            self.linear(&layer.down, &act, total, &mut mlp);
            add_inplace(&mut x, &mlp);
        }
        // Advance positions (failed rows stay put — their sessions are
        // about to be released by the engine); a completed prompt
        // publishes to the prefix cache if it's a publisher, then drops
        // its fp32 stashes. The pool's stash gauge tracks every stash
        // mutation, so `stash_bytes()` is exact at tick boundaries.
        let mut decode_tokens = 0u64;
        let mut prefill_tokens = 0u64;
        let mut decode_rows = 0u64;
        let mut prefill_rows = 0u64;
        for (r, sess) in sessions.iter_mut().enumerate() {
            match works[r] {
                RowWork::Prefill { last, .. } => {
                    prefill_rows += 1;
                    if row_err[r].is_some() {
                        continue;
                    }
                    sess.pos += widths[r];
                    prefill_tokens += widths[r] as u64;
                    if last {
                        self.finish_prefill(sess);
                    }
                    sess.sync_stash_charge();
                }
                RowWork::Decode { .. } => {
                    decode_rows += 1;
                    if row_err[r].is_some() {
                        continue;
                    }
                    sess.pos += 1;
                    decode_tokens += 1;
                }
                RowWork::Verify { .. } => {
                    // Decode-phase work: the row's full width (committed
                    // token + drafts, accepted or not) lands in the decode
                    // gauges — fetches-per-*committed*-token is computed by
                    // the engine/bench layer from its own commit counts.
                    decode_rows += 1;
                    if row_err[r].is_some() {
                        continue;
                    }
                    sess.pos += widths[r];
                    decode_tokens += widths[r] as u64;
                }
            }
        }
        // Fetch accounting: a walk's flash reads are shared by its rows
        // and cannot be attributed exactly per phase, so a mixed tick
        // splits the delta **proportionally to its row counts** — each
        // row drove the same shared layer walk once. Pure ticks land
        // wholly in their own gauge; token counts always do.
        let fetches = self.weights.metrics().total_fetches() - fetches_before;
        if decode_rows > 0 && prefill_rows > 0 {
            let decode_share = fetches * decode_rows / (decode_rows + prefill_rows);
            self.weights.note_decode_pass(decode_tokens, decode_share);
            self.weights.note_prefill_pass(prefill_tokens, fetches - decode_share);
        } else if decode_rows > 0 {
            self.weights.note_decode_pass(decode_tokens, fetches);
        } else {
            self.weights.note_prefill_pass(prefill_tokens, fetches);
        }
        // Logits only where someone will read them: successful decode
        // rows and final prefill chunks (their last token's row), through
        // one gathered lm_head pass — row-independent, so equal to
        // per-row passes. Verify rows read **every** position (`(start,
        // count)` spans), returning them concatenated. Failed rows yield
        // their error instead.
        let out_rows: Vec<Option<(usize, usize)>> = works
            .iter()
            .enumerate()
            .map(|(r, w)| {
                if row_err[r].is_some() {
                    return None;
                }
                match *w {
                    RowWork::Prefill { last: true, .. } => Some((offs[r] + widths[r] - 1, 1)),
                    RowWork::Prefill { last: false, .. } => None,
                    RowWork::Decode { .. } => Some((offs[r], 1)),
                    RowWork::Verify { .. } => Some((offs[r], widths[r])),
                }
            })
            .collect();
        let picked: Vec<usize> =
            out_rows.iter().flat_map(|o| o.map_or(0..0, |(s, n)| s..s + n)).collect();
        let n_out = picked.len();
        if n_out == 0 {
            return Ok(row_err
                .into_iter()
                .map(|e| match e {
                    Some(e) => Err(e),
                    None => Ok(None),
                })
                .collect());
        }
        let mut lastx = vec![0f32; n_out * h];
        for (j, &row) in picked.iter().enumerate() {
            lastx[j * h..(j + 1) * h].copy_from_slice(&x[row * h..(row + 1) * h]);
        }
        let mut fin = vec![0f32; n_out * h];
        self.ops.norm_rows.fetch_add(n_out as u64, Ordering::Relaxed);
        self.backend.rmsnorm(&lastx, &self.fnorm, &mut fin, n_out, cfg.rms_eps);
        let mut logits = vec![0f32; n_out * cfg.vocab];
        self.linear(&self.lm_head, &fin, n_out, &mut logits);
        if n_out == 1 {
            // Single output row (e.g. the `decode` wrapper): the buffer is
            // exactly that row — hand it back without a vocab-sized copy.
            let mut only = Some(logits);
            return Ok(row_err
                .into_iter()
                .zip(&out_rows)
                .map(|(e, o)| match e {
                    Some(e) => Err(e),
                    None => Ok(o.and_then(|_| only.take())),
                })
                .collect());
        }
        let mut cursor = 0usize;
        Ok(row_err
            .into_iter()
            .zip(&out_rows)
            .map(|(e, o)| match e {
                Some(e) => Err(e),
                None => Ok(o.map(|(_, n)| {
                    // Each surviving output row owns the next `n`
                    // consecutive vocab-sized slices of the gathered
                    // lm_head buffer, in batch order.
                    let flat = logits[cursor * cfg.vocab..(cursor + n) * cfg.vocab].to_vec();
                    cursor += n;
                    flat
                })),
            })
            .collect())
    }

    /// A prompt's final chunk landed: if the session was marked a
    /// publisher at admission, hand its quantized pages (handles cloned —
    /// refcount++, bytes counted once) and full fp32 stash to the prefix
    /// cache; then drop the transient stashes either way. Publishing is
    /// skipped — silently, it's an optimization — when any layer spilled
    /// during prefill (the resident pages no longer cover the prompt) or
    /// the stash doesn't span the whole prompt (legacy multi-turn
    /// prefill).
    // lint: allow(hot-index): stash k/v vecs are allocated with cfg.layers entries; li < cfg.layers by loop bound
    fn finish_prefill(&self, sess: &mut NativeSession) {
        if let Some(ids) = sess.publish.take() {
            let kv_dim = self.config.kv_dim();
            let complete = self.prefix.enabled()
                && sess.pos == ids.len()
                && sess
                    .kv
                    .iter()
                    .all(|l| l.spilled_tokens() == 0 && l.len() == ids.len());
            if complete {
                let mut k = Vec::with_capacity(self.config.layers);
                let mut v = Vec::with_capacity(self.config.layers);
                let mut ok = true;
                for li in 0..self.config.layers {
                    let mut kl: Vec<f32> = Vec::with_capacity(ids.len() * kv_dim);
                    let mut vl: Vec<f32> = Vec::with_capacity(ids.len() * kv_dim);
                    if let Some(sp) = sess.shared_stash.as_ref() {
                        kl.extend_from_slice(&sp.stash.k[li][..sp.fork * kv_dim]);
                        vl.extend_from_slice(&sp.stash.v[li][..sp.fork * kv_dim]);
                    }
                    if let Some(st) = sess.prefill_stash.as_ref() {
                        kl.extend_from_slice(&st.k[li]);
                        vl.extend_from_slice(&st.v[li]);
                    }
                    if kl.len() != ids.len() * kv_dim {
                        ok = false;
                        break;
                    }
                    k.push(kl);
                    v.push(vl);
                }
                if ok {
                    let pages: Vec<Vec<PageHandle>> =
                        sess.kv.iter().map(|l| l.share_prefix_pages(ids.len())).collect();
                    let tokens = ids.len();
                    let stash = CachedStash::charge(k, v, tokens, self.kv_pool.clone());
                    self.prefix.insert(ids, pages, stash);
                }
            }
        }
        sess.prefill_stash = None;
        sess.shared_stash = None;
    }

    /// Greedy generation convenience: prefill + n decode steps on `sess`.
    pub fn generate(&self, sess: &mut NativeSession, prompt: &[usize], n: usize) -> Vec<usize> {
        let logits = self.prefill(sess, prompt);
        let mut tok = crate::model::sampler::argmax(&logits);
        let mut out = vec![tok];
        for _ in 1..n {
            let logits = self.decode(sess, tok);
            tok = crate::model::sampler::argmax(&logits);
            out.push(tok);
        }
        out
    }

    /// Greedy generation on a fresh session (one-shot convenience).
    pub fn generate_once(&self, prompt: &[usize], n: usize) -> Vec<usize> {
        let mut sess = self.new_session();
        self.generate(&mut sess, prompt, n)
    }

    /// DRAM resident bytes of weights — memory accounting: the residency
    /// arena's current occupancy plus the pinned lm_head (and the DRAM
    /// embedding table in the baseline configuration).
    pub fn weight_dram_bytes(&self) -> usize {
        let emb = self.embedding_dram.as_ref().map_or(0, |t| t.len() * 4);
        self.weights.resident_bytes() + self.lm_head.weight_bytes() + emb
    }

    /// The layer-residency arena (budget / residency introspection).
    pub fn weight_store(&self) -> &WeightStore {
        &self.weights
    }

    /// Cumulative weight-residency counters + residency snapshot. The
    /// coordinator copies this into `EngineMetrics` after each drain.
    pub fn weight_metrics(&self) -> WeightResidencyMetrics {
        self.weights.metrics()
    }

    /// Live compute-backend snapshot: which backend is executing the hot
    /// ops, plus per-op invocation counts since load. The coordinator
    /// copies this into `EngineMetrics` alongside the residency snapshot.
    pub fn compute_metrics(&self) -> ComputeBackendMetrics {
        self.ops.snapshot(self.backend.name())
    }

    /// Name of the selected compute backend (`"scalar"`, `"simd-avx2"`,
    /// `"simd-neon"`).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::fixtures;

    fn load() -> (fixtures::Fixture, NativeModel) {
        fixtures::native_model(7, EngineOptions::default()).unwrap()
    }

    #[test]
    fn loads_and_generates_deterministically() {
        let (_fx, m) = load();
        let prompt = [104usize, 101, 108, 108, 111];
        let mut s1 = m.new_session();
        let a = m.generate(&mut s1, &prompt, 6);
        let mut s2 = m.new_session();
        let b = m.generate(&mut s2, &prompt, 6);
        assert_eq!(a, b);
        assert_eq!(a.len(), 6);
        assert!(a.iter().all(|&t| t < m.config.vocab));
    }

    #[test]
    fn decode_matches_prefill_rows() {
        // Same invariant as python/tests/test_model.py: prefill(x..y) last
        // logits == prefill(x) then decode(y..) last logits (up to the
        // batched-vs-single-row activation-quantization difference).
        let (_fx, m) = load();
        let ids = [3usize, 1, 4, 1, 5];
        let mut full_sess = m.new_session();
        let full = m.prefill(&mut full_sess, &ids);
        let mut step_sess = m.new_session();
        let mut step = m.prefill(&mut step_sess, &ids[..1]);
        for &t in &ids[1..] {
            step = m.decode(&mut step_sess, t);
        }
        let dot: f32 = full.iter().zip(&step).map(|(a, b)| a * b).sum();
        let na: f32 = full.iter().map(|v| v * v).sum::<f32>().sqrt();
        let nb: f32 = step.iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!(dot / (na * nb) > 0.995, "cos {}", dot / (na * nb));
        // The prefill top-1 must rank at the very top of the decode-path
        // logits too. (Exact argmax equality is too brittle for the
        // random-weight fixture: decode attends over the quantized KV while
        // batched prefill uses the raw fp32 K/V.)
        let top_full = crate::model::sampler::argmax(&full);
        let mut order: Vec<usize> = (0..step.len()).collect();
        order.sort_by(|&a, &b| step[b].total_cmp(&step[a]));
        assert!(
            order[..3].contains(&top_full),
            "prefill top-1 {top_full} not in decode top-3 {:?}",
            &order[..3]
        );
    }

    #[test]
    fn decode_batch_rows_match_sequential_decode_bitwise() {
        // The fused-round invariant at model level: one decode_batch call
        // produces, row for row, exactly the logits sequential decode
        // produces — across batch sizes, on fresh models from one fixture.
        let (fx, seq) = load();
        let bat = NativeModel::load(fx.dir(), EngineOptions::default()).unwrap();
        let prompts: [&[usize]; 3] = [&[5, 6, 7], &[100, 101], &[42, 43, 44, 45]];
        for take in 1..=prompts.len() {
            let mut seq_sessions: Vec<NativeSession> = Vec::new();
            let mut bat_sessions: Vec<NativeSession> = Vec::new();
            let mut toks = Vec::new();
            for p in &prompts[..take] {
                let mut s1 = seq.new_session();
                let l1 = seq.prefill(&mut s1, p);
                let mut s2 = bat.new_session();
                let l2 = bat.prefill(&mut s2, p);
                assert_eq!(l1, l2, "prefill parity");
                toks.push(crate::model::sampler::argmax(&l1));
                seq_sessions.push(s1);
                bat_sessions.push(s2);
            }
            for step in 0..4 {
                let batched = {
                    let mut refs: Vec<&mut NativeSession> =
                        bat_sessions.iter_mut().collect();
                    bat.decode_batch(&mut refs, &toks)
                };
                for (r, sess) in seq_sessions.iter_mut().enumerate() {
                    let single = seq.decode(sess, toks[r]);
                    assert_eq!(
                        single, batched[r],
                        "batch {take} step {step} row {r} diverged"
                    );
                    toks[r] = crate::model::sampler::argmax(&single);
                }
            }
        }
    }

    #[test]
    fn mixed_prefill_and_decode_rows_share_one_walk_bit_identically() {
        // The fused-tick invariant: one forward_tick serving a decode row
        // AND another session's prefill chunk produces, row for row,
        // exactly what the solo paths produce.
        let (fx, solo) = load();
        let fused = NativeModel::load(fx.dir(), EngineOptions::default()).unwrap();
        let pa = [5usize, 6, 7];
        let pb = [40usize, 41, 42, 43, 44, 45];
        // Solo reference: A prefills then decodes twice; B prefills.
        let mut sa = solo.new_session();
        let la = solo.prefill(&mut sa, &pa);
        let mut ta = crate::model::sampler::argmax(&la);
        let mut a_decode = Vec::new();
        for _ in 0..2 {
            let l = solo.decode(&mut sa, ta);
            ta = crate::model::sampler::argmax(&l);
            a_decode.push(l);
        }
        let mut sb = solo.new_session();
        let lb_solo = solo.prefill(&mut sb, &pb);
        // Fused: A's two decode steps ride the same walks as B's two
        // 3-token prefill chunks.
        let mut fa = fused.new_session();
        let fla = fused.prefill(&mut fa, &pa);
        assert_eq!(fla, la, "prefill parity between loads");
        let mut fb = fused.new_session();
        let mut fta = crate::model::sampler::argmax(&fla);
        let mut lb_fused = None;
        for (i, chunk) in pb.chunks(3).enumerate() {
            let last = i == 1;
            let works = [RowWork::Decode { tok: fta }, RowWork::Prefill { ids: chunk, last }];
            let rows = {
                let mut refs = [&mut fa, &mut fb];
                fused.forward_tick(&mut refs, &works).expect("weight walk")
            };
            let da =
                rows[0].as_ref().expect("row ok").as_ref().expect("decode row logits");
            assert_eq!(da, &a_decode[i], "fused decode row {i} diverged");
            fta = crate::model::sampler::argmax(da);
            if last {
                lb_fused = rows[1].as_ref().expect("row ok").clone();
            } else {
                assert!(
                    rows[1].as_ref().expect("row ok").is_none(),
                    "non-final chunk has no logits"
                );
                assert!(fb.prefill_stash_bytes() > 0, "stash held between chunks");
            }
        }
        assert_eq!(lb_fused.expect("final chunk"), lb_solo, "chunked prefill row diverged");
        assert_eq!(fb.prefill_stash_bytes(), 0, "stash dropped with the final chunk");
    }

    #[test]
    fn verify_row_is_bit_identical_to_sequential_decode() {
        // The speculative-verify invariant: one Verify row over
        // [committed, d1, d2, d3] returns per-position logits equal bit
        // for bit to four sequential decode steps, and truncate_kv rolls
        // the appended tail back to exactly the committed footprint.
        let (fx, seq) = load();
        let ver = NativeModel::load(fx.dir(), EngineOptions::default()).unwrap();
        let prompt = [5usize, 6, 7, 8];
        let mut ss = seq.new_session();
        let ls = seq.prefill(&mut ss, &prompt);
        let mut sv = ver.new_session();
        let lv = ver.prefill(&mut sv, &prompt);
        assert_eq!(ls, lv, "prefill parity between loads");
        let committed = crate::model::sampler::argmax(&ls);
        let toks = [committed, 3usize, 250, 9];
        let expect: Vec<Vec<f32>> = toks.iter().map(|&t| seq.decode(&mut ss, t)).collect();
        let flat = {
            let rows = ver
                .forward_tick(&mut [&mut sv], &[RowWork::Verify { toks: &toks }])
                .expect("weight walk");
            rows.into_iter().next().unwrap().expect("row ok").expect("verify logits")
        };
        assert_eq!(flat.len(), toks.len() * ver.config.vocab);
        for (i, want) in expect.iter().enumerate() {
            let got = &flat[i * ver.config.vocab..(i + 1) * ver.config.vocab];
            assert_eq!(got, want.as_slice(), "verify position {i} diverged");
        }
        assert_eq!(sv.pos, prompt.len() + toks.len());
        // Rollback: keep the committed token plus two accepted drafts.
        let keep = prompt.len() + 3;
        ver.truncate_kv(&mut sv, keep);
        assert_eq!(sv.pos, keep);
        assert_eq!(sv.kv[0].len(), keep);
        // A subsequent decode continues bit-identically from the kept
        // prefix: compare against a session that never speculated.
        let cont = ver.decode(&mut sv, 11);
        let mut fresh = seq.new_session();
        seq.prefill(&mut fresh, &prompt);
        for &t in &toks[..3] {
            seq.decode(&mut fresh, t);
        }
        let cont_ref = seq.decode(&mut fresh, 11);
        assert_eq!(cont, cont_ref, "post-rollback decode diverged");
    }

    #[test]
    fn kv_grows_with_tokens() {
        let (_fx, m) = load();
        let mut sess = m.new_session();
        m.prefill(&mut sess, &[1, 2, 3]);
        assert_eq!(sess.kv[0].len(), 3);
        assert_eq!(sess.pos, 3);
        m.decode(&mut sess, 9);
        assert_eq!(sess.kv[0].len(), 4);
        assert_eq!(sess.pos, 4);
    }

    #[test]
    fn sessions_are_isolated() {
        // Interleaving another session must not change a session's output:
        // the invariant continuous batching rests on.
        let (_fx, m) = load();
        let mut alone = m.new_session();
        let solo = m.generate(&mut alone, &[5, 6, 7], 4);
        let mut a = m.new_session();
        let mut b = m.new_session();
        let la = m.prefill(&mut a, &[5, 6, 7]);
        let _lb = m.prefill(&mut b, &[200, 201, 202, 203]);
        let mut tok = crate::model::sampler::argmax(&la);
        let mut interleaved = vec![tok];
        for _ in 1..4 {
            let _ = m.decode(&mut b, 9); // foreign session activity
            let l = m.decode(&mut a, tok);
            tok = crate::model::sampler::argmax(&l);
            interleaved.push(tok);
        }
        assert_eq!(solo, interleaved, "session isolation");
    }

    #[test]
    fn kv_spill_does_not_change_output() {
        let (fx, plain) = load();
        let spilled_model = NativeModel::load(
            fx.dir(),
            EngineOptions { kv_budget_tokens: 2, ..EngineOptions::default() },
        )
        .unwrap();
        let prompt = [10usize, 20, 30, 40, 50, 60];
        let a = plain.generate_once(&prompt, 4);
        let mut sess = spilled_model.new_session();
        let b = spilled_model.generate(&mut sess, &prompt, 4);
        assert_eq!(a, b, "spilling is value-neutral");
        assert!(sess.kv[0].spilled_tokens() > 0, "budget actually spilled");
    }

    #[test]
    fn pool_budget_spill_does_not_change_output() {
        // Byte-budget pressure on the shared pool must also be
        // value-neutral: same tokens, pages within budget after appends.
        let (fx, plain) = load();
        let page = crate::kv::KvPool::page_bytes(
            plain.config.kv_heads,
            plain.config.head_dim(),
        );
        // One page for a 2-layer model: the second layer's page always
        // tips the pool over budget, forcing eviction to flash.
        let tight = NativeModel::load(
            fx.dir(),
            EngineOptions { kv_pool_bytes: page, ..EngineOptions::default() },
        )
        .unwrap();
        let prompt = [10usize, 20, 30, 40, 50, 60];
        let a = plain.generate_once(&prompt, 4);
        let mut sess = tight.new_session();
        let b = tight.generate(&mut sess, &prompt, 4);
        assert_eq!(a, b, "pool pressure is value-neutral");
        assert!(sess.spilled_records() > 0);
        assert!(tight.kv_pool().resident_bytes() <= tight.kv_pool().budget_bytes());
    }

    #[test]
    fn weight_budget_below_packed_total_is_bit_identical() {
        // The weight-residency acceptance invariant at model level: a DRAM
        // budget smaller than the packed weights produces the exact same
        // tokens, with flash traffic and evictions visible in metrics.
        let (fx, plain) = load();
        let total = plain.weight_metrics().packed_bytes;
        assert!(total > 0);
        let tight = NativeModel::load(
            fx.dir(),
            EngineOptions { weight_dram_bytes: total / 2, ..EngineOptions::default() },
        )
        .unwrap();
        let prompt = [10usize, 20, 30, 40, 50];
        assert_eq!(
            plain.generate_once(&prompt, 4),
            tight.generate_once(&prompt, 4),
            "weight residency is bit-exact value-neutral"
        );
        let wm = tight.weight_metrics();
        assert!(wm.under_pressure(), "{wm:?}");
        assert!(wm.flash_read_s > 0.0);
        assert!(tight.weight_store().resident_bytes() <= total / 2);
        // The unlimited model never touched flash for weights after load.
        let um = plain.weight_metrics();
        assert_eq!(um.demand_fetches, 0);
        assert_eq!(um.evictions, 0);
        assert_eq!(um.resident_bytes, total);
    }

    #[test]
    fn flash_spill_store_reclaimed_after_sessions_end() {
        let (_fx, m) = fixtures::native_model(
            7,
            EngineOptions { kv_budget_tokens: 2, ..EngineOptions::default() },
        )
        .unwrap();
        {
            let mut sess = m.new_session();
            m.prefill(&mut sess, &[1, 2, 3, 4, 5, 6]);
            assert!(m.spill_store_bytes() > 0, "token budget spilled to flash");
            assert!(!m.reclaim_flash(), "live session blocks reclamation");
        }
        assert!(m.reclaim_flash(), "no sessions left: store reclaimable");
        assert_eq!(m.spill_store_bytes(), 0);
        // The engine still serves correctly from a reclaimed store.
        let out = m.generate_once(&[1, 2, 3, 4, 5, 6], 3);
        assert_eq!(out.len(), 3);
    }

    #[test]
    fn session_drop_returns_pages_to_pool() {
        let (_fx, m) = load();
        {
            let mut sess = m.new_session();
            m.prefill(&mut sess, &[1, 2, 3, 4, 5]);
            assert!(m.kv_pool().resident_bytes() > 0);
        }
        assert_eq!(m.kv_pool().resident_bytes(), 0);
    }

    #[test]
    fn flash_vs_dram_embedding_identical() {
        let (fx, flash) = load();
        let dram = NativeModel::load(
            fx.dir(),
            EngineOptions { embedding_in_flash: false, ..EngineOptions::default() },
        )
        .unwrap();
        let prompt = [7usize, 8, 9];
        assert_eq!(flash.generate_once(&prompt, 3), dram.generate_once(&prompt, 3));
        assert!(dram.weight_dram_bytes() > flash.weight_dram_bytes());
    }

    #[test]
    fn multithread_matches_single_thread() {
        let (fx, one) = load();
        let four = NativeModel::load(
            fx.dir(),
            EngineOptions {
                workers: WorkerConfig { rates: vec![1.0, 0.72, 0.72, 0.72] },
                ..EngineOptions::default()
            },
        )
        .unwrap();
        let prompt = [42usize, 43, 44, 45];
        assert_eq!(one.generate_once(&prompt, 4), four.generate_once(&prompt, 4));
    }

    #[test]
    fn lora_changes_output_only_for_its_task() {
        let (_fx, mut m) = load();
        let mut base_sess = m.new_session();
        let base = m.prefill(&mut base_sess, &[5, 6, 7]);
        // Load an adapter but don't select it: output unchanged.
        let mut rng = crate::util::rng::Rng::new(9);
        let h = m.config.hidden;
        let mut layers = std::collections::HashMap::new();
        layers.insert("L0.wq".to_string(),
                      crate::lora::LoraAdapter::random(&mut rng, h, h, 4));
        m.lora.load_task("style", layers);
        let mut same_sess = m.new_session();
        let same = m.prefill(&mut same_sess, &[5, 6, 7]);
        assert_eq!(base, same);
        // Select it: output changes.
        let mut changed_sess = m.new_session();
        changed_sess.lora_task = Some("style".into());
        let changed = m.prefill(&mut changed_sess, &[5, 6, 7]);
        assert_ne!(base, changed);
    }
}
