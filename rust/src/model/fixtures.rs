//! Self-contained test fixture: a tiny deterministic 2-layer model written
//! as a real artifacts directory (manifest.json + weights.bin +
//! embedding.bin) the engine loads exactly like AOT output.
//!
//! The seed repo's integration tests silently early-returned when
//! `artifacts/` (produced by the Python AOT pipeline) was absent, which
//! made the whole tier-1 suite vacuous. This module removes that
//! dependency for everything that doesn't strictly need compiled HLO
//! graphs: weights are seeded-random, quantized with the same
//! `QuantizedMatrix` scheme the exporter uses, and serialized through
//! [`WeightWriter`] — the bit-exact mirror of the weights.bin parser.
//!
//! Only PJRT-backed tests (which execute lowered graphs) still require
//! real AOT artifacts; those are `#[ignore]`d with a reason instead of
//! early-returning.

use std::path::{Path, PathBuf};

use crate::model::config::ModelConfig;
use crate::model::native::{EngineOptions, NativeModel};
use crate::model::weights::{WeightWriter, DT_I8, DT_U8};
use crate::quant::asym::{QuantizedMatrix, WeightBits};
use crate::util::bf16;
use crate::util::rng::Rng;

/// The fixture's dimensions: 2 layers, GQA (4 heads / 2 kv heads), int4
/// MLP-compatible even reduce dims, vocab covering the byte tokenizer's
/// specials (≥ 258).
pub fn fixture_config() -> ModelConfig {
    fixture_config_with_layers(2)
}

/// Like [`fixture_config`] with a configurable decoder depth. Weight
/// residency tests want ≥ 3 layers so LRU eviction and the one-ahead
/// prefetch actually churn (with 2 layers, budget + prefetch covers the
/// whole model).
pub fn fixture_config_with_layers(layers: usize) -> ModelConfig {
    ModelConfig {
        name: format!("fixture-{layers}l"),
        vocab: 512,
        hidden: 32,
        inter: 48,
        layers,
        heads: 4,
        kv_heads: 2,
        max_len: 128,
        rope_theta: 1e4,
        rms_eps: 1e-6,
    }
}

/// A generated artifacts directory; removed from disk on drop.
pub struct Fixture {
    dir: PathBuf,
}

impl Fixture {
    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

impl Drop for Fixture {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

/// Quantize a seeded-random [n, k] matrix and write its q/s/b tensors —
/// the triplet `model::native::qlin` expects.
fn push_linear(w: &mut WeightWriter, rng: &mut Rng, name: &str, n: usize, k: usize,
               bits: WeightBits) {
    let dense: Vec<f32> = rng.normal_vec(n * k).iter().map(|x| x * 0.1).collect();
    let qm = QuantizedMatrix::from_f32(&dense, n, k, bits);
    match bits {
        WeightBits::Int8 => w.push(&format!("{name}.q"), DT_I8, &[n, k], &qm.data),
        WeightBits::Int4 => w.push(&format!("{name}.q"), DT_U8, &[n, k / 2], &qm.data),
    }
    let scales: Vec<f32> = qm.params.iter().map(|p| p.scale).collect();
    let biases: Vec<f32> = qm.params.iter().map(|p| p.bias).collect();
    w.push_f32(&format!("{name}.s"), &[n], &scales);
    w.push_f32(&format!("{name}.b"), &[n], &biases);
}

/// Write an all-zero q/s/b triplet: with scale = bias = 0 every
/// dequantized weight is exactly 0.0, so the projection's output is a
/// hard zero whatever the activations are (no `QuantizedMatrix::from_f32`
/// round-trip, whose degenerate-range handling could produce nonzero
/// bias).
fn push_zero_linear(w: &mut WeightWriter, name: &str, n: usize, k: usize, bits: WeightBits) {
    match bits {
        WeightBits::Int8 => w.push(&format!("{name}.q"), DT_I8, &[n, k], &vec![0u8; n * k]),
        WeightBits::Int4 => w.push(&format!("{name}.q"), DT_U8, &[n, k / 2], &vec![0u8; n * k / 2]),
    }
    w.push_f32(&format!("{name}.s"), &[n], &vec![0.0; n]);
    w.push_f32(&format!("{name}.b"), &[n], &vec![0.0; n]);
}

/// Norm weights near 1.0 (rmsnorm gains).
fn norm_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    rng.normal_vec(n).iter().map(|x| 1.0 + 0.05 * x).collect()
}

/// Write a complete, loadable artifacts directory under the system temp
/// dir. Deterministic in `seed` (the directory name is unique per call;
/// the *contents* depend only on the seed).
pub fn write_fixture(seed: u64) -> std::io::Result<Fixture> {
    write_fixture_with_layers(seed, 2)
}

/// [`write_fixture`] at a chosen decoder depth. Contents are
/// deterministic in `(seed, layers)`.
pub fn write_fixture_with_layers(seed: u64, layers: usize) -> std::io::Result<Fixture> {
    write_fixture_inner(seed, layers, None)
}

/// The shared writer. `passthrough_above = Some(t)` makes every layer
/// `i >= t` a residual passthrough: its attention-output (`wo`) and
/// MLP-down (`down`) projections are written as exact zeros, so both
/// residual branches contribute 0.0 and the layer is an identity map on
/// the hidden state — while still computing attention and appending real
/// KV records (junk-seeded), so KV paging/spill behave like a real layer.
/// Passthrough layers draw from a *separate* RNG stream so the real
/// layers, final norm, lm_head and embedding consume exactly the same
/// bytes of `rng` as a model written without the passthrough tail.
/// `None` is byte-identical to the historical single-stream writer.
fn write_fixture_inner(
    seed: u64,
    layers: usize,
    passthrough_above: Option<usize>,
) -> std::io::Result<Fixture> {
    let cfg = fixture_config_with_layers(layers);
    let dir = crate::util::unique_temp_path("mnn_fixture", "");
    std::fs::create_dir_all(&dir)?;
    let mut rng = Rng::new(seed);
    let mut junk = Rng::new(seed ^ 0x9E37_79B9_7F4A_7C15);
    let (h, kvd, inter, vocab) = (cfg.hidden, cfg.kv_dim(), cfg.inter, cfg.vocab);

    let mut w = WeightWriter::new();
    for i in 0..cfg.layers {
        let p = format!("L{i}.");
        let zero = passthrough_above.is_some_and(|t| i >= t);
        let r = if zero { &mut junk } else { &mut rng };
        push_linear(&mut w, r, &format!("{p}wq"), h, h, WeightBits::Int8);
        push_linear(&mut w, r, &format!("{p}wk"), kvd, h, WeightBits::Int8);
        push_linear(&mut w, r, &format!("{p}wv"), kvd, h, WeightBits::Int8);
        if zero {
            push_zero_linear(&mut w, &format!("{p}wo"), h, h, WeightBits::Int8);
        } else {
            push_linear(&mut w, r, &format!("{p}wo"), h, h, WeightBits::Int8);
        }
        push_linear(&mut w, r, &format!("{p}gate"), inter, h, WeightBits::Int4);
        push_linear(&mut w, r, &format!("{p}up"), inter, h, WeightBits::Int4);
        if zero {
            push_zero_linear(&mut w, &format!("{p}down"), h, inter, WeightBits::Int4);
        } else {
            push_linear(&mut w, r, &format!("{p}down"), h, inter, WeightBits::Int4);
        }
        let bq: Vec<f32> = r.normal_vec(h).iter().map(|x| x * 0.01).collect();
        w.push_f32(&format!("{p}bq"), &[h], &bq);
        let bk: Vec<f32> = r.normal_vec(kvd).iter().map(|x| x * 0.01).collect();
        w.push_f32(&format!("{p}bk"), &[kvd], &bk);
        let bv: Vec<f32> = r.normal_vec(kvd).iter().map(|x| x * 0.01).collect();
        w.push_f32(&format!("{p}bv"), &[kvd], &bv);
        w.push_f32(&format!("{p}ln1"), &[h], &norm_vec(r, h));
        w.push_f32(&format!("{p}ln2"), &[h], &norm_vec(r, h));
    }
    w.push_f32("fnorm", &[h], &norm_vec(&mut rng, h));
    push_linear(&mut w, &mut rng, "lm_head", vocab, h, WeightBits::Int8);
    std::fs::write(dir.join("weights.bin"), w.finish())?;

    // bf16 [vocab, hidden] embedding table.
    let table: Vec<f32> = rng.normal_vec(vocab * h).iter().map(|x| x * 0.1).collect();
    let mut emb = Vec::with_capacity(table.len() * 2);
    for &v in &table {
        emb.extend_from_slice(&bf16::f32_to_bf16(v).to_le_bytes());
    }
    std::fs::write(dir.join("embedding.bin"), emb)?;

    // Manifest with empty graph/weight tables: the native backend ignores
    // them; the PJRT backend (which needs compiled graphs) cannot load a
    // fixture and is tested separately against real AOT artifacts.
    let manifest = format!(
        concat!(
            "{{\n",
            "  \"model\": {{\"name\": \"{name}\", \"vocab\": {vocab}, \"hidden\": {hidden}, ",
            "\"inter\": {inter}, \"layers\": {layers}, \"heads\": {heads}, ",
            "\"kv_heads\": {kv_heads}, \"max_len\": {max_len}, ",
            "\"rope_theta\": 10000.0, \"rms_eps\": 1e-6}},\n",
            "  \"prefill_buckets\": [16, 64],\n",
            "  \"weights\": [],\n",
            "  \"graphs\": {{}},\n",
            "  \"embedding\": {{\"file\": \"embedding.bin\"}},\n",
            "  \"seed\": {seed}\n",
            "}}\n"
        ),
        name = cfg.name,
        vocab = vocab,
        hidden = h,
        inter = inter,
        layers = cfg.layers,
        heads = cfg.heads,
        kv_heads = cfg.kv_heads,
        max_len = cfg.max_len,
        seed = seed,
    );
    std::fs::write(dir.join("manifest.json"), manifest)?;
    Ok(Fixture { dir })
}

/// A paired target/draft artifact set for speculative decoding, sharing
/// one seed. The target has `target_layers` decoder layers, but layers
/// ≥ 1 are residual passthroughs (zero `wo`/`down`); the draft is the
/// 1-layer model built from exactly the same layer-0 / final-norm /
/// lm_head / embedding bytes. Both therefore compute the *same function*
/// bit-identically: a draft whose greedy proposals the target always
/// accepts, which pins down acceptance bookkeeping in tests, while the
/// target still pays full-depth KV (so paging, spill, and rollback are
/// exercised at real depth).
pub fn write_paired_fixture(seed: u64, target_layers: usize)
                            -> std::io::Result<(Fixture, Fixture)> {
    assert!(target_layers >= 1, "target needs at least the shared layer 0");
    let target = write_fixture_inner(seed, target_layers, Some(1))?;
    let draft = write_fixture_inner(seed, 1, None)?;
    Ok((target, draft))
}

/// Fixture + loaded native model in one call (the common test setup).
/// Keep the `Fixture` alive as long as you may reload from its dir.
pub fn native_model(seed: u64, options: EngineOptions)
                    -> std::io::Result<(Fixture, NativeModel)> {
    let fx = write_fixture(seed)?;
    let m = NativeModel::load(fx.dir(), options)?;
    Ok((fx, m))
}

/// Real AOT artifacts when `artifacts/manifest.json` exists in the
/// working directory, otherwise a generated fixture — the examples' and
/// benches' "always runnable" model source. Keep the returned guard
/// (`Some` only in the fixture case) alive while loading from the path.
pub fn artifacts_or_fixture(seed: u64) -> std::io::Result<(Option<Fixture>, PathBuf)> {
    let aot = PathBuf::from("artifacts");
    if aot.join("manifest.json").exists() {
        return Ok((None, aot));
    }
    let fx = write_fixture(seed)?;
    let dir = fx.dir().to_path_buf();
    Ok((Some(fx), dir))
}

/// [`native_model`] at a chosen decoder depth (weight-residency tests).
pub fn native_model_with_layers(
    seed: u64,
    layers: usize,
    options: EngineOptions,
) -> std::io::Result<(Fixture, NativeModel)> {
    let fx = write_fixture_with_layers(seed, layers)?;
    let m = NativeModel::load(fx.dir(), options)?;
    Ok((fx, m))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::Manifest;

    #[test]
    fn fixture_manifest_parses_and_matches_config() {
        let fx = write_fixture(1).unwrap();
        let m = Manifest::load(fx.dir()).unwrap();
        assert_eq!(m.model, fixture_config());
        assert_eq!(m.prefill_buckets, vec![16, 64]);
        assert_eq!(m.embedding_file, "embedding.bin");
        assert_eq!(m.seed, 1);
    }

    #[test]
    fn fixture_contents_are_seed_deterministic() {
        let a = write_fixture(3).unwrap();
        let b = write_fixture(3).unwrap();
        let c = write_fixture(4).unwrap();
        for f in ["weights.bin", "embedding.bin", "manifest.json"] {
            let wa = std::fs::read(a.dir().join(f)).unwrap();
            let wb = std::fs::read(b.dir().join(f)).unwrap();
            assert_eq!(wa, wb, "{f}: same seed, same bytes");
        }
        assert_ne!(
            std::fs::read(a.dir().join("weights.bin")).unwrap(),
            std::fs::read(c.dir().join("weights.bin")).unwrap(),
            "different seed, different weights"
        );
    }

    #[test]
    fn fixture_model_loads_and_generates_in_vocab() {
        let (_fx, m) = native_model(2, EngineOptions::default()).unwrap();
        let out = m.generate_once(&[104, 101, 108, 108, 111], 8);
        assert_eq!(out.len(), 8);
        assert!(out.iter().all(|&t| t < m.config.vocab));
        let logits = {
            let mut sess = m.new_session();
            m.prefill(&mut sess, &[1, 2, 3])
        };
        assert_eq!(logits.len(), m.config.vocab);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn deep_fixture_loads_and_generates() {
        let (_fx, m) = native_model_with_layers(6, 4, EngineOptions::default()).unwrap();
        assert_eq!(m.config.layers, 4);
        assert_eq!(m.config.name, "fixture-4l");
        let out = m.generate_once(&[1, 2, 3], 5);
        assert_eq!(out.len(), 5);
        assert!(out.iter().all(|&t| t < m.config.vocab));
    }

    #[test]
    fn paired_fixture_is_backward_compatible_and_bitwise_equivalent() {
        // The refactored writer with no passthrough must be byte-identical
        // to what `write_fixture_with_layers` always produced (same seed →
        // same bytes is already covered; here: the paired draft equals a
        // plain 1-layer fixture of the same seed).
        let (tfx, dfx) = write_paired_fixture(11, 4).unwrap();
        let plain = write_fixture_with_layers(11, 1).unwrap();
        for f in ["weights.bin", "embedding.bin", "manifest.json"] {
            assert_eq!(
                std::fs::read(dfx.dir().join(f)).unwrap(),
                std::fs::read(plain.dir().join(f)).unwrap(),
                "{f}: draft is a plain 1-layer fixture"
            );
        }

        let target = NativeModel::load(tfx.dir(), EngineOptions::default()).unwrap();
        let draft = NativeModel::load(dfx.dir(), EngineOptions::default()).unwrap();
        assert_eq!(target.config.layers, 4);
        assert_eq!(draft.config.layers, 1);

        // The passthrough tail must not perturb the computed function:
        // prefill logits and several greedy decode steps agree bitwise.
        let prompt = [7usize, 300, 12, 451];
        let mut ts = target.new_session();
        let mut ds = draft.new_session();
        let tl = target.prefill(&mut ts, &prompt);
        let dl = draft.prefill(&mut ds, &prompt);
        assert_eq!(tl, dl, "passthrough layers changed the prefill logits");
        let mut tok = crate::model::sampler::argmax(&tl);
        for step in 0..4 {
            let a = target.decode(&mut ts, tok);
            let b = draft.decode(&mut ds, tok);
            assert_eq!(a, b, "decode step {step} diverged");
            tok = crate::model::sampler::argmax(&a);
        }
    }

    #[test]
    fn fixture_dir_removed_on_drop() {
        let path = {
            let fx = write_fixture(5).unwrap();
            fx.dir().to_path_buf()
        };
        assert!(!path.exists());
    }
}
