//! weights.bin reader (container written by python/compile/aot.py):
//!   magic "MNNW" | u32 version | u32 count |
//!   per tensor: u16 name_len | name | u8 dtype | u8 ndim | u32 dims[] |
//!               u64 nbytes | raw bytes.

use std::collections::HashMap;
use std::path::Path;

/// dtype codes shared with the exporter.
pub const DT_F32: u8 = 0;
pub const DT_I8: u8 = 1;
pub const DT_U8: u8 = 2;
pub const DT_BF16: u8 = 3;
pub const DT_I32: u8 = 4;

/// One loaded tensor.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub name: String,
    pub dtype: u8,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    /// View as f32 (panics on dtype mismatch).
    pub fn as_f32(&self) -> Vec<f32> {
        assert_eq!(self.dtype, DT_F32, "{}: not f32", self.name);
        self.data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }

    pub fn as_i8(&self) -> &[u8] {
        assert_eq!(self.dtype, DT_I8, "{}: not i8", self.name);
        &self.data
    }

    pub fn as_u8(&self) -> &[u8] {
        assert_eq!(self.dtype, DT_U8, "{}: not u8", self.name);
        &self.data
    }
}

/// The whole weight file, indexed by name (order preserved).
pub struct WeightFile {
    pub order: Vec<String>,
    pub tensors: HashMap<String, Tensor>,
}

fn bad(msg: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, format!("weights.bin: {msg}"))
}

impl WeightFile {
    pub fn load(path: &Path) -> std::io::Result<WeightFile> {
        let bytes = std::fs::read(path)?;
        Self::parse(&bytes)
    }

    pub fn parse(bytes: &[u8]) -> std::io::Result<WeightFile> {
        let mut off = 0usize;
        let take = |off: &mut usize, n: usize| -> std::io::Result<&[u8]> {
            if *off + n > bytes.len() {
                return Err(bad("truncated"));
            }
            let s = &bytes[*off..*off + n];
            *off += n;
            Ok(s)
        };
        if take(&mut off, 4)? != b"MNNW" {
            return Err(bad("bad magic"));
        }
        let version = u32::from_le_bytes(take(&mut off, 4)?.try_into().unwrap());
        if version != 1 {
            return Err(bad(&format!("unsupported version {version}")));
        }
        let count = u32::from_le_bytes(take(&mut off, 4)?.try_into().unwrap()) as usize;
        let mut order = Vec::with_capacity(count);
        let mut tensors = HashMap::with_capacity(count);
        for _ in 0..count {
            let nlen = u16::from_le_bytes(take(&mut off, 2)?.try_into().unwrap()) as usize;
            let name = String::from_utf8(take(&mut off, nlen)?.to_vec())
                .map_err(|_| bad("non-utf8 name"))?;
            let hdr = take(&mut off, 2)?;
            let (dtype, ndim) = (hdr[0], hdr[1] as usize);
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(u32::from_le_bytes(take(&mut off, 4)?.try_into().unwrap()) as usize);
            }
            let nbytes = u64::from_le_bytes(take(&mut off, 8)?.try_into().unwrap()) as usize;
            let data = take(&mut off, nbytes)?.to_vec();
            order.push(name.clone());
            tensors.insert(name.clone(), Tensor { name, dtype, shape, data });
        }
        if off != bytes.len() {
            return Err(bad("trailing bytes"));
        }
        Ok(WeightFile { order, tensors })
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.tensors.get(name)
    }

    pub fn require(&self, name: &str) -> std::io::Result<&Tensor> {
        self.get(name).ok_or_else(|| bad(&format!("missing tensor {name}")))
    }

    /// Total payload bytes.
    pub fn nbytes(&self) -> usize {
        self.tensors.values().map(|t| t.data.len()).sum()
    }
}

/// weights.bin writer — the exact mirror of [`WeightFile::parse`]. Used by
/// `model::fixtures` to generate self-contained test artifacts without the
/// Python exporter.
pub struct WeightWriter {
    count: u32,
    body: Vec<u8>,
}

impl WeightWriter {
    pub fn new() -> Self {
        WeightWriter { count: 0, body: Vec::new() }
    }

    /// Append one tensor entry. `data` must already be the raw bytes of
    /// `dtype` (e.g. packed nibbles for int4 → `DT_U8`).
    pub fn push(&mut self, name: &str, dtype: u8, shape: &[usize], data: &[u8]) {
        assert!(name.len() <= u16::MAX as usize);
        assert!(shape.len() <= u8::MAX as usize);
        self.body.extend_from_slice(&(name.len() as u16).to_le_bytes());
        self.body.extend_from_slice(name.as_bytes());
        self.body.push(dtype);
        self.body.push(shape.len() as u8);
        for &d in shape {
            self.body.extend_from_slice(&(d as u32).to_le_bytes());
        }
        self.body.extend_from_slice(&(data.len() as u64).to_le_bytes());
        self.body.extend_from_slice(data);
        self.count += 1;
    }

    /// Push a f32 tensor from a slice.
    pub fn push_f32(&mut self, name: &str, shape: &[usize], data: &[f32]) {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        self.push(name, DT_F32, shape, &bytes);
    }

    /// Finish the container: magic | version | count | entries.
    pub fn finish(self) -> Vec<u8> {
        let mut out = Vec::with_capacity(12 + self.body.len());
        out.extend_from_slice(b"MNNW");
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&self.count.to_le_bytes());
        out.extend_from_slice(&self.body);
        out
    }
}

impl Default for WeightWriter {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a tiny container in-memory (mirror of the python writer).
    fn sample() -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(b"MNNW");
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&2u32.to_le_bytes());
        // "a": f32 [2,2]
        b.extend_from_slice(&3u16.to_le_bytes());
        b.extend_from_slice(b"t.a");
        b.push(DT_F32);
        b.push(2);
        b.extend_from_slice(&2u32.to_le_bytes());
        b.extend_from_slice(&2u32.to_le_bytes());
        b.extend_from_slice(&16u64.to_le_bytes());
        for v in [1.0f32, 2.0, 3.0, 4.0] {
            b.extend_from_slice(&v.to_le_bytes());
        }
        // "b": i8 [3]
        b.extend_from_slice(&3u16.to_le_bytes());
        b.extend_from_slice(b"t.b");
        b.push(DT_I8);
        b.push(1);
        b.extend_from_slice(&3u32.to_le_bytes());
        b.extend_from_slice(&3u64.to_le_bytes());
        b.extend_from_slice(&[0xFF, 0x00, 0x7F]);
        b
    }

    #[test]
    fn parse_sample() {
        let wf = WeightFile::parse(&sample()).unwrap();
        assert_eq!(wf.order, vec!["t.a", "t.b"]);
        let a = wf.require("t.a").unwrap();
        assert_eq!(a.shape, vec![2, 2]);
        assert_eq!(a.as_f32(), vec![1.0, 2.0, 3.0, 4.0]);
        let b = wf.require("t.b").unwrap();
        assert_eq!(b.as_i8(), &[0xFF, 0x00, 0x7F]);
        assert_eq!(wf.nbytes(), 19);
    }

    #[test]
    fn rejects_corruption() {
        let mut s = sample();
        s[0] = b'X';
        assert!(WeightFile::parse(&s).is_err());
        let mut t = sample();
        t.truncate(t.len() - 1);
        assert!(WeightFile::parse(&t).is_err());
        let mut u = sample();
        u.push(0);
        assert!(WeightFile::parse(&u).is_err());
    }

    #[test]
    fn writer_roundtrips_through_parser() {
        let mut w = WeightWriter::new();
        w.push_f32("t.a", &[2, 2], &[1.0, 2.0, 3.0, 4.0]);
        w.push("t.b", DT_I8, &[3], &[0xFF, 0x00, 0x7F]);
        let bytes = w.finish();
        // Bit-identical to the hand-rolled sample container.
        assert_eq!(bytes, sample());
        let wf = WeightFile::parse(&bytes).unwrap();
        assert_eq!(wf.require("t.a").unwrap().as_f32(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn parses_real_artifacts() {
        let p = std::path::PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/weights.bin"));
        if !p.exists() {
            return;
        }
        let wf = WeightFile::load(&p).unwrap();
        assert!(wf.order.len() >= 100, "{} tensors", wf.order.len());
        assert!(wf.get("L0.wq.q").is_some());
        assert!(wf.get("lm_head.q").is_some());
        // int4 MLP weights are packed: gate has half the bytes of its dims.
        let gate = wf.require("L0.gate.q").unwrap();
        assert_eq!(gate.dtype, DT_U8);
        // gate: [inter=704, hidden/2=128] — two nibbles per byte along k.
        assert_eq!(gate.shape, vec![704, 128]);
    }
}
