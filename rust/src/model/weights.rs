//! weights.bin reader (container written by python/compile/aot.py):
//!   magic "MNNW" | u32 version | u32 count |
//!   per tensor: u16 name_len | name | u8 dtype | u8 ndim | u32 dims[] |
//!               u64 nbytes | raw bytes.
//!
//! The parser is **streaming**: [`stream_entries`] walks the container from
//! any `Read`, validating each header (known dtype, overflow-checked shape
//! product, `nbytes == elements × dtype size`) *before* handing the sink a
//! reader restricted to exactly the payload bytes. [`WeightFile::parse`]
//! buffers tensors through it; the weight residency manager
//! (`memory::weight_store`) streams payloads straight onto flash instead,
//! so the load path never holds the whole file in DRAM.

use std::collections::HashMap;
use std::io::{ErrorKind, Read};
use std::path::Path;

/// dtype codes shared with the exporter.
pub const DT_F32: u8 = 0;
pub const DT_I8: u8 = 1;
pub const DT_U8: u8 = 2;
pub const DT_BF16: u8 = 3;
pub const DT_I32: u8 = 4;

/// Bytes per element of a dtype code (None for unknown codes).
pub fn dtype_size(dtype: u8) -> Option<usize> {
    match dtype {
        DT_F32 | DT_I32 => Some(4),
        DT_BF16 => Some(2),
        DT_I8 | DT_U8 => Some(1),
        _ => None,
    }
}

/// One loaded tensor.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub name: String,
    pub dtype: u8,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    /// View as f32 (panics on dtype mismatch).
    pub fn as_f32(&self) -> Vec<f32> {
        assert_eq!(self.dtype, DT_F32, "{}: not f32", self.name);
        self.data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }

    /// View as f32, returning an error instead of panicking — the load-path
    /// variant (a corrupt artifact must fail the load, not the process).
    pub fn try_f32(&self) -> std::io::Result<Vec<f32>> {
        if self.dtype != DT_F32 {
            return Err(bad(&format!("{}: expected f32, dtype {}", self.name, self.dtype)));
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn as_i8(&self) -> &[u8] {
        assert_eq!(self.dtype, DT_I8, "{}: not i8", self.name);
        &self.data
    }

    pub fn as_u8(&self) -> &[u8] {
        assert_eq!(self.dtype, DT_U8, "{}: not u8", self.name);
        &self.data
    }
}

/// Header of one container entry, handed to streaming sinks ahead of the
/// payload bytes. Already validated: dtype is known and `nbytes` equals the
/// shape's element count times the dtype size (both overflow-checked).
#[derive(Clone, Debug)]
pub struct TensorMeta {
    pub name: String,
    pub dtype: u8,
    pub shape: Vec<usize>,
    pub nbytes: usize,
}

/// The whole weight file, indexed by name (order preserved).
pub struct WeightFile {
    pub order: Vec<String>,
    pub tensors: HashMap<String, Tensor>,
}

fn bad(msg: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, format!("weights.bin: {msg}"))
}

fn map_eof(e: std::io::Error) -> std::io::Error {
    if e.kind() == ErrorKind::UnexpectedEof {
        bad("truncated")
    } else {
        e
    }
}

fn read_arr<R: Read, const N: usize>(r: &mut R) -> std::io::Result<[u8; N]> {
    let mut a = [0u8; N];
    r.read_exact(&mut a).map_err(map_eof)?;
    Ok(a)
}

/// Parse the container from `r`, invoking `sink` once per tensor with its
/// validated header and a reader restricted to exactly the payload bytes.
/// The sink may consume any prefix of the payload; the remainder is drained
/// (and a short file is reported as truncation). Header fields are checked
/// with overflow-safe arithmetic, so a crafted `nbytes`/shape can neither
/// wrap an offset (the old parser's `off + n` panic) nor justify an
/// allocation larger than the shape allows.
pub fn stream_entries<R, F>(mut r: R, mut sink: F) -> std::io::Result<()>
where
    R: Read,
    F: FnMut(&TensorMeta, &mut dyn Read) -> std::io::Result<()>,
{
    let magic: [u8; 4] = read_arr(&mut r)?;
    if &magic != b"MNNW" {
        return Err(bad("bad magic"));
    }
    let version = u32::from_le_bytes(read_arr(&mut r)?);
    if version != 1 {
        return Err(bad(&format!("unsupported version {version}")));
    }
    let count = u32::from_le_bytes(read_arr(&mut r)?) as usize;
    for _ in 0..count {
        let nlen = u16::from_le_bytes(read_arr(&mut r)?) as usize;
        let mut name_buf = vec![0u8; nlen];
        r.read_exact(&mut name_buf).map_err(map_eof)?;
        let name = String::from_utf8(name_buf).map_err(|_| bad("non-utf8 name"))?;
        let hdr: [u8; 2] = read_arr(&mut r)?;
        let (dtype, ndim) = (hdr[0], hdr[1] as usize);
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(u32::from_le_bytes(read_arr(&mut r)?) as usize);
        }
        let nbytes64 = u64::from_le_bytes(read_arr(&mut r)?);
        let size = dtype_size(dtype)
            .ok_or_else(|| bad(&format!("{name}: unknown dtype {dtype}")))?;
        let elements = shape
            .iter()
            .try_fold(1u64, |acc, &d| acc.checked_mul(d as u64))
            .ok_or_else(|| bad(&format!("{name}: shape element count overflows")))?;
        let expected = elements
            .checked_mul(size as u64)
            .ok_or_else(|| bad(&format!("{name}: shape byte size overflows")))?;
        if nbytes64 != expected {
            return Err(bad(&format!(
                "{name}: payload {nbytes64} B does not match shape {shape:?} × {size} B/elem"
            )));
        }
        let nbytes = usize::try_from(nbytes64)
            .map_err(|_| bad(&format!("{name}: payload too large for this platform")))?;
        let meta = TensorMeta { name, dtype, shape, nbytes };
        let mut payload = (&mut r).take(nbytes64);
        sink(&meta, &mut payload)?;
        // Drain whatever prefix the sink left unread; coming up short means
        // the file ended inside this payload.
        std::io::copy(&mut payload, &mut std::io::sink())?;
        if payload.limit() > 0 {
            return Err(bad("truncated"));
        }
    }
    let mut probe = [0u8; 1];
    match r.read_exact(&mut probe) {
        Ok(()) => Err(bad("trailing bytes")),
        Err(e) if e.kind() == ErrorKind::UnexpectedEof => Ok(()),
        Err(e) => Err(e),
    }
}

impl WeightFile {
    pub fn load(path: &Path) -> std::io::Result<WeightFile> {
        Self::from_reader(std::io::BufReader::new(std::fs::File::open(path)?))
    }

    pub fn parse(bytes: &[u8]) -> std::io::Result<WeightFile> {
        Self::from_reader(bytes)
    }

    /// Parse from any reader, buffering each tensor's payload. One copy per
    /// tensor — the old parser additionally held the entire file. Payloads
    /// grow incrementally in bounded chunks, so a header lying about its
    /// size fails with `truncated` before any oversized allocation.
    pub fn from_reader<R: Read>(r: R) -> std::io::Result<WeightFile> {
        const CHUNK: usize = 1 << 20;
        let mut order = Vec::new();
        let mut tensors = HashMap::new();
        stream_entries(r, |meta, payload| {
            let mut data = Vec::new();
            let mut buf = vec![0u8; meta.nbytes.min(CHUNK)];
            let mut remaining = meta.nbytes;
            while remaining > 0 {
                let n = remaining.min(buf.len());
                payload.read_exact(&mut buf[..n]).map_err(map_eof)?;
                data.extend_from_slice(&buf[..n]);
                remaining -= n;
            }
            order.push(meta.name.clone());
            tensors.insert(
                meta.name.clone(),
                Tensor {
                    name: meta.name.clone(),
                    dtype: meta.dtype,
                    shape: meta.shape.clone(),
                    data,
                },
            );
            Ok(())
        })?;
        Ok(WeightFile { order, tensors })
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.tensors.get(name)
    }

    pub fn require(&self, name: &str) -> std::io::Result<&Tensor> {
        self.get(name).ok_or_else(|| bad(&format!("missing tensor {name}")))
    }

    /// Total payload bytes.
    pub fn nbytes(&self) -> usize {
        self.tensors.values().map(|t| t.data.len()).sum()
    }
}

/// weights.bin writer — the exact mirror of [`WeightFile::parse`]. Used by
/// `model::fixtures` to generate self-contained test artifacts without the
/// Python exporter.
pub struct WeightWriter {
    count: u32,
    body: Vec<u8>,
}

impl WeightWriter {
    pub fn new() -> Self {
        WeightWriter { count: 0, body: Vec::new() }
    }

    /// Append one tensor entry. `data` must already be the raw bytes of
    /// `dtype` (e.g. packed nibbles for int4 → `DT_U8`).
    pub fn push(&mut self, name: &str, dtype: u8, shape: &[usize], data: &[u8]) {
        // The container's field widths are fixed (the Python exporter
        // writes the same layout); values that don't fit fail loudly
        // instead of truncating the way a bare `as` cast would.
        let (Ok(nlen), Ok(rank)) = (u16::try_from(name.len()), u8::try_from(shape.len())) else {
            panic!("tensor {name}: name length or rank exceeds container field");
        };
        self.body.extend_from_slice(&nlen.to_le_bytes());
        self.body.extend_from_slice(name.as_bytes());
        self.body.push(dtype);
        self.body.push(rank);
        for &d in shape {
            let Ok(d32) = u32::try_from(d) else {
                panic!("tensor {name}: dimension {d} exceeds u32 container field");
            };
            self.body.extend_from_slice(&d32.to_le_bytes());
        }
        self.body.extend_from_slice(&(data.len() as u64).to_le_bytes());
        self.body.extend_from_slice(data);
        self.count += 1;
    }

    /// Push a f32 tensor from a slice.
    pub fn push_f32(&mut self, name: &str, shape: &[usize], data: &[f32]) {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        self.push(name, DT_F32, shape, &bytes);
    }

    /// Finish the container: magic | version | count | entries.
    pub fn finish(self) -> Vec<u8> {
        let mut out = Vec::with_capacity(12 + self.body.len());
        out.extend_from_slice(b"MNNW");
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&self.count.to_le_bytes());
        out.extend_from_slice(&self.body);
        out
    }
}

impl Default for WeightWriter {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    /// Build a tiny container in-memory (mirror of the python writer).
    fn sample() -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(b"MNNW");
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&2u32.to_le_bytes());
        // "a": f32 [2,2]
        b.extend_from_slice(&3u16.to_le_bytes());
        b.extend_from_slice(b"t.a");
        b.push(DT_F32);
        b.push(2);
        b.extend_from_slice(&2u32.to_le_bytes());
        b.extend_from_slice(&2u32.to_le_bytes());
        b.extend_from_slice(&16u64.to_le_bytes());
        for v in [1.0f32, 2.0, 3.0, 4.0] {
            b.extend_from_slice(&v.to_le_bytes());
        }
        // "b": i8 [3]
        b.extend_from_slice(&3u16.to_le_bytes());
        b.extend_from_slice(b"t.b");
        b.push(DT_I8);
        b.push(1);
        b.extend_from_slice(&3u32.to_le_bytes());
        b.extend_from_slice(&3u64.to_le_bytes());
        b.extend_from_slice(&[0xFF, 0x00, 0x7F]);
        b
    }

    #[test]
    fn parse_sample() {
        let wf = WeightFile::parse(&sample()).unwrap();
        assert_eq!(wf.order, vec!["t.a", "t.b"]);
        let a = wf.require("t.a").unwrap();
        assert_eq!(a.shape, vec![2, 2]);
        assert_eq!(a.as_f32(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a.try_f32().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let b = wf.require("t.b").unwrap();
        assert_eq!(b.as_i8(), &[0xFF, 0x00, 0x7F]);
        assert!(b.try_f32().is_err(), "try_f32 on i8 is a clean error");
        assert_eq!(wf.nbytes(), 19);
    }

    #[test]
    fn rejects_corruption() {
        let mut s = sample();
        s[0] = b'X';
        assert!(WeightFile::parse(&s).is_err());
        let mut t = sample();
        t.truncate(t.len() - 1);
        assert!(WeightFile::parse(&t).is_err());
        let mut u = sample();
        u.push(0);
        assert!(WeightFile::parse(&u).is_err());
    }

    #[test]
    fn writer_roundtrips_through_parser() {
        let mut w = WeightWriter::new();
        w.push_f32("t.a", &[2, 2], &[1.0, 2.0, 3.0, 4.0]);
        w.push("t.b", DT_I8, &[3], &[0xFF, 0x00, 0x7F]);
        let bytes = w.finish();
        // Bit-identical to the hand-rolled sample container.
        assert_eq!(bytes, sample());
        let wf = WeightFile::parse(&bytes).unwrap();
        assert_eq!(wf.require("t.a").unwrap().as_f32(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    /// Regression: a crafted huge `nbytes` used to overflow `off + n` and
    /// panic (debug) or wrap into an out-of-bounds slice (release). It must
    /// be InvalidData.
    #[test]
    fn huge_nbytes_is_invalid_data_not_panic() {
        let mut b = Vec::new();
        b.extend_from_slice(b"MNNW");
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&1u16.to_le_bytes());
        b.push(b'x');
        b.push(DT_F32);
        b.push(1);
        b.extend_from_slice(&4u32.to_le_bytes());
        b.extend_from_slice(&u64::MAX.to_le_bytes());
        let err = WeightFile::parse(&b).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    }

    /// Regression: a payload whose size disagrees with dtype × shape used to
    /// parse fine and blow up later (wrong element count at use time). It
    /// must be rejected at load.
    #[test]
    fn shape_payload_mismatch_rejected_at_load() {
        let mut b = Vec::new();
        b.extend_from_slice(b"MNNW");
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&1u16.to_le_bytes());
        b.push(b'x');
        b.push(DT_F32);
        b.push(2);
        b.extend_from_slice(&2u32.to_le_bytes());
        b.extend_from_slice(&2u32.to_le_bytes());
        // Claims 12 bytes for a [2,2] f32 tensor (needs 16).
        b.extend_from_slice(&12u64.to_le_bytes());
        b.extend_from_slice(&[0u8; 12]);
        let err = WeightFile::parse(&b).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    }

    #[test]
    fn unknown_dtype_rejected() {
        let mut b = Vec::new();
        b.extend_from_slice(b"MNNW");
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&1u16.to_le_bytes());
        b.push(b'x');
        b.push(0xEE); // no such dtype
        b.push(1);
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&1u64.to_le_bytes());
        b.push(0);
        assert!(WeightFile::parse(&b).is_err());
    }

    #[test]
    fn shape_product_overflow_rejected() {
        let mut b = Vec::new();
        b.extend_from_slice(b"MNNW");
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&1u16.to_le_bytes());
        b.push(b'x');
        b.push(DT_F32);
        b.push(3);
        for _ in 0..3 {
            b.extend_from_slice(&u32::MAX.to_le_bytes());
        }
        b.extend_from_slice(&16u64.to_le_bytes());
        b.extend_from_slice(&[0u8; 16]);
        let err = WeightFile::parse(&b).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    }

    /// Property: every strict prefix of a valid container is an error —
    /// never a panic, never a silent partial parse.
    #[test]
    fn truncation_always_errors_never_panics() {
        let full = {
            let mut w = WeightWriter::new();
            w.push_f32("t.a", &[4, 3], &[0.5f32; 12]);
            w.push("t.b", DT_I8, &[7], &[1, 2, 3, 4, 5, 6, 7]);
            w.push("t.c", DT_U8, &[2, 2], &[9, 9, 9, 9]);
            w.finish()
        };
        prop_check(300, |rng| {
            let cut = rng.below(full.len());
            match WeightFile::parse(&full[..cut]) {
                Err(_) => Ok(()),
                Ok(_) => Err(format!("prefix of {cut} bytes parsed as a whole container")),
            }
        });
    }

    /// Property: random bit flips anywhere in the container never panic;
    /// when the flipped file still parses (payload flips are undetectable —
    /// no checksums, documented), every tensor's payload size still matches
    /// its dtype × shape, so downstream indexing stays in bounds.
    #[test]
    fn bit_flips_never_panic_and_preserve_size_invariants() {
        let full = {
            let mut w = WeightWriter::new();
            w.push_f32("flip.a", &[3, 5], &[1.25f32; 15]);
            w.push("flip.b", DT_I8, &[11], &[7u8; 11]);
            w.finish()
        };
        prop_check(500, |rng| {
            let mut b = full.clone();
            let flips = 1 + rng.below(4);
            for _ in 0..flips {
                let i = rng.below(b.len());
                let bit = rng.below(8);
                b[i] ^= 1 << bit;
            }
            match WeightFile::parse(&b) {
                Err(_) => Ok(()),
                Ok(wf) => {
                    for t in wf.tensors.values() {
                        let size = match dtype_size(t.dtype) {
                            Some(s) => s,
                            None => return Err(format!("{}: unknown dtype parsed", t.name)),
                        };
                        if t.data.len() != t.elements() * size {
                            return Err(format!(
                                "{}: {} payload bytes for shape {:?}",
                                t.name,
                                t.data.len(),
                                t.shape
                            ));
                        }
                    }
                    Ok(())
                }
            }
        });
    }

    #[test]
    #[cfg(target_pointer_width = "64")]
    #[should_panic(expected = "exceeds u32 container field")]
    fn writer_rejects_dims_wider_than_the_field() {
        // Regression: dimensions were written with `as u32`, silently
        // truncating anything wider; now the writer fails loudly.
        let mut w = WeightWriter::new();
        w.push("t", DT_U8, &[1usize << 40, 1], &[]);
    }

    #[test]
    fn parses_real_artifacts() {
        let p = std::path::PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/weights.bin"));
        if !p.exists() {
            return;
        }
        let wf = WeightFile::load(&p).unwrap();
        assert!(wf.order.len() >= 100, "{} tensors", wf.order.len());
        assert!(wf.get("L0.wq.q").is_some());
        assert!(wf.get("lm_head.q").is_some());
        // int4 MLP weights are packed: gate has half the bytes of its dims.
        let gate = wf.require("L0.gate.q").unwrap();
        assert_eq!(gate.dtype, DT_U8);
        // gate: [inter=704, hidden/2=128] — two nibbles per byte along k.
        assert_eq!(gate.shape, vec![704, 128]);
    }
}
