//! Model-conversion computation-graph IR (paper §3).
//!
//! MNN-LLM's conversion pipeline takes an exported graph and applies
//! *RMSNorm fusion* and *Attention fusion*, replaces Linear layers with
//! custom parameter-external ops (so export doesn't materialize weights),
//! and leaves hooks for runtime LoRA bypasses. This module rebuilds that
//! pipeline: a small SSA-ish graph IR, pattern-matching fusion passes, and
//! a reference interpreter so every rewrite is checked for value
//! preservation (the tests run fused vs unfused graphs on real tensors).

use std::collections::HashMap;

/// Tensor value: shape + row-major data (interpreter currency).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data }
    }

    pub fn rows(&self) -> usize {
        self.shape[..self.shape.len() - 1].iter().product()
    }

    pub fn cols(&self) -> usize {
        *self.shape.last().unwrap()
    }
}

pub type NodeId = usize;

/// Graph operations. `Pow2`/`MeanLast`/`AddEps`/`Rsqrt`/`Mul` are the
/// primitive chain RMSNorm exports as; `RmsNorm` / `FusedAttention` /
/// `QuantLinear` are the fused custom ops the converter produces.
#[derive(Clone, Debug, PartialEq)]
pub enum Op {
    /// Graph input placeholder.
    Input(String),
    /// Named external parameter (weights live outside the graph — §3's
    /// "ONNX export to focus on the computation graph without parameters").
    Param(String),
    /// Dense y = x · Wᵀ (W from a Param node).
    MatMul,
    Add,
    Mul,
    /// x², elementwise.
    Pow2,
    /// Mean over the last axis, keepdim.
    MeanLast,
    /// + ε scalar.
    AddEps(f32),
    Rsqrt,
    /// Softmax over the last axis.
    SoftmaxLast,
    /// Scale by a constant (1/√d in exported attention).
    Scale(f32),
    /// y = xᵀ over the last two axes (exported attention's K transpose).
    TransposeLast2,
    // ---- fused custom ops (converter output) ----
    RmsNorm { eps: f32 },
    FusedAttention { scale: f32 },
    /// Linear with externally-stored quantized weights.
    QuantLinear { param: String },
}

/// One node: op + input edges.
#[derive(Clone, Debug)]
pub struct Node {
    pub id: NodeId,
    pub op: Op,
    pub inputs: Vec<NodeId>,
}

/// The computation graph (append-only ids; `output` marks the root).
#[derive(Clone, Debug, Default)]
pub struct Graph {
    pub nodes: Vec<Node>,
    pub output: NodeId,
}

impl Graph {
    pub fn add(&mut self, op: Op, inputs: Vec<NodeId>) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(Node { id, op, inputs });
        id
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    /// Number of nodes reachable from the output (dead nodes don't count).
    pub fn live_nodes(&self) -> usize {
        let mut seen = vec![false; self.nodes.len()];
        let mut stack = vec![self.output];
        let mut n = 0;
        while let Some(id) = stack.pop() {
            if seen[id] {
                continue;
            }
            seen[id] = true;
            n += 1;
            stack.extend(&self.nodes[id].inputs);
        }
        n
    }

    /// Build the canonical *exported* (unfused) RMSNorm chain:
    /// x * rsqrt(mean(x²)+eps) * w.
    pub fn build_rmsnorm_chain(&mut self, x: NodeId, w: NodeId, eps: f32) -> NodeId {
        let p = self.add(Op::Pow2, vec![x]);
        let m = self.add(Op::MeanLast, vec![p]);
        let e = self.add(Op::AddEps(eps), vec![m]);
        let r = self.add(Op::Rsqrt, vec![e]);
        let xn = self.add(Op::Mul, vec![x, r]);
        self.add(Op::Mul, vec![xn, w])
    }

    /// Build the exported attention chain:
    /// softmax(scale(q) · kᵀ) · v  (single head, 2-D q/k/v).
    pub fn build_attention_chain(&mut self, q: NodeId, k: NodeId, v: NodeId, scale: f32) -> NodeId {
        let qs = self.add(Op::Scale(scale), vec![q]);
        let kt = self.add(Op::TransposeLast2, vec![k]);
        let logits = self.add(Op::MatMul, vec![qs, kt]);
        let probs = self.add(Op::SoftmaxLast, vec![logits]);
        self.add(Op::MatMul, vec![probs, v])
    }
}

// ---------------------------------------------------------------------------
// Conversion passes (§3)
// ---------------------------------------------------------------------------

/// Pass 1 — RMSNorm fusion: rewrite the 6-node exported chain into one
/// `RmsNorm` node. Returns how many fusions fired.
pub fn fuse_rmsnorm(g: &mut Graph) -> usize {
    let mut fused = 0;
    for id in 0..g.nodes.len() {
        // Match  Mul(Mul(x, Rsqrt(AddEps(MeanLast(Pow2(x))))), w).
        let Op::Mul = g.nodes[id].op else { continue };
        let [xn, w] = g.nodes[id].inputs[..] else { continue };
        let Op::Mul = g.nodes[xn].op else { continue };
        let [x, r] = g.nodes[xn].inputs[..] else { continue };
        let Op::Rsqrt = g.nodes[r].op else { continue };
        let e = g.nodes[r].inputs[0];
        let Op::AddEps(eps) = g.nodes[e].op else { continue };
        let m = g.nodes[e].inputs[0];
        let Op::MeanLast = g.nodes[m].op else { continue };
        let p = g.nodes[m].inputs[0];
        let Op::Pow2 = g.nodes[p].op else { continue };
        if g.nodes[p].inputs[0] != x {
            continue; // the squared input must be the normalized input
        }
        g.nodes[id].op = Op::RmsNorm { eps };
        g.nodes[id].inputs = vec![x, w];
        fused += 1;
    }
    fused
}

/// Pass 2 — Attention fusion: rewrite
/// MatMul(SoftmaxLast(MatMul(Scale(q), TransposeLast2(k))), v)
/// into one `FusedAttention` node.
pub fn fuse_attention(g: &mut Graph) -> usize {
    let mut fused = 0;
    for id in 0..g.nodes.len() {
        let Op::MatMul = g.nodes[id].op else { continue };
        let [probs, v] = g.nodes[id].inputs[..] else { continue };
        let Op::SoftmaxLast = g.nodes[probs].op else { continue };
        let logits = g.nodes[probs].inputs[0];
        let Op::MatMul = g.nodes[logits].op else { continue };
        let [qs, kt] = g.nodes[logits].inputs[..] else { continue };
        let Op::Scale(scale) = g.nodes[qs].op else { continue };
        let q = g.nodes[qs].inputs[0];
        let Op::TransposeLast2 = g.nodes[kt].op else { continue };
        let k = g.nodes[kt].inputs[0];
        g.nodes[id].op = Op::FusedAttention { scale };
        g.nodes[id].inputs = vec![q, k, v];
        fused += 1;
    }
    fused
}

/// Pass 3 — Linear externalization: MatMul(x, Param(name)) becomes
/// QuantLinear{param: name} so the exporter never serializes weights (§3).
pub fn externalize_linears(g: &mut Graph) -> usize {
    let mut n = 0;
    for id in 0..g.nodes.len() {
        let Op::MatMul = g.nodes[id].op else { continue };
        let [x, w] = g.nodes[id].inputs[..] else { continue };
        let Op::Param(name) = &g.nodes[w].op else { continue };
        g.nodes[id].op = Op::QuantLinear { param: name.clone() };
        g.nodes[id].inputs = vec![x];
        n += 1;
    }
    n
}

/// The full conversion pipeline in the paper's order.
pub fn convert(g: &mut Graph) -> (usize, usize, usize) {
    let a = fuse_attention(g);
    let r = fuse_rmsnorm(g);
    let l = externalize_linears(g);
    (r, a, l)
}

// ---------------------------------------------------------------------------
// Reference interpreter (value-preservation oracle for the passes)
// ---------------------------------------------------------------------------

/// Execution environment: graph inputs + external parameters by name.
#[derive(Default)]
pub struct Env {
    pub inputs: HashMap<String, Tensor>,
    pub params: HashMap<String, Tensor>,
}

fn matmul_t(x: &Tensor, wt: &Tensor) -> Tensor {
    // x: [m, k] · wt: [k, n] (already transposed weight or plain matrix).
    let (m, k) = (x.rows(), x.cols());
    let n = wt.cols();
    assert_eq!(wt.rows(), k, "matmul shape");
    let mut out = vec![0f32; m * n];
    for r in 0..m {
        for c in 0..n {
            let mut acc = 0f32;
            for i in 0..k {
                acc += x.data[r * k + i] * wt.data[i * n + c];
            }
            out[r * n + c] = acc;
        }
    }
    Tensor::new(vec![m, n], out)
}

/// Evaluate the graph on `env` (panics on malformed graphs — this is the
/// conversion-time oracle, not the serving path).
pub fn eval(g: &Graph, env: &Env) -> Tensor {
    let mut vals: Vec<Option<Tensor>> = vec![None; g.nodes.len()];
    fn get(vals: &mut Vec<Option<Tensor>>, g: &Graph, env: &Env, id: NodeId) -> Tensor {
        if let Some(v) = &vals[id] {
            return v.clone();
        }
        let node = &g.nodes[id];
        let ins: Vec<Tensor> = node.inputs.iter().map(|&i| get(vals, g, env, i)).collect();
        let out = match &node.op {
            Op::Input(name) => env.inputs[name].clone(),
            Op::Param(name) => env.params[name].clone(),
            Op::MatMul => matmul_t(&ins[0], &ins[1]),
            Op::Add => {
                let mut d = ins[0].data.clone();
                for (a, b) in d.iter_mut().zip(&ins[1].data) {
                    *a += b;
                }
                Tensor::new(ins[0].shape.clone(), d)
            }
            Op::Mul => {
                // Elementwise with last-dim or per-row broadcast.
                let (a, b) = (&ins[0], &ins[1]);
                let mut d = a.data.clone();
                if b.data.len() == a.data.len() {
                    for (x, y) in d.iter_mut().zip(&b.data) {
                        *x *= y;
                    }
                } else if b.data.len() == a.cols() {
                    for r in 0..a.rows() {
                        for c in 0..a.cols() {
                            d[r * a.cols() + c] *= b.data[c];
                        }
                    }
                } else if b.data.len() == a.rows() {
                    for r in 0..a.rows() {
                        for c in 0..a.cols() {
                            d[r * a.cols() + c] *= b.data[r];
                        }
                    }
                } else {
                    panic!("mul broadcast {:?} vs {:?}", a.shape, b.shape);
                }
                Tensor::new(a.shape.clone(), d)
            }
            Op::Pow2 => Tensor::new(
                ins[0].shape.clone(),
                ins[0].data.iter().map(|v| v * v).collect(),
            ),
            Op::MeanLast => {
                let (rows, cols) = (ins[0].rows(), ins[0].cols());
                let d: Vec<f32> = (0..rows)
                    .map(|r| ins[0].data[r * cols..(r + 1) * cols].iter().sum::<f32>() / cols as f32)
                    .collect();
                Tensor::new(vec![rows], d)
            }
            Op::AddEps(e) => Tensor::new(
                ins[0].shape.clone(),
                ins[0].data.iter().map(|v| v + e).collect(),
            ),
            Op::Rsqrt => Tensor::new(
                ins[0].shape.clone(),
                ins[0].data.iter().map(|v| 1.0 / v.sqrt()).collect(),
            ),
            Op::SoftmaxLast => {
                let (rows, cols) = (ins[0].rows(), ins[0].cols());
                let mut d = ins[0].data.clone();
                for r in 0..rows {
                    crate::cpu::activation::softmax_inplace(&mut d[r * cols..(r + 1) * cols]);
                }
                Tensor::new(ins[0].shape.clone(), d)
            }
            Op::Scale(s) => Tensor::new(
                ins[0].shape.clone(),
                ins[0].data.iter().map(|v| v * s).collect(),
            ),
            Op::TransposeLast2 => {
                let (r, c) = (ins[0].rows(), ins[0].cols());
                let mut d = vec![0f32; r * c];
                for i in 0..r {
                    for j in 0..c {
                        d[j * r + i] = ins[0].data[i * c + j];
                    }
                }
                Tensor::new(vec![c, r], d)
            }
            Op::RmsNorm { eps } => {
                let (rows, cols) = (ins[0].rows(), ins[0].cols());
                let mut d = vec![0f32; rows * cols];
                crate::cpu::activation::rmsnorm(&ins[0].data, &ins[1].data, &mut d, rows, *eps);
                Tensor::new(ins[0].shape.clone(), d)
            }
            Op::FusedAttention { scale } => {
                // q:[s,d], k:[t,d], v:[t,d] → softmax(scale·q·kᵀ)·v.
                let (q, k, v) = (&ins[0], &ins[1], &ins[2]);
                let (s, d) = (q.rows(), q.cols());
                let t = k.rows();
                let mut out = vec![0f32; s * v.cols()];
                let mut scores = vec![0f32; t];
                for i in 0..s {
                    for j in 0..t {
                        let mut acc = 0f32;
                        for x in 0..d {
                            acc += q.data[i * d + x] * scale * k.data[j * d + x];
                        }
                        scores[j] = acc;
                    }
                    crate::cpu::activation::softmax_inplace(&mut scores);
                    for j in 0..t {
                        for c in 0..v.cols() {
                            out[i * v.cols() + c] += scores[j] * v.data[j * v.cols() + c];
                        }
                    }
                }
                Tensor::new(vec![s, v.cols()], out)
            }
            Op::QuantLinear { param } => {
                // Interpreter uses the f32 parameter; the engine swaps in
                // the packed quantized kernel at load time.
                let w = &env.params[param];
                matmul_t(&ins[0], w)
            }
        };
        vals[id] = Some(out.clone());
        out
    }
    get(&mut vals, g, env, g.output)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn env_with(rng: &mut Rng, s: usize, d: usize) -> Env {
        let mut env = Env::default();
        env.inputs.insert("x".into(), Tensor::new(vec![s, d], rng.normal_vec(s * d)));
        env.inputs.insert("q".into(), Tensor::new(vec![s, d], rng.normal_vec(s * d)));
        env.inputs.insert("k".into(), Tensor::new(vec![s, d], rng.normal_vec(s * d)));
        env.inputs.insert("v".into(), Tensor::new(vec![s, d], rng.normal_vec(s * d)));
        env.params.insert("gamma".into(), Tensor::new(vec![d], rng.normal_vec(d)));
        env.params.insert("w0".into(), Tensor::new(vec![d, d], rng.normal_vec(d * d)));
        env
    }

    fn close(a: &Tensor, b: &Tensor) {
        assert_eq!(a.shape, b.shape);
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() < 1e-4 * (1.0 + x.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn rmsnorm_fusion_preserves_values() {
        let mut rng = Rng::new(1);
        let env = env_with(&mut rng, 5, 16);
        let mut g = Graph::default();
        let x = g.add(Op::Input("x".into()), vec![]);
        let w = g.add(Op::Param("gamma".into()), vec![]);
        g.output = g.build_rmsnorm_chain(x, w, 1e-6);
        let before = eval(&g, &env);
        let live_before = g.live_nodes();
        assert_eq!(fuse_rmsnorm(&mut g), 1);
        let after = eval(&g, &env);
        close(&before, &after);
        assert!(g.live_nodes() < live_before, "fusion shrinks the live graph");
        assert!(matches!(g.nodes[g.output].op, Op::RmsNorm { .. }));
    }

    #[test]
    fn attention_fusion_preserves_values() {
        let mut rng = Rng::new(2);
        let env = env_with(&mut rng, 6, 8);
        let mut g = Graph::default();
        let q = g.add(Op::Input("q".into()), vec![]);
        let k = g.add(Op::Input("k".into()), vec![]);
        let v = g.add(Op::Input("v".into()), vec![]);
        g.output = g.build_attention_chain(q, k, v, 1.0 / (8f32).sqrt());
        let before = eval(&g, &env);
        assert_eq!(fuse_attention(&mut g), 1);
        let after = eval(&g, &env);
        close(&before, &after);
        assert!(matches!(g.nodes[g.output].op, Op::FusedAttention { .. }));
    }

    #[test]
    fn linear_externalization() {
        let mut rng = Rng::new(3);
        let env = env_with(&mut rng, 4, 16);
        let mut g = Graph::default();
        let x = g.add(Op::Input("x".into()), vec![]);
        let w = g.add(Op::Param("w0".into()), vec![]);
        g.output = g.add(Op::MatMul, vec![x, w]);
        let before = eval(&g, &env);
        assert_eq!(externalize_linears(&mut g), 1);
        let after = eval(&g, &env);
        close(&before, &after);
        assert!(matches!(&g.nodes[g.output].op, Op::QuantLinear { param } if param == "w0"));
    }

    #[test]
    fn full_pipeline_on_mini_block() {
        // One decoder-ish block: rmsnorm → attention(q=k=v=normed) →
        // residual add → linear. All three passes fire; values preserved.
        let mut rng = Rng::new(4);
        let env = env_with(&mut rng, 4, 16);
        let mut g = Graph::default();
        let x = g.add(Op::Input("x".into()), vec![]);
        let gamma = g.add(Op::Param("gamma".into()), vec![]);
        let normed = g.build_rmsnorm_chain(x, gamma, 1e-6);
        let attn = g.build_attention_chain(normed, normed, normed, 0.25);
        let res = g.add(Op::Add, vec![x, attn]);
        let w0 = g.add(Op::Param("w0".into()), vec![]);
        g.output = g.add(Op::MatMul, vec![res, w0]);
        let before = eval(&g, &env);
        let (r, a, l) = convert(&mut g);
        assert_eq!((r, a, l), (1, 1, 1));
        let after = eval(&g, &env);
        close(&before, &after);
    }

    #[test]
    fn partial_patterns_do_not_fuse() {
        // RMSNorm chain with the wrong input wiring must NOT fuse.
        let mut rng = Rng::new(5);
        let env = env_with(&mut rng, 3, 8);
        let mut g = Graph::default();
        let x = g.add(Op::Input("x".into()), vec![]);
        let q = g.add(Op::Input("q".into()), vec![]);
        let w = g.add(Op::Param("gamma".into()), vec![]);
        // mean(q²) applied to x — not an RMSNorm of x.
        let p = g.add(Op::Pow2, vec![q]);
        let m = g.add(Op::MeanLast, vec![p]);
        let e = g.add(Op::AddEps(1e-6), vec![m]);
        let r = g.add(Op::Rsqrt, vec![e]);
        let xn = g.add(Op::Mul, vec![x, r]);
        g.output = g.add(Op::Mul, vec![xn, w]);
        let before = eval(&g, &env);
        assert_eq!(fuse_rmsnorm(&mut g), 0, "mismatched pattern must not fuse");
        close(&before, &eval(&g, &env));
    }

    #[test]
    fn fusion_is_idempotent() {
        let mut g = Graph::default();
        let x = g.add(Op::Input("x".into()), vec![]);
        let w = g.add(Op::Param("gamma".into()), vec![]);
        g.output = g.build_rmsnorm_chain(x, w, 1e-5);
        assert_eq!(fuse_rmsnorm(&mut g), 1);
        assert_eq!(fuse_rmsnorm(&mut g), 0);
        assert_eq!(fuse_attention(&mut g), 0);
    }
}
