//! Decoder-only transformer dimensions (mirrors python/compile/model.py),
//! plus the analytic configs of the models the paper benchmarks.

/// Model dimensions; `max_len` is the static KV capacity of the AOT graphs.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub hidden: usize,
    pub inter: usize,
    pub layers: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub max_len: usize,
    pub rope_theta: f64,
    pub rms_eps: f32,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    pub fn kv_dim(&self) -> usize {
        self.kv_heads * self.head_dim()
    }

    /// Embedding (or lm_head) parameter count.
    pub fn embedding_params(&self) -> u64 {
        (self.vocab * self.hidden) as u64
    }

    /// Per-decoder-layer parameter count (weights + biases + norms).
    pub fn layer_params(&self) -> u64 {
        let h = self.hidden as u64;
        let kv = self.kv_dim() as u64;
        let i = self.inter as u64;
        h * h + 2 * h * kv + h * h      // wq, wk, wv, wo
            + h + 2 * kv                // qkv biases
            + 3 * h * i                 // gate, up, down
            + 2 * h                     // norms
    }

    /// Total parameters with untied lm_head (Table 1's structure).
    pub fn total_params(&self) -> u64 {
        2 * self.embedding_params() + self.layers as u64 * self.layer_params() + self.hidden as u64
    }

    /// Decode-phase weight bytes streamed per token under the combined
    /// quantization policy (§4.2): int8 attention + lm_head, int4 MLP,
    /// embedding in flash (excluded).
    pub fn decode_weight_bytes(&self) -> u64 {
        let h = self.hidden as u64;
        let kv = self.kv_dim() as u64;
        let i = self.inter as u64;
        let attn = h * h + 2 * h * kv + h * h; // int8 → 1 B each
        let mlp = 3 * h * i / 2; // int4 → 0.5 B each
        self.layers as u64 * (attn + mlp) + self.embedding_params() // lm_head int8
    }

    /// Qwen2-7B (paper Table 1 dims).
    pub fn qwen2_7b() -> Self {
        ModelConfig {
            name: "qwen2-7b".into(),
            vocab: 151646,
            hidden: 3584,
            inter: 18944,
            layers: 28,
            heads: 28,
            kv_heads: 4,
            max_len: 32768,
            rope_theta: 1e6,
            rms_eps: 1e-6,
        }
    }

    /// Qwen2-1.5B.
    pub fn qwen2_1_5b() -> Self {
        ModelConfig {
            name: "qwen2-1.5b".into(),
            vocab: 151646,
            hidden: 1536,
            inter: 8960,
            layers: 28,
            heads: 12,
            kv_heads: 2,
            max_len: 32768,
            rope_theta: 1e6,
            rms_eps: 1e-6,
        }
    }

    /// Llama3-8B.
    pub fn llama3_8b() -> Self {
        ModelConfig {
            name: "llama3-8b".into(),
            vocab: 128256,
            hidden: 4096,
            inter: 14336,
            layers: 32,
            heads: 32,
            kv_heads: 8,
            max_len: 8192,
            rope_theta: 5e5,
            rms_eps: 1e-5,
        }
    }

    /// The tiny executed config (must match python/compile/model.py TINY).
    pub fn tiny_qwen2() -> Self {
        ModelConfig {
            name: "tiny-qwen2".into(),
            vocab: 2048,
            hidden: 256,
            inter: 704,
            layers: 4,
            heads: 4,
            kv_heads: 2,
            max_len: 512,
            rope_theta: 1e4,
            rms_eps: 1e-6,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qwen2_7b_table1_structure() {
        let c = ModelConfig::qwen2_7b();
        // vocab × hidden = 0.5435 B; the paper's "1.09 B Embedding" counts
        // embedding + lm_head storage (EXPERIMENTS.md §Table 1).
        assert!((c.embedding_params() as f64 / 1e9 - 0.5435).abs() < 0.01);
        assert!((2.0 * c.embedding_params() as f64 / 1e9 - 1.09).abs() < 0.01);
        let total = c.total_params() as f64 / 1e9;
        assert!((7.0..7.7).contains(&total), "total {total}");
        // emb + head ≈ 14–15% of the total (the paper's "about 15%").
        let frac = 2.0 * c.embedding_params() as f64 / c.total_params() as f64;
        assert!((0.13..0.17).contains(&frac), "frac {frac}");
    }

    #[test]
    fn head_dims() {
        let c = ModelConfig::qwen2_7b();
        assert_eq!(c.head_dim(), 128);
        assert_eq!(c.kv_dim(), 512);
        let t = ModelConfig::tiny_qwen2();
        assert_eq!(t.head_dim(), 64);
        assert_eq!(t.kv_dim(), 128);
    }

    #[test]
    fn decode_bytes_smaller_than_fp16() {
        let c = ModelConfig::qwen2_7b();
        let fp16 = (c.layers as u64 * c.layer_params() + c.embedding_params()) * 2;
        assert!(c.decode_weight_bytes() < fp16 / 2);
    }
}
